"""End-to-end driver: decentralized FL over a Walker constellation's
time-varying ISL visibility schedule — the paper's motivating deployment.

8 satellites (= 8 forced host devices), each training a reduced LM on its
OWN data shard; communication happens ONLY through the paper's universal
TDM algorithm (getMeas -> matchings -> ppermute). Every round:

    local SGD steps  ->  TDM exchange over the slot's visibility relation

The script reports loss and consensus distance per round, then simulates a
satellite failure: the schedule is restricted (paper skip-slot semantics)
and training continues with the survivors.

Run:  PYTHONPATH=src python examples/train_fl_constellation.py
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import archs
from repro.core.schedule import WalkerConstellation
from repro.data import pipeline
from repro.launch import fl_train
from repro.launch.elastic import reschedule
from repro.models.config import ShapeConfig
from repro.optim import adamw


N_SATS = 8
ROUNDS = 10
LOCAL_STEPS = 2


def main():
    cfg = archs.smoke_cfg(archs.get("mamba2-780m"))
    opt_cfg = adamw.OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=100)
    fl_cfg = fl_train.FLConfig(mode="tdm", local_steps=LOCAL_STEPS)
    shape = ShapeConfig("fl", "train", 32, 4)   # per-sat batch of 4 rows

    mesh = jax.make_mesh((N_SATS,), ("data",))
    constellation = WalkerConstellation(total=N_SATS, planes=2)
    state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N_SATS)

    def stacked_batch(round_idx):
        per_node = []
        for sat in range(N_SATS):
            bs = [
                pipeline.host_batch(cfg, shape, step=round_idx * LOCAL_STEPS + h,
                                    seed=1000 + sat)
                for h in range(LOCAL_STEPS)
            ]
            per_node.append({
                k: np.stack([b[k] for b in bs]) for k in bs[0]
            })
        return {
            k: jnp.asarray(np.stack([pn[k] for pn in per_node]))
            for k in per_node[0]
        }

    print(f"{N_SATS} satellites, Walker {constellation.planes}-plane, "
          f"TDM-FL ({fl_cfg.local_steps} local steps/round)")
    alive = set(range(N_SATS))
    round_fns = {}
    for rnd in range(ROUNDS):
        rel = constellation.visibility(rnd).restrict(alive)
        key = tuple(sorted(rel.pairs))
        if key not in round_fns:
            round_fns[key] = fl_train.build_fl_round(
                cfg, opt_cfg, mesh, N_SATS, fl_cfg, rel
            )
        state, losses = round_fns[key](state, stacked_batch(rnd))
        dist = fl_train.consensus_distance(state["params"])
        print(f"round {rnd:2d}  mean-loss {float(losses.mean()):7.4f}  "
              f"consensus-dist {dist:.4f}  links {len(rel)//2}")
        if rnd == 6:
            alive -= {3}
            print("  !! satellite 3 lost — rescheduling (skip-slot semantics)")
    print("done — surviving satellites converged together "
          f"(consensus {fl_train.consensus_distance(state['params']):.4f})")


if __name__ == "__main__":
    main()
