"""End-to-end driver: FL over a constellation's geometry-derived
time-varying ISL visibility — the paper's motivating deployment.

Two modes (``--mode``):

- ``tdm`` (default) — decentralized FL: 8 MEO satellites (= 8 forced host
  devices) in a 2-plane Walker pattern, each training a reduced LM on its
  OWN data shard; communication happens ONLY through the paper's universal
  TDM algorithm (getMeas -> matchings -> ppermute) over each contact-plan
  step's visibility relation. Mid-run a satellite failure restricts the
  slot relations (paper skip-slot semantics) and training continues.
- ``groundseg`` — the paper's *centralized* generic FLA over the ground
  segment: 6 satellites + 2 ground stations. Satellite updates ride
  store-and-forward multi-hop ISL relays to the ground sinks along
  earliest-delivery contact-graph routes, the sinks FedAvg (hierarchical:
  regional models, pooled over terrestrial backhaul every other round),
  and the global model floods back on the downlink slots.
  ``--pipeline-depth 2`` overlaps round r's downlink with round r+1's
  uplink inside one contact window (disjoint slot capacity);
  ``--max-staleness K`` lets undelivered payloads persist up to K windows
  (delivered late, they are down-weighted by the staleness decay).

The topology is NOT invented: orbits are propagated, ISLs require line of
sight past the Earth's limb and a range gate, ground links an elevation
mask, and the slot relations come straight from the contact plan.

Run:  PYTHONPATH=src python examples/train_fl_constellation.py [--mode groundseg]
      (add --rounds 2 for the CI smoke run)
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import argparse

import jax
import numpy as np

from repro.configs import archs
from repro.constellation import cost
from repro.constellation.scenario import ScenarioSpec, ShellSpec, build_scenario
from repro.data import pipeline
from repro.launch import fl_train
from repro.models.config import ShapeConfig
from repro.optim import adamw


ROUNDS = 10
LOCAL_STEPS = 2
PAYLOAD_BYTES = 1 << 22     # ~4 MiB of smoke-model params per exchange


def setup(n_sats: int, n_ground: int = 0, rounds=ROUNDS):
    cfg = archs.smoke_cfg(archs.get("mamba2-780m"))
    opt_cfg = adamw.OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=100)
    shape = ShapeConfig("fl", "train", 32, 4)   # per-node batch of 4 rows

    # --- geometry: O3b-style MEO shell, visibility from orbital mechanics,
    # packaged by the unified scenario factory (same sky as the serving
    # example and the groundseg benchmarks)
    scn = build_scenario(ScenarioSpec(
        shells=(ShellSpec(planes=2, per_plane=n_sats // 2),),
        n_ground=n_ground,
        steps=max(rounds, 4),
        max_range_km=14_000.0,
    ))
    return cfg, opt_cfg, shape, scn


def make_batch_fn(cfg, shape, n_nodes):
    def batch_fn(round_idx):
        per_node = []
        for sat in range(n_nodes):
            bs = [
                pipeline.host_batch(cfg, shape, step=round_idx * LOCAL_STEPS + h,
                                    seed=1000 + sat)
                for h in range(LOCAL_STEPS)
            ]
            per_node.append({
                k: np.stack([b[k] for b in bs]) for k in bs[0]
            })
        return {
            k: np.stack([pn[k] for pn in per_node]) for k in per_node[0]
        }

    return batch_fn


def main_tdm(rounds=ROUNDS):
    n_sats = 8
    cfg, opt_cfg, shape, scn = setup(n_sats, rounds=rounds)
    geom, plan = scn.geom, scn.plan
    fl_cfg = fl_train.FLConfig(mode="tdm", local_steps=LOCAL_STEPS)
    windows = plan.windows()
    est = cost.plan_cost(plan, PAYLOAD_BYTES, mode="getmeas")
    print(
        f"{n_sats} satellites, Walker delta {geom.planes}-plane @ "
        f"{geom.altitude_km:.0f} km (period {geom.period_s/60:.0f} min): "
        f"{len(windows)} contact windows, est. comm "
        f"{est.time_s:.2f} s / {est.bytes_on_isl/1e9:.2f} GB per orbit"
    )
    for w in windows[:4]:
        print(
            f"  contact {w.i}<->{w.j}  [{w.t_start_s/60.0:5.1f}, "
            f"{w.t_end_s/60.0:5.1f}] min  {w.mean_rate_bps/1e6:.0f} Mb/s"
        )

    mesh = jax.make_mesh((n_sats,), ("data",))
    state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, n_sats)
    alive = set(range(n_sats))

    def on_round(log):
        print(f"round {log.round:2d}  mean-loss {log.loss:7.4f}  "
              f"consensus-dist {log.consensus:.4f}  links {log.n_links}")
        if log.round == 6:
            alive.discard(3)
            print("  !! satellite 3 lost — rescheduling (skip-slot semantics)")

    res = fl_train.run(fl_train.ConstellationRun(
        cfg, opt_cfg, mesh, n_sats, fl_cfg, plan, state,
        make_batch_fn(cfg, shape, n_sats),
        rounds=rounds, alive=alive, on_round=on_round,
    ))
    state = res.state
    print(f"done — {res.n_rounds} rounds, surviving satellites converged "
          f"together "
          f"(consensus {fl_train.consensus_distance(state['params']):.4f})")


def main_groundseg(rounds=ROUNDS, pipeline_depth=1, max_staleness=0):
    n_sats = 6
    cfg, opt_cfg, shape, scn = setup(n_sats, n_ground=2, rounds=rounds)
    geom, plan, ground = scn.geom, scn.plan, scn.ground_stations
    n_nodes = scn.n_nodes
    sinks = scn.ground_ids
    fl_cfg = fl_train.FLConfig(mode="tdm", local_steps=LOCAL_STEPS)
    gs_cfg = fl_train.GroundSegConfig(
        mode="hierarchical", sink_sync_every=2,
        pipeline_depth=pipeline_depth, max_staleness_windows=max_staleness,
    )

    est = cost.groundseg_mode_costs(
        plan, sinks, PAYLOAD_BYTES, antennas=2, pipeline_depth=pipeline_depth
    )
    print(
        f"{n_sats} satellites + {len(ground)} ground sinks, Walker delta "
        f"{geom.planes}-plane @ {geom.altitude_km:.0f} km "
        f"(pipeline depth {pipeline_depth}, staleness horizon "
        f"{max_staleness}):"
    )
    for mode in ("centralized", "gossip_getmeas"):
        rc = est[mode]
        print(
            f"  {mode:<16} est round {rc.time_s:9.1f} s, "
            f"{rc.bytes_on_isl/1e9:.2f} GB on ISL"
        )

    mesh = jax.make_mesh((n_nodes,), ("data",))
    state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, n_nodes)
    alive = set(range(n_nodes))
    # lose a satellite one round before the end so at least one later round
    # actually exercises the rerouting path (rounds=2 -> fail after round 0)
    fail_round = min(6, rounds - 2)

    def on_round(log):
        print(
            f"round {log.round:2d}  sat-loss {log.loss:7.4f}  "
            f"consensus-dist {log.consensus:.4f}  "
            f"delivered {log.delivered}/{log.alive}  "
            f"covered {log.covered}  carried {log.carried}  "
            f"dropped {log.dropped}  "
            f"{'pooled' if log.pooled else 'regional'}"
        )
        if log.round == fail_round and fail_round >= 0:
            alive.discard(2)
            print("  !! satellite 2 lost — rerouting (skip-slot semantics)")

    res = fl_train.run(fl_train.GroundSegRun(
        cfg, opt_cfg, mesh, n_nodes, fl_cfg, gs_cfg, plan, state,
        make_batch_fn(cfg, shape, n_nodes),
        sinks=sinks, rounds=rounds, alive=alive, on_round=on_round,
        antennas=2, payload_bytes=PAYLOAD_BYTES,
    ))
    state = res.state
    survivors = [v for v in range(n_sats) if v in alive]
    sat_params = jax.tree.map(
        lambda x: np.asarray(x)[survivors], state["params"]
    )
    print("done — surviving satellites aggregated through the ground segment "
          f"(consensus {fl_train.consensus_distance(sat_params):.4f})")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--mode", choices=("tdm", "groundseg"), default="tdm")
    p.add_argument("--rounds", type=int, default=ROUNDS,
                   help="FL rounds (2 for the CI smoke run)")
    p.add_argument("--pipeline-depth", type=int, default=1, choices=(1, 2),
                   help="groundseg: overlap round r's downlink with round "
                        "r+1's uplink in one contact window")
    p.add_argument("--max-staleness", type=int, default=0,
                   help="groundseg: windows an undelivered payload persists "
                        "before it is dropped and reported")
    p.add_argument("--trace", default=None,
                   help="write a Chrome trace (Perfetto) of this run, plus "
                        "a <trace>.metrics.json counter snapshot")
    args = p.parse_args()
    from repro import telemetry

    with telemetry.trace_scope(args.trace) as rec:
        if args.mode == "groundseg":
            main_groundseg(args.rounds, args.pipeline_depth, args.max_staleness)
        else:
            main_tdm(args.rounds)
        if args.trace:
            telemetry.write_metrics(f"{args.trace}.metrics.json", rec)
        counters = telemetry.counters_snapshot()
        if counters:
            print("telemetry counters:")
            for name in sorted(counters):
                print(f"  {name} = {counters[name]:g}")


if __name__ == "__main__":
    main()
