"""End-to-end driver: decentralized FL over a constellation's geometry-
derived time-varying ISL visibility — the paper's motivating deployment.

8 MEO satellites (= 8 forced host devices) in a 2-plane Walker pattern,
each training a reduced LM on its OWN data shard; communication happens
ONLY through the paper's universal TDM algorithm (getMeas -> matchings ->
ppermute). The topology is NOT invented: orbits are propagated, links
require line of sight past the Earth's limb and a 14 000 km range gate,
and each contact-plan time step's visibility relation is the slot relation.
Every round:

    local SGD steps  ->  TDM exchange over the slot's visibility relation

The script prints the contact windows the geometry produced, reports loss
and consensus distance per round, then simulates a satellite failure: the
slot relations are restricted (paper skip-slot semantics) and training
continues with the survivors.

Run:  PYTHONPATH=src python examples/train_fl_constellation.py
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import jax
import numpy as np

from repro.configs import archs
from repro.constellation import contact_plan, cost, orbits
from repro.data import pipeline
from repro.launch import fl_train
from repro.models.config import ShapeConfig
from repro.optim import adamw


N_SATS = 8
ROUNDS = 10
LOCAL_STEPS = 2
PAYLOAD_BYTES = 1 << 22     # ~4 MiB of smoke-model params per exchange


def main():
    cfg = archs.smoke_cfg(archs.get("mamba2-780m"))
    opt_cfg = adamw.OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=100)
    fl_cfg = fl_train.FLConfig(mode="tdm", local_steps=LOCAL_STEPS)
    shape = ShapeConfig("fl", "train", 32, 4)   # per-sat batch of 4 rows

    # --- geometry: O3b-style MEO shell, visibility from orbital mechanics
    geom = orbits.WalkerDelta(
        total=N_SATS, planes=2, altitude_km=8062.0, inclination_deg=60.0
    )
    plan = contact_plan.build_contact_plan(
        geom,
        duration_s=geom.period_s,
        step_s=geom.period_s / ROUNDS,
        max_range_km=14_000.0,
    )
    windows = plan.windows()
    est = cost.plan_cost(plan, PAYLOAD_BYTES, mode="getmeas")
    print(
        f"{N_SATS} satellites, Walker delta {geom.planes}-plane @ "
        f"{geom.altitude_km:.0f} km (period {geom.period_s/60:.0f} min): "
        f"{len(windows)} contact windows, est. comm "
        f"{est.time_s:.2f} s / {est.bytes_on_isl/1e9:.2f} GB per orbit"
    )
    for w in windows[:4]:
        print(
            f"  contact {w.i}<->{w.j}  [{w.t_start_s/60.0:5.1f}, "
            f"{w.t_end_s/60.0:5.1f}] min  {w.mean_rate_bps/1e6:.0f} Mb/s"
        )

    mesh = jax.make_mesh((N_SATS,), ("data",))
    state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N_SATS)

    def batch_fn(round_idx):
        per_node = []
        for sat in range(N_SATS):
            bs = [
                pipeline.host_batch(cfg, shape, step=round_idx * LOCAL_STEPS + h,
                                    seed=1000 + sat)
                for h in range(LOCAL_STEPS)
            ]
            per_node.append({
                k: np.stack([b[k] for b in bs]) for k in bs[0]
            })
        return {
            k: np.stack([pn[k] for pn in per_node]) for k in per_node[0]
        }

    alive = set(range(N_SATS))

    def on_round(log):
        print(f"round {log.round:2d}  mean-loss {log.loss:7.4f}  "
              f"consensus-dist {log.consensus:.4f}  links {log.n_links}")
        if log.round == 6:
            alive.discard(3)
            print("  !! satellite 3 lost — rescheduling (skip-slot semantics)")

    state, _ = fl_train.run_constellation_fl(
        cfg, opt_cfg, mesh, N_SATS, fl_cfg, plan, state, batch_fn,
        rounds=ROUNDS, alive=alive, on_round=on_round,
    )
    print("done — surviving satellites converged together "
          f"(consensus {fl_train.consensus_distance(state['params']):.4f})")


if __name__ == "__main__":
    main()
