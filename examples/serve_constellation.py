"""Constellation serving example: TDM-slotted inference end to end.

Requests arrive at two ground stations, climb earliest-delivery contact-
graph routes to satellite model replicas, decode under the TDM slot
structure (wave discipline per replica, continuous batching across the
fleet), and return on downlink slots — the inference-side twin of the
ground-segment FL pipeline, on the SAME sky: one
:class:`~repro.constellation.scenario.ScenarioSpec` builds the geometry,
contact plan, and slot schedule for both.

Mid-run one replica satellite dies; its batch drains, in-flight requests
re-route to the surviving replica, and the route-provenance auditor
checks every hop it all took (slot-legal links, no lost requests).

Run:  PYTHONPATH=src python examples/serve_constellation.py
      (add --model for the real stacked-shard_map decoder on 8 forced
       host devices; default is the deterministic NullDecoder)
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import argparse

from repro import telemetry
from repro.constellation.scenario import smoke_scenario
from repro.serving import (
    NullDecoder,
    ReplicaFleet,
    ServingEngine,
    audit_serving_run,
    synthesize_workload,
)

N_REQUESTS = 10
BATCH = 2
MAX_NEW = 6


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--model", action="store_true",
                   help="decode with the real stacked shard_map ModelDecoder")
    p.add_argument("--requests", type=int, default=N_REQUESTS)
    args = p.parse_args()

    # one scenario = the whole deployment: 6-sat MEO Walker shell + 2
    # ground stations, TDM schedule from the propagated contact plan
    scn = smoke_scenario()
    replicas = [0, 3]            # one replica per orbital plane
    print(
        f"{scn.n_sats} satellites + {len(scn.ground_stations)} ground "
        f"stations, {len(scn.slots())} TDM slots/epoch; replicas at "
        f"{replicas}, gateways at {sorted(scn.ground_ids)}"
    )

    if args.model:
        from repro.configs import archs
        from repro.serving import ModelDecoder

        cfg = archs.smoke_cfg(archs.get("gemma2-9b"))
        decoder = ModelDecoder(cfg, len(replicas), BATCH, max_len=32)
        print(f"decoder: {cfg.name} smoke config, one replica per device")
    else:
        decoder = NullDecoder(len(replicas), BATCH)
        print("decoder: deterministic NullDecoder (pass --model for the "
              "real thing)")

    fleet = ReplicaFleet(replicas, BATCH, decoder)
    eng = ServingEngine.from_scenario(scn, fleet)
    workload = synthesize_workload(
        args.requests, scn.ground_ids, rate_per_slot=1.0, max_new=MAX_NEW,
    )

    epoch = eng.epoch
    fail_at, restore_at = epoch // 2, epoch // 2 + max(2, epoch // 4)

    def on_slot(engine, slot):
        if slot == fail_at:
            print(f"  !! slot {slot}: replica satellite {replicas[0]} lost "
                  "— draining its batch, re-routing")
            engine.fail(replicas[0])
        elif slot == restore_at:
            print(f"  slot {slot}: satellite {replicas[0]} restored")
            engine.restore(replicas[0])

    report = eng.run(workload, on_slot=on_slot)
    summ = report.summary()
    print(
        f"\ndelivered {summ['delivered']}/{summ['n_requests']} requests in "
        f"{summ['n_slots']} slots ({summ['epochs']:.1f} epochs, "
        f"{summ.get('wall_s', 0):.1f} simulated s): "
        f"p50 latency {summ.get('latency_p50_slots', -1):.1f} slots, "
        f"p99 {summ.get('latency_p99_slots', -1):.1f}, "
        f"TTFT p50 {summ.get('ttft_p50_slots', -1):.1f}, "
        f"{summ['retries']} retries"
    )
    for r in report.delivered[:3]:
        print(f"  request {r.rid}: gateway {r.gateway} -> replica "
              f"{r.replica}, {len(r.out)} tokens {r.out[:4]}..., "
              f"{r.hops_up}+{r.hops_down} hops")

    verdict = audit_serving_run(
        report.records, report.requests, eng.base_rels,
        gateways=eng.gateways, replicas=replicas,
    )
    print(
        f"route-provenance audit: {verdict.n_hops} hops over "
        f"{verdict.n_windows} slots — "
        f"{'OK' if verdict.ok else f'{len(verdict.violations)} VIOLATIONS'}"
    )
    counters = telemetry.counters_snapshot()
    for name in sorted(n for n in counters if n.startswith("serve.")):
        print(f"  {name} = {counters[name]:g}")
    if not verdict.ok or summ["undelivered"]:
        raise SystemExit("serving run lost requests or failed its audit")


if __name__ == "__main__":
    main()
