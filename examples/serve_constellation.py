"""Serving example: batched inference with continuous request admission,
plus a TDM twist — the server fleet periodically synchronizes adapter-style
parameter deltas over a ring TDM schedule (model refresh without restart).

Run:  PYTHONPATH=src python examples/serve_constellation.py
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)


import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import tdm
from repro.core.schedule import ring
from repro.launch import serve as serve_lib


def main():
    # --- batched serving ----------------------------------------------------
    srv = serve_lib.main([
        "--arch", "qwen3-moe-30b-a3b", "--smoke",
        "--requests", "6", "--batch", "4", "--prompt-len", "8", "--max-new", "6",
    ])
    print("sample continuations:", {r.rid: r.out[:4] for r in
                                    list(srv.queue) or []} or "(all served)")

    # --- fleet refresh over a ring TDM schedule -----------------------------
    # 8 replicas hold slightly divergent "fine-tuned" deltas; three ring
    # gossip slots propagate + average them (paper P2: composition of
    # relations propagates data across the fleet).
    n = 8
    mesh = jax.make_mesh((n,), ("node",))
    rel = ring(n)
    deltas = np.random.default_rng(0).normal(size=(n, 256)).astype(np.float32)

    def refresh(x):
        for _ in range(3):
            x = tdm.gossip_avg(x, rel, "node", n)
        return x

    f = jax.jit(shard_map(refresh, mesh=mesh, in_specs=P("node"),
                          out_specs=P("node")))
    out = np.asarray(f(deltas))
    before = np.abs(deltas - deltas.mean(0)).max()
    after = np.abs(out - out.mean(0)).max()
    print(f"fleet delta disagreement: {before:.3f} -> {after:.3f} "
          f"after 3 ring TDM slots")


if __name__ == "__main__":
    main()
