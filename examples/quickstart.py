"""Quickstart: the paper's universal TDM algorithm in 60 lines.

1. Build exchange relations (paper §II) and check their algebra.
2. Run the paper-faithful getMeas simulator (Algorithm 1).
3. Train a small LM for a few steps with the framework's public API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core.relation import Relation
from repro.core.schedule import clique_multilink, round_robin_tournament
from repro.core.ptbfla_sim import run_schedule_getmeas, run_schedule_get1meas
from repro.launch import train as train_lib


def main():
    # --- 1. relations: R2 = {(a,b),(b,a),(b,c),(c,b)} from the paper -------
    a, b, c = 0, 1, 2
    r2 = Relation.from_pairs([(a, b), (b, a), (b, c), (c, b)])
    print("R2 valid exchange:", r2.is_valid_exchange())
    print("R2 == its inverse (P1):", r2.inverse().pairs == r2.pairs)
    print("b's peers (needs 2 antennas):", r2.peers_of(b))

    # propagation (P2): a's data reaches c through b over two slots
    r21 = Relation.from_pairs([(a, b), (b, a)])
    r22 = Relation.from_pairs([(b, c), (c, b)])
    print("R21∘R22 ∪ R22∘R21 =", sorted(r21.propagation(r22).pairs))

    # --- 2. Algorithm 1 on a 6-node clique ---------------------------------
    n = 6
    data = {i: f"odata-{i}" for i in range(n)}
    got_multi, sim_m = run_schedule_getmeas(clique_multilink(n), data, n)
    got_pair, sim_p = run_schedule_get1meas(round_robin_tournament(n), data, n)
    print(f"\ngetMeas  : 1 slot,  {sim_m.total_messages} messages")
    print(f"get1meas : {n-1} slots, {sim_p.total_messages} messages")
    assert {p: v for s in got_multi[0].values() for p, v in s.items()} == {
        p: v for s in got_pair[0].values() for p, v in s.items()
    }
    print("same exchanged data either way (semantic equivalence)")

    # --- 3. train a reduced mamba2 for a few steps -------------------------
    print("\ntraining a reduced mamba2-780m (CPU smoke config):")
    losses = train_lib.main([
        "--arch", "mamba2-780m", "--smoke", "--steps", "15",
        "--batch", "8", "--seq", "64", "--lr", "5e-3", "--log-every", "3",
    ])
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
