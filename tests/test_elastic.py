"""Fault-tolerance unit tests: heartbeat tracking, slot-deadline straggler
policy, TDM rescheduling, elastic replica membership under orbital churn,
and elastic reshard-on-restore across DIFFERENT mesh shapes (the new job's
mesh != the mesh that saved)."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core.schedule import round_robin_tournament
from repro.launch.elastic import (
    HealthTracker,
    ReplicaMembership,
    SlotDeadline,
    reschedule,
)


def test_health_tracker_deadlines():
    ht = HealthTracker(n_nodes=4, deadline_s=10.0)
    now = 100.0
    for i in range(4):
        ht.beat(i, t=now - i * 6)   # node i last seen 6i seconds ago
    assert ht.alive(now) == {0, 1}  # 0s and 6s ago alive; 12s, 18s dead
    assert ht.dead(now) == {2, 3}


def test_slot_deadline_masks_stragglers():
    pol = SlotDeadline(deadline_steps=2)
    progress = np.array([10, 9, 7, 4])
    mask = pol.participate(progress, slot_step=10)
    # nodes within 2 steps of the slot participate; laggards are odata=None
    np.testing.assert_array_equal(mask, [True, True, False, False])


def test_reschedule_preserves_validity():
    sched = round_robin_tournament(8)
    surv = reschedule(sched, alive=[0, 1, 2, 4, 6, 7])
    for slot in surv:
        assert slot.is_valid_exchange() or len(slot) == 0
        assert {3, 5}.isdisjoint(slot.participants())
    # surviving pairs are preserved
    for t, slot in enumerate(sched):
        for (i, j) in slot.pairs:
            if i in surv[t].nodes and j in surv[t].nodes:
                assert (i, j) in surv[t]


def test_membership_drain_is_immediate():
    m = ReplicaMembership([0, 3, 5])
    assert m.active == frozenset({0, 3, 5})
    delta = m.update({0, 5})              # 3 lost visibility
    assert delta.drained == frozenset({3})
    assert delta.changed
    assert m.active == frozenset({0, 5})
    assert m.drained == frozenset({3})
    # steady state: no churn, no delta
    assert not m.update({0, 5}).changed


def test_membership_readmit_without_grace():
    m = ReplicaMembership([0, 3], grace_slots=0)
    m.update({0})
    delta = m.update({0, 3})              # back for one step: re-admitted
    assert delta.admitted == frozenset({3})
    assert m.active == frozenset({0, 3})


def test_membership_grace_damps_flapping():
    m = ReplicaMembership([0, 3], grace_slots=2)
    m.update({0})
    # a grazing pass: visible for one step, gone again — never re-admitted
    assert not m.update({0, 3}).admitted
    assert not m.update({0}).changed       # streak resets
    # a real return: visible for grace_slots+1 consecutive updates
    assert not m.update({0, 3}).admitted
    assert not m.update({0, 3}).admitted
    delta = m.update({0, 3})
    assert delta.admitted == frozenset({3})
    assert m.active == frozenset({0, 3})


def test_membership_ignores_foreign_nodes():
    m = ReplicaMembership([0, 3])
    delta = m.update({0, 3, 99})          # 99 is not a replica
    assert not delta.changed
    assert m.active == frozenset({0, 3})


def test_elastic_restore_reshards_for_new_mesh(tmp_path):
    """Save from a 'job' with one layout, restore placed for another mesh:
    values must be identical and shardings must match the NEW mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {
        "w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4),
        "b": jnp.ones((4,), jnp.float32),
    }
    ckpt_lib.save(tmp_path, 3, tree, async_save=False)

    # "new job": single-device mesh (this container) with explicit shardings
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {
        "w": NamedSharding(mesh, P("data", None)),
        "b": NamedSharding(mesh, P()),
    }
    step, out = ckpt_lib.restore(tmp_path, target=tree, shardings=shardings)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding.is_equivalent_to(shardings["w"], ndim=2)
