"""Fault-tolerance unit tests: heartbeat tracking, slot-deadline straggler
policy, TDM rescheduling, and elastic reshard-on-restore across DIFFERENT
mesh shapes (the new job's mesh != the mesh that saved)."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core.schedule import round_robin_tournament
from repro.launch.elastic import HealthTracker, SlotDeadline, reschedule


def test_health_tracker_deadlines():
    ht = HealthTracker(n_nodes=4, deadline_s=10.0)
    now = 100.0
    for i in range(4):
        ht.beat(i, t=now - i * 6)   # node i last seen 6i seconds ago
    assert ht.alive(now) == {0, 1}  # 0s and 6s ago alive; 12s, 18s dead
    assert ht.dead(now) == {2, 3}


def test_slot_deadline_masks_stragglers():
    pol = SlotDeadline(deadline_steps=2)
    progress = np.array([10, 9, 7, 4])
    mask = pol.participate(progress, slot_step=10)
    # nodes within 2 steps of the slot participate; laggards are odata=None
    np.testing.assert_array_equal(mask, [True, True, False, False])


def test_reschedule_preserves_validity():
    sched = round_robin_tournament(8)
    surv = reschedule(sched, alive=[0, 1, 2, 4, 6, 7])
    for slot in surv:
        assert slot.is_valid_exchange() or len(slot) == 0
        assert {3, 5}.isdisjoint(slot.participants())
    # surviving pairs are preserved
    for t, slot in enumerate(sched):
        for (i, j) in slot.pairs:
            if i in surv[t].nodes and j in surv[t].nodes:
                assert (i, j) in surv[t]


def test_elastic_restore_reshards_for_new_mesh(tmp_path):
    """Save from a 'job' with one layout, restore placed for another mesh:
    values must be identical and shardings must match the NEW mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {
        "w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4),
        "b": jnp.ones((4,), jnp.float32),
    }
    ckpt_lib.save(tmp_path, 3, tree, async_save=False)

    # "new job": single-device mesh (this container) with explicit shardings
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {
        "w": NamedSharding(mesh, P("data", None)),
        "b": NamedSharding(mesh, P()),
    }
    step, out = ckpt_lib.restore(tmp_path, target=tree, shardings=shardings)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding.is_equivalent_to(shardings["w"], ndim=2)
