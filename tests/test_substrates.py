"""Tests for optimizer, data pipeline, and checkpointing substrates."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt_lib
from repro.data import pipeline
from repro.models.config import ShapeConfig
from repro.configs import archs
from repro.optim import adamw
from proptest import given


# ------------------------------------------------------------------ adamw
def quad_loss(p):
    return jnp.sum(jnp.square(p["w"] - 3.0)) + jnp.sum(jnp.square(p["b"] + 1.0))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges_quadratic(dtype):
    cfg = adamw.OptConfig(peak_lr=0.2, warmup_steps=5, decay_steps=400,
                          weight_decay=0.0, dtype=dtype)
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((4,))}
    state = adamw.init_opt_state(params, cfg)

    @jax.jit
    def step(params, state):
        grads = jax.grad(quad_loss)(params)
        return adamw.apply_updates(params, grads, state, cfg)

    for _ in range(300):
        params, state, metrics = step(params, state)
    final = float(quad_loss(params))
    tol = 0.5 if dtype == "int8" else 1e-2
    assert final < tol, (dtype, final)
    assert np.isfinite(float(metrics["grad_norm"]))


def test_adamw_schedule_shape():
    cfg = adamw.OptConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100)
    lrs = [float(adamw.schedule(jnp.asarray(s), cfg)) for s in range(120)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9          # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-7              # peak after warmup
    assert lrs[-1] < lrs[50] < lrs[11]             # cosine decays
    assert lrs[-1] >= 1e-4 - 1e-9                  # floor = end_lr_frac*peak


def test_adamw_clips_global_norm():
    cfg = adamw.OptConfig(clip_norm=1.0, peak_lr=1.0, warmup_steps=0, decay_steps=10)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init_opt_state(params, cfg)
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw.apply_updates(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_int8_moments_memory_layout():
    cfg = adamw.OptConfig(dtype="int8")
    params = {"w": jnp.zeros((8, 64))}
    state = adamw.init_opt_state(params, cfg)
    q = state["mu"]["w"]
    assert isinstance(q, adamw.QTensor)
    assert q.q.dtype == jnp.int8 and q.q.shape == (8, 64)
    assert q.scale.shape == (8, 1)  # row-wise scales keep param sharding


# ------------------------------------------------------------------- data
def test_pipeline_deterministic():
    cfg = archs.smoke_cfg(archs.get("gemma2-9b"))
    shape = ShapeConfig("t", "train", 32, 4)
    a = pipeline.host_batch(cfg, shape, step=7, seed=3)
    b = pipeline.host_batch(cfg, shape, step=7, seed=3)
    c = pipeline.host_batch(cfg, shape, step=8, seed=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_pipeline_host_sharding_rows():
    cfg = archs.smoke_cfg(archs.get("granite-20b"))
    shape = ShapeConfig("t", "train", 16, 8)
    full = pipeline.host_batch(cfg, shape, step=0)
    part = pipeline.host_batch(cfg, shape, step=0, rows=range(2, 5))
    np.testing.assert_array_equal(full["tokens"][2:5], part["tokens"])


def test_pipeline_learnable_structure():
    """The affine token walk is near-deterministic given the previous token
    (noise ∈ {0,1,2}) — a model can learn it: every token has ≤ 3 possible
    successors within a row."""
    cfg = archs.smoke_cfg(archs.get("mamba2-780m"))
    shape = ShapeConfig("t", "train", 512, 2)
    b = pipeline.host_batch(cfg, shape, step=0)
    row = b["tokens"][0]
    succ = {}
    for t in range(len(row) - 1):
        succ.setdefault(int(row[t]), set()).add(int(row[t + 1]))
    assert max(len(s) for s in succ.values()) <= 3


def test_vlm_and_audio_extras():
    vlm = archs.smoke_cfg(archs.get("qwen2-vl-72b"))
    b = pipeline.host_batch(vlm, ShapeConfig("t", "train", 8, 2), 0)
    assert b["positions"].shape == (2, 8, 3)
    aud = archs.smoke_cfg(archs.get("whisper-base"))
    b2 = pipeline.host_batch(aud, ShapeConfig("t", "train", 8, 2), 0)
    assert b2["enc_embeds"].shape == (2, aud.enc_frames, aud.d_model)


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.zeros((), jnp.int32)},
    }
    ckpt_lib.save(tmp_path, 5, tree, meta={"note": "x"}, async_save=False)
    step, out = ckpt_lib.restore(tmp_path, target=tree)
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_async_and_prune(tmp_path):
    tree = {"w": jnp.ones((4,))}
    for s in range(5):
        ckpt_lib.save(tmp_path, s, tree, keep=2)
    ckpt_lib.wait_all()
    assert ckpt_lib.all_steps(tmp_path) == [3, 4]
    assert ckpt_lib.latest_step(tmp_path) == 4


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"w": jnp.ones((128,))}
    ckpt_lib.save(tmp_path, 1, tree, async_save=False)
    blob = tmp_path / "step_0000000001" / "data.msgpack.zst"
    raw = bytearray(blob.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    blob.write_bytes(bytes(raw))
    with pytest.raises(Exception):
        ckpt_lib.restore(tmp_path, target=tree)


def test_checkpoint_atomic_tmp_never_visible(tmp_path):
    tree = {"w": jnp.ones((4,))}
    ckpt_lib.save(tmp_path, 7, tree, async_save=False)
    assert not list(tmp_path.glob("*.tmp"))
    assert ckpt_lib.all_steps(tmp_path) == [7]
