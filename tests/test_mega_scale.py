"""Equivalence suite for the mega-constellation fast paths (PR 8).

Every vectorized stage of plan synthesis keeps its legacy-loop twin, and
this suite asserts the fast path is BIT-IDENTICAL to it — not approximately
equal — across randomized shells, timestep counts, ground-station layouts,
and dead-satellite masks:

- ``WalkerDelta.positions``          vs ``positions_reference``
- ``links.visibility_series``        vs ``visibility_series_reference``
- ``ContactPlan.windows``            vs ``windows_reference``
- ``routing.earliest_delivery_routes`` vs ``earliest_delivery_routes_reference``

plus the incremental machinery the fast pipeline adds on top: the
``MultiWindowRouter`` table cache and the ``WindowedOptimizer`` warm start
(both must change performance counters, never plans).
"""

import random

import numpy as np
import pytest

from proptest import given, st_choice, st_int
from repro.constellation.contact_plan import (
    build_contact_plan,
    plus_grid_candidates,
    sat_ground_candidates,
)
from repro.constellation.links import (
    LinkBudget,
    visibility_matrix,
    visibility_series,
    visibility_series_reference,
)
from repro.constellation.optimizer import WindowedOptimizer, optimize_schedule
from repro.constellation.orbits import (
    GroundStation,
    MultiShell,
    WalkerDelta,
    propagate,
    sample_times,
)
from repro.core.relation import Relation
from repro.groundseg.routing import (
    MultiWindowRouter,
    build_relay_program,
    earliest_delivery_routes,
    earliest_delivery_routes_reference,
)
from repro.telemetry import recorder as telemetry


def _random_shell(rng: random.Random) -> WalkerDelta:
    planes = rng.randint(1, 6)
    per_plane = rng.randint(1, 8)
    return WalkerDelta(
        total=planes * per_plane,
        planes=planes,
        phasing=rng.randint(0, max(0, planes * per_plane - 1)),
        inclination_deg=rng.choice([0.0, 45.0, 53.0, 86.4, 97.6]),
        altitude_km=rng.choice([550.0, 780.0, 1200.0, 8000.0]),
        pattern=rng.choice(["delta", "star"]),
    )


# ------------------------------------------------------------- geometry
@given(st_int(0, 10_000), cases=40)
def test_positions_bitwise_matches_reference(seed):
    rng = random.Random(seed)
    geom = _random_shell(rng)
    ts = sample_times(rng.choice([600.0, 3600.0]), rng.choice([30.0, 60.0, 97.0]))
    assert np.array_equal(geom.positions(ts), geom.positions_reference(ts))
    t0 = rng.uniform(0.0, 7200.0)
    assert np.array_equal(geom.positions(t0), geom.positions_reference(t0))


def test_multishell_is_concatenation_of_shells():
    a = WalkerDelta(total=8, planes=2)
    b = WalkerDelta(total=6, planes=3, altitude_km=780.0, pattern="star")
    ms = MultiShell(shells=(a, b))
    assert ms.total == 14
    assert ms.shell_offsets() == (0, 8)
    assert ms.shell_of(0) == 0 and ms.shell_of(7) == 0 and ms.shell_of(8) == 1
    with pytest.raises(ValueError):
        ms.shell_of(14)
    ts = sample_times(600.0, 60.0)
    pos = ms.positions(ts)
    assert pos.shape == (len(ts), 14, 3)
    assert np.array_equal(pos[:, :8], a.positions(ts))
    assert np.array_equal(pos[:, 8:], b.positions(ts))
    # scalar time keeps the unbatched shape contract
    assert ms.positions(30.0).shape == (14, 3)


def test_multishell_needs_a_shell():
    with pytest.raises(ValueError):
        MultiShell(shells=())


# ----------------------------------------------------------- visibility
@given(st_int(0, 10_000), cases=20)
def test_visibility_series_bitwise_matches_reference(seed):
    rng = random.Random(seed)
    geom = _random_shell(rng)
    n_gs = rng.randint(0, 3)
    gss = [
        GroundStation(
            lat_deg=rng.uniform(-70, 70), lon_deg=rng.uniform(-180, 180)
        )
        for _ in range(n_gs)
    ]
    ts = sample_times(1200.0, 60.0)
    tracks = propagate(geom, ts, gss)
    if rng.random() < 0.5:
        cand = None
    else:
        cand = plus_grid_candidates(geom) + sat_ground_candidates(geom, n_gs)
    kw = dict(
        budget=LinkBudget(),
        candidates=cand,
        max_range_km=rng.choice([None, 3000.0, 6000.0]),
        min_rate_bps=rng.choice([0.0, 1e6]),
        ground_nodes=range(geom.total, geom.total + n_gs),
    )
    fast = visibility_series(tracks, **kw)
    ref = visibility_series_reference(tracks, **kw)
    assert len(fast) == len(ref)
    for gf, gr in zip(fast, ref):
        assert list(gf.keys()) == list(gr.keys())
        assert gf == gr  # Link dataclass equality is exact float equality


def test_visibility_matrix_chunking_is_invisible():
    geom = WalkerDelta(total=12, planes=3)
    tracks = propagate(geom, sample_times(1200.0, 60.0))
    whole = visibility_matrix(tracks, max_range_km=6000.0)
    tiny = visibility_matrix(tracks, max_range_km=6000.0, max_chunk_elems=1)
    assert np.array_equal(whole.visible, tiny.visible)
    assert np.array_equal(whole.range_km, tiny.range_km)
    assert np.array_equal(whole.rate_bps, tiny.rate_bps)


# -------------------------------------------------------------- windows
@given(st_int(0, 10_000), cases=15)
def test_windows_bitwise_match_reference(seed):
    rng = random.Random(seed)
    geom = _random_shell(rng)
    n_gs = rng.randint(0, 2)
    gss = [
        GroundStation(
            lat_deg=rng.uniform(-70, 70), lon_deg=rng.uniform(-180, 180)
        )
        for _ in range(n_gs)
    ]
    cand = plus_grid_candidates(geom) + sat_ground_candidates(geom, n_gs)
    plan = build_contact_plan(
        geom,
        duration_s=rng.choice([600.0, 1800.0]),
        step_s=60.0,
        ground_stations=gss,
        candidates=cand,
        max_range_km=rng.choice([3000.0, 6000.0]),
    )
    assert plan.matrix is not None
    assert plan.windows() == plan.windows_reference()


def test_plan_without_matrix_still_windows():
    plan = build_contact_plan(
        WalkerDelta(total=8, planes=2), 600.0, 60.0, candidates="plus_grid"
    )
    import dataclasses

    bare = dataclasses.replace(plan, matrix=None)
    assert bare == plan  # matrix is acceleration metadata, not identity
    assert bare.windows() == plan.windows()


# -------------------------------------------------------------- routing
def _random_slots(rng: random.Random, n: int, T: int, p: float):
    slots = []
    for _ in range(T):
        edges = [
            (i, j)
            for i in range(n)
            for j in range(i + 1, n)
            if rng.random() < p
        ]
        slots.append(Relation.from_edges(edges, nodes=range(n)))
    return slots


@given(st_int(0, 10_000), st_choice([0.05, 0.15, 0.4]), cases=60)
def test_routing_dp_bitwise_matches_reference(seed, p):
    rng = random.Random(seed)
    n = rng.randint(3, 16)
    T = rng.randint(0, 12)
    slots = _random_slots(rng, n, T, p)
    sinks = rng.sample(range(n), rng.randint(1, max(1, n // 3)))
    sources = (
        None
        if rng.random() < 0.5
        else rng.sample(range(n), rng.randint(1, n))
    )
    fast = earliest_delivery_routes(slots, n, sinks, sources)
    ref = earliest_delivery_routes_reference(slots, n, sinks, sources)
    assert fast == ref


@given(st_int(0, 10_000), cases=30)
def test_routing_dp_matches_reference_under_dead_masks(seed):
    rng = random.Random(seed)
    n = rng.randint(4, 14)
    slots = _random_slots(rng, n, rng.randint(1, 8), 0.3)
    sinks = {rng.randrange(n)}
    dead = set(rng.sample(range(n), rng.randint(0, n // 2))) - sinks
    alive = set(range(n)) - dead
    rels = [r.restrict(alive) for r in slots]
    assert earliest_delivery_routes(
        rels, n, sinks
    ) == earliest_delivery_routes_reference(rels, n, sinks)


def test_routing_hold_on_ties_prefers_lowest_next_hop():
    # 0 can reach sink 3 via 1 or 2 in the same number of slots; the
    # deterministic rule picks the lowest-id relay, and holding beats
    # forwarding when it delivers no earlier.
    rel = Relation.from_edges([(0, 1), (0, 2), (1, 3), (2, 3)], nodes=range(5))
    slots = [rel] * 3
    fast = earliest_delivery_routes(slots, 5, [3])
    ref = earliest_delivery_routes_reference(slots, 5, [3])
    assert fast == ref
    assert fast.routes[0].hops[0].dst == 1
    assert not fast.routes[4].reachable  # isolated satellite is reported


def test_routing_unreachable_and_empty_horizon():
    slots = []
    fast = earliest_delivery_routes(slots, 4, [0])
    ref = earliest_delivery_routes_reference(slots, 4, [0])
    assert fast == ref
    assert fast.reachable() == []
    assert fast.unreachable() == [1, 2, 3]


# --------------------------------------------- multi-window router cache
def test_multiwindow_router_cache_reuses_dp_and_changes_nothing():
    rng = random.Random(7)
    n = 10
    slots = _random_slots(rng, n, 6, 0.3)
    rec = telemetry.get_recorder()

    def count(name):
        return rec.counters.get(name, 0)

    h0, m0 = count("groundseg.router.table_cache.hit"), count(
        "groundseg.router.table_cache.miss"
    )
    cached = MultiWindowRouter(n, [0])
    fresh_a = MultiWindowRouter(n, [0])
    fresh_b = MultiWindowRouter(n, [0])
    # same (alive, slots) every window: one miss then hits
    w0 = cached.plan_window(slots)
    w1 = cached.plan_window(slots)
    w2 = cached.plan_window(slots, alive=range(n - 1))  # different key: miss
    assert count("groundseg.router.table_cache.miss") - m0 == 2
    assert count("groundseg.router.table_cache.hit") - h0 == 1
    # cache must be invisible in the plans: fresh routers agree per window
    assert fresh_a.plan_window(slots) == w0
    assert fresh_a.plan_window(slots) == w1
    fresh_b.plan_window(slots)
    fresh_b.plan_window(slots)
    assert fresh_b.plan_window(slots, alive=range(n - 1)) == w2


def test_multiwindow_router_cache_is_bounded():
    rng = random.Random(3)
    n = 6
    router = MultiWindowRouter(n, [0])
    for k in range(2 * router.TABLE_CACHE_MAX):
        router.plan_window(_random_slots(rng, n, 3, 0.4))
    assert len(router._table_cache) <= router.TABLE_CACHE_MAX


# ------------------------------------------------- optimizer warm start
def test_windowed_optimizer_warm_start_counters_and_guarantee():
    rec = telemetry.get_recorder()

    def count(name):
        return rec.counters.get(name, 0)

    plan = build_contact_plan(
        WalkerDelta(total=20, planes=4, altitude_km=1400.0),
        duration_s=1200.0,
        step_s=120.0,
        candidates="plus_grid",
    )
    h0, r0 = count("optimizer.warm_start.hit"), count("optimizer.warm_start.race")
    wo = WindowedOptimizer(("slow_first", "overlap"))
    results = [wo.optimize(plan) for _ in range(3)]
    for res in results:
        assert res.chosen.time_s <= res.baseline.time_s  # never worse
    assert count("optimizer.warm_start.race") - r0 == 1  # window 0 only
    assert count("optimizer.warm_start.hit") - h0 == 2
    # the warm-started windows must pick the same winner the full race does
    full = optimize_schedule(plan, strategies=("slow_first", "overlap"))
    assert {r.strategy for r in results} == {full.strategy}
    assert results[1].schedule == full.schedule


def test_windowed_optimizer_rejects_bad_config():
    with pytest.raises(ValueError):
        WindowedOptimizer(("nope",))
    with pytest.raises(ValueError):
        WindowedOptimizer(full_race_every=-1)
    with pytest.raises(ValueError):
        WindowedOptimizer(mode="rate")


def test_optimize_schedule_strategy_subset_always_races_greedy():
    plan = build_contact_plan(
        WalkerDelta(total=8, planes=2), 600.0, 120.0, candidates="plus_grid"
    )
    res = optimize_schedule(plan, strategies=("slow_first",))
    assert set(res.costs) == {"greedy", "slow_first"}
    with pytest.raises(ValueError):
        optimize_schedule(plan, strategies=("blossom5",))


# --------------------------------------------------- end-to-end (slow)
@pytest.mark.slow
def test_full_pipeline_equivalence_medium_constellation():
    """propagate → visibility → windows → schedule → route: the fast
    pipeline and the legacy oracles agree bit for bit at a few hundred
    satellites (the scale PR 8 exists for)."""
    geom = MultiShell(
        shells=(
            WalkerDelta(total=144, planes=12, phasing=1),
            WalkerDelta(
                total=60, planes=6, altitude_km=780.0,
                inclination_deg=86.4, pattern="star",
            ),
        )
    )
    gss = [
        GroundStation(lat_deg=40.0, lon_deg=-74.0),
        GroundStation(lat_deg=-33.9, lon_deg=18.4),
        GroundStation(lat_deg=64.1, lon_deg=-21.9),
    ]
    cand = plus_grid_candidates(geom) + sat_ground_candidates(geom, len(gss))
    plan = build_contact_plan(
        geom, duration_s=1800.0, step_s=60.0, ground_stations=gss,
        candidates=cand, max_range_km=6000.0,
    )
    ts = sample_times(1800.0, 60.0)
    assert np.array_equal(
        geom.positions(ts),
        np.concatenate(
            [s.positions_reference(ts) for s in geom.shells], axis=1
        ),
    )
    tracks = propagate(geom, ts, gss)
    kw = dict(
        candidates=cand, max_range_km=6000.0,
        ground_nodes=range(geom.total, plan.n_nodes),
    )
    assert visibility_series(tracks, **kw) == visibility_series_reference(
        tracks, **kw
    )
    assert plan.windows() == plan.windows_reference()
    sched = plan.schedule(antennas=4)
    rels = [s.relation for s in sched.slots]
    sinks = range(geom.total, plan.n_nodes)
    fast = earliest_delivery_routes(rels, plan.n_nodes, sinks)
    ref = earliest_delivery_routes_reference(rels, plan.n_nodes, sinks)
    assert fast == ref
    # and the static relay program built on the fast table replays cleanly
    prog = build_relay_program(rels, plan.n_nodes, sinks, table=fast)
    assert prog.delivered_count() + prog.residual_count() == geom.total
