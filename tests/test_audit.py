"""Route-provenance auditor (telemetry/audit.py): a clean plan audits
green, and every tamper class — misroute, phantom hop, nonexistent link,
capacity overlap, age-ledger drift, staleness mis-weight, lifecycle-event
divergence — is caught as a structured violation."""

import dataclasses

import numpy as np
import pytest

from repro import telemetry
from repro.core.relation import Relation
from repro.core.schedule import ring
from repro.groundseg import routing
from repro.telemetry import audit
from repro.telemetry.recorder import Event

N = 6
SINKS = frozenset({4, 5})
SLOTS = 3


def plan_programs(windows=3, occlude_at=None):
    """A small multi-window plan over ring relations: every satellite can
    reach a sink within the horizon. ``occlude_at`` makes node 0 contact-
    less (alive, so it injects, but unreachable) for that window — its
    payload carries and delivers stale, exercising the age ledger."""
    rels = [ring(N)] * SLOTS
    router = routing.MultiWindowRouter(
        N, SINKS, max_staleness_windows=2, pipeline_depth=2
    )
    programs = []
    for w in range(windows):
        slots = rels
        if occlude_at is not None and w == occlude_at:
            others = set(range(N)) - {0}
            slots = [r.restrict(others) for r in rels]
        programs.append(router.plan_window(slots))
    return rels, programs


def lifecycle_events(programs):
    """The event stream a faithful executor would emit (matching the
    fl_train driver's schema: queued carries no age)."""
    evs = []
    for wp in programs:
        for s in sorted(wp.injected):
            evs.append(Event("payload.queued", "payload", 0.0,
                             {"window": wp.window, "source": s}))
        for s, a in sorted(wp.delivered_ages.items()):
            evs.append(Event("payload.delivered", "payload", 0.0,
                             {"window": wp.window, "source": s, "age": a}))
        for s, a in sorted(wp.residual.items()):
            evs.append(Event("payload.carried", "payload", 0.0,
                             {"window": wp.window, "source": s, "age": a}))
        for s, a in sorted(wp.dropped.items()):
            evs.append(Event("payload.dropped", "payload", 0.0,
                             {"window": wp.window, "source": s, "age": a}))
    return evs


def true_weights(programs, decay):
    return [
        _weights_vec(audit.expected_sink_weights(wp, decay))
        for wp in programs
    ]


def _weights_vec(per_sink):
    vec = np.zeros(N, dtype=np.float32)
    for k, v in per_sink.items():
        vec[k] = v
    return vec


def test_clean_plan_audits_green_with_trails_and_counters():
    rels, programs = plan_programs()
    with telemetry.record_scope() as rec:
        report = audit.audit_window_programs(
            programs, decay=0.5, slots=rels,
            weights=true_weights(programs, 0.5),
            events=lifecycle_events(programs),
        )
        assert rec.get_counter("audit.windows") == len(programs)
        assert rec.get_counter("audit.violations") == 0
    assert report.ok and report.raise_if_violations() is report
    assert report.n_windows == 3
    assert report.n_payloads == sum(len(wp.ages) for wp in programs)
    assert report.events_checked == sum(
        len(wp.injected) + len(wp.delivered_ages) + len(wp.residual)
        + len(wp.dropped) for wp in programs
    )
    # every payload has a trail; delivered ones end at a sink
    for wp in programs:
        for s in wp.ages:
            trail = report.trails[(wp.window, s)]
            assert trail.age == wp.ages[s]
            if s in wp.delivered_ages:
                assert trail.sink in SINKS and trail.hops
                assert trail.hops[-1][2] == trail.sink
            else:
                assert trail.sink is None
    d = report.summary()
    assert d["ok"] and d["n_violations"] == 0 and d["n_hops"] == report.n_hops


def test_outage_window_carries_and_audits_green():
    rels, programs = plan_programs(windows=4, occlude_at=0)
    report = audit.audit_window_programs(programs, decay=0.5, slots=rels)
    assert report.ok
    # the occluded node's payload carried through and landed stale
    stale = [wp.delivered_ages.get(0) for wp in programs]
    assert any(a not in (None, 0) for a in stale)


def test_misrouted_payload_is_caught():
    rels, programs = plan_programs()
    wp = programs[1]
    d = {k: set(v) for k, v in wp.uplink.delivered.items()}
    k_from = next(k for k in sorted(d) if d[k])
    k_to = next(k for k in sorted(d) if k != k_from)
    moved = sorted(d[k_from])[0]
    d[k_from].discard(moved)
    d[k_to].add(moved)
    tampered = dataclasses.replace(
        wp,
        uplink=dataclasses.replace(
            wp.uplink, delivered={k: frozenset(v) for k, v in d.items()}
        ),
    )
    report = audit.audit_window_programs(
        programs[:1] + [tampered] + programs[2:], decay=0.5, slots=rels
    )
    assert not report.ok
    assert {v.kind for v in report.violations} == {"misroute"}
    with pytest.raises(audit.AuditError, match="misroute"):
        report.raise_if_violations()


def test_phantom_hop_and_nonexistent_link_are_caught():
    rels, programs = plan_programs()
    wp = programs[0]
    # a send from a sink (which never holds an uplink payload) over an
    # edge absent from the ring: two violations from one tampered hop
    bad_sends = (((4, 1),) + wp.uplink.slot_sends[0],) + wp.uplink.slot_sends[1:]
    tampered = dataclasses.replace(
        wp, uplink=dataclasses.replace(wp.uplink, slot_sends=bad_sends)
    )
    report = audit.audit_window_programs([tampered], decay=0.5, slots=rels)
    kinds = {v.kind for v in report.violations}
    assert "phantom-hop" in kinds and "no-such-link" in kinds


def test_capacity_overlap_at_depth2_is_caught():
    rels, programs = plan_programs()
    wp = next(
        p for p in programs
        if p.downlink is not None and p.lagged_downlink
        and any(p.uplink.slot_sends)
    )
    t, sends = next(
        (t, s) for t, s in enumerate(wp.uplink.slot_sends) if s
    )
    # downlink floods over an edge the uplink already occupies in slot t
    src, dst = sends[0]
    down_sends = list(wp.downlink.slot_sends)
    while len(down_sends) <= t:
        down_sends.append(())
    down_sends[t] = down_sends[t] + ((src, dst),)
    tampered = dataclasses.replace(
        wp,
        downlink=dataclasses.replace(
            wp.downlink, slot_sends=tuple(down_sends)
        ),
    )
    programs2 = [tampered if p.window == wp.window else p for p in programs]
    report = audit.audit_window_programs(programs2, decay=0.5, slots=rels)
    assert any(v.kind == "capacity-overlap" for v in report.violations)


def test_age_ledger_drift_is_caught():
    rels, programs = plan_programs(windows=4, occlude_at=0)
    # find a window that delivered the carried (stale) payload and shave a
    # window off its reported age — the cross-window ledger must object
    wi, wp = next(
        (i, p) for i, p in enumerate(programs)
        if p.delivered_ages.get(0, 0) > 0
    )
    lied_ages = dict(wp.ages)
    lied_ages[0] = wp.ages[0] - 1
    lied_delivered = dict(wp.delivered_ages)
    lied_delivered[0] = wp.ages[0] - 1
    tampered = dataclasses.replace(
        wp, ages=lied_ages, delivered_ages=lied_delivered
    )
    report = audit.audit_window_programs(
        programs[:wi] + [tampered] + programs[wi + 1:], decay=0.5,
    )
    assert any(
        v.kind == "age" and v.payload == 0 for v in report.violations
    )


def test_misweighted_aggregation_is_caught():
    rels, programs = plan_programs()
    weights = true_weights(programs, 0.5)
    assert audit.audit_window_programs(
        programs, decay=0.5, weights=weights
    ).ok
    weights[1] = weights[1].copy()
    k = next(iter(sorted(SINKS)))
    weights[1][k] += 0.125   # one wrong FedAvg denominator
    report = audit.audit_window_programs(
        programs, decay=0.5, weights=weights
    )
    assert [v.kind for v in report.violations] == ["weights"]
    assert report.violations[0].window == programs[1].window


def test_expected_sink_weights_match_f32_recurrence():
    _, programs = plan_programs(windows=4, occlude_at=0)
    for wp in programs:
        want = audit.expected_sink_weights(wp, 0.7)
        for k, srcs in wp.uplink.delivered.items():
            acc = np.float32(1.0)
            for s in sorted(srcs):
                w = np.float32(1.0)
                for _ in range(wp.delivered_ages[s]):
                    w = np.float32(w * np.float32(0.7))
                acc = np.float32(acc + w)
            assert want[k] == float(acc)


def test_lifecycle_event_divergence_is_caught():
    rels, programs = plan_programs()
    evs = lifecycle_events(programs)
    good = audit.audit_window_programs(programs, decay=0.5, events=evs)
    assert good.ok
    # executor lies about a delivered payload's age
    bad = [
        dataclasses.replace(e, args=dict(e.args, age=e.args["age"] + 1))
        if e.name == "payload.delivered" and e.args["source"] == 0
        else e
        for e in evs
    ]
    report = audit.audit_window_programs(programs, decay=0.5, events=bad)
    assert any(v.kind == "events" for v in report.violations)
    # an event for a window outside the audited range is flagged too
    stray = evs + [Event("payload.queued", "payload", 0.0,
                         {"window": 99, "source": 1})]
    report = audit.audit_window_programs(programs, decay=0.5, events=stray)
    assert any(
        v.kind == "events" and v.window == 99 for v in report.violations
    )


def test_non_consecutive_windows_rejected():
    _, programs = plan_programs()
    with pytest.raises(ValueError, match="consecutive"):
        audit.audit_window_programs([programs[0], programs[2]])
    assert audit.audit_window_programs([]).ok


def test_audit_recorder_uses_captured_events():
    rels, programs = plan_programs()
    with telemetry.record_scope(tracing=True) as rec:
        for e in lifecycle_events(programs):
            rec.event(e.name, cat=e.cat, **e.args)
        report = audit.audit_recorder(rec, programs, decay=0.5, slots=rels)
    assert report.ok and report.events_checked > 0


def test_ci_smoke_cli_green(capsys):
    rc = audit.main(["--ci-smoke", "--windows", "3"])
    out = capsys.readouterr().out
    assert rc == 0 and "0 violation(s)" in out


def test_mission_report_renders_audit_and_metrics(tmp_path):
    from repro.telemetry import metrics, report as report_mod

    rels, programs = plan_programs()
    with telemetry.record_scope(tracing=True) as rec:
        with rec.span("stage.plan"):
            verdict = audit.audit_window_programs(
                programs, decay=0.5, slots=rels
            )
        metrics.set_gauge("demo.gauge", 0.5)
        doc = report_mod.mission_report(
            rec, audit=verdict, title="unit run", extra={"rounds": 3}
        )
        md, js = report_mod.write_report(
            tmp_path / "sub" / "run", rec, audit=verdict, title="unit run"
        )
    assert doc["audit"]["ok"] and doc["gauges"]["demo.gauge"] == 0.5
    assert doc["stages"]["stage.plan"]["count"] == 1
    assert "audit.hops_per_payload" in doc["histograms"]
    text = md.read_text()
    assert text.startswith("# unit run")
    assert "Route-provenance audit: PASS" in text
    assert "`audit.hops_per_payload`" in text
    import json

    saved = json.loads(js.read_text())
    assert saved["audit"]["n_violations"] == 0
    # a failing audit renders its violations
    bad = dataclasses.replace(
        programs[0],
        delivered_ages={
            s: a + 1 for s, a in programs[0].delivered_ages.items()
        },
    )
    verdict2 = audit.audit_window_programs([bad], decay=0.5)
    text2 = report_mod.render_markdown(
        report_mod.mission_report(audit=verdict2, title="bad run")
    )
    assert "Route-provenance audit: FAIL" in text2
    assert "[age]" in text2
