"""End-to-end behaviour tests for the framework's public surface.

The heavyweight end-to-end paths (multi-device FL training, dry-run) have
dedicated tests/launchers; this file checks the public API contract that the
examples and launch scripts rely on.
"""

import importlib

import pytest


PUBLIC_MODULES = [
    "repro.core.relation",
    "repro.core.schedule",
    "repro.core.ptbfla_sim",
    "repro.core.tdm",
    "repro.core.gossip",
    "repro.core.fl",
    "repro.core.compress",
    "repro.constellation.scenario",
    "repro.serving",
    "repro.serving.engine",
    "repro.serving.audit",
]


@pytest.mark.parametrize("mod", PUBLIC_MODULES)
def test_module_imports(mod):
    importlib.import_module(mod)
