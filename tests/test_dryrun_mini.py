"""Launchers for multi-device integration suites that need forced host
devices (subprocesses — device count locks at first jax init):

- mini dry-run: lower+compile+roofline on a 2x2 mesh for every family
- FL constellation example: TDM-FL training with a simulated satellite loss
"""

import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _run(script, timeout=1800):
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT / 'tests'}:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(script)],
        capture_output=True, text=True, env=env, timeout=timeout, cwd=ROOT,
    )
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    return proc


@pytest.mark.slow
def test_mini_dryrun_all_families():
    proc = _run(ROOT / "tests" / "_minidryrun_worker.py")
    assert proc.returncode == 0
    assert "ALL-OK" in proc.stdout


@pytest.mark.slow
def test_fl_constellation_example():
    proc = _run(ROOT / "examples" / "train_fl_constellation.py")
    assert proc.returncode == 0
    out = proc.stdout
    assert "satellite 3 lost" in out
    # loss at round 9 < loss at round 0
    import re

    losses = [float(m) for m in re.findall(r"mean-loss\s+([\d.]+)", out)]
    assert len(losses) >= 10 and losses[-1] < losses[0] * 0.7
