"""Multi-device worker: runs the sim<->collective equivalence checks on 8
forced host devices. Launched as a subprocess by test_tdm_equivalence.py so
the main pytest process keeps its single default device.

Exit code 0 + final line "ALL-OK" on success.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import functools
import random
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import fl, tdm
from repro.core.gossip import metropolis_weights, schedule_mixing_matrix
from repro.core.ptbfla_sim import run_schedule_getmeas
from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule, hypercube_schedule

N = 8
mesh = Mesh(np.array(jax.devices()[:N]), ("node",))


def random_relation(rng: random.Random, n: int = N, p: float = 0.5) -> Relation:
    edges = [(i, j) for i in range(n) for j in range(i + 1, n) if rng.random() < p]
    return Relation.from_edges(edges, nodes=range(n))


def shmap(fn, in_specs, out_specs):
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def check(name, cond):
    if not cond:
        print(f"FAIL: {name}")
        sys.exit(1)
    print(f"ok: {name}")


# ---------------------------------------------------------------------------
# 1. collective get_meas == paper Algorithm 1 oracle (random relations)
# ---------------------------------------------------------------------------
def test_getmeas_equivalence():
    rng = random.Random(0)
    for case in range(25):
        rel = random_relation(rng)
        x = np.arange(N, dtype=np.float32) * 10 + 1  # node i holds 10i+1

        f = shmap(
            functools.partial(tdm.get_meas, rel=rel, axis_name="node", n=N),
            in_specs=P("node"),
            out_specs=(P("node"), P("node")),
        )
        peer_data, mask = jax.jit(f)(x)
        peer_data = np.asarray(peer_data).reshape(N, -1)
        mask = np.asarray(mask).reshape(N, -1)

        # oracle: paper-faithful simulator on the same relation
        sched = TDMSchedule((rel,))
        received, _ = run_schedule_getmeas(
            sched, {i: float(x[i]) for i in range(N)}, N, seed=case
        )
        for i in range(N):
            peers = rel.peers_of(i)
            got = [float(v) for v, m in zip(peer_data[i], mask[i]) if m]
            want = [received[i][0][p] for p in peers] if peers else []
            assert got == want, (case, i, got, want)
    check("collective get_meas == Algorithm 1 oracle (25 random relations)", True)


# ---------------------------------------------------------------------------
# 2. get1_meas == get_meas results (serialized vs multilink; same algebra)
# ---------------------------------------------------------------------------
def test_get1meas_equivalence():
    rng = random.Random(1)
    for case in range(10):
        rel = random_relation(rng)
        x = np.linspace(-1, 1, N).astype(np.float32)
        f_multi = shmap(
            functools.partial(tdm.get_meas, rel=rel, axis_name="node", n=N),
            in_specs=P("node"),
            out_specs=(P("node"), P("node")),
        )
        f_serial = shmap(
            functools.partial(tdm.get1_meas, rel=rel, axis_name="node", n=N),
            in_specs=P("node"),
            out_specs=(P("node"), P("node")),
        )
        a, ma = jax.jit(f_multi)(x)
        b, mb = jax.jit(f_serial)(x)
        assert np.array_equal(np.asarray(ma), np.asarray(mb))
        assert np.allclose(np.asarray(a), np.asarray(b)), case
    check("get1_meas (serialized) == get_meas (multilink) payloads", True)


# ---------------------------------------------------------------------------
# 3. gossip_avg == numpy W @ x (Metropolis weights)
# ---------------------------------------------------------------------------
def test_gossip_matches_mixing_matrix():
    rng = random.Random(2)
    for case in range(15):
        rel = random_relation(rng)
        x = np.random.default_rng(case).normal(size=(N, 4)).astype(np.float32)
        f = shmap(
            functools.partial(tdm.gossip_avg, rel=rel, axis_name="node", n=N),
            in_specs=P("node"),
            out_specs=P("node"),
        )
        got = np.asarray(jax.jit(f)(x)).reshape(N, 4)
        W = metropolis_weights(rel, N)
        want = W @ x.reshape(N, 4)
        assert np.allclose(got, want, atol=1e-5), case
    check("gossip_avg == W @ x for Metropolis W (15 random relations)", True)


# ---------------------------------------------------------------------------
# 4. schedule gossip == product of mixing matrices (paper P2, quantitative)
# ---------------------------------------------------------------------------
def test_schedule_gossip_composition():
    rng = random.Random(3)
    rels = tuple(random_relation(rng) for _ in range(3))
    sched = TDMSchedule(rels)
    x = np.random.default_rng(7).normal(size=(N, 3)).astype(np.float32)
    f = shmap(
        functools.partial(
            tdm.run_gossip_schedule, schedule=sched, axis_name="node", n=N
        ),
        in_specs=P("node"),
        out_specs=P("node"),
    )
    got = np.asarray(jax.jit(f)(x)).reshape(N, 3)
    W = schedule_mixing_matrix(sched, N)
    assert np.allclose(got, W @ x.reshape(N, 3), atol=1e-5)
    check("schedule gossip == product of per-slot mixing matrices", True)


# ---------------------------------------------------------------------------
# 5. hypercube schedule reaches exact consensus in log2(N) slots
# ---------------------------------------------------------------------------
def test_hypercube_consensus():
    sched = hypercube_schedule(N)
    x = np.random.default_rng(9).normal(size=(N,)).astype(np.float32)

    def body(v):
        for rel in sched:
            # pairwise average with hypercube partner: Metropolis on a
            # perfect matching is exactly 0.5/0.5
            v = tdm.gossip_avg(v, rel, "node", N)
        return v

    f = shmap(body, in_specs=P("node"), out_specs=P("node"))
    got = np.asarray(jax.jit(f)(x))
    assert np.allclose(got, x.mean(), atol=1e-5)
    check("hypercube TDM schedule -> exact consensus in log2(N) slots", True)


# ---------------------------------------------------------------------------
# 6. FL rounds: centralized == decentralized-clique (uniform avg)
# ---------------------------------------------------------------------------
def test_fl_round_equivalence():
    x = np.random.default_rng(11).normal(size=(N, 5)).astype(np.float32)
    f_cent = shmap(
        functools.partial(fl.centralized_round, axis_name="node"),
        in_specs=P("node"),
        out_specs=P("node"),
    )
    f_dec = shmap(
        functools.partial(fl.decentralized_round, axis_name="node", n=N),
        in_specs=P("node"),
        out_specs=P("node"),
    )
    a = np.asarray(jax.jit(f_cent)(x))
    b = np.asarray(jax.jit(f_dec)(x))
    assert np.allclose(a, b, atol=1e-5)
    assert np.allclose(a.reshape(N, 5), np.broadcast_to(x.reshape(N, 5).mean(0), (N, 5)), atol=1e-5)
    check("centralized FLA round == decentralized clique round == mean", True)


# ---------------------------------------------------------------------------
# 7. compressed exchange error bounds
# ---------------------------------------------------------------------------
def test_int8_exchange_error():
    rng = random.Random(4)
    rel = random_relation(rng, p=0.7)
    x = np.random.default_rng(13).normal(size=(N, 64)).astype(np.float32)
    f_ref = shmap(
        functools.partial(tdm.neighbor_sum, rel=rel, axis_name="node"),
        in_specs=P("node"),
        out_specs=P("node"),
    )
    f_q = shmap(
        functools.partial(tdm.neighbor_sum_int8, rel=rel, axis_name="node"),
        in_specs=P("node"),
        out_specs=P("node"),
    )
    ref = np.asarray(jax.jit(f_ref)(x))
    got = np.asarray(jax.jit(f_q)(x))
    rel_err = np.linalg.norm(got - ref) / max(np.linalg.norm(ref), 1e-9)
    assert rel_err < 0.02, rel_err
    check(f"int8-compressed neighbor_sum rel-err {rel_err:.4f} < 2%", True)


def test_topk_choco_converges():
    """CHOCO-Gossip with top-k compression: consensus under compressed
    absolute-value exchange (each round ships k=8 of 32 entries)."""
    rng = random.Random(5)
    rel = random_relation(rng, p=0.9)
    cfg = fl.TDMFLAConfig(compression="topk", topk_k=8, choco_gamma=0.4)
    x0 = np.random.default_rng(17).normal(size=(N, 32)).astype(np.float32)

    def rounds(x):
        res = None
        for _ in range(80):
            x, res = fl.tdm_mix(x, rel, "node", N, cfg, res)
        return x

    f = shmap(rounds, in_specs=P("node"), out_specs=P("node"))
    got = np.asarray(jax.jit(f)(x0)).reshape(N, 32)
    target = x0.reshape(N, 32).mean(0)
    err = np.linalg.norm(got - target) / np.linalg.norm(target)
    assert err < 0.05, err
    check(f"top-k CHOCO-Gossip TDM-FLA consensus err {err:.4f} < 5%", True)


def test_topk_error_feedback_on_deltas():
    """EF-top-k on additive deltas: summing compressed gradient-like deltas
    over many rounds recovers the uncompressed accumulation."""
    rng = random.Random(6)
    rel = random_relation(rng, p=0.8)
    g = np.random.default_rng(19).normal(size=(N, 32)).astype(np.float32)

    def rounds(grad):
        res = jnp.zeros_like(grad)
        acc = jnp.zeros_like(grad)
        for _ in range(40):
            summed, res = tdm.neighbor_sum_topk(grad, res, rel, "node", 8)
            acc = acc + summed
        return acc

    f = shmap(rounds, in_specs=P("node"), out_specs=P("node"))
    acc = np.asarray(jax.jit(f)(g)).reshape(N, 32)
    A = rel.adjacency(N).astype(np.float32)
    want = 40 * (A @ g.reshape(N, 32))
    err = np.linalg.norm(acc - want) / np.linalg.norm(want)
    assert err < 0.05, err
    check(f"EF top-k delta accumulation err {err:.4f} < 5%", True)


# ---------------------------------------------------------------------------
# 8. TDM-FLA on a Walker constellation converges to consensus
# ---------------------------------------------------------------------------
def test_walker_tdm_fla():
    from repro.constellation.scenario import ScenarioSpec, ShellSpec, build_scenario

    scn = build_scenario(
        ScenarioSpec(
            shells=(ShellSpec(planes=2, per_plane=N // 2),),
            n_ground=0,
            steps=10,
        )
    )
    sched = TDMSchedule(tuple(scn.relations()))
    x0 = np.random.default_rng(23).normal(size=(N, 6)).astype(np.float32)

    def run(x):
        for rel in sched:
            x, _ = fl.tdm_mix(x, rel, "node", N)
        return x

    f = shmap(run, in_specs=P("node"), out_specs=P("node"))
    got = np.asarray(jax.jit(f)(x0)).reshape(N, 6)
    err = fl.consensus_error(list(got))
    assert err < 0.05, err
    check(f"Walker-constellation TDM-FLA consensus err {err:.4f} < 5%", True)


# ---------------------------------------------------------------------------
# 8b. geometry-derived contact-plan relations == Algorithm 1 oracle, and they
#     drive a real fl_train TDM round (constellation subsystem end-to-end)
# ---------------------------------------------------------------------------
def test_contact_plan_equivalence():
    """Bit-equivalence of the constellation subsystem's relations: every
    non-empty contact-plan slot exchanged via the collective get_meas must
    match the paper-faithful simulator, like case 1 but with topologies
    from orbital geometry instead of random graphs."""
    from repro.constellation import contact_plan as cp
    from repro.constellation import orbits as orb

    geom = orb.WalkerDelta(
        total=N, planes=2, altitude_km=8062.0, inclination_deg=60.0
    )
    plan = cp.build_contact_plan(
        geom,
        duration_s=geom.period_s,
        step_s=geom.period_s / 6,
        max_range_km=14_000.0,
    )
    x = np.arange(N, dtype=np.float32) * 10 + 1
    checked = 0
    for t, rel in enumerate(plan.relations()):
        if len(rel) == 0:
            continue
        f = shmap(
            functools.partial(tdm.get_meas, rel=rel, axis_name="node", n=N),
            in_specs=P("node"),
            out_specs=(P("node"), P("node")),
        )
        peer_data, mask = jax.jit(f)(x)
        peer_data = np.asarray(peer_data).reshape(N, -1)
        mask = np.asarray(mask).reshape(N, -1)
        received, _ = run_schedule_getmeas(
            TDMSchedule((rel,)), {i: float(x[i]) for i in range(N)}, N, seed=t
        )
        for i in range(N):
            peers = rel.peers_of(i)
            got = [float(v) for v, m in zip(peer_data[i], mask[i]) if m]
            want = [received[i][0][p] for p in peers] if peers else []
            assert got == want, (t, i, got, want)
        checked += 1
    assert checked > 0
    check(f"contact-plan relations == Algorithm 1 oracle ({checked} slots)", True)


def test_constellation_drives_fl_round():
    """A geometry-derived slot relation drives one fl_train tdm-mode round
    on the host-device mesh (the acceptance path of the subsystem)."""
    from repro.configs import archs
    from repro.constellation import contact_plan as cp
    from repro.constellation import orbits as orb
    from repro.data import pipeline
    from repro.launch import fl_train
    from repro.models.config import ShapeConfig
    from repro.optim import adamw

    geom = orb.WalkerDelta(
        total=N, planes=2, altitude_km=8062.0, inclination_deg=60.0
    )
    plan = cp.build_contact_plan(
        geom,
        duration_s=geom.period_s,
        step_s=geom.period_s / 4,
        max_range_km=14_000.0,
    )
    cfg = archs.smoke_cfg(archs.get("mamba2-780m"))
    opt_cfg = adamw.OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=100)
    fl_cfg = fl_train.FLConfig(mode="tdm", local_steps=1)
    shape = ShapeConfig("fl", "train", 32, 2)
    fl_mesh = jax.make_mesh((N,), ("data",))
    state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N)

    def batch_fn(rnd):
        per_node = []
        for sat in range(N):
            b = pipeline.host_batch(cfg, shape, step=rnd, seed=100 + sat)
            per_node.append({k: v[None] for k, v in b.items()})
        return {k: np.stack([pn[k] for pn in per_node]) for k in per_node[0]}

    state, logs = fl_train.run_constellation_fl(
        cfg, opt_cfg, fl_mesh, N, fl_cfg, plan, state, batch_fn, rounds=2
    )
    assert len(logs) == 2
    assert all(np.isfinite(l.loss) for l in logs)
    assert any(l.n_links > 0 for l in logs)
    check(
        f"constellation plan drove fl_train tdm rounds (losses "
        f"{[round(l.loss, 2) for l in logs]})",
        True,
    )


def test_optimized_schedule_fl_matches_greedy_bitwise():
    """The rate-aware schedule optimizer must not change *what* is exchanged,
    only when: with zero slew penalty and an antenna budget covering every
    step's degree, greedy and rate-aware emit the identical relation
    sequence, so run_constellation_fl produces bit-for-bit identical
    consensus distances and losses."""
    from repro.configs import archs
    from repro.constellation import contact_plan as cp
    from repro.constellation import orbits as orb
    from repro.data import pipeline
    from repro.launch import fl_train
    from repro.models.config import ShapeConfig
    from repro.optim import adamw

    geom = orb.WalkerDelta(
        total=N, planes=2, altitude_km=8062.0, inclination_deg=60.0
    )
    plan = cp.build_contact_plan(
        geom,
        duration_s=geom.period_s,
        step_s=geom.period_s / 4,
        max_range_km=14_000.0,
    )
    cfg = archs.smoke_cfg(archs.get("mamba2-780m"))
    opt_cfg = adamw.OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=100)
    fl_cfg = fl_train.FLConfig(mode="tdm", local_steps=1)
    shape = ShapeConfig("fl", "train", 32, 2)
    fl_mesh = jax.make_mesh((N,), ("data",))

    def batch_fn(rnd):
        per_node = []
        for sat in range(N):
            b = pipeline.host_batch(cfg, shape, step=rnd, seed=100 + sat)
            per_node.append({k: v[None] for k, v in b.items()})
        return {k: np.stack([pn[k] for pn in per_node]) for k in per_node[0]}

    logs_by_mode = {}
    for optimize in ("greedy", "rate"):
        state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N)
        _, logs = fl_train.run_constellation_fl(
            cfg, opt_cfg, fl_mesh, N, fl_cfg, plan, state, batch_fn,
            rounds=2, optimize=optimize, antennas=N,
            payload_bytes=1 << 16, acquisition_s=0.0,
        )
        logs_by_mode[optimize] = logs

    g, r = logs_by_mode["greedy"], logs_by_mode["rate"]
    assert len(g) == len(r) == 2
    for lg, lr in zip(g, r):
        assert lg.n_links == lr.n_links and lg.alive == lr.alive
        assert lg.loss == lr.loss, (lg.loss, lr.loss)             # bit-for-bit
        assert lg.consensus == lr.consensus, (lg.consensus, lr.consensus)
    check(
        f"optimizer-enabled fl run == greedy bit-for-bit (consensus "
        f"{[f'{l.consensus:.3e}' for l in r]})",
        True,
    )


# ---------------------------------------------------------------------------
# 9. hierarchical (pod x data) gossip on a 2x4 mesh
# ---------------------------------------------------------------------------
def test_hierarchical_gossip():
    mesh2 = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("pod", "data"))
    intra = Relation.clique(list(range(4)))
    inter = Relation.clique(list(range(2)))
    x = np.random.default_rng(29).normal(size=(8, 3)).astype(np.float32)

    def body(v):
        return tdm.hierarchical_gossip(
            v, intra, inter, data_axis="data", pod_axis="pod", n_data=4, n_pods=2
        )

    f = shard_map(body, mesh=mesh2, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")))
    got = np.asarray(jax.jit(f)(x)).reshape(8, 3)
    assert np.allclose(got, x.reshape(8, 3).mean(0), atol=1e-5)
    check("hierarchical pod x data gossip == global mean", True)


if __name__ == "__main__":
    test_getmeas_equivalence()
    test_get1meas_equivalence()
    test_gossip_matches_mixing_matrix()
    test_schedule_gossip_composition()
    test_hypercube_consensus()
    test_fl_round_equivalence()
    test_int8_exchange_error()
    test_topk_choco_converges()
    test_topk_error_feedback_on_deltas()
    test_walker_tdm_fla()
    test_contact_plan_equivalence()
    test_constellation_drives_fl_round()
    test_optimized_schedule_fl_matches_greedy_bitwise()
    test_hierarchical_gossip()
    print("ALL-OK")
