"""Integration tests: end-to-end training loop (loss goes down, checkpoint
restart is bit-exact) and the batched server."""

import jax
import numpy as np
import pytest

from repro.launch import serve as serve_lib
from repro.launch import train as train_lib


@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    losses = train_lib.main([
        "--arch", "mamba2-780m", "--smoke", "--steps", "25",
        "--batch", "8", "--seq", "64", "--lr", "5e-3",
    ])
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])


@pytest.mark.slow
def test_train_checkpoint_restart_exact(tmp_path):
    """Crash/restart reproducibility: run 10 steps straight vs run 5 steps,
    'crash', restore, run 5 more — the tail trajectories must match
    (deterministic data pipeline + checkpointed state)."""
    ck = str(tmp_path / "ck")
    base = ["--arch", "gemma2-9b", "--smoke", "--batch", "4", "--seq", "32",
            "--lr", "1e-3"]
    full = train_lib.main(base + ["--steps", "10",
                                  "--ckpt", str(tmp_path / "full"),
                                  "--ckpt-every", "100"])
    train_lib.main(base + ["--steps", "5", "--ckpt", ck, "--ckpt-every", "5"])
    resumed = train_lib.main(base + ["--steps", "10", "--ckpt", ck,
                                     "--ckpt-every", "100", "--restore"])
    np.testing.assert_allclose(full[5:], resumed, rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_serve_batched_requests():
    srv = serve_lib.main([
        "--arch", "mamba2-780m", "--smoke", "--requests", "5",
        "--batch", "4", "--prompt-len", "6", "--max-new", "5",
    ])
    done = [r for r in ([*srv.active.values()] + srv.queue) if not r.done]
    assert not done  # every request finished
    assert srv.steps > 0
