"""Flight-recorder telemetry: recorder semantics, Chrome-trace schema,
oracle reconciliation, router drop-log bounds, and the BENCH-summary
plumbing — single-process tests plus the launcher for the multi-device
worker (_telemetry_worker.py — subprocess, 8 forced host devices)."""

import json
import os
import pathlib
import subprocess
import sys

import pytest

from repro import telemetry
from repro.core.relation import Relation
from repro.core.schedule import ring
from repro.groundseg import routing

ROOT = pathlib.Path(__file__).resolve().parents[1]


# ------------------------------------------------------------- recorder core
def test_counters_default_on_spans_off():
    rec = telemetry.Recorder()
    rec.counter("a")
    rec.counter("a", 2)
    rec.counter("b", 0.5)
    assert rec.counters == {"a": 3, "b": 0.5}
    # spans/events are no-ops without tracing — nothing recorded, and the
    # span context yields None (no args dict is built)
    with rec.span("s", cat="x", k=1) as sp:
        assert sp is None
    rec.event("e", cat="x", k=2)
    assert rec.spans == [] and rec.events == []


def test_tracing_records_spans_and_events():
    rec = telemetry.Recorder(tracing=True)
    with rec.span("outer", cat="test", fixed=1) as sp:
        sp["result"] = 42
        rec.event("mark", cat="test", at="inside")
    assert len(rec.spans) == 1 and len(rec.events) == 1
    s = rec.spans[0]
    assert s.name == "outer" and s.args == {"fixed": 1, "result": 42}
    assert s.dur_us >= 0 and s.t_start_us >= 0
    e = rec.events[0]
    assert s.t_start_us <= e.t_us <= s.t_start_us + s.dur_us


def test_buffers_bounded_with_drop_counters(monkeypatch):
    monkeypatch.setattr(telemetry.recorder, "MAX_SPANS", 2)
    monkeypatch.setattr(telemetry.recorder, "MAX_EVENTS", 2)
    rec = telemetry.Recorder(tracing=True)
    for i in range(5):
        with rec.span(f"s{i}"):
            pass
        rec.event(f"e{i}")
    assert len(rec.spans) == 2 and len(rec.events) == 2
    assert rec.counters["telemetry.dropped_spans"] == 3
    assert rec.counters["telemetry.dropped_events"] == 3
    # drop-OLDEST (dropped_log_max idiom): the tail of a long run survives,
    # which is the part a post-mortem wants
    assert [s.name for s in rec.spans] == ["s3", "s4"]
    assert [e.name for e in rec.events] == ["e3", "e4"]


def test_buffer_bounds_per_recorder_ctor_args():
    rec = telemetry.Recorder(tracing=True, max_spans=3, max_events=1)
    for i in range(6):
        with rec.span(f"s{i}"):
            pass
        rec.event(f"e{i}")
    assert [s.name for s in rec.spans] == ["s3", "s4", "s5"]
    assert [e.name for e in rec.events] == ["e5"]
    assert rec.counters["telemetry.dropped_spans"] == 3
    assert rec.counters["telemetry.dropped_events"] == 5


def test_record_scope_isolation_and_inheritance():
    outer = telemetry.get_recorder()
    outer_counters = dict(outer.counters)
    with telemetry.record_scope(tracing=True) as rec:
        assert telemetry.get_recorder() is rec
        assert telemetry.tracing_enabled()
        rec.counter("scoped", 7)
        # nested scope inherits flags from the ENCLOSING recorder
        with telemetry.record_scope() as inner:
            assert inner.tracing
            inner.counter("inner_only")
        assert "inner_only" not in rec.counters
    assert telemetry.get_recorder() is outer
    assert outer.counters == outer_counters  # nothing leaked out


def test_pop_counters_prefix_reset():
    rec = telemetry.Recorder()
    rec.counter("fused.spec_cache.hits", 3)
    rec.counter("fused.spec_cache.misses", 1)
    rec.counter("other", 9)
    popped = rec.pop_counters("fused.spec_cache")
    assert popped == {"fused.spec_cache.hits": 3, "fused.spec_cache.misses": 1}
    assert rec.counters == {"other": 9}


def test_span_stats_aggregates():
    rec = telemetry.Recorder(tracing=True)
    for _ in range(3):
        with rec.span("work"):
            pass
    stats = rec.span_stats()
    assert stats["work"]["count"] == 3
    assert stats["work"]["total_ms"] >= 0
    assert stats["work"]["max_ms"] <= stats["work"]["total_ms"]
    assert stats["work"]["mean_ms"] == pytest.approx(
        stats["work"]["total_ms"] / 3
    )


def test_spec_cache_counters_scoped_per_run():
    # the old module-global _SPEC_CACHE_STATS leaked across runs; recorder
    # scopes must isolate the counts
    import jax.numpy as jnp

    from repro.core import fused

    fused.clear_spec_cache()
    tree = {"a": jnp.zeros((3,))}
    with telemetry.record_scope():
        fused.cached_spec(tree, block=32)
        fused.cached_spec(tree, block=32)
        inside = fused.spec_cache_stats()
        assert inside["misses"] == 1 and inside["hits"] == 1
    outside = fused.spec_cache_stats()
    assert outside["hits"] == 0 and outside["misses"] == 0
    fused.clear_spec_cache()


# ------------------------------------------------------- chrome trace schema
def _trace_roundtrip(rec):
    """Serialize + reparse, as a trace viewer would."""
    return json.loads(json.dumps(telemetry.chrome_trace(rec)))


def test_chrome_trace_schema_valid_and_monotonic(tmp_path):
    with telemetry.record_scope(tracing=True) as rec:
        for i in range(4):
            with rec.span(f"round{i}", cat="slot", round=i):
                rec.event("mid", cat="slot", round=i)
        rec.counter("rounds", 4)
        doc = _trace_roundtrip(rec)
        out = telemetry.write_trace(tmp_path / "trace.json", rec)
    assert json.loads(out.read_text()) == doc
    assert set(doc) >= {"traceEvents", "displayTimeUnit", "otherData"}
    evs = doc["traceEvents"]
    assert evs, "trace must not be empty"
    # schema: every event has the required Chrome-trace keys per phase
    last_ts = None
    for ev in evs:
        assert ev["ph"] in ("M", "X", "i", "C")
        assert isinstance(ev["name"], str) and ev["name"]
        assert ev["ts"] >= 0
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
            assert isinstance(ev["tid"], int)
        if ev["ph"] == "i":
            assert ev["s"] == "t"
        # timestamps are sorted (monotonic) across the exported list
        if last_ts is not None:
            assert ev["ts"] >= last_ts
        last_ts = ev["ts"]
    assert evs[0]["ph"] == "M" and evs[0]["name"] == "process_name"
    assert sum(ev["ph"] == "X" for ev in evs) == 4
    assert sum(ev["ph"] == "i" for ev in evs) == 4
    counter_evs = [ev for ev in evs if ev["ph"] == "C"]
    assert {ev["name"] for ev in counter_evs} >= {"rounds"}
    assert doc["otherData"]["counters"]["rounds"] == 4


def test_metrics_snapshot_shape(tmp_path):
    with telemetry.record_scope(tracing=True) as rec:
        with rec.span("w"):
            pass
        rec.counter("c", 2)
        snap = telemetry.metrics_snapshot(rec)
        out = telemetry.write_metrics(tmp_path / "m.json", rec)
    assert json.loads(out.read_text()) == json.loads(json.dumps(snap))
    assert snap["counters"] == {"c": 2}
    assert snap["n_spans"] == 1 and snap["spans"]["w"]["count"] == 1


def test_trace_scope_writes_on_exit(tmp_path):
    path = tmp_path / "t.json"
    with telemetry.trace_scope(path) as rec:
        assert rec.tracing
        with rec.span("s"):
            pass
    doc = json.loads(path.read_text())
    assert any(ev["ph"] == "X" for ev in doc["traceEvents"])
    # no path -> no tracing, no file
    with telemetry.trace_scope(None) as rec:
        assert not rec.tracing


# ----------------------------------------------------------- reconciliation
FAKE_HLO = "\n".join(
    [
        "%p0 = f32[8]{0} parameter(0)",
        "%cp1 = f32[8]{0} collective-permute(%p0), source_target_pairs={{0,1}}",
        "%cp2 = f32[8]{0} collective-permute(%cp1), source_target_pairs={{1,0}}",
        "%ar = f32[8]{0} all-reduce(%cp2), to_apply=%add",
    ]
)


def test_compiled_collective_counts_from_hlo_text():
    counts = telemetry.compiled_collective_counts(FAKE_HLO)
    assert counts == {"collective-permute": 2, "all-reduce": 1}


def test_compare_only_judges_oracle_kinds():
    rep = telemetry.compare(
        {"collective-permute": 2},
        {"collective-permute": 2, "all-gather": 5},
        context="x",
    )
    assert rep.ok and rep.mismatches == ()
    bad = telemetry.compare(
        {"collective-permute": 3}, {"collective-permute": 2}, context="x"
    )
    assert not bad.ok and bad.mismatches == ("collective-permute",)
    assert "expected 3" in bad.describe()


def test_check_compiled_strict_raises_and_counts():
    with telemetry.record_scope(tracing=True) as rec:
        rep = telemetry.check_compiled(
            FAKE_HLO,
            {"collective-permute": 2, "all-reduce": 1},
            context="good",
        )
        assert rep.ok
        with pytest.raises(telemetry.ReconciliationError):
            telemetry.check_compiled(
                FAKE_HLO, {"collective-permute": 99}, context="bad"
            )
        rep2 = telemetry.check_compiled(
            FAKE_HLO, {"collective-permute": 99}, context="bad", strict=False
        )
        assert not rep2.ok
        assert rec.counters["reconcile.checked"] == 3
        assert rec.counters["reconcile.mismatched"] == 2
        assert [e.args["ok"] for e in rec.events if e.name == "reconcile"] == [
            True,
            False,
            False,
        ]


def test_expected_tdm_collectives_math():
    from repro.core import tdm

    rel = ring(8)
    m = len(tdm.edge_coloring(rel))
    assert telemetry.expected_tdm_collectives(rel, 1) == {
        "collective-permute": m
    }
    assert telemetry.expected_tdm_collectives(rel, 2) == {
        "collective-permute": 2 * m
    }
    # int8 ships payload + scales (2 per matching); fused top-k packs
    # values + indices into ONE int32 payload (1 per matching)
    assert telemetry.expected_tdm_collectives(rel, 1, compression="int8") == {
        "collective-permute": 2 * m
    }
    assert telemetry.expected_tdm_collectives(rel, 1, compression="topk") == {
        "collective-permute": m
    }
    assert telemetry.expected_tdm_collectives(rel, 3, compression="topk") == {
        "collective-permute": 3 * m
    }
    empty = Relation.empty(range(4))
    assert telemetry.expected_tdm_collectives(empty, 3) == {
        "collective-permute": 0
    }


def test_expected_hierarchical_collectives_math():
    from repro.core import tdm

    intra = Relation.clique(list(range(4)))
    inter = ring(2)
    mi = len(tdm.edge_coloring(intra))
    mo = len(tdm.edge_coloring(inter))
    assert telemetry.expected_hierarchical_collectives(intra, inter, 1) == {
        "collective-permute": mi + mo
    }
    assert telemetry.expected_hierarchical_collectives(
        intra, inter, 2, compression="int8"
    ) == {"collective-permute": 2 * 2 * (mi + mo)}
    with pytest.raises(ValueError):
        telemetry.expected_hierarchical_collectives(
            intra, inter, 1, compression="topk"
        )


def test_round_fn_cache_oracle_covers_mixed_dtype_compressed():
    """RoundFnCache.expected_collectives no longer skips mixed-dtype
    compressed params: the per-bucket count is uniform, so every fused
    getMeas TDM config gets a real oracle (reconcile never counts a skip)."""
    import ml_dtypes
    import numpy as np

    from repro.core import tdm
    from repro.launch import fl_train

    rel = ring(8)
    m = len(tdm.edge_coloring(rel))
    state = {
        "params": {
            "w": np.zeros((4, 4), np.float32),
            "h": np.zeros((8,), ml_dtypes.bfloat16),
            "b": np.zeros((3,), np.float32),
        }
    }
    per = {"none": 1, "int8": 2, "topk": 1}
    for comp, p in per.items():
        fl_cfg = fl_train.FLConfig(mode="tdm", compression=comp, fused=True)
        cache = fl_train.RoundFnCache(None, None, None, 8, fl_cfg)
        exp = cache.expected_collectives(rel, state)
        assert exp == {"collective-permute": p * m * 2}, (comp, exp)
    # non-fused / get1meas configs still have no proven oracle
    for fl_cfg in (
        fl_train.FLConfig(mode="tdm", fused=False),
        fl_train.FLConfig(mode="tdm", comm="get1meas"),
        fl_train.FLConfig(mode="centralized"),
    ):
        cache = fl_train.RoundFnCache(None, None, None, 8, fl_cfg)
        assert cache.expected_collectives(rel, state) is None


# ------------------------------------------------- router dropped_log bounds
def _isolated_slots(n=4):
    # satellite 0 never reaches the sink (3); 1 and 2 do
    return [Relation.from_edges([(1, 3), (2, 3)], nodes=range(n))]


def test_dropped_log_exact_ages_at_horizon():
    K = 2
    router = routing.MultiWindowRouter(4, [3], max_staleness_windows=K)
    slots = _isolated_slots()
    for _ in range(K + 1):
        wp = router.plan_window(slots)
        assert not wp.dropped  # ages 0..K are all within the horizon
    assert router.pending()[0] == K
    wp = router.plan_window(slots)  # age would become K+1 -> drop
    assert wp.dropped == {0: K + 1}
    assert router.dropped_total == 1
    assert [
        (d.source, d.age, d.window) for d in router.dropped_log
    ] == [(0, K + 1, K + 1)]
    # the dropping satellite re-snapshots the SAME window
    assert 0 in wp.injected and wp.ages[0] == 0


def test_dropped_log_growth_bound_over_many_windows():
    cap = 5
    router = routing.MultiWindowRouter(
        4, [3], max_staleness_windows=0, dropped_log_max=cap
    )
    slots = _isolated_slots()
    windows = 20
    for _ in range(windows):
        router.plan_window(slots)
    # satellite 0 drops once per window after the first
    assert router.dropped_total == windows - 1
    assert len(router.dropped_log) == cap
    # the retained entries are the MOST RECENT drops, in order
    assert [d.window for d in router.dropped_log] == list(
        range(windows - cap, windows)
    )
    assert all(d.age == 1 and d.source == 0 for d in router.dropped_log)


def test_dropped_log_reset_contract():
    router = routing.MultiWindowRouter(4, [3], max_staleness_windows=0)
    slots = _isolated_slots()
    for _ in range(3):
        router.plan_window(slots)
    assert router.dropped_total == 2 and len(router.dropped_log) == 2
    drained = router.reset_dropped_log()
    assert len(drained) == 2
    assert router.dropped_log == []
    assert router.dropped_total == 2  # lifetime count survives the drain
    router.plan_window(slots)
    assert len(router.dropped_log) == 1 and router.dropped_total == 3


def test_dropped_log_max_validation():
    with pytest.raises(ValueError):
        routing.MultiWindowRouter(4, [3], dropped_log_max=-1)


# -------------------------------------------------- optimizer race outcomes
def test_optimizer_race_telemetry():
    import random

    from proptest import st_contact_plan
    from repro.constellation.optimizer import optimize_schedule

    plan = st_contact_plan(max_nodes=8, max_steps=3, p=0.6).draw(
        random.Random(0)
    )
    with telemetry.record_scope(tracing=True) as rec:
        res = optimize_schedule(plan, antennas=2, payload_bytes=1 << 16)
        assert rec.counters["optimizer.races"] == 1
        assert rec.counters[f"optimizer.winner.{res.strategy}"] == 1
        races = [e for e in rec.events if e.name == "optimizer.race"]
        assert len(races) == 1
        args = races[0].args
        assert args["winner"] == res.strategy
        assert set(args["costs_s"]) == set(res.costs)
        assert args["costs_s"][res.strategy] == res.chosen.time_s
        # the optimizer provably never loses to greedy — the recorded race
        # outcome must agree
        assert args["speedup"] >= 1.0 - 1e-12
        assert args["margin_vs_greedy_s"] >= -1e-9


# --------------------------------------------- BENCH summaries + trend files
def test_run_py_parse_and_summary(tmp_path):
    from benchmarks import run as bench_run

    lines = [
        "noise",
        'BENCH {"bench": "x", "metric": 1.0}',
        "BENCH not-json",
        'TELEMETRY {"fl.rounds": 3}',
    ]
    rows, counters = bench_run._parse_lines(lines)
    assert rows == [{"bench": "x", "metric": 1.0}]
    assert counters == {"fl.rounds": 3}
    bench_run._write_summary(tmp_path, "x", rows, counters)
    doc = json.loads((tmp_path / "BENCH_x.json").read_text())
    assert doc == {"bench": "x", "rows": rows, "telemetry": counters}


def test_check_regression_reads_summaries_and_dirs(tmp_path):
    from benchmarks import check_regression

    rows = [{"bench": "b", "cell": "c", "permutes": 4}]
    (tmp_path / "BENCH_a.json").write_text(
        json.dumps({"bench": "a", "rows": rows, "telemetry": {}})
    )
    (tmp_path / "plain.json").write_text(json.dumps(rows))
    assert check_regression.load_rows(str(tmp_path / "BENCH_a.json")) == rows
    assert check_regression.load_rows(str(tmp_path / "plain.json")) == rows
    # directory: BENCH_*.json files preferred and concatenated
    assert check_regression.load_rows(str(tmp_path)) == rows
    failures, improvements, checked, _ = check_regression.compare(
        rows, rows, ("permutes",), 0.2
    )
    assert not failures and checked == 1


def test_check_regression_telemetry_diff_direction_agnostic():
    from benchmarks import check_regression

    base = {"fl.permutes": 24.0, "fl.rounds": 4.0, "fl.skipped": 0.0}
    # identical counters: clean
    failures, table = check_regression.compare_telemetry(base, dict(base), 0.2)
    assert failures == []
    assert all(r[6] == "ok" for r in table)
    # drift UP and drift DOWN both fail (schedule changed either way)
    up = dict(base, **{"fl.permutes": 48.0})
    down = dict(base, **{"fl.permutes": 12.0})
    for run in (up, down):
        failures, table = check_regression.compare_telemetry(base, run, 0.2)
        assert len(failures) == 1 and "fl.permutes" in failures[0]
        assert any(r[2] == "fl.permutes" and r[6] == "DRIFTED" for r in table)
    # within threshold: clean
    failures, _ = check_regression.compare_telemetry(
        base, dict(base, **{"fl.permutes": 26.0}), 0.2
    )
    assert failures == []
    # zero baseline -> nonzero is drift; missing counter fails; run-only
    # counters are reported as new but don't fail
    failures, table = check_regression.compare_telemetry(
        base, {"fl.permutes": 24.0, "fl.skipped": 2.0, "extra": 1.0}, 0.2
    )
    msgs = "\n".join(failures)
    assert "fl.skipped" in msgs and "zero baseline" in msgs
    assert "fl.rounds" in msgs and "missing" in msgs
    assert len(failures) == 2
    assert any(r[2] == "extra" and r[6] == "new" for r in table)
    # prefix filter gates which counters can fail
    failures, _ = check_regression.compare_telemetry(
        base, {"fl.permutes": 999.0, "fl.rounds": 4.0, "fl.skipped": 0.0},
        0.2, prefix="fl.rounds",
    )
    assert failures == []


def test_check_regression_telemetry_loading_and_exit_code(tmp_path, capsys):
    from benchmarks import check_regression

    rows = [{"bench": "b", "cell": "c", "permutes": 4}]
    base = tmp_path / "base"
    run = tmp_path / "run"
    base.mkdir()
    run.mkdir()
    (base / "BENCH_a.json").write_text(json.dumps(
        {"bench": "a", "rows": rows, "telemetry": {"fl.permutes": 24}}
    ))
    (base / "BENCH_b.json").write_text(json.dumps(
        {"bench": "b", "rows": [], "telemetry": {"fl.permutes": 6, "x": 1}}
    ))
    # directory load sums counters across summaries
    assert check_regression.load_telemetry(str(base)) == {
        "fl.permutes": 30.0, "x": 1.0
    }
    # plain row-list files carry no counters
    (tmp_path / "plain.json").write_text(json.dumps(rows))
    assert check_regression.load_telemetry(str(tmp_path / "plain.json")) == {}

    # injected counter drift fails the job end-to-end (exit code 1)
    (run / "BENCH_a.json").write_text(json.dumps(
        {"bench": "a", "rows": rows, "telemetry": {"fl.permutes": 24}}
    ))
    (run / "BENCH_b.json").write_text(json.dumps(
        {"bench": "b", "rows": [], "telemetry": {"fl.permutes": 18, "x": 1}}
    ))
    rc = check_regression.main(
        ["--run", str(run), "--baseline", str(base)]
    )
    out = capsys.readouterr().out
    assert rc == 1 and "fl.permutes" in out and "drifted" in out
    # same run with --no-telemetry (rows match): clean
    rc = check_regression.main(
        ["--run", str(run), "--baseline", str(base), "--no-telemetry"]
    )
    capsys.readouterr()
    assert rc == 0


# ------------------------------------------- metrics registry (ISSUE 9)
def test_histogram_fixed_buckets_quantiles_and_summary():
    from repro.telemetry import metrics

    h = metrics.Histogram(bounds=(1, 2, 4, 8))
    for v in (0.5, 1.5, 3, 3, 7, 100):
        h.observe(v)
    assert h.count == 6 and h.total == pytest.approx(115.0)
    assert h.vmin == 0.5 and h.vmax == 100
    # cumulative counts are monotone and end at the observation count
    cum = h.cumulative()
    assert cum == sorted(cum) and cum[-1] == h.count
    s = h.summary()
    assert set(s) == {"count", "sum", "mean", "min", "max", "p50", "p90",
                      "p99"}
    assert s["mean"] == pytest.approx(115.0 / 6)
    # quantiles interpolate within buckets and clamp to observed extremes
    assert h.quantile(0.0) == 0.5
    assert h.quantile(1.0) == 100
    assert 1 <= h.quantile(0.5) <= 4
    # overflow bucket resolves to the observed max, not infinity
    assert h.quantile(0.99) <= 100


def test_metrics_registry_lands_on_active_recorder():
    from repro.telemetry import metrics

    with telemetry.record_scope() as rec:
        metrics.set_gauge("g.x", 0.25)
        metrics.ratio_gauge("g.rate", 3, 4)
        metrics.ratio_gauge("g.skipped", 1, 0)   # zero denom: no sample
        for v in (1, 2, 40):
            metrics.observe("q.depth", v, buckets=metrics.COUNT_BUCKETS)
        assert rec.gauges == {"g.x": 0.25, "g.rate": 0.75}
        assert metrics.get_gauge("g.rate") == 0.75
        assert metrics.get_histogram("q.depth").count == 3
        snap = telemetry.metrics_snapshot(rec)
    assert snap["gauges"]["g.rate"] == 0.75
    assert snap["histograms"]["q.depth"]["count"] == 3
    assert snap["histograms"]["q.depth"]["max"] == 40
    # scope exit: nothing leaked onto the enclosing recorder
    assert "g.x" not in telemetry.get_recorder().gauges


def test_prometheus_text_exposition(tmp_path):
    from repro.telemetry import metrics

    with telemetry.record_scope() as rec:
        rec.counter("fl.rounds", 4)
        metrics.set_gauge("cache.hit_rate", 0.5)
        for v in (0.5, 1.5, 3):
            metrics.observe("lat", v, buckets=(1, 2, 4))
        text = telemetry.prometheus_text(rec)
        out = telemetry.write_prometheus(tmp_path / "m.prom", rec)
    assert out.read_text() == text
    lines = text.splitlines()
    assert "# TYPE fl_rounds counter" in lines and "fl_rounds 4" in lines
    assert "# TYPE cache_hit_rate gauge" in lines
    assert "cache_hit_rate 0.5" in lines
    # cumulative buckets: le=1 -> 1 obs, le=2 -> 2, le=4 -> 3, +Inf == count
    assert 'lat_bucket{le="1"} 1' in lines
    assert 'lat_bucket{le="2"} 2' in lines
    assert 'lat_bucket{le="4"} 3' in lines
    assert 'lat_bucket{le="+Inf"} 3' in lines
    assert "lat_sum 5" in lines and "lat_count 3" in lines


def test_chrome_trace_counter_samples_after_spans():
    """Counter ``"C"`` samples ride at the trace end: every one sorts at or
    after the last span/event timestamp, so the Perfetto counter track
    shows the final values, and names stay in sorted order."""
    with telemetry.record_scope(tracing=True) as rec:
        with rec.span("w"):
            rec.event("mark")
        rec.counter("b.count", 2)
        rec.counter("a.count", 1)
        doc = json.loads(json.dumps(telemetry.chrome_trace(rec)))
    evs = doc["traceEvents"]
    t_busy = max(
        ev["ts"] + ev.get("dur", 0.0) for ev in evs if ev["ph"] in ("X", "i")
    )
    counter_evs = [ev for ev in evs if ev["ph"] == "C"]
    assert [ev["name"] for ev in counter_evs] == ["a.count", "b.count"]
    assert all(ev["ts"] >= t_busy for ev in counter_evs)
    # and the trailing suffix of the sorted list is exactly the counters
    assert [ev["ph"] for ev in evs[-len(counter_evs):]] == ["C", "C"]
    assert doc["otherData"]["counters"] == {"a.count": 1, "b.count": 2}
    assert doc["otherData"]["gauges"] == {}


def test_pop_counters_and_snapshot_under_nested_scopes():
    """pop_counters/counters_snapshot prefix semantics: prefix filtering is
    plain startswith on the ACTIVE recorder, and nested scopes neither see
    nor disturb the enclosing recorder's counters."""
    with telemetry.record_scope() as outer:
        outer.counter("sub.a", 1)
        outer.counter("sub.b", 2)
        outer.counter("other", 9)
        with telemetry.record_scope() as inner:
            inner.counter("sub.a", 100)
            # snapshot reads the innermost scope only
            assert telemetry.counters_snapshot() == {"sub.a": 100}
            assert telemetry.counters_snapshot("sub.") == {"sub.a": 100}
            assert inner.pop_counters("sub.") == {"sub.a": 100}
            assert inner.counters == {}
        # inner scope popped its own counters; outer's are untouched
        assert telemetry.counters_snapshot("sub.") == {"sub.a": 1, "sub.b": 2}
        popped = outer.pop_counters("sub.")
        assert popped == {"sub.a": 1, "sub.b": 2}
        assert telemetry.counters_snapshot() == {"other": 9}


# ------------------------------------- check_regression silent-pass guards
def test_check_regression_fails_on_zero_row_summaries(tmp_path, capsys):
    from benchmarks import check_regression

    rows = [{"bench": "b", "cell": "c", "permutes": 4}]
    good = tmp_path / "good.json"
    empty = tmp_path / "empty.json"
    good.write_text(json.dumps(rows))
    empty.write_text(json.dumps({"bench": "b", "rows": [],
                                 "telemetry": {}}))
    # empty RUN fails (was: baseline rows each fail row-match — keep that
    # too — but the guard names the real cause)
    rc = check_regression.main(
        ["--run", str(empty), "--baseline", str(good)]
    )
    out = capsys.readouterr().out
    assert rc == 1 and "zero BENCH rows" in out
    # empty BASELINE fails (was: nothing to iterate -> exit 0, silent pass)
    rc = check_regression.main(
        ["--run", str(good), "--baseline", str(empty)]
    )
    out = capsys.readouterr().out
    assert rc == 1 and "zero BENCH rows" in out


def test_check_regression_fails_when_nothing_compared(tmp_path, capsys):
    from benchmarks import check_regression

    # rows match but carry NONE of the default metrics: the old gate
    # compared zero cells and exited 0
    rows = [{"bench": "b", "cell": "c", "wall_ms": 1.0}]
    base = tmp_path / "base.json"
    run = tmp_path / "run.json"
    base.write_text(json.dumps(rows))
    run.write_text(json.dumps(rows))
    rc = check_regression.main(["--run", str(run), "--baseline", str(base)])
    out = capsys.readouterr().out
    assert rc == 1 and "zero metric cells compared" in out
    # an explicitly requested metric that matches no baseline row fails
    # (typo protection); the same request naming a real metric passes
    rc = check_regression.main(
        ["--run", str(run), "--baseline", str(base), "--metrics", "wall_msx"]
    )
    out = capsys.readouterr().out
    assert rc == 1 and "matches no baseline row" in out
    rc = check_regression.main(
        ["--run", str(run), "--baseline", str(base), "--metrics", "wall_ms"]
    )
    capsys.readouterr()
    assert rc == 0


# ------------------------------------------------------- multidevice worker
@pytest.mark.slow
def test_telemetry_multidevice_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT / 'tests'}:" + env.get(
        "PYTHONPATH", ""
    )
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_telemetry_worker.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=1800,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    assert proc.returncode == 0, "worker failed"
    assert "ALL-OK" in proc.stdout
