"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
ref.py pure-jnp oracles. Kernels run in interpret=True mode (the container
is CPU; TPU is the compile target).

The ``tdm_compress`` family additionally gets a DIFFERENTIAL suite: the
Pallas kernels must match the jnp oracles BIT FOR BIT across random shapes
× k × block (via the proptest shim) and adversarial edges (ragged tails,
k=0, k=block, all-equal magnitudes, NaN/inf payloads). Both sides run
under ``jax.jit`` — XLA contracts ``a + w*v`` into an FMA under jit but
not in eager op-by-op execution, so comparing a jitted kernel against an
eager oracle shows spurious 1-ulp diffs that say nothing about the kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, st_choice, st_int

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref
from repro.kernels.tdm_compress import ops as q_ops
from repro.kernels.tdm_compress import ref as q_ref
from repro.models.attention import AttnSpec, flash_attention_train, naive_attention
from repro.models import mamba2 as mamba_lib


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention kernel vs oracle
# ---------------------------------------------------------------------------

FA_CASES = [
    # (B, Sq, Skv, H, KV, hd, causal, window, softcap, dtype)
    (1, 256, 256, 2, 2, 64, True, None, None, jnp.float32),
    (2, 256, 256, 4, 2, 64, True, None, None, jnp.float32),      # GQA
    (1, 384, 384, 4, 1, 64, True, None, None, jnp.float32),      # MQA
    (1, 256, 256, 2, 2, 64, True, 128, None, jnp.float32),       # window
    (1, 256, 256, 2, 2, 64, True, None, 50.0, jnp.float32),      # softcap
    (1, 256, 256, 2, 2, 64, False, None, None, jnp.float32),     # bidi
    (2, 256, 256, 4, 2, 128, True, 128, 30.0, jnp.float32),      # all
    (1, 256, 256, 2, 2, 64, True, None, None, jnp.bfloat16),
    (1, 128, 512, 2, 2, 96, False, None, None, jnp.float32),     # cross, pad hd
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_kernel_matches_ref(case):
    B, Sq, Skv, H, KV, hd, causal, window, softcap, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q = rand(ks[0], (B, Sq, H, hd), dtype)
    k = rand(ks[1], (B, Skv, KV, hd), dtype)
    v = rand(ks[2], (B, Skv, KV, hd), dtype)
    got = fa_ops.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=128, block_k=128, interpret=True,
    )
    want = fa_ref.attention_ref(
        q, k, v, causal=causal, window=window, softcap=softcap
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_model_flash_matches_kernel_and_ref():
    """Three-way: model XLA path == Pallas kernel == naive oracle."""
    B, S, H, KV, hd = 2, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = rand(ks[0], (B, S, H, hd), jnp.float32)
    k = rand(ks[1], (B, S, KV, hd), jnp.float32)
    v = rand(ks[2], (B, S, KV, hd), jnp.float32)
    spec = AttnSpec(causal=True, window=128, softcap=50.0, block_q=128, block_k=128)
    xla = flash_attention_train(q, k, v, spec)
    kern = fa_ops.flash_attention(
        q, k, v, causal=True, window=128, softcap=50.0,
        block_q=128, block_k=128, interpret=True,
    )
    ref = fa_ref.attention_ref(q, k, v, causal=True, window=128, softcap=50.0)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(ref), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_model_flash_gradients_match_naive():
    """The manual custom_vjp backward == AD through the naive oracle."""
    B, S, H, KV, hd = 1, 64, 2, 1, 32
    spec = AttnSpec(causal=True, window=48, softcap=20.0, block_q=16, block_k=16)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (B, S, H, hd), jnp.float32)
    k = rand(ks[1], (B, S, KV, hd), jnp.float32)
    v = rand(ks[2], (B, S, KV, hd), jnp.float32)

    def loss_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention_train(q, k, v, spec)))

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(naive_attention(q, k, v, spec)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# SSD kernel vs sequential oracle
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (B, S, H, P, G, N, chunk, dtype)
    (1, 128, 2, 16, 1, 32, 32, jnp.float32),
    (2, 256, 4, 64, 2, 64, 64, jnp.float32),
    (1, 256, 4, 64, 4, 128, 128, jnp.float32),
    (1, 128, 2, 32, 1, 64, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_matches_sequential_ref(case):
    B, S, H, P, G, N, chunk, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 5)
    xh = rand(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(rand(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,), minval=-1.0, maxval=1.0))
    Bv = rand(ks[3], (B, S, G, N), dtype)
    Cv = rand(ks[4], (B, S, G, N), dtype)

    y, state = ssd_ops.ssd_scan(xh, dt, A, Bv, Cv, chunk=chunk, interpret=True)

    # oracle in kernel layout
    r = H // G
    xf = xh.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    Af = jnp.broadcast_to(A[None], (B, H)).reshape(B * H)
    Bh = jnp.broadcast_to(Bv[:, :, :, None, :], (B, S, G, r, N)).transpose(
        0, 2, 3, 1, 4
    ).reshape(B * H, S, N)
    Ch = jnp.broadcast_to(Cv[:, :, :, None, :], (B, S, G, r, N)).transpose(
        0, 2, 3, 1, 4
    ).reshape(B * H, S, N)
    y_ref, state_ref = ssd_ref.ssd_ref(xf, dtf, Af, Bh, Ch)
    y_ref = y_ref.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    state_ref = state_ref.reshape(B, H, P, N)

    # chunked matmuls vs sequential recurrence sum in different orders;
    # fp32 noise grows with N (reduction width) — scale-aware tolerances.
    rtol, atol = (3e-2, 3e-1) if dtype == jnp.bfloat16 else (2e-3, 1e-2)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), rtol=rtol, atol=atol
    )
    np.testing.assert_allclose(
        np.asarray(state, np.float32), np.asarray(state_ref, np.float32),
        rtol=rtol, atol=atol,
    )


def test_model_ssd_chunked_matches_ref():
    """The model's XLA chunked SSD == sequential oracle (independent of the
    Pallas kernel)."""
    B, S, H, P, G, N, chunk = 2, 128, 4, 16, 2, 32, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    xh = rand(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,), minval=-1.0, maxval=1.0))
    Bv = rand(ks[3], (B, S, G, N), jnp.float32)
    Cv = rand(ks[4], (B, S, G, N), jnp.float32)
    y, state = mamba_lib.ssd_chunked(xh, dt, A, Bv, Cv, chunk)

    r = H // G
    xf = xh.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    Af = jnp.broadcast_to(A[None], (B, H)).reshape(B * H)
    Bh = jnp.broadcast_to(Bv[:, :, :, None, :], (B, S, G, r, N)).transpose(
        0, 2, 3, 1, 4
    ).reshape(B * H, S, N)
    Ch = jnp.broadcast_to(Cv[:, :, :, None, :], (B, S, G, r, N)).transpose(
        0, 2, 3, 1, 4
    ).reshape(B * H, S, N)
    y_ref, state_ref = ssd_ref.ssd_ref(xf, dtf, Af, Bh, Ch)
    y_ref = y_ref.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(state), np.asarray(state_ref.reshape(B, H, P, N)),
        rtol=1e-4, atol=1e-4,
    )


def test_ssd_decode_step_consistent_with_scan():
    """mamba_decode_step over S steps == chunked scan on the full sequence."""
    B, S, H, P, G, N = 1, 16, 2, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(13), 5)
    xh = rand(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,), minval=-1.0, maxval=1.0))
    Bv = rand(ks[3], (B, S, G, N), jnp.float32)
    Cv = rand(ks[4], (B, S, G, N), jnp.float32)
    y_scan, state_scan = mamba_lib.ssd_chunked(xh, dt, A, Bv, Cv, chunk=8)

    # manual per-step recurrence
    state = jnp.zeros((B, H, P, N))
    r = H // G
    ys = []
    for t in range(S):
        Bh = jnp.broadcast_to(Bv[:, t, :, None, :], (B, G, r, N)).reshape(B, H, N)
        Ch = jnp.broadcast_to(Cv[:, t, :, None, :], (B, G, r, N)).reshape(B, H, N)
        decay = jnp.exp(dt[:, t] * A[None])
        state = decay[:, :, None, None] * state + jnp.einsum(
            "bhn,bhp,bh->bhpn", Bh, xh[:, t], dt[:, t]
        )
        ys.append(jnp.einsum("bhn,bhpn->bhp", Ch, state))
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_seq), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_scan), np.asarray(state), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# int8 TDM payload kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,block", [(1024, 256), (4096, 1024), (8192, 512)])
def test_quant_kernel_matches_ref(n, block):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,), jnp.float32) * 3.0
    q, s, _ = q_ops.quantize_payload(x, block=block, interpret=True)
    q_want, s_want = q_ref.quantize_ref(x, block=block)
    np.testing.assert_array_equal(np.asarray(q[:n]), np.asarray(q_want))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_want), rtol=1e-6)

    back = q_ops.dequantize_payload(q, s, (n,), block=block, interpret=True)
    back_ref = q_ref.dequantize_ref(q_want, s_want, block=block)
    np.testing.assert_allclose(np.asarray(back), np.asarray(back_ref), rtol=1e-6)
    # quantization error bound: blockwise absmax/127
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.repeat(np.asarray(s_want), block) * 0.5 + 1e-7
    assert (err <= bound + 1e-6).all()


@pytest.mark.parametrize("shape", [(33,), (5, 7), (128, 3, 3)])
def test_quant_padding_roundtrip(shape):
    """Non-multiple sizes are padded and exactly un-padded."""
    x = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    qq, ss, shp = q_ops.quantize_payload(x, block=64, interpret=True)
    back = q_ops.dequantize_payload(qq, ss, tuple(shape), block=64, interpret=True)
    assert back.shape == tuple(shape)
    assert np.max(np.abs(np.asarray(back) - np.asarray(x))) < 0.05


@pytest.mark.parametrize("n,block", [(100, 64), (1, 256), (1023, 1024), (1025, 1024)])
def test_quant_kernel_arbitrary_length(n, block):
    """quantize_fwd/dequantize_fwd pad internally: any flat length works and
    matches the blockwise ref, payload comes back exactly n entries long."""
    from repro.kernels.tdm_compress.tdm_compress import dequantize_fwd, quantize_fwd

    x = jax.random.normal(jax.random.PRNGKey(n), (n,), jnp.float32) * 2.0
    q, s = quantize_fwd(x, block=block, interpret=True)
    q_want, s_want = q_ref.quantize_ref(x, block=block)
    assert q.shape == (n,)
    assert s.shape == (-(-n // block),)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_want))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_want), rtol=1e-6)
    back = dequantize_fwd(q, s, block=block, interpret=True)
    back_ref = q_ref.dequantize_ref(q_want, s_want, block=block)
    assert back.shape == (n,)
    np.testing.assert_allclose(np.asarray(back), np.asarray(back_ref), rtol=1e-6)


@pytest.mark.parametrize("n,block,w", [(512, 256, 0.25), (1000, 256, 1.0), (77, 64, -0.5)])
def test_dequant_accumulate_matches_ref(n, block, w):
    """Fused receive-side pass acc + w * dequant(q, s) == oracle."""
    ks = jax.random.split(jax.random.PRNGKey(n), 2)
    x = jax.random.normal(ks[0], (n,), jnp.float32) * 3.0
    acc = jax.random.normal(ks[1], (n,), jnp.float32)
    q, s = q_ref.quantize_ref(x, block=block)
    got = q_ops.dequant_accumulate(q, s, acc, w, block=block, interpret=True)
    want = q_ref.dequant_acc_ref(q, s, acc, w, block=block)
    assert got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# differential suite: tdm_compress Pallas kernels ≡ jnp oracles, bit for bit
# ---------------------------------------------------------------------------
# Kernel side goes through the jitted q_ops wrappers (interpret mode on
# CPU); oracle side gets its own jit so both see identical XLA arithmetic
# (FMA contraction — see the module docstring).

_ref_quantize = jax.jit(q_ref.quantize_ref, static_argnames=("block",))
_ref_quant_scaled = jax.jit(
    q_ref.quantize_scaled_ref, static_argnames=("block",)
)
_ref_dequant_acc = jax.jit(q_ref.dequant_acc_ref, static_argnames=("block",))
_ref_topk = jax.jit(
    q_ref.topk_sparsify_ref, static_argnums=(1,), static_argnames=("block",)
)
_ref_scatter_acc = jax.jit(q_ref.scatter_acc_ref, static_argnames=("block",))


def _payload(seed: int, n: int, kind: str) -> np.ndarray:
    """Adversarial payload generator: 'normal' random scales, 'ties' holds
    only ±1 (every magnitude equal — selection must break toward the lowest
    index), 'edge' sprinkles NaN/±inf through a normal payload."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * rng.uniform(0.1, 10.0)).astype(np.float32)
    if kind == "ties":
        x = np.where(x >= 0, np.float32(1.0), np.float32(-1.0))
    elif kind == "edge":
        m = rng.random(n)
        x[m < 0.05] = np.nan
        x[(m >= 0.05) & (m < 0.10)] = np.inf
        x[(m >= 0.10) & (m < 0.15)] = -np.inf
    return x


def _assert_topk_equal(x: np.ndarray, k: int, block: int) -> None:
    dense, vals, idxs = q_ops.topk_sparsify(
        jnp.asarray(x), k=k, block=block, interpret=True
    )
    dense_w, vals_w, idxs_w = _ref_topk(jnp.asarray(x), k, block=block)
    nb = -(-x.shape[0] // block)
    assert dense.shape == (x.shape[0],)
    assert vals.shape == idxs.shape == (nb, k)
    # assert_array_equal treats positionally-matching NaNs as equal, so
    # NaN-carrying payloads still compare bit-for-bit
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(dense_w))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(vals_w))
    np.testing.assert_array_equal(np.asarray(idxs), np.asarray(idxs_w))


@given(
    st_int(1, 2500),
    st_int(0, 128),
    st_choice([128, 256]),
    st_choice(["normal", "ties", "edge"]),
    cases=12,
)
def test_topk_sparsify_differential(n, k, block, kind):
    _assert_topk_equal(_payload(n * 7 + k, n, kind), min(k, block), block)


@pytest.mark.parametrize(
    "n,k,block,kind",
    [
        (1023, 7, 1024, "normal"),     # ragged tail inside one block
        (1025, 5, 1024, "edge"),       # ragged tail spilling a second block
        (256, 0, 256, "normal"),       # k = 0: empty payload, zero dense
        (256, 256, 256, "ties"),       # k = block = n: everything selected
        (64, 64, 256, "edge"),         # k = n < block with NaN/inf
        (1, 1, 64, "normal"),          # single element
        (500, 32, 128, "ties"),        # all-equal magnitudes, ragged
    ],
)
def test_topk_sparsify_adversarial_edges(n, k, block, kind):
    _assert_topk_equal(_payload(n + k, n, kind), k, block)


@given(
    st_int(1, 2500),
    st_int(0, 96),
    st_choice([128, 256]),
    st_choice(["normal", "ties", "edge"]),
    cases=10,
)
def test_scatter_accumulate_differential(n, k, block, kind):
    k = min(k, block)
    x = _payload(n * 13 + k, n, kind)
    rng = np.random.default_rng(n + 1)
    acc = rng.standard_normal(n).astype(np.float32)
    w = np.float32(rng.uniform(-1.5, 1.5))
    _, vals, idxs = _ref_topk(jnp.asarray(x), k, block=block)
    got = q_ops.scatter_accumulate(
        vals, idxs, jnp.asarray(acc), w, block=block, interpret=True
    )
    want = _ref_scatter_acc(vals, idxs, jnp.asarray(acc), w, block=block)
    assert got.shape == (n,)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st_int(1, 3000), st_choice([128, 256, 512]), cases=10)
def test_quantize_scaled_differential(n, block):
    """Shared-scale encode (the quantize-once relay's send side): kernel ==
    oracle exactly, under pmax-style scales ≥ the local blockwise scales."""
    x = _payload(n, n, "normal")
    rng = np.random.default_rng(n + 2)
    scales = np.asarray(q_ref.blockwise_scales_ref(jnp.asarray(x), block=block))
    shared = (scales * rng.uniform(1.0, 3.0, size=scales.shape)).astype(
        np.float32
    )
    got = q_ops.quantize_scaled(
        jnp.asarray(x), jnp.asarray(shared), block=block, interpret=True
    )
    want = _ref_quant_scaled(jnp.asarray(x), jnp.asarray(shared), block=block)
    assert got.dtype == jnp.int8 and got.shape == (n,)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st_int(1, 3000), st_choice([128, 256, 512]), cases=10)
def test_quantize_differential_bitwise(n, block):
    x = _payload(n * 3, n, "normal")
    q, s, _ = q_ops.quantize_payload(jnp.asarray(x), block=block, interpret=True)
    q_w, s_w = _ref_quantize(jnp.asarray(x), block=block)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_w))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_w))


@given(st_int(1, 2500), st_choice([128, 256]), st_choice([1, 6]), cases=10)
def test_dequant_accumulate_int16_differential(n, block, sources):
    """Integer-domain relay sums: int16 q (up to ±127×sources, the
    quantize-once relay's wire format) dequantize+accumulate bit-for-bit."""
    rng = np.random.default_rng(n * 5 + sources)
    lim = 127 * sources
    q = rng.integers(-lim, lim + 1, size=n).astype(np.int16)
    nb = -(-n // block)
    s = rng.uniform(1e-4, 0.5, size=nb).astype(np.float32)
    acc = rng.standard_normal(n).astype(np.float32)
    w = np.float32(rng.uniform(-1.0, 1.0))
    got = q_ops.dequant_accumulate(
        jnp.asarray(q), jnp.asarray(s), jnp.asarray(acc), w,
        block=block, interpret=True,
    )
    want = _ref_dequant_acc(
        jnp.asarray(q), jnp.asarray(s), jnp.asarray(acc), w, block=block
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
