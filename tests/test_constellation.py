"""Tests for the orbital constellation subsystem: geometry, link physics,
contact plans feeding the universal TDM collectives, and the cost model.

The 4x5 Walker-delta case is the subsystem's acceptance scenario: a
TDMSchedule generated from pure orbital geometry whose every slot is a
valid exchange relation respecting a per-node antenna budget.
"""

import math

import numpy as np
import pytest

from repro.constellation import contact_plan, cost, links, orbits
from repro.constellation.contact_plan import build_contact_plan
from repro.constellation.links import Link, LinkBudget
from repro.constellation.orbits import (
    R_EARTH_KM,
    GroundStation,
    WalkerDelta,
    propagate,
    sample_times,
)
from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule


GEOM_4x5 = WalkerDelta(total=20, planes=4, phasing=1, altitude_km=1400.0)


def plan_4x5(steps: int = 12) -> contact_plan.ContactPlan:
    return build_contact_plan(
        GEOM_4x5, duration_s=GEOM_4x5.period_s, step_s=GEOM_4x5.period_s / steps
    )


# ----------------------------------------------------------------- orbits
def test_circular_orbit_radius_and_determinism():
    ts = sample_times(3600.0, 60.0)
    pos = GEOM_4x5.positions(ts)
    assert pos.shape == (len(ts), 20, 3)
    radii = np.linalg.norm(pos, axis=-1)
    assert np.allclose(radii, GEOM_4x5.orbit_radius_km, rtol=1e-12)
    assert np.array_equal(pos, GEOM_4x5.positions(ts))  # bit-identical rerun


def test_orbit_period_closes():
    """After one period every satellite returns to its start position."""
    p0 = GEOM_4x5.positions(0.0)
    p1 = GEOM_4x5.positions(GEOM_4x5.period_s)
    assert np.allclose(p0, p1, atol=1e-6)


def test_leo_period_sanity():
    """~550 km LEO orbits take roughly 95 minutes."""
    leo = WalkerDelta(total=4, planes=2, altitude_km=550.0)
    assert 90.0 < leo.period_s / 60.0 < 100.0


def test_walker_star_spreads_raan_over_half_circle():
    delta = WalkerDelta(total=8, planes=4, pattern="delta")
    star = WalkerDelta(total=8, planes=4, pattern="star")
    assert math.isclose(delta.raan_rad(2), math.pi)
    assert math.isclose(star.raan_rad(2), math.pi / 2)
    with pytest.raises(ValueError):
        WalkerDelta(total=8, planes=4, pattern="spiral")
    with pytest.raises(ValueError):
        WalkerDelta(total=9, planes=4)


def test_ground_station_rotates_with_earth():
    gs = GroundStation(lat_deg=45.0, lon_deg=10.0)
    ts = np.array([0.0, 3600.0])
    pos = gs.positions(ts)
    assert np.allclose(np.linalg.norm(pos, axis=-1), R_EARTH_KM)
    assert pos[0, 2] == pytest.approx(pos[1, 2])       # latitude fixed
    assert not np.allclose(pos[0, :2], pos[1, :2])     # longitude advanced


def test_propagate_stacks_ground_stations_after_satellites():
    ts = sample_times(600.0, 300.0)
    tracks = propagate(GEOM_4x5, ts, [GroundStation(0.0, 0.0)])
    assert tracks.shape == (2, 21, 3)
    assert np.allclose(np.linalg.norm(tracks[:, -1], axis=-1), R_EARTH_KM)


# ------------------------------------------------------------------ links
def test_line_of_sight_occlusion():
    r_leo = GEOM_4x5.orbit_radius_km          # 7771 km
    a = np.array([r_leo, 0.0, 0.0])
    assert not links.line_of_sight(a, -a)     # Earth dead-center
    b = np.array([0.0, r_leo, 0.0])
    # quarter arc at 1400 km: chord grazes at r/sqrt(2) ~ 5495 km — blocked
    assert not links.line_of_sight(a, b)
    r_meo = R_EARTH_KM + 8062.0               # same arc from MEO clears
    assert links.line_of_sight(
        np.array([r_meo, 0.0, 0.0]), np.array([0.0, r_meo, 0.0])
    )
    assert links.line_of_sight(a, b) == links.line_of_sight(b, a)


def test_link_budget_monotone_in_range():
    budget = LinkBudget()
    r1, r2 = budget.data_rate_bps(1000.0), budget.data_rate_bps(4000.0)
    assert r1 > r2 > 0
    # FSPL doubles 6 dB per doubled range
    assert budget.fspl_db(2000.0) - budget.fspl_db(1000.0) == pytest.approx(
        20.0 * math.log10(2.0)
    )


def test_visibility_graph_weights():
    pos = GEOM_4x5.positions(0.0)
    graph = links.visibility_graph(pos)
    assert graph  # a 20-sat shell at 1400 km always has some LOS pairs
    for (i, j), link in graph.items():
        assert i < j
        assert link.delay_s == pytest.approx(link.range_km / links.C_KM_S)
        assert link.rate_bps > 0
        # the reported range matches the geometry
        assert link.range_km == pytest.approx(
            float(np.linalg.norm(pos[i] - pos[j]))
        )


def test_ground_station_links_use_elevation_mask():
    """Surface terminals fail the limb-occlusion chord test by construction;
    they must get links via the elevation mask instead."""
    gs = GroundStation(lat_deg=0.0, lon_deg=0.0)
    plan = build_contact_plan(
        GEOM_4x5,
        duration_s=GEOM_4x5.period_s,
        step_s=GEOM_4x5.period_s / 24,
        ground_stations=[gs],
    )
    assert plan.n_nodes == 21
    gs_edges = [
        (t, e) for t in range(len(plan.times))
        for e in plan.graphs[t] if 20 in e
    ]
    assert gs_edges  # a 20-sat shell passes over the equator every period
    # directly-overhead geometry is trivially feasible, horizon-hugging isn't
    up = np.array([R_EARTH_KM + 1400.0, 0.0, 0.0])
    g = np.array([R_EARTH_KM, 0.0, 0.0])
    assert links.elevation_visible(g, up, 10.0)
    assert not links.elevation_visible(g, np.array([0.0, R_EARTH_KM + 1400.0, 0.0]), 10.0)


def test_max_range_gate():
    pos = GEOM_4x5.positions(0.0)
    gated = links.visibility_graph(pos, max_range_km=3000.0)
    assert all(l.range_km <= 3000.0 for l in gated.values())
    assert len(gated) < len(links.visibility_graph(pos))


# ----------------------------------------------------------- contact plan
def test_4x5_contact_plan_generates_valid_tdm_schedule():
    """Acceptance: pure geometry -> TDMSchedule, every slot a valid
    exchange relation honoring a per-node antenna budget."""
    plan = plan_4x5()
    rels = plan.relations()
    assert len(rels) == 12
    assert any(len(r) > 0 for r in rels)
    for r in rels:
        assert r.is_valid_exchange()

    sched = plan.schedule(antennas=3)
    assert isinstance(sched.tdm, TDMSchedule)
    assert len(sched) > 0
    assert sched.max_antennas() <= 3
    for slot in sched.slots:
        assert slot.relation.is_valid_exchange()
        assert slot.duration_s > 0
        assert slot.min_rate_bps > 0
    # slot union per time step == that step's visibility relation
    for t in range(len(rels)):
        merged = Relation.empty(range(plan.n_nodes))
        for slot in sched.slots:
            if slot.t_index == t:
                merged = merged | slot.relation
        assert merged.pairs == rels[t].pairs


def test_heterogeneous_antenna_budget_respected():
    plan = plan_4x5(steps=4)
    antennas = {v: (3 if v % 3 == 0 else 1) for v in range(20)}
    sched = plan.schedule(antennas=antennas)
    for slot in sched.slots:
        for v in slot.relation.participants():
            assert slot.relation.degree(v) <= antennas[v]


def test_iter_slots_streams_the_materialized_schedule():
    plan = plan_4x5(steps=6)
    streamed = list(plan.iter_slots(antennas=2, payload_bytes=1 << 16))
    sched = plan.schedule(antennas=2, payload_bytes=1 << 16)
    assert [s.relation.pairs for s in streamed] == [
        s.relation.pairs for s in sched.slots
    ]
    # wall clock is globally monotone: no two slots overlap, even across
    # time steps (oversized payloads push later steps back, never concurrent)
    end = 0.0
    for s in streamed:
        assert s.start_s >= end - 1e-9
        end = s.start_s + s.duration_s


def test_oversized_payload_never_overlaps_slots():
    plan = plan_4x5(steps=6)
    slots = list(plan.iter_slots(antennas=1, payload_bytes=1 << 34))
    assert slots
    end = 0.0
    for s in slots:
        assert s.start_s >= end - 1e-9
        end = s.start_s + s.duration_s


def test_restrict_alive_drops_occluded_satellites():
    plan = plan_4x5(steps=6)
    alive = set(range(20)) - {0, 7}
    sched = plan.schedule(antennas=3, alive=alive)
    for slot in sched.slots:
        assert {0, 7}.isdisjoint(slot.relation.participants())


def test_contact_windows_consistent_with_graphs():
    plan = plan_4x5()
    for w in plan.windows():
        assert w.t_end_s > w.t_start_s
        assert 0 < w.min_rate_bps <= w.mean_rate_bps
        # the edge is feasible at the window's first step
        t0 = int(round(w.t_start_s / plan.step_s))
        assert (w.i, w.j) in plan.graphs[t0]


def test_plus_grid_candidates_shape():
    cand = contact_plan.plus_grid_candidates(GEOM_4x5)
    # ring per plane (5 edges x 4 planes) + cross-plane rings (5 x 4)
    assert len(cand) == 40
    assert all(i < j for i, j in cand)
    no_cross = contact_plan.plus_grid_candidates(GEOM_4x5, cross_plane=False)
    assert len(no_cross) == 20


def test_contact_schedule_alignment_validated():
    with pytest.raises(ValueError, match="misaligned"):
        contact_plan.ContactSchedule(
            tdm=TDMSchedule((Relation.from_edges([(0, 1)]),)), slots=()
        )


# -------------------------------------------------- ground-node edge cases
def test_contact_schedule_restrict_with_ground_nodes():
    """Restricting a materialized schedule must handle ground nodes like
    any other node: dropping a ground station removes every up/downlink
    edge; keeping it preserves its slots with rebuilt link metadata."""
    gs = [GroundStation(0.0, 0.0), GroundStation(30.0, 90.0)]
    plan = build_contact_plan(
        GEOM_4x5,
        duration_s=GEOM_4x5.period_s,
        step_s=GEOM_4x5.period_s / 12,
        ground_stations=gs,
    )
    assert plan.n_nodes == 22
    sched = plan.schedule(antennas=2)
    gs_nodes = {20, 21}
    has_ground = any(
        gs_nodes & s.relation.participants() for s in sched.slots
    )
    assert has_ground  # equatorial + mid-lat stations see a 4x5 shell

    # drop one ground station: no slot may reference it afterwards, and the
    # surviving metadata must only hold surviving edges
    kept = sched.restrict(set(range(21)), antennas=2)
    for slot in kept.slots:
        assert 21 not in slot.relation.participants()
        assert all(21 not in e for e in slot.links)
        assert slot.min_rate_bps == min(
            l.rate_bps for l in slot.links.values()
        )
    # the other station's contacts survive the restriction
    assert any(20 in s.relation.participants() for s in kept.slots)

    # drop ALL ground stations: pure ISL schedule remains, still valid
    isl_only = kept.restrict(set(range(20)), antennas=2)
    for slot in isl_only.slots:
        assert gs_nodes.isdisjoint(slot.relation.participants())
    assert len(isl_only) > 0


def test_zero_elevation_horizon_mask():
    """min_elevation_deg=0 admits a satellite exactly on the horizon
    (sin(el) >= 0) and strictly widens coverage vs the default mask."""
    g = np.array([R_EARTH_KM, 0.0, 0.0])
    horizon_sat = np.array([R_EARTH_KM, 1400.0, 0.0])  # elevation == 0
    assert links.elevation_visible(g, horizon_sat, 0.0)
    assert not links.elevation_visible(g, horizon_sat, 10.0)
    below = np.array([R_EARTH_KM - 10.0, 1400.0, 0.0])  # below horizon
    assert not links.elevation_visible(g, below, 0.0)

    gs = [GroundStation(0.0, 0.0)]
    kw = dict(
        duration_s=GEOM_4x5.period_s,
        step_s=GEOM_4x5.period_s / 24,
        ground_stations=gs,
    )
    masked = build_contact_plan(GEOM_4x5, budget=LinkBudget(), **kw)
    open_h = build_contact_plan(
        GEOM_4x5, budget=LinkBudget(min_elevation_deg=0.0), **kw
    )
    count = lambda p: sum(
        1 for t in range(len(p.times)) for e in p.graphs[t] if 20 in e
    )
    assert count(open_h) >= count(masked) > 0


def test_router_reports_unreachable_sink_on_real_geometry():
    """A polar ground station never sees an equatorial shell: the contact
    plan has no uplink edges and the router must report every satellite
    unreachable (and return immediately) rather than hang."""
    from repro.groundseg import routing

    eq = WalkerDelta(total=6, planes=2, inclination_deg=0.0,
                     altitude_km=550.0)
    polar_gs = [GroundStation(89.0, 0.0, name="pole")]
    plan = build_contact_plan(
        eq,
        duration_s=eq.period_s,
        step_s=eq.period_s / 24,
        ground_stations=polar_gs,
    )
    assert plan.n_nodes == 7
    assert not any(6 in e for t in range(len(plan.times)) for e in plan.graphs[t])
    sched = plan.schedule(antennas=2)
    table = routing.earliest_delivery_routes(list(sched.tdm), 7, sinks=[6])
    assert table.unreachable() == list(range(6))
    assert table.max_delivery_slot() is None
    up = routing.build_relay_program(list(sched.tdm), 7, [6], table=table)
    assert up.n_hops == 0 and up.delivered_count() == 0
    assert up.unreachable == frozenset(range(6))


# ------------------------------------------------------------- cost model
def test_cost_get1meas_never_faster_than_getmeas():
    plan = plan_4x5()
    payload = 1 << 20
    multi = cost.plan_cost(plan, payload, mode="getmeas")
    single = cost.plan_cost(plan, payload, mode="get1meas")
    assert single.time_s >= multi.time_s > 0
    assert single.bytes_on_isl == multi.bytes_on_isl > 0


def test_cost_empty_relation_is_free():
    sc = cost.slot_cost(Relation.empty(range(4)), {}, 1 << 20)
    assert sc.time_s == 0.0 and sc.bytes_on_isl == 0 and sc.n_matchings == 0
    with pytest.raises(ValueError):
        cost.slot_cost(Relation.empty(), {}, 1, mode="warp")


def test_slot_cost_matches_hand_computation():
    rel = Relation.from_edges([(0, 1), (2, 3)])
    lk = {
        (0, 1): Link(range_km=1000.0, delay_s=0.01, rate_bps=1e6),
        (2, 3): Link(range_km=2000.0, delay_s=0.02, rate_bps=2e6),
    }
    payload = 1000  # bytes -> 8000 bits
    sc = cost.slot_cost(rel, lk, payload, mode="getmeas")
    # one matching holds both edges; slowest transfer is 8000/2e6 + 0.02 s
    # (the faster link's propagation delay dominates its serialization win)
    assert sc.n_matchings == 1
    assert sc.time_s == pytest.approx(max(8000 / 1e6 + 0.01, 8000 / 2e6 + 0.02))
    assert sc.bytes_on_isl == payload * 4  # both directions of both edges


def test_schedule_cost_consistent_with_slot_sizing():
    """The analytic cost of a materialized schedule must agree with the
    bandwidth-aware slot durations it was sized with (getmeas mode)."""
    plan = plan_4x5(steps=6)
    sched = plan.schedule(antennas=2, payload_bytes=1 << 18)
    est = cost.schedule_cost(sched, 1 << 18, mode="getmeas")
    assert est.time_s == pytest.approx(sched.busy_s)
    assert sched.span_s >= sched.busy_s > 0


def test_fl_round_cost_adds_compute():
    plan = plan_4x5(steps=4)
    base = cost.fl_round_cost(plan, 1 << 16, compute_s_per_step=0.0)
    busy = cost.fl_round_cost(plan, 1 << 16, compute_s_per_step=1.0)
    assert busy.time_s == pytest.approx(base.time_s + 4.0)


# ------------------------------------------------- legacy shim (schedule.py)
def test_walker_shim_and_legacy_model_removed():
    """ISSUE 10: the duty-cycle toy and its contact_plan backing are gone —
    hard ImportError with a migration hint, no silent fallback."""
    import repro.core.schedule as schedule_mod

    with pytest.raises(ImportError, match="scenario"):
        schedule_mod.WalkerConstellation
    assert not hasattr(contact_plan, "legacy_duty_cycle_relation")
