"""Multi-device worker for the flight-recorder telemetry: the acceptance
gates on 8 forced host devices — telemetry-disabled runs issue ZERO extra
host syncs and stay bit-identical to traced+reconciled runs, the default-on
collective counters equal the static oracles replayed window by window,
reconcile mode AOT-verifies every compiled round, and the exported Chrome
trace is valid. Launched as a subprocess by test_telemetry.py (device count
locks at first jax init).

Exit code 0 + final line "ALL-OK" on success.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import sys
import tempfile

import jax
import numpy as np

from repro import telemetry
from repro.configs import archs
from repro.constellation import contact_plan, orbits
from repro.data import pipeline
from repro.groundseg import aggregation, routing
from repro.launch import fl_train
from repro.models.config import ShapeConfig
from repro.optim import adamw

N_SATS, N_GS = 6, 2
N = N_SATS + N_GS
SINKS = frozenset(range(N_SATS, N))
PAYLOAD = 1 << 20

GS_CFG = fl_train.GroundSegConfig(
    mode="centralized", pipeline_depth=2, max_staleness_windows=2
)


def check(name, cond):
    if not cond:
        print(f"FAIL: {name}")
        sys.exit(1)
    print(f"ok: {name}")


def groundseg_plan(steps=10):
    geom = orbits.WalkerDelta(
        total=N_SATS, planes=2, altitude_km=8062.0, inclination_deg=60.0
    )
    gs = [
        orbits.GroundStation(0.0, 0.0, name="equator"),
        orbits.GroundStation(45.0, 120.0, name="midlat"),
    ]
    return contact_plan.build_contact_plan(
        geom,
        duration_s=geom.period_s,
        step_s=geom.period_s / steps,
        ground_stations=gs,
        max_range_km=16_000.0,
    )


def tdm_plan(steps=6):
    geom = orbits.WalkerDelta(
        total=N, planes=2, altitude_km=8062.0, inclination_deg=60.0
    )
    return contact_plan.build_contact_plan(
        geom,
        duration_s=geom.period_s,
        step_s=geom.period_s / steps,
        max_range_km=16_000.0,
    )


def _fl_setup():
    cfg = archs.smoke_cfg(archs.get("mamba2-780m"))
    opt_cfg = adamw.OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=100)
    fl_cfg = fl_train.FLConfig(mode="tdm", local_steps=1)
    shape = ShapeConfig("fl", "train", 32, 2)
    mesh = jax.make_mesh((N,), ("data",))

    def batch_fn(rnd):
        per_node = []
        for sat in range(N):
            b = pipeline.host_batch(cfg, shape, step=rnd, seed=100 + sat)
            per_node.append({k: v[None] for k, v in b.items()})
        return {k: np.stack([pn[k] for pn in per_node]) for k in per_node[0]}

    return cfg, opt_cfg, fl_cfg, mesh, batch_fn


def _run_groundseg(plan, rounds, **kw):
    cfg, opt_cfg, fl_cfg, mesh, batch_fn = _fl_setup()
    state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N)
    return fl_train.run_groundseg_fl(
        cfg, opt_cfg, mesh, N, fl_cfg, GS_CFG, plan, state, batch_fn,
        sinks=SINKS, rounds=rounds, antennas=2, payload_bytes=PAYLOAD, **kw
    )


def _run_tdm(plan, rounds, **kw):
    cfg, opt_cfg, fl_cfg, mesh, batch_fn = _fl_setup()
    state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N)
    return fl_train.run_constellation_fl(
        cfg, opt_cfg, mesh, N, fl_cfg, plan, state, batch_fn,
        rounds=rounds, **kw
    )


def _n_buckets(state):
    return len({l.dtype.name for l in jax.tree.leaves(state["params"])})


# ---------------------------------------------------------------------------
# 1. telemetry disabled: counters still collected, but ZERO extra host syncs
#    (every block_until_ready is tracing-gated) and nothing traced
# ---------------------------------------------------------------------------
def test_disabled_zero_host_syncs():
    gp, tp = groundseg_plan(), tdm_plan()
    calls = []
    orig = jax.block_until_ready

    def counting(x):
        calls.append(1)
        return orig(x)

    with telemetry.record_scope() as rec:
        jax.block_until_ready = counting
        try:
            gs_state, _ = _run_groundseg(gp, rounds=3, log_every=0)
            tdm_state, _ = _run_tdm(tp, rounds=2, log_every=0)
        finally:
            jax.block_until_ready = orig
        jax.block_until_ready((gs_state, tdm_state))
        c = dict(rec.counters)
        no_trace = rec.spans == [] and rec.events == []
    check(
        "telemetry off: zero block_until_ready host syncs across "
        "3 groundseg + 2 tdm rounds",
        not calls,
    )
    check("telemetry off: no spans or events recorded", no_trace)
    check(
        "default-on counters still collected "
        f"(groundseg.rounds={c.get('groundseg.rounds')}, "
        f"fl.rounds={c.get('fl.rounds')})",
        c.get("groundseg.rounds") == 3
        and c.get("fl.rounds") == 2
        and c.get("groundseg.collectives.collective-permute", 0) > 0
        and c.get("fl.collectives.collective-permute", 0) > 0,
    )


# ---------------------------------------------------------------------------
# 2. observability must not perturb training: params after a run with
#    telemetry off == params with tracing + reconcile on, bit for bit
# ---------------------------------------------------------------------------
def test_bit_identical_when_disabled():
    gp, tp = groundseg_plan(), tdm_plan()
    runs = {}
    for label, flags in (
        ("off", {}),
        ("on", dict(tracing=True, reconcile=True)),
    ):
        with telemetry.record_scope(**flags):
            gs_state, _ = _run_groundseg(gp, rounds=3)
            tdm_state, _ = _run_tdm(tp, rounds=2)
        runs[label] = (
            jax.tree.map(np.asarray, gs_state["params"]),
            jax.tree.map(np.asarray, tdm_state["params"]),
        )
    for i, which in enumerate(("groundseg", "tdm")):
        a = jax.tree.leaves(runs["off"][i])
        b = jax.tree.leaves(runs["on"][i])
        assert len(a) == len(b)
        for x, y in zip(a, b):
            assert np.array_equal(x, y), which
    check(
        "fused tdm + pipelined groundseg params bit-identical with "
        "telemetry off vs tracing+reconcile on",
        True,
    )


# ---------------------------------------------------------------------------
# 3. groundseg: recorded per-window collective counters == the static
#    oracle replayed through a twin router; reconcile verifies every
#    compiled window; payload lifecycle + trace export
# ---------------------------------------------------------------------------
def test_groundseg_counters_match_window_oracle_and_trace():
    plan = groundseg_plan()
    rounds = 3
    with telemetry.record_scope(tracing=True, reconcile=True) as rec:
        state, logs = _run_groundseg(plan, rounds=rounds)
        c = dict(rec.counters)

    # replay the deterministic router to rebuild each window's oracle
    base_rels = list(plan.schedule(antennas=2, payload_bytes=PAYLOAD).tdm)
    router = routing.MultiWindowRouter(
        N, SINKS,
        max_staleness_windows=GS_CFG.max_staleness_windows,
        pipeline_depth=GS_CFG.pipeline_depth,
    )
    want = {}
    programs = []
    for _ in range(rounds):
        wp = router.plan_window(base_rels, alive=set(range(N)))
        programs.append(wp)
        for kind, cnt in aggregation.expected_window_collectives(
            wp, _n_buckets(state), compression=GS_CFG.compression, pool=True
        ).items():
            want[kind] = want.get(kind, 0) + cnt
    for kind, cnt in want.items():
        got = c.get(f"groundseg.collectives.{kind}", 0)
        assert got == cnt, (kind, got, cnt)
    check(
        "recorded collective counters == expected_window_collectives "
        f"summed over {rounds} windows: {want}",
        True,
    )

    # route-provenance audit of the EXECUTED run: replay every payload's
    # hop trail through the twin programs, checked against the slot
    # relations, the decay**age staleness weights, and the lifecycle
    # events the traced run actually emitted
    verdict = telemetry.audit_window_programs(
        programs,
        decay=GS_CFG.staleness_decay,
        slots=base_rels,
        weights=[
            aggregation.staleness_sink_weights(
                wp.uplink, wp.delivered_ages, GS_CFG.staleness_decay
            )
            for wp in programs
        ],
        events=rec.events,
    )
    assert verdict.ok, [str(v) for v in verdict.violations]
    assert verdict.n_windows == rounds and verdict.events_checked > 0
    assert verdict.n_payloads == sum(len(wp.ages) for wp in programs)
    check(
        f"route-provenance audit green over the executed run: "
        f"{verdict.n_payloads} payloads / {verdict.n_hops} hops / "
        f"{verdict.events_checked} lifecycle events, 0 violations",
        True,
    )

    misses = c.get("groundseg.window_cache.misses", 0)
    hits = c.get("groundseg.window_cache.hits", 0)
    assert misses + hits == rounds and misses >= 1, (misses, hits)
    assert c.get("reconcile.checked", 0) == misses
    assert c.get("reconcile.mismatched", 0) == 0
    check(
        f"reconcile AOT-verified all {misses} compiled windows "
        "(0 mismatches)",
        True,
    )

    names = [s.name for s in rec.spans]
    assert names.count("groundseg.window") == rounds
    assert names.count("groundseg.plan_window") == rounds
    assert names.count("groundseg.compile") == misses
    retraces = [e for e in rec.events if e.name == "retrace"]
    assert len(retraces) == misses
    delivered = [e for e in rec.events if e.name == "payload.delivered"]
    assert len(delivered) == sum(l.delivered for l in logs)
    queued = [e for e in rec.events if e.name == "payload.queued"]
    assert len(queued) == c.get("groundseg.payloads.queued")
    check(
        f"payload lifecycle events: {len(queued)} queued, "
        f"{len(delivered)} delivered instants match the round logs",
        True,
    )

    with tempfile.TemporaryDirectory() as d:
        out = telemetry.write_trace(os.path.join(d, "trace.json"), rec)
        doc = json.loads(out.read_text())
    evs = doc["traceEvents"]
    assert evs and evs[0]["ph"] == "M"
    assert all(ev["ph"] in ("M", "X", "i", "C") for ev in evs)
    ts = [ev["ts"] for ev in evs]
    assert ts == sorted(ts)
    x_names = {ev["name"] for ev in evs if ev["ph"] == "X"}
    assert {"groundseg.window", "groundseg.compile"} <= x_names
    assert doc["otherData"]["counters"] == c
    check(
        f"exported Chrome trace valid ({len(evs)} events, sorted, "
        "window spans present)",
        True,
    )


# ---------------------------------------------------------------------------
# 4. tdm: per-round counters == the static edge-coloring oracle over the
#    plan's relations; the round cache reconciles on every miss
# ---------------------------------------------------------------------------
def test_tdm_counters_match_static_oracle():
    plan = tdm_plan()
    rounds = 4
    with telemetry.record_scope(tracing=True, reconcile=True) as rec:
        state, _ = _run_tdm(plan, rounds=rounds)
        c = dict(rec.counters)

    rels = plan.relations()
    reps = -(-rounds // max(len(rels), 1))
    rels = (rels * reps)[:rounds]
    want = 0
    topologies = set()
    for rel in rels:
        topologies.add(tuple(sorted(rel.pairs)))
        want += telemetry.expected_tdm_collectives(rel, _n_buckets(state))[
            "collective-permute"
        ]
    assert c.get("fl.rounds") == rounds
    got = c.get("fl.collectives.collective-permute", 0)
    assert got == want and want > 0, (got, want)
    misses = c.get("fl.round_cache.misses", 0)
    assert misses == len(topologies)
    assert misses + c.get("fl.round_cache.hits", 0) == rounds
    assert c.get("reconcile.checked", 0) == misses
    assert c.get("reconcile.mismatched", 0) == 0
    names = [s.name for s in rec.spans]
    assert names.count("fl.round") == rounds
    assert names.count("fl.compile") == misses
    check(
        f"tdm rounds: {got} recorded permutes == edge-coloring oracle over "
        f"{rounds} rounds ({misses} topologies compiled, all reconciled)",
        True,
    )


if __name__ == "__main__":
    test_disabled_zero_host_syncs()
    test_bit_identical_when_disabled()
    test_groundseg_counters_match_window_oracle_and_trace()
    test_tdm_counters_match_static_oracle()
    print("ALL-OK")
