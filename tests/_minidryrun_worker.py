"""Mini dry-run worker: the full lower->compile->roofline pipeline on a 2x2
mesh with reduced configs — proves the dryrun machinery (shardings, donation,
collective parsing, staged costs) for EVERY family without 512 devices.

Run by test_dryrun_mini.py in a subprocess. Prints ALL-OK on success.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
)



from repro.configs import archs
from repro.launch.dryrun import analyze, lower_cell
from repro.launch.mesh import make_mesh
from repro.models.config import ShapeConfig

CELLS = [
    ("mamba2-780m", "train"),
    ("gemma2-9b", "train"),
    ("qwen3-moe-30b-a3b", "train"),
    ("jamba-1.5-large-398b", "decode"),
    ("whisper-base", "train"),
    ("qwen2-vl-72b", "decode"),
    ("kimi-k2-1t-a32b", "train"),     # int8 opt moments path
    ("granite-20b", "prefill"),
]


def main():
    mesh = make_mesh((2, 2), ("data", "model"))
    for name, kind in CELLS:
        cfg = archs.smoke_cfg(archs.get(name))
        # make dims friendly to the 2x2 mesh and block sizes; production
        # pp_stages (16) rescales to the 2-wide data axis
        cfg = cfg.replace(
            micro_steps=2 if kind == "train" else 1,
            pp_stages=2 if cfg.pp_stages else 0,
            pp_micro=4 if cfg.pp_stages else 0,
        )
        shape = ShapeConfig("mini", kind, 32, 4)
        lowered, staged = lower_cell(cfg, shape, mesh)
        compiled = lowered.compile()
        data = analyze(compiled, staged, cfg, shape, mesh, 0.0, 0.0)
        rf = data["roofline"]
        assert staged.flops > 0, name
        assert rf["bound_step_seconds"] > 0, name
        assert data["collectives"]["total_count"] >= 0
        # executability: run the compiled step on zero inputs
        print(f"ok: {name} {kind} lower+compile+analyze "
              f"(flops={staged.flops:.2e}, coll={data['collectives']['total_bytes']:.2e}B)")
    print("ALL-OK")


if __name__ == "__main__":
    main()
