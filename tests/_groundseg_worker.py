"""Multi-device worker for the ground-segment subsystem: the acceptance
scenario end to end on 8 forced host devices — hierarchical FL over a
Walker constellation with 2 ground sinks (consensus decreasing), router
delivery of every reachable satellite inside the plan horizon, HLO-level
verification of the fused relay collective counts, and the int8 relay
path. Launched as a subprocess by test_groundseg.py (device count locks at
first jax init).

Exit code 0 + final line "ALL-OK" on success.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import archs
from repro.constellation import contact_plan, cost, orbits
from repro.core.relation import Relation
from repro.data import pipeline
from repro.groundseg import aggregation, routing
from repro.launch import fl_train
from repro.launch.hlo_stats import collective_stats
from repro.models.config import ShapeConfig
from repro.optim import adamw

N_SATS, N_GS = 6, 2
N = N_SATS + N_GS
mesh = Mesh(np.array(jax.devices()[:N]), ("node",))


def check(name, cond):
    if not cond:
        print(f"FAIL: {name}")
        sys.exit(1)
    print(f"ok: {name}")


def walker_plan(steps=10):
    geom = orbits.WalkerDelta(
        total=N_SATS, planes=2, altitude_km=8062.0, inclination_deg=60.0
    )
    gs = [
        orbits.GroundStation(0.0, 0.0, name="equator"),
        orbits.GroundStation(45.0, 120.0, name="midlat"),
    ]
    return geom, contact_plan.build_contact_plan(
        geom,
        duration_s=geom.period_s,
        step_s=geom.period_s / steps,
        ground_stations=gs,
        max_range_km=16_000.0,
    )


SINKS = frozenset(range(N_SATS, N))


# ---------------------------------------------------------------------------
# 1. router delivers every reachable satellite within the plan horizon
# ---------------------------------------------------------------------------
def test_router_full_delivery():
    _, plan = walker_plan()
    sched = plan.schedule(antennas=2)
    rels = list(sched.tdm)
    table = routing.earliest_delivery_routes(rels, N, SINKS)
    up = routing.build_relay_program(rels, N, SINKS, table=table)
    reachable = table.reachable()
    delivered = set().union(*up.delivered.values()) if up.delivered else set()
    assert delivered == set(reachable), (delivered, reachable)
    horizon = len(rels) - 1
    for s in reachable:
        assert 0 <= table.routes[s].delivery_slot <= horizon
    # this MEO geometry covers everything — the acceptance scenario needs
    # every satellite's update at a sink
    assert len(reachable) == N_SATS, table.unreachable()
    check(
        f"router delivered {len(delivered)}/{N_SATS} satellites within "
        f"{len(rels)}-slot horizon",
        True,
    )


# ---------------------------------------------------------------------------
# 2. HLO: a compiled groundseg round issues exactly the statically-predicted
#    fused relay collectives (one permute per buffer per batch, 2x int8,
#    one masked psum per buffer when pooling)
# ---------------------------------------------------------------------------
def test_hlo_relay_collective_counts():
    _, plan = walker_plan()
    sched = plan.schedule(antennas=2)
    rels = list(sched.tdm)
    up = routing.build_relay_program(rels, N, SINKS)
    down = routing.build_broadcast_program(rels, N, SINKS)

    SHAPES = [(3, 5), (17,), (128,), (33,), (2, 2), (64, 3)]
    rng = np.random.default_rng(0)
    tree = {
        f"w{i}": jnp.asarray(rng.normal(size=(N,) + s).astype(np.float32))
        for i, s in enumerate(SHAPES)
    }
    for compression in ("none", "int8"):
        for pool in (True, False):
            def body(t):
                t = jax.tree.map(lambda x: x[0], t)
                out = aggregation.groundseg_round(
                    t, up, down, "node", pool=pool,
                    compression=compression, quant_impl="ref",
                )
                return jax.tree.map(lambda x: x[None], out)

            fn = jax.jit(
                shard_map(
                    body, mesh=mesh, in_specs=(P("node"),),
                    out_specs=P("node"), check_rep=False,
                )
            )
            stats = collective_stats(fn.lower(tree).compile().as_text())
            want = aggregation.expected_collectives(
                up, down, 1, compression=compression, pool=pool
            )
            for kind, count in want.items():
                got = stats.count_by_kind.get(kind, 0)
                assert got == count, (compression, pool, kind, got, count)
    check("HLO: relay/broadcast collectives == static program counts", True)


# ---------------------------------------------------------------------------
# 3. aggregation numerics: pooled round -> covered nodes hold the exact
#    FedAvg mean; uncovered keep their params bit-for-bit
# ---------------------------------------------------------------------------
def test_fedavg_numerics():
    slots = [
        Relation.from_edges([(0, 1), (2, 6), (4, 5)], nodes=range(N)),
        Relation.from_edges([(1, 6), (5, 7), (3, 4)], nodes=range(N)),
        Relation.from_edges([(4, 7), (3, 6)], nodes=range(N)),
    ]
    up = routing.build_relay_program(slots, N, SINKS)
    down = routing.build_broadcast_program(slots, N, SINKS)
    assert set().union(*up.delivered.values()) == set(range(N_SATS))
    rng = np.random.default_rng(1)
    tree = {"w": jnp.asarray(rng.normal(size=(N, 37)).astype(np.float32))}

    def body(t):
        t = jax.tree.map(lambda x: x[0], t)
        out = aggregation.groundseg_round(t, up, down, "node", pool=True)
        return jax.tree.map(lambda x: x[None], out)

    fn = jax.jit(
        shard_map(body, mesh=mesh, in_specs=(P("node"),),
                  out_specs=P("node"), check_rep=False)
    )
    x = np.asarray(tree["w"])
    y = np.asarray(fn(tree)["w"])
    want = x.mean(axis=0)  # 6 delivered sats + 2 sink models = all 8 rows
    cov = sorted(down.covered)
    uncov = [v for v in range(N) if v not in down.covered]
    assert np.allclose(y[cov], want, atol=1e-5)
    assert all(np.array_equal(y[v], x[v]) for v in uncov)
    # int8 relay tracks the exact mean within quantization tolerance
    def body8(t):
        t = jax.tree.map(lambda x: x[0], t)
        out = aggregation.groundseg_round(
            t, up, down, "node", pool=True, compression="int8",
            quant_impl="ref",
        )
        return jax.tree.map(lambda x: x[None], out)

    f8 = jax.jit(
        shard_map(body8, mesh=mesh, in_specs=(P("node"),),
                  out_specs=P("node"), check_rep=False)
    )
    y8 = np.asarray(f8(tree)["w"])
    err = np.linalg.norm(y8[cov] - y[cov]) / max(np.linalg.norm(y[cov]), 1e-9)
    assert err < 0.02, err
    check(f"FedAvg numerics exact; int8 relay rel-err {err:.4f} < 2%", True)


# ---------------------------------------------------------------------------
# 3b. quantize-once relay: the SAME payloads delivered over a 3-hop chain
#     and over direct 1-hop slots produce BIT-IDENTICAL sink aggregates —
#     quantization error is paid once per route, independent of hop count —
#     and the aggregate equals a single-quantization numpy replay
# ---------------------------------------------------------------------------
def test_int8_relay_hop_count_independent():
    from repro.kernels.tdm_compress import ref as q_ref

    # B: 0 -> 1 -> 2 -> sink6 (payloads merge along the chain, 3 hops for
    # sat 0); A: the same three payloads ride direct 1-hop slots
    slots_chain = [
        Relation.from_edges([(0, 1)], nodes=range(N)),
        Relation.from_edges([(1, 2)], nodes=range(N)),
        Relation.from_edges([(2, 6)], nodes=range(N)),
    ]
    slots_direct = [
        Relation.from_edges([(0, 6)], nodes=range(N)),
        Relation.from_edges([(1, 6)], nodes=range(N)),
        Relation.from_edges([(2, 6)], nodes=range(N)),
    ]
    rng = np.random.default_rng(11)
    tree = {"w": jnp.asarray(rng.normal(size=(N, 96)).astype(np.float32))}
    outs = {}
    for name, slots in (("chain", slots_chain), ("direct", slots_direct)):
        up = routing.build_relay_program(slots, N, SINKS)
        down = routing.build_broadcast_program(slots, N, SINKS)
        assert set().union(*up.delivered.values()) == {0, 1, 2}

        def body(t, up=up, down=down):
            t = jax.tree.map(lambda x: x[0], t)
            out = aggregation.groundseg_round(
                t, up, down, "node", pool=True, compression="int8",
                quant_impl="ref",
            )
            return jax.tree.map(lambda x: x[None], out)

        fn = jax.jit(
            shard_map(body, mesh=mesh, in_specs=(P("node"),),
                      out_specs=P("node"), check_rep=False)
        )
        outs[name] = np.asarray(fn(tree)["w"])
    # hop-count independence: the sinks' pooled global after 3-hop delivery
    # == after 1-hop delivery, bit for bit (the downlink floods differ in
    # reach between the two schedules, so only sink lanes are comparable)
    assert np.array_equal(outs["chain"][[6, 7]], outs["direct"][[6, 7]])
    # single-encode replay: shared scales are the pmax of every node's
    # blockwise scales; each delivered payload is quantized exactly once
    x = np.asarray(tree["w"])
    scales = np.max(
        [np.asarray(q_ref.blockwise_scales_ref(jnp.asarray(x[v]))) for v in
         range(N)],
        axis=0,
    )
    q = np.clip(np.rint(x / scales), -127, 127)
    want = (x[6] + (q[0] + q[1] + q[2]) * scales + x[7]) / 5.0
    got = outs["chain"][6]
    np.testing.assert_allclose(got, want, atol=1e-5)
    err = np.linalg.norm(got - (x[[0, 1, 2, 6, 7]].sum(0) / 5.0)) / max(
        np.linalg.norm(got), 1e-9
    )
    assert err < 0.02, err
    check(
        f"int8 relay: 3-hop == 1-hop bit-identical (single quantize/dequant "
        f"pair per route; vs exact FedAvg rel-err {err:.4f} < 2%)",
        True,
    )


# ---------------------------------------------------------------------------
# 4. acceptance: hierarchical FL over the Walker constellation with 2 ground
#    sinks — consensus distance decreases across rounds, centralized ends in
#    exact consensus on covered nodes, and the cost oracle emits sane
#    centralized-vs-decentralized numbers for the same plan
# ---------------------------------------------------------------------------
def _fl_setup():
    cfg = archs.smoke_cfg(archs.get("mamba2-780m"))
    opt_cfg = adamw.OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=100)
    fl_cfg = fl_train.FLConfig(mode="tdm", local_steps=1)
    shape = ShapeConfig("fl", "train", 32, 2)
    fl_mesh = jax.make_mesh((N,), ("data",))

    def batch_fn(rnd):
        per_node = []
        for sat in range(N):
            b = pipeline.host_batch(cfg, shape, step=rnd, seed=100 + sat)
            per_node.append({k: v[None] for k, v in b.items()})
        return {k: np.stack([pn[k] for pn in per_node]) for k in per_node[0]}

    return cfg, opt_cfg, fl_cfg, fl_mesh, batch_fn


def test_hierarchical_fl_converges():
    geom, plan = walker_plan()
    cfg, opt_cfg, fl_cfg, fl_mesh, batch_fn = _fl_setup()
    gs_cfg = fl_train.GroundSegConfig(mode="hierarchical", sink_sync_every=2)
    state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N)
    state, logs = fl_train.run_groundseg_fl(
        cfg, opt_cfg, fl_mesh, N, fl_cfg, gs_cfg, plan, state, batch_fn,
        sinks=SINKS, rounds=4, antennas=2,
    )
    assert len(logs) == 4
    assert all(np.isfinite(l.loss) for l in logs)
    assert all(l.delivered == N_SATS for l in logs)
    assert all(l.unreachable == 0 for l in logs)
    # consensus decreases: local training spreads the nodes each round, the
    # sink round pulls them back — every pooled round must beat the
    # preceding unpooled round's spread, and the final pooled state must be
    # tighter than the first unpooled one
    spread = [l.consensus for l in logs if not l.pooled]
    tight = [l.consensus for l in logs if l.pooled]
    assert tight and spread
    assert max(tight) < min(spread), (tight, spread)
    check(
        f"hierarchical FL over Walker + 2 sinks: consensus pooled "
        f"{[f'{c:.1e}' for c in tight]} < unpooled "
        f"{[f'{c:.1e}' for c in spread]}",
        True,
    )


def test_centralized_exact_consensus_on_covered():
    geom, plan = walker_plan()
    cfg, opt_cfg, fl_cfg, fl_mesh, batch_fn = _fl_setup()
    gs_cfg = fl_train.GroundSegConfig(mode="centralized")
    state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N)
    state, logs = fl_train.run_groundseg_fl(
        cfg, opt_cfg, fl_mesh, N, fl_cfg, gs_cfg, plan, state, batch_fn,
        sinks=SINKS, rounds=2, antennas=2,
    )
    # every satellite was covered by the downlink each round -> after the
    # round they all hold the identical global model
    assert all(l.covered == N_SATS for l in logs)
    for leaf in jax.tree.leaves(state["params"]):
        arr = np.asarray(leaf)
        for v in range(1, N):
            assert np.array_equal(arr[0], arr[v])
    est = cost.groundseg_mode_costs(
        plan, SINKS, payload_bytes=1 << 20, antennas=2
    )
    assert est["centralized"].bytes_on_isl < est["gossip_getmeas"].bytes_on_isl
    check(
        "centralized FL: all covered satellites bit-identical to the "
        f"global model (relay traffic {est['centralized'].bytes_on_isl/1e6:.1f}"
        f" MB < gossip {est['gossip_getmeas'].bytes_on_isl/1e6:.1f} MB)",
        True,
    )


# ---------------------------------------------------------------------------
# 5. fault tolerance: a dead satellite drops out of routing (skip-slot) and
#    the survivors keep aggregating
# ---------------------------------------------------------------------------
def test_dead_satellite_skip_slot():
    geom, plan = walker_plan()
    cfg, opt_cfg, fl_cfg, fl_mesh, batch_fn = _fl_setup()
    gs_cfg = fl_train.GroundSegConfig(mode="centralized")
    state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N)
    alive = set(range(N))
    logs_seen = []

    def on_round(log):
        logs_seen.append(log)
        if log.round == 0:
            alive.discard(3)

    state, logs = fl_train.run_groundseg_fl(
        cfg, opt_cfg, fl_mesh, N, fl_cfg, gs_cfg, plan, state, batch_fn,
        sinks=SINKS, rounds=2, alive=alive, on_round=on_round, antennas=2,
    )
    assert logs[0].delivered == N_SATS and logs[0].alive == N_SATS
    assert logs[1].alive == N_SATS - 1
    assert logs[1].delivered == N_SATS - 1
    check("dead satellite dropped from routing; survivors aggregated", True)


# ---------------------------------------------------------------------------
# 6. pipelined multi-window engine: bit-identity at the trivial config,
#    HLO counts == the extended static oracle, delay-tolerant staleness
#    numerics, and the pipelined driver end to end
# ---------------------------------------------------------------------------
def _shard3(body):
    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P("node"),) * 3,
        out_specs=(P("node"),) * 3, check_rep=False,
    ))


def _window_fn(wp, pool=True, decay=0.5, compression="none"):
    def body(t, c, p):
        t = jax.tree.map(lambda x: x[0], t)
        c = jax.tree.map(lambda x: x[0], c)
        p = jax.tree.map(lambda x: x[0], p)
        out, nc, npend = aggregation.pipelined_window_round(
            t, c, p, wp, "node", pool=pool, staleness_decay=decay,
            compression=compression, quant_impl="ref",
        )
        return tuple(
            jax.tree.map(lambda x: x[None], z) for z in (out, nc, npend)
        )

    return _shard3(body)


def _zero_aux(tree):
    from repro.core import fused

    spec = fused.build_spec(jax.tree.map(lambda x: x[0], tree))
    return (aggregation.stacked_zero_buffers(spec, N),
            aggregation.stacked_zero_buffers(spec, N))


def test_pipelined_bit_identical_at_trivial_config():
    # depth 1, staleness 0: the pipelined engine must reproduce the PR 4
    # one-shot path BIT-FOR-BIT (same relay, same weights, same flood)
    slots = [
        Relation.from_edges([(0, 1), (2, 6), (4, 5)], nodes=range(N)),
        Relation.from_edges([(1, 6), (5, 7), (3, 4)], nodes=range(N)),
        Relation.from_edges([(4, 7), (3, 6)], nodes=range(N)),
    ]
    up = routing.build_relay_program(slots, N, SINKS)
    down = routing.build_broadcast_program(slots, N, SINKS)
    router = routing.MultiWindowRouter(N, SINKS)
    wp = router.plan_window(slots)
    rng = np.random.default_rng(3)
    tree = {"w": jnp.asarray(rng.normal(size=(N, 129)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(N, 7)).astype(np.float32))}
    for pool in (True, False):
        for compression in ("none", "int8"):
            def old_body(t, pool=pool, compression=compression):
                t = jax.tree.map(lambda x: x[0], t)
                out = aggregation.groundseg_round(
                    t, up, down, "node", pool=pool, compression=compression,
                    quant_impl="ref",
                )
                return jax.tree.map(lambda x: x[None], out)

            f_old = jax.jit(shard_map(
                old_body, mesh=mesh, in_specs=(P("node"),),
                out_specs=P("node"), check_rep=False,
            ))
            carry, pend = _zero_aux(tree)
            y_old = f_old(tree)
            y_new, nc, _ = _window_fn(wp, pool=pool, compression=compression)(
                tree, carry, pend
            )
            for k in tree:
                assert np.array_equal(
                    np.asarray(y_old[k]), np.asarray(y_new[k])
                ), (pool, compression, k)
            assert all(not np.asarray(v).any() for v in nc.values())
    check("pipelined engine bit-identical to the one-shot path at "
          "depth 1 / staleness 0 (pooled and regional, none and int8)", True)


def test_pipelined_hlo_collective_counts():
    _, plan = walker_plan()
    sched = plan.schedule(antennas=2)
    rels = list(sched.tdm)
    router = routing.MultiWindowRouter(
        N, SINKS, max_staleness_windows=2, pipeline_depth=2
    )
    wp0 = router.plan_window(rels)   # warm-up: no downlink
    wp1 = router.plan_window(rels)   # steady: lagged downlink
    rng = np.random.default_rng(0)
    tree = {
        f"w{i}": jnp.asarray(rng.normal(size=(N,) + s).astype(np.float32))
        for i, s in enumerate([(3, 5), (17,), (128,), (33,)])
    }
    carry, pend = _zero_aux(tree)
    for wp in (wp0, wp1):
        for compression in ("none", "int8"):
            for pool in (True, False):
                fn = _window_fn(wp, pool=pool, compression=compression)
                stats = collective_stats(
                    fn.lower(tree, carry, pend).compile().as_text()
                )
                want = aggregation.expected_window_collectives(
                    wp, 1, compression=compression, pool=pool
                )
                for kind, count in want.items():
                    got = stats.count_by_kind.get(kind, 0)
                    assert got == count, (
                        wp.window, compression, pool, kind, got, count,
                    )
    check("HLO: pipelined window collectives == extended static oracle "
          "(warm-up + steady, none/int8, pooled/regional)", True)


def test_stale_delivery_numerics():
    # satellite 2 unreachable for exactly K windows, then delivers: the
    # sink FedAvg must include its ORIGINAL snapshot weighted decay**K
    K, DECAY = 2, 0.5
    iso = [Relation.from_edges(
        [(0, 6), (1, 6), (3, 6), (4, 7), (5, 7)], nodes=range(N)
    )]
    full = [Relation.from_edges(
        [(0, 6), (1, 6), (2, 6), (3, 6), (4, 7), (5, 7)], nodes=range(N)
    )]
    router = routing.MultiWindowRouter(N, SINKS, max_staleness_windows=K)
    rng = np.random.default_rng(7)
    tree = {"w": jnp.asarray(rng.normal(size=(N, 64)).astype(np.float32))}
    x0 = np.asarray(tree["w"]).copy()
    carry, pend = _zero_aux(tree)
    state = tree
    wps = []
    for w in range(K + 1):
        wp = router.plan_window(iso if w < K else full)
        wps.append(wp)
        state, carry, pend = _window_fn(wp, decay=DECAY)(state, carry, pend)
    last = wps[-1]
    assert last.delivered_ages[2] == K     # delivered at exactly the horizon
    assert not last.dropped
    # replay the weighted averages in numpy (params only change via floods)
    cur = x0.copy()
    for wp in wps:
        w = aggregation.staleness_sink_weights(wp.uplink, wp.delivered_ages,
                                               DECAY)
        num = sum(
            (DECAY ** wp.ages[s]) * (x0[s] if s == 2 else cur[s])
            for s in sorted(wp.delivered_ages)
        ) + cur[6] + cur[7]
        g = num / w.sum()
        for v in sorted(wp.downlink.covered | wp.uplink.sinks):
            cur[v] = g
    got = np.asarray(state["w"])
    np.testing.assert_allclose(got[6], cur[6], atol=1e-5)
    # beyond the horizon the payload is dropped, never delivered
    router2 = routing.MultiWindowRouter(N, SINKS, max_staleness_windows=1)
    for w in range(3):
        wp = router2.plan_window(iso)
    assert wp.dropped == {2: 2}
    assert router2.dropped_log[0].source == 2
    check(f"stale delivery at exactly K={K} windows lands with weight "
          f"decay^K; past-horizon payloads drop and report", True)


def test_pipelined_fl_end_to_end():
    geom, plan = walker_plan()
    cfg, opt_cfg, fl_cfg, fl_mesh, batch_fn = _fl_setup()
    gs_cfg = fl_train.GroundSegConfig(
        mode="centralized", pipeline_depth=2, max_staleness_windows=2,
    )
    state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N)
    state, logs = fl_train.run_groundseg_fl(
        cfg, opt_cfg, fl_mesh, N, fl_cfg, gs_cfg, plan, state, batch_fn,
        sinks=SINKS, rounds=3, antennas=2,
    )
    assert all(np.isfinite(log.loss) for log in logs)
    assert logs[0].covered == 0            # warm-up window: no global yet
    assert all(log.delivered == N_SATS for log in logs)
    assert all(log.covered == N_SATS for log in logs[1:])
    assert all(log.dropped == 0 for log in logs)
    # pipelined + centralized: after a steady-state round every covered
    # satellite holds the PREVIOUS round's global — all identical lanes
    for leaf in jax.tree.leaves(state["params"]):
        arr = np.asarray(leaf)
        for v in range(1, N_SATS):
            assert np.array_equal(arr[0], arr[v])
    check("pipelined depth-2 FL end to end: warm-up then steady coverage, "
          "satellites in exact consensus on the lagged global", True)


if __name__ == "__main__":
    test_router_full_delivery()
    test_hlo_relay_collective_counts()
    test_fedavg_numerics()
    test_int8_relay_hop_count_independent()
    test_hierarchical_fl_converges()
    test_centralized_exact_consensus_on_covered()
    test_dead_satellite_skip_slot()
    test_pipelined_bit_identical_at_trivial_config()
    test_pipelined_hlo_collective_counts()
    test_stale_delivery_numerics()
    test_pipelined_fl_end_to_end()
    print("ALL-OK")
