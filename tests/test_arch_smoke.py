"""Per-architecture smoke tests: reduced same-family configs run one
forward/train step (and a prefill+decode round trip) on CPU, asserting
output shapes and finiteness. The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import archs
from repro.models import registry

ARCH_NAMES = list(archs.ARCHS.keys())


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.mrope_sections is not None:
        pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3)).copy()
        pos[:, :, 1] += rng.integers(0, 3, (B, S))  # fake 2D offsets
        batch["positions"] = jnp.asarray(pos, jnp.int32)
    if cfg.enc_dec:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_frames, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_smoke(name):
    cfg = archs.smoke_cfg(archs.get(name))
    b = registry.bundle(cfg)
    params, specs = b.init(jax.random.PRNGKey(0))
    # specs mirror params
    jax.tree.map(
        lambda p, s: None, params, specs,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, jnp.ndarray),
    )
    batch = make_batch(cfg)

    loss, metrics = jax.jit(b.loss_fn)(params, batch)
    assert jnp.isfinite(loss), (name, loss)
    assert float(loss) > 0
    # one gradient step moves the loss
    grads = jax.jit(jax.grad(lambda p: b.loss_fn(p, batch)[0]))(params)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert jnp.isfinite(gnorm) and float(gnorm) > 0, name
    lr = 1e-2
    new_params = jax.tree.map(
        lambda p, g: p - lr * g.astype(p.dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params, grads,
    )
    loss2, _ = jax.jit(b.loss_fn)(new_params, batch)
    assert jnp.isfinite(loss2), name
    assert float(loss2) < float(loss) * 1.5  # sanity: no explosion


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_smoke(name):
    cfg = archs.smoke_cfg(archs.get(name))
    b = registry.bundle(cfg)
    params, _ = b.init(jax.random.PRNGKey(1))
    B, S, max_len = 2, 16, 32
    batch = make_batch(cfg, B=B, S=S, seed=1)
    pre_batch = {k: v for k, v in batch.items() if k != "labels"}
    if "positions" in pre_batch:
        pre_batch["positions"] = pre_batch["positions"][:, :S]

    logits, cache = jax.jit(
        lambda p, bt: b.prefill_fn(p, bt, max_len)
    )(params, pre_batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), name
    assert int(cache["pos"]) == S

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    step_batch = {"token": tok}
    if cfg.mrope_sections is not None:
        step_batch["positions"] = jnp.full((B, 1, 3), S, jnp.int32)
    logits2, cache2 = jax.jit(b.decode_fn)(params, cache, step_batch)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all()), name
    assert int(cache2["pos"]) == S + 1


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_matches_decode_replay(name):
    """Decoding token-by-token from an empty cache reproduces the prefill
    logits (the core cache-consistency invariant, incl. ring caches).

    fp32 compute: prefill (chunked SSD / blocked attention) and decode
    (recurrence) sum in different orders, so bf16 noise would mask real
    cache bugs. fp32 separates the two (observed: bf16 ~0.1, fp32 ~1e-5)."""
    cfg = archs.smoke_cfg(archs.get(name)).replace(compute_dtype="float32")
    b = registry.bundle(cfg)
    params, _ = b.init(jax.random.PRNGKey(2))
    B, S = 1, 8
    batch = make_batch(cfg, B=B, S=S, seed=2)
    pre_batch = {k: v for k, v in batch.items() if k != "labels"}

    logits_pre, cache_pre = jax.jit(
        lambda p, bt: b.prefill_fn(p, bt, S + 4)
    )(params, pre_batch)

    cache = b.init_cache(B, S + 4)
    if cfg.enc_dec:
        # replay needs the cross-attn KV: take it from a length-0 prefill
        # trick — run prefill on the first token to fill cross KV, then
        # continue decoding from scratch positions. Simpler: copy cross KV.
        for key in cache["units"]:
            if key.startswith("cross"):
                cache["units"][key] = cache_pre["units"][key]
    logits = None
    decode = jax.jit(b.decode_fn)
    for t in range(S):
        sb = {"token": batch["tokens"][:, t : t + 1]}
        if cfg.mrope_sections is not None:
            sb["positions"] = batch["positions"][:, t : t + 1]
        logits, cache = decode(params, cache, sb)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(logits_pre, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_param_counts_match_published_sizes():
    """Exact param counts land near the published model sizes."""
    expect = {
        "mamba2-780m": (0.6e9, 1.0e9),
        "gemma2-9b": (8.0e9, 10.5e9),
        "gemma2-27b": (24e9, 29e9),
        "granite-20b": (18e9, 22e9),
        "qwen2-72b": (68e9, 76e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.1e12),
        "whisper-base": (0.05e9, 0.11e9),
        "qwen2-vl-72b": (68e9, 76e9),
    }
    for name, (lo, hi) in expect.items():
        n = archs.get(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]B"


def test_active_param_counts():
    assert 2e9 <= archs.get("qwen3-moe-30b-a3b").active_param_count() <= 4.5e9
    assert 25e9 <= archs.get("kimi-k2-1t-a32b").active_param_count() <= 40e9


def test_cell_enumeration():
    cells = list(archs.all_cells())
    # 10 archs x 4 shapes - 8 long_500k skips (full-attention archs)
    assert len(cells) == 32
    longs = [c for c in cells if c[1] == "long_500k"]
    assert sorted(x[0] for x in longs) == ["jamba-1.5-large-398b", "mamba2-780m"]
