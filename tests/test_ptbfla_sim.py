"""Tests for the paper-faithful Algorithm 1 simulator (the reproduction
floor): getMeas semantics, timeSlotsMap reorder buffer, skip-slot, get1meas
pairwise limitation, and data propagation (paper P2) across schedules."""

import pytest

from repro.core.gossip import propagation_closure
from repro.core.ptbfla_sim import (
    PTBFLASimulator,
    run_schedule_get1meas,
    run_schedule_getmeas,
)
from repro.core.relation import Relation
from repro.core.schedule import (
    TDMSchedule,
    clique_multilink,
    round_robin_tournament,
)
from proptest import given, st_int, st_relation


# ------------------------------------------------------------ single slot
@given(st_relation(max_nodes=10, p=0.5), st_int(0, 10_000), cases=120)
def test_getmeas_delivers_peer_data_in_order(rel, seed):
    """Every node receives exactly its peers' odata, ordered as peer_ids
    (paper: 'each element of the list obss corresponds to the element in the
    same position of the list peer_ids')."""
    n = (max(rel.nodes) + 1) if rel.nodes else 2
    sched = TDMSchedule((rel,))
    data = {i: f"odata-{i}" for i in range(n)}
    received, sim = run_schedule_getmeas(sched, data, n, seed=seed)
    for i in range(n):
        peers = rel.peers_of(i)
        if not peers:
            assert received[i] == {}
        else:
            assert list(received[i][0].keys()) == peers
            for p in peers:
                assert received[i][0][p] == f"odata-{p}"


@given(st_int(0, 10_000), cases=40)
def test_timeslotsmap_buffers_fast_peers(seed):
    """Multi-slot schedules with adversarial interleaving exercise the
    reorder buffer: a fast node's slot-(t+1) message arrives while the slow
    peer is still in slot t and must be buffered, not lost."""
    n = 4
    sched = TDMSchedule(tuple(clique_multilink(n)[0] for _ in range(4)))
    data = {i: (lambda i=i: (lambda t: (i, t)))() for i in range(n)}
    received, sim = run_schedule_getmeas(sched, data, n, seed=seed)
    for i in range(n):
        for t in range(4):
            for p in [j for j in range(n) if j != i]:
                assert received[i][t][p] == (p, t)  # right slot's data, always


def test_timeslotsmap_actually_used():
    """At least one interleaving buffers at least one out-of-slot message —
    otherwise the test above proves nothing about timeSlotsMap."""
    n = 4
    sched = TDMSchedule(tuple(clique_multilink(n)[0] for _ in range(6)))
    data = {i: (lambda i=i: (lambda t: (i, t)))() for i in range(n)}
    buffered = 0
    for seed in range(25):
        _, sim = run_schedule_getmeas(sched, data, n, seed=seed)
        buffered += sum(node.n_buffered for node in sim.nodes)
    assert buffered > 0


def test_skip_slot_odata_none():
    """Paper assumption (b): a node not taking part sets odata=None, which
    just advances its slot counter."""
    sim = PTBFLASimulator(2)
    node = sim.nodes[0]
    gen_or_val = sim.get_meas(node, [], None)
    # skip path returns a plain value (no yields)
    assert not hasattr(gen_or_val, "send") or _drain(gen_or_val) is None
    assert node.time_slot == 1
    assert node.n_sent == 0


def _drain(gen):
    try:
        while True:
            gen.send(None)
    except StopIteration as s:
        return s.value


def test_get1meas_rejects_multilink_slot():
    """The original primitive's limitation (what the paper removes)."""
    rel = Relation.from_edges([(0, 1), (1, 2)])  # node 1 has two peers
    with pytest.raises(ValueError, match="pairwise"):
        run_schedule_get1meas(TDMSchedule((rel,)), {i: i for i in range(3)}, 3)


def test_invalid_schedule_deadlocks_detected():
    """A one-sided 'exchange' (aRb without bRa) deadlocks; the scheduler
    detects it rather than hanging."""
    sim = PTBFLASimulator(2)

    def prog_a(node):
        res = yield from sim.get_meas(node, [1], "x")  # waits for 1 forever
        return res

    def prog_b(node):
        if False:
            yield
        return None  # b never sends

    with pytest.raises(RuntimeError, match="deadlock"):
        sim.run({0: prog_a, 1: prog_b})


# --------------------------------------------------------- full schedules
@given(st_int(2, 9), st_int(0, 1000), cases=60)
def test_round_robin_equals_multilink_semantics(n, seed):
    """Paper §IV: the get1meas round-robin tournament and the getMeas
    single-slot clique are semantically equivalent — after the full schedule
    every node holds every other node's data."""
    data = {i: f"d{i}" for i in range(n)}
    rr, _ = run_schedule_get1meas(round_robin_tournament(n), data, n, seed=seed)
    ml, _ = run_schedule_getmeas(clique_multilink(n), data, n, seed=seed)
    for i in range(n):
        got_rr = {p: v for slot in rr[i].values() for p, v in slot.items()}
        got_ml = {p: v for slot in ml[i].values() for p, v in slot.items()}
        assert got_rr == got_ml == {j: f"d{j}" for j in range(n) if j != i}


@given(st_relation(max_nodes=8, p=0.4), st_relation(max_nodes=8, p=0.4), st_int(0, 1000), cases=60)
def test_data_propagation_matches_closure(r1, r2, seed):
    """Paper P2 realized operationally: run a 2-slot schedule where nodes
    forward everything they know; the set of node-i-originated data that
    reached j equals the propagation closure of the slot sequence."""
    n = max([max(r1.nodes, default=0), max(r2.nodes, default=0)]) + 1
    sched = TDMSchedule((r1.restrict(range(n)), r2.restrict(range(n))))
    sim = PTBFLASimulator(n, seed=seed)

    def make_prog(i):
        def prog(node):
            know = {i}
            for rel in sched:
                peers = rel.peers_of(i)
                odata = sorted(know) if peers else None
                got = yield from _as_gen_local(sim.get_meas(node, peers, odata))
                if got:
                    for lst in got:
                        know.update(lst)
            return know

        return prog

    results = sim.run({i: make_prog(i) for i in range(n)})
    reach = propagation_closure(sched, n)
    for j in range(n):
        expected = {i for i in range(n) if reach[i, j]}
        assert results[j] == expected


def _as_gen_local(gen_or_value):
    if hasattr(gen_or_value, "send"):
        result = yield from gen_or_value
        return result
    return gen_or_value


# ----------------------------------------------------------- message cost
def test_message_counts_match_theory():
    """|messages| per slot = |R| (each ordered pair is one send)."""
    n = 6
    rel = Relation.clique(list(range(n)))
    _, sim = run_schedule_getmeas(TDMSchedule((rel,)), {i: i for i in range(n)}, n)
    assert sim.total_messages == len(rel) == n * (n - 1)

    _, sim2 = run_schedule_get1meas(round_robin_tournament(n), {i: i for i in range(n)}, n)
    assert sim2.total_messages == n * (n - 1)  # same total, spread over slots
