"""Tests for the roofline accounting layers: the jaxpr cost walker
(launch/flops.py) and the trip-count-aware HLO collective parser
(launch/hlo_stats.py). These are load-bearing for §Roofline — errors here
would silently skew every reported number."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_stats
from repro.launch.flops import program_costs


# ------------------------------------------------------------ flops walker
def test_dot_flops_exact():
    a = jnp.zeros((64, 128))
    b = jnp.zeros((128, 32))
    c = program_costs(lambda a, b: a @ b, a, b)
    assert c.flops == 2 * 64 * 128 * 32
    # traffic: operands + result + program I/O (same arrays counted again)
    onepass = (64 * 128 + 128 * 32 + 64 * 32) * 4
    assert c.traffic_bytes == 2 * onepass


def test_batched_dot_flops():
    a = jnp.zeros((8, 64, 128))
    b = jnp.zeros((8, 128, 32))
    c = program_costs(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), a, b)
    assert c.flops == 8 * 2 * 64 * 128 * 32


def test_scan_multiplies_body():
    w = jnp.zeros((16, 128, 128))
    x = jnp.zeros((128, 128))

    def f(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    c = program_costs(f, x, w)
    assert c.flops == pytest.approx(16 * 2 * 128**3, rel=1e-6)


def test_nested_scan_multiplies():
    w = jnp.zeros((4, 8, 64, 64))
    x = jnp.zeros((64, 64))

    def f(x, w):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, None
            return jax.lax.scan(inner, c, wo)[0], None
        return jax.lax.scan(outer, x, w)[0]

    c = program_costs(f, x, w)
    assert c.flops == pytest.approx(4 * 8 * 2 * 64**3, rel=1e-6)


def test_grad_and_remat_counted():
    w = jnp.zeros((8, 128, 128))
    x = jnp.zeros((128, 128))

    def mk(remat):
        def f(x, w):
            body = lambda c, wi: (jnp.tanh(c @ wi), None)
            b = jax.checkpoint(body) if remat else body
            return jnp.sum(jax.lax.scan(b, x, w)[0])
        return f

    base = program_costs(mk(False), x, w).flops
    grad = program_costs(jax.grad(mk(False)), x, w).flops
    rgrad = program_costs(jax.grad(mk(True)), x, w).flops
    assert grad > base  # bwd adds work
    assert rgrad > grad  # remat adds recompute on top
    assert rgrad / base == pytest.approx(3.0, rel=0.05)


def test_transcendentals_tracked():
    x = jnp.zeros((1000,))
    c = program_costs(lambda x: jnp.exp(x) + jnp.tanh(x), x)
    assert c.transcendentals == 2000


# ---------------------------------------------------------- HLO collectives
HLO_SAMPLE = """
HloModule jit_f

%wide.body (param: (s32[], f32[4,128])) -> (s32[], f32[4,128]) {
  %ag = f32[128,128]{1,0} all-gather(%gte), channel_id=1, dimensions={1}
  %ar = bf16[4,128]{1,0} all-reduce(%x), channel_id=2
  ROOT %t = (s32[], f32[4,128]) tuple(%iv, %y)
}

%wide.cond (param.1: (s32[], f32[4,128])) -> pred[] {
  %c = s32[] constant(12)
  %gte0 = s32[] get-tuple-element(%param.1), index=0
  ROOT %cmp = pred[] compare(%gte0, %c), direction=LT
}

ENTRY %main (p0: f32[4,128]) -> f32[4,128] {
  %cp = f32[4,128]{1,0} collective-permute(%p0), channel_id=3
  %w = (s32[], f32[4,128]) while(%init), condition=%wide.cond, body=%wide.body
  %rs = f32[2,128]{1,0} reduce-scatter(%q), channel_id=4
  ROOT %out = f32[4,128] get-tuple-element(%w), index=1
}
"""


def test_collective_parser_trip_counts():
    st = hlo_stats.collective_stats(HLO_SAMPLE)
    # while body: trip 12 -> ag 128*128*4*12, ar 4*128*2*12
    assert st.bytes_by_kind["all-gather"] == 128 * 128 * 4 * 12
    assert st.bytes_by_kind["all-reduce"] == 4 * 128 * 2 * 12
    # entry-level ops once
    assert st.bytes_by_kind["collective-permute"] == 4 * 128 * 4
    assert st.bytes_by_kind["reduce-scatter"] == 2 * 128 * 4
    assert st.count_by_kind["all-gather"] == 12
    assert st.unknown_trip_whiles == 0


def test_collective_parser_real_module():
    """End-to-end: sharded scanned matmul on forced devices is covered by
    the mini dry-run worker; here just ensure no crash on a module with no
    collectives."""
    hlo = jax.jit(lambda x: x * 2).lower(jnp.ones((4,))).compile().as_text()
    st = hlo_stats.collective_stats(hlo)
    assert st.total_bytes == 0


def test_shape_bytes():
    assert hlo_stats.shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert hlo_stats.shape_bytes("f32[]") == 4
    assert hlo_stats.shape_bytes("pred[7]") == 7
    assert hlo_stats.shape_bytes("token[]") == 0
