"""Serving subsystem: workload synthesis, decoders, fleet admission,
engine transport/churn semantics, and the route-provenance auditor.

Everything here drives the deterministic :class:`NullDecoder` (pure host);
the real stacked-shard_map :class:`ModelDecoder` end-to-end run lives in
``_serving_worker.py`` (8 forced host devices, subprocess, slow tier).
"""

import dataclasses
import functools
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.constellation.scenario import smoke_scenario
from repro.serving import (
    InferenceRequest,
    NullDecoder,
    ReplicaFleet,
    Send,
    ServingEngine,
    audit_serving_run,
    synthesize_workload,
)
from repro.serving import requests as rq

ROOT = pathlib.Path(__file__).resolve().parents[1]


@functools.lru_cache(maxsize=1)
def _smoke():
    return smoke_scenario()


def _engine(replicas=(0, 3), batch=2, **kw):
    scn = _smoke()
    fleet = ReplicaFleet(list(replicas), batch, NullDecoder(len(replicas), batch))
    return ServingEngine.from_scenario(scn, fleet, **kw), scn


# ------------------------------------------------------------------ workload
def test_workload_deterministic_arrivals():
    a = synthesize_workload(10, [6, 7], rate_per_slot=2.0, seed=3)
    b = synthesize_workload(10, [6, 7], rate_per_slot=2.0, seed=3)
    for ra, rb in zip(a, b):
        assert ra.gateway == rb.gateway
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
    # arrivals advance at exactly rate_per_slot requests per slot
    assert [r.arrival_slot for r in a] == [k // 2 for k in range(10)]
    assert {r.gateway for r in a} <= {6, 7}
    with pytest.raises(ValueError, match="gateway"):
        synthesize_workload(4, [])


# ------------------------------------------------------------------ decoders
def test_null_decoder_deterministic_and_lane_isolated():
    d1, d2 = NullDecoder(2, 2), NullDecoder(2, 2)
    prompts = {0: [np.array([1, 2, 3]), np.array([4, 5])]}
    assert d1.prefill_waves(prompts) == d2.prefill_waves(prompts)
    active = np.array([True, False])
    t1, t2 = d1.step(active), d2.step(active)
    np.testing.assert_array_equal(t1, t2)
    # the inactive replica's lanes did not advance
    np.testing.assert_array_equal(t1[1], (d1._state[1] % d1.vocab))
    assert (d1._state[1] == 0).all()


# --------------------------------------------------------------------- fleet
def _req(rid, max_new=3, gateway=6):
    return InferenceRequest(
        rid=rid, gateway=gateway, prompt=np.array([rid + 1, 2]), max_new=max_new
    )


def test_fleet_wave_admission_and_ticks():
    fleet = ReplicaFleet([0], batch=2, decoder=NullDecoder(1, 2))
    for i in range(3):
        fleet.enqueue(0, _req(i))
    waves = fleet.admit({0})
    assert [r.rid for r in waves[0]] == [0, 1]    # batch-bounded wave
    assert fleet.busy(0) and fleet.queued(0) == 1
    assert all(len(r.out) == 1 for r in waves[0])  # prefill emits token 0
    # a busy replica admits nothing more (wave discipline)
    assert fleet.admit({0}) == {}
    done = []
    for _ in range(5):
        for _, reqs in fleet.tick().items():
            done.extend(reqs)
        if done:
            break
    assert sorted(r.rid for r in done) == [0, 1]
    assert all(len(r.out) == 3 for r in done)
    assert not fleet.busy(0)
    # lanes freed: the queued request admits next
    assert [r.rid for r in fleet.admit({0})[0]] == [2]


def test_fleet_max_new_1_frees_lanes_at_prefill():
    fleet = ReplicaFleet([0], batch=2, decoder=NullDecoder(1, 2))
    fleet.enqueue(0, _req(0, max_new=1))
    wave = fleet.admit({0})[0]
    assert wave[0].done
    assert not fleet.busy(0)          # regression guard: lanes released


def test_fleet_drain_returns_everything():
    fleet = ReplicaFleet([0], batch=2, decoder=NullDecoder(1, 2))
    for i in range(3):
        fleet.enqueue(0, _req(i))
    fleet.admit({0})
    drained = fleet.drain(0)
    assert sorted(r.rid for r in drained) == [0, 1, 2]
    assert not fleet.busy(0) and fleet.queued(0) == 0


# -------------------------------------------------------------------- engine
def test_engine_validates_roles():
    scn = _smoke()
    with pytest.raises(ValueError, match="gateway and replica"):
        fleet = ReplicaFleet([6], 2, NullDecoder(1, 2))
        ServingEngine.from_scenario(scn, fleet)
    eng, _ = _engine()
    with pytest.raises(ValueError, match="ground stations"):
        eng.fail(6)


def test_engine_end_to_end_all_delivered():
    eng, scn = _engine()
    workload = synthesize_workload(
        8, scn.ground_ids, rate_per_slot=2.0, max_new=4
    )
    report = eng.run(workload)
    summ = report.summary()
    assert summ["delivered"] == 8 and summ["undelivered"] == 0
    assert summ["tokens"] == 8 * 4
    assert summ["latency_p50_slots"] > 0
    assert summ["wall_s"] > 0
    for r in report.delivered:
        assert r.status == rq.DELIVERED
        assert r.hops_up >= 1 and r.hops_down >= 1
        assert r.replica in (0, 3)
        assert len(r.out) == 4
    verdict = audit_serving_run(
        report.records, report.requests, eng.base_rels,
        gateways=eng.gateways, replicas=[0, 3],
    )
    assert verdict.ok, verdict.summary()
    assert verdict.n_hops > 0


def test_engine_table_cache_lru():
    eng, _ = _engine()
    sinks = frozenset([0, 3])
    assert eng._table(sinks) is eng._table(sinks)      # hit path
    assert eng._table(frozenset()) is None


def test_engine_churn_reroutes_without_loss():
    eng, scn = _engine()
    workload = synthesize_workload(
        10, scn.ground_ids, rate_per_slot=2.0, max_new=4
    )
    epoch = eng.epoch

    def on_slot(engine, slot):
        if slot == epoch // 3:
            engine.fail(0)
        elif slot == epoch // 3 + max(2, epoch // 4):
            engine.restore(0)

    report = eng.run(workload, on_slot=on_slot)
    summ = report.summary()
    assert summ["undelivered"] == 0, [r.status for r in report.undelivered]
    # the drained wave re-routed: retries happened, nothing was lost
    assert summ["retries"] >= 1
    verdict = audit_serving_run(
        report.records, report.requests, eng.base_rels,
        gateways=eng.gateways, replicas=[0, 3],
    )
    assert verdict.ok, verdict.summary()
    # provenance recorded the drain
    assert any(r.requeued for r in report.records)


def test_engine_dead_replica_batch_drains():
    """Requests decoding on a failed replica restart from their gateway."""
    eng, scn = _engine(replicas=(0,))    # single replica: all waves land on 0
    workload = synthesize_workload(
        4, scn.ground_ids, rate_per_slot=4.0, max_new=16
    )
    seen_decoding = {}

    def on_slot(engine, slot):
        for req in engine.pending.values():
            if req.status == rq.DECODING and req.rid not in seen_decoding:
                seen_decoding[req.rid] = slot
        if len(seen_decoding) >= 2 and not engine_failed[0]:
            engine.fail(0)
            engine_failed[0] = True
        elif engine_failed[0] and 0 not in engine.alive:
            engine.restore(0)

    engine_failed = [False]
    report = eng.run(workload, on_slot=on_slot)
    assert engine_failed[0]
    summ = report.summary()
    assert summ["undelivered"] == 0
    assert summ["retries"] >= 1
    # tokens decoded before the failure were discarded, not delivered twice
    assert all(len(r.out) == 16 for r in report.delivered)


# --------------------------------------------------------------------- audit
def _clean_run():
    eng, scn = _engine()
    workload = synthesize_workload(
        6, scn.ground_ids, rate_per_slot=2.0, max_new=3
    )
    report = eng.run(workload)
    return eng, report


def test_audit_flags_phantom_and_illegal_sends():
    eng, report = _clean_run()
    records = list(report.records)
    # a hop for a request id the engine never saw
    records[0] = dataclasses.replace(
        records[0],
        sends=records[0].sends + (Send(records[0].slot, 0, 1, "req", 999),),
    )
    verdict = audit_serving_run(
        records, report.requests, eng.base_rels,
        gateways=eng.gateways, replicas=[0, 3],
    )
    assert not verdict.ok
    assert any("999" in str(v) for v in verdict.violations)


def test_audit_flags_link_not_in_slot():
    eng, report = _clean_run()
    rid = report.requests[0].rid
    records = list(report.records)
    # teleport: a hop on a pair the slot relation does not contain
    bad = Send(records[2].slot, 0, 5, "req", rid)
    records[2] = dataclasses.replace(
        records[2], sends=records[2].sends + (bad,)
    )
    verdict = audit_serving_run(
        records, report.requests, eng.base_rels,
        gateways=eng.gateways, replicas=[0, 3],
    )
    assert not verdict.ok


def test_audit_flags_lost_request():
    eng, report = _clean_run()
    # claim a request existed that never delivered and never moved
    ghost = InferenceRequest(
        rid=777, gateway=eng.gateways[0], prompt=np.array([1]), max_new=2
    )
    verdict = audit_serving_run(
        report.records, list(report.requests) + [ghost], eng.base_rels,
        gateways=eng.gateways, replicas=[0, 3],
    )
    assert any(v.kind == "lost-request" for v in verdict.violations)


# ------------------------------------------------------- multi-device (slow)
@pytest.mark.slow
def test_serving_model_decoder_suite():
    """End-to-end serving with the real stacked-shard_map decoder on 8
    forced host devices, including a mid-run satellite failure."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        f"{ROOT / 'src'}:{ROOT / 'tests'}:" + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_serving_worker.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    assert proc.returncode == 0, "worker failed"
    assert "ALL-OK" in proc.stdout
