"""Multi-device worker for the fused flat-buffer exchange engine: HLO-level
collective counts (M fused vs L×M per-leaf) and fused-vs-per-leaf
equivalence for every compression mode, on 8 forced host devices. Launched
as a subprocess by test_fused.py (device count locks at first jax init).

Exit code 0 + final line "ALL-OK" on success.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import random
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import fl, fused, tdm
from repro.core.relation import Relation
from repro.core.schedule import ring
from repro.launch.hlo_stats import collective_stats

N = 8
mesh = Mesh(np.array(jax.devices()[:N]), ("node",))

# L=12 > 10 leaves, mixed shapes, all fp32 (single bucket => exactly M)
SHAPES = [
    (3, 5), (17,), (4, 4, 2), (128,), (33,), (2, 2),
    (64, 3), (7,), (5, 5), (11, 3), (9,), (256,),
]
L = len(SHAPES)


def make_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        f"w{i}": jnp.asarray(rng.normal(size=(N,) + s).astype(np.float32))
        for i, s in enumerate(SHAPES)
    }


def round_fn(rel, cfg, **kw):
    def body(t):
        t = jax.tree.map(lambda x: x[0], t)
        if kw:
            out, _ = fused.fused_tdm_fla_round(t, rel, "node", N, cfg, **kw)
        else:
            out, _ = fl.tdm_fla_round(t, rel, "node", N, cfg)
        return jax.tree.map(lambda x: x[None], out)

    # check_rep=False: the Pallas quantization kernels have no replication
    # rule (same reason build_fl_round disables it)
    return jax.jit(
        shard_map(
            body, mesh=mesh, in_specs=(P("node"),), out_specs=P("node"),
            check_rep=False,
        )
    )


def permute_count(fn, tree) -> float:
    stats = collective_stats(fn.lower(tree).compile().as_text())
    return stats.count_by_kind.get("collective-permute", 0.0)


def tree_equal(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def tree_rel_err(a, b) -> float:
    num = sum(
        float(np.square(np.asarray(x) - np.asarray(y)).sum())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )
    den = sum(float(np.square(np.asarray(y)).sum()) for y in jax.tree.leaves(b))
    return (num / max(den, 1e-30)) ** 0.5


def random_relation(rng: random.Random, p: float = 0.5) -> Relation:
    edges = [(i, j) for i in range(N) for j in range(i + 1, N) if rng.random() < p]
    return Relation.from_edges(edges, nodes=range(N))


def check(name, cond):
    if not cond:
        print(f"FAIL: {name}")
        sys.exit(1)
    print(f"ok: {name}")


# ---------------------------------------------------------------------------
# 1. HLO collective counts: fused == M, per-leaf == L×M (the tentpole claim)
# ---------------------------------------------------------------------------
def test_hlo_collective_counts():
    tree = make_tree()
    for rel in (ring(N), Relation.clique(list(range(N)))):
        M = len(tdm.edge_coloring(rel))
        got_fused = permute_count(round_fn(rel, fl.TDMFLAConfig(fused=True)), tree)
        got_leaf = permute_count(round_fn(rel, fl.TDMFLAConfig(fused=False)), tree)
        assert got_fused == M, (got_fused, M)
        assert got_leaf == L * M, (got_leaf, L, M)
        # int8 ships payload + scales per matching: exactly 2M, still no L
        got_int8 = permute_count(
            round_fn(rel, fl.TDMFLAConfig(compression="int8", fused=True)), tree
        )
        assert got_int8 == 2 * M, (got_int8, M)
        # fused CHOCO packs values+indices into ONE int32 payload: exactly M
        # (the per-leaf path ships values and indices separately = 2LM)
        got_topk = permute_count(
            round_fn(rel, fl.TDMFLAConfig(compression="topk", fused=True)), tree
        )
        assert got_topk == M, (got_topk, M)
        # k=4 fits the smallest leaf; the collective count is k-independent
        got_topk_leaf = permute_count(
            round_fn(
                rel, fl.TDMFLAConfig(compression="topk", topk_k=4, fused=False)
            ),
            tree,
        )
        assert got_topk_leaf == 2 * L * M, (got_topk_leaf, L, M)
    check(
        f"HLO: fused = M permutes (topk packed = M too), per-leaf = {L}xM "
        f"(topk = 2x{L}xM), int8 fused = 2M",
        True,
    )


# ---------------------------------------------------------------------------
# 1b. mixed-dtype trees: every dtype bucket pays the same per-bucket count —
#     XLA must NOT combine the buckets' collectives, or the telemetry oracle
#     (and RoundFnCache's no-skip reconcile path) would be wrong
# ---------------------------------------------------------------------------
def test_mixed_dtype_hlo_counts():
    from repro import telemetry

    base = make_tree(seed=9)
    tree = {
        k: (v.astype(jnp.bfloat16) if i % 2 else v)
        for i, (k, v) in enumerate(base.items())
    }
    n_buckets = len({v.dtype.name for v in tree.values()})
    assert n_buckets == 2
    for rel in (ring(N), Relation.clique(list(range(N)))):
        for comp in ("none", "int8", "topk"):
            want = telemetry.expected_tdm_collectives(
                rel, n_buckets, compression=comp
            )["collective-permute"]
            got = permute_count(
                round_fn(rel, fl.TDMFLAConfig(compression=comp, fused=True)),
                tree,
            )
            assert got == want, (comp, got, want)
    check(
        "HLO: mixed f32+bf16 tree pays exactly per x M x n_buckets permutes "
        "for none/int8/topk (buckets never combined)",
        True,
    )


# ---------------------------------------------------------------------------
# 2. uncompressed fused == per-leaf, bit for bit (both primitives)
# ---------------------------------------------------------------------------
def test_uncompressed_bitwise():
    rng = random.Random(0)
    for case in range(8):
        rel = random_relation(rng)
        if len(rel) == 0:
            continue
        tree = make_tree(seed=case)
        for comm in ("getmeas", "get1meas"):
            a = round_fn(rel, fl.TDMFLAConfig(comm=comm, fused=True))(tree)
            b = round_fn(rel, fl.TDMFLAConfig(comm=comm, fused=False))(tree)
            assert tree_equal(a, b), (case, comm)
    check("uncompressed fused == per-leaf bitwise (getmeas + get1meas)", True)


# ---------------------------------------------------------------------------
# 3. int8: fused (blockwise, Metropolis) tracks exact gossip and the per-leaf
#    path within quantization tolerance; Pallas-interpret == jnp ref impl
# ---------------------------------------------------------------------------
def test_int8_tolerance():
    tree = make_tree(seed=3)
    rel = Relation.clique(list(range(N)))  # regular: per-leaf weights == Metropolis
    exact = round_fn(rel, fl.TDMFLAConfig(fused=True))(tree)
    got = round_fn(rel, fl.TDMFLAConfig(compression="int8", fused=True))(tree)
    err_exact = tree_rel_err(got, exact)
    assert err_exact < 0.02, err_exact
    per_leaf = round_fn(rel, fl.TDMFLAConfig(compression="int8", fused=False))(tree)
    err_leaf = tree_rel_err(got, per_leaf)
    assert err_leaf < 0.04, err_leaf
    check(
        f"int8 fused: vs exact gossip {err_exact:.4f} < 2%, "
        f"vs per-leaf int8 {err_leaf:.4f} < 4%",
        True,
    )


def test_int8_pallas_matches_ref_impl():
    tree = make_tree(seed=4)
    rel = ring(N)
    cfg = fl.TDMFLAConfig(compression="int8")
    a = round_fn(rel, cfg, quant_impl="pallas_interpret")(tree)
    b = round_fn(rel, cfg, quant_impl="ref")(tree)
    err = tree_rel_err(a, b)
    assert err < 1e-6, err
    check("int8 fused: Pallas(interpret) impl == jnp ref impl", True)


def test_topk_pallas_matches_ref_impl():
    tree = make_tree(seed=6)
    rel = ring(N)
    cfg = fl.TDMFLAConfig(compression="topk", topk_k=16)
    a = round_fn(rel, cfg, quant_impl="pallas_interpret")(tree)
    b = round_fn(rel, cfg, quant_impl="ref")(tree)
    # ~1-ulp slack: inlined jnp ref is FMA-contractable by XLA where the
    # opaque interpret-mode pallas_call boundary is not (the standalone
    # differential suite in test_kernels.py proves bitwise equality when
    # both sides are jitted in isolation)
    err = tree_rel_err(a, b)
    assert err < 1e-6, err
    check("topk fused: Pallas(interpret) impl == jnp ref impl (<1e-6)", True)


# ---------------------------------------------------------------------------
# 4. CHOCO top-k on the fused buffer converges to consensus (state carried
#    across rounds, k budget = topk_k × n_leaves)
# ---------------------------------------------------------------------------
def test_choco_fused_converges():
    # k = 16 x 12 leaves = 192 of 751 live entries (~25% density, same
    # regime as the per-leaf CHOCO test); gamma shrinks with density
    cfg = fl.TDMFLAConfig(compression="topk", topk_k=16, choco_gamma=0.3)
    rng = random.Random(5)
    rel = random_relation(rng, p=0.9)
    tree = make_tree(seed=5)

    def rounds(t):
        t = jax.tree.map(lambda x: x[0], t)
        res = None
        for _ in range(80):
            t, res = fused.fused_tdm_fla_round(t, rel, "node", N, cfg, res)
        return jax.tree.map(lambda x: x[None], t)

    f = jax.jit(
        shard_map(
            rounds, mesh=mesh, in_specs=(P("node"),), out_specs=P("node"),
            check_rep=False,
        )
    )
    got = f(tree)
    errs = []
    for k in tree:
        arr = np.asarray(got[k]).reshape(N, -1)
        target = np.asarray(tree[k]).reshape(N, -1).mean(0)
        errs.append(np.linalg.norm(arr - target) / max(np.linalg.norm(target), 1e-9))
    worst = max(errs)
    assert worst < 0.05, worst
    check(f"CHOCO top-k fused consensus err {worst:.4f} < 5%", True)


# ---------------------------------------------------------------------------
# 5. hierarchical (pod × data) gossip on the fused engine: 2×4 mesh,
#    uncompressed bit-identical to per-leaf hierarchical_gossip, int8 within
#    quantization tolerance, HLO counts == the hierarchical oracle
# ---------------------------------------------------------------------------
N_PODS, N_DATA = 2, 4
mesh2 = Mesh(np.array(jax.devices()[:N]).reshape(N_PODS, N_DATA), ("pod", "data"))
INTRA = Relation.clique(list(range(N_DATA)))
INTER = Relation.from_edges([(0, 1)], nodes=range(N_PODS))


def hier_fn(compression, quant_impl="auto"):
    def body(t):
        t = jax.tree.map(lambda x: x[0], t)
        out = fused.fused_hierarchical_round(
            t, INTRA, INTER, "data", "pod", N_DATA, N_PODS,
            compression=compression, quant_impl=quant_impl,
        )
        return jax.tree.map(lambda x: x[None], out)

    return jax.jit(
        shard_map(
            body, mesh=mesh2, in_specs=(P(("pod", "data")),),
            out_specs=P(("pod", "data")), check_rep=False,
        )
    )


def test_hierarchical_fused():
    tree = make_tree(seed=7)

    # per-leaf reference: tdm.hierarchical_gossip applied leaf by leaf
    def leaf_body(t):
        t = jax.tree.map(lambda x: x[0], t)
        out = jax.tree.map(
            lambda x: tdm.hierarchical_gossip(
                x, INTRA, INTER, "data", "pod", N_DATA, N_PODS
            ),
            t,
        )
        return jax.tree.map(lambda x: x[None], out)

    f_leaf = jax.jit(
        shard_map(
            leaf_body, mesh=mesh2, in_specs=(P(("pod", "data")),),
            out_specs=P(("pod", "data")), check_rep=False,
        )
    )
    got_none = hier_fn("none")(tree)
    assert tree_equal(got_none, f_leaf(tree))
    # clique intra (exact pod mean) + single-edge inter (pairwise mean) ==
    # the global mean on every node, up to float summation order
    err_mean = max(
        float(
            np.abs(
                np.asarray(got_none[k])
                - np.asarray(tree[k]).mean(axis=0, keepdims=True)
            ).max()
        )
        for k in tree
    )
    assert err_mean < 1e-5, err_mean
    got_int8 = hier_fn("int8")(tree)
    err8 = tree_rel_err(got_int8, got_none)
    assert err8 < 0.02, err8
    a = hier_fn("int8", quant_impl="pallas_interpret")(tree)
    b = hier_fn("int8", quant_impl="ref")(tree)
    assert tree_rel_err(a, b) < 1e-6
    check(
        f"hierarchical fused: none == per-leaf bitwise (global-mean err "
        f"{err_mean:.1e}), int8 rel-err {err8:.4f} < 2%, interpret == ref",
        True,
    )


def test_hierarchical_hlo_counts():
    from repro import telemetry

    tree = make_tree(seed=8)
    for comp in ("none", "int8"):
        want = telemetry.expected_hierarchical_collectives(
            INTRA, INTER, 1, compression=comp
        )["collective-permute"]
        stats = collective_stats(
            hier_fn(comp, quant_impl="ref").lower(tree).compile().as_text()
        )
        got = stats.count_by_kind.get("collective-permute", 0)
        assert got == want, (comp, got, want)
    check(
        "HLO: hierarchical fused round == (M_intra + M_inter) x per permutes",
        True,
    )


# ---------------------------------------------------------------------------
# 6. end-to-end: build_fl_round(fused) == build_fl_round(per-leaf) bit for
#    bit on a real smoke model (19 leaves), through the full training round
# ---------------------------------------------------------------------------
def test_build_fl_round_end_to_end():
    from repro.configs import archs
    from repro.data import pipeline
    from repro.launch import fl_train
    from repro.models.config import ShapeConfig
    from repro.optim import adamw

    cfg = archs.smoke_cfg(archs.get("mamba2-780m"))
    opt_cfg = adamw.OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=100)
    shape = ShapeConfig("fl", "train", 32, 2)
    fl_mesh = jax.make_mesh((N,), ("data",))
    rel = ring(N)

    def batch_fn():
        per_node = []
        for sat in range(N):
            b = pipeline.host_batch(cfg, shape, step=0, seed=100 + sat)
            per_node.append({k: v[None] for k, v in b.items()})
        return {k: np.stack([pn[k] for pn in per_node]) for k in per_node[0]}

    batch = batch_fn()
    outs = {}
    for fused_flag in (True, False):
        fl_cfg = fl_train.FLConfig(mode="tdm", local_steps=1, fused=fused_flag)
        state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N)
        step = fl_train.build_fl_round(cfg, opt_cfg, fl_mesh, N, fl_cfg, rel)
        outs[fused_flag] = step(state, batch)
    s_f, loss_f = outs[True]
    s_l, loss_l = outs[False]
    assert np.array_equal(np.asarray(loss_f), np.asarray(loss_l))
    assert tree_equal(s_f["params"], s_l["params"])
    check(
        f"build_fl_round fused == per-leaf bit-for-bit on mamba2 smoke "
        f"(loss {float(np.mean(np.asarray(loss_f))):.3f})",
        True,
    )


def test_build_hierarchical_fl_round_end_to_end():
    from repro.configs import archs
    from repro.data import pipeline
    from repro.launch import fl_train
    from repro.models.config import ShapeConfig
    from repro.optim import adamw

    cfg = archs.smoke_cfg(archs.get("mamba2-780m"))
    opt_cfg = adamw.OptConfig(peak_lr=5e-3, warmup_steps=2, decay_steps=100)
    shape = ShapeConfig("fl", "train", 32, 2)
    mesh2 = jax.make_mesh((N_PODS, N_DATA), ("pod", "data"))
    intra = Relation.clique(list(range(N_DATA)))
    inter = ring(N_PODS)

    def batch_fn():
        per_node = []
        for sat in range(N):
            b = pipeline.host_batch(cfg, shape, step=0, seed=100 + sat)
            per_node.append({k: v[None] for k, v in b.items()})
        return {k: np.stack([pn[k] for pn in per_node]) for k in per_node[0]}

    batch = batch_fn()
    outs = {}
    for comp in ("none", "int8"):
        fl_cfg = fl_train.FLConfig(mode="tdm", local_steps=1, compression=comp)
        state = fl_train._stack_init(jax.random.PRNGKey(0), cfg, opt_cfg, N)
        step = fl_train.build_hierarchical_fl_round(
            cfg, opt_cfg, mesh2, N_PODS, N_DATA, fl_cfg, intra, inter
        )
        new_state, losses = step(state, batch)
        outs[comp] = new_state["params"]
        losses = np.asarray(losses)
        assert losses.shape == (N,) and np.all(np.isfinite(losses))
        post = fl_train.consensus_distance(outs[comp])
        assert np.isfinite(float(post))
        check(
            f"hierarchical round ({comp}) loss "
            f"{float(losses.mean()):.3f}, node spread {float(post):.2e}",
            True,
        )
    err = tree_rel_err(outs["int8"], outs["none"])
    check(f"hierarchical builder int8 vs none rel err {err:.2e}", err < 0.02)
    try:
        fl_train.build_hierarchical_fl_round(
            cfg, opt_cfg, mesh2, N_PODS, N_DATA,
            fl_train.FLConfig(mode="tdm", compression="topk"), intra, inter,
        )
        check("hierarchical builder rejects topk", False)
    except ValueError:
        check("hierarchical builder rejects topk", True)


if __name__ == "__main__":
    test_hlo_collective_counts()
    test_mixed_dtype_hlo_counts()
    test_uncompressed_bitwise()
    test_int8_tolerance()
    test_int8_pallas_matches_ref_impl()
    test_topk_pallas_matches_ref_impl()
    test_choco_fused_converges()
    test_hierarchical_fused()
    test_hierarchical_hlo_counts()
    test_build_fl_round_end_to_end()
    test_build_hierarchical_fl_round_end_to_end()
    print("ALL-OK")
