"""Multi-device serving worker: end-to-end TDM-slotted inference with the
real stacked-``shard_map`` :class:`ModelDecoder` on 8 forced host devices.
Launched as a subprocess by ``test_serving.py`` so the main pytest process
keeps its single default device.

Checks the PR's acceptance scenario: requests enter at ground stations,
route to satellite replicas over the contact graph, decode, and return on
downlink slots — all delivered within the slot budget, every hop slot-
legal under the route-provenance audit, and a mid-run dead satellite means
re-route, not loss.

Exit code 0 + final line "ALL-OK" on success.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import sys

import numpy as np

from repro.configs import archs
from repro.constellation.scenario import smoke_scenario
from repro.serving import (
    ModelDecoder,
    NullDecoder,
    ReplicaFleet,
    ServingEngine,
    audit_serving_run,
    synthesize_workload,
)

BATCH = 2
MAX_NEW = 4
N_REQUESTS = 8
REPLICAS = [0, 2, 4]


def check(name, cond):
    if not cond:
        print(f"FAIL: {name}")
        sys.exit(1)
    print(f"ok: {name}")


def run_once(decoder_factory, *, churn: bool):
    scn = smoke_scenario()
    fleet = ReplicaFleet(REPLICAS, BATCH, decoder_factory())
    eng = ServingEngine.from_scenario(scn, fleet)
    workload = synthesize_workload(
        N_REQUESTS, scn.ground_ids, rate_per_slot=1.0, max_new=MAX_NEW,
    )
    epoch = eng.epoch

    def on_slot(engine, slot):
        if not churn:
            return
        if slot == epoch // 3:
            engine.fail(REPLICAS[0])
        elif slot == epoch // 3 + max(2, epoch // 4):
            engine.restore(REPLICAS[0])

    report = eng.run(workload, on_slot=on_slot)
    verdict = audit_serving_run(
        report.records, report.requests, eng.base_rels,
        gateways=eng.gateways, replicas=REPLICAS,
    )
    return report, verdict


def test_model_decoder_end_to_end():
    cfg = archs.smoke_cfg(archs.get("gemma2-9b"))
    report, verdict = run_once(
        lambda: ModelDecoder(cfg, len(REPLICAS), BATCH, max_len=32),
        churn=False,
    )
    summ = report.summary()
    check("all requests delivered within the slot budget",
          summ["delivered"] == N_REQUESTS and summ["undelivered"] == 0)
    check("every response carries max_new tokens",
          all(len(r.out) == MAX_NEW for r in report.delivered))
    check("route-provenance audit green", verdict.ok)
    check("hops were audited", verdict.n_hops > 0)


def test_model_decoder_matches_itself():
    """Same workload, fresh decoder: token streams must be bit-identical
    (decode is deterministic given params/seed)."""
    cfg = archs.smoke_cfg(archs.get("gemma2-9b"))
    outs = []
    for _ in range(2):
        report, _ = run_once(
            lambda: ModelDecoder(cfg, len(REPLICAS), BATCH, max_len=32),
            churn=False,
        )
        outs.append({r.rid: list(r.out) for r in report.delivered})
    check("decode deterministic across runs", outs[0] == outs[1])


def test_churn_reroutes_not_loses():
    cfg = archs.smoke_cfg(archs.get("gemma2-9b"))
    report, verdict = run_once(
        lambda: ModelDecoder(cfg, len(REPLICAS), BATCH, max_len=32),
        churn=True,
    )
    summ = report.summary()
    check("dead satellite mid-run: zero lost requests",
          summ["undelivered"] == 0)
    check("churn run audit green (requeue/reemit provenance consistent)",
          verdict.ok)
    check("the failure actually drained work",
          any(r.requeued for r in report.records) or summ["retries"] >= 0)
    # the surviving replicas carried the drained wave
    check("every delivered response is complete",
          all(len(r.out) == MAX_NEW for r in report.delivered))


def test_null_vs_model_transport_invariants():
    """Transport statistics are decoder-independent when nothing churns:
    the same scenario + workload delivers the same request set over the
    same routes whether tokens come from the LCG or the model."""
    cfg = archs.smoke_cfg(archs.get("gemma2-9b"))
    rep_null, _ = run_once(
        lambda: NullDecoder(len(REPLICAS), BATCH), churn=False
    )
    rep_model, _ = run_once(
        lambda: ModelDecoder(cfg, len(REPLICAS), BATCH, max_len=32),
        churn=False,
    )
    sn, sm = rep_null.summary(), rep_model.summary()
    check("same slot count", sn["n_slots"] == sm["n_slots"])
    check("same per-request routes", all(
        (a.replica, a.hops_up, a.hops_down)
        == (b.replica, b.hops_up, b.hops_down)
        for a, b in zip(
            sorted(rep_null.delivered, key=lambda r: r.rid),
            sorted(rep_model.delivered, key=lambda r: r.rid),
        )
    ))


if __name__ == "__main__":
    np.set_printoptions(linewidth=120)
    test_model_decoder_end_to_end()
    test_model_decoder_matches_itself()
    test_churn_reroutes_not_loses()
    test_null_vs_model_transport_invariants()
    print("ALL-OK")
