"""The optimizer's executable invariants, proven on random contact plans.

The headline property: for ANY contact plan, antenna budget, payload, and
slew penalty, the rate-aware optimizer's schedule costs no more than the
greedy first-legal-coloring baseline under the analytic oracle
(`cost.schedule_cost`), and both schedules realize exactly the same
exchanges. 200 random plans — adversarial synthetic graphs, not just
well-behaved orbital geometry.
"""

import pytest

from repro.constellation import cost
from repro.constellation.contact_plan import ContactPlan, ContactSchedule
from repro.constellation.links import Link, LinkBudget
from repro.constellation.optimizer import (
    STRATEGIES,
    edge_times_s,
    mwm_peeling,
    optimize_schedule,
    order_for_overlap,
)
from repro.core.relation import Relation
from proptest import given, st_contact_plan, st_float, st_int, st_weighted_relation

PAYLOAD = 1 << 16


def _per_step_union(sched: ContactSchedule, n_steps: int):
    unions = [frozenset() for _ in range(n_steps)]
    for slot in sched.slots:
        unions[slot.t_index] = unions[slot.t_index] | slot.relation.pairs
    return unions


# ------------------------------------------------ the never-worse oracle
@pytest.mark.slow
@given(st_contact_plan(max_nodes=10, max_steps=4, p=0.5),
       st_int(1, 3), st_float(0.0, 3.0), cases=200)
def test_optimizer_never_loses_to_greedy(plan, antennas, acquisition_s):
    """schedule_cost(optimized) <= schedule_cost(greedy), same edge coverage,
    antenna budget intact — on 200 random contact plans."""
    res = optimize_schedule(
        plan, antennas=antennas, payload_bytes=PAYLOAD,
        acquisition_s=acquisition_s,
    )
    # 1. never worse under the oracle (the metric the optimizer minimizes)
    assert res.chosen.time_s <= res.baseline.time_s + 1e-9
    assert res.speedup >= 1.0 - 1e-12
    # 2. the reported cost IS the oracle cost of the returned schedule
    recomputed = cost.schedule_cost(
        res.schedule, PAYLOAD, "getmeas", acquisition_s=acquisition_s
    )
    assert recomputed.time_s == pytest.approx(res.chosen.time_s)
    # 3. same bytes shipped, same exchanges realized, per time step
    assert res.chosen.bytes_on_isl == res.baseline.bytes_on_isl
    greedy = plan.schedule(antennas=antennas, payload_bytes=PAYLOAD,
                           acquisition_s=acquisition_s)
    n_steps = len(plan.times)
    assert _per_step_union(res.schedule, n_steps) == _per_step_union(greedy, n_steps)
    # 4. the optimized schedule still honors the antenna budget
    res.schedule.tdm.validate_antennas(antennas)


@given(st_contact_plan(max_nodes=8, max_steps=3, p=0.5), cases=50)
def test_schedule_optimize_rate_wires_through(plan):
    """ContactPlan.schedule(optimize=...) returns the optimizer's winner and
    never a schedule the oracle prices above greedy."""
    greedy = plan.schedule(antennas=2, payload_bytes=PAYLOAD)
    rated = plan.schedule(antennas=2, payload_bytes=PAYLOAD, optimize="rate")
    g = cost.schedule_cost(greedy, PAYLOAD)
    r = cost.schedule_cost(rated, PAYLOAD)
    assert r.time_s <= g.time_s + 1e-9
    # greedy alias is bit-identical to the default path
    alias = plan.schedule(antennas=2, payload_bytes=PAYLOAD, optimize="greedy")
    assert [s.relation.pairs for s in alias.slots] == [
        s.relation.pairs for s in greedy.slots
    ]


@given(st_contact_plan(max_nodes=8, max_steps=3, p=0.6), st_int(1, 4), cases=50)
def test_max_slots_truncates_winner_after_full_plan_scoring(plan, max_slots):
    """Candidates are scored over the FULL plan (equal work — truncating
    before scoring would let a 'winner' look fast by skipping expensive
    exchanges); max_slots then only caps the returned winner's slots, so
    the strategy choice and costs are independent of max_slots and the
    truncated schedule is a prefix of the untruncated winner."""
    full = optimize_schedule(plan, antennas=1, payload_bytes=PAYLOAD,
                             acquisition_s=1.0)
    res = optimize_schedule(plan, antennas=1, payload_bytes=PAYLOAD,
                            acquisition_s=1.0, max_slots=max_slots)
    assert len(res.schedule) <= max_slots
    assert res.strategy == full.strategy
    assert res.costs == full.costs  # full-plan oracle costs, unaffected
    assert res.chosen.time_s <= res.baseline.time_s + 1e-9
    assert [s.relation.pairs for s in res.schedule.slots] == [
        s.relation.pairs for s in full.schedule.slots[:max_slots]
    ]


def test_colorer_and_optimize_are_mutually_exclusive():
    plan = ContactPlan(
        n_nodes=2, times=(0.0,),
        graphs=({(0, 1): Link(1000.0, 0.003, 1e6)},), step_s=60.0,
    )
    with pytest.raises(ValueError, match="mutually exclusive"):
        plan.schedule(optimize="rate", colorer=lambda r, l, b, p: [r])


def test_optimize_mode_validation_and_single_strategy():
    plan = ContactPlan(
        n_nodes=4,
        times=(0.0,),
        graphs=({(0, 1): Link(1000.0, 0.003, 1e6),
                 (2, 3): Link(2000.0, 0.006, 1e8)},),
        step_s=60.0,
    )
    with pytest.raises(ValueError, match="optimize mode"):
        optimize_schedule(plan, mode="warp")
    for name in STRATEGIES:
        res = optimize_schedule(plan, mode=name, payload_bytes=PAYLOAD)
        assert res.chosen.time_s <= res.baseline.time_s + 1e-9
        assert set(res.costs) <= set(STRATEGIES)


# ------------------------------------------------------ mwm decomposition
@given(st_weighted_relation(max_nodes=12, p=0.5), cases=100)
def test_mwm_peeling_is_partition_into_matchings(relw):
    rel, rates = relw
    matchings = mwm_peeling(rel, rates)
    for m in matchings:
        assert m.is_matching()
    all_edges = [e for m in matchings for e in m.edge_list()]
    assert sorted(all_edges) == sorted(rel.edge_list())


def test_mwm_prefers_heavy_edges_first():
    """On a path a-b-c where both edges conflict, the max-weight matching
    takes the fast edge first."""
    rel = Relation.from_edges([(0, 1), (1, 2)])
    fast_first = mwm_peeling(rel, {(0, 1): 1e9, (1, 2): 1e5})
    assert fast_first[0].pairs == Relation.from_edges([(0, 1)]).pairs


# ------------------------------------------------------------- slew model
def test_slew_penalty_charged_only_on_fresh_edges():
    """Same relation two steps running: step 1 pays acquisition, step 2's
    edges are warm and pay nothing."""
    g = {(0, 1): Link(1000.0, 0.0, 8 * PAYLOAD)}  # transfer = exactly 1 s
    plan = ContactPlan(n_nodes=2, times=(0.0, 100.0), graphs=(g, g), step_s=100.0)
    sched = plan.schedule(payload_bytes=PAYLOAD, acquisition_s=5.0)
    assert sched.slots[0].duration_s == pytest.approx(6.0)   # acq + transfer
    assert sched.slots[1].duration_s == pytest.approx(1.0)   # warm link
    est = cost.schedule_cost(sched, PAYLOAD, "getmeas", acquisition_s=5.0)
    assert est.time_s == pytest.approx(sched.busy_s)
    # and with the model off, nothing changes vs the pre-slew world
    cold = plan.schedule(payload_bytes=PAYLOAD)
    assert cold.slots[0].duration_s == pytest.approx(1.0)


def test_link_budget_slew_penalty_s():
    assert LinkBudget().slew_penalty_s() == 0.0  # agility knobs off by default
    agile = LinkBudget(slew_rate_deg_s=10.0, acquisition_s=2.0)
    assert agile.slew_penalty_s(slew_deg=90.0) == pytest.approx(11.0)
    assert agile.slew_penalty_s(slew_deg=0.0) == pytest.approx(2.0)


def test_order_for_overlap_keeps_links_warm():
    a = Relation.from_edges([(0, 1)])
    b = Relation.from_edges([(2, 3)])
    prev = Relation.from_edges([(2, 3)])
    assert order_for_overlap([a, b], prev)[0].pairs == b.pairs
    assert order_for_overlap([a, b], None)[0].pairs == a.pairs  # stable


def test_edge_times_include_propagation():
    links = {(0, 1): Link(range_km=3000.0, delay_s=0.01, rate_bps=8 * PAYLOAD)}
    times = edge_times_s(links, PAYLOAD)
    assert times[(0, 1)] == pytest.approx(1.01)
