"""Minimal hypothesis-style property-testing shim.

The container has no ``hypothesis`` wheel (offline), so this module provides
the small subset we need: ``@given`` over seeded random *strategies*, running
each property for N cases with shrink-free but reproducible failure reports
(the failing case's seed + drawn values are printed).

Usage::

    @given(st_relation(max_nodes=12), st_int(1, 5), cases=200)
    def test_prop(rel, k):
        ...
"""

from __future__ import annotations

import functools
import random
from typing import Any, Callable, List, Sequence, Tuple

import numpy as np

DEFAULT_CASES = 100


class Strategy:
    def __init__(self, draw: Callable[[random.Random], Any], name: str = "st"):
        self._draw = draw
        self.name = name

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)), f"{self.name}.map")


def st_int(lo: int, hi: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(lo, hi), f"int[{lo},{hi}]")


def st_float(lo: float, hi: float) -> Strategy:
    return Strategy(lambda rng: rng.uniform(lo, hi), f"float[{lo},{hi}]")


def st_bool() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5, "bool")


def st_choice(options: Sequence[Any]) -> Strategy:
    opts = list(options)
    return Strategy(lambda rng: opts[rng.randrange(len(opts))], "choice")


def st_array(shape_st: Strategy, lo: float = -2.0, hi: float = 2.0) -> Strategy:
    def draw(rng: random.Random) -> np.ndarray:
        shape = shape_st.draw(rng)
        np_rng = np.random.default_rng(rng.randrange(2**31))
        return np_rng.uniform(lo, hi, size=shape).astype(np.float32)

    return Strategy(draw, "array")


def st_shape(max_rank: int = 2, max_dim: int = 16) -> Strategy:
    def draw(rng: random.Random) -> Tuple[int, ...]:
        rank = rng.randint(1, max_rank)
        return tuple(rng.randint(1, max_dim) for _ in range(rank))

    return Strategy(draw, "shape")


def st_edges(max_nodes: int = 12, p: float = 0.4) -> Strategy:
    """Random undirected simple graph edge list on nodes 0..n-1 (n >= 2)."""

    def draw(rng: random.Random) -> Tuple[int, List[Tuple[int, int]]]:
        n = rng.randint(2, max_nodes)
        edges = [
            (i, j)
            for i in range(n)
            for j in range(i + 1, n)
            if rng.random() < p
        ]
        return n, edges

    return Strategy(draw, "edges")


def st_relation(max_nodes: int = 12, p: float = 0.4) -> Strategy:
    """Random valid exchange relation (symmetric, anti-reflexive)."""
    from repro.core.relation import Relation

    def draw(rng: random.Random):
        n, edges = st_edges(max_nodes, p).draw(rng)
        return Relation.from_edges(edges, nodes=range(n))

    return Strategy(draw, "relation")


def given(*strategies: Strategy, cases: int = DEFAULT_CASES, seed: int = 0):
    """Run the wrapped property for ``cases`` seeded random draws."""

    def deco(fn):
        # NOTE: no functools.wraps — pytest would introspect the wrapped
        # signature and demand fixtures for the strategy parameters.
        def wrapper(*args, **kwargs):
            for case in range(cases):
                rng = random.Random((seed << 20) ^ case)
                drawn = [s.draw(rng) for s in strategies]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception:
                    print(
                        f"\nproptest: case {case} FAILED "
                        f"(seed={(seed << 20) ^ case})\ndrawn values:"
                    )
                    for s, v in zip(strategies, drawn):
                        print(f"  {s.name} = {v!r}")
                    raise

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
