"""Minimal hypothesis-style property-testing shim.

The container has no ``hypothesis`` wheel (offline), so this module provides
the small subset we need: ``@given`` over seeded random *strategies*, running
each property for N cases with shrink-free but reproducible failure reports
(the failing case's seed + drawn values are printed).

Usage::

    @given(st_relation(max_nodes=12), st_int(1, 5), cases=200)
    def test_prop(rel, k):
        ...
"""

from __future__ import annotations

import functools
import math
import random
from typing import Any, Callable, List, Sequence, Tuple

import numpy as np

DEFAULT_CASES = 100


class Strategy:
    def __init__(self, draw: Callable[[random.Random], Any], name: str = "st"):
        self._draw = draw
        self.name = name

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)), f"{self.name}.map")


def st_int(lo: int, hi: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(lo, hi), f"int[{lo},{hi}]")


def st_float(lo: float, hi: float) -> Strategy:
    return Strategy(lambda rng: rng.uniform(lo, hi), f"float[{lo},{hi}]")


def st_bool() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5, "bool")


def st_choice(options: Sequence[Any]) -> Strategy:
    opts = list(options)
    return Strategy(lambda rng: opts[rng.randrange(len(opts))], "choice")


def st_array(shape_st: Strategy, lo: float = -2.0, hi: float = 2.0) -> Strategy:
    def draw(rng: random.Random) -> np.ndarray:
        shape = shape_st.draw(rng)
        np_rng = np.random.default_rng(rng.randrange(2**31))
        return np_rng.uniform(lo, hi, size=shape).astype(np.float32)

    return Strategy(draw, "array")


def st_shape(max_rank: int = 2, max_dim: int = 16) -> Strategy:
    def draw(rng: random.Random) -> Tuple[int, ...]:
        rank = rng.randint(1, max_rank)
        return tuple(rng.randint(1, max_dim) for _ in range(rank))

    return Strategy(draw, "shape")


def st_edges(max_nodes: int = 12, p: float = 0.4) -> Strategy:
    """Random undirected simple graph edge list on nodes 0..n-1 (n >= 2)."""

    def draw(rng: random.Random) -> Tuple[int, List[Tuple[int, int]]]:
        n = rng.randint(2, max_nodes)
        edges = [
            (i, j)
            for i in range(n)
            for j in range(i + 1, n)
            if rng.random() < p
        ]
        return n, edges

    return Strategy(draw, "edges")


def st_relation(max_nodes: int = 12, p: float = 0.4) -> Strategy:
    """Random valid exchange relation (symmetric, anti-reflexive)."""
    from repro.core.relation import Relation

    def draw(rng: random.Random):
        n, edges = st_edges(max_nodes, p).draw(rng)
        return Relation.from_edges(edges, nodes=range(n))

    return Strategy(draw, "relation")


def st_weighted_relation(
    max_nodes: int = 12,
    p: float = 0.4,
    lo: float = 1e5,
    hi: float = 1e9,
) -> Strategy:
    """(relation, {undirected edge: weight}) with log-uniform weights —
    in family with the dynamic range of ISL link rates/transfer times."""

    def draw(rng: random.Random):
        rel = st_relation(max_nodes, p).draw(rng)
        weights = {
            e: math.exp(rng.uniform(math.log(lo), math.log(hi)))
            for e in rel.edge_list()
        }
        return rel, weights

    return Strategy(draw, "weighted_relation")


def st_contact_plan(
    max_nodes: int = 10,
    max_steps: int = 4,
    p: float = 0.5,
) -> Strategy:
    """Random synthetic :class:`ContactPlan`: random per-step visibility
    graphs with log-uniform link rates and geometry-plausible delays. Much
    cheaper than orbital propagation, and adversarial in ways real geometry
    is not (steps can share no edges at all)."""

    def draw(rng: random.Random):
        from repro.constellation.contact_plan import ContactPlan
        from repro.constellation.links import Link

        n = rng.randint(2, max_nodes)
        n_steps = rng.randint(1, max_steps)
        step_s = rng.uniform(10.0, 120.0)
        graphs = []
        for _ in range(n_steps):
            g = {}
            for i in range(n):
                for j in range(i + 1, n):
                    if rng.random() < p:
                        rate = 10.0 ** rng.uniform(5.0, 9.0)
                        rng_km = rng.uniform(100.0, 5000.0)
                        g[(i, j)] = Link(
                            range_km=rng_km,
                            delay_s=rng_km / 299_792.458,
                            rate_bps=rate,
                        )
            graphs.append(g)
        return ContactPlan(
            n_nodes=n,
            times=tuple(t * step_s for t in range(n_steps)),
            graphs=tuple(graphs),
            step_s=step_s,
        )

    return Strategy(draw, "contact_plan")


def given(*strategies: Strategy, cases: int = DEFAULT_CASES, seed: int = 0):
    """Run the wrapped property for ``cases`` seeded random draws."""

    def deco(fn):
        # NOTE: no functools.wraps — pytest would introspect the wrapped
        # signature and demand fixtures for the strategy parameters.
        def wrapper(*args, **kwargs):
            for case in range(cases):
                rng = random.Random((seed << 20) ^ case)
                drawn = [s.draw(rng) for s in strategies]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception:
                    print(
                        f"\nproptest: case {case} FAILED "
                        f"(seed={(seed << 20) ^ case})\ndrawn values:"
                    )
                    for s, v in zip(strategies, drawn):
                        print(f"  {s.name} = {v!r}")
                    raise

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
