"""Tests for TDM schedules: round-robin, edge coloring, antenna budgets,
geometry-driven propagation, hypercube gossip."""


import pytest

from repro.core.relation import Relation
from repro.core.schedule import (
    TDMSchedule,
    antenna_constrained,
    clique_multilink,
    edge_coloring,
    greedy_edge_coloring,
    hypercube_schedule,
    pack_matchings,
    ring,
    round_robin_tournament,
    weighted_edge_coloring,
)
from repro.core.gossip import propagation_closure, slots_to_full_propagation
from proptest import given, st_relation, st_int, st_weighted_relation


# ------------------------------------------------------- round robin (paper)
@pytest.mark.parametrize("n", [2, 3, 4, 5, 8, 13, 20])
def test_round_robin_covers_clique_exactly_once(n):
    """The get1meas evaluation schedule: K_n decomposed into matchings, every
    unordered pair exactly once."""
    sched = round_robin_tournament(n)
    expected_slots = n - 1 if n % 2 == 0 else n
    assert len(sched) == expected_slots
    seen = []
    for rel in sched:
        assert rel.is_matching()  # pairwise only — get1meas constraint
        seen.extend(rel.edge_list())
    assert sorted(seen) == sorted(
        (i, j) for i in range(n) for j in range(i + 1, n)
    )


def test_clique_multilink_single_slot():
    """The getMeas evaluation schedule: whole clique in ONE slot."""
    sched = clique_multilink(8)
    assert len(sched) == 1
    assert sched[0].max_degree() == 7  # 7 simultaneous links per node
    assert sched.max_antennas() == 7


@pytest.mark.parametrize("n", [4, 6, 10])
def test_round_robin_vs_multilink_same_union(n):
    """Semantically equivalent schedules (paper §IV): same exchanges overall."""
    rr = round_robin_tournament(n)
    ml = clique_multilink(n)
    assert rr.union().pairs == ml.union().pairs


# ---------------------------------------------------------- edge coloring
@given(st_relation(max_nodes=14, p=0.5), cases=200)
def test_edge_coloring_is_partition_into_matchings(rel):
    matchings = edge_coloring(rel)
    for m in matchings:
        assert m.is_matching()
    # every edge exactly once
    all_edges = [e for m in matchings for e in m.edge_list()]
    assert sorted(all_edges) == sorted(rel.edge_list())


@given(st_relation(max_nodes=14, p=0.5), cases=200)
def test_edge_coloring_vizing_bound(rel):
    """Misra–Gries uses at most Δ+1 colors (Vizing's theorem)."""
    matchings = edge_coloring(rel)
    assert len(matchings) <= rel.max_degree() + 1


@given(st_relation(max_nodes=12, p=0.6), cases=100)
def test_edge_coloring_matches_networkx_validity(rel):
    """Cross-check against networkx: our coloring is a proper edge coloring
    (no two adjacent edges share a color class)."""
    import networkx as nx

    G = nx.Graph(rel.edge_list())
    matchings = edge_coloring(rel)
    for m in matchings:
        edges = m.edge_list()
        used = set()
        for (u, v) in edges:
            assert u not in used and v not in used
            used.update((u, v))
    # sanity: number of classes is >= chromatic index lower bound Δ
    if rel.edge_list():
        assert len(matchings) >= max(dict(G.degree).values())


def test_clique_coloring_sizes():
    """Even cliques use the optimal circle-method decomposition (n-1
    matchings); odd cliques get Vizing's Δ+1 = n."""
    for n, expect in [(4, 3), (6, 5), (8, 7), (5, 5), (7, 7)]:
        rel = Relation.clique(list(range(n)))
        got = edge_coloring(rel)
        assert len(got) == expect
        for m in got:
            assert m.is_matching()
        assert sorted(e for m in got for e in m.edge_list()) == sorted(rel.edge_list())


@given(st_relation(max_nodes=14, p=0.5), cases=200)
def test_greedy_coloring_valid_fallback(rel):
    matchings = greedy_edge_coloring(rel)
    for m in matchings:
        assert m.is_matching()
    all_edges = [e for m in matchings for e in m.edge_list()]
    assert sorted(all_edges) == sorted(rel.edge_list())
    assert len(matchings) <= max(2 * rel.max_degree() - 1, 0) or not all_edges


@given(st_weighted_relation(max_nodes=14, p=0.5), cases=200)
def test_weighted_coloring_is_partition_into_matchings(relw):
    """Rate-aware coloring keeps the structural invariants of the rate-blind
    one: every color class a matching, classes partition the edge set, class
    count within the greedy 2Δ-1 bound."""
    rel, weights = relw
    matchings = weighted_edge_coloring(rel, weights)
    for m in matchings:
        assert m.is_matching()
    all_edges = [e for m in matchings for e in m.edge_list()]
    assert sorted(all_edges) == sorted(rel.edge_list())
    assert len(matchings) <= max(2 * rel.max_degree() - 1, 0) or not all_edges


@given(st_weighted_relation(max_nodes=14, p=0.5), cases=200)
def test_weighted_coloring_groups_slowest_first(relw):
    """The globally slowest edge anchors the first color class, and class
    bottlenecks never increase down the list (slow edges share classes, so
    fast edges are not held hostage by a straggler)."""
    rel, weights = relw
    matchings = weighted_edge_coloring(rel, weights)
    if not matchings:
        return
    bottlenecks = [max(weights[e] for e in m.edge_list()) for m in matchings]
    assert bottlenecks[0] == max(weights.values())
    assert all(a >= b for a, b in zip(bottlenecks, bottlenecks[1:]))


@given(st_weighted_relation(max_nodes=10, p=0.5), st_int(1, 4), cases=200)
def test_pack_matchings_respects_budget_and_covers(relw, budget):
    """First-fit packing of any matching decomposition stays inside the
    antenna budget and loses no edges, regardless of the matching order."""
    rel, weights = relw
    antennas = {v: budget for v in rel.nodes}
    packed = pack_matchings(weighted_edge_coloring(rel, weights), antennas, rel.nodes)
    union = Relation.empty(rel.nodes)
    for slot in packed:
        for v in slot.participants():
            assert slot.degree(v) <= budget
        union = union | slot
    assert union.pairs == rel.pairs


# ------------------------------------------------------- antenna budgets
@given(st_relation(max_nodes=10, p=0.5), st_int(1, 4), cases=100)
def test_antenna_constrained_respects_budget(rel, budget):
    antennas = {v: budget for v in rel.nodes}
    sched = antenna_constrained(rel, antennas)
    for slot in sched:
        for v in slot.participants():
            assert slot.degree(v) <= budget
    assert sched.union().pairs == rel.pairs


def test_antenna_constrained_zero_antenna_node_raises():
    """A node with edges but no antennas cannot realize any exchange —
    the scheduler refuses instead of silently over-subscribing."""
    rel = Relation.clique([0, 1, 2, 3])
    with pytest.raises(ValueError, match="no antennas"):
        antenna_constrained(rel, {0: 3, 1: 0, 2: 2, 3: 1})


def test_antenna_constrained_zero_antenna_isolated_node_ok():
    """Zero antennas is fine for a node with no edges (occluded satellite)."""
    rel = Relation.from_edges([(0, 1)], nodes=range(3))
    sched = antenna_constrained(rel, {0: 1, 1: 1, 2: 0})
    assert sched.union().pairs == rel.pairs


def test_edge_coloring_empty_relation():
    assert edge_coloring(Relation.empty()) == []
    assert edge_coloring(Relation.empty(range(5))) == []


def test_heterogeneous_antennas():
    """Paper §I: different satellites may have different numbers of antennas."""
    rel = Relation.clique([0, 1, 2, 3])
    antennas = {0: 3, 1: 1, 2: 2, 3: 1}
    sched = antenna_constrained(rel, antennas)
    for slot in sched:
        for v in slot.participants():
            assert slot.degree(v) <= antennas[v]
    assert sched.union().pairs == rel.pairs


# -------------------------------------------------------------- walker
def test_walker_shim_removed():
    """The duty-cycle toy is gone: importing it fails hard, with a pointer
    at the scenario factory (geometry-driven schedules)."""
    import repro.core.schedule as schedule_mod

    with pytest.raises(ImportError, match="build_scenario"):
        schedule_mod.WalkerConstellation
    with pytest.raises(AttributeError):
        schedule_mod.some_other_missing_name


def test_geometry_schedule_fully_propagates():
    """Over enough slots of a real geometry-driven schedule, every
    satellite's data reaches the whole constellation (paper P2 composed
    across slots) — the property the removed toy used to cover."""
    from repro.constellation.scenario import ScenarioSpec, ShellSpec, build_scenario

    scn = build_scenario(
        ScenarioSpec(shells=(ShellSpec(planes=4, per_plane=6),), n_ground=0,
                     steps=24)
    )
    rels = scn.relations()
    t = slots_to_full_propagation(lambda t: rels[t % len(rels)], scn.n_sats)
    assert 0 < t <= 24


# ------------------------------------------------------ ring / hypercube
def test_ring_relation():
    r = ring(8)
    assert r.is_valid_exchange()
    assert all(r.degree(v) == 2 for v in range(8))


@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_hypercube_full_propagation_in_log_n(n):
    sched = hypercube_schedule(n)
    assert len(sched) == n.bit_length() - 1
    reach = propagation_closure(sched, n)
    assert reach.all()  # log2(n) slots suffice — optimal gossip


def test_hypercube_requires_power_of_two():
    with pytest.raises(ValueError):
        hypercube_schedule(6)


# ------------------------------------------------------- schedule object
def test_schedule_validates_slots():
    with pytest.raises(ValueError):
        TDMSchedule((Relation.from_pairs([(0, 1)]),))  # one-sided pair


def test_schedule_restrict_after_failure():
    """Node failure: surviving schedule stays valid (paper skip-slot)."""
    sched = round_robin_tournament(6)
    surv = sched.restrict([0, 1, 2, 4])
    for slot in surv:
        assert slot.is_valid_exchange() or len(slot) == 0
        assert 3 not in slot.participants() and 5 not in slot.participants()


def test_schedule_restrict_all_nodes_dead():
    """Total failure degenerates to a valid schedule of empty slots — the
    skip-slot semantics taken to the limit, not an error."""
    sched = round_robin_tournament(6)
    dead = sched.restrict([])
    assert len(dead) == len(sched)
    for slot in dead:
        assert len(slot) == 0
        assert slot.is_valid_exchange()
        assert slot.participants() == set()
    assert dead.max_antennas() == 0
    assert dead.union().pairs == frozenset()


def test_validate_antennas_accepts_and_rejects():
    sched = TDMSchedule((Relation.clique([0, 1, 2, 3]),))
    assert sched.validate_antennas(3) is sched
    with pytest.raises(ValueError, match="slot 0: node"):
        sched.validate_antennas(2)
    # dict budgets default to 1 antenna for unlisted nodes
    with pytest.raises(ValueError, match="has 1 antennas"):
        sched.validate_antennas({0: 3, 1: 3, 2: 3})


@given(st_relation(max_nodes=10, p=0.5), st_int(1, 3), cases=100)
def test_restrict_preserves_antenna_validity(rel, budget):
    """Regression (optimizer PR): restriction only shrinks degrees, so a
    schedule valid for a budget stays valid — validate_antennas must agree
    on every restricted suffix of the node set."""
    antennas = {v: budget for v in rel.nodes}
    sched = antenna_constrained(rel, antennas)
    alive = [v for v in sorted(rel.nodes) if v % 2 == 0]
    surv = sched.restrict(alive)
    surv.validate_antennas(budget)  # must not raise
    assert surv.union().pairs == rel.restrict(alive).pairs


def test_restrict_optimized_contact_schedule_revalidates():
    """Regression (previously uncovered): restricting an *optimized*
    ContactSchedule must rebuild per-slot metadata — dead edges dropped from
    ``links``, bottleneck rates recomputed, tdm/slots kept aligned — and
    re-validate the antenna budget. ``TDMSchedule.restrict`` alone left the
    ContactSchedule's slot metadata stale."""
    from repro.constellation.contact_plan import ContactPlan
    from repro.constellation.links import Link

    graphs = []
    for t in range(3):
        g = {}
        for i in range(6):
            for j in range(i + 1, 6):
                if (i + j + t) % 2 == 0:
                    g[(i, j)] = Link(
                        range_km=1000.0 * (1 + i),
                        delay_s=0.003 * (1 + i),
                        rate_bps=1e6 * (1 + j),
                    )
        graphs.append(g)
    plan = ContactPlan(
        n_nodes=6, times=(0.0, 60.0, 120.0), graphs=tuple(graphs), step_s=60.0
    )
    sched = plan.schedule(antennas=2, payload_bytes=1 << 16,
                          optimize="rate", acquisition_s=0.5)
    alive = {0, 1, 2, 4}
    surv = sched.restrict(alive, antennas=2)
    assert len(surv.tdm) == len(surv.slots)  # alignment re-validated
    for slot in surv.slots:
        assert alive.issuperset(slot.relation.participants())
        # metadata rebuilt from surviving links only
        assert set(slot.links) == set(slot.relation.edge_list())
        assert slot.min_rate_bps == min(l.rate_bps for l in slot.links.values())
        assert slot.max_delay_s == max(l.delay_s for l in slot.links.values())
        assert len(slot.relation) > 0  # empty slots dropped
    surv.tdm.validate_antennas(2)  # must not raise
    # union of surviving slots == restriction of the original union
    merged = Relation.empty(range(6))
    for r in surv.tdm:
        merged = merged | r
    assert merged.pairs == sched.tdm.restrict(alive).union().pairs
