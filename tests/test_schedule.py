"""Tests for TDM schedules: round-robin, edge coloring, antenna budgets,
Walker constellations, hypercube gossip."""

import itertools

import numpy as np
import pytest

from repro.core.relation import Relation
from repro.core.schedule import (
    TDMSchedule,
    WalkerConstellation,
    antenna_constrained,
    clique_multilink,
    edge_coloring,
    greedy_edge_coloring,
    hypercube_schedule,
    ring,
    round_robin_tournament,
)
from repro.core.gossip import propagation_closure, slots_to_full_propagation
from proptest import given, st_relation, st_int


# ------------------------------------------------------- round robin (paper)
@pytest.mark.parametrize("n", [2, 3, 4, 5, 8, 13, 20])
def test_round_robin_covers_clique_exactly_once(n):
    """The get1meas evaluation schedule: K_n decomposed into matchings, every
    unordered pair exactly once."""
    sched = round_robin_tournament(n)
    expected_slots = n - 1 if n % 2 == 0 else n
    assert len(sched) == expected_slots
    seen = []
    for rel in sched:
        assert rel.is_matching()  # pairwise only — get1meas constraint
        seen.extend(rel.edge_list())
    assert sorted(seen) == sorted(
        (i, j) for i in range(n) for j in range(i + 1, n)
    )


def test_clique_multilink_single_slot():
    """The getMeas evaluation schedule: whole clique in ONE slot."""
    sched = clique_multilink(8)
    assert len(sched) == 1
    assert sched[0].max_degree() == 7  # 7 simultaneous links per node
    assert sched.max_antennas() == 7


@pytest.mark.parametrize("n", [4, 6, 10])
def test_round_robin_vs_multilink_same_union(n):
    """Semantically equivalent schedules (paper §IV): same exchanges overall."""
    rr = round_robin_tournament(n)
    ml = clique_multilink(n)
    assert rr.union().pairs == ml.union().pairs


# ---------------------------------------------------------- edge coloring
@given(st_relation(max_nodes=14, p=0.5), cases=200)
def test_edge_coloring_is_partition_into_matchings(rel):
    matchings = edge_coloring(rel)
    for m in matchings:
        assert m.is_matching()
    # every edge exactly once
    all_edges = [e for m in matchings for e in m.edge_list()]
    assert sorted(all_edges) == sorted(rel.edge_list())


@given(st_relation(max_nodes=14, p=0.5), cases=200)
def test_edge_coloring_vizing_bound(rel):
    """Misra–Gries uses at most Δ+1 colors (Vizing's theorem)."""
    matchings = edge_coloring(rel)
    assert len(matchings) <= rel.max_degree() + 1


@given(st_relation(max_nodes=12, p=0.6), cases=100)
def test_edge_coloring_matches_networkx_validity(rel):
    """Cross-check against networkx: our coloring is a proper edge coloring
    (no two adjacent edges share a color class)."""
    import networkx as nx

    G = nx.Graph(rel.edge_list())
    matchings = edge_coloring(rel)
    for m in matchings:
        edges = m.edge_list()
        used = set()
        for (u, v) in edges:
            assert u not in used and v not in used
            used.update((u, v))
    # sanity: number of classes is >= chromatic index lower bound Δ
    if rel.edge_list():
        assert len(matchings) >= max(dict(G.degree).values())


def test_clique_coloring_sizes():
    """Even cliques use the optimal circle-method decomposition (n-1
    matchings); odd cliques get Vizing's Δ+1 = n."""
    for n, expect in [(4, 3), (6, 5), (8, 7), (5, 5), (7, 7)]:
        rel = Relation.clique(list(range(n)))
        got = edge_coloring(rel)
        assert len(got) == expect
        for m in got:
            assert m.is_matching()
        assert sorted(e for m in got for e in m.edge_list()) == sorted(rel.edge_list())


@given(st_relation(max_nodes=12, p=0.5), cases=100)
def test_greedy_coloring_valid_fallback(rel):
    matchings = greedy_edge_coloring(rel)
    for m in matchings:
        assert m.is_matching()
    all_edges = [e for m in matchings for e in m.edge_list()]
    assert sorted(all_edges) == sorted(rel.edge_list())
    assert len(matchings) <= max(2 * rel.max_degree() - 1, 0) or not all_edges


# ------------------------------------------------------- antenna budgets
@given(st_relation(max_nodes=10, p=0.5), st_int(1, 4), cases=100)
def test_antenna_constrained_respects_budget(rel, budget):
    antennas = {v: budget for v in rel.nodes}
    sched = antenna_constrained(rel, antennas)
    for slot in sched:
        for v in slot.participants():
            assert slot.degree(v) <= budget
    assert sched.union().pairs == rel.pairs


def test_antenna_constrained_zero_antenna_node_raises():
    """A node with edges but no antennas cannot realize any exchange —
    the scheduler refuses instead of silently over-subscribing."""
    rel = Relation.clique([0, 1, 2, 3])
    with pytest.raises(ValueError, match="no antennas"):
        antenna_constrained(rel, {0: 3, 1: 0, 2: 2, 3: 1})


def test_antenna_constrained_zero_antenna_isolated_node_ok():
    """Zero antennas is fine for a node with no edges (occluded satellite)."""
    rel = Relation.from_edges([(0, 1)], nodes=range(3))
    sched = antenna_constrained(rel, {0: 1, 1: 1, 2: 0})
    assert sched.union().pairs == rel.pairs


def test_edge_coloring_empty_relation():
    assert edge_coloring(Relation.empty()) == []
    assert edge_coloring(Relation.empty(range(5))) == []


def test_heterogeneous_antennas():
    """Paper §I: different satellites may have different numbers of antennas."""
    rel = Relation.clique([0, 1, 2, 3])
    antennas = {0: 3, 1: 1, 2: 2, 3: 1}
    sched = antenna_constrained(rel, antennas)
    for slot in sched:
        for v in slot.participants():
            assert slot.degree(v) <= antennas[v]
    assert sched.union().pairs == rel.pairs


# -------------------------------------------------------------- walker
# (the shim is deprecated by design; these tests exercise it deliberately)
pytestmark_walker = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytestmark_walker
def test_walker_visibility_valid_and_connected():
    c = WalkerConstellation(total=24, planes=4)
    for t in range(12):
        rel = c.visibility(t)
        assert rel.is_valid_exchange()
        # intra-plane ring edges are permanent
        for p in range(c.planes):
            for k in range(c.per_plane):
                assert (c.node_id(p, k), c.node_id(p, k + 1)) in rel


@pytestmark_walker
def test_walker_schedule_fully_propagates():
    """Over enough slots, every satellite's data reaches the whole
    constellation (paper P2 composed across slots)."""
    c = WalkerConstellation(total=24, planes=4)
    t = slots_to_full_propagation(lambda t: c.visibility(t), c.total)
    assert 0 < t <= 24


@pytestmark_walker
def test_walker_cross_plane_duty_cycle():
    c = WalkerConstellation(total=24, planes=4)
    r0 = c.visibility(0, cross_plane_duty=4)
    r1 = c.visibility(1, cross_plane_duty=4)
    assert r0.pairs != r1.pairs  # time-varying topology


# ------------------------------------------------------ ring / hypercube
def test_ring_relation():
    r = ring(8)
    assert r.is_valid_exchange()
    assert all(r.degree(v) == 2 for v in range(8))


@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_hypercube_full_propagation_in_log_n(n):
    sched = hypercube_schedule(n)
    assert len(sched) == n.bit_length() - 1
    reach = propagation_closure(sched, n)
    assert reach.all()  # log2(n) slots suffice — optimal gossip


def test_hypercube_requires_power_of_two():
    with pytest.raises(ValueError):
        hypercube_schedule(6)


# ------------------------------------------------------- schedule object
def test_schedule_validates_slots():
    with pytest.raises(ValueError):
        TDMSchedule((Relation.from_pairs([(0, 1)]),))  # one-sided pair


def test_schedule_restrict_after_failure():
    """Node failure: surviving schedule stays valid (paper skip-slot)."""
    sched = round_robin_tournament(6)
    surv = sched.restrict([0, 1, 2, 4])
    for slot in surv:
        assert slot.is_valid_exchange() or len(slot) == 0
        assert 3 not in slot.participants() and 5 not in slot.participants()


def test_schedule_restrict_all_nodes_dead():
    """Total failure degenerates to a valid schedule of empty slots — the
    skip-slot semantics taken to the limit, not an error."""
    sched = round_robin_tournament(6)
    dead = sched.restrict([])
    assert len(dead) == len(sched)
    for slot in dead:
        assert len(slot) == 0
        assert slot.is_valid_exchange()
        assert slot.participants() == set()
    assert dead.max_antennas() == 0
    assert dead.union().pairs == frozenset()
