"""Property tests for the paper's §II theoretical foundation (P1–P5)."""


import pytest

from repro.core.relation import Relation
from proptest import given, st_relation, st_int


# ---------------------------------------------------------------- examples
def test_paper_example_r1():
    """R1 = {(a,b),(b,a)} — the simplest possible R (paper §II)."""
    a, b = 0, 1
    r1 = Relation.from_pairs([(a, b), (b, a)])
    assert r1.is_valid_exchange()
    assert r1.peers_of(a) == [b] and r1.peers_of(b) == [a]
    assert r1.is_matching()


def test_paper_example_r2():
    """R2: b simultaneously exchanges with a and c; a, c only with b."""
    a, b, c = 0, 1, 2
    r2 = Relation.from_pairs([(a, b), (b, a), (b, c), (c, b)])
    assert r2.is_valid_exchange()
    assert r2.degree(b) == 2  # b needs two "pairs of hands" = two antennas
    assert r2.degree(a) == 1 and r2.degree(c) == 1
    assert not r2.is_matching()  # beyond get1meas — needs the new algorithm


def test_paper_example_r3_clique():
    """R3: each instance has a pair of hands for each other instance."""
    r3 = Relation.clique([0, 1, 2])
    assert r3.is_valid_exchange()
    assert len(r3) == 6  # all ordered pairs
    assert r3.edges() == {frozenset({0, 1}), frozenset({0, 2}), frozenset({1, 2})}


def test_paper_propagation_example():
    """Paper §II.B: R21={(a,b),(b,a)}, R22={(b,c),(c,b)} =>
    R21∘R22={(a,c)}, R22∘R21={(c,a)}, union is a valid R23."""
    a, b, c = 0, 1, 2
    r21 = Relation.from_pairs([(a, b), (b, a)])
    r22 = Relation.from_pairs([(b, c), (c, b)])
    comp = r21.compose(r22)
    assert set(comp.pairs) == {(a, c)}
    comp_rev = r22.compose(r21)
    assert set(comp_rev.pairs) == {(c, a)}
    r23 = r21.propagation(r22)
    assert r23.is_valid_exchange()
    assert set(r23.pairs) == {(a, c), (c, a)}


def test_invalid_relations_rejected():
    with pytest.raises(ValueError):
        Relation.from_pairs([(0, 1)]).validate()  # one-sided
    with pytest.raises(ValueError):
        Relation.from_pairs([(0, 0)]).validate()  # reflexive
    with pytest.raises(ValueError):
        Relation.from_edges([(1, 1)])  # self-edge


# ------------------------------------------------------------- properties
@given(st_relation(max_nodes=14), cases=150)
def test_p1_inverse_equals_self(rel):
    """P1: R⁻¹ = R."""
    assert rel.inverse().pairs == rel.pairs


@given(st_relation(max_nodes=10), st_relation(max_nodes=10), cases=100)
def test_p2_propagation_is_valid_exchange(r1, r2):
    """P2: R1∘R2 ∪ R2∘R1 is a valid exchange relation."""
    out = r1.propagation(r2)
    assert out.is_symmetric()
    assert out.is_antireflexive()


@given(st_relation(max_nodes=8), st_relation(max_nodes=8), st_relation(max_nodes=8), cases=60)
def test_p2_composition_associative(r1, r2, r3):
    """Composition of relations is associative (paper §II.B)."""
    # NOTE: Relation.compose drops self-pairs at each stage (exchange
    # semantics); compare against raw relational composition on pairs.
    def raw_compose(p1, p2):
        by_src = {}
        for b, c in p2:
            by_src.setdefault(b, set()).add(c)
        return {(a, c) for a, b in p1 for c in by_src.get(b, ())}

    raw_l = raw_compose(raw_compose(rel_pairs(r1), rel_pairs(r2)), rel_pairs(r3))
    raw_r = raw_compose(rel_pairs(r1), raw_compose(rel_pairs(r2), rel_pairs(r3)))
    assert raw_l == raw_r


def rel_pairs(r):
    return set(r.pairs)


@given(st_relation(max_nodes=14), cases=150)
def test_p3_special_properties(rel):
    """P3: R is not reflexive (unless empty), symmetric, and (4) not
    anti-symmetric whenever non-empty."""
    assert rel.is_symmetric()
    assert rel.is_antireflexive()
    if len(rel) > 0:
        assert not rel.is_reflexive()
        assert not rel.is_antisymmetric()


def test_p3_transitivity_counterexample():
    """R is not transitive in general: aRb, bRa but not aRa (anti-reflexive)."""
    r = Relation.from_edges([(0, 1)])
    assert not r.is_transitive() or len(r) == 0


@given(st_relation(max_nodes=14), cases=150)
def test_p4_symmetric_closure_is_self(rel):
    """P4: R is its own symmetric closure."""
    assert rel.symmetric_closure().pairs == rel.pairs


@given(st_relation(max_nodes=14), cases=150)
def test_p5_graph_representation_roundtrip(rel):
    """P5: R <-> G(V,E) is a bijection for symmetric anti-reflexive R."""
    edges = rel.edge_list()
    back = Relation.from_edges(edges, nodes=rel.nodes)
    assert back.pairs == rel.pairs
    # |R| = 2|E|
    assert len(rel) == 2 * len(edges)


@given(st_relation(max_nodes=14), st_int(0, 13), cases=100)
def test_degree_equals_antenna_count(rel, node):
    """degree(v) = number of simultaneous links = antennas used (paper §I:
    'the number of peers is less or equal to the number of antennas')."""
    peers = rel.peers_of(node)
    assert rel.degree(node) == len(peers)
    assert all((node, p) in rel and (p, node) in rel for p in peers)


@given(st_relation(max_nodes=12), cases=100)
def test_restrict_drops_failed_nodes(rel):
    """Fault-tolerance primitive: restricting to alive nodes keeps validity
    and removes every pair touching a dead node."""
    nodes = sorted(rel.nodes)
    if not nodes:
        return
    dead = set(nodes[:: max(1, len(nodes) // 3)][:2])
    alive = set(nodes) - dead
    res = rel.restrict(alive)
    assert res.is_valid_exchange() or len(res) == 0
    assert all(i in alive and j in alive for i, j in res.pairs)
    # pairs fully inside the alive set survive
    for (i, j) in rel.pairs:
        if i in alive and j in alive:
            assert (i, j) in res


@given(st_relation(max_nodes=12), cases=80)
def test_adjacency_symmetric(rel):
    n = (max(rel.nodes) + 1) if rel.nodes else 0
    A = rel.adjacency(n)
    assert (A == A.T).all()
    assert not A.diagonal().any()
