"""Launcher for the multi-device sim<->collective equivalence suite.

The worker needs 8 forced host devices (XLA_FLAGS is locked at first jax
init), so it runs in a subprocess; this keeps every other test on the
default single device as required.
"""

import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_multidevice_equivalence_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT / 'tests'}:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_multidevice_worker.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    assert proc.returncode == 0, "worker failed"
    assert "ALL-OK" in proc.stdout
