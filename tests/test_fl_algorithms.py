"""Tests for the three generic FLAs in simulator (oracle) form, including
FL convergence: centralized == decentralized == TDM consensus on averaging."""

import numpy as np
import pytest

import functools

from repro.core import fl
from repro.core.gossip import metropolis_weights, spectral_gap
from repro.core.relation import Relation
from repro.constellation.scenario import ScenarioSpec, ShellSpec, build_scenario
from repro.core.schedule import TDMSchedule, hypercube_schedule
from proptest import given, st_int


@functools.lru_cache(maxsize=1)
def _walker_schedule(n_sats: int = 12, planes: int = 3, steps: int = 60):
    """Geometry-driven visibility schedule (replaces the removed duty-cycle
    toy): one MEO Walker shell, no ground segment, one period horizon."""
    scn = build_scenario(
        ScenarioSpec(
            shells=(ShellSpec(planes=planes, per_plane=n_sats // planes),),
            n_ground=0,
            steps=steps,
        )
    )
    return TDMSchedule(tuple(scn.relations()))


def test_centralized_fla_fedavg():
    """One round of the generic centralized FLA computes FedAvg."""
    n = 6
    client_data = {i: float(i) for i in range(n) if i != 0}

    def client_fn(model, data):
        return model + data  # local 'training': shift by local data

    def server_fn(model, updates):
        return float(np.mean(updates))

    out = fl.centralized_fla_sim(
        n_nodes=n,
        server_id=0,
        client_fn=client_fn,
        server_fn=server_fn,
        client_data=client_data,
        server_data=0.0,
        n_rounds=1,
    )
    assert out == pytest.approx(np.mean([float(i) for i in range(1, n)]))


def test_centralized_fla_multi_round():
    n = 4
    out = fl.centralized_fla_sim(
        n_nodes=n,
        server_id=2,
        client_fn=lambda m, d: 0.5 * m + d,
        server_fn=lambda m, ups: float(np.mean(ups)),
        client_data={i: 1.0 for i in range(n) if i != 2},
        server_data=8.0,
        n_rounds=20,
    )
    # fixed point of m -> 0.5 m + 1
    assert out == pytest.approx(2.0, abs=1e-4)


@given(st_int(3, 9), st_int(0, 500), cases=30)
def test_decentralized_fla_uniform_average(n, seed):
    """One clique round with uniform mixing = exact global mean everywhere."""
    data = {i: float(i * i) for i in range(n)}

    def update(own, peers):
        return (own + sum(peers)) / n

    results = fl.decentralized_fla_sim(n, update, data, n_rounds=1, seed=seed)
    want = np.mean(list(data.values()))
    for i in range(n):
        assert results[i] == pytest.approx(want)


@given(st_int(0, 500), cases=20)
def test_tdm_fla_consensus_over_walker(seed):
    """The paper's FLA over a time-varying Walker visibility schedule:
    Metropolis mixing reaches consensus on the constellation average."""
    sched = _walker_schedule()
    n = 12
    init = {i: np.array([float(i), -float(i)]) for i in range(n)}

    def mix(own, peers):
        # mirror of collective Metropolis mixing, done with plain numpy
        return own  # replaced below per node via closure
    # use schedule mixing directly: emulate with per-node closure capturing rel
    # simpler: run with mix via metropolis using node-degree info per slot
    state = {i: init[i].copy() for i in range(n)}
    for rel in sched:
        W = metropolis_weights(rel, n)
        new = {}
        for i in range(n):
            new[i] = W[i, i] * state[i] + sum(
                W[i, j] * state[j] for j in rel.peers_of(i)
            )
        state = new
    target = np.mean([init[i] for i in range(n)], axis=0)
    err = max(np.linalg.norm(state[i] - target) for i in range(n))
    assert err < 1e-3


def test_tdm_fla_sim_local_plus_mix():
    """tdm_fla_sim: local step + getMeas exchange + mix, over a hypercube
    schedule — exact consensus in log2(n) slots when mixing is pairwise avg."""
    n = 8
    sched = hypercube_schedule(n)
    init = {i: float(i) for i in range(n)}

    def local_fn(node, t, v):
        return v  # no local drift: test pure mixing

    def mix_fn(own, peers):
        return (own + peers[0]) / 2.0  # matching => exactly one peer

    results, sim = fl.tdm_fla_sim(sched, n, local_fn, mix_fn, init)
    want = np.mean(list(init.values()))
    for i in range(n):
        assert results[i] == pytest.approx(want)
    # message economy: hypercube moves n*log2(n) messages
    assert sim.total_messages == n * (n.bit_length() - 1)


def test_tdm_fla_skip_slot_isolated_nodes():
    """Nodes with no peers in a slot skip it (odata=None) and still finish."""
    n = 4
    r_partial = Relation.from_edges([(0, 1)], nodes=range(n))  # 2,3 isolated
    sched = TDMSchedule((r_partial,))
    results, _ = fl.tdm_fla_sim(
        sched,
        n,
        local_fn=lambda i, t, v: v,
        mix_fn=lambda own, peers: (own + sum(peers)) / (1 + len(peers)),
        init={i: float(i) for i in range(n)},
    )
    assert results[0] == pytest.approx(0.5)
    assert results[1] == pytest.approx(0.5)
    assert results[2] == 2.0 and results[3] == 3.0  # untouched


def test_spectral_gap_orders_topologies():
    """Clique mixes faster than ring (spectral gap ordering) — the
    quantitative face of paper P2."""
    n = 12
    from repro.core.schedule import ring

    gap_clique = spectral_gap(metropolis_weights(Relation.clique(list(range(n))), n))
    gap_ring = spectral_gap(metropolis_weights(ring(n), n))
    assert gap_clique > gap_ring > 0


def test_rounds_to_consensus_finite():
    n = 8
    W = metropolis_weights(Relation.clique(list(range(n))), n)
    t = fl.rounds_to_consensus(W, tol=1e-6)
    assert 0 < t < 100
