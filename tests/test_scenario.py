"""Unified scenario factory: spec validation, canonical ground sites,
node layout, schedule caching, and sweep helpers."""

import dataclasses
import functools

import pytest

from repro.constellation.orbits import GroundStation, MultiShell, WalkerDelta
from repro.constellation.scenario import (
    GROUND_SITES,
    ScenarioSpec,
    ShellSpec,
    build_scenario,
    replace_spec,
    smoke_scenario,
)


@functools.lru_cache(maxsize=1)
def _smoke():
    return smoke_scenario()


def test_spec_defaults_and_sites_prefix():
    spec = ScenarioSpec()
    assert spec.n_sats == 6
    assert spec.sites == GROUND_SITES[:2]
    assert spec.sites[0].name == "equator"
    # explicit ground stations override the canonical prefix
    gs = (GroundStation(10.0, 20.0, name="custom"),)
    assert ScenarioSpec(ground_stations=gs).sites == gs


def test_spec_validation():
    with pytest.raises(ValueError, match="at least one shell"):
        ScenarioSpec(shells=())
    with pytest.raises(ValueError, match="n_ground"):
        ScenarioSpec(n_ground=len(GROUND_SITES) + 1)
    # n_ground beyond the canonical list is fine with explicit stations
    gs = tuple(
        GroundStation(float(i), 0.0, name=f"g{i}") for i in range(6)
    )
    assert len(ScenarioSpec(n_ground=6, ground_stations=gs).sites) == 6


def test_spec_geometry_single_vs_multi_shell():
    single = ScenarioSpec(shells=(ShellSpec(planes=3, per_plane=4),))
    assert isinstance(single.geometry(), WalkerDelta)
    assert single.n_sats == 12
    multi = ScenarioSpec(shells=(
        ShellSpec(planes=2, per_plane=3, altitude_km=8062.0),
        ShellSpec(planes=2, per_plane=2, altitude_km=10_000.0),
    ))
    assert isinstance(multi.geometry(), MultiShell)
    assert multi.n_sats == 10
    # defaults derive from the shells: one-period horizon of the FIRST
    # shell, diameter range bound of the HIGHEST shell
    assert multi.horizon_s() == pytest.approx(
        multi.shells[0].walker().period_s
    )
    assert multi.range_km() > 2 * 10_000.0


def test_build_scenario_node_layout():
    scn = _smoke()
    assert scn.n_sats == 6
    assert scn.n_nodes == 8               # satellites first, then ground
    assert scn.ground_ids == frozenset({6, 7})
    assert sorted(scn.sat_ids) == list(range(6))
    rels = scn.relations()
    assert len(rels) == scn.spec.steps
    assert scn.describe()["n_sats"] == 6


def test_schedule_cached_and_overridable():
    scn = _smoke()
    assert scn.schedule() is scn.schedule()       # memoized
    over = scn.schedule(antennas=1)
    assert over is not scn.schedule()
    assert len(scn.slots()) > 0
    # every slot relation is a valid TDM exchange on the node universe
    for rel in scn.slots():
        assert rel.is_valid_exchange() or len(rel) == 0


def test_replace_spec_sweep_helper():
    scn = _smoke()
    bigger = replace_spec(scn, n_ground=3)
    assert bigger.n_nodes == scn.n_nodes + 1
    assert bigger.spec == dataclasses.replace(scn.spec, n_ground=3)
    # original untouched
    assert scn.n_nodes == 8
