"""Ground-segment subsystem: contact-graph routing, relay/broadcast
programs, the FedAvg cost oracle, and the FlatSpec cache — single-process
tests plus the launcher for the multi-device worker
(_groundseg_worker.py — subprocess, 8 forced host devices)."""

import os
import pathlib
import random
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.constellation import contact_plan, cost, orbits
from repro.constellation.contact_plan import ContactSchedule, Slot
from repro.constellation.links import Link
from repro.core import fused
from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule
from repro.groundseg import aggregation, routing
from repro.launch.fl_train import GroundSegConfig

ROOT = pathlib.Path(__file__).resolve().parents[1]


def chain_slots():
    """4 nodes (0..2 sats, 3 sink): 0 must relay through 1."""
    return [
        Relation.from_edges([(0, 1)], nodes=range(4)),
        Relation.from_edges([(1, 3)], nodes=range(4)),
        Relation.from_edges([(2, 3)], nodes=range(4)),
    ]


# ------------------------------------------------------------------ routing
def test_earliest_delivery_multi_hop():
    table = routing.earliest_delivery_routes(chain_slots(), 4, sinks=[3])
    r0 = table.routes[0]
    assert r0.sink == 3 and r0.delivery_slot == 1
    assert [(h.slot, h.src, h.dst) for h in r0.hops] == [(0, 0, 1), (1, 1, 3)]
    assert table.routes[1].delivery_slot == 1
    assert table.routes[2].delivery_slot == 2
    assert table.max_delivery_slot() == 2
    assert table.unreachable() == []


def test_router_reports_unreachable_without_hanging():
    # satellite 2 never contacts anyone; satellite 0 reaches the sink only
    # through 1 — and a LONG schedule of empty slots must not loop
    slots = [Relation.from_edges([(0, 1)], nodes=range(4)),
             Relation.from_edges([(1, 3)], nodes=range(4))]
    slots += [Relation.empty(range(4))] * 500
    table = routing.earliest_delivery_routes(slots, 4, sinks=[3])
    assert table.unreachable() == [2]
    assert table.routes[2].sink is None and table.routes[2].hops == ()
    assert table.reachable() == [0, 1]


def test_router_prefers_holding_on_ties():
    # 0 can deliver directly at slot 1; the slot-0 detour via 1 also
    # delivers at slot 1 but costs a transmission — the policy must hold
    slots = [
        Relation.from_edges([(0, 1)], nodes=range(3)),
        Relation.from_edges([(0, 2), (1, 2)], nodes=range(3)),
    ]
    table = routing.earliest_delivery_routes(slots, 3, sinks=[2])
    assert [(h.slot, h.src, h.dst) for h in table.routes[0].hops] == [(1, 0, 2)]


def test_router_validates_sinks():
    with pytest.raises(ValueError, match="at least one sink"):
        routing.earliest_delivery_routes(chain_slots(), 4, sinks=[])
    with pytest.raises(ValueError, match="outside node range"):
        routing.earliest_delivery_routes(chain_slots(), 4, sinks=[9])


def test_source_that_is_a_sink_is_trivially_delivered():
    table = routing.earliest_delivery_routes(
        chain_slots(), 4, sinks=[3], sources=[0, 3]
    )
    assert table.routes[3].sink == 3 and table.routes[3].delivery_slot == -1


# ----------------------------------------------------- relay and broadcast
def test_relay_program_delivers_and_merges():
    up = routing.build_relay_program(chain_slots(), 4, [3])
    assert up.delivered == {3: frozenset({0, 1, 2})}
    assert up.unreachable == frozenset()
    # slot 1: node 1 carries its own + node 0's payload in ONE send
    assert up.slot_sends[1] == ((1, 3),)
    assert up.n_hops == 3
    assert up.last_used_slot() == 2
    assert up.delivered_count() == 3


def test_relay_program_partitions_reachable_sources():
    rng = random.Random(7)
    for case in range(25):
        n = 8
        sinks = {6, 7}
        slots = []
        for _ in range(rng.randrange(1, 7)):
            edges = [
                (i, j)
                for i in range(n)
                for j in range(i + 1, n)
                if rng.random() < 0.25
            ]
            slots.append(Relation.from_edges(edges, nodes=range(n)))
        table = routing.earliest_delivery_routes(slots, n, sinks)
        up = routing.build_relay_program(slots, n, sinks, table=table)
        delivered_all = set().union(*up.delivered.values())
        # delivered + unreachable partition the satellite set
        assert delivered_all | set(up.unreachable) == set(range(6)), case
        assert delivered_all & set(up.unreachable) == set()
        # out-degree <= 1 per node per slot (accumulate-and-forward)
        for sends in up.slot_sends:
            srcs = [s for s, _ in sends]
            assert len(srcs) == len(set(srcs))
        # every send uses an edge of that slot's relation
        for t, sends in enumerate(up.slot_sends):
            for s, d in sends:
                assert (s, d) in slots[t].pairs


def test_broadcast_flood_single_parent_and_slot_causality():
    down = routing.build_broadcast_program(chain_slots(), 4, [3])
    # node 1 gets the model at slot 1, node 2 at slot 2; node 0's only
    # contact (slot 0, with then-uncovered 1) precedes coverage -> missed
    assert down.covered == frozenset({1, 2, 3})
    assert down.receive_slot == {1: 1, 2: 2}
    for sends in down.slot_sends:
        dsts = [d for _, d in sends]
        assert len(dsts) == len(set(dsts))  # one parent per receiver


def test_permutation_batches_are_ppermute_legal():
    rng = random.Random(3)
    for case in range(50):
        edges = [
            (rng.randrange(8), rng.randrange(8)) for _ in range(rng.randrange(1, 12))
        ]
        edges = [(s, d) for s, d in edges if s != d]
        batches = routing.permutation_batches(edges)
        flat = [e for b in batches for e in b]
        assert sorted(flat) == sorted(edges), case  # nothing lost or invented
        for b in batches:
            srcs = [s for s, _ in b]
            dsts = [d for _, d in b]
            assert len(srcs) == len(set(srcs))
            assert len(dsts) == len(set(dsts))


def test_expected_collectives_math():
    up = routing.build_relay_program(chain_slots(), 4, [3])
    down = routing.build_broadcast_program(chain_slots(), 4, [3])
    want = aggregation.expected_collectives(up, down, 2, compression="int8",
                                            pool=True)
    # quantize-once int8: uplink relays int16 sums (1 permute per batch),
    # downlink ships payload+scales (2 per batch) -> (3 + 2*2) x2 buffers;
    # all-reduces: 1 pmax (shared scales) + 1 pool psum, per buffer
    assert want == {"collective-permute": 14, "all-reduce": 4}
    assert aggregation.expected_collectives(up, down, 1)["collective-permute"] == 5


def test_sink_weights_static():
    up = routing.build_relay_program(chain_slots(), 4, [3])
    w = aggregation.sink_weights(up)
    assert w.tolist() == [0.0, 0.0, 0.0, 4.0]  # 3 delivered + own model


def test_relay_compression_validated():
    up = routing.build_relay_program(chain_slots(), 4, [3])
    with pytest.raises(ValueError, match="compression"):
        aggregation.relay_uplink({}, up, "node", compression="topk")


# --------------------------------------------------------------- cost oracle
def _toy_schedule(rels, dur=2.0):
    slots = []
    t0 = 0.0
    for t, r in enumerate(rels):
        links = {
            e: Link(range_km=1000.0, delay_s=0.01, rate_bps=1e6)
            for e in r.edge_list()
        }
        slots.append(Slot(relation=r, t_index=t, start_s=t0, duration_s=dur,
                          min_rate_bps=1e6, max_delay_s=0.01, links=links))
        t0 += dur
    return ContactSchedule(tdm=TDMSchedule(tuple(rels)), slots=tuple(slots))


def test_groundseg_round_cost_span_and_traffic():
    rels = chain_slots()
    sched = _toy_schedule(rels)
    up = routing.build_relay_program(rels, 4, [3])
    down = routing.build_broadcast_program(rels, 4, [3])
    rc = cost.groundseg_round_cost(sched, up, down, payload_bytes=1000)
    # uplink uses slots 0..2 (span 6 s); downlink slots 1..2 (span 6 s too:
    # window origin to end of slot 2)
    assert rc.time_s == pytest.approx(6.0 + 6.0)
    assert rc.bytes_on_isl == 1000 * (up.n_hops + down.n_hops)
    assert rc.n_slots == 3 + 2


def test_groundseg_mode_costs_on_geometry():
    geom = orbits.WalkerDelta(total=6, planes=2, altitude_km=8062.0,
                              inclination_deg=60.0)
    gs = [orbits.GroundStation(0.0, 0.0), orbits.GroundStation(45.0, 120.0)]
    plan = contact_plan.build_contact_plan(
        geom, duration_s=geom.period_s, step_s=geom.period_s / 8,
        ground_stations=gs, max_range_km=16_000.0,
    )
    sinks = range(6, plan.n_nodes)
    mc = cost.groundseg_mode_costs(plan, sinks, 1 << 16, antennas=2)
    assert set(mc) == {"centralized", "hierarchical", "gossip_getmeas",
                       "gossip_get1meas"}
    assert mc["centralized"] == mc["hierarchical"]  # ISL cost identical
    assert mc["centralized"].bytes_on_isl > 0
    # relay ships one payload per hop; gossip one per directed pair per slot
    assert mc["centralized"].bytes_on_isl < mc["gossip_getmeas"].bytes_on_isl
    assert mc["gossip_get1meas"].time_s >= mc["gossip_getmeas"].time_s


def test_optimizer_groundseg_objective_never_worse_than_greedy():
    from repro.constellation.optimizer import optimize_schedule

    geom = orbits.WalkerDelta(total=6, planes=2, altitude_km=8062.0,
                              inclination_deg=60.0)
    gs = [orbits.GroundStation(10.0, 30.0)]
    plan = contact_plan.build_contact_plan(
        geom, duration_s=geom.period_s, step_s=geom.period_s / 8,
        ground_stations=gs, max_range_km=16_000.0,
    )
    sinks = [6]
    res = optimize_schedule(plan, antennas=2, payload_bytes=1 << 16,
                            objective="groundseg", sinks=sinks)
    assert res.chosen.time_s <= res.costs["greedy"].time_s
    with pytest.raises(ValueError, match="sink"):
        optimize_schedule(plan, objective="groundseg")
    with pytest.raises(ValueError, match="objective"):
        optimize_schedule(plan, objective="latency")


# ------------------------------------------------------------ driver config
def test_groundseg_config_validation_and_cadence():
    with pytest.raises(ValueError, match="unknown groundseg mode"):
        GroundSegConfig(mode="federated")
    with pytest.raises(ValueError, match="compression"):
        GroundSegConfig(compression="topk")
    cent = GroundSegConfig(mode="centralized")
    assert all(cent.pool_round(r) for r in range(5))
    hier = GroundSegConfig(mode="hierarchical", sink_sync_every=3)
    assert [hier.pool_round(r) for r in range(6)] == [
        True, False, False, True, False, False,
    ]
    assert not GroundSegConfig(mode="hierarchical",
                               sink_sync_every=0).pool_round(0)


# ------------------------------------------------------------ FlatSpec cache
def test_cached_spec_hits_on_same_layout():
    fused.clear_spec_cache()
    tree = {"a": jnp.zeros((3, 5)), "b": jnp.ones((7,), jnp.float16)}
    s1 = fused.cached_spec(tree, block=64)
    s2 = fused.cached_spec(jax.tree.map(lambda x: x + 1, tree), block=64)
    assert s1 is s2  # same layout -> same cached object
    stats = fused.spec_cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1 and stats["size"] == 1
    # different shapes / block -> distinct specs
    s3 = fused.cached_spec({"a": jnp.zeros((4, 5)), "b": tree["b"]}, block=64)
    s4 = fused.cached_spec(tree, block=128)
    assert s3 is not s1 and s4 is not s1
    assert fused.spec_cache_stats()["size"] == 3
    fused.clear_spec_cache()
    assert fused.spec_cache_stats() == {"hits": 0, "misses": 0, "size": 0}


def test_cached_spec_works_under_tracing():
    fused.clear_spec_cache()
    tree = {"a": jnp.arange(6, dtype=jnp.float32)}

    @jax.jit
    def roundtrip(t):
        spec = fused.cached_spec(t, block=4)
        return fused.unflatten_pytree(spec, fused.flatten_pytree(spec, t))

    out = roundtrip(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    # the concrete-input key and the tracer key coincide
    assert fused.cached_spec(tree, block=4) is fused.cached_spec(tree, block=4)
    assert fused.spec_cache_stats()["size"] == 1


# ------------------------------------- multi-window pipelined + delay-tolerant
def test_relay_program_initial_loads_and_residual():
    # holder 1 carries {0, 1} (a merged carry from an earlier window);
    # holder 2 is isolated -> its load must come back as residual
    slots = [Relation.from_edges([(1, 3)], nodes=range(4))]
    up = routing.build_relay_program(
        slots, 4, [3], initial_loads={1: {0, 1}, 2: {2}}
    )
    assert up.delivered == {3: frozenset({0, 1})}
    assert up.residual == {2: frozenset({2})}
    assert up.unreachable == frozenset({2})
    assert up.residual_count() == 1
    # loads starting AT a sink are trivially delivered
    up2 = routing.build_relay_program(
        slots, 4, [3], initial_loads={3: {0}, 1: {1}}
    )
    assert up2.delivered == {3: frozenset({0, 1})}


def _iso_then_connected(n=4):
    """Window A: sat 2 isolated; window B: everyone reaches sink 3."""
    win_a = [Relation.from_edges([(0, 3), (1, 3)], nodes=range(n))]
    win_b = [Relation.from_edges([(0, 3), (1, 3), (2, 3)], nodes=range(n))]
    return win_a, win_b


def test_multiwindow_carry_and_stale_delivery():
    win_a, win_b = _iso_then_connected()
    router = routing.MultiWindowRouter(4, [3], max_staleness_windows=2)
    wp_a = router.plan_window(win_a)
    assert sorted(wp_a.injected) == [0, 1, 2]
    assert wp_a.delivered_ages == {0: 0, 1: 0}
    assert wp_a.residual == {2: 0}           # queued, age 0
    wp_b = router.plan_window(win_b)
    assert sorted(wp_b.injected) == [0, 1]   # 2 still has a pending payload
    assert wp_b.ages[2] == 1                 # aged one window boundary
    assert wp_b.delivered_ages[2] == 1       # delivered stale
    assert wp_b.residual == {} and router.pending() == {}
    assert wp_b.max_delivered_age() == 1


def test_multiwindow_delivery_at_exact_horizon_then_drop_beyond():
    win_a, win_b = _iso_then_connected()
    # unreachable for exactly max_staleness_windows, then delivers: KEPT
    router = routing.MultiWindowRouter(4, [3], max_staleness_windows=2)
    router.plan_window(win_a)
    router.plan_window(win_a)
    wp = router.plan_window(win_b)
    assert wp.delivered_ages[2] == 2 and wp.dropped == {}
    # one window beyond the horizon: DROPPED, reported, fresh re-snapshot
    router2 = routing.MultiWindowRouter(4, [3], max_staleness_windows=2)
    for _ in range(3):
        router2.plan_window(win_a)
    wp3 = router2.plan_window(win_a)
    assert wp3.dropped == {2: 3}
    assert router2.dropped_log == [
        routing.DroppedPayload(window=3, source=2, age=3)
    ]
    assert wp3.ages[2] == 0                  # re-snapshotted the same window


def test_multiwindow_staleness_zero_matches_one_shot_programs():
    # depth 1, horizon 0: every window's programs equal the PR 4 one-shot
    # builders — the static half of the bit-identical guarantee
    rels = chain_slots()
    router = routing.MultiWindowRouter(4, [3], max_staleness_windows=0)
    up_ref = routing.build_relay_program(rels, 4, [3])
    down_ref = routing.build_broadcast_program(rels, 4, [3])
    for _ in range(3):
        wp = router.plan_window(rels)
        assert wp.uplink.slot_sends == up_ref.slot_sends
        assert wp.uplink.delivered == up_ref.delivered
        assert wp.downlink.slot_sends == down_ref.slot_sends
        assert all(a == 0 for a in wp.ages.values())


def test_pipelined_window_capacity_is_disjoint():
    rels = [
        Relation.from_edges([(0, 1), (2, 3), (1, 3)], nodes=range(4)),
        Relation.from_edges([(0, 3), (1, 2), (1, 3)], nodes=range(4)),
    ]
    router = routing.MultiWindowRouter(4, [3], pipeline_depth=2,
                                       max_staleness_windows=1)
    wp0 = router.plan_window(rels)
    assert wp0.downlink is None and wp0.lagged_downlink
    wp1 = router.plan_window(rels)
    assert wp1.downlink is not None
    for up_s, down_s in zip(wp1.uplink.slot_sends, wp1.downlink.slot_sends):
        up_e = {(min(a, b), max(a, b)) for a, b in up_s}
        down_e = {(min(a, b), max(a, b)) for a, b in down_s}
        assert not (up_e & down_e)
    # remaining_capacity really removed the uplink's edges
    rem = routing.remaining_capacity(rels, wp1.uplink)
    for t, rel in enumerate(rem):
        used = {(min(a, b), max(a, b)) for a, b in wp1.uplink.slot_sends[t]}
        assert not (set(rel.edge_list()) & used)


def test_multiwindow_router_validation():
    with pytest.raises(ValueError, match="sink"):
        routing.MultiWindowRouter(4, [])
    with pytest.raises(ValueError, match="max_staleness_windows"):
        routing.MultiWindowRouter(4, [3], max_staleness_windows=-1)
    with pytest.raises(ValueError, match="pipeline_depth"):
        routing.MultiWindowRouter(4, [3], pipeline_depth=3)


def test_dead_holder_keeps_payload_until_revival():
    win_a, win_b = _iso_then_connected()
    router = routing.MultiWindowRouter(4, [3], max_staleness_windows=3)
    router.plan_window(win_b)                     # all delivered fresh
    wp = router.plan_window(win_b, alive={0, 1})  # sat 2 dies AFTER snapshot?
    # dead and nothing pending -> no snapshot, nothing queued
    assert 2 not in wp.ages
    # now: alive but occluded (snapshots), then dies holding the payload
    wp_a = router.plan_window(win_a)
    assert wp_a.residual == {2: 0}
    wp_dead = router.plan_window(win_b, alive={0, 1})
    assert wp_dead.ages[2] == 1                  # queued payload keeps aging
    assert wp_dead.residual == {2: 1}            # dead holder: no route, holds
    wp_back = router.plan_window(win_b)
    assert wp_back.delivered_ages[2] == 2        # delivers once revived


def test_staleness_sink_weights_math():
    up = routing.build_relay_program(chain_slots(), 4, [3])
    # all ages 0 -> identical to the unweighted denominators (exact FedAvg)
    w0 = aggregation.staleness_sink_weights(up, {}, decay=0.5)
    assert np.array_equal(w0, aggregation.sink_weights(up))
    w = aggregation.staleness_sink_weights(up, {0: 2, 1: 1}, decay=0.5)
    assert w[3] == pytest.approx(1.0 + 0.25 + 0.5 + 1.0)
    # decay 1.0: ages never change the weights
    w1 = aggregation.staleness_sink_weights(up, {0: 7, 2: 3}, decay=1.0)
    assert np.array_equal(w1, aggregation.sink_weights(up))


def test_expected_collectives_without_downlink():
    up = routing.build_relay_program(chain_slots(), 4, [3])
    down = routing.build_broadcast_program(chain_slots(), 4, [3])
    with_down = aggregation.expected_collectives(up, down, 2)
    without = aggregation.expected_collectives(up, None, 2, pool=False)
    assert without["collective-permute"] < with_down["collective-permute"]
    assert without["all-reduce"] == 0
    router = routing.MultiWindowRouter(4, [3], pipeline_depth=2)
    wp0 = router.plan_window(chain_slots())
    assert aggregation.expected_window_collectives(wp0, 2, pool=False) == without


def test_groundseg_pipelined_cost_depth_semantics():
    rels = chain_slots()
    sched = _toy_schedule(rels)
    up = routing.build_relay_program(rels, 4, [3])
    down = routing.build_broadcast_program(rels, 4, [3])
    d1 = cost.groundseg_pipelined_cost(sched, up, down, 1000, pipeline_depth=1)
    assert d1 == cost.groundseg_round_cost(sched, up, down, 1000)
    d2 = cost.groundseg_pipelined_cost(sched, up, down, 1000, pipeline_depth=2)
    assert d2.time_s == pytest.approx(6.0)       # max of the spans, not sum
    assert d2.bytes_on_isl == d1.bytes_on_isl    # traffic still sums
    warm = cost.groundseg_pipelined_cost(sched, up, None, 1000, pipeline_depth=2)
    assert warm.time_s == pytest.approx(6.0)


def _meo_plan(planes=2, per=3, steps=8):
    geom = orbits.WalkerDelta(total=planes * per, planes=planes,
                              altitude_km=8062.0, inclination_deg=60.0)
    gs = [orbits.GroundStation(0.0, 0.0), orbits.GroundStation(45.0, 120.0)]
    plan = contact_plan.build_contact_plan(
        geom, duration_s=geom.period_s, step_s=geom.period_s / steps,
        ground_stations=gs,
        max_range_km=2.0 * (orbits.R_EARTH_KM + 8062.0),
    )
    return geom, plan, list(range(geom.total, plan.n_nodes))


def test_pipeline_throughput_at_least_1_5x_on_meo_shell():
    # the acceptance bar: depth-2 steady-state round throughput >= 1.5x
    # depth 1 on the benchmark MEO sweep cells (deterministic oracle)
    for planes, per in [(2, 3), (2, 4)]:
        for steps in (8, 12):
            geom, plan, sinks = _meo_plan(planes, per, steps)
            sched = plan.schedule(antennas=2, payload_bytes=1 << 20)
            t1 = cost.groundseg_throughput(
                sched, sinks, n_nodes=plan.n_nodes, pipeline_depth=1
            )
            t2 = cost.groundseg_throughput(
                sched, sinks, n_nodes=plan.n_nodes, pipeline_depth=2,
                max_staleness_windows=2,
            )
            ratio = (t2["round_throughput_per_s"]
                     / max(t1["round_throughput_per_s"], 1e-12))
            assert ratio >= 1.5, (planes, per, steps, ratio)
            # the win must not come from dropping deliveries
            assert t2["delivered"] >= t1["delivered"]


def test_optimizer_pipelined_groundseg_never_worse():
    from repro.constellation.optimizer import optimize_schedule

    geom, plan, sinks = _meo_plan(2, 3, 8)
    res = optimize_schedule(
        plan, antennas=2, payload_bytes=1 << 16, objective="groundseg",
        sinks=sinks, pipeline_depth=2, max_staleness_windows=1,
    )
    assert res.chosen.time_s <= res.costs["greedy"].time_s


def test_groundseg_config_pipeline_validation():
    with pytest.raises(ValueError, match="pipeline_depth"):
        GroundSegConfig(pipeline_depth=3)
    with pytest.raises(ValueError, match="max_staleness_windows"):
        GroundSegConfig(max_staleness_windows=-1)
    with pytest.raises(ValueError, match="staleness_decay"):
        GroundSegConfig(staleness_decay=0.0)
    with pytest.raises(ValueError, match="staleness_decay"):
        GroundSegConfig(staleness_decay=1.5)
    assert not GroundSegConfig().pipelined
    assert GroundSegConfig(pipeline_depth=2).pipelined
    assert GroundSegConfig(max_staleness_windows=1).pipelined


# ------------------------------------------------------- multidevice worker
@pytest.mark.slow
def test_groundseg_multidevice_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT / 'tests'}:" + env.get(
        "PYTHONPATH", ""
    )
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_groundseg_worker.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=1800,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    assert proc.returncode == 0, "worker failed"
    assert "ALL-OK" in proc.stdout
