"""Pipeline-parallelism correctness worker (4 forced host devices):
the pipelined loss/grads must equal the sequential (scan-over-layers) path
on identical params. Prints ALL-OK on success."""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import archs
from repro.launch import pipeline as pp_lib
from repro.launch import sharding as shlib
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.optim import adamw


def main():
    n_stages, n_micro = 2, 4
    mesh = make_mesh((2, 2), ("data", "model"))
    rules = shlib.rules_for(mesh, "pp")

    cfg = archs.smoke_cfg(archs.get("gemma2-9b")).replace(
        compute_dtype="float32", n_layers=4  # 2 units of 2 -> 2 stages x 1
    )
    opt_cfg = adamw.OptConfig()
    pp_step, cfgp = pp_lib.build_pp_train_step(cfg, opt_cfg, rules, n_stages, n_micro)
    assert cfgp.n_layers == cfg.n_layers  # no padding needed here

    params, _ = registry.bundle(cfgp).init(jax.random.PRNGKey(0))
    B, S = 8, 32
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }

    # sequential reference (single device semantics)
    ref_loss, _ = registry.bundle(cfgp).loss_fn(params, batch)
    ref_loss = float(ref_loss)

    state = {
        "params": params,
        "opt": adamw.init_opt_state(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }
    with mesh:
        new_state, metrics = jax.jit(pp_step)(state, batch)
    pp_loss = float(metrics["loss"])
    print(f"sequential loss {ref_loss:.6f} vs pipelined {pp_loss:.6f}")
    assert abs(pp_loss - ref_loss) < 2e-3 * max(1.0, abs(ref_loss)), (
        ref_loss, pp_loss,
    )
    gn = float(metrics["grad_norm"])
    assert np.isfinite(gn) and gn > 0

    # padding path: 3 units on 2 stages (pad to 4)
    cfg3 = cfg.replace(n_layers=6)
    pp3, cfg3p = pp_lib.build_pp_train_step(cfg3, opt_cfg, rules, n_stages, n_micro)
    assert cfg3p.n_layers == 8  # padded
    params3, _ = registry.bundle(cfg3p).init(jax.random.PRNGKey(1))
    state3 = {
        "params": params3,
        "opt": adamw.init_opt_state(params3, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }
    with mesh:
        _, m3 = jax.jit(pp3)(state3, batch)
    assert np.isfinite(float(m3["loss"]))
    print(f"padded-pipeline loss {float(m3['loss']):.6f} (finite, masked pads)")
    print("ALL-OK")


if __name__ == "__main__":
    main()
