"""Fused flat-buffer exchange engine: single-device spec/layout tests plus
the launcher for the multi-device HLO-count / equivalence worker
(_fused_worker.py — subprocess, 8 forced host devices)."""

import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fl, fused
from repro.core.relation import Relation

ROOT = pathlib.Path(__file__).resolve().parents[1]


def mixed_tree():
    rng = np.random.default_rng(0)
    return {
        "a": jnp.asarray(rng.normal(size=(3, 5)).astype(np.float32)),
        "b": {
            "c": jnp.asarray(rng.normal(size=(17,)).astype(np.float32)),
            "d": jnp.asarray(rng.normal(size=(4, 2)).astype(np.float16)),
        },
        "e": jnp.asarray(rng.integers(0, 9, size=(6,)).astype(np.int32)),
        "f": jnp.asarray(np.float32(2.5)),  # scalar leaf
    }


def test_spec_buckets_and_padding():
    tree = mixed_tree()
    spec = fused.build_spec(tree, block=64)
    assert spec.buckets == ["float16", "float32", "int32"]
    # fp32: 15 + 17 + 1 = 33 elements -> padded to 64
    assert spec.padded_size("float32") == 64
    assert spec.n_leaves("float32") == 3
    assert spec.padded_size("float16") == 64
    assert spec.padded_size("int32") == 64
    # every padded size is a block multiple
    for b in spec.buckets:
        assert spec.padded_size(b) % 64 == 0


def test_flatten_unflatten_roundtrip():
    tree = mixed_tree()
    spec = fused.build_spec(tree, block=64)
    bufs = fused.flatten_pytree(spec, tree)
    assert set(bufs) == set(spec.buckets)
    for b, buf in bufs.items():
        assert buf.shape == (spec.padded_size(b),)
        assert buf.dtype == jnp.dtype(b)
    back = fused.unflatten_pytree(spec, bufs)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_flatten_rejects_wrong_tree():
    tree = mixed_tree()
    spec = fused.build_spec(tree, block=64)
    with pytest.raises(ValueError, match="tree mismatch"):
        fused.flatten_pytree(spec, {"zz": tree["a"]})


def test_empty_relation_passthrough():
    tree = mixed_tree()
    out, res = fused.fused_tdm_fla_round(
        tree, Relation.empty(range(4)), "node", 4, fl.TDMFLAConfig()
    )
    assert out is tree and res is None


def test_fused_is_default():
    assert fl.TDMFLAConfig().fused
    from repro.launch.fl_train import FLConfig

    assert FLConfig().fused


def test_bad_quant_impl_raises():
    with pytest.raises(ValueError, match="unknown quant impl"):
        fused._resolve_impl("metal")


@pytest.mark.slow
def test_fused_multidevice_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT / 'tests'}:" + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_fused_worker.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=1800,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    assert proc.returncode == 0, "worker failed"
    assert "ALL-OK" in proc.stdout
