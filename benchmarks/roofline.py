"""Roofline table builder: aggregates the dry-run JSONs into the
EXPERIMENTS.md table (one row per arch x shape x mesh) and picks the three
hillclimb cells (worst roofline fraction / most collective-bound / most
paper-representative).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
       [--md]   (emit the markdown table)
"""

from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

ARCH_ORDER = [
    "mamba2-780m", "gemma2-9b", "gemma2-27b", "granite-20b", "qwen2-72b",
    "jamba-1.5-large-398b", "qwen3-moe-30b-a3b", "kimi-k2-1t-a32b",
    "whisper-base", "qwen2-vl-72b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

ICI_BW = 50e9
PEAK = 197e12

# transit-byte factors per collective kind (ring algorithms, large-n limit):
# all-reduce moves ~2x the tensor over the wire; gather/scatter/a2a/permute ~1x
TRANSIT_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def recompute_terms(r: Dict) -> Dict:
    """Refine the stored roofline with transit-byte collective accounting."""
    by_kind = r["collectives"]["bytes_by_kind"]
    transit = sum(TRANSIT_FACTOR.get(k, 1.0) * v for k, v in by_kind.items())
    rf = dict(r["roofline"])
    rf["collective_s"] = transit / ICI_BW
    terms = {k: rf[k] for k in ("compute_s", "memory_s", "collective_s")}
    rf["dominant"] = max(terms, key=terms.get)
    rf["bound_step_seconds"] = max(terms.values())
    rf["roofline_mfu"] = (
        rf["model_flops_per_device"] / max(rf["bound_step_seconds"], 1e-12) / PEAK
    )
    out = dict(r)
    out["roofline"] = rf
    return out


def load_rows(d: pathlib.Path, mesh: str) -> List[Dict]:
    rows = []
    for f in sorted((d / mesh).glob("*.json")):
        data = recompute_terms(json.loads(f.read_text()))
        rows.append(data)
    rows.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]), SHAPE_ORDER.index(r["shape"])))
    return rows


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    return f"{x*1e3:7.1f}ms"


def table(rows: List[Dict], md: bool = False) -> str:
    hdr = (
        f"{'arch':<22} {'shape':<12} {'comp':>9} {'mem':>9} {'coll':>9} "
        f"{'dominant':<12} {'useful':>6} {'MFU':>6} {'GB/dev':>7}"
    )
    sep = "-" * len(hdr)
    lines = [hdr, sep]
    if md:
        lines = [
            "| arch | shape | compute | memory | collective | dominant | useful | roofline-MFU | state GB/dev |",
            "|---|---|---|---|---|---|---|---|---|",
        ]
    for r in rows:
        rf = r["roofline"]
        dom = rf["dominant"].replace("_s", "")
        gb = r.get("state_bytes_per_device", 0) / 1e9
        if md:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s']).strip()} "
                f"| {fmt_s(rf['memory_s']).strip()} | {fmt_s(rf['collective_s']).strip()} "
                f"| {dom} | {rf['useful_flops_ratio']:.2f} | {rf['roofline_mfu']*100:.1f}% "
                f"| {gb:.1f} |"
            )
        else:
            lines.append(
                f"{r['arch']:<22} {r['shape']:<12} {fmt_s(rf['compute_s'])} "
                f"{fmt_s(rf['memory_s'])} {fmt_s(rf['collective_s'])} "
                f"{dom:<12} {rf['useful_flops_ratio']:>6.2f} "
                f"{rf['roofline_mfu']*100:>5.1f}% {gb:>7.1f}"
            )
    return "\n".join(lines)


def pick_hillclimb_cells(rows: List[Dict]) -> Dict[str, Dict]:
    """worst roofline MFU / most collective-bound / paper-representative."""
    trains = [r for r in rows if r["kind"] == "train"]
    worst = min(trains, key=lambda r: r["roofline"]["roofline_mfu"])
    coll = max(
        rows,
        key=lambda r: r["roofline"]["collective_s"]
        / max(r["roofline"]["bound_step_seconds"], 1e-12),
    )
    # paper-representative: the TDM-FL communication path stresses DP-axis
    # exchange of params — the biggest DP-traffic train cell:
    rep = max(trains, key=lambda r: r["roofline"]["collective_s"])
    return {"worst_mfu": worst, "most_collective": coll, "paper_representative": rep}


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="experiments/dryrun")
    p.add_argument("--mesh", default="single")
    p.add_argument("--md", action="store_true")
    args = p.parse_args(argv)
    rows = load_rows(pathlib.Path(args.dir), args.mesh)
    print(table(rows, md=args.md))
    picks = pick_hillclimb_cells(rows)
    print("\nhillclimb picks:")
    for k, r in picks.items():
        print(f"  {k}: {r['arch']} x {r['shape']} "
              f"(MFU {r['roofline']['roofline_mfu']*100:.1f}%, "
              f"dominant {r['roofline']['dominant']})")
    return rows


if __name__ == "__main__":
    main()
