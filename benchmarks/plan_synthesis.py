"""Mega-constellation plan synthesis: vectorized pipeline vs legacy loops.

For each constellation cell (N × shells × horizon) the full plan-synthesis
pipeline runs end to end on the vectorized fast path —

  propagate → visibility matrix → contact windows → optimized TDM schedule
  → earliest-delivery routes

— and the four core stages with retained legacy twins (batched geometry,
batched visibility, run-length windows, array-relaxation routing DP) are
re-run through those legacy oracles to report the speedup. The fast and
legacy stage outputs are asserted EQUAL while timing them (the benchmark
refuses to report a speedup over a path it doesn't reproduce bit for bit);
deterministic row fields (window/slot/route counts) double as exact
identity gates for ``check_regression.py`` trending.

``PYTHONPATH=src python -m benchmarks.plan_synthesis [--smoke|--full]``
``PYTHONPATH=src python -m benchmarks.plan_synthesis --ci-smoke``
    plans a 1000-satellite shell once on the fast path only and fails if
    it exceeds the wall-clock budget (fast-tier CI guard).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import telemetry
from repro.constellation.contact_plan import (
    ContactPlan,
    build_contact_plan,
    plus_grid_candidates,
    sat_ground_candidates,
)
from repro.constellation.links import (
    LinkBudget,
    visibility_matrix,
    visibility_series_reference,
)
from repro.constellation.optimizer import optimize_schedule
from repro.constellation.orbits import (
    GroundStation,
    MultiShell,
    WalkerDelta,
    propagate,
    sample_times,
)
from repro.groundseg.routing import (
    earliest_delivery_routes,
    earliest_delivery_routes_reference,
)

# Three ground gateways at spread latitudes; every cell uses the same set so
# rows differ only by constellation shape.
GROUND = (
    GroundStation(lat_deg=40.0, lon_deg=-74.0, name="nyc"),
    GroundStation(lat_deg=-33.9, lon_deg=18.4, name="cpt"),
    GroundStation(lat_deg=64.1, lon_deg=-21.9, name="rkv"),
)

MAX_RANGE_KM = 6000.0

# The faithful legacy DP (per-call neighbor scans) goes quadratic at mega
# scale — the blowup this PR removes — so its timed twin runs on a bounded
# slot prefix and is scaled linearly to the full horizon (per-slot legacy
# cost is horizon-stationary: V · scan(E_t) with stationary visibility).
# Bit-identity with the fast DP is asserted on the timed prefix; the full
# fast/legacy equivalence lives in tests/test_mega_scale.py.
LEGACY_DP_SLOT_CAP = 120


def _shell(total: int, planes: int, alt: float = 550.0, inc: float = 53.0,
           pattern: str = "delta") -> WalkerDelta:
    return WalkerDelta(total=total, planes=planes, phasing=1,
                       inclination_deg=inc, altitude_km=alt, pattern=pattern)


# name -> (geometry, duration_s, step_s, compare_legacy)
def _cells(mode: str) -> List[Tuple[str, object, float, float, bool]]:
    small = ("walker_24", _shell(24, 4), 3600.0, 60.0, True)
    medium = ("walker_200", _shell(200, 10), 3600.0, 60.0, True)
    large = ("walker_504", _shell(504, 12), 3600.0, 60.0, True)
    mega = (
        "multishell_1092",
        MultiShell(shells=(
            _shell(648, 18),
            _shell(348, 12, alt=780.0, inc=86.4, pattern="star"),
            _shell(96, 8, alt=1200.0, inc=97.6),
        )),
        3600.0,
        60.0,
        True,
    )
    if mode == "smoke":
        return [small, medium]
    if mode == "full":
        return [small, medium, large, mega]
    return [small, medium, large, mega]


def _count_nodes(geom) -> Tuple[int, int]:
    if isinstance(geom, MultiShell):
        return geom.total, len(geom.shells)
    return geom.total, 1


def _time(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def run_cell(
    name: str,
    geom,
    duration_s: float,
    step_s: float,
    compare_legacy: bool,
    antennas: int,
    strategies: Optional[Sequence[str]],
) -> Dict:
    n_sats, n_shells = _count_nodes(geom)
    cand = plus_grid_candidates(geom) + sat_ground_candidates(geom, len(GROUND))
    budget = LinkBudget()
    times = sample_times(duration_s, step_s)

    # ------------------------------------------------------ fast pipeline
    # staged exactly like build_contact_plan(with_graphs=False): the four
    # vectorized core stages produce arrays end to end; the per-step
    # {edge: Link} dicts the (shared) scheduler consumes are materialized
    # lazily and timed as wall_s_graphs inside the scheduling wall.
    t_pipeline0 = time.perf_counter()
    tracks, t_geom = _time(lambda: propagate(geom, times, GROUND))
    ground_nodes = range(n_sats, tracks.shape[1])
    vm, t_vis = _time(lambda: visibility_matrix(
        tracks, budget, cand, MAX_RANGE_KM, 0.0, ground_nodes))
    plan = ContactPlan(
        n_nodes=tracks.shape[1], times=tuple(float(t) for t in times),
        graphs=(), step_s=float(step_s), matrix=vm,
    )
    windows, t_windows = _time(plan.windows)
    plan_g, t_graphs = _time(plan.with_graphs)
    if strategies:
        result, t_sched = _time(lambda: optimize_schedule(
            plan_g, antennas=antennas, strategies=strategies))
        sched = result.schedule
        winner = result.strategy
    else:
        sched, t_sched = _time(lambda: plan_g.schedule(antennas=antennas))
        winner = "greedy"
    rels = [s.relation for s in sched.slots]
    sinks = range(n_sats, plan.n_nodes)
    table, t_route = _time(lambda: earliest_delivery_routes(
        rels, plan.n_nodes, sinks))
    wall_fast_total = time.perf_counter() - t_pipeline0
    n_routed = len(table.reachable())

    row = dict(
        bench="plan_synthesis",
        cell=name,
        n_sats=n_sats,
        n_shells=n_shells,
        n_gs=len(GROUND),
        n_steps=len(plan.times),
        n_candidates=len(cand),
        winner=winner,
        # deterministic outputs — exact identity gates for trending
        n_windows=len(windows),
        n_slots=len(sched),
        n_routed=n_routed,
        routed_fraction=n_routed / max(1, n_sats),
        # stage walls (floats -> trend-exempt on shared runners)
        wall_s_geom=t_geom,
        wall_s_vis=t_vis,
        wall_s_windows=t_windows,
        wall_s_graphs=t_graphs,
        wall_s_schedule=t_sched,
        wall_s_route=t_route,
        wall_s_fast_total=wall_fast_total,
    )

    if not compare_legacy:
        return row

    # ------------------------------------- legacy twins, outputs checked
    shells = geom.shells if isinstance(geom, MultiShell) else (geom,)

    def legacy_positions():
        out = [
            np.concatenate([s.positions_reference(times) for s in shells],
                           axis=1)
        ]
        for gs in GROUND:
            out.append(gs.positions(times)[:, None, :])
        return np.concatenate(out, axis=1)

    ref_tracks, t_geom_ref = _time(legacy_positions)
    assert np.array_equal(ref_tracks, tracks), f"{name}: geometry drift"

    ref_graphs, t_vis_ref = _time(lambda: visibility_series_reference(
        ref_tracks, budget, cand, MAX_RANGE_KM, 0.0, ground_nodes))
    assert tuple(ref_graphs) == plan_g.graphs, f"{name}: visibility drift"

    ref_plan = ContactPlan(
        n_nodes=plan.n_nodes, times=plan.times, graphs=tuple(ref_graphs),
        step_s=plan.step_s, matrix=None,
    )
    ref_windows, t_win_ref = _time(ref_plan.windows_reference)
    assert ref_windows == windows, f"{name}: window drift"

    k = min(len(rels), LEGACY_DP_SLOT_CAP)
    prefix = rels[:k]
    fast_prefix = earliest_delivery_routes(prefix, plan.n_nodes, sinks)
    ref_prefix, t_route_ref_k = _time(
        lambda: earliest_delivery_routes_reference(prefix, plan.n_nodes, sinks))
    assert ref_prefix == fast_prefix, f"{name}: route drift"
    t_route_ref = t_route_ref_k * (len(rels) / max(1, k))

    # core-stage comparison: the four stages this PR vectorized, each
    # producing its pipeline's native artifact (legacy visibility emits the
    # per-step dicts because that IS its output format; the fast path's
    # deferred dict materialization serves only the shared scheduler and is
    # reported as wall_s_graphs above). The scheduling stage itself is
    # identical code in both pipelines and has no legacy twin.
    core_fast = t_geom + t_vis + t_windows + t_route
    core_legacy = t_geom_ref + t_vis_ref + t_win_ref + t_route_ref
    row.update(
        wall_s_geom_legacy=t_geom_ref,
        wall_s_vis_legacy=t_vis_ref,
        wall_s_windows_legacy=t_win_ref,
        wall_s_route_legacy=t_route_ref,
        wall_s_core_fast=core_fast,
        wall_s_core_legacy=core_legacy,
        route_legacy_timed_slots=k,
        speedup_geom=t_geom_ref / max(t_geom, 1e-9),
        speedup_vis=t_vis_ref / max(t_vis, 1e-9),
        speedup_windows=t_win_ref / max(t_windows, 1e-9),
        speedup_route=t_route_ref / max(t_route, 1e-9),
        speedup_core=core_legacy / max(core_fast, 1e-9),
    )
    return row


def ci_smoke(budget_s: float) -> int:
    """Plan a 1000-satellite shell on the fast path under a wall budget."""
    geom = _shell(1000, 25)
    cand = plus_grid_candidates(geom) + sat_ground_candidates(geom, len(GROUND))
    t0 = time.perf_counter()
    plan = build_contact_plan(
        geom, 3600.0, 60.0, ground_stations=GROUND, candidates=cand,
        max_range_km=MAX_RANGE_KM, with_graphs=False,
    )
    windows = plan.windows()
    sched = plan.schedule(antennas=4)
    rels = [s.relation for s in sched.slots]
    table = earliest_delivery_routes(
        rels, plan.n_nodes, range(geom.total, plan.n_nodes))
    wall = time.perf_counter() - t0
    row = dict(
        bench="plan_synthesis_ci_smoke", n_sats=geom.total,
        n_windows=len(windows), n_slots=len(sched),
        n_routed=len(table.reachable()), wall_s=wall, budget_s=budget_s,
    )
    print("BENCH " + json.dumps(row), flush=True)
    if wall > budget_s:
        print(f"FAIL: 1000-sat plan took {wall:.1f}s > budget {budget_s:.0f}s")
        return 1
    print(f"1000-sat plan synthesized in {wall:.1f}s (budget {budget_s:.0f}s)")
    return 0


def main(argv=None) -> List[Dict]:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true", help="small cells only")
    p.add_argument("--full", action="store_true", help="whole sweep")
    p.add_argument("--ci-smoke", action="store_true",
                   help="one 1000-sat fast-path plan under --budget-s")
    p.add_argument("--budget-s", type=float, default=60.0,
                   help="ci-smoke wall-clock budget (seconds)")
    p.add_argument("--antennas", type=int, default=4)
    p.add_argument("--strategies", default="slow_first",
                   help="comma list raced vs greedy ('' = greedy only; "
                        "mwm excluded by default — O(V^3) at mega scale)")
    p.add_argument("--out", default=None, help="write BENCH rows as json")
    args = p.parse_args(argv)

    if args.ci_smoke:
        raise SystemExit(ci_smoke(args.budget_s))

    mode = "smoke" if args.smoke else ("full" if args.full else "default")
    strategies = tuple(s for s in args.strategies.split(",") if s) or None
    rows: List[Dict] = []
    hdr = (f"{'cell':<16} {'N':>5} {'win':>5} {'slots':>6} {'routed':>6} "
           f"{'fast_s':>7} {'legacy_s':>9} {'speedup':>8}")
    print(f"plan synthesis sweep ({mode}); strategies={strategies or '(greedy)'}")
    print(hdr)
    for name, geom, duration_s, step_s, cmp_legacy in _cells(mode):
        row = run_cell(name, geom, duration_s, step_s, cmp_legacy,
                       args.antennas, strategies)
        rows.append(row)
        legacy = row.get("wall_s_core_legacy")
        print(
            f"{row['cell']:<16} {row['n_sats']:>5} {row['n_windows']:>5} "
            f"{row['n_slots']:>6} {row['n_routed']:>6} "
            f"{row['wall_s_fast_total']:>7.2f} "
            + (f"{legacy:>9.2f} {row['speedup_core']:>7.1f}x"
               if legacy is not None else f"{'-':>9} {'-':>8}")
        )
        print("BENCH " + json.dumps(row), flush=True)

    big = [r for r in rows if r["n_sats"] >= 500 and "speedup_core" in r]
    if big:
        worst = min(r["speedup_core"] for r in big)
        print(f"\ncore-stage speedup at N>=500: worst {worst:.1f}x "
              f"({'MEETS' if worst >= 10.0 else 'BELOW'} the 10x bar)")
    print("TELEMETRY " + json.dumps(telemetry.counters_snapshot()), flush=True)

    if args.out:
        out_path = pathlib.Path(args.out)
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rows, indent=1))
        print(f"wrote {len(rows)} rows to {out_path}")
    return rows


if __name__ == "__main__":
    main()
