"""Collective-level benchmark of the paper's two TDM primitives on a real
device mesh (8 forced host devices): HLO collective bytes + op counts for

  get1meas   (serialized matchings — single-antenna baseline)
  getMeas    (parallel matchings — the paper's universal algorithm)
  getMeas+int8 (beyond-paper: quantized ISL payloads)
  hierarchical (pod x data two-level gossip)

and wall-clock on CPU as a sanity signal. The structural claim to verify:
both primitives move the SAME bytes for a given relation (the paper's
constant-factor gap is concurrency/scheduling, not volume), while int8
cuts payload bytes ~4x.

Run as its own process (device count lock):
  PYTHONPATH=src python -m benchmarks.tdm_collectives
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import tdm
from repro.core.relation import Relation
from repro.launch.hlo_stats import collective_stats

N = 8
SIZE = 1 << 16   # payload floats per node


def compile_and_stats(fn, x):
    mesh = jax.make_mesh((N,), ("node",))
    f = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("node"), out_specs=P("node")))
    lowered = f.lower(x)
    compiled = lowered.compile()
    stats = collective_stats(compiled.as_text())
    # wall time (CPU, rough): run a few times
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        out = f(x)
    out.block_until_ready()
    wall = (time.perf_counter() - t0) / 5
    return stats, wall


def main(argv=None):
    rel = Relation.clique(list(range(N)))
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(N, SIZE)).astype(np.float32)
    )

    variants = {
        "get1meas_serial": lambda v: tdm.get1_meas(v, rel, "node", N)[0].sum(0),
        "getmeas_multilink": lambda v: tdm.get_meas(v, rel, "node", N)[0].sum(0),
        "neighbor_sum_fp32": lambda v: tdm.neighbor_sum(v, rel, "node"),
        "neighbor_sum_int8": lambda v: tdm.neighbor_sum_int8(v, rel, "node"),
    }
    rows = {}
    print(f"{'variant':<22} {'coll bytes':>12} {'ops':>5} {'wall ms':>9}")
    for name, fn in variants.items():
        stats, wall = compile_and_stats(fn, x)
        rows[name] = dict(bytes=stats.total_bytes, ops=stats.total_count, wall=wall)
        print(f"{name:<22} {stats.total_bytes:>12.0f} {stats.total_count:>5.0f} "
              f"{wall*1e3:>9.2f}")

    same_volume = rows["get1meas_serial"]["bytes"] == rows["getmeas_multilink"]["bytes"]
    ratio = rows["neighbor_sum_fp32"]["bytes"] / max(rows["neighbor_sum_int8"]["bytes"], 1)
    print(f"\nsame bytes serial vs multilink (concurrency-only gap): {same_volume}")
    print(f"int8 payload reduction: {ratio:.2f}x (expect ~3.5-4x)")
    return rows


if __name__ == "__main__":
    main()
