"""Benchmark harness entry point: one benchmark per paper table/figure plus
the framework's own perf tables.

  fig3        paper Fig. 3 — get1meas vs getMeas clique scaling (wall time)
  constellation  geometry-driven contact plans: round time / ISL bytes sweep
  optimizer   greedy vs rate-aware TDM schedules (never-worse by oracle)
  gossip      paper P2 quantified — consensus speed per TDM topology
  moe         MoE dispatch useful-FLOPs vs capacity factor
  tdm         collective bytes/ops of the TDM primitives (subprocess: 8 devs)
  fused       fused vs per-leaf exchange engine: M vs L×M collectives + wall
              time (subprocess: 8 devs)
  groundseg   ground-segment FL: centralized/hierarchical sink rounds vs
              gossip — cost oracle + measured exchange (subprocess: 8 devs)
  pipeline    pipelined multi-window groundseg rounds: depth x window x
              staleness throughput sweep + HLO-checked measured window
              (subprocess: 8 devs)
  roofline    the 40-cell dry-run roofline table (reads experiments/dryrun)

``python -m benchmarks.run``            runs everything quick
``python -m benchmarks.run --only fig3 --full``
"""

from __future__ import annotations

import argparse
import os
import pathlib
import subprocess
import sys


def _banner(name: str):
    print(f"\n{'='*72}\n== {name}\n{'='*72}", flush=True)


def _subprocess_bench(module: str, extra_args=(), timeout: int = 1200):
    """Run a benchmark module in its own process (needed when it forces its
    own XLA device count, which locks at first jax init)."""
    root = pathlib.Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, "-m", module, *extra_args],
        cwd=root,
        env={**os.environ, "PYTHONPATH": f"{root/'src'}:{root}"},
        capture_output=True, text=True, timeout=timeout,
    )
    print(proc.stdout)
    if proc.returncode != 0:
        print(proc.stderr)
        raise SystemExit(f"{module} failed")


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None)
    p.add_argument("--full", action="store_true", help="paper-size sweeps")
    args = p.parse_args(argv)
    want = lambda n: args.only is None or args.only == n

    if want("fig3"):
        _banner("fig3: paper Fig.3 — TDM primitive scaling over a clique")
        from benchmarks import fig3_tdm_scaling
        fig3_tdm_scaling.main(["--full"] if args.full else [])

    if want("constellation"):
        _banner("constellation: geometry-driven round time / ISL traffic sweep")
        from benchmarks import constellation_round_time
        constellation_round_time.main(["--full"] if args.full else [])

    if want("optimizer"):
        _banner("optimizer: greedy vs rate-aware TDM schedules")
        from benchmarks import schedule_optimizer
        schedule_optimizer.main(["--full"] if args.full else [])

    if want("gossip"):
        _banner("gossip: consensus speed per TDM topology (paper P2)")
        from benchmarks import gossip_convergence
        gossip_convergence.main([])

    if want("moe"):
        _banner("moe: dispatch useful-FLOPs vs capacity factor")
        from benchmarks import moe_dispatch
        moe_dispatch.main([])

    if want("tdm"):
        _banner("tdm: collective bytes of get1meas / getMeas / int8 (8 devices)")
        _subprocess_bench("benchmarks.tdm_collectives")

    if want("fused"):
        _banner("fused: flat-buffer exchange engine vs per-leaf (8 devices)")
        _subprocess_bench(
            "benchmarks.fused_exchange",
            ["--full"] if args.full else ["--smoke"],
            timeout=3600,
        )

    if want("groundseg"):
        _banner("groundseg: sink-based FL vs gossip over the same schedule")
        _subprocess_bench(
            "benchmarks.groundseg_round_time",
            ["--full"] if args.full else ["--smoke"],
            timeout=3600,
        )

    if want("pipeline"):
        _banner("pipeline: pipelined multi-window groundseg round throughput")
        _subprocess_bench(
            "benchmarks.groundseg_pipeline",
            ["--full"] if args.full else ["--smoke"],
            timeout=3600,
        )

    if want("roofline"):
        _banner("roofline: 40-cell dry-run table (single-pod 16x16)")
        from benchmarks import roofline
        d = pathlib.Path("experiments/dryrun")
        if (d / "single").exists():
            roofline.main(["--mesh", "single"])
        else:
            print("experiments/dryrun/single missing — run "
                  "`python -m repro.launch.dryrun --mesh single` first")

    print("\nall benchmarks done")


if __name__ == "__main__":
    main()
