"""Benchmark harness entry point: one benchmark per paper table/figure plus
the framework's own perf tables.

  fig3        paper Fig. 3 — get1meas vs getMeas clique scaling (wall time)
  constellation  geometry-driven contact plans: round time / ISL bytes sweep
  optimizer   greedy vs rate-aware TDM schedules (never-worse by oracle)
  gossip      paper P2 quantified — consensus speed per TDM topology
  moe         MoE dispatch useful-FLOPs vs capacity factor
  tdm         collective bytes/ops of the TDM primitives (subprocess: 8 devs)
  fused       fused vs per-leaf exchange engine: M vs L×M collectives + wall
              time (subprocess: 8 devs)
  groundseg   ground-segment FL: centralized/hierarchical sink rounds vs
              gossip — cost oracle + measured exchange (subprocess: 8 devs)
  pipeline    pipelined multi-window groundseg rounds: depth x window x
              staleness throughput sweep + HLO-checked measured window
              (subprocess: 8 devs)
  plan_synthesis  mega-constellation plan synthesis: vectorized geometry /
              visibility / windows / routing-DP pipeline vs the retained
              legacy oracles (wall time + speedups)
  serving     constellation serving: TDM-slotted inference end-to-end —
              ground-station ingress, contact-graph routing, replica decode,
              downlink; deterministic sweep + churn + measured decode
              (subprocess: 8 devs)
  roofline    the 40-cell dry-run roofline table (reads experiments/dryrun)

``python -m benchmarks.run``            runs everything quick
``python -m benchmarks.run --only fig3 --full``

``--out-dir DIR`` writes one machine-readable ``BENCH_<name>.json`` per
benchmark: ``{"bench": name, "rows": [...], "telemetry": {...}}`` where
``rows`` are the benchmark's ``BENCH {json}`` lines and ``telemetry`` the
flight-recorder counters of the run (``check_regression.py`` accepts the
files, or the whole directory, as ``--run``).
"""

from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import pathlib
import subprocess
import sys


def _banner(name: str):
    print(f"\n{'='*72}\n== {name}\n{'='*72}", flush=True)


def _parse_lines(lines):
    """Pull ``BENCH {json}`` rows and ``TELEMETRY {json}`` counters out of a
    benchmark's output lines."""
    rows, counters = [], {}
    for line in lines:
        if line.startswith("BENCH "):
            try:
                rows.append(json.loads(line[len("BENCH "):]))
            except json.JSONDecodeError:
                pass
        elif line.startswith("TELEMETRY "):
            try:
                counters.update(json.loads(line[len("TELEMETRY "):]))
            except json.JSONDecodeError:
                pass
    return rows, counters


def _write_summary(out_dir, name, rows, counters):
    if out_dir is None:
        return
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"BENCH_{name}.json"
    path.write_text(json.dumps(
        {"bench": name, "rows": rows, "telemetry": counters}, indent=1
    ))
    print(f"wrote {path} ({len(rows)} rows, "
          f"{len(counters)} telemetry counters)", flush=True)


class _Tee(io.TextIOBase):
    """Pass stdout through while keeping a copy for BENCH-line parsing."""

    def __init__(self, stream):
        self.stream = stream
        self.captured: list = []
        self._buf = ""

    def write(self, s):
        self.stream.write(s)
        self._buf += s
        while "\n" in self._buf:
            line, self._buf = self._buf.split("\n", 1)
            self.captured.append(line)
        return len(s)

    def flush(self):
        self.stream.flush()

    def finish(self):
        if self._buf:
            self.captured.append(self._buf)
            self._buf = ""
        return self.captured


def _inproc_bench(name: str, fn, out_dir):
    """Run an in-process benchmark under its own flight-recorder scope,
    tee its stdout, and write the BENCH_<name>.json summary."""
    tee = _Tee(sys.stdout)
    counters = {}
    try:
        from repro import telemetry
    except ImportError:
        telemetry = None
    with contextlib.redirect_stdout(tee):
        if telemetry is None:
            fn()
        else:
            with telemetry.record_scope():
                fn()
                counters = telemetry.counters_snapshot()
    rows, printed = _parse_lines(tee.finish())
    counters.update(printed)
    _write_summary(out_dir, name, rows, counters)


def _subprocess_bench(module: str, extra_args=(), timeout: int = 1200,
                      name: str = None, out_dir=None):
    """Run a benchmark module in its own process (needed when it forces its
    own XLA device count, which locks at first jax init)."""
    root = pathlib.Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, "-m", module, *extra_args],
        cwd=root,
        env={**os.environ, "PYTHONPATH": f"{root/'src'}:{root}"},
        capture_output=True, text=True, timeout=timeout,
    )
    print(proc.stdout)
    if proc.returncode != 0:
        print(proc.stderr)
        raise SystemExit(f"{module} failed")
    rows, counters = _parse_lines(proc.stdout.splitlines())
    _write_summary(out_dir, name or module.rsplit(".", 1)[-1], rows, counters)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None)
    p.add_argument("--full", action="store_true", help="paper-size sweeps")
    p.add_argument(
        "--out-dir", default=None,
        help="write one BENCH_<name>.json (rows + telemetry counters) per "
             "benchmark into this directory",
    )
    args = p.parse_args(argv)
    want = lambda n: args.only is None or args.only == n
    out_dir = args.out_dir

    if want("fig3"):
        _banner("fig3: paper Fig.3 — TDM primitive scaling over a clique")
        from benchmarks import fig3_tdm_scaling
        _inproc_bench(
            "fig3",
            lambda: fig3_tdm_scaling.main(["--full"] if args.full else []),
            out_dir,
        )

    if want("constellation"):
        _banner("constellation: geometry-driven round time / ISL traffic sweep")
        from benchmarks import constellation_round_time
        _inproc_bench(
            "constellation",
            lambda: constellation_round_time.main(
                ["--full"] if args.full else []
            ),
            out_dir,
        )

    if want("optimizer"):
        _banner("optimizer: greedy vs rate-aware TDM schedules")
        from benchmarks import schedule_optimizer
        _inproc_bench(
            "optimizer",
            lambda: schedule_optimizer.main(["--full"] if args.full else []),
            out_dir,
        )

    if want("gossip"):
        _banner("gossip: consensus speed per TDM topology (paper P2)")
        from benchmarks import gossip_convergence
        _inproc_bench("gossip", lambda: gossip_convergence.main([]), out_dir)

    if want("moe"):
        _banner("moe: dispatch useful-FLOPs vs capacity factor")
        from benchmarks import moe_dispatch
        _inproc_bench("moe", lambda: moe_dispatch.main([]), out_dir)

    if want("tdm"):
        _banner("tdm: collective bytes of get1meas / getMeas / int8 (8 devices)")
        _subprocess_bench("benchmarks.tdm_collectives", name="tdm",
                          out_dir=out_dir)

    if want("fused"):
        _banner("fused: flat-buffer exchange engine vs per-leaf (8 devices)")
        _subprocess_bench(
            "benchmarks.fused_exchange",
            ["--full"] if args.full else ["--smoke"],
            timeout=3600,
            name="fused",
            out_dir=out_dir,
        )

    if want("groundseg"):
        _banner("groundseg: sink-based FL vs gossip over the same schedule")
        _subprocess_bench(
            "benchmarks.groundseg_round_time",
            ["--full"] if args.full else ["--smoke"],
            timeout=3600,
            name="groundseg",
            out_dir=out_dir,
        )

    if want("pipeline"):
        _banner("pipeline: pipelined multi-window groundseg round throughput")
        _subprocess_bench(
            "benchmarks.groundseg_pipeline",
            ["--full"] if args.full else ["--smoke"],
            timeout=3600,
            name="pipeline",
            out_dir=out_dir,
        )

    if want("serving"):
        _banner("serving: TDM-slotted inference over the ground segment")
        _subprocess_bench(
            "benchmarks.serving_throughput",
            ["--full"] if args.full else ["--smoke"],
            timeout=3600,
            name="serving",
            out_dir=out_dir,
        )

    if want("plan_synthesis"):
        _banner("plan_synthesis: mega-constellation plan pipeline vs legacy")
        from benchmarks import plan_synthesis
        _inproc_bench(
            "plan_synthesis",
            lambda: plan_synthesis.main(["--full"] if args.full else ["--smoke"]),
            out_dir,
        )

    if want("roofline"):
        _banner("roofline: 40-cell dry-run table (single-pod 16x16)")
        from benchmarks import roofline
        d = pathlib.Path("experiments/dryrun")
        if (d / "single").exists():
            _inproc_bench(
                "roofline", lambda: roofline.main(["--mesh", "single"]), out_dir
            )
        else:
            print("experiments/dryrun/single missing — run "
                  "`python -m repro.launch.dryrun --mesh single` first")

    print("\nall benchmarks done")


if __name__ == "__main__":
    main()
