"""Fused vs per-leaf TDM exchange: collective counts (HLO-verified) and
per-round wall time, swept over model size × relation degree — on BOTH
synthetic leaf-count sweeps and real model registries
(``models/registry.py`` smoke variants: true leaf structures, mixed shapes,
scan-stacked layers), so the L×M claim is demonstrated on the trees the FL
drivers actually exchange.

The structural claim (core/fused.py): a per-leaf round issues L×M
collective-permutes for an L-leaf model over an M-matching relation (2M per
leaf-payload-component for compressed modes), while the fused flat-buffer
engine issues exactly M (2M for int8: payload + scales; top-k bit-packs
values + indices into ONE int32 payload so it stays at M) — independent
of L.
Collective counts come from the compiled HLO via
``launch.hlo_stats.collective_stats``; wall time is measured on the forced
8-host-device mesh (launch overhead dominates there exactly as it does on a
real mesh, which is the effect being benchmarked).

Emits one ``BENCH {json}`` line per measured cell plus a summary row, and
optionally writes the full row list to ``--out`` (the nightly workflow
uploads it so the perf trajectory is recorded).

Run as its own process (device count lock):
  PYTHONPATH=src python -m benchmarks.fused_exchange --smoke
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import telemetry
from repro.core import fl, tdm
from repro.core.relation import Relation
from repro.core.schedule import ring
from repro.launch.hlo_stats import collective_stats

N = 8


def make_tree(n_leaves: int, leaf_elems: int, seed: int = 0, n: int = N):
    """Synthetic L-leaf model, stacked on the node axis. Shapes are jittered
    (+leaf index) so no two leaves are identical arrays XLA could CSE.
    (Also the payload generator for benchmarks/groundseg_round_time.py.)"""
    rng = np.random.default_rng(seed)
    return {
        f"w{i:03d}": jnp.asarray(
            rng.normal(size=(n, leaf_elems + i)).astype(np.float32)
        )
        for i in range(n_leaves)
    }


def make_registry_tree(arch_name: str):
    """A REAL model's parameter pytree (smoke-sized registry variant),
    stacked on the node axis — the exact tree ``launch/fl_train`` ships
    through the exchange engine."""
    from repro.configs import archs
    from repro.models import registry

    cfg = archs.smoke_cfg(archs.get(arch_name))
    params, _ = registry.bundle(cfg).init(jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (N,) + x.shape), params
    )


def model_cells(names):
    """(label, tree, n_leaves, elems_per_node, min_leaf) for synthetic specs
    ``(n_leaves, leaf_elems)`` and registry arch-name strings alike.
    ``min_leaf`` bounds the per-leaf top-k payload (``jax.lax.top_k``
    requires k <= leaf size; the fused engine has no such limit)."""
    cells = []
    for spec in names:
        if isinstance(spec, str):
            tree = make_registry_tree(spec)
            label = spec
        else:
            n_leaves, leaf_elems = spec
            tree = make_tree(n_leaves, leaf_elems)
            label = f"synth-L{n_leaves}"
        leaves = jax.tree.leaves(tree)
        sizes = [int(np.prod(l.shape[1:])) for l in leaves]
        cells.append((label, tree, len(leaves), sum(sizes), min(sizes)))
    return cells


def relations():
    return {
        "ring": ring(N),                                   # degree 2
        "circ4": Relation.from_edges(
            [(i, (i + d) % N) for i in range(N) for d in (1, 2)]
        ),                                                 # degree 4
        "clique": Relation.clique(list(range(N))),         # degree 7
    }


def build_round_fn(mesh, rel, cfg):
    def body(t):
        t = jax.tree.map(lambda x: x[0], t)
        out, _ = fl.tdm_fla_round(t, rel, "node", N, cfg)
        return jax.tree.map(lambda x: x[None], out)

    # check_rep=False: the fused int8 path may lower through pallas_call,
    # which has no shard_map replication rule
    return jax.jit(
        shard_map(
            body, mesh=mesh, in_specs=(P("node"),), out_specs=P("node"),
            check_rep=False,
        )
    )


def measure(fn, tree, reps: int):
    # time the AOT executable itself — fn(tree) would re-trace and compile
    # a second copy through the jit dispatch cache
    rec = telemetry.get_recorder()
    with rec.span("bench.compile", cat="compile"):
        compiled = fn.lower(tree).compile()
    stats = collective_stats(compiled.as_text())
    out = compiled(tree)
    jax.block_until_ready(out)
    with rec.span("bench.measure", cat="bench", reps=reps):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = compiled(tree)
        jax.block_until_ready(out)
        wall = (time.perf_counter() - t0) / reps
    rec.counter("bench.measured_cells")
    return stats, wall


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true", help="single small cell")
    p.add_argument("--full", action="store_true", help="paper-size sweeps")
    p.add_argument("--reps", type=int, default=None)
    p.add_argument("--out", default=None, help="write BENCH rows as json")
    p.add_argument("--trace", default=None,
                   help="write a Chrome trace (Perfetto) of this run")
    p.add_argument("--report", default=None, metavar="PREFIX",
                   help="write PREFIX.md/.json mission report of this run")
    args = p.parse_args(argv)
    with telemetry.trace_scope(args.trace):
        rows = _main(args)
        print("TELEMETRY " + json.dumps(telemetry.counters_snapshot()),
              flush=True)
        if args.report:
            from repro.telemetry.report import write_report

            md, js = write_report(
                args.report,
                title="fused exchange bench",
                extra={
                    "bench": "fused_exchange",
                    "n_rows": len(rows),
                    "args": {
                        "smoke": args.smoke, "full": args.full,
                        "reps": args.reps,
                    },
                },
            )
            print(f"wrote mission report to {md} and {js}")
    return rows


def _main(args):
    if args.smoke:
        models = [(12, 1 << 10), "mamba2-780m"]
        rel_names = ["ring", "clique"]
        modes = ["none", "int8", "topk"]
        reps = args.reps or 3
    elif args.full:
        models = [
            (12, 1 << 10), (48, 1 << 12), (96, 1 << 14),
            "mamba2-780m", "gemma2-9b", "qwen3-moe-30b-a3b",
        ]
        rel_names = ["ring", "circ4", "clique"]
        modes = ["none", "int8", "topk"]
        reps = args.reps or 10
    else:
        models = [(12, 1 << 10), (48, 1 << 12), "mamba2-780m", "gemma2-9b"]
        rel_names = ["ring", "clique"]
        modes = ["none", "int8", "topk"]
        reps = args.reps or 5

    mesh = Mesh(np.array(jax.devices()[:N]), ("node",))
    rels = relations()
    rows = []
    print(
        f"{'model':<16} {'rel':<7} {'mode':<5} {'engine':<8} "
        f"{'permutes':>8} {'coll MB':>8} {'wall ms':>9}"
    )
    for label, tree, n_leaves, elems, min_leaf in model_cells(models):
        for rel_name in rel_names:
            rel = rels[rel_name]
            n_matchings = len(tdm.edge_coloring(rel))
            for mode in modes:
                cell = {}
                # per-leaf top-k caps k at the smallest leaf (top_k errors
                # above it); the collective COUNT is k-independent, so the
                # permute comparison is unaffected
                topk_k = min(64, min_leaf)
                for engine in ("perleaf", "fused"):
                    cfg = fl.TDMFLAConfig(
                        compression=mode, topk_k=topk_k,
                        fused=(engine == "fused"),
                    )
                    fn = build_round_fn(mesh, rel, cfg)
                    stats, wall = measure(fn, tree, reps)
                    permutes = stats.count_by_kind.get("collective-permute", 0)
                    row = dict(
                        bench="fused_exchange",
                        model=label,
                        n_leaves=n_leaves,
                        elems=elems,
                        relation=rel_name,
                        n_matchings=n_matchings,
                        mode=mode,
                        engine=engine,
                        permutes=permutes,
                        collective_bytes=stats.total_bytes,
                        wall_ms=wall * 1e3,
                    )
                    rows.append(row)
                    cell[engine] = row
                    print(
                        f"{label:<16} {rel_name:<7} "
                        f"{mode:<5} {engine:<8} {permutes:>8.0f} "
                        f"{stats.total_bytes/2**20:>8.2f} {wall*1e3:>9.2f}"
                    )
                    print("BENCH " + json.dumps(row), flush=True)
                speedup = cell["perleaf"]["wall_ms"] / max(
                    cell["fused"]["wall_ms"], 1e-9
                )
                summary = dict(
                    bench="fused_exchange_summary",
                    model=label,
                    n_leaves=n_leaves,
                    elems=elems,
                    relation=rel_name,
                    mode=mode,
                    n_matchings=n_matchings,
                    permutes_perleaf=cell["perleaf"]["permutes"],
                    permutes_fused=cell["fused"]["permutes"],
                    permute_reduction=cell["perleaf"]["permutes"]
                    / max(cell["fused"]["permutes"], 1),
                    speedup=speedup,
                )
                rows.append(summary)
                print("BENCH " + json.dumps(summary), flush=True)

    # headline: uncompressed cells must show M vs L*M and a wall-time win
    best = max(
        (r for r in rows if r["bench"] == "fused_exchange_summary"),
        key=lambda r: r["speedup"],
    )
    print(
        f"\nbest fused speedup: {best['speedup']:.2f}x "
        f"({best['model']} L={best['n_leaves']}, {best['relation']}, "
        f"mode={best['mode']}; permutes {best['permutes_perleaf']:.0f} -> "
        f"{best['permutes_fused']:.0f})"
    )
    if args.out:
        # summary-object form ({bench, rows, telemetry}) so
        # check_regression can trend the flight-recorder counters too
        out_path = pathlib.Path(args.out)
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps({
            "bench": "fused_exchange",
            "rows": rows,
            "telemetry": telemetry.counters_snapshot(),
        }, indent=1))
        print(f"wrote {len(rows)} rows to {out_path}")
    return rows


if __name__ == "__main__":
    main()
