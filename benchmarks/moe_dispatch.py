"""MoE dispatch efficiency: useful-FLOPs ratio and dispatch traffic vs
capacity factor, from the staged-program cost model (no device execution).

Shows why the decode path needed capacity-floor surgery (EXPERIMENTS.md
§Perf): E*C slot padding multiplies wasted expert FLOPs when tokens/group
is small.

Run:  PYTHONPATH=src python -m benchmarks.moe_dispatch
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.launch.flops import program_costs
from repro.models import moe as moe_lib


def measure(cfg, B, S):
    x = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    params = jax.eval_shape(
        lambda: moe_lib.init_moe(jax.random.PRNGKey(0), cfg)[0]
    )

    def f(p, x):
        out, aux = moe_lib.moe_apply(p, x, cfg)
        return out

    costs = program_costs(f, params, x)
    m = cfg.moe
    useful = 2.0 * 3 * cfg.d_model * m.d_ff * m.top_k * B * S  # active expert flops
    return costs.flops, useful


def main(argv=None):
    base = archs.get("qwen3-moe-30b-a3b")
    print(f"{'cell':<18} {'cf':>5} {'staged GF':>10} {'useful GF':>10} {'ratio':>6}")
    for name, B, S in (("train-like", 8, 4096), ("decode-like", 128, 1)):
        for cf in (1.0, 1.25, 2.0):
            cfg = base.replace(moe=dataclasses.replace(base.moe, capacity_factor=cf))
            staged, useful = measure(cfg, B, S)
            print(f"{name:<18} {cf:>5.2f} {staged/1e9:>10.1f} {useful/1e9:>10.1f} "
                  f"{useful/staged:>6.2f}")
    return 0


if __name__ == "__main__":
    main()
