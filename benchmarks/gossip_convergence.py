"""Quantitative face of paper Property 2: consensus speed of TDM schedules.

For each topology: spectral gap of the per-slot Metropolis mixing matrix,
slots to full data propagation (the P2 closure), and measured rounds to
1e-6 consensus — clique (the paper's evaluation case) vs ring vs hypercube
vs Walker visibility schedules, at several constellation sizes.

Run:  PYTHONPATH=src python -m benchmarks.gossip_convergence
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.gossip import (
    metropolis_weights,
    slots_to_full_propagation,
    spectral_gap,
)
from repro.core.relation import Relation
from repro.constellation.scenario import ScenarioSpec, ShellSpec, build_scenario
from repro.core.schedule import hypercube_schedule, ring


def measured_rounds(schedule_gen, n: int, tol: float = 1e-6, cap: int = 5000) -> int:
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, 8))
    target = x.mean(axis=0)
    t = 0
    while np.abs(x - target).max() > tol and t < cap:
        W = metropolis_weights(schedule_gen(t), n)
        x = W @ x
        t += 1
    return t


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--sizes", default="8,16,24")
    args = p.parse_args(argv)
    sizes = [int(s) for s in args.sizes.split(",")]

    print(f"{'topology':<18} {'n':>4} {'gap':>8} {'propagate':>10} {'rounds@1e-6':>12}")
    for n in sizes:
        clique = Relation.clique(list(range(n)))
        topos = {
            "clique (paper)": lambda t, r=clique: r,
            "ring": lambda t, n=n: ring(n),
        }
        if (n & (n - 1)) == 0:
            hc = hypercube_schedule(n)
            topos["hypercube"] = lambda t, hc=hc: hc[t % len(hc)]
        if n % 4 == 0:
            scn = build_scenario(ScenarioSpec(
                shells=(ShellSpec(planes=4, per_plane=n // 4),),
                n_ground=0, steps=32,
            ))
            rels = scn.relations()
            topos["walker 4-plane"] = lambda t, r=rels: r[t % len(r)]

        for name, gen in topos.items():
            gap = spectral_gap(metropolis_weights(gen(0), n))
            prop = slots_to_full_propagation(gen, n)
            rounds = measured_rounds(gen, n)
            print(f"{name:<18} {n:>4} {gap:>8.4f} {prop:>10} {rounds:>12}")
    return 0


if __name__ == "__main__":
    main()
