"""Ground-segment round time: centralized / hierarchical FL through ground
sinks vs pure decentralized gossip, over Walker shells × ground-station
counts.

Two layers, emitted as ``BENCH {json}`` lines (and optionally ``--out``):

1. **Cost-oracle sweep** (any constellation size, pure Python): for each
   (planes × sats/plane) shell and ground-station count, route the
   materialized TDM schedule through
   :func:`repro.constellation.cost.groundseg_mode_costs` and report the
   estimated round time / ISL traffic of centralized, hierarchical, and
   both gossip primitives, plus delivery statistics from the router. Note
   the semantics: sink-based times are *delivery spans* (store-and-forward
   waits for geometry — idle gaps count), gossip times are link-busy
   seconds; traffic is directly comparable (relay ships one payload per
   hop, gossip one per directed pair per slot).

2. **Measured exchange** (8 forced host devices): the compiled
   ground-segment exchange (uplink relay -> sink FedAvg -> downlink
   broadcast on the fused buffers) and the equivalent per-slot fused
   gossip pass over the SAME schedule, wall-clocked and HLO-counted, so
   the oracle's centralized-vs-decentralized ordering can be checked
   against what the collectives actually cost on a mesh.

Run as its own process (device count lock):
  PYTHONPATH=src python -m benchmarks.groundseg_round_time --smoke
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import pathlib
import time

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import telemetry
from repro.constellation import cost
from repro.constellation.scenario import ScenarioSpec, ShellSpec, build_scenario
from repro.core import fl, tdm
from repro.groundseg import aggregation, routing
from repro.launch.hlo_stats import collective_stats

QUICK_SHELLS = [(2, 3), (2, 4)]
FULL_SHELLS = [(2, 3), (2, 4), (3, 4), (4, 5)]


def build_plan(planes, per_plane, n_gs, altitude_km, steps):
    """One scenario-factory deployment; the ground segment is the canonical
    ``scenario.GROUND_SITES`` prefix (this file used to carry its own copy)."""
    scn = build_scenario(ScenarioSpec(
        shells=(ShellSpec(
            planes=planes, per_plane=per_plane, altitude_km=altitude_km,
        ),),
        n_ground=n_gs,
        steps=steps,
    ))
    return scn.geom, scn.plan, scn.ground_ids


def oracle_rows(shells, gs_counts, payload_bytes, antennas, steps, altitude):
    rows = []
    for planes, per in shells:
        for n_gs in gs_counts:
            geom, plan, sinks = build_plan(planes, per, n_gs, altitude, steps)
            sched = plan.schedule(antennas=antennas, payload_bytes=payload_bytes)
            rels = list(sched.tdm)
            table = routing.earliest_delivery_routes(rels, plan.n_nodes, sinks)
            est = cost.groundseg_mode_costs(
                plan, sinks, payload_bytes, antennas=antennas
            )
            for mode, rc in est.items():
                rows.append(dict(
                    bench="groundseg_round_time",
                    planes=planes, per_plane=per, n_sats=geom.total,
                    n_gs=n_gs, mode=mode,
                    est_time_s=rc.time_s,
                    est_mbytes_isl=rc.bytes_on_isl / 1e6,
                    n_slots=rc.n_slots,
                    reachable=len(table.reachable()),
                    unreachable=len(table.unreachable()),
                    sched_span_s=sched.span_s,
                    sched_busy_s=sched.busy_s,
                ))
    return rows


# ---------------------------------------------------------------------------
# Measured exchange on the host-device mesh
# ---------------------------------------------------------------------------

def measure(fn, tree, reps):
    rec = telemetry.get_recorder()
    with rec.span("bench.compile", cat="compile"):
        compiled = fn.lower(tree).compile()
    stats = collective_stats(compiled.as_text())
    out = compiled(tree)
    jax.block_until_ready(out)
    with rec.span("bench.measure", cat="bench", reps=reps):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = compiled(tree)
        jax.block_until_ready(out)
        wall = (time.perf_counter() - t0) / reps
    rec.counter("bench.measured_cells")
    return stats, wall


def measured_rows(payload_bytes, payload_leaves, leaf_elems, antennas, steps,
                  altitude, reps, gs_counts):
    from benchmarks.fused_exchange import make_tree

    rows = []
    for n_gs in gs_counts:
        geom, plan, sinks = build_plan(2, 3, n_gs, altitude, steps)
        n = plan.n_nodes
        if n > len(jax.devices()):
            print(
                f"skipping measured cell {geom.total}sat+{n_gs}gs: needs "
                f"{n} devices, mesh has {len(jax.devices())} "
                "(oracle rows above still cover it)"
            )
            continue
        mesh = Mesh(np.array(jax.devices()[:n]), ("node",))
        sched = plan.schedule(antennas=antennas, payload_bytes=payload_bytes)
        rels = [r for r in sched.tdm]
        up = routing.build_relay_program(rels, n, sinks)
        down = routing.build_broadcast_program(rels, n, sinks)
        est = cost.groundseg_mode_costs(
            plan, sinks, payload_bytes, antennas=antennas
        )
        tree = make_tree(payload_leaves, leaf_elems, n=n)

        def wrap(body):
            return jax.jit(shard_map(
                body, mesh=mesh, in_specs=(P("node"),), out_specs=P("node"),
                check_rep=False,
            ))

        def groundseg_body(compression):
            def body(t):
                t = jax.tree.map(lambda x: x[0], t)
                out = aggregation.groundseg_round(
                    t, up, down, "node", pool=True, compression=compression,
                )
                return jax.tree.map(lambda x: x[None], out)
            return body

        def gossip_body(t):
            t = jax.tree.map(lambda x: x[0], t)
            for rel in rels:
                if len(rel) == 0:
                    continue
                t, _ = fl.tdm_fla_round(t, rel, "node", n, fl.TDMFLAConfig())
            return jax.tree.map(lambda x: x[None], t)

        cells = {
            "centralized": wrap(groundseg_body("none")),
            "centralized_int8": wrap(groundseg_body("int8")),
            "gossip": wrap(gossip_body),
        }
        for engine, fn in cells.items():
            stats, wall = measure(fn, tree, reps)
            oracle = est["centralized" if engine.startswith("centralized")
                         else "gossip_getmeas"]
            row = dict(
                bench="groundseg_measured",
                n_sats=geom.total, n_gs=n_gs, engine=engine,
                permutes=stats.count_by_kind.get("collective-permute", 0),
                collective_bytes=stats.total_bytes,
                wall_ms=wall * 1e3,
                est_time_s=oracle.time_s,
                est_mbytes_isl=oracle.bytes_on_isl / 1e6,
            )
            rows.append(row)
            print(
                f"measured {geom.total}sat+{n_gs}gs {engine:<17} "
                f"permutes {row['permutes']:>5.0f}  "
                f"coll {stats.total_bytes/2**20:>7.2f} MB  "
                f"wall {wall*1e3:>8.2f} ms  oracle {oracle.time_s:>9.1f} s"
            )
            print("BENCH " + json.dumps(row), flush=True)
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true", help="small sweep")
    p.add_argument("--full", action="store_true", help="larger shells")
    p.add_argument("--steps", type=int, default=10)
    p.add_argument("--antennas", type=int, default=2)
    p.add_argument("--altitude", type=float, default=8062.0)
    p.add_argument("--payload-mib", type=float, default=4.0)
    p.add_argument("--reps", type=int, default=None)
    p.add_argument("--out", default=None, help="write BENCH rows as json")
    p.add_argument("--trace", default=None,
                   help="write a Chrome trace (Perfetto) of this run")
    args = p.parse_args(argv)
    with telemetry.trace_scope(args.trace):
        rows = _main(args)
        print("TELEMETRY " + json.dumps(telemetry.counters_snapshot()),
              flush=True)
    return rows


def _main(args):
    if args.smoke:
        shells, gs_counts, reps = QUICK_SHELLS[:1], [1, 2], args.reps or 3
        leaves, elems = 8, 1 << 10
    elif args.full:
        shells, gs_counts, reps = FULL_SHELLS, [1, 2, 3, 4], args.reps or 10
        leaves, elems = 24, 1 << 12
    else:
        shells, gs_counts, reps = QUICK_SHELLS, [1, 2], args.reps or 5
        leaves, elems = 12, 1 << 10

    payload = int(args.payload_mib * (1 << 20))
    rows = oracle_rows(shells, gs_counts, payload, args.antennas, args.steps,
                       args.altitude)
    hdr = (f"{'shell':>6} {'gs':>3} {'mode':<17} {'est_time_s':>11} "
           f"{'MB_ISL':>8} {'slots':>6} {'reach':>6}")
    print(hdr)
    for r in rows:
        print(
            f"{r['planes']}x{r['per_plane']:<4} {r['n_gs']:>3} "
            f"{r['mode']:<17} {r['est_time_s']:>11.2f} "
            f"{r['est_mbytes_isl']:>8.1f} {r['n_slots']:>6} "
            f"{r['reachable']:>3}/{r['reachable'] + r['unreachable']:<3}"
        )
        print("BENCH " + json.dumps(r), flush=True)

    rows += measured_rows(payload, leaves, elems, args.antennas, args.steps,
                          args.altitude, reps, gs_counts)

    # headline: traffic ratio of the sink route vs gossip on the biggest cell
    cent = [r for r in rows if r["bench"] == "groundseg_round_time"
            and r["mode"] == "centralized" and r["reachable"] > 0]
    goss = {(r["planes"], r["per_plane"], r["n_gs"]): r for r in rows
            if r.get("mode") == "gossip_getmeas"}
    if cent:
        best = max(
            cent,
            key=lambda r: goss[(r["planes"], r["per_plane"], r["n_gs"])][
                "est_mbytes_isl"] / max(r["est_mbytes_isl"], 1e-9),
        )
        g = goss[(best["planes"], best["per_plane"], best["n_gs"])]
        ratio = g["est_mbytes_isl"] / max(best["est_mbytes_isl"], 1e-9)
        summary = dict(
            bench="groundseg_summary",
            planes=best["planes"], per_plane=best["per_plane"],
            n_gs=best["n_gs"], traffic_ratio_gossip_over_central=ratio,
        )
        rows.append(summary)
        print(
            f"\nbest ISL-traffic win: centralized ships {ratio:.1f}x fewer "
            f"bytes than gossip on {best['planes']}x{best['per_plane']} "
            f"+{best['n_gs']}gs"
        )
        print("BENCH " + json.dumps(summary), flush=True)

    if args.out:
        out_path = pathlib.Path(args.out)
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rows, indent=1))
        print(f"wrote {len(rows)} rows to {out_path}")
    return rows


if __name__ == "__main__":
    main()
