import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Perf lab: lower+compile named config VARIANTS of the hillclimb cells and
log their roofline terms to experiments/perf/ — the §Perf iteration record.

Usage: PYTHONPATH=src:. python -m benchmarks.perf_lab [--only name] [--mesh single]
"""

import argparse
import json
import pathlib
import time

from repro.configs import archs
from repro.launch.dryrun import analyze, lower_cell
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES
from benchmarks.roofline import recompute_terms


def variant(name, arch, shape, **overrides):
    return dict(name=name, arch=arch, shape=shape, overrides=overrides)


VARIANTS = [
    # --- gemma2-9b train: escape the small-model TP trap ------------------
    variant("gemma2-9b_train_fsdp", "gemma2-9b", "train_4k",
            parallel_mode="fsdp"),
    variant("gemma2-9b_train_fsdp_pure", "gemma2-9b", "train_4k",
            parallel_mode="fsdp_pure"),
    variant("gemma2-9b_train_pp", "gemma2-9b", "train_4k",
            pp_stages=16, pp_micro=64),
    # --- mamba2 train: same trap, smaller model ---------------------------
    variant("mamba2_train_fsdp_pure", "mamba2-780m", "train_4k",
            parallel_mode="fsdp_pure"),
    # --- qwen2-72b train: FSDP x micro gather traffic ----------------------
    variant("qwen2-72b_train_fsdp_micro1", "qwen2-72b", "train_4k",
            micro_steps=1),
    variant("qwen2-72b_train_pp", "qwen2-72b", "train_4k",
            pp_stages=16, pp_micro=64),
    # --- serving modes ------------------------------------------------------
    variant("qwen2-72b_decode_tp", "qwen2-72b", "decode_32k"),
    variant("kimi_decode_tp2d", "kimi-k2-1t-a32b", "decode_32k",
            serve_parallel_mode="tp2d"),
    variant("kimi_train_pp", "kimi-k2-1t-a32b", "train_4k",
            pp_stages=16, pp_micro=64),
]


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None)
    p.add_argument("--out", default="experiments/perf")
    args = p.parse_args(argv)
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    mesh = make_production_mesh()

    for v in VARIANTS:
        if args.only and args.only not in v["name"]:
            continue
        out = out_dir / f"{v['name']}.json"
        if out.exists():
            print(f"CACHED {v['name']}")
            continue
        cfg = archs.get(v["arch"]).replace(**v["overrides"])
        shape = SHAPES[v["shape"]]
        print(f"LOWER {v['name']} ...", flush=True)
        t0 = time.time()
        try:
            lowered, staged = lower_cell(cfg, shape, mesh)
            compiled = lowered.compile()
        except Exception as e:
            print(f"  FAILED: {e}")
            out.write_text(json.dumps({"name": v["name"], "error": str(e)}))
            continue
        d = recompute_terms(
            analyze(compiled, staged, cfg, shape, mesh, 0, time.time() - t0)
        )
        d["variant"] = v["name"]
        d["overrides"] = {k: str(val) for k, val in v["overrides"].items()}
        out.write_text(json.dumps(d, indent=2))
        rf = d["roofline"]
        print(
            f"  OK {time.time()-t0:.0f}s compute={rf['compute_s']:.2f}s "
            f"mem={rf['memory_s']:.2f}s coll={rf['collective_s']:.2f}s "
            f"dominant={rf['dominant']} MFU={rf['roofline_mfu']*100:.1f}%",
            flush=True,
        )
    print("PERF LAB DONE")


if __name__ == "__main__":
    main()
