"""BENCH-json trending: compare a benchmark run against a committed
baseline and fail on regression.

The nightly workflow runs each benchmark with ``--out run.json`` and then::

  python -m benchmarks.check_regression \\
      --run bench-fused.json \\
      --baseline benchmarks/baselines/fused_exchange.json

Rows are matched by their IDENTITY fields (every non-float scalar not
named in ``--metrics``: bench name, model, relation, mode, engine, shell
shape, ...). For each matched row, each metric present in both sides is
compared lower-is-better; a run value more than ``--threshold`` (default
20%) above the baseline fails the job. A baseline row with no matching run
row also fails — silently dropping a swept cell is how perf regressions
hide. Improvements beyond the threshold are reported (refresh the baseline
to bank them) but never fail.

Default metrics are the DETERMINISTIC ones (collective counts/bytes and
the analytic cost-oracle estimates) so shared CI runners can't flake the
job; add ``wall_ms`` via ``--metrics`` when the runner is dedicated
hardware.

Summary objects also carry the flight-recorder ``telemetry`` counter
snapshot; when both sides have counters they are diffed too —
DIRECTION-AGNOSTIC (a counter drifting either way means the executed
collective schedule changed, which is drift whether it got "better" or
worse), with zero-baseline -> nonzero and missing counters failing
outright. Filter which counters gate the job with ``--telemetry-prefix``
(default trends them all); disable with ``--no-telemetry``.

The gate never passes vacuously: a zero-row run or baseline, an explicitly
requested metric matching no baseline row, or zero compared metric cells
overall each fail the job — a trender that compares nothing must not be
green.

To (re)generate a baseline, run the benchmark with the same flags CI uses
and commit its ``--out`` file under ``benchmarks/baselines/``.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
from typing import Dict, List, Tuple

DEFAULT_METRICS = (
    "permutes",
    "collective_bytes",
    "est_mbytes_isl",
    "permutes_perleaf",
    "permutes_fused",
)


def row_key(row: Dict, metrics) -> Tuple:
    """Identity of a BENCH row: its non-float scalar fields (bench name,
    labels, sweep coordinates) minus anything being compared as a metric."""
    return tuple(
        sorted(
            (k, v)
            for k, v in row.items()
            if k not in metrics
            and isinstance(v, (str, int, bool))
            and not isinstance(v, float)
        )
    )


def _load_one(path: pathlib.Path) -> List[Dict]:
    data = json.loads(path.read_text())
    if isinstance(data, dict) and isinstance(data.get("rows"), list):
        # a benchmarks/run.py --out-dir summary: {"bench", "rows", "telemetry"}
        return data["rows"]
    if isinstance(data, list):
        return data
    raise SystemExit(
        f"{path}: expected a json list of BENCH rows or a "
        "BENCH_<name>.json summary object"
    )


def load_rows(path: str) -> List[Dict]:
    """Rows from a ``--out`` list, a ``BENCH_<name>.json`` summary, or a
    DIRECTORY of summaries (``benchmarks/run.py --out-dir``) — directory
    rows are concatenated, so one baseline dir can trend a whole run."""
    p = pathlib.Path(path)
    if p.is_dir():
        files = sorted(p.glob("BENCH_*.json")) or sorted(p.glob("*.json"))
        if not files:
            raise SystemExit(f"{path}: no BENCH_*.json files in directory")
        rows: List[Dict] = []
        for f in files:
            rows.extend(_load_one(f))
        return rows
    return _load_one(p)


def _telemetry_of(path: pathlib.Path) -> Dict[str, float]:
    data = json.loads(path.read_text())
    if isinstance(data, dict) and isinstance(data.get("telemetry"), dict):
        return {
            k: float(v)
            for k, v in data["telemetry"].items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)
        }
    return {}


def load_telemetry(path: str) -> Dict[str, float]:
    """Flight-recorder counters from a summary object (or a directory of
    them, counters summed across benches — collisions like
    ``bench.measured_cells`` accumulate exactly as a combined run would).
    Plain row-list files carry no counters -> {} (telemetry diff skipped)."""
    p = pathlib.Path(path)
    if p.is_dir():
        files = sorted(p.glob("BENCH_*.json")) or sorted(p.glob("*.json"))
        merged: Dict[str, float] = {}
        for f in files:
            for k, v in _telemetry_of(f).items():
                merged[k] = merged.get(k, 0.0) + v
        return merged
    return _telemetry_of(p)


def compare_telemetry(
    baseline: Dict[str, float],
    run: Dict[str, float],
    threshold: float,
    prefix: str = "",
):
    """Diff counter snapshots. Returns (failures, table_rows).

    Unlike BENCH metrics this is direction-agnostic: a counter moving
    EITHER way beyond the threshold means the executed schedule changed
    (e.g. a collective-permute appearing or disappearing), which is drift
    regardless of sign. Missing counters and zero-baseline -> nonzero fail;
    counters only present in the run are listed as ``new`` but don't fail
    (adding instrumentation shouldn't break the nightly)."""
    failures: List[str] = []
    table: List[Tuple[str, str, str, str, str, str, str]] = []
    for name in sorted(baseline):
        if prefix and not name.startswith(prefix):
            continue
        b = baseline[name]
        if name not in run:
            failures.append(f"[telemetry] counter {name} missing from run")
            table.append(("telemetry", "", name, f"{b:.6g}", "missing", "—",
                          "FAIL"))
            continue
        r = run[name]
        if b == 0:
            if r != 0:
                failures.append(
                    f"[telemetry] {name} drifted from zero baseline to "
                    f"{r:.6g}"
                )
                table.append(("telemetry", "", name, "0", f"{r:.6g}", "—",
                              "DRIFTED"))
            else:
                table.append(("telemetry", "", name, "0", "0", "+0.0%", "ok"))
            continue
        ratio = r / b
        delta = f"{(ratio - 1) * 100:+.1f}%"
        if abs(ratio - 1.0) > threshold:
            failures.append(
                f"[telemetry] {name} drifted {b:.6g} -> {r:.6g} "
                f"({delta})"
            )
            status = "DRIFTED"
        else:
            status = "ok"
        table.append(("telemetry", "", name, f"{b:.6g}", f"{r:.6g}", delta,
                      status))
    for name in sorted(set(run) - set(baseline)):
        if prefix and not name.startswith(prefix):
            continue
        table.append(("telemetry", "", name, "—", f"{run[name]:.6g}", "—",
                      "new"))
    return failures, table


def compare(
    baseline: List[Dict],
    run: List[Dict],
    metrics,
    threshold: float,
):
    """Returns (failures, improvements, checked, table) where ``table`` is
    one per-metric delta row [bench, label, metric, base, run, delta%,
    status] for every compared cell — the job-summary table."""
    run_by_key: Dict[Tuple, Dict] = {}
    for row in run:
        run_by_key[row_key(row, metrics)] = row
    failures: List[str] = []
    improvements: List[str] = []
    table: List[Tuple[str, str, str, str, str, str, str]] = []
    checked = 0
    for base in baseline:
        relevant = [m for m in metrics if m in base]
        if not relevant:
            continue
        key = row_key(base, metrics)
        label = " ".join(f"{k}={v}" for k, v in key if k != "bench")
        bench = dict(key).get("bench", "?")
        got = run_by_key.get(key)
        if got is None:
            failures.append(f"[{bench}] {label}: row missing from run")
            table.append((bench, label, "—", "—", "missing", "—", "FAIL"))
            continue
        for m in relevant:
            if m not in got:
                failures.append(f"[{bench}] {label}: metric {m} missing")
                table.append((bench, label, m, f"{float(base[m]):.6g}",
                              "missing", "—", "FAIL"))
                continue
            b, r = float(base[m]), float(got[m])
            checked += 1
            if b <= 0:
                # no ratio exists at a zero baseline, but a nonzero run
                # value IS a regression (e.g. undelivered going 0 -> 6) —
                # the zero baselines are exactly the guarantees to keep
                if b == 0 and r > 0:
                    failures.append(
                        f"[{bench}] {label}: {m} regressed from zero "
                        f"baseline to {r:.6g}"
                    )
                    table.append((bench, label, m, f"{b:.6g}", f"{r:.6g}",
                                  "—", "REGRESSED"))
                else:
                    table.append((bench, label, m, f"{b:.6g}", f"{r:.6g}",
                                  "—", "ok"))
                continue
            ratio = r / b
            delta = f"{(ratio - 1) * 100:+.1f}%"
            if ratio > 1.0 + threshold:
                failures.append(
                    f"[{bench}] {label}: {m} regressed "
                    f"{b:.6g} -> {r:.6g} (+{(ratio - 1) * 100:.1f}%)"
                )
                status = "REGRESSED"
            elif ratio < 1.0 - threshold:
                improvements.append(
                    f"[{bench}] {label}: {m} improved "
                    f"{b:.6g} -> {r:.6g} ({(ratio - 1) * 100:.1f}%) — "
                    "consider refreshing the baseline"
                )
                status = "improved"
            else:
                status = "ok"
            table.append((bench, label, m, f"{b:.6g}", f"{r:.6g}", delta,
                          status))
    return failures, improvements, checked, table


_TABLE_HEADER = ("bench", "cell", "metric", "baseline", "run", "delta",
                 "status")


def format_table(table, markdown: bool = False) -> str:
    """Render the per-metric delta table — plain text for the job log,
    GitHub-flavored markdown for $GITHUB_STEP_SUMMARY."""
    rows = [_TABLE_HEADER] + [tuple(r) for r in table]
    if markdown:
        lines = ["| " + " | ".join(_TABLE_HEADER) + " |",
                 "|" + "---|" * len(_TABLE_HEADER)]
        lines += ["| " + " | ".join(r) + " |" for r in table]
        return "\n".join(lines)
    widths = [max(len(r[i]) for r in rows) for i in range(len(_TABLE_HEADER))]
    lines = []
    for i, r in enumerate(rows):
        lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())
        if i == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def write_step_summary(table, failures, improvements, checked,
                       baseline_name: str, threshold: float) -> None:
    """Append the delta table to the GitHub Actions job summary when
    running inside a workflow ($GITHUB_STEP_SUMMARY set); no-op locally."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    verdict = (
        f"❌ {len(failures)} regression(s)" if failures else "✅ no regressions"
    )
    body = (
        f"### Benchmark trend vs `{baseline_name}`\n\n"
        f"{verdict} — {checked} metric cells checked, "
        f"{len(improvements)} improvement(s) beyond "
        f"±{threshold * 100:.0f}%\n\n"
        + format_table(table, markdown=True)
        + "\n"
    )
    with open(path, "a") as fh:
        fh.write(body)


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--run", required=True, help="this run's --out json")
    p.add_argument("--baseline", required=True, help="committed baseline json")
    p.add_argument(
        "--metrics",
        default=",".join(DEFAULT_METRICS),
        help="comma-separated lower-is-better metrics to compare",
    )
    p.add_argument("--threshold", type=float, default=0.20,
                   help="fractional regression that fails (default 0.20)")
    p.add_argument("--no-telemetry", action="store_true",
                   help="skip the flight-recorder counter diff")
    p.add_argument("--telemetry-prefix", default="",
                   help="only diff counters with this prefix (default: all)")
    args = p.parse_args(argv)
    metrics = tuple(m.strip() for m in args.metrics.split(",") if m.strip())

    base_rows = load_rows(args.baseline)
    run_rows = load_rows(args.run)
    # Guard against the silent-pass failure modes: an empty row list on
    # either side means the bench crashed mid-run (or wrote a stub), and a
    # gate that compares nothing would exit 0 right past it.
    failures: List[str] = []
    if not base_rows:
        failures.append(
            f"[guard] baseline {args.baseline} contains zero BENCH rows"
        )
    if not run_rows:
        failures.append(f"[guard] run {args.run} contains zero BENCH rows")
    explicit_metrics = args.metrics != ",".join(DEFAULT_METRICS)
    if base_rows and explicit_metrics:
        # explicitly requested metrics must exist somewhere in the baseline
        # — a typo'd --metrics list must not pass by matching nothing (the
        # default list is a cross-bench union, so it is exempt)
        for m in metrics:
            if not any(m in row for row in base_rows):
                failures.append(
                    f"[guard] requested metric {m!r} matches no baseline row"
                )

    cmp_failures, improvements, checked, table = compare(
        base_rows, run_rows, metrics, args.threshold
    )
    failures += cmp_failures
    if not args.no_telemetry:
        base_tel = load_telemetry(args.baseline)
        run_tel = load_telemetry(args.run)
        if base_tel and run_tel:
            tel_failures, tel_table = compare_telemetry(
                base_tel, run_tel, args.threshold, args.telemetry_prefix
            )
            failures += tel_failures
            table += tel_table
            checked += sum(1 for r in tel_table if r[6] != "new")
    if checked == 0 and not failures:
        # nothing compared and nothing else flagged it: every baseline row
        # lacked the requested metrics — loud failure, not a green gate
        failures.append(
            "[guard] zero metric cells compared (no baseline row carries "
            f"any of: {', '.join(metrics)})"
        )
    if table:
        print(format_table(table))
        print()
    for line in improvements:
        print(f"IMPROVED  {line}")
    for line in failures:
        print(f"REGRESSED {line}")
    print(
        f"\nchecked {checked} metric cells against "
        f"{pathlib.Path(args.baseline).name}: "
        f"{len(failures)} regression(s), {len(improvements)} improvement(s) "
        f"beyond ±{args.threshold * 100:.0f}%"
    )
    write_step_summary(
        table, failures, improvements, checked,
        pathlib.Path(args.baseline).name, args.threshold,
    )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
