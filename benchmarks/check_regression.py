"""BENCH-json trending: compare a benchmark run against a committed
baseline and fail on regression.

The nightly workflow runs each benchmark with ``--out run.json`` and then::

  python -m benchmarks.check_regression \\
      --run bench-fused.json \\
      --baseline benchmarks/baselines/fused_exchange.json

Rows are matched by their IDENTITY fields (every non-float scalar not
named in ``--metrics``: bench name, model, relation, mode, engine, shell
shape, ...). For each matched row, each metric present in both sides is
compared lower-is-better; a run value more than ``--threshold`` (default
20%) above the baseline fails the job. A baseline row with no matching run
row also fails — silently dropping a swept cell is how perf regressions
hide. Improvements beyond the threshold are reported (refresh the baseline
to bank them) but never fail.

Default metrics are the DETERMINISTIC ones (collective counts/bytes and
the analytic cost-oracle estimates) so shared CI runners can't flake the
job; add ``wall_ms`` via ``--metrics`` when the runner is dedicated
hardware.

To (re)generate a baseline, run the benchmark with the same flags CI uses
and commit its ``--out`` file under ``benchmarks/baselines/``.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Tuple

DEFAULT_METRICS = (
    "permutes",
    "collective_bytes",
    "est_mbytes_isl",
    "permutes_perleaf",
    "permutes_fused",
)


def row_key(row: Dict, metrics) -> Tuple:
    """Identity of a BENCH row: its non-float scalar fields (bench name,
    labels, sweep coordinates) minus anything being compared as a metric."""
    return tuple(
        sorted(
            (k, v)
            for k, v in row.items()
            if k not in metrics
            and isinstance(v, (str, int, bool))
            and not isinstance(v, float)
        )
    )


def load_rows(path: str) -> List[Dict]:
    rows = json.loads(pathlib.Path(path).read_text())
    if not isinstance(rows, list):
        raise SystemExit(f"{path}: expected a json list of BENCH rows")
    return rows


def compare(
    baseline: List[Dict],
    run: List[Dict],
    metrics,
    threshold: float,
):
    """Returns (failures, improvements, checked) as printable strings."""
    run_by_key: Dict[Tuple, Dict] = {}
    for row in run:
        run_by_key[row_key(row, metrics)] = row
    failures: List[str] = []
    improvements: List[str] = []
    checked = 0
    for base in baseline:
        relevant = [m for m in metrics if m in base]
        if not relevant:
            continue
        key = row_key(base, metrics)
        label = " ".join(f"{k}={v}" for k, v in key if k != "bench")
        bench = dict(key).get("bench", "?")
        got = run_by_key.get(key)
        if got is None:
            failures.append(f"[{bench}] {label}: row missing from run")
            continue
        for m in relevant:
            if m not in got:
                failures.append(f"[{bench}] {label}: metric {m} missing")
                continue
            b, r = float(base[m]), float(got[m])
            checked += 1
            if b <= 0:
                continue
            ratio = r / b
            if ratio > 1.0 + threshold:
                failures.append(
                    f"[{bench}] {label}: {m} regressed "
                    f"{b:.6g} -> {r:.6g} (+{(ratio - 1) * 100:.1f}%)"
                )
            elif ratio < 1.0 - threshold:
                improvements.append(
                    f"[{bench}] {label}: {m} improved "
                    f"{b:.6g} -> {r:.6g} ({(ratio - 1) * 100:.1f}%) — "
                    "consider refreshing the baseline"
                )
    return failures, improvements, checked


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--run", required=True, help="this run's --out json")
    p.add_argument("--baseline", required=True, help="committed baseline json")
    p.add_argument(
        "--metrics",
        default=",".join(DEFAULT_METRICS),
        help="comma-separated lower-is-better metrics to compare",
    )
    p.add_argument("--threshold", type=float, default=0.20,
                   help="fractional regression that fails (default 0.20)")
    args = p.parse_args(argv)
    metrics = tuple(m.strip() for m in args.metrics.split(",") if m.strip())

    failures, improvements, checked = compare(
        load_rows(args.baseline), load_rows(args.run), metrics, args.threshold
    )
    for line in improvements:
        print(f"IMPROVED  {line}")
    for line in failures:
        print(f"REGRESSED {line}")
    print(
        f"\nchecked {checked} metric cells against "
        f"{pathlib.Path(args.baseline).name}: "
        f"{len(failures)} regression(s), {len(improvements)} improvement(s) "
        f"beyond ±{args.threshold * 100:.0f}%"
    )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
