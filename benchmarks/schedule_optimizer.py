"""Greedy vs rate-aware TDM schedules over a constellation shape sweep.

For each Walker-delta shape the contact plan is generated from orbital
mechanics (propagation -> occlusion -> FSPL link budget) and two schedules
are materialized for the same antenna budget and payload:

- **greedy** — the first legal coloring (Misra–Gries matchings packed
  first-fit), PR 1's rate-blind baseline,
- **rate**   — the optimizer's strategy portfolio (slow-first grouping,
  max-weight-matching peeling, slew-warm ordering), scored by the analytic
  cost oracle; the greedy schedule is always in the candidate set, so the
  reported rate-aware round time can never exceed the greedy one.

Reported per shape: round time for both, the winning strategy, ISL bytes
(identical by construction — same edges, same payload), and sub-slot
counts. A second pass prices terminal slew/acquisition to show the warm-
link effect. The final verdict line checks the never-worse invariant on
every swept shape.

``PYTHONPATH=src python -m benchmarks.schedule_optimizer [--full]``
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

import numpy as np

from repro.constellation import contact_plan, cost, orbits
from repro.constellation.optimizer import optimize_schedule

QUICK_SHAPES = [(2, 4), (4, 5), (4, 8)]
FULL_SHAPES = [(2, 4), (2, 8), (3, 5), (4, 5), (4, 8), (6, 6), (8, 8)]


def sweep_one(
    planes: int,
    per_plane: int,
    altitude_km: float,
    steps: int,
    payload_bytes: int,
    antennas: int,
    acquisition_s: float,
) -> Dict:
    geom = orbits.WalkerDelta(
        total=planes * per_plane, planes=planes, altitude_km=altitude_km
    )
    plan = contact_plan.build_contact_plan(
        geom, duration_s=geom.period_s, step_s=geom.period_s / steps
    )
    res = optimize_schedule(
        plan,
        antennas=antennas,
        payload_bytes=payload_bytes,
        acquisition_s=acquisition_s,
    )
    return dict(
        planes=planes,
        per_plane=per_plane,
        n=geom.total,
        acq_s=acquisition_s,
        greedy_s=res.baseline.time_s,
        rate_s=res.chosen.time_s,
        strategy=res.strategy,
        speedup=res.speedup,
        gbytes_isl=res.chosen.bytes_on_isl / 1e9,
        bytes_equal=res.chosen.bytes_on_isl == res.baseline.bytes_on_isl,
        greedy_slots=res.baseline.n_slots,
        rate_slots=res.chosen.n_slots,
        never_worse=res.chosen.time_s <= res.baseline.time_s + 1e-9,
    )


def main(argv=None) -> List[Dict]:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true", help="larger shape sweep")
    p.add_argument("--altitude", type=float, default=8062.0,
                   help="shell altitude km (default MEO: sparse shapes keep LOS)")
    p.add_argument("--steps", type=int, default=8, help="contact-plan steps/orbit")
    p.add_argument("--payload-mib", type=float, default=4.0)
    p.add_argument("--antennas", type=int, default=2)
    p.add_argument("--acquisition-s", type=float, default=2.0,
                   help="slew/PAT penalty per freshly pointed link (2nd pass)")
    p.add_argument("--json", type=str, default=None)
    args = p.parse_args(argv)
    if args.steps < 1:
        p.error("--steps must be >= 1")
    if args.payload_mib <= 0:
        p.error("--payload-mib must be positive")

    payload = int(args.payload_mib * (1 << 20))
    shapes = FULL_SHAPES if args.full else QUICK_SHAPES
    rows = []
    for planes, per in shapes:
        for acq in (0.0, args.acquisition_s):
            rows.append(
                sweep_one(planes, per, args.altitude, args.steps, payload,
                          args.antennas, acq)
            )

    hdr = (f"{'shape':>7} {'n':>4} {'acq_s':>6} {'greedy_s':>10} {'rate_s':>10} "
           f"{'speedup':>8} {'strategy':>10} {'GB_ISL':>7} {'slots g/r':>10}")
    print(f"payload {args.payload_mib:.1f} MiB, altitude {args.altitude:.0f} km, "
          f"{args.steps} steps/orbit, {args.antennas} antennas/sat")
    print(hdr)
    for r in rows:
        print(
            f"{r['planes']}x{r['per_plane']:<5} {r['n']:>4} {r['acq_s']:>6.1f} "
            f"{r['greedy_s']:>10.3f} {r['rate_s']:>10.3f} {r['speedup']:>7.2f}x "
            f"{r['strategy']:>10} {r['gbytes_isl']:>7.2f} "
            f"{r['greedy_slots']:>4}/{r['rate_slots']}"
        )
    ok = all(r["never_worse"] for r in rows)
    same_bytes = all(r["bytes_equal"] for r in rows)
    gain = float(np.mean([r["speedup"] for r in rows]))
    print(f"\nrate-aware <= greedy on every shape: "
          f"{'CONFIRMED' if ok else 'VIOLATED'}; identical ISL bytes: "
          f"{'yes' if same_bytes else 'NO'}; mean speedup {gain:.2f}x")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    if not ok:
        raise SystemExit("optimizer lost to the greedy baseline")
    return rows


if __name__ == "__main__":
    main()
