"""Constellation shape sweep: round time and ISL traffic from pure geometry.

For each Walker-delta shape (planes x sats/plane), with and without
cross-plane ISLs, the contact plan is generated from orbital mechanics
(propagation -> Earth-occlusion line of sight -> FSPL link budget) and the
analytic cost model reports, per one-orbit FL round:

- wall-clock comm time for getMeas (multi-antenna, matchings concurrent)
  vs get1meas (single-antenna, matchings serialized) — the paper's Fig. 3
  comparison on physical link parameters,
- bytes shipped over inter-satellite links,
- antenna-constrained sub-slot count for a fixed terminal budget.

Satellites that lose line of sight simply have no pairs that step (the
paper's skip-slot case), so sparse shapes show fewer links, not failures.

``PYTHONPATH=src python -m benchmarks.constellation_round_time [--full]``
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List, Tuple

import numpy as np

from repro.constellation import contact_plan, cost, orbits

QUICK_SHAPES = [(2, 4), (4, 5), (4, 8)]
FULL_SHAPES = [(2, 4), (2, 8), (3, 5), (4, 5), (4, 8), (6, 6), (8, 8)]


def intra_plane_candidates(geom: orbits.WalkerDelta) -> List[Tuple[int, int]]:
    """All same-plane pairs — the cross-plane-less terminal fit."""
    out = []
    for p in range(geom.planes):
        ids = [geom.node_id(p, k) for k in range(geom.per_plane)]
        out.extend(
            (ids[a], ids[b]) for a in range(len(ids)) for b in range(a + 1, len(ids))
        )
    return out


def sweep_one(
    planes: int,
    per_plane: int,
    cross_plane: bool,
    altitude_km: float,
    steps: int,
    payload_bytes: int,
    antennas: int,
) -> Dict:
    geom = orbits.WalkerDelta(
        total=planes * per_plane, planes=planes, altitude_km=altitude_km
    )
    plan = contact_plan.build_contact_plan(
        geom,
        duration_s=geom.period_s,
        step_s=geom.period_s / steps,
        candidates="all" if cross_plane else intra_plane_candidates(geom),
    )
    links_per_step = [len(g) for g in plan.graphs]
    multi = cost.plan_cost(plan, payload_bytes, mode="getmeas")
    single = cost.plan_cost(plan, payload_bytes, mode="get1meas")
    sched = plan.schedule(antennas=antennas, payload_bytes=payload_bytes)
    return dict(
        planes=planes,
        per_plane=per_plane,
        n=geom.total,
        cross=cross_plane,
        mean_links=float(np.mean(links_per_step)),
        windows=len(plan.windows()),
        getmeas_s=multi.time_s,
        get1meas_s=single.time_s,
        ratio=single.time_s / multi.time_s if multi.time_s else float("nan"),
        gbytes_isl=multi.bytes_on_isl / 1e9,
        subslots=len(sched),
        sched_busy_s=sched.busy_s,
        sched_span_s=sched.span_s,
    )


def main(argv=None) -> List[Dict]:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true", help="larger shape sweep")
    p.add_argument("--altitude", type=float, default=8062.0,
                   help="shell altitude km (default MEO: sparse shapes keep LOS)")
    p.add_argument("--steps", type=int, default=12, help="contact-plan steps/orbit")
    p.add_argument("--payload-mib", type=float, default=4.0)
    p.add_argument("--antennas", type=int, default=3)
    p.add_argument("--json", type=str, default=None)
    args = p.parse_args(argv)
    if args.steps < 1:
        p.error("--steps must be >= 1")
    if args.payload_mib <= 0:
        p.error("--payload-mib must be positive")

    payload = int(args.payload_mib * (1 << 20))
    shapes = FULL_SHAPES if args.full else QUICK_SHAPES
    rows = []
    for planes, per in shapes:
        for cross in (True, False):
            rows.append(
                sweep_one(planes, per, cross, args.altitude, args.steps,
                          payload, args.antennas)
            )

    hdr = (f"{'shape':>7} {'n':>4} {'xlinks':>6} {'links':>6} {'win':>4} "
           f"{'getMeas_s':>10} {'get1meas_s':>11} {'ratio':>6} "
           f"{'GB_ISL':>7} {'subslots':>8}")
    print(f"payload {args.payload_mib:.1f} MiB, altitude {args.altitude:.0f} km, "
          f"{args.steps} steps/orbit, {args.antennas} antennas/sat")
    print(hdr)
    for r in rows:
        print(
            f"{r['planes']}x{r['per_plane']:<5} {r['n']:>4} "
            f"{'yes' if r['cross'] else 'no':>6} {r['mean_links']:>6.1f} "
            f"{r['windows']:>4} {r['getmeas_s']:>10.3f} {r['get1meas_s']:>11.3f} "
            f"{r['ratio']:>6.2f} {r['gbytes_isl']:>7.2f} {r['subslots']:>8}"
        )
    with_cross = [r for r in rows if r["cross"] and r["getmeas_s"] > 0]
    if with_cross:
        gap = float(np.mean([r["ratio"] for r in with_cross]))
        print(f"\nmean get1meas/getMeas gap over geometric plans: {gap:.2f}x "
              f"({'CONFIRMS' if gap > 1.0 else 'REFUTES'} the paper's Fig. 3 ordering)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


if __name__ == "__main__":
    main()
