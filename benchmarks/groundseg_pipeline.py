"""Pipelined ground-segment rounds: depth x window-length x staleness sweep.

The tentpole claim this benchmark trends: at ``pipeline_depth=2`` round
r's downlink flood and round r+1's uplink relay share ONE contact window
on disjoint slot capacity, so the engine completes one round per window
instead of one per two (the one-shot engine traverses the window twice —
uplink, then "the next identical window" for the downlink). Steady-state
round throughput should be >= 1.5x depth 1 on the MEO shell sweep
(2.0x when the leftover capacity still covers every satellite, which it
does on these shells — the ``uncovered`` metric would show otherwise).

Two layers, emitted as ``BENCH {json}`` lines (and optionally ``--out``):

1. **Cost-oracle sweep** (pure Python, deterministic): for each shell x
   window-length (contact-plan steps) x staleness-horizon x depth cell,
   the steady-state throughput model (:func:`repro.constellation.cost.
   groundseg_throughput`), the occupancy oracle
   (:func:`~repro.constellation.cost.groundseg_schedule_cost`) and the
   router's delivery statistics. A delay-tolerance cell kills one
   satellite for the warm-up window and reports the stale delivery age
   once it revives.

2. **Measured exchange** (8 forced host devices): the compiled pipelined
   window (:func:`repro.groundseg.aggregation.pipelined_window_round`) at
   depth 1 vs depth 2, HLO collective counts checked against the extended
   ``expected_collectives`` static oracle (deterministic), wall clock
   advisory.

Run as its own process (device count lock):
  PYTHONPATH=src python -m benchmarks.groundseg_pipeline --smoke
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import pathlib
import time

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import telemetry
from repro.constellation import cost
from repro.constellation.scenario import ScenarioSpec, ShellSpec, build_scenario
from repro.groundseg import aggregation, routing
from repro.launch.hlo_stats import collective_stats
from repro.telemetry import audit

N_GS = 2   # canonical scenario.GROUND_SITES prefix (equator + midlat-e)

QUICK_SHELLS = [(2, 3)]
DEFAULT_SHELLS = [(2, 3), (2, 4)]
FULL_SHELLS = [(2, 3), (2, 4), (3, 4), (4, 5)]


def build_sched(planes, per_plane, steps, altitude_km, antennas, payload):
    """One scenario-factory deployment; the ground segment is the canonical
    ``scenario.GROUND_SITES`` prefix (this file used to carry its own copy)."""
    scn = build_scenario(ScenarioSpec(
        shells=(ShellSpec(
            planes=planes, per_plane=per_plane, altitude_km=altitude_km,
        ),),
        n_ground=N_GS,
        steps=steps,
        antennas=antennas,
        payload_bytes=payload,
    ))
    sinks = sorted(scn.ground_ids)
    return scn.geom, scn.plan, scn.schedule(), sinks


def oracle_rows(shells, steps_list, staleness_list, payload, antennas,
                altitude):
    rows = []
    for planes, per in shells:
        for steps in steps_list:
            geom, plan, sched, sinks = build_sched(
                planes, per, steps, altitude, antennas, payload
            )
            for stale in staleness_list:
                per_depth = {}
                for depth in (1, 2):
                    th = cost.groundseg_throughput(
                        sched, sinks, n_nodes=plan.n_nodes,
                        pipeline_depth=depth, max_staleness_windows=stale,
                    )
                    occ = cost.groundseg_schedule_cost(
                        sched, sinks, payload, n_nodes=plan.n_nodes,
                        pipeline_depth=depth, max_staleness_windows=stale,
                    )
                    n_sats = geom.total
                    row = dict(
                        bench="groundseg_pipeline",
                        planes=planes, per_plane=per, n_sats=n_sats,
                        n_gs=N_GS, steps=steps,
                        staleness=stale, depth=depth,
                        window_s=th["window_s"],
                        est_occupancy_s=occ.time_s,
                        est_mbytes_isl=occ.bytes_on_isl / 1e6,
                        thpt_rounds_per_ks=th["round_throughput_per_s"] * 1e3,
                        undelivered=float(n_sats - th["delivered"]),
                        uncovered=float(n_sats - th["covered"]),
                        carried=th["carried"],
                        dropped=th["dropped"],
                    )
                    per_depth[depth] = row
                    rows.append(row)
                ratio = (
                    per_depth[2]["thpt_rounds_per_ks"]
                    / max(per_depth[1]["thpt_rounds_per_ks"], 1e-12)
                )
                rows.append(dict(
                    bench="groundseg_pipeline_summary",
                    planes=planes, per_plane=per, steps=steps,
                    staleness=stale,
                    throughput_ratio_d2_over_d1=ratio,
                    # lower-is-better form for the regression trender
                    inv_throughput_ratio=1.0 / max(ratio, 1e-12),
                ))
    return rows


def delay_tolerance_rows(payload, antennas, altitude, steps, staleness):
    """Deterministic delay-tolerance scenario: one satellite is OCCLUDED
    (alive, so it snapshots a payload, but contactless) for the warm-up
    window; once its contacts return the queued payload delivers one
    window stale — the oracle-side twin of the multi-device staleness
    tests."""
    geom, plan, sched, sinks = build_sched(
        2, 3, steps, altitude, antennas, payload
    )
    rels = list(sched.tdm)
    n = plan.n_nodes
    occluded = 0
    others = set(range(n)) - {occluded}
    router = routing.MultiWindowRouter(
        n, sinks, max_staleness_windows=staleness, pipeline_depth=2
    )
    # window 0: the satellite is live (injects its snapshot) but none of
    # its contacts exist — the payload must persist
    wp0 = router.plan_window([r.restrict(others) for r in rels])
    wp1 = router.plan_window(rels)          # contacts back: stale delivery
    # route-provenance audit over the scenario's per-window slot relations
    verdict = audit.audit_window_programs(
        [wp0, wp1], decay=0.5,
        slots=[[r.restrict(others) for r in rels], rels],
    )
    rows = [dict(
        bench="groundseg_delay_tolerance",
        planes=2, per_plane=3, steps=steps, staleness=staleness,
        occluded_sat=occluded,
        warmup_delivered=float(wp0.uplink.delivered_count()),
        warmup_carried=float(len(wp0.residual)),
        steady_delivered=float(wp1.uplink.delivered_count()),
        stale_age=float(wp1.delivered_ages.get(occluded, -1)),
        dropped=float(len(wp1.dropped)),
        audit_violations=float(len(verdict.violations)),
    )]
    return rows, verdict


# ---------------------------------------------------------------------------
# Measured pipelined exchange on the host-device mesh
# ---------------------------------------------------------------------------

def measure(fn, args, reps):
    rec = telemetry.get_recorder()
    with rec.span("bench.compile", cat="compile"):
        compiled = fn.lower(*args).compile()
    stats = collective_stats(compiled.as_text())
    out = compiled(*args)
    jax.block_until_ready(out)
    with rec.span("bench.measure", cat="bench", reps=reps):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = compiled(*args)
        jax.block_until_ready(out)
        wall = (time.perf_counter() - t0) / reps
    rec.counter("bench.measured_cells")
    return stats, wall


def measured_rows(payload_leaves, leaf_elems, antennas, steps, altitude,
                  reps):
    from benchmarks.fused_exchange import make_tree

    rows = []
    geom, plan, sched, sinks = build_sched(
        2, 3, steps, altitude, antennas, 1 << 22
    )
    n = plan.n_nodes
    if n > len(jax.devices()):
        print(f"skipping measured cells: need {n} devices, "
              f"have {len(jax.devices())}")
        return rows
    mesh = Mesh(np.array(jax.devices()[:n]), ("node",))
    rels = list(sched.tdm)
    tree = make_tree(payload_leaves, leaf_elems, n=n)
    from repro.core import fused
    spec = fused.build_spec(
        jax.tree.map(lambda x: x[0], tree)
    )
    carry = aggregation.stacked_zero_buffers(spec, n)
    pend = aggregation.stacked_zero_buffers(spec, n)

    for depth in (1, 2):
        router = routing.MultiWindowRouter(
            n, sinks, max_staleness_windows=2, pipeline_depth=depth
        )
        router.plan_window(rels)
        wp = router.plan_window(rels)   # steady-state window

        def body(t, c, p, wp=wp):
            t = jax.tree.map(lambda x: x[0], t)
            c = jax.tree.map(lambda x: x[0], c)
            p = jax.tree.map(lambda x: x[0], p)
            out, nc, npend = aggregation.pipelined_window_round(
                t, c, p, wp, "node", pool=True, staleness_decay=0.5,
            )
            return tuple(
                jax.tree.map(lambda x: x[None], z) for z in (out, nc, npend)
            )

        fn = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P("node"),) * 3,
            out_specs=(P("node"),) * 3, check_rep=False,
        ))
        stats, wall = measure(fn, (tree, carry, pend), reps)
        want = aggregation.expected_window_collectives(
            wp, len(spec.buckets), compression="none", pool=True
        )
        got_permutes = stats.count_by_kind.get("collective-permute", 0)
        ok = got_permutes == want["collective-permute"]
        row = dict(
            bench="groundseg_pipeline_measured",
            n_sats=geom.total, n_gs=len(sinks), depth=depth,
            permutes=got_permutes,
            expected_permutes=want["collective-permute"],
            oracle_match=bool(ok),
            collective_bytes=stats.total_bytes,
            wall_ms=wall * 1e3,
        )
        rows.append(row)
        print(
            f"measured depth {depth}: permutes {got_permutes} "
            f"(oracle {want['collective-permute']}, "
            f"{'match' if ok else 'MISMATCH'})  "
            f"coll {stats.total_bytes/2**20:.2f} MB  wall {wall*1e3:.2f} ms"
        )
        print("BENCH " + json.dumps(row), flush=True)
        if not ok:
            raise SystemExit(
                "HLO collective count diverged from the static oracle"
            )
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true", help="small sweep")
    p.add_argument("--full", action="store_true", help="larger shells")
    p.add_argument("--antennas", type=int, default=2)
    p.add_argument("--altitude", type=float, default=8062.0)
    p.add_argument("--payload-mib", type=float, default=4.0)
    p.add_argument("--reps", type=int, default=None)
    p.add_argument("--out", default=None, help="write BENCH rows as json")
    p.add_argument("--trace", default=None,
                   help="write a Chrome trace (Perfetto) of this run")
    p.add_argument("--report", default=None, metavar="PREFIX",
                   help="write PREFIX.md/.json mission report of this run")
    args = p.parse_args(argv)
    with telemetry.trace_scope(args.trace):
        rows, verdict = _main(args)
        print("TELEMETRY " + json.dumps(telemetry.counters_snapshot()),
              flush=True)
        if args.report:
            from repro.telemetry.report import write_report

            md, js = write_report(
                args.report,
                audit=verdict,
                title="groundseg pipeline bench",
                extra={
                    "bench": "groundseg_pipeline",
                    "n_rows": len(rows),
                    "args": {
                        "smoke": args.smoke, "full": args.full,
                        "reps": args.reps, "antennas": args.antennas,
                    },
                },
            )
            print(f"wrote mission report to {md} and {js}")
        if not verdict.ok:
            raise SystemExit(
                f"route-provenance audit failed: "
                f"{len(verdict.violations)} violation(s)"
            )
    return rows


def _main(args):
    if args.smoke:
        shells, steps_list, stales, reps = QUICK_SHELLS, [8], [0, 2], 3
        leaves, elems = 8, 1 << 10
    elif args.full:
        shells, steps_list = FULL_SHELLS, [8, 12, 16]
        stales, reps = [0, 1, 2, 4], 10
        leaves, elems = 24, 1 << 12
    else:
        shells, steps_list = DEFAULT_SHELLS, [8, 12]
        stales, reps = [0, 1, 2], 5
        leaves, elems = 12, 1 << 10
    reps = args.reps or reps

    payload = int(args.payload_mib * (1 << 20))
    rows = oracle_rows(shells, steps_list, stales, payload, args.antennas,
                       args.altitude)
    hdr = (f"{'shell':>6} {'steps':>6} {'stale':>6} {'depth':>6} "
           f"{'thpt/ks':>9} {'occup_s':>9} {'undeliv':>8} {'uncov':>6}")
    print(hdr)
    for r in rows:
        if r["bench"] != "groundseg_pipeline":
            continue
        print(
            f"{r['planes']}x{r['per_plane']:<4} {r['steps']:>6} "
            f"{r['staleness']:>6} {r['depth']:>6} "
            f"{r['thpt_rounds_per_ks']:>9.4f} {r['est_occupancy_s']:>9.1f} "
            f"{r['undelivered']:>8.0f} {r['uncovered']:>6.0f}"
        )
    for r in rows:
        print("BENCH " + json.dumps(r), flush=True)

    dt_rows, verdict = delay_tolerance_rows(
        payload, args.antennas, args.altitude, steps_list[0],
        max(stales) or 2,
    )
    rows += dt_rows
    print("BENCH " + json.dumps(rows[-1]), flush=True)
    print(
        f"route-provenance audit: {verdict.n_windows} windows, "
        f"{verdict.n_payloads} payloads, {verdict.n_hops} hops, "
        f"{len(verdict.violations)} violation(s)"
    )

    rows += measured_rows(leaves, elems, args.antennas, steps_list[0],
                          args.altitude, reps)

    ratios = [
        r["throughput_ratio_d2_over_d1"]
        for r in rows
        if r["bench"] == "groundseg_pipeline_summary"
    ]
    if ratios:
        print(
            f"\npipelining win: depth-2 round throughput "
            f"{min(ratios):.2f}x-{max(ratios):.2f}x depth-1 across "
            f"{len(ratios)} sweep cells (>= 1.5x expected)"
        )

    if args.out:
        out_path = pathlib.Path(args.out)
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rows, indent=1))
        print(f"wrote {len(rows)} rows to {out_path}")
    return rows, verdict


if __name__ == "__main__":
    main()
