"""Serving-throughput roofline per decode cell: tokens/s/chip and
latency-per-token bounds from the dry-run artifacts — the numbers a serving
capacity planner actually wants.

    latency_bound  = max(compute_s, memory_s, collective_s)   per step
    tokens/s/chip  = global_batch / latency_bound / chips
    batch-1 floor  = params_bytes/chip / HBM_bw  (weights-read floor)

Run: PYTHONPATH=src:. python -m benchmarks.serving_throughput
"""

from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.roofline import recompute_terms
from repro.configs import archs


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="experiments/dryrun")
    p.add_argument("--mesh", default="single")
    args = p.parse_args(argv)
    d = pathlib.Path(args.dir) / args.mesh

    print(f"{'arch':<22} {'cell':<12} {'ms/token':>9} {'tok/s/chip':>11} "
          f"{'bound':<10} {'weights-floor ms':>16}")
    for f in sorted(d.glob("*.json")):
        r = recompute_terms(json.loads(f.read_text()))
        if r["kind"] != "decode":
            continue
        rf = r["roofline"]
        step = rf["bound_step_seconds"]
        chips = r["chips"]
        batch = {"decode_32k": 128, "long_500k": 1}[r["shape"]]
        tok_s_chip = batch / step / chips
        cfg = archs.get(r["arch"])
        wbytes = cfg.param_count() * 2 / chips  # bf16 serving cast
        floor_ms = wbytes / 819e9 * 1e3
        print(f"{r['arch']:<22} {r['shape']:<12} {step*1e3:>9.2f} "
              f"{tok_s_chip:>11.2f} {rf['dominant'].replace('_s',''):<10} "
              f"{floor_ms:>16.3f}")
    return 0


if __name__ == "__main__":
    main()
