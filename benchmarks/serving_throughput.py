"""Constellation serving throughput: end-to-end TDM-slotted inference.

Requests arrive at ground stations, ride earliest-delivery contact-graph
routes up to satellite model replicas, decode under the TDM slot structure
(wave discipline per replica, continuous batching across the fleet), and
return on downlink slots. Every cell is a full :class:`repro.serving.
ServingEngine` run over a :func:`repro.constellation.scenario.
build_scenario` deployment, route-provenance audited.

Three layers, emitted as ``BENCH {json}`` lines (and optionally ``--out``):

1. **Deterministic transport sweep** (pure host, :class:`NullDecoder`):
   shells x ground-station counts x replica counts — delivered counts,
   p50/p99 request latency and TTFT in slots, request throughput per slot
   and per simulated second (slot durations from the contact plan), audit
   violations. Bit-deterministic, so the nightly trends it via
   ``check_regression.py`` against ``benchmarks/baselines/
   serving_throughput.json``.
2. **Churn cell** (deterministic): a replica dies mid-run and later
   returns; the gate is zero lost requests and a green audit — re-route,
   never lose.
3. **Measured decode** (8 forced host devices): the same engine driving a
   real stacked-``shard_map`` :class:`ModelDecoder` fleet; wall clock is
   advisory (token counts and audit stay deterministic). Skipped with
   ``--no-measured`` or when the device pool is too small.

Run as its own process (device count lock):
  PYTHONPATH=src:. python -m benchmarks.serving_throughput --smoke
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import pathlib
import time

from repro import telemetry
from repro.constellation.scenario import ScenarioSpec, ShellSpec, build_scenario
from repro.serving import (
    NullDecoder,
    ReplicaFleet,
    ServingEngine,
    audit_serving_run,
    synthesize_workload,
)
from repro.telemetry.audit import AuditReport

QUICK_SHELLS = [(2, 3), (2, 4), (3, 4)]
FULL_SHELLS = [(2, 3), (2, 4), (3, 4), (4, 5)]


def make_scenario(planes, per_plane, n_gs, steps):
    return build_scenario(ScenarioSpec(
        shells=(ShellSpec(planes=planes, per_plane=per_plane),),
        n_ground=n_gs,
        steps=steps,
    ))


def pick_replicas(n_sats, n_replicas):
    """Spread replicas across the shell (every n/k-th satellite)."""
    n_replicas = min(n_replicas, n_sats)
    return sorted({i * n_sats // n_replicas for i in range(n_replicas)})


def run_cell(scn, replica_ids, batch, workload, *, on_slot=None,
             decoder=None, max_slots=None):
    """One engine run + audit; returns (report, audit, engine)."""
    decoder = decoder or NullDecoder(len(replica_ids), batch)
    fleet = ReplicaFleet(replica_ids, batch, decoder)
    eng = ServingEngine.from_scenario(scn, fleet)
    report = eng.run(workload, max_slots=max_slots, on_slot=on_slot)
    verdict = audit_serving_run(
        report.records, report.requests, eng.base_rels,
        gateways=eng.gateways, replicas=replica_ids,
    )
    return report, verdict, eng


def transport_rows(shells, gs_counts, replica_counts, *, steps, n_requests,
                   rate, max_new, batch):
    rows, audits = [], []
    for planes, per in shells:
        for n_gs in gs_counts:
            scn = make_scenario(planes, per, n_gs, steps)
            for n_rep in replica_counts:
                if n_rep >= scn.n_sats:
                    continue
                reps = pick_replicas(scn.n_sats, n_rep)
                workload = synthesize_workload(
                    n_requests, scn.ground_ids, rate_per_slot=rate,
                    max_new=max_new, seed=scn.spec.seed,
                )
                report, verdict, _ = run_cell(scn, reps, batch, workload)
                audits.append(verdict)
                row = dict(
                    bench="serving_throughput",
                    engine="null",
                    planes=planes, per_plane=per,
                    n_replicas=len(reps), batch=batch,
                    **scn.describe(),
                    **report.summary(),
                    audit_violations=float(len(verdict.violations)),
                )
                rows.append(row)
    return rows, audits


def churn_rows(*, steps, n_requests, rate, max_new, batch):
    """Kill the first replica mid-run, restore it a quarter-epoch later:
    the deterministic re-route-not-lose cell the nightly gates on."""
    scn = make_scenario(2, 3, 2, steps)
    reps = pick_replicas(scn.n_sats, 2)
    workload = synthesize_workload(
        n_requests, scn.ground_ids, rate_per_slot=rate, max_new=max_new,
    )
    epoch = len(scn.slots())
    fail_at = epoch // 2
    restore_at = fail_at + max(2, epoch // 4)

    def on_slot(eng, slot):
        if slot == fail_at:
            eng.fail(reps[0])
        elif slot == restore_at:
            eng.restore(reps[0])

    report, verdict, _ = run_cell(
        scn, reps, batch, workload, on_slot=on_slot,
    )
    summ = report.summary()
    row = dict(
        bench="serving_churn",
        engine="null",
        planes=2, per_plane=3, n_replicas=len(reps), batch=batch,
        **scn.describe(),
        delivered=summ["delivered"],
        undelivered=summ["undelivered"],
        lost_requests=float(summ["undelivered"]),
        retries=summ["retries"],
        n_slots=summ["n_slots"],
        audit_violations=float(len(verdict.violations)),
    )
    return [row], [verdict]


def measured_rows(*, steps, n_requests, max_new, batch):
    """Real stacked shard_map decode on the forced host-device mesh."""
    import jax

    from repro.configs import archs
    from repro.serving import ModelDecoder

    scn = make_scenario(2, 3, 2, steps)
    reps = pick_replicas(scn.n_sats, 3)
    if len(jax.devices()) < len(reps):
        print(f"skipping measured cell: need {len(reps)} devices, "
              f"have {len(jax.devices())}")
        return [], []
    cfg = archs.smoke_cfg(archs.get("gemma2-9b"))
    decoder = ModelDecoder(cfg, len(reps), batch, max_len=32)
    workload = synthesize_workload(
        n_requests, scn.ground_ids, rate_per_slot=1.0, max_new=max_new,
    )
    t0 = time.perf_counter()
    report, verdict, _ = run_cell(
        scn, reps, batch, workload, decoder=decoder,
    )
    wall = time.perf_counter() - t0
    summ = report.summary()
    row = dict(
        bench="serving_measured",
        engine="model", arch=cfg.name,
        planes=2, per_plane=3, n_replicas=len(reps), batch=batch,
        **scn.describe(),
        delivered=summ["delivered"],
        undelivered=summ["undelivered"],
        tokens=summ["tokens"],
        n_slots=summ["n_slots"],
        audit_violations=float(len(verdict.violations)),
        host_wall_ms=wall * 1e3,
        tok_per_host_s=summ["tokens"] / max(wall, 1e-9),
    )
    print(
        f"measured model decode: {summ['delivered']}/{summ['n_requests']} "
        f"delivered, {summ['tokens']} tokens in {wall*1e3:.0f} ms host wall "
        f"({row['tok_per_host_s']:.1f} tok/s)"
    )
    return [row], [verdict]


def merge_audits(audits):
    total = AuditReport()
    for a in audits:
        total.n_windows += a.n_windows
        total.n_payloads += a.n_payloads
        total.n_hops += a.n_hops
        total.n_delivered += a.n_delivered
        total.n_dropped += a.n_dropped
        total.events_checked += a.events_checked
        total.violations.extend(a.violations)
        total.trails.update(a.trails)
    return total


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true", help="small sweep")
    p.add_argument("--full", action="store_true", help="larger shells")
    p.add_argument("--steps", type=int, default=10)
    p.add_argument("--no-measured", action="store_true",
                   help="skip the ModelDecoder layer")
    p.add_argument("--out", default=None, help="write BENCH rows as json")
    p.add_argument("--trace", default=None,
                   help="write a Chrome trace (Perfetto) of this run")
    p.add_argument("--report", default=None, metavar="PREFIX",
                   help="write PREFIX.md/.json mission report of this run")
    args = p.parse_args(argv)
    with telemetry.trace_scope(args.trace):
        rows, verdict = _main(args)
        print("TELEMETRY " + json.dumps(telemetry.counters_snapshot()),
              flush=True)
        if args.report:
            from repro.telemetry.report import write_report

            md, js = write_report(
                args.report,
                audit=verdict,
                title="serving throughput bench",
                extra={
                    "bench": "serving_throughput",
                    "n_rows": len(rows),
                    "args": {"smoke": args.smoke, "full": args.full,
                             "steps": args.steps},
                },
            )
            print(f"wrote mission report to {md} and {js}")
        if not verdict.ok:
            raise SystemExit(
                f"route-provenance audit failed: "
                f"{len(verdict.violations)} violation(s)"
            )
    return rows


def _main(args):
    if args.smoke:
        shells, gs_counts, rep_counts = QUICK_SHELLS, [1, 2], [2, 3]
        n_requests, rate, max_new, batch = 12, 2.0, 4, 2
    elif args.full:
        shells, gs_counts, rep_counts = FULL_SHELLS, [1, 2, 4], [2, 4, 6]
        n_requests, rate, max_new, batch = 48, 4.0, 8, 4
    else:
        shells, gs_counts, rep_counts = QUICK_SHELLS, [1, 2], [2, 4]
        n_requests, rate, max_new, batch = 24, 2.0, 6, 2

    rows, audits = transport_rows(
        shells, gs_counts, rep_counts, steps=args.steps,
        n_requests=n_requests, rate=rate, max_new=max_new, batch=batch,
    )
    hdr = (f"{'shell':>6} {'gs':>3} {'reps':>5} {'deliv':>7} {'slots':>6} "
           f"{'p50':>6} {'p99':>6} {'ttft':>6} {'req/s':>9} {'audit':>6}")
    print(hdr)
    for r in rows:
        print(
            f"{r['planes']}x{r['per_plane']:<4} {r['n_gs']:>3} "
            f"{r['n_replicas']:>5} "
            f"{r['delivered']:>3}/{r['n_requests']:<3} {r['n_slots']:>6} "
            f"{r.get('latency_p50_slots', -1):>6.1f} "
            f"{r.get('latency_p99_slots', -1):>6.1f} "
            f"{r.get('ttft_p50_slots', -1):>6.1f} "
            f"{r.get('req_per_s', 0) * 1e3:>7.2f}m "
            f"{'ok' if r['audit_violations'] == 0 else 'FAIL':>6}"
        )
        print("BENCH " + json.dumps(r), flush=True)

    c_rows, c_audits = churn_rows(
        steps=args.steps, n_requests=n_requests, rate=rate,
        max_new=max_new, batch=batch,
    )
    rows += c_rows
    audits += c_audits
    c = c_rows[0]
    print(
        f"churn cell: replica dies mid-run — {c['delivered']}/"
        f"{c['delivered'] + c['undelivered']} delivered, "
        f"{c['retries']} retries, {c['lost_requests']:.0f} lost, "
        f"audit {'ok' if c['audit_violations'] == 0 else 'FAIL'}"
    )
    for r in c_rows:
        print("BENCH " + json.dumps(r), flush=True)

    if not args.no_measured:
        m_rows, m_audits = measured_rows(
            steps=args.steps, n_requests=min(n_requests, 6),
            max_new=max_new, batch=batch,
        )
        rows += m_rows
        audits += m_audits
        for r in m_rows:
            print("BENCH " + json.dumps(r), flush=True)

    verdict = merge_audits(audits)
    print(
        f"route-provenance audit: {verdict.n_windows} slots, "
        f"{verdict.n_payloads} requests, {verdict.n_hops} hops, "
        f"{len(verdict.violations)} violation(s)"
    )

    if args.out:
        out_path = pathlib.Path(args.out)
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rows, indent=1))
        print(f"wrote {len(rows)} rows to {out_path}")
    return rows, verdict


if __name__ == "__main__":
    main()
