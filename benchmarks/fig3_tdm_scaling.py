"""Paper Fig. 3 reproduction: average execution time vs number of nodes for
the two TDM primitives over a clique (the worst-case relation).

- ``get1meas``: round-robin tournament schedule (n-1 pairwise slots)
- ``getMeas``:  the paper's universal algorithm (1 slot, n-1 links/node)

Paper's claims to validate (§IV): (1) both grow O(n²) with clique size —
consistent with the O(n²) edge count; (2) get1meas is slower by a constant
factor (the lower line in Fig. 3 is getMeas).

The paper measures wall time of its TCP process testbed on an i7-8550U; we
measure wall time of the faithful discrete-event simulator (same message
count, same algorithmic structure, no network noise), plus the analytic
message/slot counts that explain the shape.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.core.ptbfla_sim import run_schedule_get1meas, run_schedule_getmeas
from repro.core.schedule import clique_multilink, round_robin_tournament


def time_once(fn, *args, **kwargs) -> float:
    t0 = time.perf_counter()
    fn(*args, **kwargs)
    return time.perf_counter() - t0


def run(node_counts: List[int], reps: int, seed: int = 0) -> List[Dict]:
    rows = []
    for n in node_counts:
        data = {i: float(i) for i in range(n)}
        rr_sched = round_robin_tournament(n)
        ml_sched = clique_multilink(n)
        t_rr = [
            time_once(run_schedule_get1meas, rr_sched, data, n, seed + r)
            for r in range(reps)
        ]
        t_ml = [
            time_once(run_schedule_getmeas, ml_sched, data, n, seed + r)
            for r in range(reps)
        ]
        _, sim_rr = run_schedule_get1meas(rr_sched, data, n, seed)
        _, sim_ml = run_schedule_getmeas(ml_sched, data, n, seed)
        rows.append(
            dict(
                n=n,
                get1meas_ms=float(np.mean(t_rr) * 1e3),
                getmeas_ms=float(np.mean(t_ml) * 1e3),
                get1meas_slots=len(rr_sched),
                getmeas_slots=len(ml_sched),
                messages=sim_ml.total_messages,
                messages_rr=sim_rr.total_messages,
            )
        )
    return rows


def quadratic_fit_r2(ns: np.ndarray, ts: np.ndarray) -> float:
    """R² of a quadratic fit t = a n² + b n + c (paper: O(n²) growth)."""
    coeffs = np.polyfit(ns, ts, 2)
    pred = np.polyval(coeffs, ns)
    ss_res = float(np.sum((ts - pred) ** 2))
    ss_tot = float(np.sum((ts - ts.mean()) ** 2))
    return 1.0 - ss_res / max(ss_tot, 1e-30)


def main(argv=None) -> Dict:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true", help="paper-size sweep 20..200")
    p.add_argument("--reps", type=int, default=None)
    p.add_argument("--json", type=str, default=None)
    args = p.parse_args(argv)

    if args.full:
        counts = list(range(20, 201, 20))
        reps = args.reps or 5
    else:
        counts = [20, 40, 60, 80, 100]
        reps = args.reps or 3

    rows = run(counts, reps)
    ns = np.array([r["n"] for r in rows], dtype=float)
    t1 = np.array([r["get1meas_ms"] for r in rows])
    tm = np.array([r["getmeas_ms"] for r in rows])

    r2_1 = quadratic_fit_r2(ns, t1)
    r2_m = quadratic_fit_r2(ns, tm)
    gap = float(np.mean(t1 / tm))

    print(f"{'n':>5} {'get1meas_ms':>12} {'getMeas_ms':>11} {'ratio':>6} {'msgs':>8}")
    for r in rows:
        print(
            f"{r['n']:>5} {r['get1meas_ms']:>12.2f} {r['getmeas_ms']:>11.2f} "
            f"{r['get1meas_ms'] / r['getmeas_ms']:>6.2f} {r['messages']:>8}"
        )
    print(f"\nquadratic fit R^2: get1meas={r2_1:.4f}  getMeas={r2_m:.4f}")
    print(f"mean constant-factor gap (get1meas / getMeas): {gap:.2f}x")
    verdict_growth = r2_1 > 0.98 and r2_m > 0.98
    verdict_gap = gap > 1.0
    print(f"paper claim 'O(n^2) growth'        : {'CONFIRMED' if verdict_growth else 'REFUTED'}")
    print(f"paper claim 'getMeas faster, const': {'CONFIRMED' if verdict_gap else 'REFUTED'}")

    out = dict(rows=rows, r2_get1meas=r2_1, r2_getmeas=r2_m, gap=gap,
               growth_confirmed=bool(verdict_growth), gap_confirmed=bool(verdict_gap))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    main()
