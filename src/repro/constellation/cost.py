"""Analytic wall-clock / traffic model for TDM exchanges over a plan.

Quantifies the paper's Fig. 3 comparison on *physical* link parameters
instead of testbed wall time: for a slot relation R with per-edge rates,

- ``getmeas`` (multi-antenna): the matchings of R transfer concurrently —
  slot time is the slowest single transfer,
- ``get1meas`` (single-antenna): matchings serialize — slot time is the sum
  of per-matching times.

Both ship the same bytes (every directed pair carries one payload); the
paper's constant-factor gap is exactly the serialization of the coloring.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, List, Optional

from repro.constellation.contact_plan import ContactPlan, ContactSchedule
from repro.constellation.links import Edge, Link
from repro.core.relation import Relation
from repro.core.schedule import edge_coloring

_MODES = ("getmeas", "get1meas")


def fresh_edges(prev: Optional[Relation], cur: Relation) -> FrozenSet[Edge]:
    """Edges of ``cur`` that were not active in the previous slot and must
    re-point/acquire before carrying data (undirected (i, j), i < j). With
    no previous slot every edge is fresh."""
    cur_e = frozenset(cur.edge_list())
    if prev is None:
        return cur_e
    return cur_e - frozenset(prev.edge_list())


@dataclass(frozen=True)
class SlotCost:
    time_s: float
    bytes_on_isl: int
    n_matchings: int


@dataclass(frozen=True)
class RoundCost:
    """A whole schedule (or FL round) traversed in one mode."""

    time_s: float
    bytes_on_isl: int
    n_slots: int
    max_slot_s: float

    def __add__(self, other: "RoundCost") -> "RoundCost":
        return RoundCost(
            time_s=self.time_s + other.time_s,
            bytes_on_isl=self.bytes_on_isl + other.bytes_on_isl,
            n_slots=self.n_slots + other.n_slots,
            max_slot_s=max(self.max_slot_s, other.max_slot_s),
        )


def _edge_time_s(
    link: Link, payload_bytes: int, acquisition_s: float = 0.0
) -> float:
    return link.transfer_time_s(payload_bytes, acquisition_s)


def slot_cost(
    rel: Relation,
    links: Dict[Edge, Link],
    payload_bytes: int,
    mode: str = "getmeas",
    fresh: Optional[Iterable[Edge]] = None,
    acquisition_s: float = 0.0,
) -> SlotCost:
    """Cost of exchanging ``payload_bytes`` over relation ``rel`` whose
    physical edges are described by ``links``.

    ``acquisition_s`` charges the slew/acquisition penalty on every edge in
    ``fresh`` (undirected (i, j) keys; ``None`` = all edges fresh) —
    terminals acquire in parallel, so the penalty folds into each edge's
    completion time rather than summing across a matching."""
    if mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
    matchings = edge_coloring(rel)
    if not matchings:
        return SlotCost(time_s=0.0, bytes_on_isl=0, n_matchings=0)
    fresh_s = None if fresh is None else {tuple(e) for e in fresh}

    def acq(e: Edge) -> float:
        if acquisition_s <= 0.0:
            return 0.0
        return acquisition_s if (fresh_s is None or e in fresh_s) else 0.0

    per_matching: List[float] = []
    for m in matchings:
        per_matching.append(
            max(
                _edge_time_s(
                    links[(min(i, j), max(i, j))],
                    payload_bytes,
                    acq((min(i, j), max(i, j))),
                )
                for i, j in m.edge_list()
            )
        )
    time_s = max(per_matching) if mode == "getmeas" else sum(per_matching)
    return SlotCost(
        time_s=time_s,
        bytes_on_isl=payload_bytes * len(rel.pairs),  # one payload per directed pair
        n_matchings=len(matchings),
    )


def plan_cost(
    plan: ContactPlan,
    payload_bytes: int,
    mode: str = "getmeas",
    alive: Optional[Iterable[int]] = None,
) -> RoundCost:
    """Traverse every time step's visibility relation once (one gossip
    exchange per step — the tdm-FL round structure)."""
    alive_s = set(alive) if alive is not None else None
    total = RoundCost(0.0, 0, 0, 0.0)
    for t in range(len(plan.times)):
        rel = plan.relation(t)
        if alive_s is not None:
            rel = rel.restrict(alive_s)
        if len(rel) == 0:
            continue
        sc = slot_cost(rel, plan.graphs[t], payload_bytes, mode)
        total = total + RoundCost(sc.time_s, sc.bytes_on_isl, 1, sc.time_s)
    return total


def schedule_cost(
    sched: ContactSchedule,
    payload_bytes: int,
    mode: str = "getmeas",
    acquisition_s: float = 0.0,
) -> RoundCost:
    """Cost of an antenna-constrained :class:`ContactSchedule`, computed
    from each slot's real per-edge links. Sub-slots produced by the antenna
    splitter always serialize (they exist because the terminals are busy);
    ``mode`` governs concurrency *within* each sub-slot. In ``getmeas``
    mode with the same payload and ``acquisition_s`` the slots were sized
    for, this equals the schedule's ``busy_s`` exactly.

    ``acquisition_s > 0`` prices terminal retargeting: an edge absent from
    the immediately preceding slot pays the slew/acquisition penalty before
    its transfer starts (edges kept warm across consecutive slots pay
    nothing). This is the oracle the schedule optimizer minimizes."""
    total = RoundCost(0.0, 0, 0, 0.0)
    prev: Optional[Relation] = None
    track_fresh = acquisition_s > 0.0
    for slot in sched.slots:
        sc = slot_cost(
            slot.relation,
            slot.links,
            payload_bytes,
            mode,
            fresh=fresh_edges(prev, slot.relation) if track_fresh else None,
            acquisition_s=acquisition_s,
        )
        total = total + RoundCost(sc.time_s, sc.bytes_on_isl, 1, sc.time_s)
        if track_fresh:
            prev = slot.relation
    return total


# ---------------------------------------------------------------------------
# Ground-segment (centralized FL) oracle — centralized vs decentralized
# ---------------------------------------------------------------------------

def _program_cost(
    sched: ContactSchedule, slot_sends, payload_bytes: int
) -> RoundCost:
    """Time/traffic of one store-and-forward program over a schedule window:
    the wall clock runs from the window start to the end of the last slot
    that carries a relay transfer; every directed hop ships ONE payload
    (relay, not exchange — half a gossip edge's traffic)."""
    used = [t for t, sends in enumerate(slot_sends) if sends]
    n_hops = sum(len(sends) for sends in slot_sends)
    if not used:
        return RoundCost(0.0, 0, 0, 0.0)
    last = sched.slots[used[-1]]
    origin = sched.slots[0].start_s
    return RoundCost(
        time_s=last.start_s + last.duration_s - origin,
        bytes_on_isl=payload_bytes * n_hops,
        n_slots=len(used),
        max_slot_s=max(sched.slots[t].duration_s for t in used),
    )


def groundseg_round_cost(
    sched: ContactSchedule,
    uplink,
    downlink,
    payload_bytes: int,
) -> RoundCost:
    """One centralized/hierarchical FL round over the ground segment:
    uplink relay over one schedule window plus downlink broadcast over the
    next identical window (orbits are periodic when the plan horizon is one
    period; inter-sink pooling rides terrestrial backhaul and is free in
    ISL terms, so centralized and hierarchical cost the same here).

    ``uplink``/``downlink`` are the static programs from
    :mod:`repro.groundseg.routing` built on this schedule's slots.
    """
    return _program_cost(sched, uplink.slot_sends, payload_bytes) + _program_cost(
        sched, downlink.slot_sends, payload_bytes
    )


def groundseg_pipelined_cost(
    sched: ContactSchedule,
    uplink,
    downlink,
    payload_bytes: int,
    pipeline_depth: int = 1,
) -> RoundCost:
    """Steady-state per-round cost of a pipelined ground-segment window.

    At depth 1 the uplink and downlink traverse the window sequentially —
    identical to :func:`groundseg_round_cost`. At depth 2 they share ONE
    window on disjoint slot capacity, so the steady-state wall time per
    round is the LONGER of the two program spans (the pipeline's bottleneck
    stage), while ISL traffic and busy slots still sum — that is the
    pipelining win the throughput benchmark measures. ``downlink=None``
    (a depth-2 warm-up window) prices the uplink alone."""
    up = _program_cost(sched, uplink.slot_sends, payload_bytes)
    down = (
        _program_cost(sched, downlink.slot_sends, payload_bytes)
        if downlink is not None
        else RoundCost(0.0, 0, 0, 0.0)
    )
    if pipeline_depth == 1:
        return up + down
    return RoundCost(
        time_s=max(up.time_s, down.time_s),
        bytes_on_isl=up.bytes_on_isl + down.bytes_on_isl,
        n_slots=up.n_slots + down.n_slots,
        max_slot_s=max(up.max_slot_s, down.max_slot_s),
    )


def groundseg_schedule_cost(
    sched: ContactSchedule,
    sinks: Iterable[int],
    payload_bytes: int,
    n_nodes: Optional[int] = None,
    pipeline_depth: int = 1,
    max_staleness_windows: int = 0,
) -> RoundCost:
    """Convenience oracle: route over ``sched`` and price the round — what
    the schedule optimizer minimizes under ``objective="groundseg"``.

    ``pipeline_depth=2`` prices the steady-state pipelined round: the
    multi-window router plans a warm-up window then a steady window whose
    uplink and downlink share capacity, and the steady window's
    :func:`groundseg_pipelined_cost` is returned. ``max_staleness_windows``
    feeds the router so carried payloads (if the geometry strands any)
    shape the steady window exactly as the driver would run it."""
    from repro.groundseg import routing  # lazy: groundseg imports this pkg

    sinks = sorted(int(s) for s in sinks)
    if n_nodes is None:
        n_nodes = max(
            [max(s.relation.participants(), default=0) for s in sched.slots]
            + [max(sinks, default=0)]
        ) + 1
    rels = list(sched.tdm)
    if pipeline_depth == 1 and max_staleness_windows == 0:
        table = routing.earliest_delivery_routes(rels, n_nodes, sinks)
        up = routing.build_relay_program(rels, n_nodes, sinks, table=table)
        down = routing.build_broadcast_program(rels, n_nodes, sinks)
        return groundseg_round_cost(sched, up, down, payload_bytes)
    router = routing.MultiWindowRouter(
        n_nodes,
        sinks,
        max_staleness_windows=max_staleness_windows,
        pipeline_depth=pipeline_depth,
    )
    router.plan_window(rels)          # warm-up (depth 2: no downlink yet)
    wp = router.plan_window(rels)     # steady state
    return groundseg_pipelined_cost(
        sched, wp.uplink, wp.downlink, payload_bytes, pipeline_depth
    )


def groundseg_throughput(
    sched: ContactSchedule,
    sinks: Iterable[int],
    n_nodes: Optional[int] = None,
    pipeline_depth: int = 1,
    max_staleness_windows: int = 0,
) -> Dict[str, float]:
    """Steady-state round throughput of the ground-segment engine.

    The cadence model, from the engine's own semantics: the one-shot
    engine (depth 1) traverses the materialized slot window TWICE per
    round — uplink on one window, downlink on "the next identical window"
    — so it completes one round per two window periods. The pipelined
    engine (depth 2) packs round r's downlink and round r+1's uplink into
    ONE traversal on disjoint slot capacity, completing one round per
    window. Steady-state round throughput is therefore::

        rounds_per_window x delivered_fraction / window_period

    where ``delivered_fraction`` is the share of satellites whose payload
    lands at a sink in the steady window (the uplink plans first, so
    pipelining never costs deliveries; capacity contention shows up in
    ``covered_frac`` — satellites the leftover-capacity downlink misses
    keep their local params and catch a later flood, the usual skip-slot
    semantics). All quantities are static functions of the schedule, so
    this oracle is deterministic and CI-trendable.
    """
    from repro.groundseg import routing  # lazy: groundseg imports this pkg

    sinks = sorted(int(s) for s in sinks)
    if n_nodes is None:
        n_nodes = max(
            [max(s.relation.participants(), default=0) for s in sched.slots]
            + [max(sinks, default=0)]
        ) + 1
    rels = list(sched.tdm)
    n_sats = n_nodes - len(sinks)
    router = routing.MultiWindowRouter(
        n_nodes,
        sinks,
        max_staleness_windows=max_staleness_windows,
        pipeline_depth=pipeline_depth,
    )
    router.plan_window(rels)          # warm-up (depth 2: no downlink yet)
    wp = router.plan_window(rels)     # steady state
    window_s = max(sched.span_s, 1e-9)
    rounds_per_window = 1.0 if pipeline_depth == 2 else 0.5
    delivered = wp.uplink.delivered_count()
    covered = (
        len(wp.downlink.covered - frozenset(sinks))
        if wp.downlink is not None
        else 0
    )
    delivered_frac = delivered / max(n_sats, 1)
    return {
        "window_s": window_s,
        "rounds_per_window": rounds_per_window,
        "delivered": float(delivered),
        "delivered_frac": delivered_frac,
        "covered": float(covered),
        "covered_frac": covered / max(n_sats, 1),
        "carried": float(len(wp.residual)),
        "dropped": float(len(wp.dropped)),
        "round_throughput_per_s": rounds_per_window * delivered_frac / window_s,
    }


def groundseg_mode_costs(
    plan: ContactPlan,
    sinks: Iterable[int],
    payload_bytes: int,
    antennas=None,
    acquisition_s: float = 0.0,
    optimize: Optional[str] = None,
    pipeline_depth: int = 1,
) -> Dict[str, RoundCost]:
    """The centralized-vs-decentralized scoreboard for one plan window:

    - ``centralized`` / ``hierarchical`` — sink-based rounds (uplink relay
      + downlink broadcast; identical ISL cost, they differ only in what
      the sinks do terrestrially),
    - ``gossip_getmeas`` / ``gossip_get1meas`` — the decentralized TDM
      passes over the same materialized schedule.

    This is the oracle ``benchmarks/groundseg_round_time.py`` sweeps and
    the schedule optimizer scores sink-based schedules with.
    ``pipeline_depth=2`` prices the sink-based modes as pipelined rounds
    (steady-state, see :func:`groundseg_pipelined_cost`); the gossip rows
    are unaffected — gossip has no uplink/downlink phases to overlap.
    """
    sched = plan.schedule(
        antennas=antennas,
        payload_bytes=payload_bytes,
        optimize=optimize,
        acquisition_s=acquisition_s,
    )
    central = groundseg_schedule_cost(
        sched, sinks, payload_bytes, n_nodes=plan.n_nodes,
        pipeline_depth=pipeline_depth,
    )
    return {
        "centralized": central,
        "hierarchical": central,
        "gossip_getmeas": schedule_cost(
            sched, payload_bytes, "getmeas", acquisition_s
        ),
        "gossip_get1meas": schedule_cost(
            sched, payload_bytes, "get1meas", acquisition_s
        ),
    }


def fl_round_cost(
    plan: ContactPlan,
    payload_bytes: int,
    compute_s_per_step: float = 0.0,
    mode: str = "getmeas",
) -> RoundCost:
    """One decentralized-FL pass over the plan: local compute each time step
    plus the TDM exchange (paper: local ODTS measurement + getMeas)."""
    comm = plan_cost(plan, payload_bytes, mode)
    return RoundCost(
        time_s=comm.time_s + compute_s_per_step * len(plan.times),
        bytes_on_isl=comm.bytes_on_isl,
        n_slots=comm.n_slots,
        max_slot_s=comm.max_slot_s,
    )
