"""Analytic wall-clock / traffic model for TDM exchanges over a plan.

Quantifies the paper's Fig. 3 comparison on *physical* link parameters
instead of testbed wall time: for a slot relation R with per-edge rates,

- ``getmeas`` (multi-antenna): the matchings of R transfer concurrently —
  slot time is the slowest single transfer,
- ``get1meas`` (single-antenna): matchings serialize — slot time is the sum
  of per-matching times.

Both ship the same bytes (every directed pair carries one payload); the
paper's constant-factor gap is exactly the serialization of the coloring.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, List, Optional

from repro.constellation.contact_plan import ContactPlan, ContactSchedule
from repro.constellation.links import Edge, Link
from repro.core.relation import Relation
from repro.core.schedule import edge_coloring

_MODES = ("getmeas", "get1meas")


def fresh_edges(prev: Optional[Relation], cur: Relation) -> FrozenSet[Edge]:
    """Edges of ``cur`` that were not active in the previous slot and must
    re-point/acquire before carrying data (undirected (i, j), i < j). With
    no previous slot every edge is fresh."""
    cur_e = frozenset(cur.edge_list())
    if prev is None:
        return cur_e
    return cur_e - frozenset(prev.edge_list())


@dataclass(frozen=True)
class SlotCost:
    time_s: float
    bytes_on_isl: int
    n_matchings: int


@dataclass(frozen=True)
class RoundCost:
    """A whole schedule (or FL round) traversed in one mode."""

    time_s: float
    bytes_on_isl: int
    n_slots: int
    max_slot_s: float

    def __add__(self, other: "RoundCost") -> "RoundCost":
        return RoundCost(
            time_s=self.time_s + other.time_s,
            bytes_on_isl=self.bytes_on_isl + other.bytes_on_isl,
            n_slots=self.n_slots + other.n_slots,
            max_slot_s=max(self.max_slot_s, other.max_slot_s),
        )


def _edge_time_s(
    link: Link, payload_bytes: int, acquisition_s: float = 0.0
) -> float:
    return link.transfer_time_s(payload_bytes, acquisition_s)


def slot_cost(
    rel: Relation,
    links: Dict[Edge, Link],
    payload_bytes: int,
    mode: str = "getmeas",
    fresh: Optional[Iterable[Edge]] = None,
    acquisition_s: float = 0.0,
) -> SlotCost:
    """Cost of exchanging ``payload_bytes`` over relation ``rel`` whose
    physical edges are described by ``links``.

    ``acquisition_s`` charges the slew/acquisition penalty on every edge in
    ``fresh`` (undirected (i, j) keys; ``None`` = all edges fresh) —
    terminals acquire in parallel, so the penalty folds into each edge's
    completion time rather than summing across a matching."""
    if mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
    matchings = edge_coloring(rel)
    if not matchings:
        return SlotCost(time_s=0.0, bytes_on_isl=0, n_matchings=0)
    fresh_s = None if fresh is None else {tuple(e) for e in fresh}

    def acq(e: Edge) -> float:
        if acquisition_s <= 0.0:
            return 0.0
        return acquisition_s if (fresh_s is None or e in fresh_s) else 0.0

    per_matching: List[float] = []
    for m in matchings:
        per_matching.append(
            max(
                _edge_time_s(
                    links[(min(i, j), max(i, j))],
                    payload_bytes,
                    acq((min(i, j), max(i, j))),
                )
                for i, j in m.edge_list()
            )
        )
    time_s = max(per_matching) if mode == "getmeas" else sum(per_matching)
    return SlotCost(
        time_s=time_s,
        bytes_on_isl=payload_bytes * len(rel.pairs),  # one payload per directed pair
        n_matchings=len(matchings),
    )


def plan_cost(
    plan: ContactPlan,
    payload_bytes: int,
    mode: str = "getmeas",
    alive: Optional[Iterable[int]] = None,
) -> RoundCost:
    """Traverse every time step's visibility relation once (one gossip
    exchange per step — the tdm-FL round structure)."""
    alive_s = set(alive) if alive is not None else None
    total = RoundCost(0.0, 0, 0, 0.0)
    for t in range(len(plan.times)):
        rel = plan.relation(t)
        if alive_s is not None:
            rel = rel.restrict(alive_s)
        if len(rel) == 0:
            continue
        sc = slot_cost(rel, plan.graphs[t], payload_bytes, mode)
        total = total + RoundCost(sc.time_s, sc.bytes_on_isl, 1, sc.time_s)
    return total


def schedule_cost(
    sched: ContactSchedule,
    payload_bytes: int,
    mode: str = "getmeas",
    acquisition_s: float = 0.0,
) -> RoundCost:
    """Cost of an antenna-constrained :class:`ContactSchedule`, computed
    from each slot's real per-edge links. Sub-slots produced by the antenna
    splitter always serialize (they exist because the terminals are busy);
    ``mode`` governs concurrency *within* each sub-slot. In ``getmeas``
    mode with the same payload and ``acquisition_s`` the slots were sized
    for, this equals the schedule's ``busy_s`` exactly.

    ``acquisition_s > 0`` prices terminal retargeting: an edge absent from
    the immediately preceding slot pays the slew/acquisition penalty before
    its transfer starts (edges kept warm across consecutive slots pay
    nothing). This is the oracle the schedule optimizer minimizes."""
    total = RoundCost(0.0, 0, 0, 0.0)
    prev: Optional[Relation] = None
    track_fresh = acquisition_s > 0.0
    for slot in sched.slots:
        sc = slot_cost(
            slot.relation,
            slot.links,
            payload_bytes,
            mode,
            fresh=fresh_edges(prev, slot.relation) if track_fresh else None,
            acquisition_s=acquisition_s,
        )
        total = total + RoundCost(sc.time_s, sc.bytes_on_isl, 1, sc.time_s)
        if track_fresh:
            prev = slot.relation
    return total


# ---------------------------------------------------------------------------
# Ground-segment (centralized FL) oracle — centralized vs decentralized
# ---------------------------------------------------------------------------

def _program_cost(
    sched: ContactSchedule, slot_sends, payload_bytes: int
) -> RoundCost:
    """Time/traffic of one store-and-forward program over a schedule window:
    the wall clock runs from the window start to the end of the last slot
    that carries a relay transfer; every directed hop ships ONE payload
    (relay, not exchange — half a gossip edge's traffic)."""
    used = [t for t, sends in enumerate(slot_sends) if sends]
    n_hops = sum(len(sends) for sends in slot_sends)
    if not used:
        return RoundCost(0.0, 0, 0, 0.0)
    last = sched.slots[used[-1]]
    origin = sched.slots[0].start_s
    return RoundCost(
        time_s=last.start_s + last.duration_s - origin,
        bytes_on_isl=payload_bytes * n_hops,
        n_slots=len(used),
        max_slot_s=max(sched.slots[t].duration_s for t in used),
    )


def groundseg_round_cost(
    sched: ContactSchedule,
    uplink,
    downlink,
    payload_bytes: int,
) -> RoundCost:
    """One centralized/hierarchical FL round over the ground segment:
    uplink relay over one schedule window plus downlink broadcast over the
    next identical window (orbits are periodic when the plan horizon is one
    period; inter-sink pooling rides terrestrial backhaul and is free in
    ISL terms, so centralized and hierarchical cost the same here).

    ``uplink``/``downlink`` are the static programs from
    :mod:`repro.groundseg.routing` built on this schedule's slots.
    """
    return _program_cost(sched, uplink.slot_sends, payload_bytes) + _program_cost(
        sched, downlink.slot_sends, payload_bytes
    )


def groundseg_schedule_cost(
    sched: ContactSchedule,
    sinks: Iterable[int],
    payload_bytes: int,
    n_nodes: Optional[int] = None,
) -> RoundCost:
    """Convenience oracle: route over ``sched`` and price the round — what
    the schedule optimizer minimizes under ``objective="groundseg"``."""
    from repro.groundseg import routing  # lazy: groundseg imports this pkg

    sinks = sorted(int(s) for s in sinks)
    if n_nodes is None:
        n_nodes = max(
            [max(s.relation.participants(), default=0) for s in sched.slots]
            + [max(sinks, default=0)]
        ) + 1
    rels = list(sched.tdm)
    table = routing.earliest_delivery_routes(rels, n_nodes, sinks)
    up = routing.build_relay_program(rels, n_nodes, sinks, table=table)
    down = routing.build_broadcast_program(rels, n_nodes, sinks)
    return groundseg_round_cost(sched, up, down, payload_bytes)


def groundseg_mode_costs(
    plan: ContactPlan,
    sinks: Iterable[int],
    payload_bytes: int,
    antennas=None,
    acquisition_s: float = 0.0,
    optimize: Optional[str] = None,
) -> Dict[str, RoundCost]:
    """The centralized-vs-decentralized scoreboard for one plan window:

    - ``centralized`` / ``hierarchical`` — sink-based rounds (uplink relay
      + downlink broadcast; identical ISL cost, they differ only in what
      the sinks do terrestrially),
    - ``gossip_getmeas`` / ``gossip_get1meas`` — the decentralized TDM
      passes over the same materialized schedule.

    This is the oracle ``benchmarks/groundseg_round_time.py`` sweeps and
    the schedule optimizer scores sink-based schedules with.
    """
    sched = plan.schedule(
        antennas=antennas,
        payload_bytes=payload_bytes,
        optimize=optimize,
        acquisition_s=acquisition_s,
    )
    central = groundseg_schedule_cost(
        sched, sinks, payload_bytes, n_nodes=plan.n_nodes
    )
    return {
        "centralized": central,
        "hierarchical": central,
        "gossip_getmeas": schedule_cost(
            sched, payload_bytes, "getmeas", acquisition_s
        ),
        "gossip_get1meas": schedule_cost(
            sched, payload_bytes, "get1meas", acquisition_s
        ),
    }


def fl_round_cost(
    plan: ContactPlan,
    payload_bytes: int,
    compute_s_per_step: float = 0.0,
    mode: str = "getmeas",
) -> RoundCost:
    """One decentralized-FL pass over the plan: local compute each time step
    plus the TDM exchange (paper: local ODTS measurement + getMeas)."""
    comm = plan_cost(plan, payload_bytes, mode)
    return RoundCost(
        time_s=comm.time_s + compute_s_per_step * len(plan.times),
        bytes_on_isl=comm.bytes_on_isl,
        n_slots=comm.n_slots,
        max_slot_s=comm.max_slot_s,
    )
