"""Contact plans: orbital geometry → per-slot exchange relations → TDM.

The pipeline the paper assumes exists but never specifies:

1. propagate the constellation (:mod:`orbits`) over a sample grid,
2. evaluate the weighted visibility graph per step (:mod:`links`),
3. extract contact windows, and
4. emit per-slot :class:`~repro.core.relation.Relation`s that honor
   per-node antenna budgets (reusing ``edge_coloring`` /
   ``antenna_constrained``) with bandwidth-aware slot sizing — a
   :class:`ContactSchedule` whose ``.tdm`` is a plain ``TDMSchedule`` every
   existing collective (``get_meas``/``get1_meas``/gossip) consumes as-is.

Occlusion is handled by construction: a satellite with no line of sight
simply has no pairs in that step's relation, which is exactly the paper's
``odata=None`` skip-slot case (and what ``Relation.restrict`` produces for
failures).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.constellation import links as links_lib
from repro.constellation import orbits as orbits_lib
from repro.constellation.links import Edge, Link, LinkBudget
from repro.constellation.orbits import GroundStation, WalkerDelta
from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule, antenna_constrained

AntennaSpec = Union[int, Dict[int, int], None]

# A colorer turns one time step's (relation, per-edge links, antenna budget,
# previous emitted sub-slot relation) into an ordered list of sub-slot
# relations. The schedule optimizer supplies rate-aware colorers; ``None``
# means the default Misra–Gries + first-fit antenna packing.
Colorer = Callable[
    [Relation, Dict[Edge, Link], Dict[int, int], Optional[Relation]],
    Sequence[Relation],
]


def _antenna_map(antennas: AntennaSpec, nodes: Iterable[int]) -> Dict[int, int]:
    if antennas is None:
        return {v: 1 for v in nodes}
    if isinstance(antennas, int):
        return {v: antennas for v in nodes}
    return {v: antennas.get(v, 1) for v in nodes}


def plus_grid_candidates(geom: WalkerDelta, cross_plane: bool = True) -> List[Edge]:
    """The +grid ISL candidate set: each satellite's terminals point at its
    intra-plane fore/aft neighbors and (optionally) the same-slot satellite
    in each adjacent plane. Geometry still gates every candidate — a
    candidate pair with the Earth in between produces no contact."""
    edges: List[Edge] = []
    s = geom.per_plane
    for p in range(geom.planes):
        for k in range(s):
            if s > 1:
                edges.append((geom.node_id(p, k), geom.node_id(p, k + 1)))
            if cross_plane and geom.planes > 1:
                edges.append((geom.node_id(p, k), geom.node_id((p + 1) % geom.planes, k)))
    return sorted({(min(a, b), max(a, b)) for a, b in edges if a != b})


@dataclass(frozen=True)
class ContactWindow:
    """A maximal interval during which an edge stays feasible."""

    i: int
    j: int
    t_start_s: float
    t_end_s: float
    min_rate_bps: float
    mean_rate_bps: float

    @property
    def duration_s(self) -> float:
        return self.t_end_s - self.t_start_s


@dataclass(frozen=True)
class Slot:
    """One emitted TDM slot: a relation plus its physical sizing."""

    relation: Relation
    t_index: int          # contact-plan time step this slot came from
    start_s: float        # slot start on the wall clock
    duration_s: float     # bandwidth-aware: slowest edge's transfer + delay
    min_rate_bps: float   # bottleneck link rate inside the slot
    max_delay_s: float    # worst one-way propagation delay inside the slot
    links: Dict[Edge, Link] = None  # per-edge physics (keys (i, j), i < j)


@dataclass(frozen=True)
class ContactSchedule:
    """A ``TDMSchedule`` plus per-slot physical metadata (aligned 1:1)."""

    tdm: TDMSchedule
    slots: Tuple[Slot, ...]

    def __post_init__(self):
        if len(self.tdm) != len(self.slots):
            raise ValueError("tdm slots and metadata misaligned")

    def __len__(self) -> int:
        return len(self.slots)

    @property
    def busy_s(self) -> float:
        """Total link-occupied time (sum of slot durations, gaps excluded)."""
        return sum(s.duration_s for s in self.slots)

    @property
    def span_s(self) -> float:
        """Wall-clock span from the first slot's start to the last slot's
        end — includes the idle gaps between contact-plan steps."""
        if not self.slots:
            return 0.0
        last = self.slots[-1]
        return last.start_s + last.duration_s - self.slots[0].start_s

    def max_antennas(self) -> int:
        return self.tdm.max_antennas()

    def restrict(
        self, alive: Iterable[int], antennas: AntennaSpec = None
    ) -> "ContactSchedule":
        """Drop failed/occluded nodes and re-validate the schedule.

        ``TDMSchedule.restrict`` alone is not enough for a materialized
        (possibly optimizer-produced) schedule: the per-slot metadata would
        keep dead edges in ``links`` and stale ``min_rate_bps`` bottlenecks.
        This rebuilds each surviving slot from its surviving links, drops
        slots that went empty, keeps ``tdm`` and ``slots`` aligned, and —
        when ``antennas`` is given — re-validates the per-node budget
        (``TDMSchedule.validate_antennas``). Slot starts/durations are kept:
        the TDM grid was already committed and surviving transfers only get
        faster when a slower edge drops out."""
        alive_s = set(alive)
        slots: List[Slot] = []
        for slot in self.slots:
            r = slot.relation.restrict(alive_s)
            if len(r) == 0:
                continue
            links = {e: slot.links[e] for e in r.edge_list()}
            slots.append(
                dataclasses.replace(
                    slot,
                    relation=r,
                    links=links,
                    min_rate_bps=min(l.rate_bps for l in links.values()),
                    max_delay_s=max(l.delay_s for l in links.values()),
                )
            )
        out = ContactSchedule(
            tdm=TDMSchedule(tuple(s.relation for s in slots)), slots=tuple(slots)
        )
        if antennas is not None:
            parts = {v for s in slots for v in s.relation.participants()}
            out.tdm.validate_antennas(_antenna_map(antennas, parts))
        return out


@dataclass(frozen=True)
class ContactPlan:
    """Weighted time-varying visibility over a sample grid.

    ``graphs[t]`` is the feasible-edge map at ``times[t]``; node ids are the
    Walker layout (satellites first, then ground stations).
    """

    n_nodes: int
    times: Tuple[float, ...]
    graphs: Tuple[Dict[Edge, Link], ...]
    step_s: float

    # ----------------------------------------------------------- relations
    def relation(self, t_index: int) -> Relation:
        """The (possibly empty) exchange relation at one time step."""
        return Relation.from_edges(
            sorted(self.graphs[t_index]), nodes=range(self.n_nodes)
        )

    def relations(self) -> List[Relation]:
        """One relation per time step — the time-varying schedule FL loops
        iterate (empty relation = everyone skips the slot)."""
        return [self.relation(t) for t in range(len(self.times))]

    def link(self, t_index: int, i: int, j: int) -> Link:
        return self.graphs[t_index][(min(i, j), max(i, j))]

    # ------------------------------------------------------------- windows
    def windows(self) -> List[ContactWindow]:
        """Merge per-step feasibility into maximal contact windows."""
        open_: Dict[Edge, List] = {}   # edge -> [t_start_idx, rates]
        out: List[ContactWindow] = []

        def close(edge: Edge, start_idx: int, end_idx: int, rates: List[float]):
            out.append(
                ContactWindow(
                    i=edge[0],
                    j=edge[1],
                    t_start_s=self.times[start_idx],
                    t_end_s=self.times[end_idx] + self.step_s,
                    min_rate_bps=min(rates),
                    mean_rate_bps=float(np.mean(rates)),
                )
            )

        for t, graph in enumerate(self.graphs):
            for edge, link in graph.items():
                if edge in open_:
                    open_[edge][2].append(link.rate_bps)
                    open_[edge][1] = t
                else:
                    open_[edge] = [t, t, [link.rate_bps]]
            for edge in [e for e in open_ if e not in graph]:
                start, end, rates = open_.pop(edge)
                close(edge, start, end, rates)
        for edge, (start, end, rates) in sorted(open_.items()):
            close(edge, start, end, rates)
        out.sort(key=lambda w: (w.t_start_s, w.i, w.j))
        return out

    # ------------------------------------------------------------ schedule
    def iter_slots(
        self,
        antennas: AntennaSpec = None,
        payload_bytes: int = 1 << 20,
        alive: Optional[Iterable[int]] = None,
        acquisition_s: float = 0.0,
        colorer: Optional[Colorer] = None,
    ) -> Iterator[Slot]:
        """Stream TDM slots in wall-clock order (lazy — no materialization).

        Each time step's visibility relation is split by
        ``antenna_constrained`` into sub-slots a node's terminal count can
        realize; each sub-slot is sized so the payload clears the slowest
        link it contains (plus one-way propagation). Dead/occluded nodes are
        dropped via ``Relation.restrict`` (paper skip-slot semantics).

        ``acquisition_s > 0`` prices terminal retargeting: an edge that was
        not active in the immediately preceding sub-slot pays the slew/
        acquisition penalty before its transfer (warm edges pay nothing).
        ``colorer`` swaps the default decomposition for a rate-aware one
        (see :mod:`repro.constellation.optimizer`); its output is validated
        against the antenna budget.
        """
        alive_s = set(alive) if alive is not None else None
        cursor = 0.0
        prev_edges: frozenset = frozenset()
        prev_rel: Optional[Relation] = None
        for t in range(len(self.times)):
            rel = self.relation(t)
            if alive_s is not None:
                rel = rel.restrict(alive_s)
            if len(rel) == 0:
                continue
            budget = _antenna_map(antennas, rel.nodes)
            # monotone cursor: sub-slots never overlap, even when the
            # previous step's payload overran its sampling interval (the
            # schedule then runs behind the plan cadence rather than
            # emitting physically impossible concurrent slots)
            cursor = max(cursor, float(self.times[t]))
            if colorer is None:
                subs = list(antenna_constrained(rel, budget))
            else:
                subs = list(colorer(rel, self.graphs[t], budget, prev_rel))
            for sub in subs:
                if len(sub) == 0:
                    continue
                if colorer is not None:
                    for v in sub.participants():
                        if sub.degree(v) > budget.get(v, 1):
                            raise ValueError(
                                f"colorer over-subscribed node {v}: "
                                f"{sub.degree(v)} links > {budget.get(v, 1)} antennas"
                            )
                links = {
                    (i, j): self.link(t, i, j) for i, j in sub.edge_list()
                }
                # slot ends when its slowest transfer lands (acquisition for
                # freshly pointed edges + serialization + propagation) — the
                # getMeas completion time of the sub-slot
                duration = max(
                    l.transfer_time_s(
                        payload_bytes,
                        acquisition_s
                        if acquisition_s > 0.0 and e not in prev_edges
                        else 0.0,
                    )
                    for e, l in links.items()
                )
                yield Slot(
                    relation=sub,
                    t_index=t,
                    start_s=cursor,
                    duration_s=duration,
                    min_rate_bps=min(l.rate_bps for l in links.values()),
                    max_delay_s=max(l.delay_s for l in links.values()),
                    links=links,
                )
                cursor += duration
                prev_edges = frozenset(links)
                prev_rel = sub

    def schedule(
        self,
        antennas: AntennaSpec = None,
        payload_bytes: int = 1 << 20,
        alive: Optional[Iterable[int]] = None,
        max_slots: Optional[int] = None,
        optimize: Optional[str] = None,
        acquisition_s: float = 0.0,
        colorer: Optional[Colorer] = None,
    ) -> ContactSchedule:
        """Materialize the stream into a validated ``ContactSchedule``.

        ``optimize`` selects the decomposition policy: ``None``/``"greedy"``
        emit the first legal coloring (Misra–Gries + first-fit packing);
        ``"rate"`` searches the full strategy portfolio of
        :func:`repro.constellation.optimizer.optimize_schedule` and returns
        the schedule with the lowest oracle cost (never worse than greedy —
        the greedy schedule is always in the candidate set); any single
        strategy name (``"slow_first"``, ``"mwm"``, ``"overlap"``) races just
        that strategy against greedy."""
        if optimize not in (None, "greedy"):
            if colorer is not None:
                raise ValueError(
                    "colorer and optimize are mutually exclusive: optimize "
                    "selects its own decomposition strategies"
                )
            from repro.constellation.optimizer import optimize_schedule

            return optimize_schedule(
                self,
                antennas=antennas,
                payload_bytes=payload_bytes,
                alive=alive,
                acquisition_s=acquisition_s,
                mode=optimize,
                max_slots=max_slots,
            ).schedule
        slots: List[Slot] = []
        for slot in self.iter_slots(
            antennas, payload_bytes, alive, acquisition_s, colorer
        ):
            slots.append(slot)
            if max_slots is not None and len(slots) >= max_slots:
                break
        return ContactSchedule(
            tdm=TDMSchedule(tuple(s.relation for s in slots)), slots=tuple(slots)
        )


def build_contact_plan(
    geom: WalkerDelta,
    duration_s: float,
    step_s: float,
    budget: LinkBudget = LinkBudget(),
    ground_stations: Sequence[GroundStation] = (),
    candidates: Union[str, Sequence[Edge]] = "all",
    max_range_km: Optional[float] = None,
    min_rate_bps: float = 0.0,
) -> ContactPlan:
    """Propagate, evaluate links, and package the time-varying graph.

    ``candidates`` is ``"all"`` (any pair may link — phased-array/optical
    gimbal), ``"plus_grid"`` (fixed fore/aft + cross-plane terminals), or an
    explicit edge list. Ground stations (node ids after the satellites)
    participate only in ``"all"`` mode or when listed explicitly; their
    links use the budget's elevation mask instead of limb occlusion.
    """
    times = orbits_lib.sample_times(duration_s, step_s)
    tracks = orbits_lib.propagate(geom, times, ground_stations)
    if isinstance(candidates, str):
        if candidates == "all":
            cand = None
        elif candidates == "plus_grid":
            cand = plus_grid_candidates(geom)
        else:
            raise ValueError(f"unknown candidate mode {candidates!r}")
    else:
        cand = list(candidates)
    ground_nodes = range(geom.total, tracks.shape[1])
    graphs = links_lib.visibility_series(
        tracks, budget, cand, max_range_km, min_rate_bps, ground_nodes
    )
    return ContactPlan(
        n_nodes=tracks.shape[1],
        times=tuple(float(t) for t in times),
        graphs=tuple(graphs),
        step_s=float(step_s),
    )


# ---------------------------------------------------------------------------
# Legacy toy model (duty-cycled +grid) — kept only for the deprecated
# repro.core.schedule.WalkerConstellation shim.
# ---------------------------------------------------------------------------

def legacy_duty_cycle_relation(
    geom: WalkerDelta, t_slot: int, cross_plane_duty: int = 4
) -> Relation:
    """The pre-subsystem invented topology: permanent intra-plane ring plus
    duty-cycled, phasing-shifted cross-plane edges. Not geometry — prefer
    :func:`build_contact_plan`."""
    edges: List[Tuple[int, int]] = []
    s = geom.per_plane
    for p in range(geom.planes):
        for k in range(s):
            edges.append((geom.node_id(p, k), geom.node_id(p, k + 1)))
    for p in range(geom.planes - 1):
        if (t_slot + p) % cross_plane_duty == 0:
            continue  # cross-plane link outage window
        shift = (geom.phasing * (t_slot % s)) % s
        for k in range(s):
            edges.append((geom.node_id(p, k), geom.node_id(p + 1, (k + shift) % s)))
    dedup = {(min(a, b), max(a, b)) for a, b in edges if a != b}
    return Relation.from_edges(sorted(dedup), nodes=range(geom.total))
