"""Contact plans: orbital geometry → per-slot exchange relations → TDM.

The pipeline the paper assumes exists but never specifies:

1. propagate the constellation (:mod:`orbits`) over a sample grid,
2. evaluate the weighted visibility graph per step (:mod:`links`),
3. extract contact windows, and
4. emit per-slot :class:`~repro.core.relation.Relation`s that honor
   per-node antenna budgets (reusing ``edge_coloring`` /
   ``antenna_constrained``) with bandwidth-aware slot sizing — a
   :class:`ContactSchedule` whose ``.tdm`` is a plain ``TDMSchedule`` every
   existing collective (``get_meas``/``get1_meas``/gossip) consumes as-is.

Occlusion is handled by construction: a satellite with no line of sight
simply has no pairs in that step's relation, which is exactly the paper's
``odata=None`` skip-slot case (and what ``Relation.restrict`` produces for
failures).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.constellation import links as links_lib
from repro.constellation import orbits as orbits_lib
from repro.constellation.links import Edge, Link, LinkBudget, VisibilityMatrix
from repro.constellation.orbits import Geometry, GroundStation, MultiShell, WalkerDelta
from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule, antenna_constrained
from repro.telemetry import metrics
from repro.telemetry import recorder as telemetry

AntennaSpec = Union[int, Dict[int, int], None]

# A colorer turns one time step's (relation, per-edge links, antenna budget,
# previous emitted sub-slot relation) into an ordered list of sub-slot
# relations. The schedule optimizer supplies rate-aware colorers; ``None``
# means the default Misra–Gries + first-fit antenna packing.
Colorer = Callable[
    [Relation, Dict[Edge, Link], Dict[int, int], Optional[Relation]],
    Sequence[Relation],
]


def _antenna_map(antennas: AntennaSpec, nodes: Iterable[int]) -> Dict[int, int]:
    if antennas is None:
        return {v: 1 for v in nodes}
    if isinstance(antennas, int):
        return {v: antennas for v in nodes}
    return {v: antennas.get(v, 1) for v in nodes}


def plus_grid_candidates(geom: Geometry, cross_plane: bool = True) -> List[Edge]:
    """The +grid ISL candidate set: each satellite's terminals point at its
    intra-plane fore/aft neighbors and (optionally) the same-slot satellite
    in each adjacent plane. Geometry still gates every candidate — a
    candidate pair with the Earth in between produces no contact.

    A :class:`MultiShell` gets the union of its shells' +grids (node ids
    offset per shell); inter-shell ISLs need an explicit candidate list."""
    if isinstance(geom, MultiShell):
        edges: List[Edge] = []
        for off, shell in zip(geom.shell_offsets(), geom.shells):
            edges.extend(
                (a + off, b + off)
                for a, b in plus_grid_candidates(shell, cross_plane)
            )
        return edges
    edges = []
    s = geom.per_plane
    for p in range(geom.planes):
        for k in range(s):
            if s > 1:
                edges.append((geom.node_id(p, k), geom.node_id(p, k + 1)))
            if cross_plane and geom.planes > 1:
                edges.append((geom.node_id(p, k), geom.node_id((p + 1) % geom.planes, k)))
    return sorted({(min(a, b), max(a, b)) for a, b in edges if a != b})


def sat_ground_candidates(geom: Geometry, n_ground: int) -> List[Edge]:
    """Every satellite × ground-station candidate pair (gateway downlinks).

    Ground stations occupy node ids ``geom.total .. geom.total+n_ground-1``
    (the :func:`repro.constellation.orbits.propagate` layout). Combine with
    :func:`plus_grid_candidates` to plan a constellation whose terminals are
    fixed +grid ISLs plus steerable ground feeders — the elevation mask and
    link budget still gate every pair."""
    n = geom.total
    return [(s, n + g) for g in range(n_ground) for s in range(n)]


@dataclass(frozen=True)
class ContactWindow:
    """A maximal interval during which an edge stays feasible."""

    i: int
    j: int
    t_start_s: float
    t_end_s: float
    min_rate_bps: float
    mean_rate_bps: float

    @property
    def duration_s(self) -> float:
        return self.t_end_s - self.t_start_s


@dataclass(frozen=True)
class Slot:
    """One emitted TDM slot: a relation plus its physical sizing."""

    relation: Relation
    t_index: int          # contact-plan time step this slot came from
    start_s: float        # slot start on the wall clock
    duration_s: float     # bandwidth-aware: slowest edge's transfer + delay
    min_rate_bps: float   # bottleneck link rate inside the slot
    max_delay_s: float    # worst one-way propagation delay inside the slot
    links: Dict[Edge, Link] = None  # per-edge physics (keys (i, j), i < j)


@dataclass(frozen=True)
class ContactSchedule:
    """A ``TDMSchedule`` plus per-slot physical metadata (aligned 1:1)."""

    tdm: TDMSchedule
    slots: Tuple[Slot, ...]

    def __post_init__(self):
        if len(self.tdm) != len(self.slots):
            raise ValueError("tdm slots and metadata misaligned")

    def __len__(self) -> int:
        return len(self.slots)

    @property
    def busy_s(self) -> float:
        """Total link-occupied time (sum of slot durations, gaps excluded)."""
        return sum(s.duration_s for s in self.slots)

    @property
    def span_s(self) -> float:
        """Wall-clock span from the first slot's start to the last slot's
        end — includes the idle gaps between contact-plan steps."""
        if not self.slots:
            return 0.0
        last = self.slots[-1]
        return last.start_s + last.duration_s - self.slots[0].start_s

    def max_antennas(self) -> int:
        return self.tdm.max_antennas()

    def restrict(
        self, alive: Iterable[int], antennas: AntennaSpec = None
    ) -> "ContactSchedule":
        """Drop failed/occluded nodes and re-validate the schedule.

        ``TDMSchedule.restrict`` alone is not enough for a materialized
        (possibly optimizer-produced) schedule: the per-slot metadata would
        keep dead edges in ``links`` and stale ``min_rate_bps`` bottlenecks.
        This rebuilds each surviving slot from its surviving links, drops
        slots that went empty, keeps ``tdm`` and ``slots`` aligned, and —
        when ``antennas`` is given — re-validates the per-node budget
        (``TDMSchedule.validate_antennas``). Slot starts/durations are kept:
        the TDM grid was already committed and surviving transfers only get
        faster when a slower edge drops out."""
        alive_s = set(alive)
        slots: List[Slot] = []
        for slot in self.slots:
            r = slot.relation.restrict(alive_s)
            if len(r) == 0:
                continue
            links = {e: slot.links[e] for e in r.edge_list()}
            slots.append(
                dataclasses.replace(
                    slot,
                    relation=r,
                    links=links,
                    min_rate_bps=min(l.rate_bps for l in links.values()),
                    max_delay_s=max(l.delay_s for l in links.values()),
                )
            )
        out = ContactSchedule(
            tdm=TDMSchedule(tuple(s.relation for s in slots)), slots=tuple(slots)
        )
        if antennas is not None:
            parts = {v for s in slots for v in s.relation.participants()}
            out.tdm.validate_antennas(_antenna_map(antennas, parts))
        return out


def link_accounting(
    sched: ContactSchedule, payload_bytes: int
) -> Dict[str, object]:
    """Per-link bytes / busy-time / utilization-vs-capacity over a
    materialized schedule — the link-layer summary mission reports embed.

    For every edge: how many slots it rode, the payload bytes scheduled
    over it, the time it actually spent transferring, and its utilization
    against capacity (scheduled bytes / bytes the link could have carried
    at ``rate_bps`` during the slots it was active in). Utilization well
    below 1.0 marks links the slot sizing leaves idle (slots last as long
    as their slowest member); the schedule-level ``occupancy`` compares
    link-busy time to the full wall-clock span, gaps included. Keys are
    ``"i-j"`` strings so the dict drops straight into a JSON report.
    """
    per: Dict[Edge, Dict[str, float]] = {}
    for slot in sched.slots:
        for e, link in (slot.links or {}).items():
            d = per.setdefault(
                e,
                {"slots": 0, "bytes": 0.0, "busy_s": 0.0, "capacity_bytes": 0.0},
            )
            d["slots"] += 1
            d["bytes"] += float(payload_bytes)
            d["busy_s"] += min(
                link.transfer_time_s(payload_bytes), slot.duration_s
            )
            d["capacity_bytes"] += link.rate_bps * slot.duration_s / 8.0
    for d in per.values():
        d["utilization"] = (
            d["bytes"] / d["capacity_bytes"] if d["capacity_bytes"] > 0 else 0.0
        )
    total_bytes = sum(d["bytes"] for d in per.values())
    utils = [d["utilization"] for d in per.values()]
    return {
        "n_slots": len(sched),
        "n_links": len(per),
        "total_bytes": total_bytes,
        "busy_s": sched.busy_s,
        "span_s": sched.span_s,
        "occupancy": sched.busy_s / sched.span_s if sched.span_s > 0 else 0.0,
        "mean_utilization": sum(utils) / len(utils) if utils else 0.0,
        "min_utilization": min(utils) if utils else 0.0,
        "links": {f"{i}-{j}": d for (i, j), d in sorted(per.items())},
    }


@dataclass(frozen=True)
class ContactPlan:
    """Weighted time-varying visibility over a sample grid.

    ``graphs[t]`` is the feasible-edge map at ``times[t]``; node ids are the
    Walker layout (satellites first, then ground stations).
    """

    n_nodes: int
    times: Tuple[float, ...]
    graphs: Tuple[Dict[Edge, Link], ...]
    step_s: float
    # Batched (T, E) link physics when the plan came through the vectorized
    # pipeline — lets windows() run as an array pass instead of per-step
    # dict scans. Pure acceleration metadata: excluded from equality so a
    # plan with and without it is the same plan.
    matrix: Optional[VisibilityMatrix] = dataclasses.field(
        default=None, compare=False, repr=False
    )

    # -------------------------------------------------- lazy graph backing
    @property
    def _graphs_deferred(self) -> bool:
        """True for a matrix-backed plan built with ``with_graphs=False`` —
        windows/relations/routing run off the arrays; the per-step Link
        dicts only get materialized if something (the scheduler) needs
        them."""
        return (
            not self.graphs
            and self.matrix is not None
            and self.matrix.n_steps > 0
        )

    def with_graphs(self) -> "ContactPlan":
        """Materialize the per-step ``{edge: Link}`` dicts from the matrix
        (no-op when they are already present)."""
        if not self._graphs_deferred:
            return self
        return dataclasses.replace(self, graphs=tuple(self.matrix.graphs()))

    # ----------------------------------------------------------- relations
    def relation(self, t_index: int) -> Relation:
        """The (possibly empty) exchange relation at one time step."""
        if self._graphs_deferred:
            vm = self.matrix
            live = np.flatnonzero(vm.visible[t_index])
            edges = list(zip(vm.iu[live].tolist(), vm.ju[live].tolist()))
            return Relation.from_edges(edges, nodes=range(self.n_nodes))
        return Relation.from_edges(
            sorted(self.graphs[t_index]), nodes=range(self.n_nodes)
        )

    def relations(self) -> List[Relation]:
        """One relation per time step — the time-varying schedule FL loops
        iterate (empty relation = everyone skips the slot)."""
        return [self.relation(t) for t in range(len(self.times))]

    def link(self, t_index: int, i: int, j: int) -> Link:
        return self.graphs[t_index][(min(i, j), max(i, j))]

    # ------------------------------------------------------------- windows
    def windows(self) -> List[ContactWindow]:
        """Merge per-step feasibility into maximal contact windows.

        With a :class:`VisibilityMatrix` attached this is a run-length pass
        over the ``(T, E)`` feasibility array (per-candidate-edge
        ``flatnonzero``/``diff``); otherwise it falls back to the legacy
        per-step dict scan. Both orders end in the same total sort key, and
        the rate statistics are computed over the identical float sequence,
        so the two paths are bit-identical (equivalence suite asserts it).
        """
        if self.matrix is None:
            return self.windows_reference()
        vm = self.matrix
        if vm.n_candidates == 0 or vm.n_steps == 0:
            return []
        T = vm.n_steps
        # one run-length pass over ALL edges at once: transpose to (E, T),
        # append a False column so every run closes inside its own row, and
        # read run starts/ends off the sign changes of the flattened array
        vis = np.concatenate(
            (vm.visible.T, np.zeros((vm.n_candidates, 1), dtype=bool)), axis=1
        )
        flat = vis.ravel().view(np.int8)
        d = np.diff(flat, prepend=np.int8(0))
        starts = np.flatnonzero(d == 1)
        stops = np.flatnonzero(d == -1)        # exclusive
        rates_t = np.ascontiguousarray(vm.rate_bps.T)  # (E, T) row slices
        iu_l, ju_l = vm.iu.tolist(), vm.ju.tolist()
        out: List[ContactWindow] = []
        for s, p in zip(starts.tolist(), stops.tolist()):
            e, t0 = divmod(s, T + 1)
            t1 = t0 + (p - s) - 1
            rates = rates_t[e, t0 : t1 + 1]
            out.append(
                ContactWindow(
                    i=iu_l[e],
                    j=ju_l[e],
                    t_start_s=self.times[t0],
                    t_end_s=self.times[t1] + self.step_s,
                    min_rate_bps=float(rates.min()),
                    mean_rate_bps=float(np.mean(rates)),
                )
            )
        out.sort(key=lambda w: (w.t_start_s, w.i, w.j))
        return out

    def windows_reference(self) -> List[ContactWindow]:
        """The legacy per-step dict-scan window extraction, retained as the
        equivalence oracle for the run-length fast path."""
        open_: Dict[Edge, List] = {}   # edge -> [t_start_idx, rates]
        out: List[ContactWindow] = []

        def close(edge: Edge, start_idx: int, end_idx: int, rates: List[float]):
            out.append(
                ContactWindow(
                    i=edge[0],
                    j=edge[1],
                    t_start_s=self.times[start_idx],
                    t_end_s=self.times[end_idx] + self.step_s,
                    min_rate_bps=min(rates),
                    mean_rate_bps=float(np.mean(rates)),
                )
            )

        for t, graph in enumerate(self.graphs):
            for edge, link in graph.items():
                if edge in open_:
                    open_[edge][2].append(link.rate_bps)
                    open_[edge][1] = t
                else:
                    open_[edge] = [t, t, [link.rate_bps]]
            for edge in [e for e in open_ if e not in graph]:
                start, end, rates = open_.pop(edge)
                close(edge, start, end, rates)
        for edge, (start, end, rates) in sorted(open_.items()):
            close(edge, start, end, rates)
        out.sort(key=lambda w: (w.t_start_s, w.i, w.j))
        return out

    # ------------------------------------------------------------ schedule
    def iter_slots(
        self,
        antennas: AntennaSpec = None,
        payload_bytes: int = 1 << 20,
        alive: Optional[Iterable[int]] = None,
        acquisition_s: float = 0.0,
        colorer: Optional[Colorer] = None,
    ) -> Iterator[Slot]:
        """Stream TDM slots in wall-clock order (lazy — no materialization).

        Each time step's visibility relation is split by
        ``antenna_constrained`` into sub-slots a node's terminal count can
        realize; each sub-slot is sized so the payload clears the slowest
        link it contains (plus one-way propagation). Dead/occluded nodes are
        dropped via ``Relation.restrict`` (paper skip-slot semantics).

        ``acquisition_s > 0`` prices terminal retargeting: an edge that was
        not active in the immediately preceding sub-slot pays the slew/
        acquisition penalty before its transfer (warm edges pay nothing).
        ``colorer`` swaps the default decomposition for a rate-aware one
        (see :mod:`repro.constellation.optimizer`); its output is validated
        against the antenna budget.
        """
        if self._graphs_deferred:
            # scheduling needs per-edge Link physics — materialize now
            yield from self.with_graphs().iter_slots(
                antennas, payload_bytes, alive, acquisition_s, colorer
            )
            return
        alive_s = set(alive) if alive is not None else None
        cursor = 0.0
        prev_edges: frozenset = frozenset()
        prev_rel: Optional[Relation] = None
        for t in range(len(self.times)):
            rel = self.relation(t)
            if alive_s is not None:
                rel = rel.restrict(alive_s)
            if len(rel) == 0:
                continue
            budget = _antenna_map(antennas, rel.nodes)
            # monotone cursor: sub-slots never overlap, even when the
            # previous step's payload overran its sampling interval (the
            # schedule then runs behind the plan cadence rather than
            # emitting physically impossible concurrent slots)
            cursor = max(cursor, float(self.times[t]))
            if colorer is None:
                subs = list(antenna_constrained(rel, budget))
            else:
                subs = list(colorer(rel, self.graphs[t], budget, prev_rel))
            for sub in subs:
                if len(sub) == 0:
                    continue
                if colorer is not None:
                    for v in sub.participants():
                        if sub.degree(v) > budget.get(v, 1):
                            raise ValueError(
                                f"colorer over-subscribed node {v}: "
                                f"{sub.degree(v)} links > {budget.get(v, 1)} antennas"
                            )
                links = {
                    (i, j): self.link(t, i, j) for i, j in sub.edge_list()
                }
                # slot ends when its slowest transfer lands (acquisition for
                # freshly pointed edges + serialization + propagation) — the
                # getMeas completion time of the sub-slot
                duration = max(
                    l.transfer_time_s(
                        payload_bytes,
                        acquisition_s
                        if acquisition_s > 0.0 and e not in prev_edges
                        else 0.0,
                    )
                    for e, l in links.items()
                )
                # link-layer accounting (default-on, host-side only): slot
                # occupancy plus each edge's busy fraction of the slot it
                # rides — the slot lasts as long as its slowest transfer,
                # so fast links idle for the rest. Counts cover every
                # schedule this plan materializes (the optimizer race
                # streams candidate schedules through here too).
                rec = telemetry.get_recorder()
                rec.counter("contact.slots_emitted")
                rec.counter(
                    "contact.scheduled_bytes", float(payload_bytes) * len(links)
                )
                metrics.observe(
                    "contact.slot_duration_s",
                    duration,
                    buckets=metrics.LOG_BUCKETS,
                    rec=rec,
                )
                metrics.observe(
                    "contact.slot_links",
                    len(links),
                    buckets=metrics.COUNT_BUCKETS,
                    rec=rec,
                )
                for l in links.values():
                    busy = l.transfer_time_s(payload_bytes)
                    metrics.observe(
                        "contact.link_utilization",
                        min(busy / duration, 1.0) if duration > 0 else 1.0,
                        buckets=metrics.UNIT_BUCKETS,
                        rec=rec,
                    )
                yield Slot(
                    relation=sub,
                    t_index=t,
                    start_s=cursor,
                    duration_s=duration,
                    min_rate_bps=min(l.rate_bps for l in links.values()),
                    max_delay_s=max(l.delay_s for l in links.values()),
                    links=links,
                )
                cursor += duration
                prev_edges = frozenset(links)
                prev_rel = sub

    def schedule(
        self,
        antennas: AntennaSpec = None,
        payload_bytes: int = 1 << 20,
        alive: Optional[Iterable[int]] = None,
        max_slots: Optional[int] = None,
        optimize: Optional[str] = None,
        acquisition_s: float = 0.0,
        colorer: Optional[Colorer] = None,
    ) -> ContactSchedule:
        """Materialize the stream into a validated ``ContactSchedule``.

        ``optimize`` selects the decomposition policy: ``None``/``"greedy"``
        emit the first legal coloring (Misra–Gries + first-fit packing);
        ``"rate"`` searches the full strategy portfolio of
        :func:`repro.constellation.optimizer.optimize_schedule` and returns
        the schedule with the lowest oracle cost (never worse than greedy —
        the greedy schedule is always in the candidate set); any single
        strategy name (``"slow_first"``, ``"mwm"``, ``"overlap"``) races just
        that strategy against greedy."""
        if optimize not in (None, "greedy"):
            if colorer is not None:
                raise ValueError(
                    "colorer and optimize are mutually exclusive: optimize "
                    "selects its own decomposition strategies"
                )
            from repro.constellation.optimizer import optimize_schedule

            return optimize_schedule(
                # materialize once — the race iterates the slots per strategy
                self.with_graphs(),
                antennas=antennas,
                payload_bytes=payload_bytes,
                alive=alive,
                acquisition_s=acquisition_s,
                mode=optimize,
                max_slots=max_slots,
            ).schedule
        slots: List[Slot] = []
        for slot in self.with_graphs().iter_slots(
            antennas, payload_bytes, alive, acquisition_s, colorer
        ):
            slots.append(slot)
            if max_slots is not None and len(slots) >= max_slots:
                break
        return ContactSchedule(
            tdm=TDMSchedule(tuple(s.relation for s in slots)), slots=tuple(slots)
        )


def build_contact_plan(
    geom: Geometry,
    duration_s: float,
    step_s: float,
    budget: LinkBudget = LinkBudget(),
    ground_stations: Sequence[GroundStation] = (),
    candidates: Union[str, Sequence[Edge]] = "all",
    max_range_km: Optional[float] = None,
    min_rate_bps: float = 0.0,
    with_graphs: bool = True,
) -> ContactPlan:
    """Propagate, evaluate links, and package the time-varying graph.

    ``candidates`` is ``"all"`` (any pair may link — phased-array/optical
    gimbal), ``"plus_grid"`` (fixed fore/aft + cross-plane terminals), or an
    explicit edge list. Ground stations (node ids after the satellites)
    participate only in ``"all"`` mode or when listed explicitly; their
    links use the budget's elevation mask instead of limb occlusion.

    ``with_graphs=False`` skips materializing the per-step ``{edge: Link}``
    dicts — at mega-constellation scale building the Link objects costs
    more than the batched physics itself, and windows / relations / routing
    all run straight off the :class:`VisibilityMatrix`. Anything that does
    need the dicts (``schedule``) materializes them lazily via
    :meth:`ContactPlan.with_graphs`.
    """
    times = orbits_lib.sample_times(duration_s, step_s)
    tracks = orbits_lib.propagate(geom, times, ground_stations)
    if isinstance(candidates, str):
        if candidates == "all":
            cand = None
        elif candidates == "plus_grid":
            cand = plus_grid_candidates(geom)
        else:
            raise ValueError(f"unknown candidate mode {candidates!r}")
    else:
        cand = list(candidates)
    ground_nodes = range(geom.total, tracks.shape[1])
    vm = links_lib.visibility_matrix(
        tracks, budget, cand, max_range_km, min_rate_bps, ground_nodes
    )
    return ContactPlan(
        n_nodes=tracks.shape[1],
        times=tuple(float(t) for t in times),
        graphs=tuple(vm.graphs()) if with_graphs else (),
        step_s=float(step_s),
        matrix=vm,
    )


