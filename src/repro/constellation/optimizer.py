"""Rate-aware TDM schedule optimization: search, scored by the cost oracle.

PR 1's contact plans emit the *first* legal coloring — Misra–Gries matchings
packed first-fit into antenna-feasible sub-slots, blind to link rates. This
module searches over feasible schedules instead. Each *strategy* is a
complete decomposition policy applied uniformly across the plan:

- ``greedy``     — the rate-blind baseline, exactly what
  ``ContactPlan.schedule()`` emits today (always in the candidate set).
- ``slow_first`` — ``weighted_edge_coloring`` on per-edge transfer times:
  slow edges grouped into shared color classes so a fast edge's sub-slot is
  never sized by a slot-straggler.
- ``mwm``        — peel maximum-weight matchings (weight = link rate, via
  networkx blossom): each sub-slot carries the highest aggregate rate the
  remaining edges allow — the fastest exchanges complete earliest.
- ``overlap``    — ``slow_first`` grouping, then sub-slots reordered at step
  boundaries to keep links warm (edges active in consecutive sub-slots skip
  the slew/acquisition penalty).

Every strategy materializes a real :class:`ContactSchedule` through
``ContactPlan.iter_slots`` (so antenna budgets, monotone wall clock, and
skip-slot semantics all still hold) and the winner is chosen by
:func:`repro.constellation.cost.schedule_cost` — the same analytic oracle
the property tests check against. Because the greedy baseline is scored with
the identical oracle and kept when nothing beats it, the optimizer provably
never loses to greedy.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro import telemetry
from repro.constellation import cost as cost_lib
from repro.telemetry import metrics
from repro.constellation.contact_plan import (
    AntennaSpec,
    Colorer,
    ContactPlan,
    ContactSchedule,
)
from repro.constellation.links import Edge, Link
from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule, pack_matchings, weighted_edge_coloring

STRATEGIES = ("greedy", "slow_first", "mwm", "overlap")


def edge_times_s(links: Dict[Edge, Link], payload_bytes: int) -> Dict[Edge, float]:
    """Per-edge completion time (``Link.transfer_time_s``, the same formula
    slot sizing and the cost oracle use) — the weights the rate-aware
    colorings group by."""
    return {e: l.transfer_time_s(payload_bytes) for e, l in links.items()}


def mwm_peeling(rel: Relation, rates: Dict[Edge, float]) -> List[Relation]:
    """Decompose ``rel`` by repeatedly extracting the maximum-weight matching
    of the remaining edges (weight = link rate). The first color classes
    carry the highest aggregate throughput, so fast exchanges finish before
    any slow edge gets to straggle. Each class is a matching and the classes
    partition ``rel``'s edge set."""
    import networkx as nx

    remaining = set(rel.edge_list())
    out: List[Relation] = []
    while remaining:
        g = nx.Graph()
        g.add_weighted_edges_from(
            (u, v, float(rates.get((u, v), 0.0))) for u, v in remaining
        )
        picked = {
            (min(a, b), max(a, b))
            for a, b in nx.max_weight_matching(g, maxcardinality=True)
        }
        if not picked:  # pragma: no cover - blossom always matches >= 1 edge
            picked = {min(remaining)}
        out.append(Relation.from_edges(sorted(picked), nodes=rel.nodes))
        remaining -= picked
    return out


def order_for_overlap(
    subs: Sequence[Relation], prev: Optional[Relation]
) -> List[Relation]:
    """Greedily chain sub-slots so each keeps the most edges warm from its
    predecessor (ties break toward the original order). Within one time step
    sub-slots are edge-disjoint, so in practice this picks which sub-slot
    inherits the previous *step*'s pointing."""
    rest = list(subs)
    out: List[Relation] = []
    warm = set(prev.edge_list()) if prev is not None else set()
    while rest:
        scores = [len(warm & set(r.edge_list())) for r in rest]
        best = scores.index(max(scores))
        chosen = rest.pop(best)
        out.append(chosen)
        warm = set(chosen.edge_list())
    return out


def _slow_first_colorer(payload_bytes: int) -> Colorer:
    def colorer(rel, links, budget, prev):
        times = edge_times_s(links, payload_bytes)
        return pack_matchings(weighted_edge_coloring(rel, times), budget, rel.nodes)

    return colorer


def _mwm_colorer(payload_bytes: int) -> Colorer:
    def colorer(rel, links, budget, prev):
        rates = {e: l.rate_bps for e, l in links.items()}
        return pack_matchings(mwm_peeling(rel, rates), budget, rel.nodes)

    return colorer


def _overlap_colorer(payload_bytes: int) -> Colorer:
    slow = _slow_first_colorer(payload_bytes)

    def colorer(rel, links, budget, prev):
        return order_for_overlap(slow(rel, links, budget, prev), prev)

    return colorer


_COLORER_FACTORIES = {
    "greedy": None,  # ContactPlan.iter_slots' built-in path, bit-for-bit
    "slow_first": _slow_first_colorer,
    "mwm": _mwm_colorer,
    "overlap": _overlap_colorer,
}


@dataclass(frozen=True)
class OptimizationResult:
    """The winning schedule plus the full per-strategy scoreboard."""

    schedule: ContactSchedule
    strategy: str
    costs: Dict[str, cost_lib.RoundCost]

    @property
    def baseline(self) -> cost_lib.RoundCost:
        return self.costs["greedy"]

    @property
    def chosen(self) -> cost_lib.RoundCost:
        return self.costs[self.strategy]

    @property
    def speedup(self) -> float:
        """Greedy round time over the chosen schedule's (>= 1 by construction)."""
        if self.chosen.time_s <= 0.0:
            return 1.0
        return self.baseline.time_s / self.chosen.time_s


def optimize_schedule(
    plan: ContactPlan,
    antennas: AntennaSpec = None,
    payload_bytes: int = 1 << 20,
    alive: Optional[Iterable[int]] = None,
    acquisition_s: float = 0.0,
    mode: str = "rate",
    comm_mode: str = "getmeas",
    max_slots: Optional[int] = None,
    objective: str = "gossip",
    sinks: Optional[Iterable[int]] = None,
    pipeline_depth: int = 1,
    max_staleness_windows: int = 0,
    strategies: Optional[Sequence[str]] = None,
) -> OptimizationResult:
    """Pick the cheapest feasible schedule for ``plan`` under the cost oracle.

    ``mode`` is ``"rate"`` (race the whole strategy portfolio) or a single
    strategy name from :data:`STRATEGIES` (raced against greedy).
    ``strategies`` overrides ``mode`` with an explicit portfolio subset —
    greedy is injected regardless (mega-constellation plans subset away
    ``"mwm"``, whose O(V³) blossom dominates at 1000+ nodes). The greedy
    baseline is *always* a candidate and wins ties, so the returned
    schedule's ``schedule_cost`` is never above the baseline's — the
    invariant ``tests/test_schedule_optimizer.py`` proves on random plans.

    ``objective`` selects what the oracle prices: ``"gossip"`` (default)
    scores one decentralized TDM pass (``cost.schedule_cost``);
    ``"groundseg"`` scores a sink-based centralized round — uplink relays
    + downlink broadcast routed over each candidate's slots
    (``cost.groundseg_schedule_cost``; requires ``sinks``). With
    ``pipeline_depth=2`` (and optionally ``max_staleness_windows``) the
    groundseg objective prices the steady-state PIPELINED round, so the
    optimizer picks the schedule whose bottleneck stage is shortest. The
    never-worse-than-greedy guarantee holds per objective, since every
    candidate is scored by the same oracle.

    Candidates are always scored over the FULL plan (equal work — every
    candidate realizes the same exchanges). ``max_slots`` then caps the
    *returned winner's* materialized slots, exactly like
    ``ContactPlan.schedule(max_slots=)``; truncating before scoring would
    let a "winner" look fast by simply skipping expensive exchanges.
    """
    if objective not in ("gossip", "groundseg"):
        raise ValueError(
            f"objective must be 'gossip' or 'groundseg', got {objective!r}"
        )
    if objective == "groundseg" and sinks is None:
        raise ValueError("objective='groundseg' needs the sink node ids")
    if strategies is not None:
        bad = sorted(set(strategies) - set(_COLORER_FACTORIES))
        if bad:
            raise ValueError(
                f"unknown strategies {bad}; choose from {sorted(_COLORER_FACTORIES)}"
            )
        # greedy is always raced (the never-worse anchor); order preserved
        names: Tuple[str, ...] = tuple(dict.fromkeys(("greedy", *strategies)))
    elif mode == "rate":
        names = STRATEGIES
    elif mode in _COLORER_FACTORIES:
        names = ("greedy", mode) if mode != "greedy" else ("greedy",)
    else:
        raise ValueError(
            f"optimize mode must be 'rate' or one of {sorted(_COLORER_FACTORIES)}, "
            f"got {mode!r}"
        )
    plan = plan.with_graphs()   # materialize Link dicts once, not per strategy
    candidates: Dict[str, ContactSchedule] = {}
    costs: Dict[str, cost_lib.RoundCost] = {}
    for name in names:
        factory = _COLORER_FACTORIES[name]
        colorer = None if factory is None else factory(payload_bytes)
        sched = plan.schedule(
            antennas=antennas,
            payload_bytes=payload_bytes,
            alive=alive,
            acquisition_s=acquisition_s,
            colorer=colorer,
        )
        candidates[name] = sched
        if objective == "groundseg":
            costs[name] = cost_lib.groundseg_schedule_cost(
                sched, sinks, payload_bytes, n_nodes=plan.n_nodes,
                pipeline_depth=pipeline_depth,
                max_staleness_windows=max_staleness_windows,
            )
        else:
            costs[name] = cost_lib.schedule_cost(
                sched, payload_bytes, comm_mode, acquisition_s
            )
    best = "greedy"
    for name in names:
        if costs[name].time_s < costs[best].time_s:
            best = name
    # flight-recorder note of the race outcome: every candidate's cost,
    # the winner, and its margin over the greedy baseline
    rec = telemetry.get_recorder()
    rec.counter("optimizer.races")
    rec.counter(f"optimizer.winner.{best}")
    greedy_t = costs["greedy"].time_s
    best_t = costs[best].time_s
    rec.event(
        "optimizer.race",
        cat="optimizer",
        objective=objective,
        winner=best,
        costs_s={n: costs[n].time_s for n in names},
        margin_vs_greedy_s=greedy_t - best_t,
        speedup=(greedy_t / best_t) if best_t > 0 else 1.0,
    )
    winner = candidates[best]
    if max_slots is not None and len(winner) > max_slots:
        winner = ContactSchedule(
            tdm=TDMSchedule(winner.tdm.slots[:max_slots]),
            slots=winner.slots[:max_slots],
        )
    return OptimizationResult(schedule=winner, strategy=best, costs=costs)


class WindowedOptimizer:
    """Incremental schedule optimization across consecutive plan windows.

    Re-racing the full strategy portfolio every window repeats work that
    consecutive windows almost always agree on (orbital geometry drifts
    slowly relative to a plan window). This warm-starts each window from
    the previous window's winning strategy:

    - window 0 (and any window after a winner change) races the FULL
      portfolio — recorded as ``optimizer.warm_start.race``;
    - subsequent windows race only {greedy, previous winner}. If the
      previous winner still wins, that cheap race is the answer —
      ``optimizer.warm_start.hit``. If it lost its edge (the geometry
      shifted), the full portfolio is re-raced immediately, so a stale
      warm start costs one extra cheap race, never a worse schedule.

    Greedy is a candidate in every race, so the per-window
    never-worse-than-greedy guarantee of :func:`optimize_schedule` is
    preserved verbatim. ``full_race_every=k`` (optional) forces a full
    re-race every k windows, bounding how long a greedy-winning streak can
    mask a newly profitable strategy.
    """

    def __init__(
        self,
        portfolio: Sequence[str] = STRATEGIES,
        full_race_every: int = 0,
        **optimize_kwargs,
    ):
        bad = sorted(set(portfolio) - set(_COLORER_FACTORIES))
        if bad:
            raise ValueError(
                f"unknown strategies {bad}; choose from {sorted(_COLORER_FACTORIES)}"
            )
        if full_race_every < 0:
            raise ValueError(f"full_race_every must be >= 0, got {full_race_every}")
        if "strategies" in optimize_kwargs or "mode" in optimize_kwargs:
            raise ValueError(
                "pass the portfolio positionally; WindowedOptimizer owns the "
                "per-window strategy selection"
            )
        self.portfolio = tuple(dict.fromkeys(("greedy", *portfolio)))
        self.full_race_every = int(full_race_every)
        self.optimize_kwargs = optimize_kwargs
        self._prev_winner: Optional[str] = None
        self._window = -1

    @property
    def window(self) -> int:
        """Index of the last optimized window (-1 before the first)."""
        return self._window

    def optimize(
        self, plan: ContactPlan, alive: Optional[Iterable[int]] = None
    ) -> OptimizationResult:
        """Optimize the next window's plan, warm-starting from the last."""
        self._window += 1
        rec = telemetry.get_recorder()
        due_full = (
            self._prev_winner is None
            or (
                self.full_race_every > 0
                and self._window % self.full_race_every == 0
            )
        )
        if not due_full:
            warm = optimize_schedule(
                plan,
                alive=alive,
                strategies=("greedy", self._prev_winner),
                **self.optimize_kwargs,
            )
            if warm.strategy == self._prev_winner:
                rec.counter("optimizer.warm_start.hit")
                self._update_hit_rate(rec)
                return warm
            # previous winner dethroned — the window changed character;
            # fall through to a full portfolio race
        rec.counter("optimizer.warm_start.race")
        self._update_hit_rate(rec)
        result = optimize_schedule(
            plan, alive=alive, strategies=self.portfolio, **self.optimize_kwargs
        )
        self._prev_winner = result.strategy
        return result

    @staticmethod
    def _update_hit_rate(rec) -> None:
        """Keep the warm-start hit-rate gauge current (hits over all
        windows optimized so far in this recording scope)."""
        hits = rec.get_counter("optimizer.warm_start.hit")
        races = rec.get_counter("optimizer.warm_start.race")
        metrics.ratio_gauge(
            "optimizer.warm_start.hit_rate", hits, hits + races, rec=rec
        )
