"""Circular-orbit propagation for Walker constellations and ground stations.

Everything is pure NumPy and deterministic: positions are closed-form
functions of time (no integrator state), so a contact plan generated twice
from the same geometry is bit-identical — the property the TDM scheduler
relies on when satellites compute the schedule independently (paper
assumption (a): common knowledge of the schedule).

Conventions: kilometres and seconds; ECI frame with the z-axis through the
north pole; a Walker pattern ``i:t/p/f`` is ``WalkerDelta(total=t, planes=p,
phasing=f, inclination_deg=i)``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

import numpy as np

MU_EARTH_KM3_S2 = 398600.4418      # standard gravitational parameter
R_EARTH_KM = 6371.0                # mean Earth radius
EARTH_ROT_RAD_S = 7.2921159e-5     # sidereal rotation rate


def _rot_x(a: float) -> np.ndarray:
    c, s = math.cos(a), math.sin(a)
    return np.array([[1.0, 0.0, 0.0], [0.0, c, -s], [0.0, s, c]])


def _rot_z(a: float) -> np.ndarray:
    c, s = math.cos(a), math.sin(a)
    return np.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])


@dataclass(frozen=True)
class WalkerDelta:
    """Walker constellation i:t/p/f on circular orbits.

    ``pattern="delta"`` spreads the ascending nodes over 360° (Kuiper/
    Starlink style); ``pattern="star"`` over 180° (Iridium style, polar
    seams). Satellite (plane p, slot k) has node id ``p * per_plane + k`` —
    the node ids the rest of the repo's relations/schedules use.
    """

    total: int = 24
    planes: int = 4
    phasing: int = 1
    inclination_deg: float = 53.0
    altitude_km: float = 550.0
    pattern: str = "delta"

    def __post_init__(self):
        if self.total % self.planes:
            raise ValueError("total must be divisible by planes")
        if self.pattern not in ("delta", "star"):
            raise ValueError(f"unknown Walker pattern {self.pattern!r}")

    # ------------------------------------------------------------- layout
    @property
    def per_plane(self) -> int:
        return self.total // self.planes

    def node_id(self, plane: int, slot: int) -> int:
        return (plane % self.planes) * self.per_plane + (slot % self.per_plane)

    def plane_of(self, node: int) -> int:
        return node // self.per_plane

    # ----------------------------------------------------------- dynamics
    @property
    def orbit_radius_km(self) -> float:
        return R_EARTH_KM + self.altitude_km

    @property
    def period_s(self) -> float:
        return 2.0 * math.pi * math.sqrt(self.orbit_radius_km ** 3 / MU_EARTH_KM3_S2)

    @property
    def mean_motion_rad_s(self) -> float:
        return 2.0 * math.pi / self.period_s

    def raan_rad(self, plane: int) -> float:
        spread = 2.0 * math.pi if self.pattern == "delta" else math.pi
        return spread * plane / self.planes

    def phase_rad(self, plane: int, slot: int) -> float:
        """Argument of latitude at t=0 (in-plane spacing + inter-plane
        phasing f: adjacent planes offset by 2π·f/total)."""
        return (
            2.0 * math.pi * slot / self.per_plane
            + 2.0 * math.pi * self.phasing * plane / self.total
        )

    def positions(self, t: float | np.ndarray) -> np.ndarray:
        """ECI positions at time(s) ``t`` (seconds).

        Scalar ``t`` -> (total, 3); array (T,) -> (T, total, 3). Km.
        """
        ts = np.atleast_1d(np.asarray(t, dtype=np.float64))
        r = self.orbit_radius_km
        n = self.mean_motion_rad_s
        inc = math.radians(self.inclination_deg)
        out = np.empty((ts.shape[0], self.total, 3))
        for p in range(self.planes):
            rot = _rot_z(self.raan_rad(p)) @ _rot_x(inc)
            for k in range(self.per_plane):
                u = self.phase_rad(p, k) + n * ts  # (T,)
                in_plane = np.stack(
                    [r * np.cos(u), r * np.sin(u), np.zeros_like(u)], axis=-1
                )
                out[:, self.node_id(p, k)] = in_plane @ rot.T
        return out if np.ndim(t) else out[0]


@dataclass(frozen=True)
class GroundStation:
    """A fixed Earth-surface terminal, rotated into ECI with the planet."""

    lat_deg: float
    lon_deg: float
    alt_km: float = 0.0
    name: str = ""

    def positions(self, t: float | np.ndarray) -> np.ndarray:
        ts = np.atleast_1d(np.asarray(t, dtype=np.float64))
        lat = math.radians(self.lat_deg)
        r = R_EARTH_KM + self.alt_km
        lon = math.radians(self.lon_deg) + EARTH_ROT_RAD_S * ts  # (T,)
        out = np.stack(
            [
                r * math.cos(lat) * np.cos(lon),
                r * math.cos(lat) * np.sin(lon),
                np.full_like(lon, r * math.sin(lat)),
            ],
            axis=-1,
        )
        return out if np.ndim(t) else out[0]


def propagate(
    geom: WalkerDelta,
    times: Sequence[float] | np.ndarray,
    ground_stations: Sequence[GroundStation] = (),
) -> np.ndarray:
    """Stack satellite + ground-station ECI tracks: (T, total + G, 3).

    Node ids 0..total-1 are satellites (Walker layout); total..total+G-1 are
    the ground stations in the given order.
    """
    times = np.asarray(times, dtype=np.float64)
    tracks = [geom.positions(times)]
    for gs in ground_stations:
        tracks.append(gs.positions(times)[:, None, :])
    return np.concatenate(tracks, axis=1)


def sample_times(duration_s: float, step_s: float) -> np.ndarray:
    """Uniform sample grid [0, duration) — one contact-plan time step each."""
    if step_s <= 0 or duration_s <= 0:
        raise ValueError("duration_s and step_s must be positive")
    return np.arange(0.0, duration_s, step_s, dtype=np.float64)
