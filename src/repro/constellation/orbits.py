"""Circular-orbit propagation for Walker constellations and ground stations.

Everything is pure NumPy and deterministic: positions are closed-form
functions of time (no integrator state), so a contact plan generated twice
from the same geometry is bit-identical — the property the TDM scheduler
relies on when satellites compute the schedule independently (paper
assumption (a): common knowledge of the schedule).

Conventions: kilometres and seconds; ECI frame with the z-axis through the
north pole; a Walker pattern ``i:t/p/f`` is ``WalkerDelta(total=t, planes=p,
phasing=f, inclination_deg=i)``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple, Union

import numpy as np

MU_EARTH_KM3_S2 = 398600.4418      # standard gravitational parameter
R_EARTH_KM = 6371.0                # mean Earth radius
EARTH_ROT_RAD_S = 7.2921159e-5     # sidereal rotation rate


def _rot_x(a: float) -> np.ndarray:
    c, s = math.cos(a), math.sin(a)
    return np.array([[1.0, 0.0, 0.0], [0.0, c, -s], [0.0, s, c]])


def _rot_z(a: float) -> np.ndarray:
    c, s = math.cos(a), math.sin(a)
    return np.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])


@dataclass(frozen=True)
class WalkerDelta:
    """Walker constellation i:t/p/f on circular orbits.

    ``pattern="delta"`` spreads the ascending nodes over 360° (Kuiper/
    Starlink style); ``pattern="star"`` over 180° (Iridium style, polar
    seams). Satellite (plane p, slot k) has node id ``p * per_plane + k`` —
    the node ids the rest of the repo's relations/schedules use.
    """

    total: int = 24
    planes: int = 4
    phasing: int = 1
    inclination_deg: float = 53.0
    altitude_km: float = 550.0
    pattern: str = "delta"

    def __post_init__(self):
        if self.total % self.planes:
            raise ValueError("total must be divisible by planes")
        if self.pattern not in ("delta", "star"):
            raise ValueError(f"unknown Walker pattern {self.pattern!r}")

    # ------------------------------------------------------------- layout
    @property
    def per_plane(self) -> int:
        return self.total // self.planes

    def node_id(self, plane: int, slot: int) -> int:
        return (plane % self.planes) * self.per_plane + (slot % self.per_plane)

    def plane_of(self, node: int) -> int:
        return node // self.per_plane

    # ----------------------------------------------------------- dynamics
    @property
    def orbit_radius_km(self) -> float:
        return R_EARTH_KM + self.altitude_km

    @property
    def period_s(self) -> float:
        return 2.0 * math.pi * math.sqrt(self.orbit_radius_km ** 3 / MU_EARTH_KM3_S2)

    @property
    def mean_motion_rad_s(self) -> float:
        return 2.0 * math.pi / self.period_s

    def raan_rad(self, plane: int) -> float:
        spread = 2.0 * math.pi if self.pattern == "delta" else math.pi
        return spread * plane / self.planes

    def phase_rad(self, plane: int, slot: int) -> float:
        """Argument of latitude at t=0 (in-plane spacing + inter-plane
        phasing f: adjacent planes offset by 2π·f/total)."""
        return (
            2.0 * math.pi * slot / self.per_plane
            + 2.0 * math.pi * self.phasing * plane / self.total
        )

    def positions(self, t: float | np.ndarray) -> np.ndarray:
        """ECI positions at time(s) ``t`` (seconds).

        Scalar ``t`` -> (total, 3); array (T,) -> (T, total, 3). Km.

        One batched (T, N, 3) array program — no per-plane/per-satellite
        Python loops, so a 1000+ satellite shell propagates in one shot.
        Bit-identical to :meth:`positions_reference` (the retained legacy
        loop), which the mega-constellation equivalence suite asserts.
        """
        ts = np.atleast_1d(np.asarray(t, dtype=np.float64))
        r = self.orbit_radius_km
        n = self.mean_motion_rad_s
        inc = math.radians(self.inclination_deg)
        ci, si = math.cos(inc), math.sin(inc)
        node = np.arange(self.total)
        plane = node // self.per_plane
        slot = node % self.per_plane
        # raan_rad / phase_rad, evaluated for every node at once with the
        # same scalar operation order as the per-satellite originals
        spread = 2.0 * math.pi if self.pattern == "delta" else math.pi
        raan = spread * plane / self.planes                         # (N,)
        phase = (
            2.0 * math.pi * slot / self.per_plane
            + 2.0 * math.pi * self.phasing * plane / self.total
        )                                                           # (N,)
        u = phase[None, :] + n * ts[:, None]                        # (T, N)
        x = r * np.cos(u)
        y = r * np.sin(u)
        z = np.zeros_like(u)
        # rot = Rz(raan) @ Rx(inc) in closed form; out = in_plane @ rot.T
        # with the zero third component kept so the flop sequence (and
        # therefore every rounding) matches the legacy matmul exactly
        ca, sa = np.cos(raan), np.sin(raan)
        out = np.empty((ts.shape[0], self.total, 3))
        out[..., 0] = x * ca + y * (-sa * ci) + z * (sa * si)
        out[..., 1] = x * sa + y * (ca * ci) + z * (-ca * si)
        out[..., 2] = x * 0.0 + y * si + z * ci
        return out if np.ndim(t) else out[0]

    def positions_reference(self, t: float | np.ndarray) -> np.ndarray:
        """The per-plane/per-satellite propagation loop, retained as the
        equivalence oracle for :meth:`positions` (PR 3/PR 7 style: every
        fast path keeps its legacy twin). The rotation is applied with
        explicit component products rather than ``@`` so the flop sequence
        is FMA-free on every platform — the batched path then reproduces it
        bit for bit (BLAS contracts the tiny matmul with fused
        multiply-adds, which rounds differently by ~1 ulp)."""
        ts = np.atleast_1d(np.asarray(t, dtype=np.float64))
        r = self.orbit_radius_km
        n = self.mean_motion_rad_s
        inc = math.radians(self.inclination_deg)
        out = np.empty((ts.shape[0], self.total, 3))
        for p in range(self.planes):
            rot = _rot_z(self.raan_rad(p)) @ _rot_x(inc)
            for k in range(self.per_plane):
                u = self.phase_rad(p, k) + n * ts  # (T,)
                x = r * np.cos(u)
                y = r * np.sin(u)
                z = np.zeros_like(u)
                nid = self.node_id(p, k)
                for axis in range(3):
                    out[:, nid, axis] = (
                        x * rot[axis, 0] + y * rot[axis, 1] + z * rot[axis, 2]
                    )
        return out if np.ndim(t) else out[0]


@dataclass(frozen=True)
class MultiShell:
    """A stack of Walker shells — the mega-constellation layout.

    Starlink-class systems fly several shells at different altitudes and
    inclinations; node ids run shell by shell in the given order (shell 0's
    Walker layout first, then shell 1 offset by ``shells[0].total``, ...),
    so one :class:`MultiShell` drops into every relation/schedule/routing
    API that takes a flat node-id universe. ``positions`` is the batched
    concatenation of the per-shell array programs.
    """

    shells: Tuple[WalkerDelta, ...]

    def __post_init__(self):
        if not self.shells:
            raise ValueError("MultiShell needs at least one shell")

    @property
    def total(self) -> int:
        return sum(s.total for s in self.shells)

    def shell_offsets(self) -> Tuple[int, ...]:
        """Node id of each shell's first satellite."""
        offs: List[int] = []
        acc = 0
        for s in self.shells:
            offs.append(acc)
            acc += s.total
        return tuple(offs)

    def shell_of(self, node: int) -> int:
        acc = 0
        for idx, s in enumerate(self.shells):
            acc += s.total
            if node < acc:
                return idx
        raise ValueError(f"node {node} outside 0..{self.total - 1}")

    def positions(self, t: float | np.ndarray) -> np.ndarray:
        """ECI positions: scalar ``t`` -> (total, 3); (T,) -> (T, total, 3)."""
        ts = np.atleast_1d(np.asarray(t, dtype=np.float64))
        out = np.concatenate([s.positions(ts) for s in self.shells], axis=1)
        return out if np.ndim(t) else out[0]


Geometry = Union["WalkerDelta", "MultiShell"]


@dataclass(frozen=True)
class GroundStation:
    """A fixed Earth-surface terminal, rotated into ECI with the planet."""

    lat_deg: float
    lon_deg: float
    alt_km: float = 0.0
    name: str = ""

    def positions(self, t: float | np.ndarray) -> np.ndarray:
        ts = np.atleast_1d(np.asarray(t, dtype=np.float64))
        lat = math.radians(self.lat_deg)
        r = R_EARTH_KM + self.alt_km
        lon = math.radians(self.lon_deg) + EARTH_ROT_RAD_S * ts  # (T,)
        out = np.stack(
            [
                r * math.cos(lat) * np.cos(lon),
                r * math.cos(lat) * np.sin(lon),
                np.full_like(lon, r * math.sin(lat)),
            ],
            axis=-1,
        )
        return out if np.ndim(t) else out[0]


def propagate(
    geom: Geometry,
    times: Sequence[float] | np.ndarray,
    ground_stations: Sequence[GroundStation] = (),
) -> np.ndarray:
    """Stack satellite + ground-station ECI tracks: (T, total + G, 3).

    Node ids 0..total-1 are satellites (Walker layout); total..total+G-1 are
    the ground stations in the given order.
    """
    times = np.asarray(times, dtype=np.float64)
    tracks = [geom.positions(times)]
    for gs in ground_stations:
        tracks.append(gs.positions(times)[:, None, :])
    return np.concatenate(tracks, axis=1)


def sample_times(duration_s: float, step_s: float) -> np.ndarray:
    """Uniform sample grid [0, duration) — one contact-plan time step each."""
    if step_s <= 0 or duration_s <= 0:
        raise ValueError("duration_s and step_s must be positive")
    return np.arange(0.0, duration_s, step_s, dtype=np.float64)
