"""Inter-satellite link physics: visibility, latency, and link budget.

Turns the geometry from :mod:`repro.constellation.orbits` into weighted
time-varying graphs: an edge exists when the two bodies have line of sight
past the Earth's limb (plus an atmosphere margin), its latency is the
range over c, and its capacity comes from a free-space-path-loss budget
(Friis → C/N0 → Shannon). All pure NumPy, vectorized over node pairs.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.constellation.orbits import R_EARTH_KM

C_KM_S = 299_792.458               # speed of light
BOLTZMANN_DBW = -228.6             # 10*log10(k), dBW/(K·Hz)

Edge = Tuple[int, int]


@dataclass(frozen=True)
class LinkBudget:
    """Free-space RF (or optical-equivalent) ISL budget.

    Defaults model a Ka-band crosslink (23 GHz, 10 W, ~37 dBi dishes,
    400 MHz channel) — in family with published LEO ISL terminals. Rate is
    Shannon capacity times an implementation efficiency.
    """

    freq_ghz: float = 23.0
    tx_power_w: float = 10.0
    tx_gain_dbi: float = 37.0
    rx_gain_dbi: float = 37.0
    bandwidth_hz: float = 400e6
    noise_temp_k: float = 500.0
    misc_losses_db: float = 3.0
    spectral_efficiency: float = 0.75
    atmosphere_margin_km: float = 80.0   # grazing rays through the mesosphere
    min_elevation_deg: float = 10.0      # ground-terminal horizon mask
    # --- terminal agility: retargeting a link between slots is not free
    slew_rate_deg_s: float = 0.0         # gimbal slew rate; 0 = instantaneous
    acquisition_s: float = 0.0           # PAT lock time per freshly pointed link

    def fspl_db(self, range_km: np.ndarray | float) -> np.ndarray | float:
        """Free-space path loss, Friis in engineering units (km, GHz)."""
        return 92.45 + 20.0 * np.log10(np.maximum(range_km, 1e-6)) + 20.0 * math.log10(self.freq_ghz)

    def cn0_dbhz(self, range_km: np.ndarray | float) -> np.ndarray | float:
        eirp_dbw = 10.0 * math.log10(self.tx_power_w) + self.tx_gain_dbi
        return (
            eirp_dbw
            + self.rx_gain_dbi
            - self.fspl_db(range_km)
            - self.misc_losses_db
            - BOLTZMANN_DBW
            - 10.0 * math.log10(self.noise_temp_k)
        )

    def snr_db(self, range_km: np.ndarray | float) -> np.ndarray | float:
        return self.cn0_dbhz(range_km) - 10.0 * math.log10(self.bandwidth_hz)

    def data_rate_bps(self, range_km: np.ndarray | float) -> np.ndarray | float:
        """Shannon-limited rate at the given slant range (scalar or array)."""
        snr = 10.0 ** (np.asarray(self.snr_db(range_km)) / 10.0)
        return self.spectral_efficiency * self.bandwidth_hz * np.log2(1.0 + snr)

    def slew_penalty_s(self, slew_deg: float = 90.0) -> float:
        """Dead time before a *freshly pointed* link can carry data: gimbal
        slew through ``slew_deg`` (a quarter turn by default — terminals
        rarely need more between neighboring targets) plus pointing/
        acquisition/tracking lock. 0.0 when both agility knobs are unset,
        which preserves the pre-slew cost model exactly. An edge that was
        already active in the previous TDM slot stays locked and pays
        nothing — that is the optimizer's incentive to keep links warm."""
        mech = slew_deg / self.slew_rate_deg_s if self.slew_rate_deg_s > 0 else 0.0
        return mech + self.acquisition_s


@dataclass(frozen=True)
class Link:
    """One feasible edge at one time step."""

    range_km: float
    delay_s: float
    rate_bps: float

    def transfer_time_s(
        self, payload_bytes: int, acquisition_s: float = 0.0
    ) -> float:
        """Completion time for one payload over this link: optional
        pointing/acquisition dead time, serialization at the link rate, and
        one-way propagation. The single source of the per-edge time formula
        — slot sizing, the cost oracle, and the optimizer's edge weights all
        delegate here so they can never drift apart."""
        return (
            acquisition_s
            + 8.0 * payload_bytes / max(self.rate_bps, 1.0)
            + self.delay_s
        )


def slant_range_km(p: np.ndarray, q: np.ndarray) -> np.ndarray:
    return np.linalg.norm(np.asarray(p) - np.asarray(q), axis=-1)


def line_of_sight(
    p: np.ndarray, q: np.ndarray, occlusion_radius_km: float = R_EARTH_KM
) -> np.ndarray:
    """True where the segment p–q clears the occluding sphere (broadcasts
    over leading dims; positions in ECI km).

    The closest point of the chord to the Earth's centre decides: if it lies
    within the segment and inside the sphere, the Earth blocks the link.
    """
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    d = q - p
    dd = np.sum(d * d, axis=-1)
    # parameter of the closest approach to the origin, clamped to the segment
    t = np.where(dd > 0, -np.sum(p * d, axis=-1) / np.maximum(dd, 1e-12), 0.0)
    t = np.clip(t, 0.0, 1.0)
    closest = p + t[..., None] * d
    return np.linalg.norm(closest, axis=-1) >= occlusion_radius_km


def elevation_visible(
    ground: np.ndarray, sat: np.ndarray, min_elevation_deg: float
) -> np.ndarray:
    """Ground-terminal feasibility: the satellite must sit above the local
    horizon by the elevation mask (the limb-occlusion chord test always
    fails for a surface endpoint, so ground links use this instead)."""
    g = np.asarray(ground, dtype=np.float64)
    s = np.asarray(sat, dtype=np.float64)
    d = s - g
    dn = np.linalg.norm(d, axis=-1)
    gn = np.linalg.norm(g, axis=-1)
    up = np.sum(g * d, axis=-1) / np.maximum(gn * dn, 1e-12)  # sin(elevation)
    return up >= math.sin(math.radians(min_elevation_deg))


def _candidate_arrays(
    n: int, candidates: Optional[Iterable[Edge]]
) -> Tuple[np.ndarray, np.ndarray]:
    if candidates is None:
        return np.triu_indices(n, k=1)
    pairs = sorted({(min(i, j), max(i, j)) for i, j in candidates if i != j})
    iu = np.array([e[0] for e in pairs], dtype=np.intp)
    ju = np.array([e[1] for e in pairs], dtype=np.intp)
    return iu, ju


def _ground_masks(
    iu: np.ndarray, ju: np.ndarray, ground_nodes: frozenset
) -> Tuple[np.ndarray, np.ndarray]:
    """Which candidate endpoints are surface terminals — computed once per
    candidate set, not per timestep (the old per-call Python set-membership
    scan was a measurable cost at mega-constellation scale)."""
    if not ground_nodes:
        z = np.zeros(iu.shape, dtype=bool)
        return z, z.copy()
    g = np.fromiter(ground_nodes, dtype=np.intp)
    return np.isin(iu, g), np.isin(ju, g)


def _graph_at(
    pos: np.ndarray,
    budget: LinkBudget,
    iu: np.ndarray,
    ju: np.ndarray,
    is_ground_i: np.ndarray,
    is_ground_j: np.ndarray,
    max_range_km: Optional[float],
    min_rate_bps: float,
) -> Dict[Edge, Link]:
    if iu.size == 0:
        return {}
    p, q = pos[iu], pos[ju]
    space = ~is_ground_i & ~is_ground_j
    visible = np.zeros(iu.shape, dtype=bool)
    visible[space] = line_of_sight(
        p[space], q[space], R_EARTH_KM + budget.atmosphere_margin_km
    )
    up_i = is_ground_i & ~is_ground_j   # ground -> satellite
    up_j = is_ground_j & ~is_ground_i
    visible[up_i] = elevation_visible(p[up_i], q[up_i], budget.min_elevation_deg)
    visible[up_j] = elevation_visible(q[up_j], p[up_j], budget.min_elevation_deg)
    # ground-ground pairs stay False: terrestrial backhaul is out of scope
    rng = slant_range_km(p, q)
    if max_range_km is not None:
        visible &= rng <= max_range_km
    rate = np.asarray(budget.data_rate_bps(rng))
    visible &= rate >= min_rate_bps
    out: Dict[Edge, Link] = {}
    for a, b, v, r, rt in zip(iu, ju, visible, rng, rate):
        if v:
            out[(int(a), int(b))] = Link(
                range_km=float(r), delay_s=float(r / C_KM_S), rate_bps=float(rt)
            )
    return out


@dataclass(frozen=True)
class VisibilityMatrix:
    """Link physics for every candidate edge at every timestep, as arrays.

    The mega-constellation fast path: one batched ``(T, E)`` evaluation of
    LOS / elevation mask / slant range / link budget replaces T per-step
    ``_graph_at`` calls. Row ``t`` reconstructs the exact per-step weighted
    graph (:meth:`graph_at` is bit-identical to the legacy loop — asserted
    by the equivalence suite), and contact-window extraction runs directly
    on ``visible`` as a run-length pass without materializing graphs.
    """

    iu: np.ndarray        # (E,) candidate endpoints, i < j, ascending pairs
    ju: np.ndarray        # (E,)
    visible: np.ndarray   # (T, E) bool — edge feasible at step t
    range_km: np.ndarray  # (T, E) slant range (valid everywhere, not just visible)
    rate_bps: np.ndarray  # (T, E) budget-limited data rate

    @property
    def n_steps(self) -> int:
        return self.visible.shape[0]

    @property
    def n_candidates(self) -> int:
        return int(self.iu.size)

    def graph_at(self, t: int) -> Dict[Edge, Link]:
        """Materialize the step-``t`` weighted graph {(i, j): Link}."""
        out: Dict[Edge, Link] = {}
        rng = self.range_km[t]
        rate = self.rate_bps[t]
        for e in np.flatnonzero(self.visible[t]):
            r = rng[e]
            out[(int(self.iu[e]), int(self.ju[e]))] = Link(
                range_km=float(r), delay_s=float(r / C_KM_S), rate_bps=float(rate[e])
            )
        return out

    def graphs(self) -> List[Dict[Edge, Link]]:
        return [self.graph_at(t) for t in range(self.n_steps)]


def visibility_matrix(
    tracks: np.ndarray,
    budget: LinkBudget = LinkBudget(),
    candidates: Optional[Sequence[Edge]] = None,
    max_range_km: Optional[float] = None,
    min_rate_bps: float = 0.0,
    ground_nodes: Iterable[int] = (),
    max_chunk_elems: int = 1 << 18,
) -> VisibilityMatrix:
    """Batched visibility for a (T, N, 3) track array → :class:`VisibilityMatrix`.

    All candidate edges across all timesteps are evaluated in one array
    program (chunked over T so peak memory stays bounded at ~``max_chunk_elems``
    edge-steps regardless of horizon length — the default keeps each
    chunk's position/range temporaries inside the L2/L3 working set, which
    measures ~1.7× faster than letting the intermediates spill to DRAM). Every elementwise operation
    matches the per-step path exactly, so the result is bit-identical to
    running :func:`visibility_graph` per step.
    """
    tracks = np.asarray(tracks, dtype=np.float64)
    T = tracks.shape[0]
    iu, ju = _candidate_arrays(tracks.shape[1], candidates)
    is_gi, is_gj = _ground_masks(iu, ju, frozenset(ground_nodes))
    E = int(iu.size)
    visible = np.zeros((T, E), dtype=bool)
    range_km = np.zeros((T, E), dtype=np.float64)
    rate_bps = np.zeros((T, E), dtype=np.float64)
    if E == 0 or T == 0:
        return VisibilityMatrix(iu, ju, visible, range_km, rate_bps)
    space = ~is_gi & ~is_gj
    up_i = is_gi & ~is_gj   # ground -> satellite
    up_j = is_gj & ~is_gi
    chunk = max(1, max_chunk_elems // E)
    for t0 in range(0, T, chunk):
        t1 = min(T, t0 + chunk)
        p = tracks[t0:t1, iu]   # (Tc, E, 3)
        q = tracks[t0:t1, ju]
        vis = np.zeros((t1 - t0, E), dtype=bool)
        vis[:, space] = line_of_sight(
            p[:, space], q[:, space], R_EARTH_KM + budget.atmosphere_margin_km
        )
        vis[:, up_i] = elevation_visible(
            p[:, up_i], q[:, up_i], budget.min_elevation_deg
        )
        vis[:, up_j] = elevation_visible(
            q[:, up_j], p[:, up_j], budget.min_elevation_deg
        )
        # ground-ground columns stay False: terrestrial backhaul out of scope
        rng = slant_range_km(p, q)
        if max_range_km is not None:
            vis &= rng <= max_range_km
        rate = np.asarray(budget.data_rate_bps(rng))
        vis &= rate >= min_rate_bps
        visible[t0:t1] = vis
        range_km[t0:t1] = rng
        rate_bps[t0:t1] = rate
    return VisibilityMatrix(iu, ju, visible, range_km, rate_bps)


def visibility_graph(
    positions: np.ndarray,
    budget: LinkBudget = LinkBudget(),
    candidates: Optional[Iterable[Edge]] = None,
    max_range_km: Optional[float] = None,
    min_rate_bps: float = 0.0,
    ground_nodes: Iterable[int] = (),
) -> Dict[Edge, Link]:
    """Weighted visibility graph for one time step.

    ``positions`` is (N, 3) ECI km. ``candidates`` restricts the edge set
    (e.g. a +grid of hardware-pointable terminals); default is every pair.
    Nodes listed in ``ground_nodes`` are surface terminals and use the
    elevation mask instead of the limb-occlusion test. Returns
    {(i, j): Link} with i < j.
    """
    pos = np.asarray(positions, dtype=np.float64)
    iu, ju = _candidate_arrays(pos.shape[0], candidates)
    is_gi, is_gj = _ground_masks(iu, ju, frozenset(ground_nodes))
    return _graph_at(pos, budget, iu, ju, is_gi, is_gj, max_range_km, min_rate_bps)


def visibility_series(
    tracks: np.ndarray,
    budget: LinkBudget = LinkBudget(),
    candidates: Optional[Sequence[Edge]] = None,
    max_range_km: Optional[float] = None,
    min_rate_bps: float = 0.0,
    ground_nodes: Iterable[int] = (),
) -> List[Dict[Edge, Link]]:
    """Per-time-step weighted graphs for a (T, N, 3) track array.

    Routed through the batched :func:`visibility_matrix` — one array program
    over all edge-steps — then materialized per step. Bit-identical to
    :func:`visibility_series_reference` (the retained legacy per-step loop)."""
    vm = visibility_matrix(
        tracks, budget, candidates, max_range_km, min_rate_bps, ground_nodes
    )
    return vm.graphs()


def visibility_series_reference(
    tracks: np.ndarray,
    budget: LinkBudget = LinkBudget(),
    candidates: Optional[Sequence[Edge]] = None,
    max_range_km: Optional[float] = None,
    min_rate_bps: float = 0.0,
    ground_nodes: Iterable[int] = (),
) -> List[Dict[Edge, Link]]:
    """The legacy one-``_graph_at``-call-per-timestep path, retained as the
    equivalence oracle for :func:`visibility_series` (PR 3/PR 7 style).

    Faithful to the pre-batching implementation, which also rebuilt the
    ground-endpoint masks with a Python membership scan on every call —
    the per-step overhead the hoisted :func:`_ground_masks` removed."""
    tracks = np.asarray(tracks, dtype=np.float64)
    iu, ju = _candidate_arrays(tracks.shape[1], candidates)
    ground_s = frozenset(ground_nodes)
    out = []
    for t in range(tracks.shape[0]):
        is_gi = np.array([i in ground_s for i in iu], dtype=bool)
        is_gj = np.array([j in ground_s for j in ju], dtype=bool)
        out.append(
            _graph_at(
                tracks[t], budget, iu, ju, is_gi, is_gj, max_range_km,
                min_rate_bps,
            )
        )
    return out
