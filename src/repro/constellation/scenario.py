"""Unified scenario factory: one setup path for examples, benchmarks, tests.

Before this module every driver rebuilt the same geometry by hand —
``examples/train_fl_constellation.py``, ``examples/serve_constellation.py``,
and the groundseg benchmarks each carried their own Walker-shell +
ground-station + contact-plan boilerplate, with subtly diverging defaults
(two benchmarks even held two different ``GROUND_SITES`` lists). A
:class:`ScenarioSpec` names the whole deployment — shells, ground stations,
link budget, horizon, seed — and :func:`build_scenario` turns it into a
:class:`Scenario` holding the propagated geometry, the contact plan, and a
cached TDM schedule, so training and serving provably run the same sky.

Quick use::

    from repro.constellation.scenario import (
        ScenarioSpec, ShellSpec, build_scenario,
    )

    scn = build_scenario(ScenarioSpec(
        shells=(ShellSpec(planes=2, per_plane=3),), n_ground=2,
    ))
    sched = scn.schedule()            # cached ContactSchedule
    rels = scn.slots()                # per-slot TDM Relations
    sinks = scn.ground_ids            # frozenset of ground-station node ids
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.constellation.contact_plan import (
    ContactPlan,
    ContactSchedule,
    build_contact_plan,
)
from repro.constellation.links import LinkBudget
from repro.constellation.orbits import (
    R_EARTH_KM,
    Geometry,
    GroundStation,
    MultiShell,
    WalkerDelta,
)
from repro.core.relation import Relation

# Canonical ground segment: the union of the site lists that used to live,
# duplicated and diverging, in benchmarks/groundseg_round_time.py and
# benchmarks/groundseg_pipeline.py. ``n_ground`` selects a prefix.
GROUND_SITES: Tuple[GroundStation, ...] = (
    GroundStation(0.0, 0.0, name="equator"),
    GroundStation(45.0, 120.0, name="midlat-e"),
    GroundStation(-30.0, -60.0, name="midlat-s"),
    GroundStation(60.0, 10.0, name="highlat"),
)


@dataclass(frozen=True)
class ShellSpec:
    """One Walker shell of a (possibly multi-shell) constellation."""

    planes: int = 2
    per_plane: int = 3
    altitude_km: float = 8062.0   # MEO: whole-period plans stay small
    inclination_deg: float = 60.0
    phasing: int = 1
    pattern: str = "delta"

    @property
    def total(self) -> int:
        return self.planes * self.per_plane

    def walker(self) -> WalkerDelta:
        return WalkerDelta(
            total=self.total,
            planes=self.planes,
            phasing=self.phasing,
            inclination_deg=self.inclination_deg,
            altitude_km=self.altitude_km,
            pattern=self.pattern,
        )


@dataclass(frozen=True)
class ScenarioSpec:
    """Everything that defines a deployment, in one hashable record.

    ``shells`` stacks Walker shells (one → plain :class:`WalkerDelta`
    geometry, several → :class:`MultiShell`); ``ground_stations`` overrides
    the canonical :data:`GROUND_SITES` prefix selected by ``n_ground``.
    ``duration_s=None`` defaults the horizon to one orbital period of the
    first shell; ``max_range_km=None`` defaults to the diameter bound
    ``2·(R⊕ + max altitude)`` the benchmarks always used.
    """

    shells: Tuple[ShellSpec, ...] = (ShellSpec(),)
    n_ground: int = 2
    ground_stations: Optional[Tuple[GroundStation, ...]] = None
    budget: LinkBudget = LinkBudget()
    duration_s: Optional[float] = None
    steps: int = 16
    candidates: str = "all"
    max_range_km: Optional[float] = None
    min_rate_bps: float = 0.0
    antennas: int = 2
    payload_bytes: int = 1 << 20
    acquisition_s: float = 0.0
    optimize: Optional[str] = None
    seed: int = 0

    def __post_init__(self):
        if not self.shells:
            raise ValueError("ScenarioSpec needs at least one shell")
        if self.ground_stations is None and not (
            0 <= self.n_ground <= len(GROUND_SITES)
        ):
            raise ValueError(
                f"n_ground must be in 0..{len(GROUND_SITES)} "
                f"(got {self.n_ground}); pass ground_stations= for more"
            )

    @property
    def sites(self) -> Tuple[GroundStation, ...]:
        if self.ground_stations is not None:
            return tuple(self.ground_stations)
        return GROUND_SITES[: self.n_ground]

    @property
    def n_sats(self) -> int:
        return sum(s.total for s in self.shells)

    def geometry(self) -> Geometry:
        if len(self.shells) == 1:
            return self.shells[0].walker()
        return MultiShell(shells=tuple(s.walker() for s in self.shells))

    def horizon_s(self) -> float:
        if self.duration_s is not None:
            return float(self.duration_s)
        return self.shells[0].walker().period_s

    def range_km(self) -> float:
        if self.max_range_km is not None:
            return float(self.max_range_km)
        return 2.0 * (R_EARTH_KM + max(s.altitude_km for s in self.shells))


@dataclass(frozen=True)
class Scenario:
    """A realized deployment: geometry + contact plan + cached schedule."""

    spec: ScenarioSpec
    geom: Geometry
    ground_stations: Tuple[GroundStation, ...]
    plan: ContactPlan

    @property
    def n_sats(self) -> int:
        return self.geom.total

    @property
    def n_nodes(self) -> int:
        return self.plan.n_nodes

    @property
    def sat_ids(self) -> range:
        return range(self.n_sats)

    @property
    def ground_ids(self) -> frozenset:
        """Ground-station node ids (satellites first, then ground — the
        Walker layout contract)."""
        return frozenset(range(self.n_sats, self.n_nodes))

    def relations(self) -> List[Relation]:
        """Raw per-step visibility relations (no antenna decomposition)."""
        return self.plan.relations()

    def schedule(self, **overrides) -> ContactSchedule:
        """Antenna-constrained TDM schedule; the no-override call is cached
        (memoized in ``__dict__`` — legal on a frozen dataclass)."""
        if overrides:
            return self.plan.schedule(**{**self._schedule_kwargs(), **overrides})
        cached = self.__dict__.get("_sched_cache")
        if cached is None:
            cached = self.plan.schedule(**self._schedule_kwargs())
            self.__dict__["_sched_cache"] = cached
        return cached

    def slots(self) -> List[Relation]:
        """Per-slot exchange relations of the cached TDM schedule."""
        return list(self.schedule().tdm)

    def _schedule_kwargs(self) -> dict:
        return dict(
            antennas=self.spec.antennas,
            payload_bytes=self.spec.payload_bytes,
            optimize=self.spec.optimize,
            acquisition_s=self.spec.acquisition_s,
        )

    def describe(self) -> dict:
        """Identity fields for BENCH rows / mission reports."""
        return dict(
            shells=len(self.spec.shells),
            n_sats=self.n_sats,
            n_gs=len(self.ground_stations),
            steps=self.spec.steps,
            seed=self.spec.seed,
        )


def build_scenario(spec: ScenarioSpec) -> Scenario:
    """Propagate the spec's geometry and package the contact plan."""
    geom = spec.geometry()
    horizon = spec.horizon_s()
    plan = build_contact_plan(
        geom,
        duration_s=horizon,
        step_s=horizon / spec.steps,
        budget=spec.budget,
        ground_stations=spec.sites,
        candidates=spec.candidates,
        max_range_km=spec.range_km(),
        min_rate_bps=spec.min_rate_bps,
    )
    return Scenario(
        spec=spec, geom=geom, ground_stations=spec.sites, plan=plan
    )


def smoke_scenario(**overrides) -> Scenario:
    """The small Walker shell CI smoke jobs and fast tests share: 6 sats /
    2 planes / 2 ground stations, 12-step period horizon."""
    kw = dict(
        shells=(ShellSpec(planes=2, per_plane=3),), n_ground=2, steps=12
    )
    kw.update(overrides)
    return build_scenario(ScenarioSpec(**kw))


def replace_spec(scn: Scenario, **changes) -> Scenario:
    """Rebuild a scenario with some spec fields changed (sweep helper)."""
    return build_scenario(dataclasses.replace(scn.spec, **changes))


__all__ = [
    "GROUND_SITES",
    "Scenario",
    "ScenarioSpec",
    "ShellSpec",
    "build_scenario",
    "replace_spec",
    "smoke_scenario",
]
