"""Orbital constellation subsystem: geometry-driven contact plans.

Turns orbital mechanics into the exchange relations and TDM schedules the
rest of the repo consumes — the missing link between the paper's abstract
relation algebra (:mod:`repro.core.relation`) and its motivating deployment
(TDM communication over inter-satellite links):

- :mod:`repro.constellation.orbits`       — circular-orbit propagation for
  Walker-delta/star constellations plus ground stations (ECI positions over
  time; pure NumPy, deterministic).
- :mod:`repro.constellation.links`        — line-of-sight visibility with
  Earth occlusion, range → latency, and a free-space-path-loss link budget
  yielding per-edge data rates (weighted time-varying graphs).
- :mod:`repro.constellation.contact_plan` — contact windows → per-slot
  ``Relation``s honoring per-node antenna budgets → a (streaming)
  ``TDMSchedule`` with bandwidth-aware slot sizing.
- :mod:`repro.constellation.cost`         — analytic per-slot wall-clock /
  traffic model for ``get_meas`` vs ``get1_meas`` over a generated plan.
- :mod:`repro.constellation.optimizer`    — rate-aware schedule search:
  strategy portfolio (slow-first grouping, max-weight-matching peeling,
  slew-warm ordering) scored by the cost oracle, provably never worse than
  the greedy first-legal-coloring baseline.
- :mod:`repro.constellation.scenario`     — the unified scenario factory:
  ``build_scenario(ScenarioSpec)`` names a whole deployment (shells, ground
  stations, link budget, horizon, seed) and is the single setup path shared
  by examples, benchmarks, and the serving/training drivers.

Pipeline, end to end::

    geom = orbits.WalkerDelta(total=20, planes=4, altitude_km=1400.0)
    plan = contact_plan.build_contact_plan(geom, duration_s=1200, step_s=60)
    sched = plan.schedule(antennas=3, optimize="rate")   # ContactSchedule
    est = cost.schedule_cost(sched, payload_bytes=1 << 20, mode="getmeas")
"""

from repro.constellation import (
    contact_plan,
    cost,
    links,
    optimizer,
    orbits,
    scenario,
)
from repro.constellation.scenario import (
    GROUND_SITES,
    Scenario,
    ScenarioSpec,
    ShellSpec,
    build_scenario,
    smoke_scenario,
)

__all__ = [
    "GROUND_SITES",
    "Scenario",
    "ScenarioSpec",
    "ShellSpec",
    "build_scenario",
    "contact_plan",
    "cost",
    "links",
    "optimizer",
    "orbits",
    "scenario",
    "smoke_scenario",
]
