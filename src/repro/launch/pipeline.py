"""GPipe-style pipeline parallelism, pjit-native.

Formulation (no shard_map): the pipeline's register file is ONE array with
a leading stage dim, sharded ``stage -> data``:

    h : (n_stages, B_micro, S, D)     stage i holds microbatch activations

One tick = ``jnp.roll(h, 1, axis=0)`` (GSPMD lowers the shift on a sharded
dim to a collective-permute — exactly the stage-to-stage hop) + inject the
next microbatch's embeddings at stage 0 + apply every stage's layer block
in parallel (``jax.vmap`` over the stage dim; einsums stay device-local
because both operands are stage-sharded). After M + n_stages - 1 ticks all
microbatches have drained; the collected last-stage outputs go through the
(stage-free) vocab projection + loss.

Why this beats FSDP for trillion-scale MoE (kimi-k2, EXPERIMENTS.md §Perf):
weights are STATIONARY — zero gather traffic, and weight grads are LOCAL to
their stage (no per-microbatch grad reduction). The only inter-stage bytes
are microbatch activations (seq-sharded over `model` in flight, so the
per-tick permute moves (B_m, S/16, D)).

Bubble: (S-1)/(M+S-1) of the ticks are ramp/drain — counted honestly in the
staged FLOPs (the roofline's useful-flops ratio shows it).

Layer-count padding: n_layers is rounded up to a multiple of n_stages with
inert extra units (outputs masked to passthrough); their params exist but
receive zero gradient.
"""

from __future__ import annotations

import math
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.launch import sharding as shlib
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import embed_tokens, lm_logits, rmsnorm
from repro.optim import adamw


def padded_cfg(cfg: ModelConfig, n_stages: int) -> Tuple[ModelConfig, int, int]:
    """Round the unit count up to a stage multiple. Returns
    (cfg_padded, n_units_real, units_per_stage)."""
    unit_len = len(transformer.scan_unit(cfg))
    u_real = cfg.n_layers // unit_len
    u_pad = math.ceil(u_real / n_stages) * n_stages
    cfgp = cfg.replace(n_layers=u_pad * unit_len)
    return cfgp, u_real, u_pad // n_stages


def build_pp_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.OptConfig,
    rules: shlib.ShardingRules,
    n_stages: int,
    n_micro: int,
) -> Tuple[Callable, ModelConfig]:
    """Returns (train_step(state, batch) -> (state, metrics), cfg_padded).

    ``state`` must be built from cfg_padded (extra inert units)."""
    cfgp, u_real, u_loc = padded_cfg(cfg, n_stages)

    def pipeline_hidden(params, tokens, positions):
        """Run the pipe; returns last-stage hidden states (M, Bm, S, D)."""
        M = n_micro
        Bg, S = tokens.shape
        Bm = Bg // M
        toks = tokens.reshape(M, Bm, S)
        D = cfgp.d_model

        # stage-stacked unit params: (n_stages, u_loc, ...)
        units_r = jax.tree.map(
            lambda x: x.reshape((n_stages, u_loc) + x.shape[1:]),
            params["units"],
        )

        def stage_apply(h, stage_units, stage_idx):
            """One stage's u_loc units, with inert-pad masking."""
            def unit_body(carry, xs):
                hc = carry
                unit_p, u_local = xs
                h2, _, _ = transformer._unit_forward(
                    hc, unit_p, positions, cfgp, None, False, S
                )
                u_global = stage_idx * u_loc + u_local
                hc = jnp.where(u_global < u_real, h2, hc)
                return shlib.shard_activation(hc, ("batch", "seq", None)), None

            fn = jax.checkpoint(unit_body) if cfgp.remat != "none" else unit_body
            h, _ = jax.lax.scan(fn, h, (stage_units, jnp.arange(u_loc)))
            return h

        vmapped_stages = jax.vmap(stage_apply, in_axes=(0, 0, 0))
        stage_ids = jnp.arange(n_stages)

        def constrain_h(h):
            return shlib.shard_activation(h, ("stage", "batch", "pp_seq", None))

        # embed ALL microbatches once, outside the tick loop: the
        # vocab-sharded table is gathered once per step, not once per tick
        # (measured: 1.46 TB/device of per-tick table gathers on kimi).
        embeds = embed_tokens(params["embed"], toks.reshape(M * Bm, S), cfgp)
        embeds = embeds.reshape(M, Bm, S, D)
        embeds = shlib.shard_activation(embeds, (None, "batch", "pp_seq", None))

        def tick(carry, t):
            # The tick carry rides seq-sharded over `model` (15 MB/device on
            # kimi instead of 235 MB full-seq); stages gather to full seq
            # ONCE at entry and reshard at exit. The whole tick is
            # checkpointed: only the (small) carries survive to the backward
            # pass — without this, every tick's internal residuals are saved
            # (measured 143 GB/device of temps).
            h = carry                                  # (n_stages, Bm, S, D)
            h = jnp.roll(h, 1, axis=0)                 # stage hop (ppermute)
            m_in = jnp.clip(t, 0, M - 1)
            x0 = jax.lax.dynamic_index_in_dim(embeds, m_in, 0, keepdims=False)
            h = jax.lax.dynamic_update_index_in_dim(h, x0.astype(h.dtype), 0, 0)
            # gather to full seq for the stage compute (bf16, one AG)
            h = shlib.shard_activation(h, ("stage", "batch", "seq", None))
            h = vmapped_stages(h, units_r, stage_ids)
            # reshard seq->model for the hop + the saved carry (one RS)
            h = constrain_h(h)
            out = h[n_stages - 1]                      # valid when t >= S-1
            return h, out

        h0 = jnp.zeros((n_stages, Bm, S, D), embeds.dtype)
        ticks = M + n_stages - 1
        # NOTE tick-level remat is a memory/collective trade: checkpointing
        # ticks halves bwd temps but re-runs every stage's TP exchanges in
        # the recompute (kimi: 63s -> 98s collective). We keep the faster
        # schedule; 1F1B scheduling is the proper memory fix (future work,
        # EXPERIMENTS.md §Perf iteration 3).
        _, outs = jax.lax.scan(tick, constrain_h(h0), jnp.arange(ticks))
        # outs[t] = last-stage output at tick t; micro m exits at t = m+S-1
        hidden = jax.lax.slice_in_dim(outs, n_stages - 1, ticks, axis=0)
        return hidden                                   # (M, Bm, S, D)

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        labels = batch["labels"]
        Bg, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (Bg // n_micro, S))
        if cfgp.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[..., None], positions.shape + (3,))
        hidden = pipeline_hidden(params, tokens, positions)
        M, Bm = hidden.shape[0], hidden.shape[1]
        h = hidden.reshape(M * Bm, S, -1)
        # exit the pipe: loss compute resharded batch -> (pod+)data x vocab
        h = jax.lax.with_sharding_constraint(
            h, rules.sharding_for(("loss_batch", "seq", None), h.shape)
        )
        h = rmsnorm(h, params["final_ln"], cfgp.norm_eps)
        y = labels.reshape(M * Bm, S)

        chunk = min(cfgp.loss_chunk, S)
        nch = S // chunk
        h_c = h.reshape(M * Bm, nch, chunk, -1).transpose(1, 0, 2, 3)
        y_c = y.reshape(M * Bm, nch, chunk).transpose(1, 0, 2)

        def chunk_loss(carry, xs):
            hc, yc = xs
            logits = lm_logits(params["embed"], hc, cfgp)
            logits = jax.lax.with_sharding_constraint(
                logits,
                rules.sharding_for(("loss_batch", None, "vocab"), logits.shape),
            )
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
            return carry + jnp.sum(lse - gold), None

        total, _ = jax.lax.scan(
            jax.checkpoint(chunk_loss), jnp.zeros((), jnp.float32), (h_c, y_c)
        )
        loss = total / (Bg * S)
        return loss, {"ce_loss": loss}

    def train_step(state, batch):
        with shlib.use_rules(rules):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch
            )
            new_p, new_opt, opt_metrics = adamw.apply_updates(
                state["params"], grads, state["opt"], opt_cfg
            )
        return (
            {"params": new_p, "opt": new_opt, "step": state["step"] + 1},
            {"loss": loss, **metrics, **opt_metrics},
        )

    return train_step, cfgp


def pp_bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
