"""Fault tolerance and elasticity.

Mechanisms (all exercised by tests/examples; hardware failure itself is
simulated — this container has one CPU):

1. **Checkpoint/restart** — launch/train.py saves every K steps (async,
   zstd, sha256-verified); --restore resumes bit-exact (the synthetic data
   pipeline is a pure function of step, so the token stream replays).
2. **Elastic reshard-on-restore** — checkpoints are mesh-agnostic;
   ``restore_for_mesh`` re-places every tensor for whatever mesh the new
   job has (checkpoint.restore + make_array_from_callback shard-by-shard).
3. **TDM rescheduling on node loss** — the paper's skip-slot semantics:
   a dead/occluded satellite is dropped from every slot's relation
   (``Relation.restrict``); remaining exchanges stay valid (tested
   property), and gossip re-mixes the survivors.
4. **Straggler mitigation** — slot-deadline policy: a node that misses the
   slot deadline is treated as ``odata=None`` (participate=False masks its
   payload in tdm.get_meas); gradient accumulation (cfg.micro_steps)
   smooths per-step jitter.
5. **Elastic replica membership** — the serving twin of (3):
   ``ReplicaMembership`` tracks which model-replica satellites are in
   service under orbital churn. A replica losing visibility is *drained*
   (the serving engine abandons its batch and re-routes the requests);
   one regaining visibility is re-admitted after ``grace_slots`` of
   continuous visibility.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, Optional, Set

import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule
from repro.launch import sharding as shlib
from repro.launch import steps as steps_lib
from repro.models.config import ModelConfig
from repro.optim import adamw


@dataclasses.dataclass
class HealthTracker:
    """Heartbeat bookkeeping for the node set (satellites / hosts)."""

    n_nodes: int
    deadline_s: float = 10.0
    last_seen: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, node: int, t: Optional[float] = None) -> None:
        self.last_seen[node] = time.monotonic() if t is None else t

    def alive(self, now: Optional[float] = None) -> Set[int]:
        now = time.monotonic() if now is None else now
        return {
            i for i in range(self.n_nodes)
            if now - self.last_seen.get(i, -1e18) <= self.deadline_s
        }

    def dead(self, now: Optional[float] = None) -> Set[int]:
        return set(range(self.n_nodes)) - self.alive(now)


def reschedule(schedule: TDMSchedule, alive: Iterable[int]) -> TDMSchedule:
    """Drop failed nodes from every slot (paper skip-slot semantics)."""
    return schedule.restrict(alive)


@dataclasses.dataclass(frozen=True)
class SlotDeadline:
    """Straggler policy: who participates in the current slot.

    ``participate(progress, slot_deadline)`` returns the boolean mask the
    TDM collective consumes — late nodes ship zeros and are masked by their
    peers, exactly the paper's `odata=None` assumption (b)."""

    deadline_steps: int

    def participate(self, node_progress: np.ndarray, slot_step: int) -> np.ndarray:
        return node_progress >= slot_step - self.deadline_steps


@dataclasses.dataclass(frozen=True)
class MembershipDelta:
    """One membership update: replicas drained / (re-)admitted this step."""

    drained: frozenset
    admitted: frozenset

    @property
    def changed(self) -> bool:
        return bool(self.drained or self.admitted)


class ReplicaMembership:
    """Elastic replica membership under orbital churn.

    ``update(visible)`` moves replicas between in-service and drained based
    on the visibility set the caller computes (alive + reachable on the
    contact graph). Draining is immediate — a replica that cannot uplink
    or downlink must abandon its batch *now* so requests re-route; re-
    admission waits for ``grace_slots`` consecutive visible updates, which
    damps flapping at a contact-window edge (a replica seen for a single
    step of a grazing pass is not worth re-prefetching a wave onto).
    """

    def __init__(self, replicas: Iterable[int], grace_slots: int = 0):
        self.replicas = frozenset(int(r) for r in replicas)
        self.grace_slots = int(grace_slots)
        self._active: Set[int] = set(self.replicas)
        self._streak: Dict[int, int] = {r: 0 for r in self.replicas}

    @property
    def active(self) -> frozenset:
        """Replicas currently in service (admission-eligible)."""
        return frozenset(self._active)

    @property
    def drained(self) -> frozenset:
        return self.replicas - self.active

    def update(self, visible: Iterable[int]) -> MembershipDelta:
        vis = set(visible) & self.replicas
        drained = frozenset(self._active - vis)
        self._active -= drained
        admitted: Set[int] = set()
        for r in self.replicas:
            if r in vis:
                self._streak[r] += 1
                if r not in self._active and self._streak[r] > self.grace_slots:
                    admitted.add(r)
            else:
                self._streak[r] = 0
        self._active |= admitted
        return MembershipDelta(drained=drained, admitted=frozenset(admitted))


def restore_for_mesh(
    ckpt_dir: str,
    cfg: ModelConfig,
    opt_cfg: adamw.OptConfig,
    mesh,
    step: Optional[int] = None,
):
    """Elastic restart: restore the latest checkpoint RESHARDED for ``mesh``
    (which may have a different size/topology than the mesh that saved it)."""
    rules = shlib.rules_for(mesh, cfg.fsdp)
    target = steps_lib.state_specs(cfg, opt_cfg)
    shardings = steps_lib.state_shardings(cfg, opt_cfg, rules)
    with mesh:
        return ckpt_lib.restore(ckpt_dir, step=step, target=target,
                                shardings=shardings)
