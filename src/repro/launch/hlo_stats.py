"""HLO statistics for the roofline: collective bytes by kind, TRIP-COUNT
AWARE.

Collective bytes are NOT in cost_analysis, and a naive text scan counts
while-loop (= lax.scan) bodies once — under-counting every per-layer
collective by the layer count. This parser reconstructs the computation
call graph of the partitioned module (compiled.as_text()) and multiplies
while bodies by their trip count, which XLA materializes as an s32 constant
inside the loop's condition computation (verified structure; see
EXPERIMENTS.md §Dry-run notes).

Counted ops: all-reduce, all-gather, reduce-scatter, all-to-all,
collective-permute (sync and async -start forms; -done skipped). Bytes are
the RESULT sizes in the per-device program — i.e. bytes landing in each
device per step, the collective roofline numerator.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branches=\{([^}]*)\}")
_S32_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _result_bytes(result_part: str) -> int:
    return sum(
        shape_bytes(m.group(0)) for m in _SHAPE_RE.finditer(result_part)
    )


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, float] = field(default_factory=dict)
    unknown_trip_whiles: int = 0

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    @property
    def total_count(self) -> float:
        return float(sum(self.count_by_kind.values()))

    def to_json(self) -> Dict:
        return {
            "bytes_by_kind": {k: float(v) for k, v in self.bytes_by_kind.items()},
            "count_by_kind": {k: float(v) for k, v in self.count_by_kind.items()},
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
            "unknown_trip_whiles": self.unknown_trip_whiles,
        }


def _split_computations(hlo_text: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    current = None
    depth = 0
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if current is None:
            m = _COMP_HDR_RE.match(s)
            if m and s.endswith("{"):
                current = m.group(1)
                comps[current] = []
                if s.startswith("ENTRY"):
                    entry = current
                depth = 1
            continue
        depth += s.count("{") - s.count("}")
        if depth <= 0:
            current = None
            continue
        comps[current].append(s)
    return comps, entry


def _trip_count(cond_lines: List[str]) -> Optional[int]:
    consts = []
    for l in cond_lines:
        for m in _S32_CONST_RE.finditer(l):
            consts.append(int(m.group(1)))
    return max(consts) if consts else None


def collective_stats(hlo_text: str, details: Optional[list] = None) -> CollectiveStats:
    """``details``: optional list; appended with dicts
    {kind, bytes, trips, total, line} for every collective call-site,
    trip-multiplied (call sites inside while bodies appear once with their
    effective multiplier)."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:  # fall back: treat whole text as one computation
        comps = {"__all__": [l.strip() for l in hlo_text.splitlines()]}
        entry = "__all__"

    memo: Dict[str, Tuple[Dict[str, float], Dict[str, float], int]] = {}

    def visit(name: str, stack=()) -> Tuple[Dict[str, float], Dict[str, float], int]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return ({}, {}, 0)
        by_b: Dict[str, float] = defaultdict(float)
        by_c: Dict[str, float] = defaultdict(float)
        unknown = 0
        for s in comps[name]:
            # while loops: body x trip
            mw = _COND_BODY_RE.search(s)
            if mw and " while(" in s:
                cond_name, body_name = mw.group(1), mw.group(2)
                trip = _trip_count(comps.get(cond_name, []))
                if trip is None:
                    trip = 1
                    unknown += 1
                bb, bc, bu = visit(body_name, stack + (name,))
                for k, v in bb.items():
                    by_b[k] += v * trip
                for k, v in bc.items():
                    by_c[k] += v * trip
                unknown += bu
                continue
            # conditionals: worst branch
            mb = _BRANCHES_RE.search(s)
            if mb:
                best: Tuple[Dict[str, float], Dict[str, float], int] = ({}, {}, 0)
                for bname in re.findall(r"%?([\w\.\-]+)", mb.group(1)):
                    sub = visit(bname, stack + (name,))
                    if sum(sub[0].values()) > sum(best[0].values()):
                        best = sub
                for k, v in best[0].items():
                    by_b[k] += v
                for k, v in best[1].items():
                    by_c[k] += v
                unknown += best[2]
                continue
            # calls / fusions
            mc = _CALLS_RE.search(s)
            if mc:
                sub = visit(mc.group(1), stack + (name,))
                for k, v in sub[0].items():
                    by_b[k] += v
                for k, v in sub[1].items():
                    by_c[k] += v
                unknown += sub[2]
                # fall through: a fused collective won't also match below
            # direct collectives
            for kind in _COLLECTIVES:
                if f" {kind}(" in s or f" {kind}-start(" in s:
                    eq = s.find(" = ")
                    idx = s.find(f" {kind}")
                    if eq < 0 or eq > idx:
                        continue
                    nbytes = _result_bytes(s[eq + 3 : idx])
                    by_b[kind] += nbytes
                    by_c[kind] += 1
                    if details is not None:
                        details.append(
                            {"kind": kind, "bytes": nbytes, "comp": name,
                             "line": s[:200]}
                        )
                    break
        out = (dict(by_b), dict(by_c), unknown)
        memo[name] = out
        return out

    b, c, u = visit(entry)
    return CollectiveStats(dict(b), dict(c), u)
