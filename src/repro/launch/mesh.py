"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.

Mesh semantics (DESIGN.md §6): ``model`` is the intra-node tensor/expert
axis (dense ICI); ``data`` is batch/FSDP; ``pod`` is the cross-pod axis —
in the constellation analogy, node groups along (pod, data) are satellites
and the TDM relation schedules their exchanges.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            f"run under XLA_FLAGS=--xla_force_host_platform_device_count={need}"
        )
    return jax.make_mesh(shape, axes, devices=devices)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    need = math.prod(shape)
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(f"mesh {shape} needs {need} devices")
    return jax.make_mesh(shape, axes, devices=devices)


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link direction
