"""Exact staged-program FLOPs and a fusion-aware HBM-traffic model, computed
by walking the jaxpr — because XLA's HloCostAnalysis counts while-loop
(= lax.scan) bodies ONCE, which under-counts every scanned model by the
layer count (verified empirically; see EXPERIMENTS.md §Dry-run notes).

FLOPs (exact for the staged program, global shapes):
- dot_general / conv: 2 * M*N*K (batch-aware)
- elementwise: 1 flop per output element; transcendentals tallied separately
- reductions: 1 flop per input element
- scan bodies multiplied by trip count; remat recompute appears naturally in
  the VJP jaxpr and is therefore included (that's the point).

Traffic model (roofline memory term): assumes perfect producer->consumer
fusion of elementwise chains, i.e. bytes move only at
- program inputs/outputs (params, batch, caches) — counted once,
- matmul/conv operands+results,
- gather/scatter/dynamic-slice data,
- scan carries (once per step).
This is the fusion-OPTIMAL floor; real traffic >= this. Dominance decisions
in §Roofline use it together with XLA's (per-body) numbers.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
from jax import core as jcore


ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "neg", "abs", "floor", "ceil",
    "round", "sign", "and", "or", "xor", "not", "select_n", "clamp",
    "rem", "nextafter", "real", "imag", "integer_pow", "square",
}
TRANSCENDENTAL = {
    "exp", "log", "log1p", "expm1", "tanh", "logistic", "sin", "cos", "tan",
    "rsqrt", "sqrt", "cbrt", "pow", "erf", "erfc", "erf_inv", "atan2",
    "exp2", "lgamma", "digamma",
}
REDUCE = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "cumsum", "cumlogsumexp", "cummax",
    "cumprod",
}
MEMORY_OPS = {
    "gather", "scatter", "scatter-add", "scatter_add", "dynamic_slice",
    "dynamic_update_slice", "sort", "top_k",
}
CALL_PARAM_NAMES = ("jaxpr", "call_jaxpr", "fun_jaxpr")


@dataclass
class Costs:
    flops: float = 0.0
    transcendentals: float = 0.0
    traffic_bytes: float = 0.0

    def __iadd__(self, o: "Costs"):
        self.flops += o.flops
        self.transcendentals += o.transcendentals
        self.traffic_bytes += o.traffic_bytes
        return self

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.transcendentals * k, self.traffic_bytes * k)


def _nbytes(aval) -> float:
    try:
        return float(math.prod(aval.shape) * aval.dtype.itemsize)
    except Exception:
        return 0.0


def _nelems(aval) -> float:
    try:
        return float(math.prod(aval.shape))
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    (contract, batch) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    lc, rc = contract
    lb, rb = batch
    batch_sz = math.prod(lhs.shape[i] for i in lb) if lb else 1
    k = math.prod(lhs.shape[i] for i in lc) if lc else 1
    m = math.prod(
        lhs.shape[i] for i in range(len(lhs.shape)) if i not in set(lc) | set(lb)
    )
    n = math.prod(
        rhs.shape[i] for i in range(len(rhs.shape)) if i not in set(rc) | set(rb)
    )
    return 2.0 * batch_sz * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # 2 * out_elems * (kernel spatial * in_features)
    kernel = math.prod(rhs.shape[:-1])
    return 2.0 * _nelems(out) * kernel


def jaxpr_costs(jaxpr: jcore.Jaxpr) -> Costs:
    total = Costs()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        # --- control flow / calls
        if prim == "scan":
            inner = jaxpr_costs(eqn.params["jaxpr"].jaxpr)
            length = eqn.params["length"]
            body = inner.scaled(length)
            # carry traffic once per step
            n_carry = eqn.params["num_carry"]
            carry_bytes = sum(_nbytes(v.aval) for v in eqn.invars[
                eqn.params["num_consts"]: eqn.params["num_consts"] + n_carry
            ])
            body.traffic_bytes += carry_bytes * length
            total += body
            continue
        if prim == "while":
            inner = Costs()
            inner += jaxpr_costs(eqn.params["body_jaxpr"].jaxpr)
            total += inner  # trip count unknown: count once (we never use raw while)
            continue
        if prim == "cond":
            branches = [jaxpr_costs(b.jaxpr) for b in eqn.params["branches"]]
            worst = max(branches, key=lambda c: c.flops) if branches else Costs()
            total += worst
            continue
        handled_call = False
        for name in CALL_PARAM_NAMES:
            sub = eqn.params.get(name)
            if sub is None:
                continue
            inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub  # Closed or raw
            if hasattr(inner, "eqns"):
                total += jaxpr_costs(inner)
                handled_call = True
                break
        if handled_call:
            continue
        if prim == "custom_vjp_call":
            # fwd costs only; bwd shows up in the grad jaxpr itself
            call = eqn.params.get("call_jaxpr")
            if call is not None:
                total += jaxpr_costs(call.jaxpr)
            continue
        # --- compute ops
        if prim == "dot_general":
            fl = _dot_flops(eqn)
            total.flops += fl
            total.traffic_bytes += (
                _nbytes(eqn.invars[0].aval)
                + _nbytes(eqn.invars[1].aval)
                + _nbytes(eqn.outvars[0].aval)
            )
            continue
        if prim == "conv_general_dilated":
            total.flops += _conv_flops(eqn)
            total.traffic_bytes += sum(_nbytes(v.aval) for v in eqn.invars)
            total.traffic_bytes += _nbytes(eqn.outvars[0].aval)
            continue
        if prim in ELEMENTWISE:
            total.flops += _nelems(eqn.outvars[0].aval)
            continue
        if prim in TRANSCENDENTAL:
            n = _nelems(eqn.outvars[0].aval)
            total.flops += n
            total.transcendentals += n
            continue
        if prim in REDUCE:
            total.flops += _nelems(eqn.invars[0].aval)
            continue
        if prim in MEMORY_OPS:
            total.traffic_bytes += sum(_nbytes(v.aval) for v in eqn.invars)
            total.traffic_bytes += sum(_nbytes(v.aval) for v in eqn.outvars)
            continue
        # everything else: free (reshape/transpose/broadcast fuse away)
    return total


def program_costs(fn, *args, **kwargs) -> Costs:
    """Costs of fn(*args) plus top-level I/O traffic (params read, outputs
    written, donated caches rewritten)."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    c = jaxpr_costs(closed.jaxpr)
    io_bytes = sum(_nbytes(v.aval) for v in closed.jaxpr.invars)
    io_bytes += sum(_nbytes(v.aval) for v in closed.jaxpr.outvars)
    c.traffic_bytes += io_bytes
    return c
