"""PTB-FLA training mode: satellites = node groups, each training on local
data, communicating ONLY via the paper's generic algorithms.

Implementation: parameters get a leading ``node`` axis sharded over the
mesh's node axis; one ``shard_map`` spans local compute + the TDM exchange,
so the per-slot relation literally becomes the collective schedule
(matchings -> ppermute, DESIGN.md §3). Three modes:

- ``centralized``   — FedAvg via all-reduce-mean every H steps
- ``decentralized`` — clique gossip (the paper's getMeas evaluation case)
- ``tdm``           — gossip over an arbitrary TDM schedule (constellation
                      visibility, ring, hypercube, ...), optionally int8 /
                      top-k (CHOCO) compressed

Time-varying schedules: :class:`RoundFnCache` + :func:`run_tdm_rounds` drive
one FL round per slot relation, recompiling only on unseen topologies;
:func:`run_constellation_fl` feeds them straight from a geometry-derived
:class:`~repro.constellation.contact_plan.ContactPlan` (the paper's actual
deployment — occluded satellites simply have no pairs that slot).

Fault tolerance: a failed/occluded satellite is dropped from the slot's
relation (``Relation.restrict``) — the paper's skip-slot semantics — and the
others keep training; its params re-sync through later gossip rounds.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import telemetry
from repro.core import fl, tdm
from repro.core.relation import Relation
from repro.models import registry
from repro.models.config import ModelConfig
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class FLConfig:
    mode: str = "tdm"               # centralized | decentralized | tdm
    local_steps: int = 1            # H: optimizer steps between exchanges
    comm: str = "getmeas"           # getmeas | get1meas (paper primitives)
    compression: str = "none"       # none | int8 | topk
    topk_k: int = 64
    fused: bool = True              # flat-buffer exchange engine (core/fused)


def _stack_init(key, cfg: ModelConfig, opt_cfg, n_nodes: int):
    """Per-node states, stacked on a leading node axis.

    Every node starts from the SAME init (consensus start: seed is
    ``fold_in(key, 0)`` for all of them), so the model/opt state is built
    once and broadcast — not re-initialized n_nodes times.
    """
    params, _ = registry.bundle(cfg).init(jax.random.fold_in(key, 0))
    state = {
        "params": params,
        "opt": adamw.init_opt_state(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_nodes,) + x.shape), state
    )


def build_fl_round(
    cfg: ModelConfig,
    opt_cfg: adamw.OptConfig,
    mesh: Mesh,
    n_nodes: int,
    fl_cfg: FLConfig,
    rel: Relation,
    axis: str = "data",
) -> Callable:
    """One FL round = local_steps SGD steps on node-local data + one
    exchange over ``rel``. Returns a jit'd (stacked_state, stacked_batch) ->
    (stacked_state, metrics) function."""
    b = registry.bundle(cfg)
    tdm_cfg = fl.TDMFLAConfig(
        comm=fl_cfg.comm,
        compression=fl_cfg.compression,
        topk_k=fl_cfg.topk_k,
        fused=fl_cfg.fused,
    )

    def node_round(state, batch):
        # state/batch leading dim = 1 (this node's shard); squeeze it
        state = jax.tree.map(lambda x: x[0], state)
        batch = jax.tree.map(lambda x: x[0], batch)

        def one_step(st, mb):
            (loss, _), grads = jax.value_and_grad(
                lambda p: b.loss_fn(p, mb), has_aux=True
            )(st["params"])
            new_p, new_opt, _ = adamw.apply_updates(
                st["params"], grads, st["opt"], opt_cfg
            )
            return {"params": new_p, "opt": new_opt, "step": st["step"] + 1}, loss

        losses = []
        for h in range(fl_cfg.local_steps):
            mb = jax.tree.map(lambda x: x[h], batch)
            state, loss = one_step(state, mb)
            losses.append(loss)
        local_loss = jnp.stack(losses).mean()

        # ---- the paper's communication step
        params = state["params"]
        if fl_cfg.mode == "centralized":
            params = fl.centralized_round(params, axis)
        elif fl_cfg.mode == "decentralized":
            params = fl.decentralized_round(params, axis, n_nodes)
        else:
            params, _ = fl.tdm_fla_round(params, rel, axis, n_nodes, tdm_cfg)
        state = dict(state, params=params)

        state = jax.tree.map(lambda x: x[None], state)
        return state, local_loss[None]

    spec_state = P(axis)
    fn = shard_map(
        node_round,
        mesh=mesh,
        in_specs=(spec_state, spec_state),
        out_specs=(spec_state, P(axis)),
        check_rep=False,  # model-internal scans carry node-invariant zeros;
                          # vma tracking would demand pcasts throughout
    )
    return jax.jit(fn, donate_argnums=(0,))


def build_hierarchical_fl_round(
    cfg: ModelConfig,
    opt_cfg: adamw.OptConfig,
    mesh: Mesh,
    n_pods: int,
    n_data: int,
    fl_cfg: FLConfig,
    intra_rel: Relation,
    inter_rel: Relation,
    pod_axis: str = "pod",
    data_axis: str = "data",
) -> Callable:
    """One hierarchical (pod × data) FL round: ``local_steps`` SGD steps on
    node-local data, then two-level fused gossip — ``intra_rel`` over the
    data axis inside each pod, ``inter_rel`` over the pod axis across pods
    (:func:`repro.core.fused.fused_hierarchical_round`). ``mesh`` must be a
    2D ``(pod_axis, data_axis)`` mesh of ``n_pods × n_data`` devices; state
    and batches carry a leading node axis sharded over BOTH mesh axes.

    ``fl_cfg.compression`` selects the fused wire format per level:
    ``"none"`` (f32 buffers) or ``"int8"`` (quantize-once blockwise via the
    tdm_compress kernels; 2 permutes per matching per bucket — the
    :func:`repro.telemetry.expected_hierarchical_collectives` oracle).
    Returns a jit'd (stacked_state, stacked_batch) -> (stacked_state,
    losses) function with the :func:`build_fl_round` contract."""
    from repro.core import fused as fused_lib

    b = registry.bundle(cfg)
    if fl_cfg.compression not in ("none", "int8"):
        raise ValueError(
            f"hierarchical FL supports compression 'none'/'int8', "
            f"got {fl_cfg.compression!r}"
        )

    def node_round(state, batch):
        state = jax.tree.map(lambda x: x[0], state)
        batch = jax.tree.map(lambda x: x[0], batch)

        def one_step(st, mb):
            (loss, _), grads = jax.value_and_grad(
                lambda p: b.loss_fn(p, mb), has_aux=True
            )(st["params"])
            new_p, new_opt, _ = adamw.apply_updates(
                st["params"], grads, st["opt"], opt_cfg
            )
            return {"params": new_p, "opt": new_opt, "step": st["step"] + 1}, loss

        losses = []
        for h in range(fl_cfg.local_steps):
            mb = jax.tree.map(lambda x: x[h], batch)
            state, loss = one_step(state, mb)
            losses.append(loss)
        local_loss = jnp.stack(losses).mean()

        params = fused_lib.fused_hierarchical_round(
            state["params"],
            intra_rel,
            inter_rel,
            data_axis,
            pod_axis,
            n_data,
            n_pods,
            compression=fl_cfg.compression,
        )
        state = dict(state, params=params)

        state = jax.tree.map(lambda x: x[None], state)
        return state, local_loss[None]

    spec_state = P((pod_axis, data_axis))
    fn = shard_map(
        node_round,
        mesh=mesh,
        in_specs=(spec_state, spec_state),
        out_specs=(spec_state, P((pod_axis, data_axis))),
        check_rep=False,  # same reason as build_fl_round (+ pallas int8 path)
    )
    return jax.jit(fn, donate_argnums=(0,))


class RoundFnCache:
    """Compiled FL-round functions keyed by slot relation.

    Time-varying schedules revisit topologies (orbits are periodic), so the
    jit cache is keyed on the relation's pair set — each distinct topology
    compiles once, every revisit is a cache hit. Misses and hits land on
    the flight recorder (``fl.round_cache.*`` counters plus a ``retrace``
    event); in reconcile mode each miss is ahead-of-time compiled via
    :func:`repro.telemetry.compile_and_check` so the cached executable is
    the one the collective oracle verified.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg,
        mesh: Mesh,
        n_nodes: int,
        fl_cfg: FLConfig,
        axis: str = "data",
    ):
        self.args = (cfg, opt_cfg, mesh, n_nodes, fl_cfg)
        self.n_nodes = n_nodes
        self.axis = axis
        self._fns: Dict[Any, Callable] = {}
        self._expected: Dict[Any, Optional[Dict[str, int]]] = {}

    def expected_collectives(
        self, rel: Relation, state: Any
    ) -> Optional[Dict[str, int]]:
        """Static per-round collective oracle for ``rel``, memoized on the
        cache key. ``None`` when no proven oracle covers the config (only
        the fused getMeas TDM path has one). Mixed-dtype compressed params
        ARE covered: the per-bucket formula is uniform — every dtype
        bucket pays the same sidecar structure (int8 ships payload+scales
        per bucket, fused top-k packs values+indices into one payload per
        bucket), so the count is ``matchings × per × n_buckets``."""
        key = tuple(sorted(rel.pairs))
        if key in self._expected:
            return self._expected[key]
        fl_cfg = self.args[4]
        exp: Optional[Dict[str, int]] = None
        if fl_cfg.mode == "tdm" and fl_cfg.fused and fl_cfg.comm == "getmeas":
            # dtype buckets of the fused spec, without touching device
            # values (no slicing — counters must stay sync-free)
            n_buckets = len(
                {leaf.dtype.name for leaf in jax.tree.leaves(state["params"])}
            )
            exp = telemetry.expected_tdm_collectives(
                rel, n_buckets, compression=fl_cfg.compression
            )
        self._expected[key] = exp
        return exp

    def __call__(self, rel: Relation, example_args=None) -> Callable:
        key = tuple(sorted(rel.pairs))
        rec = telemetry.get_recorder()
        fn = self._fns.get(key)
        if fn is None:
            rec.counter("fl.round_cache.misses")
            rec.event(
                "retrace",
                cat="compile",
                kind="fl_round",
                links=len(rel) // 2,
                cache_size=len(self._fns),
            )
            fn = build_fl_round(*self.args, rel, axis=self.axis)
            if rec.reconcile and example_args is not None:
                with rec.span("fl.compile", cat="compile", links=len(rel) // 2):
                    fn = telemetry.compile_and_check(
                        fn,
                        example_args,
                        self.expected_collectives(rel, example_args[0]),
                        context=f"fl_round[{len(rel) // 2} links]",
                        recorder=rec,
                    )
            self._fns[key] = fn
        else:
            rec.counter("fl.round_cache.hits")
        return fn

    def __len__(self) -> int:
        return len(self._fns)


@dataclasses.dataclass(frozen=True)
class RoundLog:
    round: int
    loss: float
    consensus: float
    n_links: int        # undirected ISLs active this round
    alive: int          # participating satellites


def run_tdm_rounds(
    cache: RoundFnCache,
    state: Any,
    relations: Sequence[Relation],
    batch_fn: Callable[[int], Any],
    alive: Optional[set] = None,
    on_round: Optional[Callable[[RoundLog], None]] = None,
    log_every: int = 1,
):
    """Drive one FL round per slot relation (the time-varying-schedule mode).

    ``alive`` is read *each round*, so callers may mutate it mid-flight to
    model satellite failures; occluded/dead nodes drop out of the round's
    relation via ``Relation.restrict`` (paper skip-slot semantics) while
    their local training continues. Returns (state, [RoundLog, ...]).

    ``log_every``: compute loss/consensus metrics only every k-th round
    (always including round 0). ``consensus_distance`` transfers the full
    stacked parameters to the host — a device sync per round that benchmark
    and long runs don't want; skipped rounds log NaN metrics and never touch
    device values, so rounds stay async-dispatchable. ``log_every=0``
    disables metrics entirely.

    Telemetry: every round bumps default-on flight-recorder counters
    (``fl.rounds``, cache hit/miss, the oracle's per-round collective
    counts) — host-side dict updates only, no extra device syncs. With
    tracing on, each round also records a ``cat="slot"`` span whose wall
    time is made accurate by a ``block_until_ready`` sync (tracing-only,
    so untraced runs stay async-dispatchable).
    """
    rec = telemetry.get_recorder()
    n_nodes = cache.n_nodes
    logs = []
    for rnd, rel in enumerate(relations):
        live = set(alive) if alive is not None else set(range(n_nodes))
        rel_t = rel.restrict(live)
        batch = batch_fn(rnd)
        with rec.span(
            "fl.round",
            cat="slot",
            round=rnd,
            links=len(rel_t) // 2,
            alive=len(live),
        ):
            fn = cache(
                rel_t,
                example_args=(state, batch) if rec.reconcile else None,
            )
            state, losses = fn(state, batch)
            if rec.tracing:
                jax.block_until_ready((state, losses))
        rec.counter("fl.rounds")
        expected = cache.expected_collectives(rel_t, state)
        if expected:
            for kind, count in expected.items():
                rec.counter(f"fl.collectives.{kind}", count)
        log_this = log_every > 0 and rnd % log_every == 0
        log = RoundLog(
            round=rnd,
            loss=float(jnp.mean(losses)) if log_this else float("nan"),
            consensus=(
                consensus_distance(state["params"]) if log_this else float("nan")
            ),
            n_links=len(rel_t) // 2,
            alive=len(live),
        )
        logs.append(log)
        if on_round is not None:
            on_round(log)
    return state, logs


def run_constellation_fl(
    cfg: ModelConfig,
    opt_cfg,
    mesh: Mesh,
    n_nodes: int,
    fl_cfg: FLConfig,
    plan,
    state: Any,
    batch_fn: Callable[[int], Any],
    rounds: Optional[int] = None,
    alive: Optional[set] = None,
    on_round: Optional[Callable[[RoundLog], None]] = None,
    optimize: Optional[str] = None,
    antennas=None,
    payload_bytes: int = 1 << 20,
    acquisition_s: float = 0.0,
    log_every: int = 1,
):
    """Constellation-driven FL: one round per contact-plan time step.

    ``plan`` is a :class:`repro.constellation.contact_plan.ContactPlan`;
    its geometry-derived visibility relations *are* the TDM schedule. When
    ``rounds`` exceeds the plan horizon the plan repeats (orbits are
    periodic when the horizon is one period).

    ``optimize`` switches the round schedule from the raw per-step
    visibility relations to a materialized antenna-constrained
    ``ContactSchedule`` — ``"greedy"`` for the first-legal-coloring
    baseline, ``"rate"`` for the min-cost schedule over the optimizer's
    strategy portfolio for this plan window (never costlier than greedy;
    see :mod:`repro.constellation.optimizer`). One FL round then runs per
    emitted sub-slot. ``antennas``/``payload_bytes``/``acquisition_s`` are
    the physical knobs the schedule is sized (and priced) with; with zero
    slew penalty and an antenna budget covering each step's degree, greedy
    and rate-aware emit the identical relation sequence, so training is
    bit-for-bit unchanged — only the time accounting improves.

    The schedule is built for the full constellation; ``alive`` keeps its
    ``run_tdm_rounds`` contract (read each round, mutable mid-flight), so
    failures and recoveries apply per round in both modes. A plan window
    with no feasible contacts falls back to the per-step relations (all
    empty), preserving the skip-slot semantics: local training continues.
    """
    if optimize is None:
        relations = plan.relations()
    else:
        with telemetry.get_recorder().span(
            "fl.build_schedule", cat="schedule", optimize=optimize
        ):
            sched = plan.schedule(
                antennas=antennas,
                payload_bytes=payload_bytes,
                optimize=optimize,
                acquisition_s=acquisition_s,
            )
        relations = list(sched.tdm)
        if not relations:
            relations = plan.relations()
    if rounds is not None:
        reps = -(-rounds // max(len(relations), 1))
        relations = (relations * reps)[:rounds]
    cache = RoundFnCache(cfg, opt_cfg, mesh, n_nodes, fl_cfg)
    return run_tdm_rounds(
        cache, state, relations, batch_fn, alive, on_round, log_every=log_every
    )


# ===========================================================================
# Ground-segment (centralized / hierarchical) FL over contact-graph routes
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class GroundSegConfig:
    """Config for sink-based FL over the ground segment.

    mode: 'centralized'  — sinks pool every round over terrestrial backhaul
                           (one masked psum per buffer); every satellite
                           that the downlink reaches gets the same global.
          'hierarchical' — sinks keep regional FedAvg models and pool only
                           every ``sink_sync_every`` rounds; regions mix on
                           the sync cadence (and through satellites whose
                           routes migrate between sinks as orbits advance).
    compression: relay payload encoding ('none' | 'int8' — blockwise via
                 the tdm_compress kernels, quantized ONCE end-to-end:
                 pmax-shared scales, exact int16 relay sums on the wire,
                 single dequant at the sink).
    pipeline_depth: 1 — one-shot rounds: uplink then downlink traverse the
                    window sequentially (the PR 4 path, bit-for-bit when
                    ``max_staleness_windows == 0``). 2 — pipelined: round
                    r's downlink flood overlaps round r+1's uplink relay
                    inside ONE window, on disjoint slot capacity — the
                    sink never idles and steady-state round throughput
                    roughly doubles.
    max_staleness_windows: delay-tolerant horizon — an undelivered payload
                    persists (and keeps aging) this many windows before it
                    is dropped and reported; 0 disables persistence.
    staleness_decay: sink FedAvg weight of a payload delivered at age
                    ``a`` is ``staleness_decay ** a`` (1.0 = pure FedAvg
                    regardless of age; age 0 is always weight 1 — exact
                    FedAvg recovered when nothing is stale).
    """

    mode: str = "centralized"
    sink_sync_every: int = 2
    compression: str = "none"
    block: int = 1024
    quant_impl: str = "auto"
    pipeline_depth: int = 1
    max_staleness_windows: int = 0
    staleness_decay: float = 0.5

    def __post_init__(self):
        if self.mode not in ("centralized", "hierarchical"):
            raise ValueError(f"unknown groundseg mode {self.mode!r}")
        if self.compression not in ("none", "int8"):
            raise ValueError(
                f"groundseg compression must be 'none' or 'int8', "
                f"got {self.compression!r}"
            )
        if self.pipeline_depth not in (1, 2):
            raise ValueError(
                f"pipeline_depth must be 1 or 2, got {self.pipeline_depth}"
            )
        if self.max_staleness_windows < 0:
            raise ValueError(
                f"max_staleness_windows must be >= 0, "
                f"got {self.max_staleness_windows}"
            )
        if not (0.0 < self.staleness_decay <= 1.0):
            raise ValueError(
                f"staleness_decay must be in (0, 1], got {self.staleness_decay}"
            )

    @property
    def pipelined(self) -> bool:
        """Does this config need the multi-window engine? The trivial
        config (depth 1, no persistence) routes through the PR 4 one-shot
        path, whose numerics the pipelined engine reproduces bit-for-bit
        (HLO-verified in tests/_groundseg_worker.py)."""
        return self.pipeline_depth > 1 or self.max_staleness_windows > 0

    def pool_round(self, rnd: int) -> bool:
        """Do the sinks reconcile over backhaul this round?"""
        if self.mode == "centralized":
            return True
        return self.sink_sync_every > 0 and rnd % self.sink_sync_every == 0


def build_groundseg_round(
    cfg: ModelConfig,
    opt_cfg: adamw.OptConfig,
    mesh: Mesh,
    n_nodes: int,
    fl_cfg: FLConfig,
    gs_cfg: GroundSegConfig,
    uplink,
    downlink,
    pool: bool,
    axis: str = "data",
) -> Callable:
    """One ground-segment FL round: satellites run ``local_steps`` SGD
    steps on their own shards (sinks hold — ground stations have no
    training data, their lanes compute and discard, as SPMD demands), then
    the full uplink-relay -> sink-FedAvg -> downlink-broadcast exchange
    from :func:`repro.groundseg.aggregation.groundseg_round` runs on the
    fused buffers. Same (stacked_state, stacked_batch) contract as
    :func:`build_fl_round`."""
    from repro.groundseg import aggregation

    b = registry.bundle(cfg)
    sink_mask = np.zeros((n_nodes,), dtype=bool)
    sink_mask[sorted(uplink.sinks)] = True

    def node_round(state, batch):
        state = jax.tree.map(lambda x: x[0], state)
        batch = jax.tree.map(lambda x: x[0], batch)
        idx = jax.lax.axis_index(axis)
        is_sink = jnp.asarray(sink_mask)[idx]

        def one_step(st, mb):
            (loss, _), grads = jax.value_and_grad(
                lambda p: b.loss_fn(p, mb), has_aux=True
            )(st["params"])
            new_p, new_opt, _ = adamw.apply_updates(
                st["params"], grads, st["opt"], opt_cfg
            )
            return {"params": new_p, "opt": new_opt, "step": st["step"] + 1}, loss

        trained = state
        losses = []
        for h in range(fl_cfg.local_steps):
            mb = jax.tree.map(lambda x: x[h], batch)
            trained, loss = one_step(trained, mb)
            losses.append(loss)
        local_loss = jnp.stack(losses).mean()
        # sinks are aggregation infrastructure, not learners
        state = jax.tree.map(
            lambda new, old: jnp.where(is_sink, old, new), trained, state
        )

        params = aggregation.groundseg_round(
            state["params"],
            uplink,
            downlink,
            axis,
            pool=pool,
            compression=gs_cfg.compression,
            block=gs_cfg.block,
            quant_impl=gs_cfg.quant_impl,
        )
        state = dict(state, params=params)

        state = jax.tree.map(lambda x: x[None], state)
        return state, local_loss[None]

    spec_state = P(axis)
    fn = shard_map(
        node_round,
        mesh=mesh,
        in_specs=(spec_state, spec_state),
        out_specs=(spec_state, P(axis)),
        check_rep=False,  # same reason as build_fl_round (+ pallas int8 path)
    )
    return jax.jit(fn, donate_argnums=(0,))


def build_pipelined_groundseg_round(
    cfg: ModelConfig,
    opt_cfg: adamw.OptConfig,
    mesh: Mesh,
    n_nodes: int,
    fl_cfg: FLConfig,
    gs_cfg: GroundSegConfig,
    wp,
    pool: bool,
    axis: str = "data",
) -> Callable:
    """One pipelined/delay-tolerant window: local training (sinks hold),
    then :func:`repro.groundseg.aggregation.pipelined_window_round` on the
    fused buffers. Contract: ``(stacked_state, aux, stacked_batch) ->
    (stacked_state, aux, losses)`` where ``aux = {"carry": .., "pending":
    ..}`` are the stacked payload-queue and pending-global buffer dicts
    threaded across windows."""
    from repro.groundseg import aggregation

    b = registry.bundle(cfg)
    sink_mask = np.zeros((n_nodes,), dtype=bool)
    sink_mask[sorted(wp.uplink.sinks)] = True

    def node_round(state, aux, batch):
        state = jax.tree.map(lambda x: x[0], state)
        aux = jax.tree.map(lambda x: x[0], aux)
        batch = jax.tree.map(lambda x: x[0], batch)
        idx = jax.lax.axis_index(axis)
        is_sink = jnp.asarray(sink_mask)[idx]

        def one_step(st, mb):
            (loss, _), grads = jax.value_and_grad(
                lambda p: b.loss_fn(p, mb), has_aux=True
            )(st["params"])
            new_p, new_opt, _ = adamw.apply_updates(
                st["params"], grads, st["opt"], opt_cfg
            )
            return {"params": new_p, "opt": new_opt, "step": st["step"] + 1}, loss

        trained = state
        losses = []
        for h in range(fl_cfg.local_steps):
            mb = jax.tree.map(lambda x: x[h], batch)
            trained, loss = one_step(trained, mb)
            losses.append(loss)
        local_loss = jnp.stack(losses).mean()
        state = jax.tree.map(
            lambda new, old: jnp.where(is_sink, old, new), trained, state
        )

        params, carry, pending = aggregation.pipelined_window_round(
            state["params"],
            aux["carry"],
            aux["pending"],
            wp,
            axis,
            pool=pool,
            staleness_decay=gs_cfg.staleness_decay,
            compression=gs_cfg.compression,
            block=gs_cfg.block,
            quant_impl=gs_cfg.quant_impl,
        )
        state = dict(state, params=params)
        aux = {"carry": carry, "pending": pending}

        state = jax.tree.map(lambda x: x[None], state)
        aux = jax.tree.map(lambda x: x[None], aux)
        return state, aux, local_loss[None]

    spec_state = P(axis)
    fn = shard_map(
        node_round,
        mesh=mesh,
        in_specs=(spec_state, spec_state, spec_state),
        out_specs=(spec_state, spec_state, P(axis)),
        check_rep=False,  # same reason as build_fl_round (+ pallas int8 path)
    )
    return jax.jit(fn, donate_argnums=(0, 1))


@dataclasses.dataclass(frozen=True)
class GroundSegRoundLog:
    round: int
    loss: float          # mean over live satellites (sinks excluded)
    consensus: float     # consensus distance over satellite params
    delivered: int       # satellite payloads landing at sinks this round
    covered: int         # satellites the downlink reached
    unreachable: int     # live satellites with no route to any sink
    alive: int           # live satellites
    pooled: bool         # sinks reconciled over backhaul this round
    carried: int = 0     # payloads persisting to the next window
    dropped: int = 0     # payloads discarded past the staleness horizon
    max_age: int = 0     # oldest delivered payload's age (windows)


def run_groundseg_fl(
    cfg: ModelConfig,
    opt_cfg,
    mesh: Mesh,
    n_nodes: int,
    fl_cfg: FLConfig,
    gs_cfg: GroundSegConfig,
    plan,
    state: Any,
    batch_fn: Callable[[int], Any],
    sinks,
    rounds: int,
    alive: Optional[set] = None,
    on_round: Optional[Callable[[GroundSegRoundLog], None]] = None,
    optimize: Optional[str] = None,
    antennas=None,
    payload_bytes: int = 1 << 20,
    acquisition_s: float = 0.0,
    log_every: int = 1,
):
    """Centralized/hierarchical FL with ground stations as aggregation
    sinks, routed over the plan's materialized TDM schedule.

    ``plan`` must include the ground stations
    (``build_contact_plan(..., ground_stations=[...])``); ``sinks`` are
    their node ids (satellites first, then ground — node ids ``geom.total``
    onward). Each round: local training, store-and-forward uplink of every
    reachable satellite's params along its earliest-delivery route, sink
    FedAvg (pooled per :meth:`GroundSegConfig.pool_round`), and the global
    (or regional) model flooding back on the downlink — uplink on one
    schedule window, downlink on the next identical window (orbits are
    periodic when the horizon is one period).

    ``alive`` keeps the :func:`run_tdm_rounds` contract: read every round,
    mutable mid-flight; sinks are ground infrastructure and always up.
    Routing, relay and broadcast programs, and the compiled round are
    cached per (alive-set, pool-flag) — orbital periodicity makes revisits
    cache hits. Returns ``(state, [GroundSegRoundLog, ...])``.

    When ``gs_cfg.pipelined`` (``pipeline_depth == 2`` and/or
    ``max_staleness_windows > 0``) the multi-window engine drives the loop
    instead: a :class:`repro.groundseg.routing.MultiWindowRouter` re-plans
    each window from the live set, undelivered payloads persist in a carry
    buffer across windows (dropped and reported past the staleness
    horizon), and at depth 2 round r's downlink overlaps round r+1's
    uplink on disjoint slot capacity. The compiled-window cache is keyed by
    (alive set, payload ages, pool, downlink presence) — steady state
    revisits the same few keys.
    """
    from repro.groundseg import routing

    sinks_s = frozenset(int(s) for s in sinks)
    if not sinks_s:
        raise ValueError("run_groundseg_fl needs at least one sink node id")
    sched = plan.schedule(
        antennas=antennas,
        payload_bytes=payload_bytes,
        optimize=optimize,
        acquisition_s=acquisition_s,
    )
    base_rels = list(sched.tdm)
    sat_ids = [v for v in range(n_nodes) if v not in sinks_s]
    if gs_cfg.pipelined:
        return _run_groundseg_pipelined(
            cfg, opt_cfg, mesh, n_nodes, fl_cfg, gs_cfg, base_rels, state,
            batch_fn, sinks_s, sat_ids, rounds, alive, on_round, log_every,
        )
    # routing depends only on the alive set; the compiled round also on the
    # pool flag — two caches so hierarchical pool/regional alternation does
    # not redo the DP and program replay
    from repro.groundseg import aggregation

    rec = telemetry.get_recorder()
    n_buckets = len(
        {leaf.dtype.name for leaf in jax.tree.leaves(state["params"])}
    )
    prog_cache: Dict[Any, Any] = {}
    fn_cache: Dict[Any, Any] = {}
    exp_cache: Dict[Any, Dict[str, int]] = {}
    logs: list = []
    for rnd in range(rounds):
        live = set(alive) if alive is not None else set(range(n_nodes))
        live |= sinks_s
        pool = gs_cfg.pool_round(rnd)
        live_key = frozenset(live)
        if live_key not in prog_cache:
            rec.counter("groundseg.route_cache.misses")
            rec.event(
                "reroute", cat="routing", round=rnd, alive=len(live)
            )
            with rec.span("groundseg.route", cat="routing", alive=len(live)):
                rels = [r.restrict(live) for r in base_rels]
                table = routing.earliest_delivery_routes(
                    rels,
                    n_nodes,
                    sinks_s,
                    sources=[v for v in sat_ids if v in live],
                )
                up = routing.build_relay_program(
                    rels, n_nodes, sinks_s, table=table
                )
                down = routing.build_broadcast_program(rels, n_nodes, sinks_s)
            prog_cache[live_key] = (up, down)
        else:
            rec.counter("groundseg.route_cache.hits")
        up, down = prog_cache[live_key]
        fn_key = (live_key, pool)
        if fn_key not in exp_cache:
            exp_cache[fn_key] = aggregation.expected_collectives(
                up, down, n_buckets, compression=gs_cfg.compression, pool=pool
            )
        expected = exp_cache[fn_key]
        batch = batch_fn(rnd)
        if fn_key not in fn_cache:
            rec.counter("groundseg.round_cache.misses")
            rec.event(
                "retrace",
                cat="compile",
                kind="groundseg_round",
                round=rnd,
                pool=pool,
                cache_size=len(fn_cache),
            )
            fn = build_groundseg_round(
                cfg, opt_cfg, mesh, n_nodes, fl_cfg, gs_cfg, up, down, pool
            )
            if rec.reconcile:
                with rec.span("groundseg.compile", cat="compile", pool=pool):
                    fn = telemetry.compile_and_check(
                        fn,
                        (state, batch),
                        expected,
                        context=f"groundseg_round[pool={pool}]",
                        recorder=rec,
                    )
            fn_cache[fn_key] = fn
        else:
            rec.counter("groundseg.round_cache.hits")
        fn = fn_cache[fn_key]
        with rec.span(
            "groundseg.round",
            cat="window",
            round=rnd,
            pool=pool,
            alive=len(live),
            delivered=up.delivered_count(),
            unreachable=len(up.unreachable),
        ):
            state, losses = fn(state, batch)
            if rec.tracing:
                jax.block_until_ready((state, losses))
        rec.counter("groundseg.rounds")
        rec.counter("groundseg.payloads.delivered", up.delivered_count())
        rec.counter("groundseg.payloads.unreachable", len(up.unreachable))
        for kind, count in expected.items():
            rec.counter(f"groundseg.collectives.{kind}", count)
        live_sats = [v for v in sat_ids if v in live]
        log_this = log_every > 0 and rnd % log_every == 0
        if log_this and live_sats:
            loss_v = float(np.mean(np.asarray(losses)[live_sats]))
            cons_v = consensus_distance(
                jax.tree.map(lambda x: np.asarray(x)[live_sats], state["params"])
            )
        else:
            loss_v = cons_v = float("nan")
        log = GroundSegRoundLog(
            round=rnd,
            loss=loss_v,
            consensus=cons_v,
            delivered=up.delivered_count(),
            covered=len(down.covered - sinks_s),
            unreachable=len(up.unreachable),
            alive=len(live_sats),
            pooled=pool,
        )
        logs.append(log)
        if on_round is not None:
            on_round(log)
    return state, logs


def _run_groundseg_pipelined(
    cfg: ModelConfig,
    opt_cfg,
    mesh: Mesh,
    n_nodes: int,
    fl_cfg: FLConfig,
    gs_cfg: GroundSegConfig,
    base_rels,
    state: Any,
    batch_fn: Callable[[int], Any],
    sinks_s,
    sat_ids,
    rounds: int,
    alive: Optional[set],
    on_round: Optional[Callable[[GroundSegRoundLog], None]],
    log_every: int,
):
    """The multi-window loop behind :func:`run_groundseg_fl`: one window
    per round, payload queues persisting in device-side carry buffers, the
    previous round's global staged in a pending buffer when pipelining."""
    from repro.core import fused
    from repro.groundseg import aggregation, routing

    rec = telemetry.get_recorder()
    router = routing.MultiWindowRouter(
        n_nodes,
        sinks_s,
        max_staleness_windows=gs_cfg.max_staleness_windows,
        pipeline_depth=gs_cfg.pipeline_depth,
    )
    node_params = jax.tree.map(lambda x: x[0], state["params"])
    spec = fused.cached_spec(node_params, block=gs_cfg.block)
    n_buckets = len(spec.buckets)
    aux = {
        "carry": aggregation.stacked_zero_buffers(spec, n_nodes),
        "pending": aggregation.stacked_zero_buffers(spec, n_nodes),
    }
    fn_cache: Dict[Any, Any] = {}
    exp_cache: Dict[Any, Dict[str, int]] = {}
    logs: list = []
    for rnd in range(rounds):
        live = set(alive) if alive is not None else set(range(n_nodes))
        live |= sinks_s
        pool = gs_cfg.pool_round(rnd)
        with rec.span("groundseg.plan_window", cat="routing", window=rnd):
            wp = router.plan_window(base_rels, alive=live)
        key = (
            frozenset(live),
            tuple(sorted(wp.ages.items())),
            pool,
            wp.downlink is None,
        )
        if key not in exp_cache:
            exp_cache[key] = aggregation.expected_window_collectives(
                wp, n_buckets, compression=gs_cfg.compression, pool=pool
            )
        expected = exp_cache[key]
        batch = batch_fn(rnd)
        if key not in fn_cache:
            rec.counter("groundseg.window_cache.misses")
            rec.event(
                "retrace",
                cat="compile",
                kind="groundseg_window",
                window=wp.window,
                pool=pool,
                ages=dict(wp.ages),
                cache_size=len(fn_cache),
            )
            fn = build_pipelined_groundseg_round(
                cfg, opt_cfg, mesh, n_nodes, fl_cfg, gs_cfg, wp, pool
            )
            if rec.reconcile:
                with rec.span("groundseg.compile", cat="compile", pool=pool):
                    fn = telemetry.compile_and_check(
                        fn,
                        (state, aux, batch),
                        expected,
                        context=f"groundseg_window[{wp.window}, pool={pool}]",
                        recorder=rec,
                    )
            fn_cache[key] = fn
        else:
            rec.counter("groundseg.window_cache.hits")
        with rec.span(
            "groundseg.window",
            cat="window",
            window=wp.window,
            pool=pool,
            alive=len(live),
            queued=len(wp.injected),
            delivered=wp.uplink.delivered_count(),
            carried=len(wp.residual),
            dropped=len(wp.dropped),
        ):
            state, aux, losses = fn_cache[key](state, aux, batch)
            if rec.tracing:
                jax.block_until_ready((state, losses))
        # payload lifecycle: queued -> relayed -> delivered | carried |
        # dropped. Counters are default-on; per-payload instants (with
        # staleness ages) exist only while tracing.
        rec.counter("groundseg.rounds")
        rec.counter("groundseg.payloads.queued", len(wp.injected))
        rec.counter("groundseg.payloads.delivered", wp.uplink.delivered_count())
        rec.counter("groundseg.payloads.carried", len(wp.residual))
        rec.counter("groundseg.payloads.dropped", len(wp.dropped))
        rec.counter("groundseg.payloads.unreachable", len(wp.uplink.unreachable))
        rec.set_counter(
            "groundseg.payloads.max_delivered_age",
            max(
                rec.get_counter("groundseg.payloads.max_delivered_age"),
                wp.max_delivered_age(),
            ),
        )
        for kind, count in expected.items():
            rec.counter(f"groundseg.collectives.{kind}", count)
        if rec.tracing:
            for src in sorted(wp.injected):
                rec.event(
                    "payload.queued", cat="payload", window=wp.window, source=src
                )
            for src, age in sorted(wp.delivered_ages.items()):
                rec.event(
                    "payload.delivered",
                    cat="payload",
                    window=wp.window,
                    source=src,
                    age=age,
                )
            for src, age in sorted(wp.residual.items()):
                rec.event(
                    "payload.carried",
                    cat="payload",
                    window=wp.window,
                    source=src,
                    age=age,
                )
            for src, age in sorted(wp.dropped.items()):
                rec.event(
                    "payload.dropped",
                    cat="payload",
                    window=wp.window,
                    source=src,
                    age=age,
                )
        live_sats = [v for v in sat_ids if v in live]
        log_this = log_every > 0 and rnd % log_every == 0
        if log_this and live_sats:
            loss_v = float(np.mean(np.asarray(losses)[live_sats]))
            cons_v = consensus_distance(
                jax.tree.map(lambda x: np.asarray(x)[live_sats], state["params"])
            )
        else:
            loss_v = cons_v = float("nan")
        log = GroundSegRoundLog(
            round=rnd,
            loss=loss_v,
            consensus=cons_v,
            delivered=wp.uplink.delivered_count(),
            covered=(
                len(wp.downlink.covered - sinks_s)
                if wp.downlink is not None
                else 0
            ),
            unreachable=len(wp.uplink.unreachable),
            alive=len(live_sats),
            pooled=pool,
            carried=len(wp.residual),
            dropped=len(wp.dropped),
            max_age=wp.max_delivered_age(),
        )
        logs.append(log)
        if on_round is not None:
            on_round(log)
    return state, logs


def consensus_distance(stacked_params) -> float:
    """Max relative L2 distance of any node's params from the mean."""
    leaves = jax.tree.leaves(stacked_params)
    num = 0.0
    den = 0.0
    for leaf in leaves:
        arr = np.asarray(leaf, dtype=np.float64)
        mean = arr.mean(axis=0, keepdims=True)
        num += float(np.square(arr - mean).sum())
        den += float(np.square(mean).sum() * arr.shape[0])
    return (num / max(den, 1e-30)) ** 0.5


# ---------------------------------------------------------------------------
# One driver entry point (ISSUE 10): run(cfg) dispatches on config type
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TDMRun:
    """Config for :func:`run_tdm_rounds` — one FL round per slot relation."""

    cache: RoundFnCache
    state: Any
    relations: Sequence[Relation]
    batch_fn: Callable[[int], Any]
    alive: Optional[set] = None
    on_round: Optional[Callable[[RoundLog], None]] = None
    log_every: int = 1


@dataclasses.dataclass
class ConstellationRun:
    """Config for :func:`run_constellation_fl` — geometry-driven rounds."""

    cfg: ModelConfig
    opt_cfg: Any
    mesh: Mesh
    n_nodes: int
    fl_cfg: FLConfig
    plan: Any
    state: Any
    batch_fn: Callable[[int], Any]
    rounds: Optional[int] = None
    alive: Optional[set] = None
    on_round: Optional[Callable[[RoundLog], None]] = None
    optimize: Optional[str] = None
    antennas: Any = None
    payload_bytes: int = 1 << 20
    acquisition_s: float = 0.0
    log_every: int = 1


@dataclasses.dataclass
class GroundSegRun:
    """Config for :func:`run_groundseg_fl` — ground stations as sinks."""

    cfg: ModelConfig
    opt_cfg: Any
    mesh: Mesh
    n_nodes: int
    fl_cfg: FLConfig
    gs_cfg: GroundSegConfig
    plan: Any
    state: Any
    batch_fn: Callable[[int], Any]
    sinks: Any = ()
    rounds: int = 1
    alive: Optional[set] = None
    on_round: Optional[Callable[[GroundSegRoundLog], None]] = None
    optimize: Optional[str] = None
    antennas: Any = None
    payload_bytes: int = 1 << 20
    acquisition_s: float = 0.0
    log_every: int = 1


@dataclasses.dataclass
class RunResult:
    """Shared return shape of :func:`run`: mode tag + final state + logs."""

    mode: str                    # "tdm" | "constellation" | "groundseg"
    state: Any
    logs: List[Any]

    @property
    def n_rounds(self) -> int:
        return len(self.logs)

    @property
    def final(self) -> Any:
        """Last round's log (None for a zero-round run)."""
        return self.logs[-1] if self.logs else None


def run(run_cfg) -> RunResult:
    """One driver entry point over the three FL modes.

    Dispatches on the config dataclass type — :class:`TDMRun` →
    :func:`run_tdm_rounds`, :class:`ConstellationRun` →
    :func:`run_constellation_fl`, :class:`GroundSegRun` →
    :func:`run_groundseg_fl` — and normalizes the ``(state, logs)`` returns
    into one :class:`RunResult`. The underlying functions are unchanged
    (and remain directly callable); this is pure plumbing so examples and
    higher drivers can switch modes by swapping a config object.
    """
    if isinstance(run_cfg, TDMRun):
        state, logs = run_tdm_rounds(
            run_cfg.cache, run_cfg.state, run_cfg.relations, run_cfg.batch_fn,
            alive=run_cfg.alive, on_round=run_cfg.on_round,
            log_every=run_cfg.log_every,
        )
        return RunResult("tdm", state, logs)
    if isinstance(run_cfg, ConstellationRun):
        state, logs = run_constellation_fl(
            run_cfg.cfg, run_cfg.opt_cfg, run_cfg.mesh, run_cfg.n_nodes,
            run_cfg.fl_cfg, run_cfg.plan, run_cfg.state, run_cfg.batch_fn,
            rounds=run_cfg.rounds, alive=run_cfg.alive,
            on_round=run_cfg.on_round, optimize=run_cfg.optimize,
            antennas=run_cfg.antennas, payload_bytes=run_cfg.payload_bytes,
            acquisition_s=run_cfg.acquisition_s, log_every=run_cfg.log_every,
        )
        return RunResult("constellation", state, logs)
    if isinstance(run_cfg, GroundSegRun):
        state, logs = run_groundseg_fl(
            run_cfg.cfg, run_cfg.opt_cfg, run_cfg.mesh, run_cfg.n_nodes,
            run_cfg.fl_cfg, run_cfg.gs_cfg, run_cfg.plan, run_cfg.state,
            run_cfg.batch_fn, run_cfg.sinks, run_cfg.rounds,
            alive=run_cfg.alive, on_round=run_cfg.on_round,
            optimize=run_cfg.optimize, antennas=run_cfg.antennas,
            payload_bytes=run_cfg.payload_bytes,
            acquisition_s=run_cfg.acquisition_s, log_every=run_cfg.log_every,
        )
        return RunResult("groundseg", state, logs)
    raise TypeError(
        f"run() takes a TDMRun / ConstellationRun / GroundSegRun config, "
        f"got {type(run_cfg).__name__}"
    )
