"""PTB-FLA training mode: satellites = node groups, each training on local
data, communicating ONLY via the paper's generic algorithms.

Implementation: parameters get a leading ``node`` axis sharded over the
mesh's node axis; one ``shard_map`` spans local compute + the TDM exchange,
so the per-slot relation literally becomes the collective schedule
(matchings -> ppermute, DESIGN.md §3). Three modes:

- ``centralized``   — FedAvg via all-reduce-mean every H steps
- ``decentralized`` — clique gossip (the paper's getMeas evaluation case)
- ``tdm``           — gossip over an arbitrary TDM schedule (constellation
                      visibility, ring, hypercube, ...), optionally int8 /
                      top-k (CHOCO) compressed

Time-varying schedules: :class:`RoundFnCache` + :func:`run_tdm_rounds` drive
one FL round per slot relation, recompiling only on unseen topologies;
:func:`run_constellation_fl` feeds them straight from a geometry-derived
:class:`~repro.constellation.contact_plan.ContactPlan` (the paper's actual
deployment — occluded satellites simply have no pairs that slot).

Fault tolerance: a failed/occluded satellite is dropped from the slot's
relation (``Relation.restrict``) — the paper's skip-slot semantics — and the
others keep training; its params re-sync through later gossip rounds.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import fl, tdm
from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule
from repro.models import registry
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class FLConfig:
    mode: str = "tdm"               # centralized | decentralized | tdm
    local_steps: int = 1            # H: optimizer steps between exchanges
    comm: str = "getmeas"           # getmeas | get1meas (paper primitives)
    compression: str = "none"       # none | int8 | topk
    topk_k: int = 64
    fused: bool = True              # flat-buffer exchange engine (core/fused)


def _stack_init(key, cfg: ModelConfig, opt_cfg, n_nodes: int):
    """Per-node states, stacked on a leading node axis.

    Every node starts from the SAME init (consensus start: seed is
    ``fold_in(key, 0)`` for all of them), so the model/opt state is built
    once and broadcast — not re-initialized n_nodes times.
    """
    params, _ = registry.bundle(cfg).init(jax.random.fold_in(key, 0))
    state = {
        "params": params,
        "opt": adamw.init_opt_state(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_nodes,) + x.shape), state
    )


def build_fl_round(
    cfg: ModelConfig,
    opt_cfg: adamw.OptConfig,
    mesh: Mesh,
    n_nodes: int,
    fl_cfg: FLConfig,
    rel: Relation,
    axis: str = "data",
) -> Callable:
    """One FL round = local_steps SGD steps on node-local data + one
    exchange over ``rel``. Returns a jit'd (stacked_state, stacked_batch) ->
    (stacked_state, metrics) function."""
    b = registry.bundle(cfg)
    tdm_cfg = fl.TDMFLAConfig(
        comm=fl_cfg.comm,
        compression=fl_cfg.compression,
        topk_k=fl_cfg.topk_k,
        fused=fl_cfg.fused,
    )

    def node_round(state, batch):
        # state/batch leading dim = 1 (this node's shard); squeeze it
        state = jax.tree.map(lambda x: x[0], state)
        batch = jax.tree.map(lambda x: x[0], batch)

        def one_step(st, mb):
            (loss, _), grads = jax.value_and_grad(
                lambda p: b.loss_fn(p, mb), has_aux=True
            )(st["params"])
            new_p, new_opt, _ = adamw.apply_updates(
                st["params"], grads, st["opt"], opt_cfg
            )
            return {"params": new_p, "opt": new_opt, "step": st["step"] + 1}, loss

        losses = []
        for h in range(fl_cfg.local_steps):
            mb = jax.tree.map(lambda x: x[h], batch)
            state, loss = one_step(state, mb)
            losses.append(loss)
        local_loss = jnp.stack(losses).mean()

        # ---- the paper's communication step
        params = state["params"]
        if fl_cfg.mode == "centralized":
            params = fl.centralized_round(params, axis)
        elif fl_cfg.mode == "decentralized":
            params = fl.decentralized_round(params, axis, n_nodes)
        else:
            params, _ = fl.tdm_fla_round(params, rel, axis, n_nodes, tdm_cfg)
        state = dict(state, params=params)

        state = jax.tree.map(lambda x: x[None], state)
        return state, local_loss[None]

    spec_state = P(axis)
    fn = shard_map(
        node_round,
        mesh=mesh,
        in_specs=(spec_state, spec_state),
        out_specs=(spec_state, P(axis)),
        check_rep=False,  # model-internal scans carry node-invariant zeros;
                          # vma tracking would demand pcasts throughout
    )
    return jax.jit(fn, donate_argnums=(0,))


class RoundFnCache:
    """Compiled FL-round functions keyed by slot relation.

    Time-varying schedules revisit topologies (orbits are periodic), so the
    jit cache is keyed on the relation's pair set — each distinct topology
    compiles once, every revisit is a cache hit.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg,
        mesh: Mesh,
        n_nodes: int,
        fl_cfg: FLConfig,
        axis: str = "data",
    ):
        self.args = (cfg, opt_cfg, mesh, n_nodes, fl_cfg)
        self.n_nodes = n_nodes
        self.axis = axis
        self._fns: Dict[Any, Callable] = {}

    def __call__(self, rel: Relation) -> Callable:
        key = tuple(sorted(rel.pairs))
        if key not in self._fns:
            self._fns[key] = build_fl_round(*self.args, rel, axis=self.axis)
        return self._fns[key]

    def __len__(self) -> int:
        return len(self._fns)


@dataclasses.dataclass(frozen=True)
class RoundLog:
    round: int
    loss: float
    consensus: float
    n_links: int        # undirected ISLs active this round
    alive: int          # participating satellites


def run_tdm_rounds(
    cache: RoundFnCache,
    state: Any,
    relations: Sequence[Relation],
    batch_fn: Callable[[int], Any],
    alive: Optional[set] = None,
    on_round: Optional[Callable[[RoundLog], None]] = None,
    log_every: int = 1,
):
    """Drive one FL round per slot relation (the time-varying-schedule mode).

    ``alive`` is read *each round*, so callers may mutate it mid-flight to
    model satellite failures; occluded/dead nodes drop out of the round's
    relation via ``Relation.restrict`` (paper skip-slot semantics) while
    their local training continues. Returns (state, [RoundLog, ...]).

    ``log_every``: compute loss/consensus metrics only every k-th round
    (always including round 0). ``consensus_distance`` transfers the full
    stacked parameters to the host — a device sync per round that benchmark
    and long runs don't want; skipped rounds log NaN metrics and never touch
    device values, so rounds stay async-dispatchable. ``log_every=0``
    disables metrics entirely.
    """
    n_nodes = cache.n_nodes
    logs = []
    for rnd, rel in enumerate(relations):
        live = set(alive) if alive is not None else set(range(n_nodes))
        rel_t = rel.restrict(live)
        state, losses = cache(rel_t)(state, batch_fn(rnd))
        log_this = log_every > 0 and rnd % log_every == 0
        log = RoundLog(
            round=rnd,
            loss=float(jnp.mean(losses)) if log_this else float("nan"),
            consensus=(
                consensus_distance(state["params"]) if log_this else float("nan")
            ),
            n_links=len(rel_t) // 2,
            alive=len(live),
        )
        logs.append(log)
        if on_round is not None:
            on_round(log)
    return state, logs


def run_constellation_fl(
    cfg: ModelConfig,
    opt_cfg,
    mesh: Mesh,
    n_nodes: int,
    fl_cfg: FLConfig,
    plan,
    state: Any,
    batch_fn: Callable[[int], Any],
    rounds: Optional[int] = None,
    alive: Optional[set] = None,
    on_round: Optional[Callable[[RoundLog], None]] = None,
    optimize: Optional[str] = None,
    antennas=None,
    payload_bytes: int = 1 << 20,
    acquisition_s: float = 0.0,
    log_every: int = 1,
):
    """Constellation-driven FL: one round per contact-plan time step.

    ``plan`` is a :class:`repro.constellation.contact_plan.ContactPlan`;
    its geometry-derived visibility relations *are* the TDM schedule. When
    ``rounds`` exceeds the plan horizon the plan repeats (orbits are
    periodic when the horizon is one period).

    ``optimize`` switches the round schedule from the raw per-step
    visibility relations to a materialized antenna-constrained
    ``ContactSchedule`` — ``"greedy"`` for the first-legal-coloring
    baseline, ``"rate"`` for the min-cost schedule over the optimizer's
    strategy portfolio for this plan window (never costlier than greedy;
    see :mod:`repro.constellation.optimizer`). One FL round then runs per
    emitted sub-slot. ``antennas``/``payload_bytes``/``acquisition_s`` are
    the physical knobs the schedule is sized (and priced) with; with zero
    slew penalty and an antenna budget covering each step's degree, greedy
    and rate-aware emit the identical relation sequence, so training is
    bit-for-bit unchanged — only the time accounting improves.

    The schedule is built for the full constellation; ``alive`` keeps its
    ``run_tdm_rounds`` contract (read each round, mutable mid-flight), so
    failures and recoveries apply per round in both modes. A plan window
    with no feasible contacts falls back to the per-step relations (all
    empty), preserving the skip-slot semantics: local training continues.
    """
    if optimize is None:
        relations = plan.relations()
    else:
        sched = plan.schedule(
            antennas=antennas,
            payload_bytes=payload_bytes,
            optimize=optimize,
            acquisition_s=acquisition_s,
        )
        relations = list(sched.tdm)
        if not relations:
            relations = plan.relations()
    if rounds is not None:
        reps = -(-rounds // max(len(relations), 1))
        relations = (relations * reps)[:rounds]
    cache = RoundFnCache(cfg, opt_cfg, mesh, n_nodes, fl_cfg)
    return run_tdm_rounds(
        cache, state, relations, batch_fn, alive, on_round, log_every=log_every
    )


def consensus_distance(stacked_params) -> float:
    """Max relative L2 distance of any node's params from the mean."""
    leaves = jax.tree.leaves(stacked_params)
    num = 0.0
    den = 0.0
    for leaf in leaves:
        arr = np.asarray(leaf, dtype=np.float64)
        mean = arr.mean(axis=0, keepdims=True)
        num += float(np.square(arr - mean).sum())
        den += float(np.square(mean).sum() * arr.shape[0])
    return (num / max(den, 1e-30)) ** 0.5
