"""End-to-end training driver.

Runs any assigned arch (full or smoke config) on any mesh: plain global-batch
training (pjit) with checkpoint/restart, or PTB-FLA mode (--fl tdm|...)
where node groups are satellites doing local steps + TDM exchange — see
launch/fl_train.py.

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --smoke \
      --steps 30 --seq 64 --batch 8
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m --smoke \
      --steps 20 --ckpt /tmp/ck --restore
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs import archs
from repro.data import pipeline
from repro.launch import sharding as shlib
from repro.launch import steps as steps_lib
from repro.models.config import ShapeConfig
from repro.optim import adamw


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--ckpt", type=str, default=None)
    p.add_argument("--ckpt-every", type=int, default=10)
    p.add_argument("--restore", action="store_true")
    p.add_argument("--log-every", type=int, default=1)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = archs.get(args.arch)
    if args.smoke:
        cfg = archs.smoke_cfg(cfg)
    shape = ShapeConfig("custom", "train", args.seq, args.batch)
    opt_cfg = adamw.OptConfig(
        peak_lr=args.lr, warmup_steps=5, decay_steps=max(args.steps, 10)
    )

    n_dev = len(jax.devices())
    rules = None
    if n_dev > 1:
        axes = {"data": min(n_dev, max(1, args.batch)), "model": 1}
        mesh = jax.make_mesh((axes["data"], 1), ("data", "model"),
                             devices=jax.devices()[: axes["data"]])
        rules = shlib.rules_for(mesh, cfg.fsdp)

    train_step = jax.jit(
        steps_lib.build_train_step(cfg, opt_cfg, rules), donate_argnums=(0,)
    )

    state = steps_lib.init_state(jax.random.PRNGKey(args.seed), cfg, opt_cfg)
    start_step = 0
    if args.ckpt and args.restore and ckpt_lib.latest_step(args.ckpt) is not None:
        start_step, state = ckpt_lib.restore(args.ckpt, target=state)
        print(f"restored checkpoint at step {start_step}")

    stream = pipeline.SyntheticStream(cfg, shape, seed=args.seed)
    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(step).items()}
        state, metrics = train_step(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(
                f"step {step:4d} loss {loss:8.4f} "
                f"gnorm {float(metrics['grad_norm']):8.3f} "
                f"lr {float(metrics['lr']):.2e}",
                flush=True,
            )
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt, step + 1, state)
    ckpt_lib.wait_all()
    dt = time.time() - t0
    if losses:
        print(
            f"done: {args.steps - start_step} steps in {dt:.1f}s; "
            f"loss {losses[0]:.4f} -> {losses[-1]:.4f}"
        )
    else:
        print(f"nothing to do: restored step {start_step} >= --steps {args.steps}")
    return losses


if __name__ == "__main__":
    main()
