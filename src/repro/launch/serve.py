"""Serving driver: batched prefill + decode loop with a continuous-batching
style slot manager (requests join/leave the batch between steps).

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \
      --requests 6 --max-new 12
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import archs
from repro.models import registry


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class BatchedServer:
    """Fixed-width decode batch; free slots are refilled from the queue
    after each prefill (padded prompts share one prefill shape bucket)."""

    def __init__(self, cfg, batch: int, max_len: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.bundle = registry.bundle(cfg)
        self.params, _ = self.bundle.init(jax.random.PRNGKey(seed))
        self._decode = jax.jit(self.bundle.decode_fn)
        self._prefill = jax.jit(
            lambda p, b: self.bundle.prefill_fn(p, b, max_len)
        )
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.cache = None
        self.steps = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Admit up to `batch` queued requests as one padded prefill."""
        if not self.queue or self.active:
            return
        admitted = self.queue[: self.batch]
        self.queue = self.queue[self.batch :]
        plen = max(len(r.prompt) for r in admitted)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(admitted):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
            self.active[i] = r
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        self.cache = cache
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i, r in self.active.items():
            r.out.append(int(nxt[i]))
        self._last = nxt

    def step(self) -> bool:
        """One decode step for the active batch. Returns False when idle."""
        self._admit()
        if not self.active:
            return False
        tok = jnp.asarray(self._last[:, None])
        logits, self.cache = self._decode(self.params, self.cache, {"token": tok})
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        self._last = nxt
        self.steps += 1
        finished = [i for i, r in self.active.items() if r.done]
        for i, r in list(self.active.items()):
            if not r.done:
                r.out.append(int(nxt[i]))
        if len(finished) == len(self.active) and finished:
            self.active.clear()
            self.cache = None
        return bool(self.active) or bool(self.queue)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=8)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = archs.get(args.arch)
    if args.smoke:
        cfg = archs.smoke_cfg(cfg)
    max_len = args.prompt_len + args.max_new + 8
    srv = BatchedServer(cfg, args.batch, max_len, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        srv.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new=args.max_new,
        ))
    t0 = time.time()
    while srv.step():
        pass
    dt = time.time() - t0
    total_tokens = args.requests * args.max_new
    print(f"served {args.requests} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s, {srv.steps} steps)")
    return srv


if __name__ == "__main__":
    main()
