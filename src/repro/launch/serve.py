"""Single-host serving driver: one model replica behind the fleet scheduler.

Since ISSUE 10 the actual scheduling logic lives in
:mod:`repro.serving.replica` — :class:`BatchedServer` here is the
degenerate fleet (one satellite, one replica, no contact graph): the same
wave admission, per-replica decode cache, and continuous-batching
semantics, so the local CLI path and the constellation engine
(:mod:`repro.serving.engine`) exercise identical code. For requests that
arrive at ground stations and route over inter-satellite links, use
``ServingEngine`` / ``examples/serve_constellation.py``.

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \
      --requests 6 --max-new 12
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List

import numpy as np

from repro.configs import archs
from repro.serving.replica import ModelDecoder, ReplicaFleet


@dataclasses.dataclass
class Request:
    """A local request: duck-compatible with the fleet's lane protocol
    (``prompt`` / ``out`` / ``done``), minus the ground-segment lifecycle
    fields of :class:`repro.serving.requests.InferenceRequest`."""

    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class BatchedServer:
    """Fixed-width decode batch; free lanes refill from the queue whenever
    the replica goes idle (wave discipline — the decode cache keeps one
    scalar position per replica, so waves prefill together)."""

    _SAT = 0   # the single pseudo-satellite id

    def __init__(self, cfg, batch: int, max_len: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.fleet = ReplicaFleet(
            [self._SAT],
            batch,
            ModelDecoder(cfg, 1, batch, max_len, seed=seed),
        )
        self.steps = 0

    # Contract kept for tests/callers of the pre-fleet server: ``queue`` is
    # the waiting list, ``active`` the occupied decode lanes.
    @property
    def queue(self) -> List[Request]:
        return list(self.fleet.queues[self._SAT])

    @property
    def active(self) -> Dict[int, Request]:
        return {
            lane: r
            for lane, r in enumerate(self.fleet.lanes[self._SAT])
            if r is not None
        }

    def submit(self, req: Request) -> None:
        self.fleet.enqueue(self._SAT, req)

    def step(self) -> bool:
        """Admit if idle, then one decode step. False when fully drained."""
        self.fleet.admit({self._SAT})
        if self.fleet.tick():
            pass  # finished requests already carry their full output
        if self.fleet.busy(self._SAT):
            self.steps += 1
        return self.fleet.busy(self._SAT) or bool(self.fleet.queues[self._SAT])


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=8)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = archs.get(args.arch)
    if args.smoke:
        cfg = archs.smoke_cfg(cfg)
    max_len = args.prompt_len + args.max_new + 8
    srv = BatchedServer(cfg, args.batch, max_len, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        srv.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new=args.max_new,
        ))
    t0 = time.time()
    while srv.step():
        pass
    dt = time.time() - t0
    total_tokens = args.requests * args.max_new
    print(f"served {args.requests} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s, {srv.steps} steps)")
    return srv


if __name__ == "__main__":
    main()
