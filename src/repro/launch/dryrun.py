import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and extract the roofline terms from the compiled
artifact.

MUST be run as its own process (the device count above is locked at first
jax init — hence the two lines before any other import).

Per cell this writes ``<out>/<mesh>/<arch>__<shape>.json`` with:
  - memory_analysis (per-device argument/output/temp/code bytes)
  - cost_analysis   (per-device HLO flops / bytes accessed)
  - collective op bytes/counts by kind (parsed from the partitioned HLO)
  - the three roofline terms in seconds + the dominant term
  - MODEL_FLOPS (6·N_active·D or 2·N_active·D) and the useful-flops ratio

Usage:
  python -m repro.launch.dryrun --mesh single --arch gemma2-9b --shape train_4k
  python -m repro.launch.dryrun --mesh both          # all 32 valid cells x 2
"""

import argparse
import hashlib
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import archs
from repro.launch import sharding as shlib
from repro.launch import steps as steps_lib
from repro.launch.flops import program_costs
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.models import registry
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.optim import adamw


def cfg_fingerprint(cfg: ModelConfig) -> str:
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


def sharded_bytes(shapes_tree, shardings_tree, mesh) -> int:
    """Per-device bytes of a sharded pytree (analytic)."""
    total = 0
    flat_s = jax.tree.leaves(shapes_tree)
    flat_sh = jax.tree.leaves(
        shardings_tree, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)
    )
    for s, sh in zip(flat_s, flat_sh):
        n = int(np.prod(s.shape)) if s.shape else 1
        shard_factor = 1
        if isinstance(sh, jax.sharding.NamedSharding):
            for ax in sh.spec:
                if ax is None:
                    continue
                key = ax if isinstance(ax, (tuple, list)) else (ax,)
                for k in key:
                    shard_factor *= mesh.shape[k]
        total += -(-n // shard_factor) * s.dtype.itemsize
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per row


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Build + lower the right step function for a cell.

    Returns (lowered, staged_costs) — staged_costs are the exact jaxpr-level
    flops / fusion-aware traffic (global shapes), see launch/flops.py.

    Sharding mode: cfg.train_mode for training cells; cfg.serve_parallel_mode
    for prefill/decode (serving never pays FSDP gather-per-token).
    """
    if shape.kind != "train":
        mode = cfg.serve_parallel_mode
    elif cfg.pp_stages > 0:
        mode = "pp"
    else:
        mode = cfg.train_mode
    rules = shlib.rules_for(mesh, mode)
    opt_cfg = adamw.OptConfig(dtype=cfg.opt_dtype)
    in_specs = registry.input_specs(cfg, shape)
    batch_sh = steps_lib.batch_shardings(cfg, shape, rules)

    if shape.kind == "train":
        if cfg.pp_stages > 0:
            from repro.launch import pipeline as pp_lib

            n_micro = cfg.pp_micro or 4 * cfg.pp_stages
            fn, cfgp = pp_lib.build_pp_train_step(
                cfg, opt_cfg, rules, cfg.pp_stages, n_micro
            )
            st_specs = steps_lib.state_specs(cfgp, opt_cfg)
            st_sh = steps_lib.state_shardings(cfgp, opt_cfg, rules)
        else:
            st_specs = steps_lib.state_specs(cfg, opt_cfg)
            st_sh = steps_lib.state_shardings(cfg, opt_cfg, rules)
            fn = steps_lib.build_train_step(cfg, opt_cfg, rules)
        costs = program_costs(fn, st_specs, in_specs)
        jf = jax.jit(
            fn,
            in_shardings=(st_sh, batch_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )
        with mesh:
            return jf.lower(st_specs, in_specs), costs

    p_shapes, p_specs = registry.param_specs(cfg)
    # serving runs on a bf16 cast of the checkpoint (params are read-only;
    # fp32 master copies are a training concern — 2x HBM for nothing here)
    p_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s,
        p_shapes,
    )
    p_sh = shlib.param_shardings(rules, p_specs, p_shapes)
    if shape.kind == "prefill":
        fn = steps_lib.build_prefill_step(cfg, shape, rules)
        cache_sh = steps_lib.cache_shardings(cfg, shape, rules)
        costs = program_costs(fn, p_shapes, in_specs)
        jf = jax.jit(
            fn,
            in_shardings=(p_sh, batch_sh),
            out_shardings=(None, {"pos": None, "units": cache_sh["units"]}),
        )
        with mesh:
            return jf.lower(p_shapes, in_specs), costs

    # decode
    fn = steps_lib.build_decode_step(cfg, rules)
    cache_shapes = registry.cache_specs(cfg, shape)
    cache_sh = steps_lib.cache_shardings(cfg, shape, rules)
    costs = program_costs(fn, p_shapes, cache_shapes, in_specs)
    jf = jax.jit(
        fn,
        in_shardings=(p_sh, cache_sh, batch_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )
    with mesh:
        return jf.lower(p_shapes, cache_shapes, in_specs), costs


def analyze(compiled, staged, cfg, shape, mesh, lower_s, compile_s):
    n_chips = mesh.size
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {
            k: float(v)
            for k, v in ca.items()
            if np.isscalar(v) and k in ("flops", "bytes accessed", "transcendentals")
        }
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    # staged (jaxpr-exact) costs are GLOBAL; divide by chips for per-device.
    # (XLA cost_analysis counts scan bodies once — kept only as a reference.)
    flops_dev = staged.flops / n_chips
    bytes_dev = staged.traffic_bytes / n_chips
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = coll.total_bytes / ICI_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_dev = mf / n_chips
    useful = mf_dev / flops_dev if flops_dev else 0.0
    step_s = max(terms.values())
    mfu = (mf_dev / max(step_s, 1e-12)) / PEAK_FLOPS_BF16 if step_s else 0.0

    return {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": list(mesh.shape.values()),
        "mesh_axes": list(mesh.shape.keys()),
        "chips": n_chips,
        "fingerprint": cfg_fingerprint(cfg),
        "mode": cfg.train_mode if shape.kind == "train" else cfg.serve_parallel_mode,
        "micro_steps": cfg.micro_steps,
        "opt_dtype": cfg.opt_dtype,
        "param_dtype": cfg.param_dtype,
        "lower_seconds": lower_s,
        "compile_seconds": compile_s,
        "staged_costs": {
            "flops_global": staged.flops,
            "traffic_bytes_global": staged.traffic_bytes,
            "transcendentals_global": staged.transcendentals,
        },
        "xla_cost_analysis_per_body": cost,
        "memory_analysis": mem,
        "collectives": coll.to_json(),
        "roofline": {
            **terms,
            "dominant": dominant,
            "model_flops_total": mf,
            "model_flops_per_device": mf_dev,
            "hlo_flops_per_device": flops_dev,
            "useful_flops_ratio": useful,
            "bound_step_seconds": step_s,
            "roofline_mfu": mfu,
        },
        "hlo_bytes": len(hlo),
    }


def run_cell(name: str, shape_name: str, mesh_kind: str, out_dir: pathlib.Path,
             skip_existing: bool = True) -> dict | None:
    shape = SHAPES[shape_name]
    cfg = archs.cfg_for_cell(archs.get(name), shape)
    if cfg is None:
        print(f"SKIP {name} x {shape_name} (inapplicable: full attention at 500k)")
        return None
    out = out_dir / mesh_kind / f"{name}__{shape_name}.json"
    if skip_existing and out.exists():
        try:
            data = json.loads(out.read_text())
            if data.get("fingerprint") == cfg_fingerprint(cfg):
                print(f"CACHED {name} x {shape_name} [{mesh_kind}]")
                return data
        except Exception:
            pass
    out.parent.mkdir(parents=True, exist_ok=True)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    print(f"LOWER {name} x {shape_name} [{mesh_kind}] ...", flush=True)
    t0 = time.time()
    lowered, staged = lower_cell(cfg, shape, mesh)
    t1 = time.time()
    print(f"  lowered in {t1-t0:.1f}s; compiling ...", flush=True)
    compiled = lowered.compile()
    t2 = time.time()
    data = analyze(compiled, staged, cfg, shape, mesh, t1 - t0, t2 - t1)
    # analytic per-device state bytes (complements memory_analysis)
    if shape.kind != "train":
        mode = cfg.serve_parallel_mode
    else:
        mode = "pp" if cfg.pp_stages > 0 else cfg.train_mode
    rules = shlib.rules_for(mesh, mode)
    opt_cfg = adamw.OptConfig(dtype=cfg.opt_dtype)
    if shape.kind == "train":
        st_specs = steps_lib.state_specs(cfg, opt_cfg)
        st_sh = steps_lib.state_shardings(cfg, opt_cfg, rules)
        data["state_bytes_per_device"] = sharded_bytes(st_specs, st_sh, mesh)
    else:
        p_shapes, p_specs = registry.param_specs(cfg)
        p_sh = shlib.param_shardings(rules, p_specs, p_shapes)
        data["state_bytes_per_device"] = sharded_bytes(p_shapes, p_sh, mesh)
        if shape.kind == "decode":
            cache_shapes = registry.cache_specs(cfg, shape)
            cache_sh = steps_lib.cache_shardings(cfg, shape, rules)
            data["cache_bytes_per_device"] = sharded_bytes(
                cache_shapes, cache_sh, mesh
            )
    out.write_text(json.dumps(data, indent=2))
    r = data["roofline"]
    print(
        f"  OK compile={t2-t1:.1f}s compute={r['compute_s']*1e3:.2f}ms "
        f"memory={r['memory_s']*1e3:.2f}ms coll={r['collective_s']*1e3:.2f}ms "
        f"dominant={r['dominant']} useful={r['useful_flops_ratio']:.2f}",
        flush=True,
    )
    return data


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="all")
    p.add_argument("--shape", default="all")
    p.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--force", action="store_true")
    args = p.parse_args(argv)

    out_dir = pathlib.Path(args.out)
    names = list(archs.ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for mesh_kind in meshes:
        for name in names:
            for shape_name in shapes:
                try:
                    run_cell(name, shape_name, mesh_kind, out_dir,
                             skip_existing=not args.force)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((mesh_kind, name, shape_name, str(e)))
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
