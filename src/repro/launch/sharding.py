"""Logical-axis sharding: the single place where model-internal axis names
meet the physical mesh.

Models annotate parameters (via the ``specs`` trees returned by ``init_*``)
and activations (via :func:`shard_activation`) with LOGICAL names; the
launch layer activates a :class:`ShardingRules` mapping logical -> mesh axes
for the current mesh. Outside any rules context (unit tests, single device)
everything is a no-op.

Two standard rule sets are provided:

- ``tp_rules``     — tensor/expert parallel over ``model``; batch over
                     ``(pod, data)``; params replicated over ``data``.
- ``fsdp_rules``   — tp_rules + ZeRO-3: the ``embed`` (or widest) dim of
                     every weight additionally sharded over ``data``;
                     XLA inserts all-gather-on-use / reduce-scatter-on-grad.
"""

from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    logical_to_mesh: Dict[str, Any] = field(default_factory=dict)

    def spec_for(self, logical: Tuple) -> P:
        axes = []
        used = set()
        for name in logical:
            mesh_axis = self.logical_to_mesh.get(name)
            # an axis can be consumed only once per spec; later dims replicate
            if mesh_axis is None:
                axes.append(None)
                continue
            key = tuple(mesh_axis) if isinstance(mesh_axis, (tuple, list)) else (mesh_axis,)
            if any(k in used for k in key):
                axes.append(None)
                continue
            # drop axes whose mesh extent doesn't divide... divisibility is
            # checked by the caller (sharding_for) with the array shape.
            axes.append(mesh_axis)
            used.update(key)
        return P(*axes)

    def sharding_for(self, logical: Tuple, shape: Tuple[int, ...]) -> NamedSharding:
        """NamedSharding for an array, dropping mesh axes that don't divide
        the corresponding dim (e.g. kv_heads=8 on a model axis of 16)."""
        spec = list(self.spec_for(logical))
        fixed = []
        for dim, ax in zip(shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            key = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
            extent = 1
            for k in key:
                extent *= self.mesh.shape[k]
            fixed.append(ax if dim % extent == 0 else None)
        fixed += [None] * (len(shape) - len(fixed))
        return NamedSharding(self.mesh, P(*fixed))


_ACTIVE: contextvars.ContextVar[Optional[ShardingRules]] = contextvars.ContextVar(
    "sharding_rules", default=None
)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    token = _ACTIVE.set(rules)
    try:
        yield rules
    finally:
        _ACTIVE.reset(token)


def current_rules() -> Optional[ShardingRules]:
    return _ACTIVE.get()


def shard_activation(x: jax.Array, logical: Tuple) -> jax.Array:
    """Annotate an activation with logical axes (no-op without rules).

    Rank-adaptive: under vmap (pipeline stages) arrays gain leading dims;
    those map to the "stage" logical axis so stage-sharded activations stay
    stage-sharded instead of being forced replicated."""
    rules = _ACTIVE.get()
    if rules is None:
        return x
    if x.ndim > len(logical):
        logical = ("stage",) * (x.ndim - len(logical)) + tuple(logical)
    sh = rules.sharding_for(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, sh)


def param_shardings(rules: ShardingRules, specs: Any, params_shape: Any) -> Any:
    """Map a specs tree + eval_shape tree -> NamedSharding tree."""
    is_spec = lambda s: isinstance(s, tuple) and all(
        isinstance(x, (str, type(None))) for x in s
    )
    return jax.tree.map(
        lambda s, p: rules.sharding_for(s, p.shape),
        specs,
        params_shape,
        is_leaf=is_spec,
    )


# ---------------------------------------------------------------------------
# rule sets
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh) -> Any:
    return ("pod", "data") if "pod" in mesh.shape else "data"


def tp_rules(mesh: Mesh) -> ShardingRules:
    """Tensor/expert parallel; params replicated over data."""
    return ShardingRules(
        mesh=mesh,
        logical_to_mesh={
            "batch": _batch_axes(mesh),
            "seq": None,
            "embed": None,
            "vocab": "model",
            "heads": "model",
            "kv_heads": "model",
            "head_dim": None,
            "mlp": "model",
            "experts": "model",
            "expert_mlp": None,
            "mamba_inner": "model",
            "mamba_heads": "model",
            "groups": None,
            "state": None,
            "conv_k": None,
            "conv_dim": "model",
            "layers": None,
            "cache_seq": None,
            "frames": None,
        },
    )


def fsdp_rules(mesh: Mesh) -> ShardingRules:
    """tp_rules + ZeRO-3 sharding of the embed dim over data
    (weights gathered on use, grads reduce-scattered)."""
    base = tp_rules(mesh)
    over = dict(base.logical_to_mesh)
    over["embed"] = "data"
    over["expert_mlp"] = None  # E over model, D over data is enough
    return ShardingRules(mesh=mesh, logical_to_mesh=over)


def fsdp_pure_rules(mesh: Mesh) -> ShardingRules:
    """Full ZeRO-3, no tensor parallelism: batch over EVERY mesh axis
    (per-device batch = B/chips), weights 2D-sharded (embed x mlp/heads).
    Per-layer traffic = weight all-gathers (param bytes), not activation
    all-reduces — the right trade for models whose activations/chip exceed
    their per-layer weights (small-d_model archs at big batch)."""
    base = tp_rules(mesh)
    over = dict(base.logical_to_mesh)
    over["batch"] = ("pod", "data", "model") if "pod" in mesh.shape else ("data", "model")
    over["embed"] = "data"
    return ShardingRules(mesh=mesh, logical_to_mesh=over)


def tp2d_rules(mesh: Mesh) -> ShardingRules:
    """Stationary-expert 2D sharding for trillion-scale MoE: expert weights
    sharded (experts -> model) x (expert_mlp/F -> data) and NEVER gathered —
    the F-contraction lowers to an activation psum instead of weight
    all-gathers (gather-per-microbatch is what made FSDP kimi-k2 move
    7 TB/device/step). Non-expert params: plain TP over model."""
    base = tp_rules(mesh)
    over = dict(base.logical_to_mesh)
    over["expert_mlp"] = ("pod", "data") if "pod" in mesh.shape else "data"
    return ShardingRules(mesh=mesh, logical_to_mesh=over)


def pp_rules(mesh: Mesh) -> ShardingRules:
    """Pipeline parallelism: layer stacks sharded over `data` (= the stage
    axis), TP/EP over `model` within each stage, DP over `pod` when present.
    Weights are STATIONARY (no gathers, grads local to the stage); only
    microbatch activations move between stages (launch/pipeline.py)."""
    base = tp_rules(mesh)
    over = dict(base.logical_to_mesh)
    over["layers"] = "data"
    over["stage"] = "data"
    over["batch"] = "pod" if "pod" in mesh.shape else None
    # in-flight (stage boundary) activations ride seq-sharded over `model`:
    # 16x smaller pipeline carries + permutes, Megatron-SP style
    over["pp_seq"] = "model"
    # pipe-exit loss: batch spreads back over the whole mesh ("batch" itself
    # maps to pod-only under pp — leaving the exit hidden replicated would
    # gather 30 GB/device and replicate the loss compute)
    over["loss_batch"] = ("pod", "data") if "pod" in mesh.shape else "data"
    return ShardingRules(mesh=mesh, logical_to_mesh=over)


MODES = {
    "tp": tp_rules,
    "fsdp": fsdp_rules,
    "fsdp_pure": fsdp_pure_rules,
    "tp2d": tp2d_rules,
    "pp": pp_rules,
}


def rules_for(mesh: Mesh, mode) -> ShardingRules:
    if isinstance(mode, bool):  # legacy: fsdp flag
        mode = "fsdp" if mode else "tp"
    return MODES[mode](mesh)
