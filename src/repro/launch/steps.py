"""Step-function builders: training (with microbatch gradient accumulation),
prefill, and decode — plus their in/out shardings for a mesh.

These are the functions the dry-run lowers and the trainers execute; the
models themselves never see the mesh (logical axes only).
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import sharding as shlib
from repro.models import registry
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import adamw


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def init_state(key, cfg: ModelConfig, opt_cfg: adamw.OptConfig):
    params, _ = registry.bundle(cfg).init(key)
    opt = adamw.init_opt_state(params, opt_cfg)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def state_specs(cfg: ModelConfig, opt_cfg: adamw.OptConfig):
    """ShapeDtypeStructs for the full train state (no allocation)."""
    p_shapes, _ = registry.param_specs(cfg)
    opt_shapes = jax.eval_shape(lambda: adamw.init_opt_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p_shapes), opt_cfg
    ))
    return {
        "params": p_shapes,
        "opt": opt_shapes,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_shardings(cfg: ModelConfig, opt_cfg: adamw.OptConfig,
                    rules: shlib.ShardingRules):
    p_shapes, p_specs = registry.param_specs(cfg)
    p_sh = shlib.param_shardings(rules, p_specs, p_shapes)

    # moments shard like their params; QTensor scales like the param minus
    # the last axis; counts replicated.
    def moment_sharding(psh: NamedSharding, pshape, stored):
        if isinstance(stored, adamw.QTensor):
            # scale = param.shape[:-1] + (1,): inherit all but the last axis
            ndim = len(stored.scale.shape)
            spec = list(psh.spec)[: ndim - 1]
            spec += [None] * (ndim - len(spec))
            return adamw.QTensor(
                q=psh, scale=NamedSharding(rules.mesh, P(*spec))
            )
        return psh

    opt_shapes = jax.eval_shape(lambda: adamw.init_opt_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p_shapes), opt_cfg
    ))
    is_q = lambda x: isinstance(x, adamw.QTensor)

    def map_moments(msh_tree):
        flat_p, treedef = jax.tree.flatten(p_sh, is_leaf=lambda x: isinstance(x, NamedSharding))
        flat_ps, _ = jax.tree.flatten(p_shapes)
        flat_m = jax.tree.flatten(msh_tree, is_leaf=is_q)[0]
        out = [moment_sharding(s, ps, m) for s, ps, m in zip(flat_p, flat_ps, flat_m)]
        return jax.tree.unflatten(treedef, out)

    mu_sh = map_moments(opt_shapes["mu"])
    nu_sh = map_moments(opt_shapes["nu"])
    return {
        "params": p_sh,
        "opt": {
            "mu": mu_sh,
            "nu": nu_sh,
            "count": NamedSharding(rules.mesh, P()),
        },
        "step": NamedSharding(rules.mesh, P()),
    }


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig,
                    rules: shlib.ShardingRules) -> Dict[str, NamedSharding]:
    specs = registry.input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        logical = ("batch",) + (None,) * (len(s.shape) - 1)
        out[name] = rules.sharding_for(logical, s.shape)
    return out


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig,
                    rules: shlib.ShardingRules):
    b = registry.bundle(cfg)
    cache_shapes = registry.cache_specs(cfg, shape)
    logical = b.cache_logical_specs()

    def map_one(l, c):
        return rules.sharding_for(l, c.shape)

    is_spec = lambda s: isinstance(s, tuple) and all(
        isinstance(x, (str, type(None))) for x in s
    )
    return jax.tree.map(map_one, logical, cache_shapes, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def build_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.OptConfig,
    rules: Optional[shlib.ShardingRules],
) -> Callable:
    """Global-batch pjit train step with microbatch gradient accumulation.

    The fp32 grad accumulator is EXPLICITLY constrained to the param
    shardings — left unconstrained, GSPMD materializes a replicated
    accumulator and emits a full-size all-reduce per microbatch (measured:
    +2.7 TB/device/step on kimi-k2; see EXPERIMENTS.md §Perf iteration 2).
    """
    b = registry.bundle(cfg)
    micro = max(cfg.micro_steps, 1)
    grad_shardings = None
    if rules is not None:
        p_shapes, p_specs = registry.param_specs(cfg)
        grad_shardings = shlib.param_shardings(rules, p_specs, p_shapes)

    def train_step(state, batch):
        with shlib.use_rules(rules):
            params = state["params"]

            def loss_of(p, mb):
                loss, metrics = b.loss_fn(p, mb)
                return loss, metrics

            if micro == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True
                )(params, batch)
            else:
                def split(x):
                    Bg = x.shape[0]
                    return x.reshape((micro, Bg // micro) + x.shape[1:])

                mbatches = jax.tree.map(split, batch)

                def constrain(g):
                    if grad_shardings is None:
                        return g
                    return jax.tree.map(
                        lambda x, sh: jax.lax.with_sharding_constraint(x, sh),
                        g, grad_shardings,
                    )

                def acc_body(carry, mb):
                    g_acc, l_acc = carry
                    (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(
                        params, mb
                    )
                    g_acc = constrain(jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), g_acc, g
                    ))
                    return (g_acc, l_acc + l), m

                g0 = constrain(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                ))
                (grads, loss_sum), metrics = jax.lax.scan(
                    acc_body, (g0, jnp.zeros((), jnp.float32)), mbatches
                )
                grads = jax.tree.map(lambda g: g / micro, grads)
                loss = loss_sum / micro
                metrics = jax.tree.map(lambda m: m.mean(), metrics)

            new_params, new_opt, opt_metrics = adamw.apply_updates(
                params, grads, state["opt"], opt_cfg
            )
            new_state = {
                "params": new_params,
                "opt": new_opt,
                "step": state["step"] + 1,
            }
            metrics = {"loss": loss, **metrics, **opt_metrics}
            return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                       rules: Optional[shlib.ShardingRules]) -> Callable:
    b = registry.bundle(cfg)

    def prefill_step(params, batch):
        with shlib.use_rules(rules):
            return b.prefill_fn(params, batch, shape.seq_len)

    return prefill_step


def build_decode_step(cfg: ModelConfig,
                      rules: Optional[shlib.ShardingRules]) -> Callable:
    b = registry.bundle(cfg)

    def serve_step(params, cache, batch):
        with shlib.use_rules(rules):
            return b.decode_fn(params, cache, batch)

    return serve_step
