"""Ground-segment subsystem: ground stations as first-class FL sinks.

The paper's generic *centralized* FLA, deployed over real contact
geometry: satellites train locally; their parameter payloads ride
store-and-forward multi-hop ISL relays to ground sinks over the TDM slots
a :class:`~repro.constellation.contact_plan.ContactPlan` materialized; the
sinks FedAvg (optionally pooling over terrestrial backhaul); and the
global model floods back out on the downlink slots.

- :mod:`repro.groundseg.routing`     — earliest-delivery contact-graph
  router (backward DP over the time-expanded slot sequence; reports
  unreachable satellites instead of hanging) plus the static uplink relay
  and downlink broadcast programs and their ppermute-legal batching.
- :mod:`repro.groundseg.aggregation` — the programs lowered to SPMD
  collectives on the fused flat buffers (:mod:`repro.core.fused`): one
  ppermute batch per buffer per relay slot (two for int8 via the Pallas
  ``tdm_compress`` kernels), one masked psum per buffer to pool sinks.

Rounds need not be one-shot: :class:`repro.groundseg.routing.MultiWindowRouter`
plans PIPELINED multi-window rounds (round r's downlink flood overlapping
round r+1's uplink relay on disjoint slot capacity) with delay-tolerant
payload persistence — a satellite that misses the sink this window still
delivers in a later one, its payload aging until a configurable staleness
horizon drops (and reports) it, and the sink FedAvg down-weights stale
deliveries by ``staleness_decay ** age``
(:func:`repro.groundseg.aggregation.pipelined_window_round`).

Drivers live in :func:`repro.launch.fl_train.run_groundseg_fl`; the
centralized-vs-decentralized cost oracle in
:func:`repro.constellation.cost.groundseg_round_cost` and the pipelined
steady-state oracle in
:func:`repro.constellation.cost.groundseg_pipelined_cost`.

Pipeline, end to end::

    geom = orbits.WalkerDelta(total=6, planes=2, altitude_km=8062.0)
    gs = [orbits.GroundStation(0.0, 0.0), orbits.GroundStation(45.0, 100.0)]
    plan = contact_plan.build_contact_plan(
        geom, duration_s=geom.period_s, step_s=geom.period_s / 12,
        ground_stations=gs)
    sched = plan.schedule(antennas=2)
    sinks = range(geom.total, plan.n_nodes)
    table = routing.earliest_delivery_routes(
        list(sched.tdm), plan.n_nodes, sinks)
    up = routing.build_relay_program(list(sched.tdm), plan.n_nodes, sinks)
    down = routing.build_broadcast_program(
        list(sched.tdm), plan.n_nodes, sinks)
"""

from repro.groundseg import aggregation, routing

__all__ = ["aggregation", "routing"]
