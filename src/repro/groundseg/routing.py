"""Store-and-forward contact-graph routing over a TDM slot sequence.

The ground-segment subsystem's scheduling core: given the slot relations a
:class:`~repro.constellation.contact_plan.ContactPlan` materialized (each
slot = one parallel TDM exchange opportunity), compute for every satellite
the EARLIEST slot by which its payload can reach a ground sink, allowing
multi-hop ISL relays — classic contact-graph routing (CGR) specialized to
the repo's slot algebra.

The computation is a backward DP over the time-expanded contact graph
rather than an explicit Dijkstra over (node, time) vertices: with ``T``
slots and per-slot relations, ``f[v][t]`` = earliest delivery slot for a
payload held by ``v`` at the *start* of slot ``t``. One hop per slot (a
slot is a single parallel exchange; data received during slot ``t`` can be
forwarded no earlier than slot ``t+1``):

    f[v][t] = min( f[v][t+1],                                  # hold
                   min over {v,u} in slots[t]:
                       t            if u is a sink             # deliver
                       f[u][t+1]    otherwise )                # relay

The DP runs in O(T·(V+E)) and always terminates after T steps, so an
unreachable satellite (no contact path to any sink inside the horizon) is
*reported* (``Route.sink is None``), never spun on. Ties prefer holding
(fewer transmissions) and then the lowest next-hop id, keeping every
product of this module deterministic — the property the paper's
assumption (a) (common knowledge of the schedule) needs so ground and
space segments compute identical plans independently.

On top of the per-(node, time) policy two STATIC programs are derived:

- :func:`build_relay_program` — the uplink: start every (alive, reachable)
  satellite with its own payload, replay the policy, and record the
  directed sends per slot. Payloads merge at shared relays
  (accumulate-and-forward: a carrier ships everything it holds and sheds
  it), so the per-slot digraph has out-degree <= 1 and the sink receives a
  SUM — exactly what FedAvg wants.
- :func:`build_broadcast_program` — the downlink: flood the global model
  from the sinks outward, each uncovered node adopting one covered
  neighbor per slot.

Both programs are pure Python; :mod:`repro.groundseg.aggregation` lowers
them to ``ppermute`` chains over the fused flat buffers. The ppermute
legality constraint (each device sends at most one and receives at most
one payload per collective) is handled by :func:`permutation_batches`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.relation import Relation
from repro.telemetry import metrics
from repro.telemetry import recorder as telemetry

DirectedEdge = Tuple[int, int]


@dataclass(frozen=True)
class Hop:
    """One scheduled transfer: ``src`` sends to ``dst`` during ``slot``."""

    slot: int
    src: int
    dst: int


@dataclass(frozen=True)
class Route:
    """One satellite's earliest-delivery path to the ground segment."""

    source: int
    sink: Optional[int]            # delivering sink; None = unreachable
    delivery_slot: Optional[int]   # slot whose transfer lands at the sink
    hops: Tuple[Hop, ...]

    @property
    def reachable(self) -> bool:
        return self.sink is not None


@dataclass(frozen=True)
class RoutingTable:
    """Earliest-delivery routes for every source, plus the DP policy."""

    n_nodes: int
    n_slots: int
    sinks: FrozenSet[int]
    routes: Dict[int, Route]
    # policy[t][v]: None = hold, else the neighbor v forwards to in slot t
    policy: Tuple[Tuple[Optional[int], ...], ...]

    def reachable(self) -> List[int]:
        return sorted(s for s, r in self.routes.items() if r.reachable)

    def unreachable(self) -> List[int]:
        return sorted(s for s, r in self.routes.items() if not r.reachable)

    def max_delivery_slot(self) -> Optional[int]:
        """Latest delivery slot over the reachable sources (None if none)."""
        slots = [
            r.delivery_slot for r in self.routes.values() if r.reachable
        ]
        return max(slots) if slots else None


def _neighbors_reference(rel: Relation, v: int) -> List[int]:
    """The pre-adjacency-cache neighbor query — an O(pairs) scan per call,
    exactly as ``Relation.peers_of`` worked before the memoized adjacency
    map. The reference DP keeps it so the retained oracle measures (and
    reproduces) the code the vectorized relaxation actually replaced."""
    return sorted(j for i, j in rel.pairs if i == v)


def _check_sinks_sources(
    n_nodes: int, sinks: Iterable[int], sources: Optional[Iterable[int]]
) -> Tuple[FrozenSet[int], List[int]]:
    sink_s = frozenset(int(s) for s in sinks)
    if not sink_s:
        raise ValueError("need at least one sink node")
    bad = [s for s in sink_s if not (0 <= s < n_nodes)]
    if bad:
        raise ValueError(f"sink ids {bad} outside node range 0..{n_nodes - 1}")
    if sources is None:
        src_list = [v for v in range(n_nodes) if v not in sink_s]
    else:
        src_list = sorted(set(int(s) for s in sources))
    return sink_s, src_list


def _routes_from_policy(
    policy: Sequence[Tuple[Optional[int], ...]],
    f0: Sequence[float],
    src_list: Sequence[int],
    sink_s: FrozenSet[int],
) -> Dict[int, Route]:
    """Walk the DP policy from each source — shared by the vectorized and
    reference DPs (the policy rows fully determine the routes)."""
    T = len(policy)
    routes: Dict[int, Route] = {}
    for s in src_list:
        if s in sink_s:
            routes[s] = Route(source=s, sink=s, delivery_slot=-1, hops=())
            continue
        if not math.isfinite(float(f0[s])):
            routes[s] = Route(source=s, sink=None, delivery_slot=None, hops=())
            continue
        hops: List[Hop] = []
        v = s
        for t in range(T):
            if v in sink_s:
                break
            nxt = policy[t][v]
            if nxt is not None:
                hops.append(Hop(slot=t, src=v, dst=nxt))
                v = nxt
        assert v in sink_s, f"finite DP value but walk missed a sink for {s}"
        routes[s] = Route(
            source=s, sink=v, delivery_slot=hops[-1].slot, hops=tuple(hops)
        )
    return routes


def _dp_policy(
    slots: Sequence[Relation], n_nodes: int, sink_s: FrozenSet[int]
) -> Tuple[Tuple[Tuple[Optional[int], ...], ...], np.ndarray]:
    """The batched backward relaxation: (policy, f[.][0]).

    One segmented-min pass per slot over the slot's sorted directed pairs
    instead of nested Python loops — O(T·(V+E)) NumPy work. The
    hold-on-ties / lowest-next-hop determinism rule is reproduced exactly:
    a node forwards only on a STRICT improvement over holding, and among
    neighbors achieving the minimum the lowest id wins.
    """
    T = len(slots)
    is_sink = np.zeros(n_nodes, dtype=bool)
    is_sink[list(sink_s)] = True
    f_next = np.full(n_nodes, np.inf)
    policy: List[Tuple[Optional[int], ...]] = []
    hold_row = (None,) * n_nodes
    for t in range(T - 1, -1, -1):
        pairs = slots[t].pairs_array()
        if pairs.size == 0:
            policy.append(hold_row)
            continue
        srcs, dsts = pairs[:, 0], pairs[:, 1]
        keep = ~is_sink[srcs]            # sinks never forward
        if not keep.all():
            srcs, dsts = srcs[keep], dsts[keep]
        if srcs.size == 0:
            policy.append(hold_row)
            continue
        # value of forwarding to each neighbor: deliver now (t) at a sink,
        # else the neighbor's own earliest delivery from the next slot on
        val = np.where(is_sink[dsts], float(t), f_next[dsts])
        # pairs_array is (src, dst)-sorted, so each source is one contiguous
        # group: segmented min via reduceat (exact — min is order-free)
        # instead of the much slower buffered ufunc.at scatter
        gs = np.flatnonzero(np.concatenate(([True], srcs[1:] != srcs[:-1])))
        gmin = np.minimum.reduceat(val, gs)
        gsrc = srcs[gs]
        imp = gmin < f_next[gsrc]        # strict: hold preferred on ties
        if not imp.any():
            policy.append(hold_row)
            continue
        # among neighbors achieving the min the lowest dst wins; dsts are
        # ascending within each group, so that is the FIRST index hitting
        # the group minimum
        P = val.size
        counts = np.diff(np.concatenate((gs, [P])))
        at_min = val == np.repeat(gmin, counts)
        first = np.minimum.reduceat(np.where(at_min, np.arange(P), P), gs)
        g_imp = np.flatnonzero(imp)
        move = gsrc[g_imp]
        f_next[move] = gmin[g_imp]
        row = list(hold_row)
        for v, a in zip(move.tolist(), dsts[first[g_imp]].tolist()):
            row[v] = a
        policy.append(tuple(row))
    policy.reverse()
    return tuple(policy), f_next


def earliest_delivery_routes(
    slots: Sequence[Relation],
    n_nodes: int,
    sinks: Iterable[int],
    sources: Optional[Iterable[int]] = None,
) -> RoutingTable:
    """Earliest-delivery contact-graph routes from each source to any sink.

    ``slots`` is the materialized TDM slot sequence (e.g.
    ``ContactSchedule.tdm.slots`` or ``ContactPlan.relations()``);
    ``sources`` defaults to every non-sink node id. A source that IS a sink
    is trivially delivered (empty hop list, ``delivery_slot=-1``).

    The DP runs as a batched array relaxation (:func:`_dp_policy`) —
    bit-identical to :func:`earliest_delivery_routes_reference`, the
    retained legacy nested-loop oracle.
    """
    sink_s, src_list = _check_sinks_sources(n_nodes, sinks, sources)
    policy, f0 = _dp_policy(slots, n_nodes, sink_s)
    routes = _routes_from_policy(policy, f0, src_list, sink_s)
    return RoutingTable(
        n_nodes=n_nodes,
        n_slots=len(slots),
        sinks=sink_s,
        routes=routes,
        policy=policy,
    )


def earliest_delivery_routes_reference(
    slots: Sequence[Relation],
    n_nodes: int,
    sinks: Iterable[int],
    sources: Optional[Iterable[int]] = None,
) -> RoutingTable:
    """The legacy per-slot/per-node/per-neighbor Python DP, retained as the
    equivalence oracle for :func:`earliest_delivery_routes`.

    Faithful to the pre-vectorization implementation including its
    per-call neighbor scan (:func:`_neighbors_reference`) — which is why it
    goes quadratic at mega-constellation scale. Run it on small instances
    (the property suite) or bounded slot prefixes (the benchmark's timed
    twin), not on 1000-satellite horizons."""
    sink_s, src_list = _check_sinks_sources(n_nodes, sinks, sources)
    T = len(slots)

    # backward DP: f_next = f[.][t+1]; policy filled for t = T-1 .. 0
    f_next = [math.inf] * n_nodes
    policy: List[Tuple[Optional[int], ...]] = []
    for t in range(T - 1, -1, -1):
        rel = slots[t]
        f_cur = list(f_next)
        row: List[Optional[int]] = [None] * n_nodes
        for v in range(n_nodes):
            if v in sink_s:
                continue
            best = f_next[v]           # hold (preferred on ties)
            act: Optional[int] = None
            for u in _neighbors_reference(rel, v):
                val = t if u in sink_s else f_next[u]
                if val < best:
                    best, act = val, u
            f_cur[v] = best
            row[v] = act
        f_next = f_cur
        policy.append(tuple(row))
    policy.reverse()

    routes = _routes_from_policy(policy, f_next, src_list, sink_s)
    return RoutingTable(
        n_nodes=n_nodes,
        n_slots=T,
        sinks=sink_s,
        routes=routes,
        policy=tuple(policy),
    )


# ---------------------------------------------------------------------------
# Static uplink / downlink programs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RelayProgram:
    """The uplink as a static per-slot directed-send plan.

    ``slot_sends[t]`` holds ``(src, dst)`` transfers for slot ``t`` — src
    ships its ENTIRE accumulated payload and sheds it (out-degree <= 1 per
    node per slot by construction; fan-in merges at the receiver).
    ``delivered[k]`` is the set of payload ids (source satellites) landing
    at sink ``k``; ``unreachable`` the holders with no route this window;
    ``residual[h]`` the payload ids stranded at holder ``h`` when the
    window ends — always a subset of the unreachable holders' loads, since
    a payload only moves along a route that delivers it within the window
    (the delay-tolerant invariant the multi-window router relies on).
    """

    n_nodes: int
    sinks: FrozenSet[int]
    slot_sends: Tuple[Tuple[DirectedEdge, ...], ...]
    delivered: Dict[int, FrozenSet[int]]
    unreachable: FrozenSet[int]
    residual: Dict[int, FrozenSet[int]] = None  # type: ignore[assignment]

    def __post_init__(self):
        if self.residual is None:
            object.__setattr__(self, "residual", {})

    @property
    def n_hops(self) -> int:
        return sum(len(s) for s in self.slot_sends)

    def delivered_count(self) -> int:
        return sum(len(v) for v in self.delivered.values())

    def residual_count(self) -> int:
        return sum(len(v) for v in self.residual.values())

    def last_used_slot(self) -> Optional[int]:
        used = [t for t, s in enumerate(self.slot_sends) if s]
        return max(used) if used else None


def build_relay_program(
    slots: Sequence[Relation],
    n_nodes: int,
    sinks: Iterable[int],
    sources: Optional[Iterable[int]] = None,
    table: Optional[RoutingTable] = None,
    initial_loads: Optional[Dict[int, Iterable[int]]] = None,
) -> RelayProgram:
    """Replay the routing policy with every reachable holder injecting its
    payload(s) at slot 0, merging payloads that meet at a relay.

    ``initial_loads`` maps holder node -> payload ids it starts the window
    with (default: every source holds exactly its own payload). Loads held
    by a sink are trivially delivered; loads at holders with no route stay
    put and come back in ``residual`` — the carry the multi-window router
    re-schedules next window.
    """
    if initial_loads is not None and sources is None:
        sources = sorted(initial_loads)
    if table is None:
        table = earliest_delivery_routes(slots, n_nodes, sinks, sources)
    sink_s = table.sinks
    if initial_loads is None:
        initial_loads = {
            s: {s} for s in table.routes if s not in sink_s
        }
    carrying: Dict[int, set] = {}
    delivered: Dict[int, set] = {k: set() for k in sorted(sink_s)}
    unreachable = set()
    residual: Dict[int, set] = {}
    for h, load in sorted(initial_loads.items()):
        load = set(load)
        if not load:
            continue
        if h in sink_s:
            delivered[h] |= load            # already on the ground
            continue
        route = table.routes.get(h)
        if route is None or not route.reachable:
            unreachable.add(h)
            residual[h] = load              # holds; re-scheduled next window
            continue
        carrying.setdefault(h, set()).update(load)
    slot_sends: List[Tuple[DirectedEdge, ...]] = []
    for t in range(table.n_slots):
        outgoing: Dict[int, int] = {}
        for v in sorted(carrying):
            if not carrying[v]:
                continue
            nxt = table.policy[t][v]
            if nxt is not None:
                outgoing[v] = nxt
        loads = {v: carrying[v] for v in outgoing}
        for v in outgoing:
            carrying[v] = set()
        for v, u in outgoing.items():
            if u in sink_s:
                delivered[u] |= loads[v]
            else:
                carrying.setdefault(u, set()).update(loads[v])
        slot_sends.append(tuple(sorted(outgoing.items())))
    leftover = {v for v, load in carrying.items() if load}
    assert not leftover, (
        f"relay left payloads stranded at {sorted(leftover)} — the routing "
        "policy must deliver every reachable holder inside the horizon"
    )
    return RelayProgram(
        n_nodes=n_nodes,
        sinks=sink_s,
        slot_sends=tuple(slot_sends),
        delivered={k: frozenset(v) for k, v in delivered.items()},
        unreachable=frozenset(unreachable),
        residual={h: frozenset(v) for h, v in residual.items()},
    )


@dataclass(frozen=True)
class BroadcastProgram:
    """The downlink as a static per-slot directed-send plan.

    Flood from the sinks: ``slot_sends[t]`` holds ``(src, dst)`` where a
    covered ``src`` hands the model to an uncovered ``dst`` (one parent per
    receiver; a node covered during slot ``t`` first forwards in ``t+1``).
    ``covered`` is every node holding the model at horizon end (sinks
    included); satellites outside it keep their local params — the paper's
    skip-slot semantics on the downlink side.
    """

    n_nodes: int
    sinks: FrozenSet[int]
    slot_sends: Tuple[Tuple[DirectedEdge, ...], ...]
    covered: FrozenSet[int]
    receive_slot: Dict[int, int]

    @property
    def n_hops(self) -> int:
        return sum(len(s) for s in self.slot_sends)

    def last_used_slot(self) -> Optional[int]:
        used = [t for t, s in enumerate(self.slot_sends) if s]
        return max(used) if used else None


def build_broadcast_program(
    slots: Sequence[Relation],
    n_nodes: int,
    sinks: Iterable[int],
) -> BroadcastProgram:
    """Earliest-arrival flood of the global model from the sinks."""
    sink_s = frozenset(int(s) for s in sinks)
    if not sink_s:
        raise ValueError("need at least one sink node")
    have = set(sink_s)
    slot_sends: List[Tuple[DirectedEdge, ...]] = []
    receive_slot: Dict[int, int] = {}
    for t, rel in enumerate(slots):
        new: Dict[int, int] = {}
        for v in sorted(rel.participants()):
            if v in have:
                continue
            parents = [u for u in rel.peers_of(v) if u in have]
            if parents:
                new[v] = min(parents)
        for v, p in new.items():
            receive_slot[v] = t
        have |= set(new)
        slot_sends.append(tuple(sorted((p, v) for v, p in new.items())))
    return BroadcastProgram(
        n_nodes=n_nodes,
        sinks=sink_s,
        slot_sends=tuple(slot_sends),
        covered=frozenset(have),
        receive_slot=receive_slot,
    )


# ---------------------------------------------------------------------------
# ppermute-legal batching
# ---------------------------------------------------------------------------

def permutation_batches(
    edges: Sequence[DirectedEdge],
) -> List[Tuple[DirectedEdge, ...]]:
    """Split directed sends into ppermute-legal batches.

    ``jax.lax.ppermute`` requires unique sources AND unique destinations
    per call; a slot's send set can violate either (fan-in at a relay on
    the uplink, fan-out at a broadcaster on the downlink). First-fit in
    the given order keeps the result deterministic; the batch count is
    bounded by the max in/out multiplicity, which the antenna budget
    already bounded at schedule time."""
    batches: List[List[DirectedEdge]] = []
    srcs: List[set] = []
    dsts: List[set] = []
    for s, d in edges:
        for batch, bs, bd in zip(batches, srcs, dsts):
            if s not in bs and d not in bd:
                batch.append((s, d))
                bs.add(s)
                bd.add(d)
                break
        else:
            batches.append([(s, d)])
            srcs.append({s})
            dsts.append({d})
    return [tuple(b) for b in batches]


def program_batch_count(
    program: "RelayProgram | BroadcastProgram",
) -> int:
    """Total ppermute batches a program lowers to (per payload buffer) —
    the static count the HLO tests verify against compiled modules."""
    return sum(len(permutation_batches(s)) for s in program.slot_sends if s)


# ---------------------------------------------------------------------------
# Multi-window pipelined rounds with delay-tolerant payload persistence
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DroppedPayload:
    """A payload that aged past the staleness horizon and was discarded."""

    window: int     # window in which the drop happened
    source: int     # satellite whose snapshot it was
    age: int        # windows since the snapshot was taken (> horizon)


@dataclass(frozen=True)
class WindowProgram:
    """Everything one plan window executes, statically derived.

    ``uplink`` relays this window's payloads (fresh snapshots from
    ``injected`` plus carried-over stale ones) toward the sinks;
    ``downlink`` floods a global model back out — at pipeline depth 2 it is
    the PREVIOUS round's global (``lagged_downlink``) riding slot capacity
    the uplink left free, and it is ``None`` on the very first window (no
    global exists yet). ``ages[s]`` is payload ``s``'s age in windows at
    the start of this window (0 = snapshotted now); ``delivered_ages`` /
    ``residual`` split it by outcome, and ``dropped`` reports payloads that
    aged past the staleness horizon and were discarded this window.
    """

    window: int
    uplink: RelayProgram
    downlink: Optional[BroadcastProgram]
    lagged_downlink: bool
    injected: FrozenSet[int]
    ages: Dict[int, int]
    delivered_ages: Dict[int, int]
    residual: Dict[int, int]
    dropped: Dict[int, int]

    def max_delivered_age(self) -> int:
        return max(self.delivered_ages.values(), default=0)


def remaining_capacity(
    slots: Sequence[Relation], program: RelayProgram
) -> List[Relation]:
    """Each slot's relation minus the undirected edges the relay program
    occupies — the capacity a pipelined downlink may flood over. An ISL
    terminal busy relaying an uplink payload cannot simultaneously carry
    the broadcast, so disjointness is per-edge per-slot."""
    out: List[Relation] = []
    for rel, sends in zip(slots, program.slot_sends):
        used = {(min(s, d), max(s, d)) for s, d in sends}
        keep = [e for e in rel.edge_list() if e not in used]
        out.append(Relation.from_edges(keep, nodes=rel.nodes))
    return out


class MultiWindowRouter:
    """Plans ground-segment windows with payloads persisting across them.

    The delay-tolerant queue discipline (all static Python, so ground and
    space compute identical plans — the paper's assumption (a)):

    - every live satellite holds at most ONE pending payload: the snapshot
      of its params taken the first window it had nothing queued. While it
      is pending the satellite keeps training locally but does not enqueue
      a second snapshot (the next snapshot, taken after delivery, reflects
      all the training in between);
    - a pending payload ages one window per boundary. Because a payload
      only ever moves along a route that delivers it within the window
      (reachable holders ship everything; unreachable ones hold), an
      undelivered payload always sits at its own source — briefly
      unreachable satellites deliver as soon as geometry allows;
    - a payload whose age would exceed ``max_staleness_windows`` is dropped
      AND reported (``WindowProgram.dropped``, :attr:`dropped_log`), and
      its satellite snapshots fresh the same window;
    - at ``pipeline_depth=2`` round r's downlink flood overlaps round
      r+1's uplink relay inside one window, on disjoint slot capacity. The
      uplink plans first (training updates are the scarce resource; a
      satellite the downlink misses simply keeps its local params and
      catches the next flood — the skip-slot semantics already tolerate
      that), the broadcast floods over what remains.

    The DP policy depends only on ``(alive, slots)`` — not on which
    payloads are queued — so repeated windows over the same plan with the
    same alive set (the common steady-state case) reuse a cached policy
    instead of re-running the DP; per-source routes are rebuilt from it in
    O(sources·hops). Hits/misses land on the flight recorder as
    ``groundseg.router.table_cache.{hit,miss}``; the cache is a small
    bounded LRU (a long-running router must not grow without bound).
    """

    TABLE_CACHE_MAX = 8

    def __init__(
        self,
        n_nodes: int,
        sinks: Iterable[int],
        *,
        max_staleness_windows: int = 0,
        pipeline_depth: int = 1,
        dropped_log_max: int = 1024,
    ):
        self.n_nodes = int(n_nodes)
        self.sinks = frozenset(int(s) for s in sinks)
        if not self.sinks:
            raise ValueError("need at least one sink node")
        if max_staleness_windows < 0:
            raise ValueError(
                f"max_staleness_windows must be >= 0, got {max_staleness_windows}"
            )
        if pipeline_depth not in (1, 2):
            raise ValueError(
                "pipeline_depth must be 1 (sequential uplink->downlink) or 2 "
                f"(downlink of round r overlaps uplink of r+1), got {pipeline_depth}"
            )
        if dropped_log_max < 0:
            raise ValueError(
                f"dropped_log_max must be >= 0, got {dropped_log_max}"
            )
        self.max_staleness_windows = int(max_staleness_windows)
        self.pipeline_depth = int(pipeline_depth)
        self._pending: Dict[int, int] = {}   # source -> age of queued payload
        self._window = -1
        # dropped_log keeps the MOST RECENT dropped_log_max drop records (a
        # long-running router must not grow without bound); dropped_total
        # keeps the exact lifetime count regardless of trimming.
        self.dropped_log_max = int(dropped_log_max)
        self.dropped_log: List[DroppedPayload] = []
        self.dropped_total: int = 0
        # (alive, slots) -> (restricted rels, DP policy, f[.][0]); ordered
        # for LRU eviction at TABLE_CACHE_MAX entries
        self._table_cache: Dict[
            Tuple[FrozenSet[int], Tuple[Relation, ...]],
            Tuple[List[Relation], Tuple[Tuple[Optional[int], ...], ...], np.ndarray],
        ] = {}

    def reset_dropped_log(self) -> List[DroppedPayload]:
        """Drain the retained drop records (``dropped_total`` keeps the
        lifetime count). Returns the drained entries, oldest first."""
        out, self.dropped_log = self.dropped_log, []
        return out

    @property
    def window(self) -> int:
        """Index of the last planned window (-1 before the first)."""
        return self._window

    def pending(self) -> Dict[int, int]:
        """Snapshot of the queued payloads (source -> age)."""
        return dict(self._pending)

    def plan_window(
        self,
        slots: Sequence[Relation],
        alive: Optional[Iterable[int]] = None,
    ) -> WindowProgram:
        """Plan the next window over ``slots`` (restricted to ``alive``).

        ``alive`` is re-read per window — the per-window rerouting
        contract: dead satellites drop out of every slot relation, their
        queued payloads hold (and keep aging) until they revive or the
        staleness horizon discards them.
        """
        self._window += 1
        live = (
            set(int(v) for v in alive)
            if alive is not None
            else set(range(self.n_nodes))
        )
        live |= self.sinks
        rec = telemetry.get_recorder()
        cache_key = (frozenset(live), tuple(slots))
        cached = self._table_cache.get(cache_key)
        if cached is not None:
            rec.counter("groundseg.router.table_cache.hit")
            rels, dp_policy, dp_f0 = cached
            # refresh LRU position
            self._table_cache[cache_key] = self._table_cache.pop(cache_key)
        else:
            rec.counter("groundseg.router.table_cache.miss")
            rels = [r.restrict(live) for r in slots]
            dp_policy, dp_f0 = _dp_policy(rels, self.n_nodes, self.sinks)
            self._table_cache[cache_key] = (rels, dp_policy, dp_f0)
            while len(self._table_cache) > self.TABLE_CACHE_MAX:
                self._table_cache.pop(next(iter(self._table_cache)))
        metrics.ratio_gauge(
            "groundseg.router.table_cache.hit_rate",
            rec.get_counter("groundseg.router.table_cache.hit"),
            rec.get_counter("groundseg.router.table_cache.hit")
            + rec.get_counter("groundseg.router.table_cache.miss"),
            rec=rec,
        )

        dropped: Dict[int, int] = {}
        if self._window > 0:
            aged = {s: a + 1 for s, a in self._pending.items()}
            dropped = {
                s: a for s, a in aged.items() if a > self.max_staleness_windows
            }
            self._pending = {
                s: a for s, a in aged.items() if a <= self.max_staleness_windows
            }
            self.dropped_total += len(dropped)
            self.dropped_log.extend(
                DroppedPayload(window=self._window, source=s, age=a)
                for s, a in sorted(dropped.items())
            )
            if len(self.dropped_log) > self.dropped_log_max:
                del self.dropped_log[: len(self.dropped_log) - self.dropped_log_max]

        sat_ids = [v for v in range(self.n_nodes) if v not in self.sinks]
        injected = frozenset(
            v for v in sat_ids if v in live and v not in self._pending
        )
        ages = dict(self._pending)
        ages.update({v: 0 for v in injected})

        table = RoutingTable(
            n_nodes=self.n_nodes,
            n_slots=len(rels),
            sinks=self.sinks,
            routes=_routes_from_policy(
                dp_policy, dp_f0, sorted(ages), self.sinks
            ),
            policy=dp_policy,
        )
        uplink = build_relay_program(
            rels,
            self.n_nodes,
            self.sinks,
            table=table,
            initial_loads={v: {v} for v in sorted(ages)},
        )

        lagged = self.pipeline_depth == 2
        if lagged:
            downlink = (
                None
                if self._window == 0
                else build_broadcast_program(
                    remaining_capacity(rels, uplink), self.n_nodes, self.sinks
                )
            )
        else:
            downlink = build_broadcast_program(rels, self.n_nodes, self.sinks)

        delivered_ids = (
            set().union(*uplink.delivered.values())
            if uplink.delivered
            else set()
        )
        delivered_ages = {s: ages[s] for s in sorted(delivered_ids)}
        residual = {s: ages[s] for s in sorted(ages) if s not in delivered_ids}
        self._pending = dict(residual)
        # mission-control distributions (default-on host dict/bisect work):
        # how deep the routing queue runs per window and how stale payloads
        # are when they land / when they carry over.
        metrics.observe(
            "groundseg.router.queue_depth",
            len(ages),
            buckets=metrics.COUNT_BUCKETS,
            rec=rec,
        )
        metrics.observe(
            "groundseg.router.carried_depth",
            len(residual),
            buckets=metrics.COUNT_BUCKETS,
            rec=rec,
        )
        for age in delivered_ages.values():
            metrics.observe(
                "groundseg.router.payload_age",
                age,
                buckets=metrics.AGE_BUCKETS,
                rec=rec,
            )
        return WindowProgram(
            window=self._window,
            uplink=uplink,
            downlink=downlink,
            lagged_downlink=lagged,
            injected=injected,
            ages=ages,
            delivered_ages=delivered_ages,
            residual=residual,
            dropped=dropped,
        )
