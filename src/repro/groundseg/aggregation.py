"""Centralized / hierarchical FedAvg over the ground segment, as SPMD
collectives on the fused flat buffers.

The paper's *generic centralized FLA* (its first generic algorithm),
deployed the way real ISL constellations do it: satellites train locally,
their parameter payloads ride the store-and-forward relay programs of
:mod:`repro.groundseg.routing` to ground sinks over the TDM schedule, the
sinks FedAvg, and the global model floods back out on the downlink slots.

Everything here runs inside ``shard_map`` over the node axis (satellites
AND ground sinks are node groups, exactly like :mod:`repro.core.tdm`), and
every payload is a fused dtype-bucketed flat buffer from
:mod:`repro.core.fused` — so one relay slot costs one ``ppermute`` batch
per buffer (two for int8: payload + blockwise scales), never one per model
leaf. Key structural facts, all static Python:

- Uplink (:func:`relay_uplink`): per slot, senders ship their whole
  accumulated buffer and shed it; receivers add what lands. The sum over
  all nodes is invariant, so whatever reaches a sink is exactly
  ``sum_i params_i`` over the satellites routed to it. FedAvg weights are
  payload *counts*, which the routing program knows statically — no weight
  ever travels on an ISL.
- Aggregation (:func:`sink_fedavg`): each sink averages its delivered
  payloads together with its own held model (weight 1 — the previous
  global anchors rounds where few updates land). ``pool=True`` adds ONE
  masked ``psum`` per buffer to reconcile the sinks over their terrestrial
  backhaul (free in ISL terms): that is centralized FedAvg. ``pool=False``
  keeps per-sink regional models: the hierarchical mode, whose regions
  re-mix only on their sync cadence.
- Downlink (:func:`broadcast_downlink`): the flood program's receivers
  OVERWRITE their buffer from the ppermute; covered nodes then unflatten
  and adopt, uncovered satellites keep their locally-trained params (the
  paper's skip-slot semantics applied to the model broadcast).

int8 relaying is QUANTIZE-ONCE: every route performs exactly one
quantize/dequant pair end-to-end, however many hops it rides.

- Uplink: the nodes agree on shared blockwise scales (one ``pmax``
  all-reduce per bucket — the scales never travel on an ISL), each source
  encodes its payload once with the shared scales (Pallas
  ``quantize_scaled``), and relays accumulate IN THE INTEGER DOMAIN: the
  int16 partial sums ride the ppermutes (one permute per batch per bucket)
  and integer addition is exact, so no relay ever re-encodes and
  quantization error is independent of hop count. At the sink one fused
  dequant+accumulate pass folds ``scales · Σ q`` onto the fp32 channel
  (non-source models — the sink's own anchor — stay fp32 and never
  quantize).
- Downlink: the sink quantizes the global model once; the flood forwards
  the (payload, scales) pair VERBATIM (2 permutes per batch per bucket),
  so every covered satellite decodes the identical bits regardless of its
  depth in the flood tree.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fused
from repro.groundseg.routing import (
    BroadcastProgram,
    RelayProgram,
    permutation_batches,
)
from repro.kernels.tdm_compress import ref as q_ref
from repro.kernels.tdm_compress import tdm_compress as q_kernel

Buffers = Dict[str, jax.Array]

_COMPRESSIONS = ("none", "int8")


def _check_compression(compression: str) -> None:
    if compression not in _COMPRESSIONS:
        raise ValueError(
            f"groundseg relay compression must be one of {_COMPRESSIONS}, "
            f"got {compression!r} (topk/CHOCO is stateful per relation and "
            "does not fit a one-shot relay hop)"
        )


def _quantize(x32: jax.Array, block: int, impl: str):
    if impl == "ref":
        return q_ref.quantize_ref(x32, block=block)
    return q_kernel.quantize_fwd(
        x32, block=block, interpret=(impl == "pallas_interpret")
    )


def _quantize_scaled(x32: jax.Array, scales: jax.Array, block: int, impl: str):
    if impl == "ref":
        return q_ref.quantize_scaled_ref(x32, scales, block=block)
    return q_kernel.quantize_scaled_fwd(
        x32, scales, block=block, interpret=(impl == "pallas_interpret")
    )


def _dequant_acc(q, s, acc, w, block: int, impl: str):
    if impl == "ref":
        return q_ref.dequant_acc_ref(q, s, acc, w, block=block)
    return q_kernel.dequant_accumulate_fwd(
        q, s, acc, w, block=block, interpret=(impl == "pallas_interpret")
    )


def _ppermute(x: jax.Array, perm: Sequence[Tuple[int, int]], axis_name: str):
    return jax.lax.ppermute(x, axis_name, list(perm))


def _mask(ids, n: int) -> np.ndarray:
    m = np.zeros((n,), dtype=bool)
    m[list(ids)] = True
    return m


def relay_uplink(
    buffers: Buffers,
    program: RelayProgram,
    axis_name: str,
    *,
    compression: str = "none",
    block: int = fused.DEFAULT_BLOCK,
    quant_impl: str = "auto",
) -> Buffers:
    """Execute the uplink relay program on fused buffers.

    Per slot: every scheduled sender ships its whole accumulated buffer
    (one ppermute batch per buffer) and sheds it; arrivals — including
    arrivals AT a sender, which stay for its next scheduled hop —
    accumulate. Nodes outside the program are untouched.

    int8 is the quantize-once path: shared blockwise scales are agreed via
    ONE ``pmax`` all-reduce per bucket, every node that ever sends encodes
    its payload once with them, and the relay accumulates int16 partial
    sums on the wire (integer adds are exact; ``|Σq| ≤ 127 × sources``
    fits int16 comfortably). A single fused dequant+accumulate pass at the
    end folds the integer channel onto the fp32 channel holding the
    never-sent models (sink anchors), so a payload's quantization error is
    the single-encode error no matter how many hops it rode. One permute
    per batch per bucket — scales never travel.
    """
    _check_compression(compression)
    n = program.n_nodes
    idx = jax.lax.axis_index(axis_name)
    out = dict(buffers)
    sources = sorted({s for sends in program.slot_sends for s, _ in sends})
    if compression == "int8" and sources:
        impl = fused._resolve_impl(quant_impl)
        ever_src = jnp.asarray(_mask(sources, n))[idx]
        for bucket, buf in out.items():
            x32 = buf.astype(jnp.float32)
            s_shared = jax.lax.pmax(
                q_ref.blockwise_scales_ref(x32, block=block), axis_name
            )
            q = _quantize_scaled(x32, s_shared, block, impl)
            z = jnp.where(ever_src, q, 0).astype(jnp.int16)
            f = jnp.where(ever_src, 0.0, x32)
            for sends in program.slot_sends:
                if not sends:
                    continue
                is_sender = jnp.asarray(_mask([s for s, _ in sends], n))[idx]
                z_pre = z
                z = jnp.where(is_sender, jnp.int16(0), z)
                for batch in permutation_batches(sends):
                    z = z + _ppermute(z_pre, batch, axis_name)
            out[bucket] = _dequant_acc(
                z, s_shared, f, jnp.float32(1.0), block, impl
            ).astype(buf.dtype)
        return out
    for sends in program.slot_sends:
        if not sends:
            continue
        is_sender = jnp.asarray(_mask([s for s, _ in sends], n))[idx]
        batches = permutation_batches(sends)
        for bucket, buf in out.items():
            acc = jnp.where(is_sender, jnp.zeros_like(buf), buf)
            for batch in batches:
                acc = acc + _ppermute(buf, batch, axis_name)
            out[bucket] = acc
    return out


def sink_weights(program: RelayProgram) -> np.ndarray:
    """Static FedAvg denominators: per node, the number of payloads its
    post-uplink buffer sums (delivered satellites + the sink's own model
    for sinks; 0 elsewhere — non-sinks never divide)."""
    w = np.zeros((program.n_nodes,), dtype=np.float32)
    for k, srcs in program.delivered.items():
        w[k] = 1.0 + len(srcs)
    return w


def staleness_sink_weights(
    program: RelayProgram,
    delivered_ages: Dict[int, int],
    decay: float,
) -> np.ndarray:
    """Per-sink FedAvg denominators with per-satellite staleness weighting.

    A payload delivered at age ``a`` (windows since its snapshot) carries
    weight ``decay ** a``: the carry channel multiplies a queued buffer by
    ``decay`` once per window boundary, so by delivery the payload VALUE is
    scaled ``decay ** a`` and this denominator matches it exactly. At age 0
    (or ``decay == 1``) every weight is 1.0 and this reduces bit-for-bit to
    :func:`sink_weights` — exact FedAvg."""
    w = np.zeros((program.n_nodes,), dtype=np.float32)
    for k, srcs in program.delivered.items():
        total = np.float32(1.0)
        for s in sorted(srcs):
            # repeated f32 multiply, mirroring the per-window buffer scaling
            ws = np.float32(1.0)
            for _ in range(int(delivered_ages.get(s, 0))):
                ws = np.float32(ws * np.float32(decay))
            total = np.float32(total + ws)
        w[k] = total
    return w


def sink_fedavg(
    buffers: Buffers,
    program: RelayProgram,
    axis_name: str,
    *,
    pool: bool,
    weights: Optional[np.ndarray] = None,
) -> Buffers:
    """FedAvg at the sinks: regional mean of (own model + delivered sums).

    ``pool=True`` reconciles the sinks over terrestrial backhaul — one
    masked ``psum`` per buffer pools the raw weighted sums so every sink
    holds the identical global FedAvg (centralized mode / the hierarchical
    sync round). ``pool=False`` leaves per-sink regional models. Satellite
    buffers pass through untouched (the psum is computed everywhere, as
    SPMD requires, but masked out of non-sink lanes).

    ``weights`` overrides the static per-node denominators (default:
    payload counts via :func:`sink_weights`; the pipelined engine passes
    :func:`staleness_sink_weights`)."""
    n = program.n_nodes
    idx = jax.lax.axis_index(axis_name)
    w = sink_weights(program) if weights is None else np.asarray(weights)
    is_sink = jnp.asarray(_mask(program.sinks, n))[idx]
    total_w = float(w.sum())
    my_w = jnp.asarray(np.maximum(w, 1.0))[idx]
    out = {}
    for bucket, buf in buffers.items():
        f32 = buf.astype(jnp.float32)
        if pool:
            pooled = jax.lax.psum(
                jnp.where(is_sink, f32, jnp.zeros_like(f32)), axis_name
            )
            avg = pooled / max(total_w, 1.0)
        else:
            avg = f32 / my_w
        out[bucket] = jnp.where(is_sink, avg, f32).astype(buf.dtype)
    return out


def broadcast_downlink(
    buffers: Buffers,
    program: BroadcastProgram,
    axis_name: str,
    *,
    compression: str = "none",
    block: int = fused.DEFAULT_BLOCK,
    quant_impl: str = "auto",
) -> Buffers:
    """Execute the downlink flood on fused buffers: each receiver adopts
    its (single) parent's buffer the slot it is first covered.

    int8 is quantize-once: each node encodes its own buffer ONCE up front
    (only the flood roots' encodings matter — everyone else's channel is
    overwritten before it first sends), and the flood forwards the
    (payload, scales) pair VERBATIM — a covered receiver both adopts the
    decoded model and relays the original bits, so every satellite on a
    route decodes the identical single-quantization payload. 2 permutes
    per batch per bucket, one quantize at the root and one dequant per
    receiver, independent of hop count.
    """
    _check_compression(compression)
    impl = fused._resolve_impl(quant_impl) if compression == "int8" else None
    n = program.n_nodes
    idx = jax.lax.axis_index(axis_name)
    out = dict(buffers)
    receivers = sorted({d for sends in program.slot_sends for _, d in sends})
    for bucket, buf in out.items():
        if compression == "int8":
            if not receivers:
                continue
            x32 = buf.astype(jnp.float32)
            q, s = _quantize(x32, block, impl)
            for sends in program.slot_sends:
                if not sends:
                    continue
                for batch in permutation_batches(sends):
                    got = jnp.asarray(_mask([d for _, d in batch], n))[idx]
                    q_r = _ppermute(q, batch, axis_name)
                    s_r = _ppermute(s, batch, axis_name)
                    q = jnp.where(got, q_r, q)
                    s = jnp.where(got, s_r, s)
            dec = _dequant_acc(
                q, s, jnp.zeros_like(x32), jnp.float32(1.0), block, impl
            )
            covered = jnp.asarray(_mask(receivers, n))[idx]
            out[bucket] = jnp.where(covered, dec.astype(buf.dtype), buf)
        else:
            for sends in program.slot_sends:
                if not sends:
                    continue
                for batch in permutation_batches(sends):
                    got = jnp.asarray(_mask([d for _, d in batch], n))[idx]
                    recv = _ppermute(buf, batch, axis_name)
                    buf = jnp.where(got, recv, buf)
            out[bucket] = buf
    return out


def expected_collectives(
    uplink: RelayProgram,
    downlink: Optional[BroadcastProgram],
    n_buckets: int,
    *,
    compression: str = "none",
    pool: bool = True,
) -> Dict[str, int]:
    """Static collective counts one ground-segment round lowers to — the
    oracle the HLO tests compare compiled modules against.

    Uncompressed: one permute per ppermute batch per buffer. int8
    (quantize-once): the uplink ships int16 partial sums — ONE permute per
    batch per buffer (scales never travel, they are agreed via one ``pmax``
    all-reduce per buffer) — while the downlink floods (payload, scales)
    verbatim at two permutes per batch per buffer. Pooling sinks adds one
    masked psum per buffer. ``downlink=None`` (the first window of a
    depth-2 pipeline — no global model to flood yet) contributes nothing;
    the carry/staleness channel is local arithmetic and never adds a
    collective."""
    from repro.groundseg.routing import program_batch_count

    up_batches = program_batch_count(uplink)
    down_batches = (
        program_batch_count(downlink) if downlink is not None else 0
    )
    uplink_sends = any(uplink.slot_sends)
    if compression == "int8":
        permutes = (up_batches + 2 * down_batches) * n_buckets
        all_reduce = (n_buckets if uplink_sends else 0) + (
            n_buckets if pool else 0
        )
    else:
        permutes = (up_batches + down_batches) * n_buckets
        all_reduce = n_buckets if pool else 0
    return {
        "collective-permute": permutes,
        "all-reduce": all_reduce,
    }


def expected_window_collectives(
    wp,
    n_buckets: int,
    *,
    compression: str = "none",
    pool: bool = True,
) -> Dict[str, int]:
    """:func:`expected_collectives` for a
    :class:`~repro.groundseg.routing.WindowProgram` — the extended static
    oracle for pipelined, delay-tolerant windows."""
    return expected_collectives(
        wp.uplink, wp.downlink, n_buckets, compression=compression, pool=pool
    )


def groundseg_round(
    params,
    uplink: RelayProgram,
    downlink: BroadcastProgram,
    axis_name: str,
    *,
    pool: bool,
    compression: str = "none",
    block: int = fused.DEFAULT_BLOCK,
    quant_impl: str = "auto",
):
    """One full ground-segment exchange for a parameter pytree: flatten ->
    uplink relay -> sink FedAvg (optionally pooled) -> downlink broadcast
    -> unflatten, adopting the broadcast model only where it arrived.

    Returns the mixed pytree. Satellites outside ``downlink.covered`` keep
    their input params bit-for-bit (their local training continues; later
    windows re-sync them), as do unreachable satellites' contributions on
    the uplink side."""
    spec = fused.cached_spec(params, block=block)
    buffers = fused.flatten_pytree(spec, params)
    buffers = relay_uplink(
        buffers, uplink, axis_name,
        compression=compression, block=block, quant_impl=quant_impl,
    )
    buffers = sink_fedavg(buffers, uplink, axis_name, pool=pool)
    buffers = broadcast_downlink(
        buffers, downlink, axis_name,
        compression=compression, block=block, quant_impl=quant_impl,
    )
    mixed = fused.unflatten_pytree(spec, buffers)
    n = uplink.n_nodes
    idx = jax.lax.axis_index(axis_name)
    adopt = jnp.asarray(_mask(downlink.covered, n))[idx]
    return jax.tree.map(
        lambda new, old: jnp.where(adopt, new, old), mixed, params
    )


# ---------------------------------------------------------------------------
# Pipelined multi-window rounds with a delay-tolerant carry channel
# ---------------------------------------------------------------------------

def stacked_zero_buffers(spec, n_nodes: int) -> Buffers:
    """Driver-side initial state for the carry / pending-global channels:
    one zeroed fused buffer per dtype bucket, stacked over the node axis."""
    return {
        b: jnp.zeros((n_nodes, spec.padded_size(b)), dtype=jnp.dtype(b))
        for b in spec.buckets
    }


def pipelined_window_round(
    params,
    carry: Buffers,
    pending: Buffers,
    wp,
    axis_name: str,
    *,
    pool: bool,
    staleness_decay: float = 1.0,
    compression: str = "none",
    block: int = fused.DEFAULT_BLOCK,
    quant_impl: str = "auto",
):
    """One pipelined, delay-tolerant ground-segment window on fused buffers.

    ``wp`` is a :class:`~repro.groundseg.routing.WindowProgram`; ``carry``
    holds each satellite's still-queued payload buffer (zeros where none),
    ``pending`` the previous round's global model at the sink lanes (used
    only when ``wp.lagged_downlink``). Steps:

    1. payload assembly — injecting satellites snapshot their params;
       carriers re-offer their queued buffer scaled by ``staleness_decay``
       (one multiply per window boundary, so a payload delivered at age
       ``a`` arrives scaled ``decay**a``, matching
       :func:`staleness_sink_weights` exactly); sinks offer their own model
       as the FedAvg anchor, like the one-shot path;
    2. uplink relay + staleness-weighted sink FedAvg (pooled per ``pool``);
    3. the new residual carry is read off the post-relay buffers (an
       undelivered payload never moves, so it sits at its source's lane);
       dropped payloads simply have no residual mask — their lanes zero;
    4. downlink — at depth 1 the just-computed global floods (identical to
       :func:`groundseg_round`, bit-for-bit when nothing is carried); at
       depth 2 the PREVIOUS round's global (``pending``) floods on the slot
       capacity the uplink left free, and the new global becomes next
       window's pending. Sinks always adopt the new global as their anchor.

    Returns ``(mixed_params, new_carry, new_pending)``.
    """
    _check_compression(compression)
    spec = fused.cached_spec(params, block=block)
    pbuf = fused.flatten_pytree(spec, params)
    n = wp.uplink.n_nodes
    idx = jax.lax.axis_index(axis_name)

    carriers = sorted(s for s, a in wp.ages.items() if a > 0)
    if carriers:
        offer = jnp.asarray(_mask(carriers, n))[idx]
        decay = jnp.float32(staleness_decay)
        payload = {
            b: jnp.where(
                offer,
                (carry[b].astype(jnp.float32) * decay).astype(buf.dtype),
                buf,
            )
            for b, buf in pbuf.items()
        }
    else:
        payload = pbuf

    post = relay_uplink(
        payload, wp.uplink, axis_name,
        compression=compression, block=block, quant_impl=quant_impl,
    )
    weights = staleness_sink_weights(
        wp.uplink, wp.delivered_ages, staleness_decay
    )
    agg = sink_fedavg(post, wp.uplink, axis_name, pool=pool, weights=weights)

    holds = jnp.asarray(_mask(sorted(wp.residual), n))[idx]
    new_carry = {
        b: jnp.where(holds, buf, jnp.zeros_like(buf))
        for b, buf in post.items()
    }

    is_sink = jnp.asarray(_mask(wp.uplink.sinks, n))[idx]
    new_pending = {
        b: jnp.where(is_sink, buf, jnp.zeros_like(buf))
        for b, buf in agg.items()
    }

    if wp.downlink is None:
        # first window of a depth-2 pipeline: nothing to flood yet — sinks
        # still adopt the new global as their anchor, satellites keep their
        # locally-trained params
        final = {
            b: jnp.where(is_sink, agg[b], pbuf[b]) for b in pbuf
        }
        adopt = is_sink
    else:
        chan = (
            {b: jnp.where(is_sink, pending[b], agg[b]) for b in agg}
            if wp.lagged_downlink
            else agg
        )
        out = broadcast_downlink(
            chan, wp.downlink, axis_name,
            compression=compression, block=block, quant_impl=quant_impl,
        )
        final = (
            {b: jnp.where(is_sink, agg[b], out[b]) for b in out}
            if wp.lagged_downlink
            else out
        )
        adopt = jnp.asarray(_mask(wp.downlink.covered | wp.uplink.sinks, n))[idx]
    mixed = fused.unflatten_pytree(spec, final)
    new_params = jax.tree.map(
        lambda new, old: jnp.where(adopt, new, old), mixed, params
    )
    return new_params, new_carry, new_pending
