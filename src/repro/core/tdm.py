"""TPU-native universal TDM communication: the paper's getMeas/get1meas as
JAX collectives.

Adaptation (DESIGN.md §3): a per-slot exchange relation R is edge-colored
into matchings (Misra–Gries, ≤ Δ+1); each matching is a permutation of the
node axis and lowers to ONE ``jax.lax.ppermute``. The paper's two primitives
then differ only in scheduling:

- ``get_meas``  — all matchings issued in one slot, as independent ops; XLA
  overlaps the collective-permutes across distinct ICI links. This is the
  multi-antenna satellite: k peers ⇒ k simultaneous links.
- ``get1_meas`` — one matching per slot with an explicit data-dependency
  chain (``optimization_barrier``) so transfers serialize. This is the
  single-antenna satellite, i.e. the original PTB-FLA primitive.

The paper's `timeSlotsMap` reorder buffer has no TPU counterpart because XLA
delivers collectives deterministically; its *purpose* (letting fast peers
run ahead) is served by XLA's async collective start/done scheduling.

All functions here are designed to run inside ``shard_map`` over the node
axis (the mesh's ``data`` axis; satellites = data-parallel node groups), and
are tested for bit-equivalence against the paper-faithful simulator
(:mod:`repro.core.ptbfla_sim`).
"""

from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as compress_lib
from repro.core.gossip import metropolis_weights
from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule, edge_coloring


# ---------------------------------------------------------------------------
# Static (Python-side) schedule preprocessing
# ---------------------------------------------------------------------------

def matching_permutation(matching: Relation) -> List[Tuple[int, int]]:
    """ppermute `perm` pairs for one matching: every pair (i, j) ∈ M means
    "i sends to j"; M symmetric ⇒ both directions present ⇒ a permutation
    restricted to participants (non-participants send/receive nothing and
    ppermute fills their output with zeros)."""
    return sorted(matching.pairs)


def peer_slot_table(rel: Relation, n: int) -> Tuple[np.ndarray, List[Relation]]:
    """Static map from (node, peer-position) -> matching color.

    ``table[i, p]`` = index of the matching that carries the exchange between
    node i and its p-th peer (peers in ``rel.peers_of(i)`` order, the paper's
    `peer_ids` list), or -1 past the node's degree.
    """
    matchings = edge_coloring(rel)
    max_deg = rel.max_degree()
    table = -np.ones((n, max(max_deg, 1)), dtype=np.int32)
    for i in range(n):
        for p, j in enumerate(rel.peers_of(i)):
            for c, m in enumerate(matchings):
                if (i, j) in m:
                    table[i, p] = c
                    break
            assert table[i, p] >= 0, f"edge ({i},{j}) missing from coloring"
    return table, matchings


# ---------------------------------------------------------------------------
# Collective primitives (call inside shard_map over `axis_name`)
# ---------------------------------------------------------------------------

def exchange_matching(x: jax.Array, matching: Relation, axis_name: str) -> jax.Array:
    """One pairwise exchange round: ppermute along the node axis."""
    perm = matching_permutation(matching)
    if not perm:
        return jnp.zeros_like(x)
    return jax.lax.ppermute(x, axis_name, perm)


def get_meas(
    x: jax.Array,
    rel: Relation,
    axis_name: str,
    n: int,
    participate: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Universal TDM exchange (paper Algorithm 1), multi-link.

    Every node sends ``x`` to all its peers in relation ``rel`` and receives
    each peer's ``x``. Returns ``(peer_data, peer_mask)``:

    - ``peer_data``: (max_deg, *x.shape) — entry p is the data received from
      this node's p-th peer (in ``rel.peers_of`` order = the paper's
      `peer_ids`), zeros where the node has fewer peers.
    - ``peer_mask``: (max_deg,) bool — valid entries.

    ``participate`` (scalar bool per node) implements the paper's
    `odata=None` skip: a skipping node sends zeros and its peers mask it out
    — the static-schedule analogue of assumption (b). For full fidelity the
    *schedule* should drop the node (``Relation.restrict``); this dynamic
    flag covers in-flight stragglers.
    """
    if participate is not None:
        x = jnp.where(participate, x, jnp.zeros_like(x))
    table, matchings = peer_slot_table(rel, n)
    max_deg = rel.max_degree()
    if max_deg == 0:
        z = jnp.zeros((1,) + x.shape, x.dtype)
        return z, jnp.zeros((1,), dtype=bool)
    # One ppermute per matching; independent ops => XLA overlaps them
    # (multi-antenna simultaneous links).
    received = jnp.stack(
        [exchange_matching(x, m, axis_name) for m in matchings]
    )  # (n_matchings, *x.shape)
    idx = jax.lax.axis_index(axis_name)
    my_slots = jnp.asarray(table)[idx]            # (max_deg,) int32
    mask = my_slots >= 0
    safe = jnp.maximum(my_slots, 0)
    peer_data = received[safe]                    # (max_deg, *x.shape)
    peer_data = jnp.where(
        mask.reshape((-1,) + (1,) * x.ndim), peer_data, jnp.zeros_like(peer_data)
    )
    return peer_data, mask


def get1_meas(
    x: jax.Array,
    rel: Relation,
    axis_name: str,
    n: int,
) -> Tuple[jax.Array, jax.Array]:
    """Original pairwise TDM primitive: same exchanges as ``get_meas`` but
    matchings are SERIALIZED (single antenna — one link at a time). The
    explicit dependency chain prevents XLA from overlapping the permutes,
    which is exactly the hardware constraint being modeled."""
    table, matchings = peer_slot_table(rel, n)
    max_deg = rel.max_degree()
    if max_deg == 0:
        z = jnp.zeros((1,) + x.shape, x.dtype)
        return z, jnp.zeros((1,), dtype=bool)
    received = []
    carry = x
    for m in matchings:
        carry = jax.lax.optimization_barrier(carry)
        got = exchange_matching(carry, m, axis_name)
        received.append(got)
        # chain: next slot's send depends on this slot's receive
        carry = jax.lax.optimization_barrier(x + 0 * got.astype(x.dtype))
    received = jnp.stack(received)
    idx = jax.lax.axis_index(axis_name)
    my_slots = jnp.asarray(table)[idx]
    mask = my_slots >= 0
    safe = jnp.maximum(my_slots, 0)
    peer_data = received[safe]
    peer_data = jnp.where(
        mask.reshape((-1,) + (1,) * x.ndim), peer_data, jnp.zeros_like(peer_data)
    )
    return peer_data, mask


def neighbor_sum(x: jax.Array, rel: Relation, axis_name: str) -> jax.Array:
    """Σ_{j ∈ N(i)} x_j — the reduction most FL updates need. Cheaper than
    ``get_meas`` (no stacking): one ppermute per matching, summed."""
    matchings = edge_coloring(rel)
    out = jnp.zeros_like(x)
    for m in matchings:
        out = out + exchange_matching(x, m, axis_name)
    return out


def gossip_avg(
    x: jax.Array,
    rel: Relation,
    axis_name: str,
    n: int,
) -> jax.Array:
    """One Metropolis gossip step x_i ← W_ii x_i + Σ_j W_ij x_j over R.

    Per-edge weights vary (they depend on both endpoint degrees), so each
    matching carries its own per-node weight vector (static constants).
    """
    diag, per_matching = matching_weight_vectors(rel, n)
    idx = jax.lax.axis_index(axis_name)
    out = jnp.asarray(diag, dtype=x.dtype)[idx] * x
    for m, w_m in zip(edge_coloring(rel), per_matching):
        recv = exchange_matching(x, m, axis_name)
        out = out + jnp.asarray(w_m, dtype=x.dtype)[idx] * recv
    return out


def gossip_avg_serial(
    x: jax.Array,
    rel: Relation,
    axis_name: str,
    n: int,
) -> jax.Array:
    """Metropolis gossip step via the SERIALIZED primitive (``get1_meas``):
    same algebra as :func:`gossip_avg`, but the matchings chain one after
    another (single-antenna satellite). Shared by the per-leaf and fused
    exchange paths so both are bit-identical by construction."""
    if len(rel) == 0:
        return x
    W = metropolis_weights(rel, n)
    idx = jax.lax.axis_index(axis_name)
    self_w = jnp.asarray(np.diag(W), dtype=x.dtype)[idx]
    out = self_w * x
    peer_data, mask = get1_meas(x, rel, axis_name, n)
    # weight received values: receiver i applies W[i, peer_p] to its p-th peer
    max_deg = rel.max_degree()
    wmat = np.zeros((n, max_deg))
    for i in range(n):
        for p, j in enumerate(rel.peers_of(i)):
            wmat[i, p] = W[i, j]
    w_row = jnp.asarray(wmat, dtype=x.dtype)[idx]  # (max_deg,)
    return out + jnp.sum(
        w_row.reshape((-1,) + (1,) * x.ndim) * peer_data.astype(x.dtype), axis=0
    )


def matching_weight_vectors(rel: Relation, n: int) -> Tuple[np.ndarray, List[np.ndarray]]:
    """Static Metropolis weight vectors per matching: returns
    ``(diag, [w_m, ...])`` where ``diag[i]`` is node i's self weight and
    ``w_m[i]`` the weight node i applies to the value received via matching
    m (zero when i does not participate in m). Matchings are in
    :func:`edge_coloring` order — the contract shared by every gossip path."""
    W = metropolis_weights(rel, n)
    vecs = []
    for m in edge_coloring(rel):
        w_m = np.zeros((n,))
        for (i, j) in m.pairs:
            w_m[i] = W[i, j]
        vecs.append(w_m)
    return np.diag(W).copy(), vecs


def gossip_avg_tree(params, rel: Relation, axis_name: str, n: int):
    """gossip_avg over every leaf of a pytree (model params / grads)."""
    return jax.tree.map(lambda p: gossip_avg(p, rel, axis_name, n), params)


# ---------------------------------------------------------------------------
# Compressed exchange (beyond-paper: ISL bandwidth saver)
# ---------------------------------------------------------------------------

def neighbor_sum_int8(x: jax.Array, rel: Relation, axis_name: str) -> jax.Array:
    """neighbor_sum with int8-quantized payloads: 4× less ICI traffic at
    <1% relative error (see tests). Scales travel alongside as fp32."""
    payload = compress_lib.int8_compress(x)
    matchings = edge_coloring(rel)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for m in matchings:
        q = exchange_matching(payload.q, m, axis_name)
        s = exchange_matching(payload.scale[None], m, axis_name)[0]
        out = out + q.astype(jnp.float32) * s
    return out.astype(x.dtype)


def neighbor_sum_topk(
    x: jax.Array, residual: jax.Array, rel: Relation, axis_name: str, k: int
) -> Tuple[jax.Array, jax.Array]:
    """neighbor_sum with top-k sparsified payloads + error feedback.

    Correct usage: ``x`` must be an additive DELTA (gradient, model update) —
    error feedback preserves convergence for accumulated deltas (Stich et
    al. 2018), NOT for absolute-value gossip (use :func:`choco_gossip_round`
    for that). Returns (sum of decompressed neighbor payloads, new
    residual). Traffic per edge: 8k bytes instead of 4·numel.
    """
    payload, new_residual = compress_lib.topk_with_error_feedback(x, residual, k)
    matchings = edge_coloring(rel)
    out = jnp.zeros(x.size, dtype=jnp.float32)
    for m in matchings:
        vals = exchange_matching(payload.values, m, axis_name)
        idxs = exchange_matching(payload.indices, m, axis_name)
        got_any = exchange_matching(jnp.ones((), jnp.float32), m, axis_name)
        contrib = jnp.zeros(x.size, dtype=jnp.float32).at[idxs].add(
            vals.astype(jnp.float32)
        )
        out = out + got_any * contrib
    return out.reshape(x.shape).astype(x.dtype), new_residual


class ChocoState(NamedTuple):
    """Per-node CHOCO-Gossip state for one tensor.

    x_hat — this node's *public* copy (what peers believe it holds);
    s     — running Σ_j W_ij x̂_j over the FIXED relation (maintained
            incrementally from the received compressed updates, so no
            per-neighbor buffers are needed).
    """

    x_hat: jax.Array
    s: jax.Array


def choco_init(x: jax.Array) -> ChocoState:
    return ChocoState(x_hat=jnp.zeros_like(x), s=jnp.zeros_like(x))


def choco_gossip_round(
    x: jax.Array,
    state: ChocoState,
    rel: Relation,
    axis_name: str,
    n: int,
    k: int,
    gamma: float = 0.4,
) -> Tuple[jax.Array, ChocoState]:
    """One CHOCO-Gossip round (Koloskova et al., ICML 2019) over relation R
    with top-k compression — converging consensus under compressed exchange
    of *absolute values* (which naive error feedback does not give):

        q_i   = top_k(x_i - x̂_i)            (compressed public update)
        x̂_i  += q_i ;  s_i += Σ_j W_ij q_j   (incremental public copies)
        x_i  += γ (s_i - d_i x̂_i)            where d_i = Σ_j W_ij

    Requires the SAME relation every round (the incremental ``s`` is tied to
    W); time-varying schedules should use int8 (stateless) compression.
    """
    W = metropolis_weights(rel, n)
    idx = jax.lax.axis_index(axis_name)
    payload = compress_lib.topk_compress(x - state.x_hat, k)
    q_dense = compress_lib.topk_decompress(payload, x.shape, x.dtype)
    new_x_hat = state.x_hat + q_dense
    _, per_matching = matching_weight_vectors(rel, n)
    s = state.s
    for m, w_m in zip(edge_coloring(rel), per_matching):
        vals = exchange_matching(payload.values, m, axis_name)
        idxs = exchange_matching(payload.indices, m, axis_name)
        contrib = (
            jnp.zeros(x.size, dtype=jnp.float32)
            .at[idxs]
            .add(vals.astype(jnp.float32))
            .reshape(x.shape)
        )
        # weight by W[i, peer-under-matching-m]
        s = s + jnp.asarray(w_m, x.dtype)[idx] * contrib.astype(x.dtype)
    deg_w = np.zeros((n,), dtype=np.float32)
    for i in range(n):
        deg_w[i] = sum(W[i, j] for j in rel.peers_of(i))
    d_i = jnp.asarray(deg_w, x.dtype)[idx]
    new_x = x + gamma * (s - d_i * new_x_hat)
    return new_x, ChocoState(x_hat=new_x_hat, s=s)


# ---------------------------------------------------------------------------
# Whole-schedule execution + hierarchical (multi-pod) TDM
# ---------------------------------------------------------------------------

def run_gossip_schedule(
    x: jax.Array, schedule: TDMSchedule, axis_name: str, n: int
) -> jax.Array:
    """Apply one gossip step per slot, in slot order (paper P2: the composed
    relation's propagation; associativity lets XLA pipeline across slots)."""
    for rel in schedule:
        if len(rel) == 0:
            continue
        x = gossip_avg(x, rel, axis_name, n)
    return x


def hierarchical_gossip(
    x: jax.Array,
    intra_rel: Relation,
    inter_rel: Relation,
    data_axis: str,
    pod_axis: str,
    n_data: int,
    n_pods: int,
) -> jax.Array:
    """Multi-pod TDM: gossip within each pod over `data_axis` (dense ICI),
    then between pods over `pod_axis` (sparse DCI/optical — the actual
    inter-satellite links in the constellation analogy)."""
    x = gossip_avg(x, intra_rel, data_axis, n_data)
    if len(inter_rel) > 0:
        x = gossip_avg(x, inter_rel, pod_axis, n_pods)
    return x
