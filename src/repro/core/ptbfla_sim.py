"""Paper-faithful PTB-FLA simulator: Algorithm 1 (`getMeas`) line-for-line.

This is the reproduction FLOOR: the paper's generic algorithm exactly as
published (§III.B, Algorithm 1), including the `timeSlotsMap` reorder buffer
for messages from *faster peers* in future slots, the skip-slot semantics
(`odata=None`), and the original pairwise `get1meas` primitive it
generalizes.

The paper runs one OS process per node over TCP. Here nodes are simulated
processes driven by a deterministic discrete-event scheduler with FIFO
channels and *adversarially chosen* interleavings (seeded), so tests can
exercise exactly the out-of-order situations the `timeSlotsMap` exists for —
a fast peer racing ahead and sending its slot-(t+1) message before this node
finished slot t.

The JAX collective implementation (:mod:`repro.core.tdm`) is property-tested
for equivalence against this oracle.
"""

from __future__ import annotations

import random
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.schedule import TDMSchedule


class _Recv:
    """Sentinel yielded by a node coroutine when it blocks on rcvMsg()."""


@dataclass
class _Node:
    """One PTB-FLA application instance (paper: node n_i running a_i, t_i)."""

    node_id: int
    # PTB-FLA instance data (paper Algorithm 1, line 01)
    time_slot: int = 0
    time_slots_map: Dict[Tuple[int, int], list] = field(default_factory=dict)
    inbox: Deque[list] = field(default_factory=deque)

    # stats for the evaluation section
    n_sent: int = 0
    n_received: int = 0
    n_buffered: int = 0  # messages that went through timeSlotsMap


class PTBFLASimulator:
    """Deterministic discrete-event testbed running the paper's algorithms.

    ``programs[i]`` is a generator function taking (node, api) and yielding
    at every blocking receive; the scheduler interleaves ready nodes in a
    seeded random order, modelling nodes running at different speeds.
    """

    def __init__(self, n_nodes: int, seed: int = 0):
        self.nodes = [_Node(i) for i in range(n_nodes)]
        self.rng = random.Random(seed)
        self.total_messages = 0

    # -------------------------------------------------------- message layer
    def send_msg(self, src: int, dst: int, msg: list) -> None:
        """sendMsg(peerId, [timeSlot, nodeId, odata]) — FIFO per channel."""
        self.nodes[dst].inbox.append(list(msg))
        self.nodes[src].n_sent += 1
        self.total_messages += 1

    # -------------------------------------------------------- Algorithm 1
    def get_meas(self, node: _Node, peer_ids: Sequence[int], odata: Any):
        """The paper's getMeas, as a coroutine (yields while blocked on recv).

        Transcribed from Algorithm 1; line numbers in comments refer to the
        paper's listing.
        """
        # 03-06: odata None => skip this time slot
        if odata is None:
            node.time_slot += 1              # 05
            return None                      # 06 (generator: raise StopIteration w/ None)

        # 07-09: send own odata to the peers
        for peer_id in peer_ids:             # 08
            self.send_msg(node.node_id, peer_id, [node.time_slot, node.node_id, odata])  # 09

        # 10-26: then receive peers' odata
        peer_odatas: List[Any] = []          # 10
        for peer_id in peer_ids:             # 11
            if (node.time_slot, peer_id) in node.time_slots_map:       # 12
                msg = node.time_slots_map[(node.time_slot, peer_id)]   # 13
                del node.time_slots_map[(node.time_slot, peer_id)]     # 14
            else:                            # 15
                while True:                  # 16
                    while not node.inbox:    # rcvMsg blocks on empty inbox
                        yield _Recv()
                    msg = node.inbox.popleft()                          # 17
                    node.n_received += 1
                    peer_time_slot, peer_node_id, peer_odata = msg      # 18
                    if (peer_time_slot, peer_node_id) != (node.time_slot, peer_id):  # 19
                        node.time_slots_map[(peer_time_slot, peer_node_id)] = msg    # 20
                        node.n_buffered += 1
                        continue             # 21
                    break                    # 23
            peer_time_slot, peer_node_id, peer_odata = msg              # 25
            peer_odatas.append(peer_odata)   # 26
        node.time_slot += 1                  # 27
        return peer_odatas                   # 28

    def get1_meas(self, node: _Node, peer_id: Optional[int], odata: Any):
        """The ORIGINAL pairwise primitive the paper generalizes: exactly one
        peer per slot (single-antenna satellite); peer_id None skips."""
        if peer_id is None or odata is None:
            node.time_slot += 1
            return None
        result = yield from self.get_meas(node, [peer_id], odata)
        return result

    # ----------------------------------------------------------- scheduler
    def run(self, programs: Dict[int, Callable]) -> Dict[int, Any]:
        """Run one coroutine per node to completion with seeded interleaving.

        ``programs[i]`` = generator function(node) -> yields on blocked recv,
        returns the node's final result. Nodes not in ``programs`` idle.
        """

        results: Dict[int, Any] = {}
        gens: Dict[int, Any] = {}
        for i, prog in programs.items():
            gens[i] = prog(self.nodes[i])

        ready = list(gens.keys())
        blocked: List[int] = []
        steps = 0
        limit = 10_000_000
        while ready or blocked:
            # wake any blocked node whose inbox is non-empty
            still_blocked = []
            for i in blocked:
                if self.nodes[i].inbox:
                    ready.append(i)
                else:
                    still_blocked.append(i)
            blocked = still_blocked
            if not ready:
                raise RuntimeError(
                    f"deadlock: nodes {sorted(blocked)} blocked on recv with empty "
                    f"inboxes — schedule is not a valid exchange relation?"
                )
            # adversarial interleaving: run a random ready node one step
            i = ready.pop(self.rng.randrange(len(ready)))
            try:
                gens[i].send(None)  # first send(None) primes the generator
                # yielded => blocked on recv
                blocked.append(i)
            except StopIteration as stop:
                results[i] = stop.value
            steps += 1
            if steps > limit:  # pragma: no cover
                raise RuntimeError("scheduler step limit exceeded")
        return results


# ---------------------------------------------------------------------------
# Whole-schedule drivers (used by tests, benchmarks, and the FL layer)
# ---------------------------------------------------------------------------

def run_schedule_getmeas(
    schedule: TDMSchedule,
    data: Dict[int, Any],
    n_nodes: int,
    seed: int = 0,
) -> Tuple[Dict[int, Dict[int, Any]], PTBFLASimulator]:
    """Run a TDM schedule where each slot uses getMeas (multi-link).

    Returns ``received[node][slot] = {peer: odata}`` plus the simulator (for
    message stats). ``data[node]`` may be a constant or a fn(slot) -> odata.
    """
    sim = PTBFLASimulator(n_nodes, seed=seed)

    def make_prog(node_id: int):
        def prog(node: _Node):
            out: Dict[int, Dict[int, Any]] = {}
            for t, rel in enumerate(schedule):
                peer_ids = rel.peers_of(node_id)
                odata = data[node_id](t) if callable(data.get(node_id)) else data.get(node_id)
                if not peer_ids:
                    res = yield from _as_gen(sim.get_meas(node, peer_ids, None))
                else:
                    res = yield from _as_gen(sim.get_meas(node, peer_ids, odata))
                if res is not None:
                    out[t] = dict(zip(peer_ids, res))
            return out

        return prog

    results = sim.run({i: make_prog(i) for i in range(n_nodes)})
    return results, sim


def run_schedule_get1meas(
    schedule: TDMSchedule,
    data: Dict[int, Any],
    n_nodes: int,
    seed: int = 0,
) -> Tuple[Dict[int, Dict[int, Any]], PTBFLASimulator]:
    """Run a pairwise schedule (every slot must be a matching) with get1meas."""
    for t, rel in enumerate(schedule):
        if not rel.is_matching():
            raise ValueError(
                f"slot {t} has a node with >1 peers; get1meas supports only "
                f"pairwise exchange (the limitation the paper removes)"
            )
    sim = PTBFLASimulator(n_nodes, seed=seed)

    def make_prog(node_id: int):
        def prog(node: _Node):
            out: Dict[int, Dict[int, Any]] = {}
            for t, rel in enumerate(schedule):
                peers = rel.peers_of(node_id)
                peer = peers[0] if peers else None
                odata = data[node_id](t) if callable(data.get(node_id)) else data.get(node_id)
                res = yield from _as_gen(sim.get1_meas(node, peer, odata))
                if res is not None:
                    out[t] = {peer: res[0]}
            return out

        return prog

    results = sim.run({i: make_prog(i) for i in range(n_nodes)})
    return results, sim


def _as_gen(gen_or_value):
    """getMeas returns a generator (it may yield) — delegate; plain values
    (skip path returns immediately) pass through."""
    if hasattr(gen_or_value, "send"):
        result = yield from gen_or_value
        return result
    return gen_or_value
