"""TDM schedules: sequences of per-slot exchange relations.

Two schedule families correspond to the paper's two primitives:

- ``round_robin_tournament(n)`` — the paper's get1meas evaluation schedule: a
  clique decomposed into perfect matchings via the circle method, one pairwise
  matching per time slot (single-antenna satellites).
- ``clique_multilink(n)`` — the paper's getMeas evaluation schedule: the whole
  clique relation in ONE slot; every node lists all other node IDs as peers
  (multi-antenna satellites, simultaneous links).

Between these extremes, ``edge_coloring`` decomposes an arbitrary exchange
relation R into matchings (Misra–Gries, ≤ Δ+1 colors by Vizing's theorem).
The number of colors used = number of antennas a satellite needs to realize R
in a single slot; a schedule generator can also respect *per-node* antenna
budgets by splitting R across slots (``antenna_constrained``).

Time-varying visibility relations for real constellations are produced by
the :mod:`repro.constellation` subsystem (orbital propagation, Earth
occlusion, link budgets) — start from
``repro.constellation.scenario.build_scenario``. The old
``WalkerConstellation`` duty-cycle toy was removed (module ``__getattr__``
below raises a hard ImportError with the migration hint).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


from repro.core.relation import Relation

Pair = Tuple[int, int]


@dataclass(frozen=True)
class TDMSchedule:
    """A sequence of per-slot exchange relations R_1 .. R_T."""

    slots: Tuple[Relation, ...]

    def __post_init__(self):
        for t, r in enumerate(self.slots):
            if not r.is_valid_exchange():
                raise ValueError(f"slot {t}: not a valid exchange relation")

    def __len__(self) -> int:
        return len(self.slots)

    def __iter__(self):
        return iter(self.slots)

    def __getitem__(self, t: int) -> Relation:
        return self.slots[t]

    def union(self) -> Relation:
        """All exchanges realized over the schedule (ignoring multiplicity)."""
        out = Relation.empty()
        for r in self.slots:
            out = out | r
        return out

    def total_pairs(self) -> int:
        return sum(len(r) for r in self.slots)

    def max_antennas(self) -> int:
        """Max simultaneous links any node needs in any single slot."""
        return max((r.max_degree() for r in self.slots), default=0)

    def restrict(self, alive: Iterable[int]) -> "TDMSchedule":
        """Elastic rescheduling after node failure (paper skip-slot semantics)."""
        alive = list(alive)
        return TDMSchedule(tuple(r.restrict(alive) for r in self.slots))

    def validate_antennas(
        self, antennas: "int | Dict[int, int]"
    ) -> "TDMSchedule":
        """Check every slot against per-node antenna budgets.

        Raises ``ValueError`` on the first over-subscribed node; returns
        ``self`` so the call chains. Restriction can only shrink degrees, but
        optimizer-produced or hand-edited schedules must be re-checked after
        any transformation — this is that check."""
        for t, r in enumerate(self.slots):
            for v in r.participants():
                cap = antennas if isinstance(antennas, int) else antennas.get(v, 1)
                if r.degree(v) > cap:
                    raise ValueError(
                        f"slot {t}: node {v} needs {r.degree(v)} simultaneous "
                        f"links but has {cap} antennas"
                    )
        return self


# --------------------------------------------------------------------------
# Paper evaluation schedules
# --------------------------------------------------------------------------

def round_robin_tournament(n: int, nodes: Sequence[int] | None = None) -> TDMSchedule:
    """Circle-method round-robin: decomposes K_n into perfect matchings.

    The paper's get1meas schedule: "we generated the schedule as a round robin
    tournament, resulting in a deterministic communication inside time slots
    for every node". For even n this is n-1 slots of n/2 disjoint pairs; for
    odd n it is n slots with one bye per slot.
    """
    if nodes is None:
        nodes = list(range(n))
    nodes = list(nodes)
    if len(nodes) != n:
        raise ValueError("len(nodes) != n")
    bye = None
    if n % 2 == 1:
        bye = object()
        nodes = nodes + [bye]
        n += 1
    half = n // 2
    arr = list(nodes)
    slots: List[Relation] = []
    for _ in range(n - 1):
        edges = []
        for i in range(half):
            a, b = arr[i], arr[n - 1 - i]
            if a is not bye and b is not bye:
                edges.append((a, b))
        slots.append(Relation.from_edges(edges, nodes=[x for x in nodes if x is not bye]))
        # rotate all but the first element
        arr = [arr[0]] + [arr[-1]] + arr[1:-1]
    return TDMSchedule(tuple(slots))


def clique_multilink(n: int, nodes: Sequence[int] | None = None) -> TDMSchedule:
    """The paper's getMeas schedule: one slot, every node peers with all others."""
    if nodes is None:
        nodes = list(range(n))
    return TDMSchedule((Relation.clique(list(nodes)),))


# --------------------------------------------------------------------------
# Edge coloring: R -> matchings  (Misra & Gries, Δ+1 colors)
# --------------------------------------------------------------------------

def edge_coloring(rel: Relation) -> List[Relation]:
    """Decompose a valid exchange relation into matchings.

    Misra–Gries edge coloring (constructive Vizing): any simple graph is edge
    colorable with ≤ Δ+1 colors. Each color class is a matching = one physical
    ppermute / one antenna-pairing round. Falls back to the greedy (≤ 2Δ-1)
    coloring if the Δ+1 invariant is ever violated (defensive; property tests
    exercise the main path).

    Cliques on an even node count are special-cased to the circle-method
    decomposition, which is OPTIMAL (Δ = n-1 colors, vs Misra–Gries' Δ+1):
    one fewer matching = one fewer ppermute on the collective path.
    """
    parts = sorted(rel.participants())
    # O(E) pair-count guard before the O(V^2) clique materialization — at
    # mega-constellation sizes the set build would dominate the coloring.
    if (
        len(parts) % 2 == 0
        and len(parts) >= 2
        and len(rel.pairs) == len(parts) * (len(parts) - 1)
    ):
        want = {(i, j) for i in parts for j in parts if i != j}
        if rel.pairs == frozenset(want):  # exact clique on participants
            return list(round_robin_tournament(len(parts), nodes=parts))
    try:
        matchings = _misra_gries(rel)
    except AssertionError:  # pragma: no cover - defensive fallback
        matchings = greedy_edge_coloring(rel)
    for m in matchings:
        if not m.is_matching():  # pragma: no cover - defensive fallback
            return greedy_edge_coloring(rel)
    return matchings


def _misra_gries(rel: Relation) -> List[Relation]:
    edges = rel.edge_list()
    if not edges:
        return []
    delta = rel.max_degree()
    ncolors = delta + 1
    # adj[u][c] = v  <=>  edge {u,v} has color c
    adj: Dict[int, Dict[int, int]] = {v: {} for v in rel.nodes}

    def free(u: int) -> int:
        for c in range(ncolors):
            if c not in adj[u]:
                return c
        raise AssertionError("no free color (Vizing bound violated)")

    def is_free(u: int, c: int) -> bool:
        return c not in adj[u]

    def set_color(u: int, v: int, c: int) -> None:
        assert is_free(u, c) and is_free(v, c), "color collision"
        adj[u][c] = v
        adj[v][c] = u

    def unset_color(u: int, v: int, c: int) -> None:
        assert adj[u].get(c) == v and adj[v].get(c) == u
        del adj[u][c]
        del adj[v][c]

    def color_of(u: int, v: int):
        for c, w in adj[u].items():
            if w == v:
                return c
        return None

    for (u, v) in edges:
        # 1. Maximal fan F of u starting at v: F[i+1] is the u-neighbor whose
        #    edge color is free on F[i].
        fan = [v]
        while True:
            c = free(fan[-1])
            w = adj[u].get(c)
            if w is None or w in fan:
                break
            fan.append(w)
        c_u = free(u)
        d = free(fan[-1])
        if not is_free(u, d):
            # 2. Invert the maximal (d, c_u)-alternating path starting at u.
            x, col = u, d
            path = []
            seen = {u}
            while col in adj[x]:
                y = adj[x][col]
                if y in seen:  # pragma: no cover - cannot happen on a path
                    break
                path.append((x, y, col))
                seen.add(y)
                x, col = y, (c_u if col == d else d)
            for (a, b, col) in path:
                unset_color(a, b, col)
            for (a, b, col) in path:
                set_color(a, b, c_u if col == d else d)
            assert is_free(u, d), "path inversion must free d at u"
        # 3. Truncate the fan at the first w with d free (prefix of a fan that
        #    satisfies the fan property after inversion).
        k = None
        for i, w in enumerate(fan):
            if is_free(w, d):
                # verify prefix fan property still holds up to i
                ok = True
                for j in range(i):
                    cj = color_of(u, fan[j + 1])
                    if cj is None or not is_free(fan[j], cj):
                        ok = False
                        break
                if ok:
                    k = i
                    break
        assert k is not None, "Misra–Gries: no rotatable fan prefix"
        fan = fan[: k + 1]
        # 4. Rotate the fan: shift each colored edge (u, F[j+1])'s color onto
        #    (u, F[j]); the last edge (u, F[k]) takes color d.
        for j in range(len(fan) - 1):
            cj = color_of(u, fan[j + 1])
            unset_color(u, fan[j + 1], cj)
            set_color(u, fan[j], cj)  # (u, fan[j]) is uncolored at this point
        set_color(u, fan[-1], d)

    by_color: Dict[int, List[Tuple[int, int]]] = {}
    seen_pairs = set()
    for uu in adj:
        for c, vv in adj[uu].items():
            e = (min(uu, vv), max(uu, vv))
            if e not in seen_pairs:
                seen_pairs.add(e)
                by_color.setdefault(c, []).append(e)
    assert seen_pairs == set(edges), "every edge must be colored exactly once"
    matchings = []
    for c in sorted(by_color):
        m = Relation.from_edges(by_color[c], nodes=rel.nodes)
        assert m.is_matching(), f"color class {c} is not a matching"
        matchings.append(m)
    return matchings


def greedy_edge_coloring(rel: Relation) -> List[Relation]:
    """Simple greedy fallback (≤ 2Δ-1 colors). Kept for cross-checking."""
    edges = rel.edge_list()
    color: Dict[Tuple[int, int], int] = {}
    for (u, v) in edges:
        used = {c for e, c in color.items() if u in e or v in e}
        c = 0
        while c in used:
            c += 1
        color[(u, v)] = c
    by_color: Dict[int, List[Tuple[int, int]]] = {}
    for e, c in color.items():
        by_color.setdefault(c, []).append(e)
    return [Relation.from_edges(by_color[c], nodes=rel.nodes) for c in sorted(by_color)]


def weighted_edge_coloring(
    rel: Relation, weights: Dict[Pair, float]
) -> List[Relation]:
    """Rate-aware decomposition: group edges of similar cost into matchings.

    ``weights`` maps undirected edges (i, j), i < j, to a cost (e.g. transfer
    time — higher = slower). Edges are placed slowest-first into the first
    matching with both endpoints free, so slow edges share color classes and
    fast edges are not held hostage by a slot-straggler. Classes come out in
    slowest-first order (≤ 2Δ-1 of them); each is a matching and their union
    is exactly ``rel``. Missing edges weigh 0.
    """
    edges = rel.edge_list()
    if not edges:
        return []
    order = sorted(edges, key=lambda e: (-float(weights.get(e, 0.0)), e))
    classes: List[List[Pair]] = []
    busy: List[set] = []
    for (u, v) in order:
        for cls, used in zip(classes, busy):
            if u not in used and v not in used:
                cls.append((u, v))
                used.update((u, v))
                break
        else:
            classes.append([(u, v)])
            busy.append({u, v})
    return [Relation.from_edges(cls, nodes=rel.nodes) for cls in classes]


def pack_matchings(
    matchings: Sequence[Relation],
    antennas: Dict[int, int],
    nodes: Iterable[int],
) -> List[Relation]:
    """First-fit pack matchings into antenna-feasible slots, in the given
    order — callers control grouping by ordering the matchings (e.g.
    slowest-first from ``weighted_edge_coloring``). A node with a
    zero/negative budget that appears in any matching is a contradiction
    and raises (it could never be placed)."""
    dead = sorted(
        {v for m in matchings for v in m.participants() if antennas.get(v, 1) < 1}
    )
    if dead:
        raise ValueError(
            f"nodes {dead} have edges in R but no antennas; drop them from "
            "the relation first (Relation.restrict)"
        )
    slots: List[List[Relation]] = []
    budgets: List[Dict[int, int]] = []
    for m in matchings:
        placed = False
        for slot, budget in zip(slots, budgets):
            if all(budget.get(v, antennas.get(v, 1)) >= 1 for v in m.participants()):
                slot.append(m)
                for v in m.participants():
                    budget[v] = budget.get(v, antennas.get(v, 1)) - 1
                placed = True
                break
        if not placed:
            slots.append([m])
            budgets.append({v: antennas.get(v, 1) - 1 for v in m.participants()})
    out = []
    for group in slots:
        r = Relation.empty(nodes)
        for m in group:
            r = r | m
        out.append(r)
    return out


def antenna_constrained(
    rel: Relation,
    antennas: Dict[int, int],
    weights: Optional[Dict[Pair, float]] = None,
) -> TDMSchedule:
    """Split R across slots so node v never uses more than antennas[v] links
    per slot. Matchings are packed first-fit into slots; with ``weights``
    (per-edge costs) the rate-aware ``weighted_edge_coloring`` replaces the
    Misra–Gries decomposition, grouping similar-cost edges. A node with a
    zero/negative antenna budget cannot realize any exchange, so its
    presence in R is a contradiction and raises (in ``pack_matchings``)."""
    if weights is None:
        matchings = edge_coloring(rel)
    else:
        matchings = weighted_edge_coloring(rel, weights)
    return TDMSchedule(tuple(pack_matchings(matchings, antennas, rel.nodes)))


# --------------------------------------------------------------------------
# Walker-delta constellation visibility — REMOVED (was a deprecated shim)
# --------------------------------------------------------------------------

def __getattr__(name: str):
    if name == "WalkerConstellation":
        raise ImportError(
            "WalkerConstellation (the duty-cycle toy) was removed: build a "
            "geometry-driven schedule via repro.constellation.scenario."
            "build_scenario(ScenarioSpec(...)).slots() instead."
        )
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# --------------------------------------------------------------------------
# Ring / torus schedules (hierarchical TDM for the multi-pod mesh)
# --------------------------------------------------------------------------

def ring(n: int, stride: int = 1) -> Relation:
    """Bidirectional ring relation with the given stride (n > 2 for validity;
    n == 2 degenerates to a single pair)."""
    edges = {(min(i, (i + stride) % n), max(i, (i + stride) % n)) for i in range(n)}
    edges = {(a, b) for a, b in edges if a != b}
    return Relation.from_edges(sorted(edges), nodes=range(n))


def hypercube_schedule(n: int) -> TDMSchedule:
    """log2(n) slots of dimension-exchange matchings — the classic gossip
    schedule; after all slots every node's data has propagated everywhere
    (paper Property 2 applied log n times)."""
    if n & (n - 1):
        raise ValueError("hypercube needs power-of-two n")
    slots = []
    for bit in range(n.bit_length() - 1):
        edges = [(i, i ^ (1 << bit)) for i in range(n) if i < (i ^ (1 << bit))]
        slots.append(Relation.from_edges(edges, nodes=range(n)))
    return TDMSchedule(tuple(slots))
