"""Fused flat-buffer TDM exchange engine: O(matchings) collectives per round.

Motivation (perf): :func:`repro.core.fl.tdm_mix` applied leaf-by-leaf issues
O(L×M) small ``ppermute``s per round for a model with L parameter leaves and
a relation colored into M matchings — collective-launch latency dominates on
real meshes long before the ISL/ICI link saturates. This module flattens the
parameter pytree ONCE per round into dtype-bucketed, block-padded contiguous
buffers, runs the whole mixing step on the fused buffer(s), and unflattens:

    per-leaf:  L×M collective-permutes  (2–3 L×M for compressed payloads)
    fused:       M collective-permutes  (2M int8: payload+scales; M CHOCO —
                 values+indices packed into one int32 payload)

per dtype bucket — for the common all-fp32 model, exactly M. The claim is
HLO-verified in tests (``tests/_fused_worker.py``) and measured by
``benchmarks/fused_exchange.py``.

Numerical contract per compression mode:

- ``none`` (both ``getmeas`` and ``get1meas``): BIT-IDENTICAL to the
  per-leaf path. Mixing is elementwise (per-node scalar weights), so
  gossiping the concatenation equals concatenating the gossips; both paths
  share the very same :func:`repro.core.tdm.gossip_avg` /
  :func:`~repro.core.tdm.gossip_avg_serial` code.
- ``int8``: Metropolis gossip with BLOCKWISE-quantized payloads via the
  Pallas ``tdm_compress`` kernels — quantize once per round, then per
  matching one fused dequant+weighted-accumulate pass over the receive
  buffer. Blockwise scales (one per ``block`` entries) replace the per-leaf
  path's per-tensor scale, so results differ from the per-leaf path by
  quantization granularity only (tighter: a block's absmax ≤ the tensor's).
  The per-leaf path also uses uniform 1/(1+Δ) weights where the fused path
  uses exact Metropolis weights — identical on regular relations.
- ``topk`` (CHOCO-Gossip): the compression state lives on the fused buffer
  and selection is BLOCKWISE over the bucket (the fused ``topk_sparsify``
  kernel picks ``ceil(k_total/nb)`` coordinates per block, one select+
  scatter pass, no host-side gather); the per-round payload budget is
  matched by scaling ``k_total`` to ``topk_k × n_leaves``. Values and
  block-local indices travel PACKED in a single int32 array, so a round
  costs M collective-permutes per bucket — same as uncompressed — and the
  receive side folds each arrival into the CHOCO accumulator with the
  fused ``scatter_accumulate`` kernel. Same convergence guarantees (the
  same CHOCO recursion on the concatenated state); per-round outputs
  differ from per-leaf by which coordinates the budget selects.

All entry points run inside ``shard_map`` over the node axis, like
everything in :mod:`repro.core.tdm`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tdm
from repro.core.relation import Relation
from repro.telemetry import metrics
from repro.telemetry import recorder as telemetry
from repro.kernels.tdm_compress import ref as q_ref
from repro.kernels.tdm_compress import tdm_compress as q_kernel

DEFAULT_BLOCK = 1024


# ---------------------------------------------------------------------------
# Flat-buffer spec: static (Python-side) layout of a pytree
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one pytree leaf lives inside its dtype bucket's flat buffer."""

    bucket: str                 # canonical dtype name, e.g. "float32"
    offset: int                 # element offset into the bucket buffer
    size: int                   # number of elements
    shape: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static layout: leaf -> (bucket, offset) plus padded bucket sizes.

    Buffers are padded to a multiple of ``block`` so the Pallas quantization
    kernels tile them exactly; padding lanes hold zeros and never travel
    back into the tree.
    """

    treedef: Any
    slots: Tuple[LeafSlot, ...]
    bucket_sizes: Tuple[Tuple[str, int], ...]   # (bucket, padded elements)
    bucket_leaves: Tuple[Tuple[str, int], ...]  # (bucket, n leaves)
    block: int

    @property
    def buckets(self) -> List[str]:
        return [b for b, _ in self.bucket_sizes]

    def padded_size(self, bucket: str) -> int:
        return dict(self.bucket_sizes)[bucket]

    def n_leaves(self, bucket: str) -> int:
        return dict(self.bucket_leaves)[bucket]


def build_spec(params: Any, block: int = DEFAULT_BLOCK) -> FlatSpec:
    """Lay out ``params``' leaves into dtype-bucketed contiguous buffers.

    Leaves keep tree order within their bucket; buckets are sorted by dtype
    name so the layout is deterministic for a given tree structure.
    """
    leaves, treedef = jax.tree.flatten(params)
    by_bucket: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    slots = []
    for leaf in leaves:
        bucket = jnp.asarray(leaf).dtype.name
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        off = by_bucket.get(bucket, 0)
        slots.append(LeafSlot(bucket, off, size, tuple(leaf.shape)))
        by_bucket[bucket] = off + size
        counts[bucket] = counts.get(bucket, 0) + 1
    sizes = tuple(
        (b, -(-by_bucket[b] // block) * block) for b in sorted(by_bucket)
    )
    return FlatSpec(
        treedef=treedef,
        slots=tuple(slots),
        bucket_sizes=sizes,
        bucket_leaves=tuple((b, counts[b]) for b in sorted(by_bucket)),
        block=block,
    )


# Specs are pure functions of (tree structure, leaf shapes/dtypes, block),
# and FL loops re-trace the same model layout for every distinct topology —
# re-deriving the layout per compile is pure waste. Bounded FIFO cache;
# keys hold treedefs and shape tuples only (no arrays, so no device memory).
# Hit/miss stats live on the flight recorder (per run scope, so benchmark
# and test runs cannot leak counts into each other) under this prefix.
_SPEC_CACHE: Dict[Any, FlatSpec] = {}
_SPEC_CACHE_MAX = 128
SPEC_CACHE_COUNTER = "fused.spec_cache"


def _spec_key(params: Any, block: int):
    leaves, treedef = jax.tree.flatten(params)
    return (
        treedef,
        int(block),
        tuple(
            (jnp.asarray(l).dtype.name, tuple(jnp.shape(l))) for l in leaves
        ),
    )


def cached_spec(params: Any, block: int = DEFAULT_BLOCK) -> FlatSpec:
    """:func:`build_spec` behind a cache keyed by (treedef, leaf
    shapes/dtypes, block). Works on tracers and concrete arrays alike —
    the key never touches values, so one layout derivation serves every
    (re)trace of the same model."""
    key = _spec_key(params, block)
    rec = telemetry.get_recorder()
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        rec.counter(f"{SPEC_CACHE_COUNTER}.misses")
        spec = build_spec(params, block=block)
        if len(_SPEC_CACHE) >= _SPEC_CACHE_MAX:
            _SPEC_CACHE.pop(next(iter(_SPEC_CACHE)))
        _SPEC_CACHE[key] = spec
    else:
        rec.counter(f"{SPEC_CACHE_COUNTER}.hits")
    return spec


def spec_cache_stats() -> Dict[str, int]:
    """Hit/miss counts of the ACTIVE run scope (the layout cache itself is
    process-wide; its stats are per-recorder so runs don't leak into each
    other — see :mod:`repro.telemetry.recorder`)."""
    rec = telemetry.get_recorder()
    return {
        "hits": int(rec.get_counter(f"{SPEC_CACHE_COUNTER}.hits")),
        "misses": int(rec.get_counter(f"{SPEC_CACHE_COUNTER}.misses")),
        "size": len(_SPEC_CACHE),
    }


def clear_spec_cache() -> None:
    _SPEC_CACHE.clear()
    telemetry.get_recorder().pop_counters(SPEC_CACHE_COUNTER)


def flatten_pytree(spec: FlatSpec, params: Any) -> Dict[str, jax.Array]:
    """Pytree -> {dtype name: flat padded buffer} (one concatenate per bucket)."""
    leaves, treedef = jax.tree.flatten(params)
    if treedef != spec.treedef:
        raise ValueError(f"tree mismatch: {treedef} != {spec.treedef}")
    parts: Dict[str, List[jax.Array]] = {b: [] for b in spec.buckets}
    used: Dict[str, int] = {b: 0 for b in spec.buckets}
    for slot, leaf in zip(spec.slots, leaves):
        parts[slot.bucket].append(jnp.asarray(leaf).reshape(-1))
        used[slot.bucket] += slot.size
    out = {}
    for bucket in spec.buckets:
        pad = spec.padded_size(bucket) - used[bucket]
        if pad:
            parts[bucket].append(jnp.zeros((pad,), dtype=jnp.dtype(bucket)))
        out[bucket] = (
            jnp.concatenate(parts[bucket])
            if len(parts[bucket]) > 1
            else parts[bucket][0]
        )
    return out


def unflatten_pytree(spec: FlatSpec, buffers: Dict[str, jax.Array]) -> Any:
    """Inverse of :func:`flatten_pytree` (static slices — free at trace time)."""
    leaves = []
    for slot in spec.slots:
        buf = buffers[slot.bucket]
        leaves.append(buf[slot.offset : slot.offset + slot.size].reshape(slot.shape))
    return jax.tree.unflatten(spec.treedef, leaves)


# ---------------------------------------------------------------------------
# Fused buffer mixing
# ---------------------------------------------------------------------------

def _resolve_impl(impl: str) -> str:
    """'auto' -> the Pallas kernels on TPU, their validated jnp oracle
    elsewhere (interpret-mode Pallas is a debugging path, not a hot path)."""
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl not in ("pallas", "pallas_interpret", "ref"):
        raise ValueError(f"unknown quant impl {impl}")
    return impl


def _quantize(x32, block: int, impl: str):
    if impl == "ref":
        return q_ref.quantize_ref(x32, block=block)
    return q_kernel.quantize_fwd(
        x32, block=block, interpret=(impl == "pallas_interpret")
    )


def _dequant_acc(q, s, acc, w, block: int, impl: str):
    if impl == "ref":
        return q_ref.dequant_acc_ref(q, s, acc, w, block=block)
    return q_kernel.dequant_accumulate_fwd(
        q, s, acc, w, block=block, interpret=(impl == "pallas_interpret")
    )


def _topk(x32, k: int, block: int, impl: str):
    if impl == "ref":
        return q_ref.topk_sparsify_ref(x32, k, block=block)
    return q_kernel.topk_sparsify_fwd(
        x32, k, block=block, interpret=(impl == "pallas_interpret")
    )


def _scatter_acc(vals, idxs, acc, w, block: int, impl: str):
    if impl == "ref":
        return q_ref.scatter_acc_ref(vals, idxs, acc, w, block=block)
    return q_kernel.scatter_accumulate_fwd(
        vals, idxs, acc, w, block=block, interpret=(impl == "pallas_interpret")
    )


def int8_gossip(
    x: jax.Array,
    rel: Relation,
    axis_name: str,
    n: int,
    *,
    block: int = DEFAULT_BLOCK,
    impl: str = "auto",
) -> jax.Array:
    """One Metropolis gossip step with blockwise-int8-quantized payloads.

    Send side quantizes ``x`` ONCE (Pallas ``quantize_fwd``); each matching
    then ships (int8 payload, fp32 blockwise scales) = 2 ppermutes, and the
    receive side folds each arrival into the accumulator with the fused
    dequant+weighted-accumulate kernel — a single pass over the buffer per
    matching, no fp32 payload ever materialized.

    ``x`` must be flat with ``len(x) % block == 0`` (the FlatSpec contract).
    """
    if len(rel) == 0:
        return x
    impl = _resolve_impl(impl)
    idx = jax.lax.axis_index(axis_name)
    diag, per_matching = tdm.matching_weight_vectors(rel, n)
    x32 = x.astype(jnp.float32)
    q, scales = _quantize(x32, block, impl)
    acc = jnp.zeros_like(x32)
    matchings = tdm.edge_coloring(rel)
    for m, w_m in zip(matchings, per_matching):
        q_r = tdm.exchange_matching(q, m, axis_name)
        s_r = tdm.exchange_matching(scales, m, axis_name)
        w = jnp.asarray(w_m, jnp.float32)[idx]
        acc = _dequant_acc(q_r, s_r, acc, w, block, impl)
    self_w = jnp.asarray(diag, jnp.float32)[idx]
    return (self_w * x32 + acc).astype(x.dtype)


def choco_fused_round(
    buf: jax.Array,
    state: tdm.ChocoState,
    rel: Relation,
    axis_name: str,
    n: int,
    k_total: int,
    *,
    gamma: float = 0.4,
    block: int = DEFAULT_BLOCK,
    impl: str = "auto",
) -> Tuple[jax.Array, tdm.ChocoState]:
    """One CHOCO-Gossip round on a fused buffer via the fused top-k kernels.

    The same recursion as :func:`repro.core.tdm.choco_gossip_round` (x̂/s
    public-copy state, γ-damped consensus step), lowered onto the
    ``tdm_compress`` kernel family:

    - selection: ONE ``topk_sparsify`` pass picks ``ceil(k_total/nb)``
      coordinates per block and emits the dense sparsified update (for x̂)
      plus the wire payload (vals + block-local idxs) — no argsort/gather on
      the host path;
    - wire: vals are bitcast to int32 and PACKED with the indices into a
      single (nb, 2, k_b) array, so each matching costs ONE
      collective-permute — M per round per bucket, half of the unpacked
      values+indices scheme;
    - receive: each arrival folds into the CHOCO accumulator ``s`` with one
      fused ``scatter_accumulate`` pass (dense contribution never hits HBM).

    State is carried in fp32 regardless of the buffer dtype. Requires
    ``len(buf) % block == 0`` (the FlatSpec contract) and a FIXED relation
    across rounds, like every CHOCO path.
    """
    if buf.shape[0] % block:
        raise ValueError(
            f"fused CHOCO needs a block-padded buffer: {buf.shape[0]} % "
            f"{block} != 0"
        )
    impl = _resolve_impl(impl)
    nb = buf.shape[0] // block
    k_b = max(1, min(block, -(-int(k_total) // nb)))
    idx = jax.lax.axis_index(axis_name)
    x32 = buf.astype(jnp.float32)
    x_hat = state.x_hat.astype(jnp.float32)
    s = state.s.astype(jnp.float32)

    dense_q, vals, idxs = _topk(x32 - x_hat, k_b, block, impl)
    new_x_hat = x_hat + dense_q
    payload = jnp.stack(
        [jax.lax.bitcast_convert_type(vals, jnp.int32), idxs], axis=1
    )  # (nb, 2, k_b): one int32 wire word per payload entry component

    W = tdm.metropolis_weights(rel, n)
    _, per_matching = tdm.matching_weight_vectors(rel, n)
    for m, w_m in zip(tdm.edge_coloring(rel), per_matching):
        p_r = tdm.exchange_matching(payload, m, axis_name)
        v_r = jax.lax.bitcast_convert_type(p_r[:, 0, :], jnp.float32)
        i_r = p_r[:, 1, :]
        w = jnp.asarray(w_m, jnp.float32)[idx]
        s = _scatter_acc(v_r, i_r, s, w, block, impl)

    deg_w = np.zeros((n,), dtype=np.float32)
    for i in range(n):
        deg_w[i] = sum(W[i, j] for j in rel.peers_of(i))
    d_i = jnp.asarray(deg_w, jnp.float32)[idx]
    new_x = x32 + jnp.float32(gamma) * (s - d_i * new_x_hat)
    return new_x.astype(buf.dtype), tdm.ChocoState(x_hat=new_x_hat, s=s)


def mix_wire_bytes(
    n_elems: int,
    itemsize: int,
    compression: str,
    *,
    k: int = 0,
    block: int = DEFAULT_BLOCK,
) -> int:
    """Static wire bytes ONE device ships per matching for one buffer.

    ``none`` ships the raw buffer; ``int8`` ships the quantized buffer plus
    one f32 scale per block (they travel as separate permutes but are one
    matching's payload); ``topk`` ships ``k`` packed (value, block-local
    index) pairs per block — the PR 7 single-payload layout. Per-round
    totals multiply by the relation's matching count; the accounting
    counters in :func:`fused_buffer_mix` do exactly that."""
    nb = -(-int(n_elems) // int(block))
    if compression == "topk":
        return nb * int(k) * 8
    if compression == "int8":
        return int(n_elems) + nb * 4
    return int(n_elems) * int(itemsize)


def _account_exchange(
    rel: Relation, n_elems: int, itemsize: int, compression: str, k: int, block: int
) -> None:
    """Trace-time exchange-size accounting (ISSUE 9 link-layer metrics).

    Runs on the host while the mix is being traced — one bump per
    (topology, layout) COMPILE, not per executed round (per-round rates
    come from multiplying the static per-round counters the drivers keep).
    Zero device ops, so compiled programs and outputs stay bit-identical.
    """
    m = len(tdm.edge_coloring(rel))
    wire = m * mix_wire_bytes(
        n_elems, itemsize, compression, k=k, block=block
    )
    rec = telemetry.get_recorder()
    rec.counter("fused.exchange.mixes_traced")
    rec.counter("fused.exchange.wire_bytes_per_round", wire)
    metrics.observe(
        "fused.exchange.wire_mbytes",
        wire / 1e6,
        buckets=metrics.LOG_BUCKETS,
        rec=rec,
    )


def fused_buffer_mix(
    buf: jax.Array,
    rel: Relation,
    axis_name: str,
    n: int,
    cfg,
    residual: Optional[tdm.ChocoState] = None,
    *,
    n_leaves: int = 1,
    block: int = DEFAULT_BLOCK,
    quant_impl: str = "auto",
) -> Tuple[jax.Array, Optional[tdm.ChocoState]]:
    """One TDM-FLA mixing step for a single fused buffer.

    ``cfg`` is a :class:`repro.core.fl.TDMFLAConfig` (duck-typed to avoid a
    circular import). ``n_leaves`` scales the top-k budget so fused CHOCO
    ships the same payload as the per-leaf path would.
    """
    if len(rel) == 0:
        return buf, residual
    _account_exchange(
        rel,
        buf.shape[0],
        jnp.dtype(buf.dtype).itemsize,
        cfg.compression,
        min(getattr(cfg, "topk_k", 0) * max(n_leaves, 1), buf.shape[0])
        if cfg.compression == "topk"
        else 0,
        block,
    )
    if cfg.compression == "topk":
        k = min(cfg.topk_k * max(n_leaves, 1), buf.shape[0])
        state = (
            residual
            if isinstance(residual, tdm.ChocoState)
            else tdm.choco_init(buf.astype(jnp.float32))
        )
        return choco_fused_round(
            buf, state, rel, axis_name, n, k,
            gamma=cfg.choco_gamma, block=block, impl=quant_impl,
        )
    if cfg.compression == "int8":
        return (
            int8_gossip(
                buf, rel, axis_name, n, block=block, impl=quant_impl
            ),
            residual,
        )
    if cfg.comm == "get1meas":
        return tdm.gossip_avg_serial(buf, rel, axis_name, n), residual
    return tdm.gossip_avg(buf, rel, axis_name, n), residual


def fused_tdm_fla_round(
    params: Any,
    rel: Relation,
    axis_name: str,
    n: int,
    cfg,
    residuals: Any = None,
    *,
    block: int = DEFAULT_BLOCK,
    quant_impl: str = "auto",
) -> Tuple[Any, Any]:
    """One TDM-FLA round over a whole pytree through the fused engine.

    Flatten -> mix each dtype bucket's buffer -> unflatten. Residuals (CHOCO
    state) are keyed by bucket name — an opaque carry; hand back exactly
    what the previous call returned (or None to reset).
    """
    if len(rel) == 0:
        return params, residuals
    spec = cached_spec(params, block=block)
    buffers = flatten_pytree(spec, params)
    res_in = residuals if isinstance(residuals, dict) else {}
    mixed, res_out = {}, {}
    for bucket, buf in buffers.items():
        mixed[bucket], res_out[bucket] = fused_buffer_mix(
            buf,
            rel,
            axis_name,
            n,
            cfg,
            res_in.get(bucket),
            n_leaves=spec.n_leaves(bucket),
            block=block,
            quant_impl=quant_impl,
        )
    return unflatten_pytree(spec, mixed), res_out


# ---------------------------------------------------------------------------
# Hierarchical (pod × data) gossip on fused buffers
# ---------------------------------------------------------------------------

_HIERARCHICAL_COMPRESSIONS = ("none", "int8")


def hierarchical_buffer_mix(
    buf: jax.Array,
    intra_rel: Relation,
    inter_rel: Relation,
    data_axis: str,
    pod_axis: str,
    n_data: int,
    n_pods: int,
    *,
    compression: str = "none",
    block: int = DEFAULT_BLOCK,
    quant_impl: str = "auto",
) -> jax.Array:
    """Two-level TDM mixing of one fused buffer: gossip within each pod over
    ``data_axis`` (dense ICI), then between pods over ``pod_axis`` (the
    sparse optical ISLs) — :func:`repro.core.tdm.hierarchical_gossip`
    lowered onto the fused engine, now including the int8 kernel path
    (quantize once PER LEVEL; each level's matchings ship payload+scales
    through the fused dequant+accumulate kernel).

    ``compression`` must be ``"none"`` or ``"int8"``: topk/CHOCO state is
    tied to one fixed relation and does not fit a two-level schedule.
    """
    if compression not in _HIERARCHICAL_COMPRESSIONS:
        raise ValueError(
            "hierarchical gossip compression must be one of "
            f"{_HIERARCHICAL_COMPRESSIONS}, got {compression!r} (topk/CHOCO "
            "state is tied to one fixed relation, not a two-level schedule)"
        )
    for rel, axis, n_ax in (
        (intra_rel, data_axis, n_data),
        (inter_rel, pod_axis, n_pods),
    ):
        if len(rel) == 0:
            continue
        if compression == "int8":
            buf = int8_gossip(
                buf, rel, axis, n_ax, block=block, impl=quant_impl
            )
        else:
            buf = tdm.gossip_avg(buf, rel, axis, n_ax)
    return buf


def fused_hierarchical_round(
    params: Any,
    intra_rel: Relation,
    inter_rel: Relation,
    data_axis: str,
    pod_axis: str,
    n_data: int,
    n_pods: int,
    *,
    compression: str = "none",
    block: int = DEFAULT_BLOCK,
    quant_impl: str = "auto",
) -> Any:
    """Hierarchical (pod × data) TDM round over a whole pytree through the
    fused engine: flatten once, mix each dtype bucket at both levels,
    unflatten. ``compression="none"`` is bit-identical to per-leaf
    :func:`repro.core.tdm.hierarchical_gossip` (same elementwise gossip on
    the concatenation); static cost is
    ``(M_intra + M_inter) × per × n_buckets`` collective-permutes with
    ``per = 2`` for int8 — the
    :func:`repro.telemetry.expected_hierarchical_collectives` oracle.
    """
    spec = cached_spec(params, block=block)
    buffers = flatten_pytree(spec, params)
    mixed = {
        bucket: hierarchical_buffer_mix(
            buf, intra_rel, inter_rel, data_axis, pod_axis, n_data, n_pods,
            compression=compression, block=block, quant_impl=quant_impl,
        )
        for bucket, buf in buffers.items()
    }
    return unflatten_pytree(spec, mixed)
