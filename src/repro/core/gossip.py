"""Gossip-averaging theory on top of the paper's relation model.

The paper's Property 2 (data propagation by composition of per-slot
relations) is, in FL terms, the statement that decentralized averaging over
a TDM schedule mixes information across the constellation. This module makes
that quantitative: mixing matrices W(R), their spectral gap (the convergence
rate of decentralized FL over the schedule), and the propagation closure
(which node's data has reached whom after slots R_1..R_T — paper §II.B).
"""

from __future__ import annotations


import numpy as np

from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule


def metropolis_weights(rel: Relation, n: int) -> np.ndarray:
    """Metropolis–Hastings mixing matrix for exchange relation R.

    W[i,j] = 1/(1+max(d_i,d_j)) for (i,j) in R; W[i,i] = 1 - sum_j W[i,j].
    Symmetric, doubly stochastic, diagonalizable — the standard choice for
    decentralized averaging on an undirected graph (= R, by paper P5).
    """
    rel.validate()
    W = np.zeros((n, n))
    deg = {v: rel.degree(v) for v in range(n)}
    for i, j in rel.pairs:
        W[i, j] = 1.0 / (1.0 + max(deg.get(i, 0), deg.get(j, 0)))
    for i in range(n):
        W[i, i] = 1.0 - W[i].sum()
    return W


def uniform_neighbor_weights(rel: Relation, n: int, self_weight: float | None = None) -> np.ndarray:
    """W[i,j] = (1-w_self)/d_i over neighbors. Doubly stochastic only for
    regular graphs; used for the clique (paper's evaluation scenario) where
    it equals exact averaging in one slot when self_weight = 1/n."""
    W = np.zeros((n, n))
    for i in range(n):
        peers = rel.peers_of(i)
        if not peers:
            W[i, i] = 1.0
            continue
        w_self = self_weight if self_weight is not None else 1.0 / (len(peers) + 1)
        W[i, i] = w_self
        for j in peers:
            W[i, j] = (1.0 - w_self) / len(peers)
    return W


def spectral_gap(W: np.ndarray) -> float:
    """1 - |λ₂(W)|: per-slot contraction rate of disagreement."""
    eig = np.linalg.eigvals(W)
    eig = sorted(np.abs(eig), reverse=True)
    if len(eig) < 2:
        return 1.0
    return float(1.0 - eig[1])


def schedule_mixing_matrix(schedule: TDMSchedule, n: int) -> np.ndarray:
    """Product of per-slot Metropolis matrices — the effective mixing of one
    full TDM schedule period (composition of relations, paper P2)."""
    W = np.eye(n)
    for rel in schedule:
        W = metropolis_weights(rel, n) @ W
    return W


def propagation_closure(schedule: TDMSchedule, n: int) -> np.ndarray:
    """reach[i, j] = True iff node i's slot-0 data can have reached node j by
    the end of the schedule via the slot-ordered path relation (paper §II.B:
    evaluating the sequence of R compositions left to right)."""
    reach = np.eye(n, dtype=bool)
    for rel in schedule:
        A = rel.adjacency(n)
        reach = reach | (reach @ A)
    return reach


def slots_to_full_propagation(schedule_gen, n: int, max_periods: int = 64) -> int:
    """How many slots until every node's data reached every other node
    (diameter of the time-expanded graph). ``schedule_gen(t)`` -> Relation."""
    reach = np.eye(n, dtype=bool)
    t = 0
    while not reach.all():
        rel = schedule_gen(t)
        reach = reach | (reach @ rel.adjacency(n))
        t += 1
        if t > max_periods * n:
            return -1  # never propagates fully (disconnected schedule)
    return t
