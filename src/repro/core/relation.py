"""The paper's algebraic model of collective TDM data exchange.

Paper §II: a set A = {a_1 .. a_m} of application instances participating in
the TDM data exchange of the current time slot, and a relation R ⊆ A×A with
the semantics ``aRb`` ⇔ *a sends its data to b and receives b's data from b*.
A valid exchange relation is symmetric (exchange needs both directions) and
anti-reflexive (a node does not exchange with itself).

This module makes R a first-class object with the paper's five properties
(P1 inverse, P2 composition/propagation, P3 special properties, P4 symmetric
closure, P5 graph representation) implemented and testable.

Nodes are integers (the paper's node IDs). Everything here is pure Python /
numpy — the JAX lowering lives in :mod:`repro.core.tdm`.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, Iterator, List, Sequence, Set, Tuple

import numpy as np

Pair = Tuple[int, int]


@dataclass(frozen=True)
class Relation:
    """A relation R on a node set, per paper §II.

    ``pairs`` holds ordered pairs (i, j) meaning "i sends to j and receives
    from j". ``nodes`` is the universe A (a node may be in A yet isolated in
    R — the paper's `odata=None` skip case).
    """

    nodes: FrozenSet[int]
    pairs: FrozenSet[Pair]

    # ------------------------------------------------------ adjacency cache
    # Scheduling and routing interrogate a relation many times per slot
    # (peers_of / degree / edge_list in inner loops); recomputing them by
    # scanning ``pairs`` is O(E) per call and turns the contact-plan colorer
    # and the routing DP into O(V·E) per step. The adjacency map is derived
    # once per instance and memoized directly in ``__dict__`` (legal on a
    # frozen dataclass — only ``__setattr__`` is blocked), keeping the
    # public API and the value semantics unchanged.
    def _adjacency(self) -> Dict[int, Tuple[int, ...]]:
        cached = self.__dict__.get("_adj_cache")
        if cached is None:
            by_src: Dict[int, List[int]] = {}
            for i, j in self.pairs:
                by_src.setdefault(i, []).append(j)
            cached = {v: tuple(sorted(ps)) for v, ps in by_src.items()}
            self.__dict__["_adj_cache"] = cached
        return cached

    # ---------------------------------------------------------------- build
    @staticmethod
    def from_pairs(pairs: Iterable[Pair], nodes: Iterable[int] | None = None) -> "Relation":
        ps = frozenset((int(i), int(j)) for i, j in pairs)
        ns = set(nodes) if nodes is not None else set()
        for i, j in ps:
            ns.add(i)
            ns.add(j)
        return Relation(frozenset(ns), ps)

    @staticmethod
    def from_edges(edges: Iterable[Tuple[int, int]], nodes: Iterable[int] | None = None) -> "Relation":
        """Build a valid exchange relation from undirected edges (P5 inverse map)."""
        ps: Set[Pair] = set()
        for a, b in edges:
            if a == b:
                raise ValueError(f"self-edge {a} is not a valid exchange (R is anti-reflexive)")
            ps.add((int(a), int(b)))
            ps.add((int(b), int(a)))
        return Relation.from_pairs(ps, nodes)

    @staticmethod
    def clique(nodes: Sequence[int]) -> "Relation":
        """The paper's R3-style relation: every instance exchanges with all others."""
        return Relation.from_edges(itertools.combinations(nodes, 2), nodes)

    @staticmethod
    def empty(nodes: Iterable[int] = ()) -> "Relation":
        return Relation(frozenset(nodes), frozenset())

    # ------------------------------------------------------------ validity
    def is_valid_exchange(self) -> bool:
        """A relation supports data exchange iff it is symmetric and anti-reflexive."""
        return self.is_symmetric() and self.is_antireflexive()

    def validate(self) -> "Relation":
        if not self.is_antireflexive():
            bad = [p for p in self.pairs if p[0] == p[1]]
            raise ValueError(f"R must be anti-reflexive; got self-pairs {bad}")
        if not self.is_symmetric():
            bad = [(i, j) for (i, j) in self.pairs if (j, i) not in self.pairs]
            raise ValueError(
                f"exchange needs both aRb and bRa (paper §II); one-sided pairs: {bad}"
            )
        return self

    # ------------------------------------------------ P1: inverse relation
    def inverse(self) -> "Relation":
        return Relation(self.nodes, frozenset((j, i) for i, j in self.pairs))

    # ------------------------------------------- P2: composition/propagation
    def compose(self, other: "Relation") -> "Relation":
        """R1 ∘ R2 = {(a, c) : ∃b. aR1b ∧ bR2c}, excluding self-pairs.

        Paper §II.B: compositions of exchange relations model multi-hop data
        propagation. The composition itself need not be a valid exchange
        relation; the union with its reverse composition is (paper's R23).
        """
        by_src: Dict[int, Set[int]] = {}
        for b, c in other.pairs:
            by_src.setdefault(b, set()).add(c)
        out: Set[Pair] = set()
        for a, b in self.pairs:
            for c in by_src.get(b, ()):
                if a != c:
                    out.add((a, c))
        return Relation(self.nodes | other.nodes, frozenset(out))

    def propagation(self, other: "Relation") -> "Relation":
        """The paper's R23 = R1∘R2 ∪ R2∘R1 — a valid exchange relation."""
        return self.compose(other).union(other.compose(self))

    def union(self, other: "Relation") -> "Relation":
        return Relation(self.nodes | other.nodes, self.pairs | other.pairs)

    # --------------------------------------------- P3: special properties
    def is_reflexive(self) -> bool:
        return all((a, a) in self.pairs for a in self.participants())

    def is_antireflexive(self) -> bool:
        return all(i != j for i, j in self.pairs)

    def is_symmetric(self) -> bool:
        return all((j, i) in self.pairs for i, j in self.pairs)

    def is_transitive(self) -> bool:
        by_src: Dict[int, Set[int]] = {}
        for i, j in self.pairs:
            by_src.setdefault(i, set()).add(j)
        return all(
            (a, c) in self.pairs
            for a, b in self.pairs
            for c in by_src.get(b, ())
        )

    def is_antisymmetric(self) -> bool:
        return all(not ((j, i) in self.pairs and i != j) for i, j in self.pairs)

    # --------------------------------------------- P4: symmetric closure
    def symmetric_closure(self) -> "Relation":
        return self.union(self.inverse())

    # ------------------------------------------- P5: graph representation
    def edges(self) -> Set[FrozenSet[int]]:
        """E = {{a, b} : (a, b) ∈ R} (valid because R is symmetric anti-reflexive)."""
        return {frozenset(p) for p in self.pairs}

    def edge_list(self) -> List[Tuple[int, int]]:
        cached = self.__dict__.get("_edge_list_cache")
        if cached is None:
            cached = tuple(
                sorted({(min(a, b), max(a, b)) for a, b in self.pairs})
            )
            self.__dict__["_edge_list_cache"] = cached
        return list(cached)

    def participants(self) -> Set[int]:
        """Nodes that take part in this slot (paper: the set A, m ≤ n)."""
        return set(self._adjacency())

    def peers_of(self, node: int) -> List[int]:
        """The node's `peer_ids` argument to getMeas, in sorted order."""
        return list(self._adjacency().get(node, ()))

    def degree(self, node: int) -> int:
        """Number of simultaneous links node needs = number of antennas used."""
        return len(self._adjacency().get(node, ()))

    def max_degree(self) -> int:
        return max((len(ps) for ps in self._adjacency().values()), default=0)

    def pairs_array(self) -> np.ndarray:
        """The directed pairs as a sorted (P, 2) intp array — the form the
        vectorized routing DP consumes. Memoized like the adjacency map
        (ascending (src, dst) order, so scatter-min tie-breaks reproduce the
        legacy ascending-neighbor iteration)."""
        arr = self.__dict__.get("_pairs_array_cache")
        if arr is None:
            if self.pairs:
                # chain.from_iterable keeps the flattening in C — a Python
                # genexpr here dominated the whole routing DP at mega scale
                flat = np.fromiter(
                    itertools.chain.from_iterable(self.pairs),
                    dtype=np.intp,
                    count=2 * len(self.pairs),
                )
                arr = flat.reshape(-1, 2)
                arr = arr[np.lexsort((arr[:, 1], arr[:, 0]))]
            else:
                arr = np.empty((0, 2), dtype=np.intp)
            self.__dict__["_pairs_array_cache"] = arr
        return arr

    def adjacency(self, n: int | None = None) -> np.ndarray:
        """Boolean adjacency matrix over node IDs 0..n-1."""
        if n is None:
            n = (max(self.nodes) + 1) if self.nodes else 0
        A = np.zeros((n, n), dtype=bool)
        for i, j in self.pairs:
            A[i, j] = True
        return A

    # --------------------------------------------------- scheduling helpers
    def is_matching(self) -> bool:
        """True iff every participant has exactly one peer (a pairwise slot —
        what the original get1meas primitive supports)."""
        return all(self.degree(v) == 1 for v in self.participants())

    def restrict(self, alive: Iterable[int]) -> "Relation":
        """Drop pairs touching failed/occluded nodes (fault tolerance: the
        paper's skip-slot semantics applied by the scheduler)."""
        alive_s = set(alive)
        return Relation(
            frozenset(self.nodes & alive_s),
            frozenset((i, j) for i, j in self.pairs if i in alive_s and j in alive_s),
        )

    # ------------------------------------------------------------- dunder
    def __contains__(self, pair: Pair) -> bool:
        return tuple(pair) in self.pairs

    def __iter__(self) -> Iterator[Pair]:
        return iter(sorted(self.pairs))

    def __len__(self) -> int:
        return len(self.pairs)

    def __or__(self, other: "Relation") -> "Relation":
        return self.union(other)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Relation(n={len(self.nodes)}, pairs={sorted(self.pairs)})"
