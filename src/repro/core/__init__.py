"""The paper's contribution: universal TDM communication over ISLs.

- relation.py    R ⊆ A×A exchange relations (paper §II, properties P1–P5)
- schedule.py    TDM schedules, edge coloring, Walker constellations
- ptbfla_sim.py  paper-faithful Algorithm 1 (getMeas) discrete-event oracle
- tdm.py         TPU-native getMeas/get1meas as shard_map collectives
- gossip.py      mixing matrices, spectral gaps, propagation closure (P2)
- fl.py          the 3 generic FLAs: centralized / decentralized / TDM
- compress.py    ISL payload compression (top-k + error feedback, int8)
- fused.py       fused flat-buffer exchange engine (M collectives/round)
"""

from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule
