"""ISL-link payload compression for TDM exchange (beyond-paper feature).

The paper exchanges raw orbital data over TCP; on a real constellation (and
on the TPU mesh standing in for it) inter-satellite link bandwidth is the
scarce resource. This module provides the two standard distributed-
optimization compressors, applied to TDM payloads before ``ppermute``:

- ``topk``  — magnitude top-k sparsification with **error feedback**
  (Stich et al., "Sparsified SGD with Memory", NeurIPS 2018): the
  compression residual is carried to the next round, preserving
  convergence.
- ``int8``  — symmetric per-tensor int8 quantization with fp32 scale.

Both have pure-jnp reference implementations here; the Pallas fused kernel
(`repro.kernels.tdm_compress`) implements the hot path and is validated
against these in tests.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class TopKPayload(NamedTuple):
    """Sparse payload: values + flat indices + original shape is static."""

    values: jax.Array   # (k,)
    indices: jax.Array  # (k,) int32 into the flattened tensor


def topk_compress(x: jax.Array, k: int) -> TopKPayload:
    """Keep the k largest-|x| entries. Deterministic tie-break by index."""
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = jnp.sort(idx)  # canonical order (stable payloads across nodes)
    return TopKPayload(values=flat[idx], indices=idx.astype(jnp.int32))


def topk_decompress(payload: TopKPayload, shape: Tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    out = jnp.zeros((math.prod(shape),), dtype=dtype)
    out = out.at[payload.indices].set(payload.values.astype(dtype))
    return out.reshape(shape)


def topk_with_error_feedback(
    x: jax.Array, residual: jax.Array, k: int
) -> Tuple[TopKPayload, jax.Array]:
    """Compress (x + residual); return payload and the new residual."""
    corrected = x + residual
    payload = topk_compress(corrected, k)
    new_residual = corrected - topk_decompress(payload, x.shape, corrected.dtype)
    return payload, new_residual


class Int8Payload(NamedTuple):
    q: jax.Array      # int8 tensor
    scale: jax.Array  # () float32


def int8_compress(x: jax.Array) -> Int8Payload:
    scale = jnp.maximum(jnp.max(jnp.abs(x)).astype(jnp.float32), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return Int8Payload(q=q, scale=scale)


def int8_decompress(p: Int8Payload, dtype=jnp.float32) -> jax.Array:
    return (p.q.astype(jnp.float32) * p.scale).astype(dtype)


def compression_ratio(shape: Tuple[int, ...], k: int | None, mode: str) -> float:
    """Payload bytes / raw fp32 bytes — used by the ISL roofline model."""
    n = 1
    for s in shape:
        n *= s
    raw = 4 * n
    if mode == "topk":
        assert k is not None
        return (4 * k + 4 * k) / raw  # fp32 value + int32 index per entry
    if mode == "int8":
        return (n + 4) / raw
    if mode == "none":
        return 1.0
    raise ValueError(mode)
