"""The three PTB-FLA generic algorithms, as first-class framework features.

Paper §III.A: "New PTB-FLA version offers: (1) the generic centralized FLA,
(2) the generic decentralized FLA, and (3) the new generic universal TDM
communication algorithm."

Each algorithm exists in two semantically-equivalent forms:

1. **Simulator form** (``*_sim``) — message-passing over the paper-faithful
   discrete-event testbed (:mod:`repro.core.ptbfla_sim`), with the paper's
   callback structure (server/client processing functions). This is the
   oracle.
2. **Collective form** — SPMD functions designed to run inside ``shard_map``
   over a mesh axis, where satellites are node groups along the ``data`` /
   ``pod`` axes and exchanges lower to ``ppermute``/``psum`` (DESIGN.md §3).

The TDM FLA is the paper's contribution: decentralized learning where the
per-round communication is *exactly* the universal TDM exchange ``getMeas``
over a (possibly time-varying) relation schedule — e.g. the visibility graph
of a Walker constellation — rather than a star or a clique.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tdm
from repro.core.ptbfla_sim import PTBFLASimulator, _Node, _as_gen
from repro.core.relation import Relation
from repro.core.schedule import TDMSchedule


# ===========================================================================
# Simulator (oracle) forms — the paper's callback-style generic algorithms
# ===========================================================================

def centralized_fla_sim(
    n_nodes: int,
    server_id: int,
    client_fn: Callable[[Any, Any], Any],
    server_fn: Callable[[Any, List[Any]], Any],
    client_data: Dict[int, Any],
    server_data: Any,
    n_rounds: int = 1,
    seed: int = 0,
) -> Any:
    """Generic centralized FLA (star topology), per round:

    1. server sends its current model to every client,
    2. client i computes ``client_fn(model, client_data[i])``,
    3. clients send updates back; server sets
       ``model = server_fn(model, updates)``.

    Communication uses the same sendMsg/rcvMsg substrate as Algorithm 1 (the
    star is the materialization of the abstract graph in centralized mode).
    Returns the server's final model.
    """
    sim = PTBFLASimulator(n_nodes, seed=seed)
    clients = [i for i in range(n_nodes) if i != server_id]

    def server_prog(node: _Node):
        model = server_data
        for _ in range(n_rounds):
            for c in clients:
                sim.send_msg(node.node_id, c, [node.time_slot, node.node_id, model])
            updates = []
            for _ in clients:
                while not node.inbox:
                    yield None  # block on recv
                msg = node.inbox.popleft()
                node.n_received += 1
                updates.append(msg[2])
            model = server_fn(model, updates)
            node.time_slot += 1
        return model

    def make_client(cid: int):
        def prog(node: _Node):
            result = None
            for _ in range(n_rounds):
                while not node.inbox:
                    yield None
                msg = node.inbox.popleft()
                node.n_received += 1
                model = msg[2]
                result = client_fn(model, client_data.get(cid))
                sim.send_msg(cid, server_id, [node.time_slot, cid, result])
                node.time_slot += 1
            return result

        return prog

    programs = {server_id: server_prog}
    for c in clients:
        programs[c] = make_client(c)
    results = sim.run(programs)
    return results[server_id]


def decentralized_fla_sim(
    n_nodes: int,
    update_fn: Callable[[Any, List[Any]], Any],
    node_data: Dict[int, Any],
    n_rounds: int = 1,
    seed: int = 0,
) -> Dict[int, Any]:
    """Generic decentralized FLA: the clique materialization. Every round,
    every node exchanges its value with all others (this is exactly getMeas
    over the clique relation — the paper's evaluation scenario) and applies
    ``update_fn(own, peer_values)``. Returns each node's final value."""
    sim = PTBFLASimulator(n_nodes, seed=seed)
    rel = Relation.clique(list(range(n_nodes)))

    def make_prog(node_id: int):
        def prog(node: _Node):
            value = node_data[node_id]
            peer_ids = rel.peers_of(node_id)
            for _ in range(n_rounds):
                got = yield from _as_gen(sim.get_meas(node, peer_ids, value))
                value = update_fn(value, got)
            return value

        return prog

    return sim.run({i: make_prog(i) for i in range(n_nodes)})


def tdm_fla_sim(
    schedule: TDMSchedule,
    n_nodes: int,
    local_fn: Callable[[int, int, Any], Any],
    mix_fn: Callable[[Any, List[Any]], Any],
    init: Dict[int, Any],
    seed: int = 0,
) -> Tuple[Dict[int, Any], PTBFLASimulator]:
    """The paper's contribution as an FL algorithm: per slot t, each node

    1. runs its local computation ``local_fn(node, t, value)`` (e.g. a local
       SGD step on its own data / its own orbital measurement),
    2. exchanges the result with its slot-t peers via **getMeas** (skipping
       the slot when it has no peers — the `odata=None` case),
    3. mixes: ``value = mix_fn(own, peer_values)``.

    Returns each node's final value plus the simulator (message stats).
    """
    sim = PTBFLASimulator(n_nodes, seed=seed)

    def make_prog(node_id: int):
        def prog(node: _Node):
            value = init[node_id]
            for t, rel in enumerate(schedule):
                value = local_fn(node_id, t, value)
                peer_ids = rel.peers_of(node_id)
                odata = value if peer_ids else None
                got = yield from _as_gen(sim.get_meas(node, peer_ids, odata))
                if got is not None:
                    value = mix_fn(value, got)
            return value

        return prog

    results = sim.run({i: make_prog(i) for i in range(n_nodes)})
    return results, sim


# ===========================================================================
# Collective (SPMD) forms — run inside shard_map over a mesh axis
# ===========================================================================

def centralized_round(update: Any, axis_name: str) -> Any:
    """FedAvg aggregation. In SPMD the star's up-link + server-average +
    down-link collapses into one all-reduce-mean over the node axis (the
    server is virtual — every node deterministically computes the same
    aggregate, which is bit-identical to receiving it from a server)."""
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), update)


def decentralized_round(value: Any, axis_name: str, n: int) -> Any:
    """Generic decentralized FLA round over the clique: every node averages
    its value with all peers' (uniform weights 1/n). Implemented as the TDM
    clique exchange — NOT pmean — so the lowering is the paper's multi-link
    getMeas (n-1 simultaneous ppermutes), benchmarkable against get1meas."""
    rel = Relation.clique(list(range(n)))

    def avg(x):
        total = tdm.neighbor_sum(x, rel, axis_name) + x
        return total / n

    return jax.tree.map(avg, value)


@dataclass(frozen=True)
class TDMFLAConfig:
    """Config for the universal TDM FLA (collective form).

    comm: 'getmeas'      — multi-link; matchings issued concurrently (paper)
          'get1meas'     — single-link; matchings serialized (the baseline
                           primitive the paper generalizes)
    compression: 'none' | 'int8' | 'topk'
    topk_k: payload size for 'topk' (per leaf; the fused engine scales it)
    local_steps: local optimizer steps between TDM slots (H in local-SGD)
    fused: route :func:`tdm_fla_round` through the flat-buffer exchange
           engine (:mod:`repro.core.fused`) — M collectives per round
           instead of L×M for an L-leaf model. Uncompressed results are
           bit-identical; see fused.py for the compressed-mode contract.
    """

    comm: str = "getmeas"
    compression: str = "none"
    topk_k: int = 64
    choco_gamma: float = 0.4
    local_steps: int = 1
    fused: bool = True

    def __post_init__(self):
        if self.comm not in ("getmeas", "get1meas"):
            raise ValueError(f"unknown comm mode {self.comm}")
        if self.compression not in ("none", "int8", "topk"):
            raise ValueError(f"unknown compression {self.compression}")


def tdm_mix(
    x: jax.Array,
    rel: Relation,
    axis_name: str,
    n: int,
    cfg: TDMFLAConfig = TDMFLAConfig(),
    residual: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """One TDM-FLA mixing step for a single array over relation ``rel``.

    Metropolis-weighted gossip x_i <- W_ii x_i + sum_j W_ij x_j where the
    neighbor values travel via the selected TDM primitive, optionally
    compressed. Isolated nodes keep their value (paper skip-slot).
    Returns (mixed, new_residual) — residual is used by top-k error feedback.
    """
    if len(rel) == 0:
        return x, residual
    if cfg.compression == "topk":
        # CHOCO-Gossip: the provably-convergent way to gossip ABSOLUTE
        # values under sparsified exchange (naive error feedback only works
        # for additive deltas — see tdm.neighbor_sum_topk's contract).
        state = residual if isinstance(residual, tdm.ChocoState) else tdm.choco_init(x)
        mixed, new_state = tdm.choco_gossip_round(
            x, state, rel, axis_name, n, cfg.topk_k, gamma=cfg.choco_gamma
        )
        return mixed, new_state
    if cfg.compression == "int8":
        w = 1.0 / (1.0 + rel.max_degree())
        summed = tdm.neighbor_sum_int8(x, rel, axis_name)
        idx = jax.lax.axis_index(axis_name)
        deg = jnp.asarray([rel.degree(v) for v in range(n)], dtype=x.dtype)[idx]
        mixed = x + w * (summed - deg * x)
        return mixed, residual
    # Uncompressed: full Metropolis gossip via the selected primitive.
    if cfg.comm == "getmeas":
        return tdm.gossip_avg(x, rel, axis_name, n), residual
    # get1meas: serialized matchings — same algebra, chained transfers.
    return tdm.gossip_avg_serial(x, rel, axis_name, n), residual


def tdm_fla_round(
    params: Any,
    rel: Relation,
    axis_name: str,
    n: int,
    cfg: TDMFLAConfig = TDMFLAConfig(),
    residuals: Any = None,
) -> Tuple[Any, Any]:
    """One TDM-FLA mixing round over a parameter pytree.

    With ``cfg.fused`` (the default) the pytree is flattened into contiguous
    dtype-bucketed buffers and mixed through the fused exchange engine —
    exactly M collectives per round for an M-matching relation, regardless
    of leaf count. ``cfg.fused=False`` applies :func:`tdm_mix` leaf by leaf
    (L×M collectives); both paths are bit-identical when uncompressed.

    The ``residuals`` carry (CHOCO state) is path-specific: per-leaf returns
    a pytree of per-leaf states, fused returns per-buffer states. Pass back
    only what the same path returned.
    """
    if cfg.fused:
        from repro.core import fused as fused_lib

        return fused_lib.fused_tdm_fla_round(
            params, rel, axis_name, n, cfg, residuals
        )
    leaves, treedef = jax.tree.flatten(params)
    if residuals is None:
        res_leaves = [None] * len(leaves)
    else:
        res_leaves = jax.tree.flatten(
            residuals, is_leaf=lambda x: isinstance(x, tdm.ChocoState)
        )[0]
    out, new_res = [], []
    for leaf, res in zip(leaves, res_leaves):
        mixed, r = tdm_mix(leaf, rel, axis_name, n, cfg, res)
        out.append(mixed)
        new_res.append(r)
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, new_res)


# ===========================================================================
# Convergence math (used by tests + EXPERIMENTS.md §Paper-validation)
# ===========================================================================

def consensus_error(values: Sequence[np.ndarray]) -> float:
    """Max_i ||x_i - mean|| / ||mean|| — disagreement across the node set."""
    stack = np.stack([np.asarray(v, dtype=np.float64) for v in values])
    mean = stack.mean(axis=0)
    denom = max(float(np.linalg.norm(mean)), 1e-30)
    return float(np.max(np.linalg.norm(stack - mean, axis=tuple(range(1, stack.ndim))))) / denom


def rounds_to_consensus(
    W: np.ndarray, tol: float = 1e-6, max_rounds: int = 100_000
) -> int:
    """Rounds of mixing with matrix W until worst-case disagreement < tol
    (from the spectral gap: (1-gap)^t < tol)."""
    from repro.core.gossip import spectral_gap

    gap = spectral_gap(W)
    if gap <= 0:
        return -1
    t = int(np.ceil(np.log(tol) / np.log(max(1e-12, 1.0 - gap))))
    return min(t, max_rounds)
