"""Replica decode: per-satellite KV/decode-state caches behind one fleet.

Two interchangeable decoders drive the serving engine:

- :class:`NullDecoder` — a pure-host deterministic token source. Zero jax,
  zero devices; it exists so the transport/scheduling/audit logic (the
  part this subsystem actually adds) is testable fast and its benchmark
  layer is bit-deterministic for nightly trending.
- :class:`ModelDecoder` — the real thing: one model replica per satellite,
  decoded as a *stacked* ``shard_map`` program over a ``("replica",)``
  device mesh (params replicated, caches and token streams carried with a
  leading replica axis, one per-lane squeeze/restack inside the body —
  the same idiom as ``launch/fl_train.py``'s stacked FL rounds).

Both expose the same two calls: ``prefill_waves({replica_idx: prompts})``
admits whole waves (the transformer decode cache keeps a single scalar
``pos`` per replica, so lanes inside one replica cannot stagger — wave
discipline per replica, continuous batching across the fleet), and
``step(active_mask)`` advances every busy replica one decode step.

:class:`ReplicaFleet` owns the mapping satellite-id → replica lane state:
admission queues, lane occupancy, wave admission, drain-on-churn.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from repro import telemetry
from repro.serving import requests as rq

_NULL_MOD = 65521  # largest prime < 2**16: cheap LCG modulus


class NullDecoder:
    """Deterministic host-side decoder (no model, no devices).

    First token of a lane is a hash of its prompt; each step advances a
    per-lane LCG. Tokens are meaningless but reproducible — exactly what
    the transport tests and the deterministic benchmark layer need.
    """

    def __init__(self, n_replicas: int, batch: int, vocab: int = 128):
        self.n_replicas = n_replicas
        self.batch = batch
        self.vocab = vocab
        self._state = np.zeros((n_replicas, batch), np.int64)

    def prefill_waves(
        self, waves: Dict[int, List[np.ndarray]]
    ) -> Dict[int, List[int]]:
        firsts: Dict[int, List[int]] = {}
        for ridx, prompts in waves.items():
            out: List[int] = []
            for lane, prompt in enumerate(prompts):
                h = (int(np.sum(prompt, dtype=np.int64)) * 31 + lane) % _NULL_MOD
                self._state[ridx, lane] = h
                out.append(h % self.vocab)
            firsts[ridx] = out
        return firsts

    def step(self, active: np.ndarray) -> np.ndarray:
        nxt = (self._state * 75 + 74) % _NULL_MOD
        self._state = np.where(active[:, None], nxt, self._state)
        return (self._state % self.vocab).astype(np.int64)


class ModelDecoder:
    """Stacked shard_map decode across a replica device mesh.

    Caches live stacked with a leading ``(R,)`` replica axis sharded over
    the mesh; ``prefill_waves`` runs the whole fleet through one padded
    prefill program (per prompt-length bucket, so jit retraces stay
    bounded) and merges each replica's new cache in under its admit flag;
    ``step`` advances only replicas flagged active — idle replicas keep
    their cache (and crucially their scalar ``pos``) frozen, so a replica
    can sit out contact gaps without walking its cache off ``max_len``.
    """

    def __init__(
        self,
        cfg,
        n_replicas: int,
        batch: int,
        max_len: int,
        seed: int = 0,
        mesh=None,
    ):
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.models import registry

        self._jax, self._jnp = jax, jnp
        self.cfg = cfg
        self.n_replicas = n_replicas
        self.batch = batch
        self.max_len = max_len
        self.bundle = registry.bundle(cfg)
        self.params, _ = self.bundle.init(jax.random.PRNGKey(seed))
        if mesh is None:
            devs = jax.devices()
            if len(devs) < n_replicas:
                raise ValueError(
                    f"ModelDecoder needs >= {n_replicas} devices "
                    f"(got {len(devs)}); use NullDecoder for host-only runs"
                )
            mesh = Mesh(np.array(devs[:n_replicas]), ("replica",))
        self.mesh = mesh

        cache0 = self.bundle.init_cache(batch, max_len)
        self._cache = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_replicas,) + x.shape), cache0
        )
        self._last = np.zeros((n_replicas, batch), np.int64)
        self._prefill_progs: Dict[int, object] = {}

        def decode_body(params, cache, tok, active):
            lane = jax.tree.map(lambda x: x[0], cache)
            logits, new = self.bundle.decode_fn(params, lane, {"token": tok[0]})
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            merged = jax.tree.map(
                lambda n, o: jnp.where(active[0], n, o), new, lane
            )
            return jax.tree.map(lambda x: x[None], merged), nxt[None]

        self._decode = jax.jit(
            shard_map(
                decode_body,
                mesh=mesh,
                in_specs=(P(), P("replica"), P("replica"), P("replica")),
                out_specs=(P("replica"), P("replica")),
                check_rep=False,
            ),
            donate_argnums=(1,),
        )

    def _prefill_prog(self, plen: int):
        prog = self._prefill_progs.get(plen)
        if prog is not None:
            return prog
        jax, jnp = self._jax, self._jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def body(params, cache, toks, admit):
            lane = jax.tree.map(lambda x: x[0], cache)
            logits, new = self.bundle.prefill_fn(
                params, {"tokens": toks[0]}, self.max_len
            )
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            merged = jax.tree.map(
                lambda n, o: jnp.where(admit[0], n, o), new, lane
            )
            return jax.tree.map(lambda x: x[None], merged), nxt[None]

        prog = jax.jit(
            shard_map(
                body,
                mesh=self.mesh,
                in_specs=(P(), P("replica"), P("replica"), P("replica")),
                out_specs=(P("replica"), P("replica")),
                check_rep=False,
            ),
            donate_argnums=(1,),
        )
        self._prefill_progs[plen] = prog
        return prog

    @staticmethod
    def _bucket(plen: int) -> int:
        b = 8
        while b < plen:
            b *= 2
        return b

    def prefill_waves(
        self, waves: Dict[int, List[np.ndarray]]
    ) -> Dict[int, List[int]]:
        jnp = self._jnp
        plen = self._bucket(max(len(p) for ps in waves.values() for p in ps))
        if plen + 1 > self.max_len:
            raise ValueError(
                f"prompt bucket {plen} does not fit max_len={self.max_len}"
            )
        toks = np.zeros((self.n_replicas, self.batch, plen), np.int32)
        admit = np.zeros((self.n_replicas,), np.bool_)
        for ridx, prompts in waves.items():
            admit[ridx] = True
            for lane, prompt in enumerate(prompts):
                toks[ridx, lane, plen - len(prompt):] = prompt  # left-pad
        self._cache, first = self._prefill_prog(plen)(
            self.params, self._cache, jnp.asarray(toks), jnp.asarray(admit)
        )
        first = np.asarray(first)
        out: Dict[int, List[int]] = {}
        for ridx, prompts in waves.items():
            out[ridx] = [int(first[ridx, lane]) for lane in range(len(prompts))]
            self._last[ridx] = first[ridx]
        return out

    def step(self, active: np.ndarray) -> np.ndarray:
        jnp = self._jnp
        self._cache, nxt = self._decode(
            self.params,
            self._cache,
            jnp.asarray(self._last[:, :, None].astype(np.int32)),
            jnp.asarray(active.astype(np.bool_)),
        )
        nxt = np.asarray(nxt, np.int64)
        self._last = np.where(active[:, None], nxt, self._last)
        return self._last.copy()


class ReplicaFleet:
    """Slot-aware continuous batching across the satellite replica set.

    Each replica runs wave discipline (a new wave is admitted only when its
    lanes are all free — the decode cache is one unit per replica); the
    *fleet* batches continuously: waves start and finish independently
    across replicas, and requests finishing early inside a wave release
    their response immediately while the wave's stragglers keep decoding.
    """

    def __init__(self, replica_ids: Sequence[int], batch: int, decoder):
        self.replica_ids: List[int] = sorted(int(s) for s in replica_ids)
        self.index = {sat: i for i, sat in enumerate(self.replica_ids)}
        self.batch = batch
        self.decoder = decoder
        self.queues: Dict[int, Deque[rq.InferenceRequest]] = {
            sat: deque() for sat in self.replica_ids
        }
        self.lanes: Dict[int, List[Optional[rq.InferenceRequest]]] = {
            sat: [None] * batch for sat in self.replica_ids
        }

    # ------------------------------------------------------------- queries
    def queued(self, sat: int) -> int:
        return len(self.queues[sat])

    def busy(self, sat: int) -> bool:
        return any(r is not None for r in self.lanes[sat])

    def active_requests(self, sat: int) -> List[rq.InferenceRequest]:
        return [r for r in self.lanes[sat] if r is not None and not r.done]

    def occupancy(self) -> float:
        """Active decode lanes / total lanes (fleet utilization gauge)."""
        total = len(self.replica_ids) * self.batch
        if total == 0:
            return 0.0
        busy = sum(
            1
            for sat in self.replica_ids
            for r in self.lanes[sat]
            if r is not None and not r.done
        )
        return busy / total

    # ----------------------------------------------------------- admission
    def enqueue(self, sat: int, req: rq.InferenceRequest) -> None:
        self.queues[sat].append(req)

    def admit(self, eligible) -> Dict[int, List[rq.InferenceRequest]]:
        """Start a wave on every eligible idle replica with queued work.

        Returns the admitted requests per satellite; each already carries
        its first decoded token (prefill emits it), so a ``max_new=1``
        request is complete straight out of admission.
        """
        waves: Dict[int, List[rq.InferenceRequest]] = {}
        prompts: Dict[int, List[np.ndarray]] = {}
        for sat in self.replica_ids:
            if sat not in eligible or self.busy(sat) or not self.queues[sat]:
                continue
            wave = [
                self.queues[sat].popleft()
                for _ in range(min(self.batch, len(self.queues[sat])))
            ]
            for lane, req in enumerate(wave):
                self.lanes[sat][lane] = req
            waves[sat] = wave
            prompts[self.index[sat]] = [r.prompt for r in wave]
        if not waves:
            return {}
        firsts = self.decoder.prefill_waves(prompts)
        for sat, wave in waves.items():
            for lane, req in enumerate(wave):
                req.out.append(int(firsts[self.index[sat]][lane]))
            if all(r.done for r in wave):
                # one-token requests: the wave completed at prefill, so the
                # lanes free immediately (tick would never see it active)
                self.lanes[sat] = [None] * self.batch
        return waves

    # -------------------------------------------------------------- decode
    def tick(self) -> Dict[int, List[rq.InferenceRequest]]:
        """One decode step for every replica with unfinished lanes.

        Returns the requests that just finished, keyed by satellite; fully
        finished waves release their lanes (the replica goes idle and can
        admit again next admission pass)."""
        active = np.zeros((len(self.replica_ids),), np.bool_)
        for i, sat in enumerate(self.replica_ids):
            active[i] = bool(self.active_requests(sat))
        if not active.any():
            return {}
        toks = self.decoder.step(active)
        finished: Dict[int, List[rq.InferenceRequest]] = {}
        for i, sat in enumerate(self.replica_ids):
            if not active[i]:
                continue
            for lane, req in enumerate(self.lanes[sat]):
                if req is None or req.done:
                    continue
                req.out.append(int(toks[i, lane]))
                if req.done:
                    finished.setdefault(sat, []).append(req)
            if all(r is None or r.done for r in self.lanes[sat]):
                self.lanes[sat] = [None] * self.batch
        telemetry.get_recorder().counter(
            "serve.decode.steps", float(int(active.sum()))
        )
        return finished

    # --------------------------------------------------------------- churn
    def drain(self, sat: int) -> List[rq.InferenceRequest]:
        """A replica lost visibility: abandon its wave and queue.

        Returns every request that still needs serving (mid-decode lanes
        and the admission queue); finished lanes keep nothing — their
        responses already left the fleet. The lane state clears so a
        re-admitted replica starts idle."""
        if sat not in self.index:
            return []
        out = [r for r in self.lanes[sat] if r is not None and not r.done]
        out.extend(self.queues[sat])
        self.lanes[sat] = [None] * self.batch
        self.queues[sat].clear()
        if out:
            telemetry.get_recorder().counter("serve.fleet.drained", len(out))
        return out


__all__ = ["ModelDecoder", "NullDecoder", "ReplicaFleet"]
