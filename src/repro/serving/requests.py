"""Request model and workload synthesis for constellation serving.

An :class:`InferenceRequest` is the unit the whole serving path moves: it
is born at a ground station, rides the uplink contact graph to a satellite
replica, decodes there under the TDM slot structure, and its response
floods back down to the *origin* gateway. The mutable fields are engine
state — the request object itself is the single source of truth for where
a payload currently sits and how far through its lifecycle it is, so the
auditor can replay the whole run from the request set plus the per-slot
provenance records.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

# Lifecycle states, in order. ``queued`` covers both "waiting at the origin
# gateway" and "waiting in a replica's admission queue" (``node`` tells
# them apart); ``uplink``/``downlink`` mean in transit on the contact graph.
QUEUED = "queued"
UPLINK = "uplink"
ROUTED = "routed"
DECODING = "decoding"
DOWNLINK = "downlink"
DELIVERED = "delivered"

LIFECYCLE = (QUEUED, UPLINK, ROUTED, DECODING, DOWNLINK, DELIVERED)


@dataclasses.dataclass
class InferenceRequest:
    """One user request plus its engine-owned lifecycle state."""

    rid: int
    gateway: int                 # origin ground-station node id
    prompt: np.ndarray           # int32 token ids
    max_new: int
    arrival_slot: int = 0

    # --- engine-owned mutable state
    status: str = QUEUED
    node: Optional[int] = None   # current holder while in transit/queued
    replica: Optional[int] = None  # serving satellite once routed
    out: List[int] = dataclasses.field(default_factory=list)
    retries: int = 0             # churn re-injections (never dropped)
    hops_up: int = 0
    hops_down: int = 0

    # --- slot timestamps (engine slot indices; -1 = not reached yet)
    submitted_slot: int = -1
    routed_slot: int = -1
    admitted_slot: int = -1
    first_token_slot: int = -1
    completed_slot: int = -1
    delivered_slot: int = -1

    @property
    def done(self) -> bool:
        """Decode finished (response exists, delivery may still be pending)."""
        return len(self.out) >= self.max_new

    @property
    def delivered(self) -> bool:
        return self.status == DELIVERED

    @property
    def latency_slots(self) -> int:
        """Submit → response-at-origin-gateway, in engine slots."""
        if self.delivered_slot < 0 or self.submitted_slot < 0:
            return -1
        return self.delivered_slot - self.submitted_slot

    @property
    def ttft_slots(self) -> int:
        """Submit → first decoded token, in engine slots."""
        if self.first_token_slot < 0 or self.submitted_slot < 0:
            return -1
        return self.first_token_slot - self.submitted_slot

    def requeue(self) -> None:
        """Churn re-injection: back to the origin gateway, decode restarts.

        Any tokens already decoded on a now-dead replica are gone with it,
        so the request re-enters the uplink from scratch — re-routed, never
        lost. Hop counters keep accumulating (the audit trail records the
        abandoned legs too)."""
        self.status = QUEUED
        self.node = self.gateway
        self.replica = None
        self.out = []
        self.retries += 1


def synthesize_workload(
    n_requests: int,
    gateways: Sequence[int],
    *,
    rate_per_slot: float = 2.0,
    prompt_len: Tuple[int, int] = (4, 12),
    max_new: int = 8,
    vocab: int = 128,
    seed: int = 0,
) -> List[InferenceRequest]:
    """Deterministic synthetic arrival process.

    Arrival slots advance at ``rate_per_slot`` requests per engine slot
    (``arrival_slot = floor(k / rate)`` — deterministic so tests and the
    benchmark baselines can reason about offered load exactly); gateways
    and prompt contents come from a seeded generator.
    """
    if not gateways:
        raise ValueError("need at least one gateway")
    if rate_per_slot <= 0:
        raise ValueError("rate_per_slot must be positive")
    rng = np.random.default_rng(seed)
    gws = sorted(int(g) for g in gateways)
    lo, hi = prompt_len
    reqs: List[InferenceRequest] = []
    for k in range(n_requests):
        plen = int(rng.integers(lo, hi + 1))
        reqs.append(
            InferenceRequest(
                rid=k,
                gateway=gws[int(rng.integers(0, len(gws)))],
                prompt=rng.integers(0, vocab, plen).astype(np.int32),
                max_new=max_new,
                arrival_slot=int(k // rate_per_slot),
            )
        )
    return reqs


__all__ = [
    "DECODING",
    "DELIVERED",
    "DOWNLINK",
    "InferenceRequest",
    "LIFECYCLE",
    "QUEUED",
    "ROUTED",
    "UPLINK",
    "synthesize_workload",
]
