"""Route-provenance audit for serving runs.

The serving twin of :func:`repro.telemetry.audit.audit_window_programs`:
replay the engine's per-slot provenance records against the base TDM
schedule and the request set, and return the same structured
:class:`~repro.telemetry.audit.AuditReport` the mission-control layer
already knows how to render, gate on, and embed in reports.

Checks, per the store-and-forward contract:

- **no-such-link** — every send (src, dst) rides an edge present in the
  slot's scheduled relation *restricted to the recorded alive set*;
- **dead-node** — no send touches a node outside the alive set;
- **fanout** — a payload takes at most one hop per slot;
- **misroute** — each transport leg is contiguous (hop k+1 departs where
  hop k landed; a churn requeue legally resets the chain to the origin
  gateway), requests start at their gateway, responses start at the
  serving replica and end at the origin gateway;
- **lost-request / duplicate-delivery** — every submitted request is
  delivered exactly once (churn re-routes, never drops).

Violation ``window`` fields carry the engine slot index; ``payload``
carries the request id.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.relation import Relation
from repro.serving import requests as rq
from repro.serving.engine import SlotRecord
from repro.telemetry.audit import AuditReport, AuditViolation, PayloadTrail


def audit_serving_run(
    records: Sequence[SlotRecord],
    requests: Sequence[rq.InferenceRequest],
    base_rels: Sequence[Relation],
    *,
    gateways: Sequence[int],
    replicas: Sequence[int],
) -> AuditReport:
    """Replay a serving run's provenance hop by hop."""
    epoch = len(base_rels)
    gw = set(int(g) for g in gateways)
    reps = set(int(r) for r in replicas)
    report = AuditReport(n_windows=len(records), n_payloads=len(requests))
    viol = report.violations

    by_rid: Dict[int, rq.InferenceRequest] = {r.rid: r for r in requests}
    sends_by_rid: Dict[int, List] = {r.rid: [] for r in requests}
    # chain resets, chronological: ("requeue", slot, gateway) restarts the
    # trail at the origin gateway; ("reemit", slot, replica) restarts the
    # downlink leg at the replica that held the decoded response
    resets_by_rid: Dict[int, List] = {r.rid: [] for r in requests}
    delivered_count: Dict[int, int] = {r.rid: 0 for r in requests}

    # --- per-slot legality: links exist, nodes live, fanout <= 1
    for recd in records:
        rel = base_rels[recd.t].restrict(recd.alive)
        seen_this_slot: Dict[int, int] = {}
        for send in recd.sends:
            report.n_hops += 1
            report.events_checked += 1
            if send.rid not in by_rid:
                viol.append(AuditViolation(
                    "phantom-hop", send.slot,
                    f"send for unknown request {send.rid}", send.rid,
                ))
                continue
            if send.src not in recd.alive or send.dst not in recd.alive:
                viol.append(AuditViolation(
                    "dead-node", send.slot,
                    f"hop {send.src}->{send.dst} touches a dead node",
                    send.rid,
                ))
            if (send.src, send.dst) not in rel.pairs:
                viol.append(AuditViolation(
                    "no-such-link", send.slot,
                    f"hop {send.src}->{send.dst} not in slot {recd.t}'s "
                    f"scheduled relation", send.rid,
                ))
            n = seen_this_slot.get(send.rid, 0) + 1
            seen_this_slot[send.rid] = n
            if n > 1:
                viol.append(AuditViolation(
                    "fanout", send.slot,
                    f"request took {n} hops in one slot", send.rid,
                ))
            sends_by_rid[send.rid].append(send)
        for rid, node in recd.requeued:
            report.events_checked += 1
            if rid in resets_by_rid:
                resets_by_rid[rid].append(("requeue", recd.slot, None))
        for rid, node in recd.reemitted:
            report.events_checked += 1
            if rid in resets_by_rid:
                resets_by_rid[rid].append(("reemit", recd.slot, node))
        for rid in recd.delivered:
            report.events_checked += 1
            if rid in delivered_count:
                delivered_count[rid] += 1

    # --- per-request trail contiguity and terminal checks
    for req in requests:
        sends = sorted(sends_by_rid[req.rid], key=lambda s: s.slot)
        resets = sorted(resets_by_rid[req.rid], key=lambda e: e[1])
        expect_src: Optional[int] = req.gateway
        kind_prev = "req"
        ri = 0
        for send in sends:
            # consume chain resets that took effect at or before this hop:
            # a churn requeue restarts the trail at the origin gateway, a
            # response re-emission restarts the downlink leg at the replica
            while ri < len(resets) and resets[ri][1] <= send.slot:
                what, _, node = resets[ri]
                ri += 1
                if what == "requeue":
                    expect_src, kind_prev = req.gateway, "req"
                else:
                    expect_src, kind_prev = node, "resp"
            if send.kind == "resp" and kind_prev == "req":
                # decode handoff: the downlink leg must depart a replica,
                # and specifically the replica the uplink chain ended at —
                # otherwise a request-side detour right before decode
                # would vanish into the handoff
                if send.src not in reps:
                    viol.append(AuditViolation(
                        "misroute", send.slot,
                        f"response departs non-replica node {send.src}",
                        req.rid,
                    ))
                elif expect_src is not None and send.src != expect_src:
                    viol.append(AuditViolation(
                        "misroute", send.slot,
                        f"response departs {send.src}, uplink chain ended "
                        f"at {expect_src}", req.rid,
                    ))
                expect_src = send.src
                kind_prev = "resp"
            if send.src != expect_src:
                viol.append(AuditViolation(
                    "misroute", send.slot,
                    f"hop departs {send.src}, chain expected {expect_src}",
                    req.rid,
                ))
            expect_src = send.dst
        if delivered_count[req.rid] == 0:
            viol.append(AuditViolation(
                "lost-request", req.submitted_slot,
                f"request submitted at slot {req.submitted_slot} never "
                f"delivered (status={req.status})", req.rid,
            ))
        elif delivered_count[req.rid] > 1:
            viol.append(AuditViolation(
                "double-queue", req.delivered_slot,
                f"delivered {delivered_count[req.rid]} times", req.rid,
            ))
        else:
            report.n_delivered += 1
            if sends and sends[-1].dst != req.gateway:
                viol.append(AuditViolation(
                    "misroute", sends[-1].slot,
                    f"final hop lands at {sends[-1].dst}, origin gateway is "
                    f"{req.gateway}", req.rid,
                ))
        report.trails[(req.arrival_slot, req.gateway)] = PayloadTrail(
            window=req.arrival_slot,
            source=req.gateway,
            age=req.retries,
            sink=req.replica,
            hops=tuple((s.slot, s.src, s.dst) for s in sends),
        )
    return report


__all__ = ["audit_serving_run"]
