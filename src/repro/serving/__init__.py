"""Constellation-scale serving: TDM-slotted inference over the ground segment.

The inference-side twin of :mod:`repro.groundseg` (ISSUE 10): user
requests arrive at ground stations, ride the earliest-delivery contact-
graph routes up to satellites holding model replicas, decode there under
the TDM slot structure with fleet-level continuous batching, and the
responses descend to their origin gateways — with elastic replica
membership under orbital churn and full flight-recorder instrumentation.

- :mod:`repro.serving.requests` — the request lifecycle model
  (queued → uplink → routed → decoding → downlink → delivered) and
  deterministic workload synthesis.
- :mod:`repro.serving.replica`  — per-satellite decode state: the
  :class:`ReplicaFleet` continuous-batching scheduler over either a pure-
  host :class:`NullDecoder` (transport logic, fast tests, deterministic
  benchmark layer) or the stacked shard_map :class:`ModelDecoder` (one
  model replica per device).
- :mod:`repro.serving.engine`   — the slot loop: transport, admission,
  decode, churn handling, per-slot provenance records, telemetry.
- :mod:`repro.serving.audit`    — replay the provenance against the TDM
  schedule (slot-legal links only, contiguous trails, every request
  delivered exactly once) into a :class:`repro.telemetry.AuditReport`.

Quick use::

    from repro.constellation.scenario import smoke_scenario
    from repro import serving

    scn = smoke_scenario()
    fleet = serving.ReplicaFleet([0, 3], batch=2,
                                 decoder=serving.NullDecoder(2, 2))
    eng = serving.ServingEngine.from_scenario(scn, fleet)
    work = serving.synthesize_workload(8, scn.ground_ids, seed=0)
    report = eng.run(work)
    verdict = serving.audit_serving_run(
        report.records, report.requests, eng.base_rels,
        gateways=eng.gateways, replicas=sorted(eng.replicas))
    assert verdict.ok and not report.undelivered
"""

from repro.serving.audit import audit_serving_run
from repro.serving.engine import (
    Send,
    ServeReport,
    ServingEngine,
    SlotRecord,
)
from repro.serving.replica import ModelDecoder, NullDecoder, ReplicaFleet
from repro.serving.requests import InferenceRequest, synthesize_workload

__all__ = [
    "InferenceRequest",
    "ModelDecoder",
    "NullDecoder",
    "ReplicaFleet",
    "Send",
    "ServeReport",
    "ServingEngine",
    "SlotRecord",
    "audit_serving_run",
    "synthesize_workload",
]
