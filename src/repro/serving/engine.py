"""TDM-slotted serving engine: the inference-side twin of the ground segment.

The engine advances in *engine slots* — the materialized TDM schedule
replayed cyclically (one schedule pass = one epoch). Each slot:

1. new requests arrive at their gateways (ground stations);
2. replica membership refreshes against the contact graph (a satellite
   that lost visibility drains its batch; its requests re-route);
3. transport: every in-transit payload takes at most one hop along the
   earliest-delivery DP policy (``groundseg/routing.py``) — requests climb
   toward the nearest in-service replica (sinks = active replicas),
   responses descend toward their *origin* gateway (sinks = {gateway});
   payloads with no useful move hold (delay-tolerant);
4. admission: idle in-service replicas admit a wave from their queue
   (prefill emits the first token);
5. decode: ``decode_steps_per_slot`` fleet ticks; requests reaching
   ``max_new`` become responses at their replica and enter the downlink
   on the *next* slot (data decoded during slot t forwards no earlier
   than t+1 — the store-and-forward contract the auditor checks).

Routing tables are the same backward DP the FL ground segment uses,
cached LRU-style per (alive-set, sink-set) exactly like
``MultiWindowRouter`` caches its window tables; a membership change mid-
epoch is safe because policy row ``t`` only depends on rows ``> t``.

Everything the run did is recorded: per-slot provenance (alive set, every
(src, dst, rid) send, requeues, deliveries) for the route-provenance
auditor in :mod:`repro.serving.audit`, plus PR 6/9 telemetry — lifecycle
counters (queued → routed → decoding → delivered), queue-depth / TTFT /
latency histograms, per-slot spans under tracing.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import telemetry
from repro.core.relation import Relation
from repro.groundseg import routing
from repro.launch.elastic import ReplicaMembership
from repro.serving import requests as rq
from repro.serving.replica import ReplicaFleet
from repro.telemetry.metrics import AGE_BUCKETS, COUNT_BUCKETS

# Bounded like groundseg.routing.TABLE_CACHE_MAX: one uplink table per
# alive-set plus one downlink table per (alive-set, gateway).
TABLE_CACHE_MAX = 16


@dataclasses.dataclass(frozen=True)
class Send:
    """One payload hop taken in one engine slot."""

    slot: int
    src: int
    dst: int
    kind: str        # "req" (uplink) | "resp" (downlink)
    rid: int


@dataclasses.dataclass(frozen=True)
class SlotRecord:
    """Provenance of one engine slot — the auditor's replay unit."""

    slot: int
    t: int                       # epoch-relative schedule index
    alive: frozenset
    active_replicas: frozenset
    sends: Tuple[Send, ...]
    requeued: Tuple[Tuple[int, int], ...]   # (rid, node it was pulled from)
    # (rid, replica) — response re-emitted at its replica after its
    # downlink relay died (tokens survive; the downlink leg restarts)
    reemitted: Tuple[Tuple[int, int], ...]
    delivered: Tuple[int, ...]
    admitted: Tuple[int, ...]


@dataclasses.dataclass
class ServeReport:
    """Outcome of a serving run: per-request records plus the summary."""

    n_slots: int
    epoch_slots: int
    requests: List[rq.InferenceRequest]
    records: List[SlotRecord]
    wall_s: float = 0.0          # simulated wall clock (slot durations)

    @property
    def delivered(self) -> List[rq.InferenceRequest]:
        return [r for r in self.requests if r.delivered]

    @property
    def undelivered(self) -> List[rq.InferenceRequest]:
        return [r for r in self.requests if not r.delivered]

    def summary(self) -> Dict[str, object]:
        done = self.delivered
        lat = np.array([r.latency_slots for r in done], np.float64)
        ttft = np.array(
            [r.ttft_slots for r in done if r.ttft_slots >= 0], np.float64
        )
        hops = [r.hops_up + r.hops_down for r in done]
        out: Dict[str, object] = {
            "n_requests": len(self.requests),
            "delivered": len(done),
            "undelivered": len(self.undelivered),
            "n_slots": self.n_slots,
            "epochs": self.n_slots / self.epoch_slots if self.epoch_slots else 0,
            "retries": sum(r.retries for r in self.requests),
            "tokens": sum(len(r.out) for r in done),
        }
        if len(done):
            out.update(
                latency_p50_slots=float(np.percentile(lat, 50)),
                latency_p99_slots=float(np.percentile(lat, 99)),
                ttft_p50_slots=float(np.percentile(ttft, 50)) if len(ttft) else -1.0,
                mean_hops=float(np.mean(hops)),
                req_per_slot=len(done) / self.n_slots,
            )
        if self.wall_s > 0 and len(done):
            out["req_per_s"] = len(done) / self.wall_s
            out["wall_s"] = self.wall_s
        return out


class ServingEngine:
    """Constellation-scale serving over a TDM slot schedule."""

    def __init__(
        self,
        slots: Sequence[Relation],
        n_nodes: int,
        gateways: Sequence[int],
        fleet: ReplicaFleet,
        *,
        slot_durations: Optional[Sequence[float]] = None,
        decode_steps_per_slot: int = 1,
        grace_slots: int = 0,
    ):
        if not slots:
            raise ValueError("need a non-empty slot schedule")
        self.base_rels: List[Relation] = list(slots)
        self.epoch = len(self.base_rels)
        self.n_nodes = n_nodes
        self.gateways = sorted(int(g) for g in gateways)
        if not self.gateways:
            raise ValueError("need at least one gateway")
        self.fleet = fleet
        self.replicas = frozenset(fleet.replica_ids)
        bad = self.replicas & set(self.gateways)
        if bad:
            raise ValueError(f"nodes {sorted(bad)} are both gateway and replica")
        self.membership = ReplicaMembership(self.replicas, grace_slots=grace_slots)
        self.alive: set = set(range(n_nodes))
        self.slot_durations = (
            [float(d) for d in slot_durations] if slot_durations else None
        )
        if self.slot_durations is not None and len(self.slot_durations) != self.epoch:
            raise ValueError("slot_durations must align with the slot schedule")
        self.decode_steps_per_slot = decode_steps_per_slot

        self.slot = 0
        self.pending: Dict[int, rq.InferenceRequest] = {}
        self.records: List[SlotRecord] = []
        self._tables: OrderedDict = OrderedDict()
        self._visible_cache: Dict[frozenset, frozenset] = {}
        self._pending_requeues: List[Tuple[int, int]] = []
        self._pending_reemits: List[Tuple[int, int]] = []

    # ------------------------------------------------------------ scenario
    @classmethod
    def from_scenario(
        cls,
        scn,
        fleet: ReplicaFleet,
        *,
        decode_steps_per_slot: int = 1,
        grace_slots: int = 0,
    ) -> "ServingEngine":
        """Wire an engine onto a :class:`~repro.constellation.scenario.
        Scenario`: TDM slots from the cached schedule, gateways = ground
        stations, simulated wall clock from the per-slot durations."""
        sched = scn.schedule()
        return cls(
            list(sched.tdm),
            scn.n_nodes,
            sorted(scn.ground_ids),
            fleet,
            slot_durations=[s.duration_s for s in sched.slots],
            decode_steps_per_slot=decode_steps_per_slot,
            grace_slots=grace_slots,
        )

    # ------------------------------------------------------------- routing
    def _table(self, sinks: frozenset) -> Optional[routing.RoutingTable]:
        """Earliest-delivery DP table for the current alive set, LRU-cached
        per (alive, sinks) — the MultiWindowRouter caching discipline."""
        if not sinks:
            return None
        key = (frozenset(self.alive), sinks)
        rec = telemetry.get_recorder()
        table = self._tables.get(key)
        if table is not None:
            self._tables.move_to_end(key)
            rec.counter("serve.router.table_cache.hit")
            return table
        rec.counter("serve.router.table_cache.miss")
        rels = [r.restrict(self.alive) for r in self.base_rels]
        table = routing.earliest_delivery_routes(rels, self.n_nodes, sinks)
        self._tables[key] = table
        while len(self._tables) > TABLE_CACHE_MAX:
            self._tables.popitem(last=False)
        return table

    def _visible_replicas(self) -> frozenset:
        """Replicas alive and present on at least one slot of the epoch's
        restricted contact graph — the visibility signal membership eats."""
        key = frozenset(self.alive)
        vis = self._visible_cache.get(key)
        if vis is None:
            seen: set = set()
            for rel in self.base_rels:
                seen |= rel.restrict(key).participants() & self.replicas
            vis = frozenset(seen & key)
            self._visible_cache[key] = vis
        return vis

    # --------------------------------------------------------------- churn
    def fail(self, node: int) -> None:
        """Kill a satellite mid-run: re-route, never lose.

        Payloads held *at* the dead node re-inject at their origin gateway
        (a response whose replica is also gone restarts decode from
        scratch); if the node is a replica its batch drains. Routing
        tables for the new alive set build lazily on next use."""
        node = int(node)
        if node in self.gateways:
            raise ValueError("ground stations do not fail in this model")
        if node not in self.alive:
            return
        self.alive.discard(node)
        telemetry.get_recorder().counter("serve.churn.failed")
        self._refresh_membership()
        for req in list(self.pending.values()):
            if req.status in (rq.UPLINK, rq.QUEUED) and req.node == node:
                self._requeue(req)
            elif req.status == rq.DOWNLINK and req.node == node:
                # The response payload died with its relay. Re-emit it at
                # the replica that decoded it if that replica still serves;
                # otherwise the whole request restarts.
                if (
                    req.replica is not None
                    and req.replica in self.alive
                    and req.replica in self.membership.active
                ):
                    req.node = req.replica
                    self._pending_reemits.append((req.rid, req.replica))
                    telemetry.get_recorder().counter("serve.requests.reemitted")
                else:
                    self._requeue(req)

    def restore(self, node: int) -> None:
        """Bring a satellite back; membership re-admits it after grace."""
        self.alive.add(int(node))
        telemetry.get_recorder().counter("serve.churn.restored")
        self._refresh_membership()

    def _refresh_membership(self) -> None:
        delta = self.membership.update(self._visible_replicas())
        for sat in sorted(delta.drained):
            for req in self.fleet.drain(sat):
                self._requeue(req)
        if delta.admitted:
            telemetry.get_recorder().counter(
                "serve.churn.readmitted", len(delta.admitted)
            )
        telemetry.set_gauge(
            "serve.replicas.active", float(len(self.membership.active))
        )

    def _requeue(self, req: rq.InferenceRequest) -> None:
        pulled_from = req.node if req.node is not None else req.gateway
        req.requeue()
        self._pending_requeues.append((req.rid, int(pulled_from)))
        telemetry.get_recorder().counter("serve.requests.requeued")

    # ---------------------------------------------------------------- step
    def submit(self, req: rq.InferenceRequest) -> None:
        """Inject a request at its gateway (counted from the current slot)."""
        req.submitted_slot = self.slot
        req.status = rq.QUEUED
        req.node = req.gateway
        self.pending[req.rid] = req
        telemetry.get_recorder().counter("serve.requests.submitted")

    def step(self) -> bool:
        """Advance one engine slot. Returns True while work remains."""
        s, t = self.slot, self.slot % self.epoch
        rec = telemetry.get_recorder()
        sends: List[Send] = []
        delivered: List[int] = []
        admitted_rids: List[int] = []

        with rec.span("serve.slot", cat="serve", slot=s):
            self._refresh_membership()
            serving = frozenset(self.membership.active & self.alive)

            # --- transport: snapshot positions, then move (≤1 hop/payload)
            up = self._table(serving)
            movers = [
                r
                for r in self.pending.values()
                if r.status in (rq.QUEUED, rq.UPLINK, rq.DOWNLINK)
                and r.node is not None
            ]
            for req in movers:
                if req.status == rq.DOWNLINK:
                    table = self._table(frozenset((req.gateway,)))
                else:
                    table = up
                if table is None:
                    continue
                nxt = table.policy[t][req.node]
                if nxt is None:
                    continue
                sends.append(Send(s, req.node, nxt, _kind(req), req.rid))
                req.node = nxt
                if req.status == rq.DOWNLINK:
                    req.hops_down += 1
                    if nxt == req.gateway:
                        self._deliver(req, s)
                        delivered.append(req.rid)
                else:
                    req.hops_up += 1
                    req.status = rq.UPLINK
                    if nxt in serving:
                        req.status = rq.ROUTED
                        req.replica = nxt
                        if req.routed_slot < 0:
                            req.routed_slot = s
                        self.fleet.enqueue(nxt, req)
                        rec.counter("serve.requests.routed")

            # --- admission: idle in-service replicas start a wave
            for sat, wave in self.fleet.admit(serving).items():
                for req in wave:
                    req.status = rq.DECODING
                    req.admitted_slot = s
                    req.first_token_slot = s
                    admitted_rids.append(req.rid)
                    rec.counter("serve.requests.admitted")
                    telemetry.observe(
                        "serve.ttft_slots", req.ttft_slots, buckets=COUNT_BUCKETS
                    )
                    if req.done:          # max_new == 1: done at prefill
                        self._complete(req, s)

            # --- decode ticks
            for _ in range(self.decode_steps_per_slot):
                for sat, reqs in self.fleet.tick().items():
                    for req in reqs:
                        self._complete(req, s)

            # --- per-slot instrumentation
            depth = sum(
                1 for r in self.pending.values() if r.status == rq.QUEUED
            ) + sum(self.fleet.queued(sat) for sat in self.fleet.replica_ids)
            telemetry.observe("serve.queue_depth", depth, buckets=COUNT_BUCKETS)
            telemetry.set_gauge("serve.fleet.occupancy", self.fleet.occupancy())

        self.records.append(
            SlotRecord(
                slot=s,
                t=t,
                alive=frozenset(self.alive),
                active_replicas=serving,
                sends=tuple(sends),
                requeued=tuple(self._pending_requeues),
                reemitted=tuple(self._pending_reemits),
                delivered=tuple(delivered),
                admitted=tuple(admitted_rids),
            )
        )
        self._pending_requeues = []
        self._pending_reemits = []
        self.slot += 1
        return bool(self.pending)

    def _complete(self, req: rq.InferenceRequest, s: int) -> None:
        req.status = rq.DOWNLINK          # enters transport next slot
        req.node = req.replica
        req.completed_slot = s
        telemetry.get_recorder().counter("serve.requests.completed")

    def _deliver(self, req: rq.InferenceRequest, s: int) -> None:
        req.status = rq.DELIVERED
        req.delivered_slot = s
        req.node = None
        self.pending.pop(req.rid, None)
        rec = telemetry.get_recorder()
        rec.counter("serve.requests.delivered")
        rec.counter("serve.tokens.delivered", len(req.out))
        telemetry.observe(
            "serve.latency_slots", req.latency_slots, buckets=COUNT_BUCKETS
        )
        telemetry.observe("serve.retries", req.retries, buckets=AGE_BUCKETS)

    # ----------------------------------------------------------------- run
    def run(
        self,
        workload: Sequence[rq.InferenceRequest],
        *,
        max_slots: Optional[int] = None,
        on_slot: Optional[Callable[["ServingEngine", int], None]] = None,
    ) -> ServeReport:
        """Drive a workload to completion (or the slot budget).

        ``on_slot(engine, slot)`` runs before each slot — the hook scripted
        churn (``engine.fail`` / ``engine.restore``) plugs into."""
        by_arrival: Dict[int, List[rq.InferenceRequest]] = {}
        for req in workload:
            by_arrival.setdefault(req.arrival_slot, []).append(req)
        last_arrival = max(by_arrival) if by_arrival else 0
        budget = max_slots if max_slots is not None else 50 * self.epoch
        while self.slot < budget:
            if on_slot is not None:
                on_slot(self, self.slot)
            for req in by_arrival.pop(self.slot, ()):
                self.submit(req)
            busy = self.step()
            if not busy and self.slot > last_arrival and not by_arrival:
                break
        wall = 0.0
        if self.slot_durations is not None:
            full, rem = divmod(self.slot, self.epoch)
            wall = full * sum(self.slot_durations) + sum(self.slot_durations[:rem])
        return ServeReport(
            n_slots=self.slot,
            epoch_slots=self.epoch,
            requests=list(workload),
            records=list(self.records),
            wall_s=wall,
        )


def _kind(req: rq.InferenceRequest) -> str:
    return "resp" if req.status == rq.DOWNLINK else "req"


__all__ = ["Send", "ServeReport", "ServingEngine", "SlotRecord", "TABLE_CACHE_MAX"]
