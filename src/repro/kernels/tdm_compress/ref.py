"""Pure-jnp oracle for blockwise int8 TDM payload compression."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _pad_to_block(x: jax.Array, block: int) -> jax.Array:
    pad = (-x.shape[0]) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    return x


def quantize_ref(x: jax.Array, block: int = 1024):
    """x: flat (n,) fp32, any n -> (q int8 (n,), scales (ceil(n/block),))."""
    n = x.shape[0]
    xp = _pad_to_block(x.astype(jnp.float32), block)
    nb = xp.shape[0] // block
    xb = xp.reshape(nb, block)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(nb * block)[:n], scale


def dequantize_ref(q: jax.Array, scale: jax.Array, block: int = 1024):
    n = q.shape[0]
    qp = _pad_to_block(q, block)
    nb = qp.shape[0] // block
    return (qp.reshape(nb, block).astype(jnp.float32) * scale[:, None]).reshape(
        nb * block
    )[:n]


def dequant_acc_ref(q: jax.Array, scale: jax.Array, acc: jax.Array, w,
                    block: int = 1024):
    """acc + w * dequant(q, scale) — oracle for the fused receive pass."""
    return acc.astype(jnp.float32) + jnp.asarray(w, jnp.float32) * dequantize_ref(
        q, scale, block
    )
