"""Pure-jnp oracle for blockwise int8 TDM payload compression."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x: jax.Array, block: int = 1024):
    """x: flat (n,) fp32, n % block == 0 -> (q int8 (n,), scales (n/block,))."""
    n = x.shape[0]
    nb = n // block
    xb = x.reshape(nb, block).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(n), scale


def dequantize_ref(q: jax.Array, scale: jax.Array, block: int = 1024):
    n = q.shape[0]
    nb = n // block
    return (q.reshape(nb, block).astype(jnp.float32) * scale[:, None]).reshape(n)
