"""Pure-jnp oracle for blockwise int8 TDM payload compression."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _pad_to_block(x: jax.Array, block: int) -> jax.Array:
    pad = (-x.shape[0]) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    return x


def blockwise_scales_ref(x: jax.Array, block: int = 1024) -> jax.Array:
    """Per-block symmetric quantization scales: ``max(absmax, 1e-12)/127``.

    The scale computation shared by every quantize path (local scales, and
    the relay's ``pmax``-shared scales) — a pure reduction, so the Pallas
    and ref quantizers both consume it bit-identically."""
    xp = _pad_to_block(x.astype(jnp.float32), block)
    xb = xp.reshape(xp.shape[0] // block, block)
    return jnp.maximum(jnp.max(jnp.abs(xb), axis=1), 1e-12) / 127.0


def quantize_ref(x: jax.Array, block: int = 1024):
    """x: flat (n,) fp32, any n -> (q int8 (n,), scales (ceil(n/block),))."""
    n = x.shape[0]
    xp = _pad_to_block(x.astype(jnp.float32), block)
    nb = xp.shape[0] // block
    xb = xp.reshape(nb, block)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(nb * block)[:n], scale


def quantize_scaled_ref(x: jax.Array, scales: jax.Array, block: int = 1024):
    """Quantize with CALLER-SUPPLIED blockwise scales (the quantize-once
    relay contract: scales are shared across the route via ``pmax``, so a
    payload is encoded exactly once end-to-end). x: flat (n,); scales:
    (ceil(n/block),) fp32 positive -> q int8 (n,)."""
    n = x.shape[0]
    xp = _pad_to_block(x.astype(jnp.float32), block)
    nb = xp.shape[0] // block
    xb = xp.reshape(nb, block)
    q = jnp.clip(jnp.round(xb / scales[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(nb * block)[:n]


def dequantize_ref(q: jax.Array, scale: jax.Array, block: int = 1024):
    n = q.shape[0]
    qp = _pad_to_block(q, block)
    nb = qp.shape[0] // block
    return (qp.reshape(nb, block).astype(jnp.float32) * scale[:, None]).reshape(
        nb * block
    )[:n]


def dequant_acc_ref(q: jax.Array, scale: jax.Array, acc: jax.Array, w,
                    block: int = 1024):
    """acc + w * dequant(q, scale) — oracle for the fused receive pass.

    ``q`` may be any integer dtype: int8 payloads on the gossip path, int16
    partial sums on the quantize-once relay path (integer-domain
    accumulation keeps multi-hop routes exact between the endpoints)."""
    return acc.astype(jnp.float32) + jnp.asarray(w, jnp.float32) * dequantize_ref(
        q, scale, block
    )


def topk_sparsify_ref(x: jax.Array, k: int, block: int = 1024):
    """Blockwise top-k magnitude sparsification — the jnp oracle for the
    fused select+scatter kernel.

    x: flat (n,) -> ``(dense (n,) fp32, vals (nb, k) fp32, idxs (nb, k)
    int32)`` where ``nb = ceil(n/block)`` and ``idxs`` are block-LOCAL
    positions. Selection key is ``|x|`` with NaN ranked above +inf (NaN
    never silently drops a coordinate); ties break toward the lowest
    index. ``vals``/``idxs`` are ordered by descending key — the exact
    selection order of the Pallas kernel, so the two implementations are
    comparable elementwise, not just as sets.
    """
    n = x.shape[0]
    xp = _pad_to_block(x.astype(jnp.float32), block)
    nb = xp.shape[0] // block
    xb = xp.reshape(nb, block)
    if k == 0:
        return (
            jnp.zeros((n,), jnp.float32),
            jnp.zeros((nb, 0), jnp.float32),
            jnp.zeros((nb, 0), jnp.int32),
        )
    key = jnp.where(jnp.isnan(xb), jnp.inf, jnp.abs(xb))
    order = jnp.argsort(-key, axis=1, stable=True)[:, :k]
    vals = jnp.take_along_axis(xb, order, axis=1)
    dense = jnp.zeros_like(xb).at[jnp.arange(nb)[:, None], order].set(vals)
    return dense.reshape(nb * block)[:n], vals, order.astype(jnp.int32)


def scatter_acc_ref(vals: jax.Array, idxs: jax.Array, acc: jax.Array, w,
                    block: int = 1024):
    """acc + w * scatter(vals at block-local idxs) — oracle for the fused
    top-k receive pass. vals/idxs: (nb, k) as produced by
    :func:`topk_sparsify_ref` (indices unique within each block row);
    acc: flat fp32, ``nb = ceil(len(acc)/block)``. Returns fp32 (len(acc),).
    """
    n = acc.shape[0]
    accp = _pad_to_block(acc.astype(jnp.float32), block)
    nb = accp.shape[0] // block
    assert vals.shape == idxs.shape and vals.shape[0] == nb, (
        vals.shape, idxs.shape, nb,
    )
    dense = (
        jnp.zeros((nb, block), jnp.float32)
        .at[jnp.arange(nb)[:, None], idxs]
        .add(vals.astype(jnp.float32))
    )
    out = accp.reshape(nb, block) + jnp.asarray(w, jnp.float32) * dense
    return out.reshape(nb * block)[:n]
