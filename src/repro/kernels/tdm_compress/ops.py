"""jit'd wrappers for blockwise int8 TDM payload compression."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.tdm_compress.tdm_compress import (
    dequant_accumulate_fwd,
    dequantize_fwd,
    quantize_fwd,
    quantize_scaled_fwd,
    scatter_accumulate_fwd,
    topk_sparsify_fwd,
)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_payload(
    x: jax.Array, *, block: int = 1024, interpret: bool = False
) -> Tuple[jax.Array, jax.Array, Tuple[int, ...]]:
    """Any-shaped tensor -> (int8 payload, blockwise scales, orig shape).

    Padding to the block boundary happens inside :func:`quantize_fwd`; the
    returned payload has exactly ``x.size`` entries.
    """
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    q, s = quantize_fwd(flat, block=block, interpret=interpret)
    return q, s, shape


@functools.partial(jax.jit, static_argnames=("shape", "block", "interpret"))
def dequantize_payload(
    q: jax.Array, scales: jax.Array, shape: Tuple[int, ...], *,
    block: int = 1024, interpret: bool = False,
) -> jax.Array:
    x = dequantize_fwd(q, scales, block=block, interpret=interpret)
    n = 1
    for d in shape:
        n *= d
    return x[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequant_accumulate(
    q: jax.Array, scales: jax.Array, acc: jax.Array, w: jax.Array, *,
    block: int = 1024, interpret: bool = False,
) -> jax.Array:
    """Fused ``acc + w * dequant(q, scales)`` over a flat payload."""
    return dequant_accumulate_fwd(
        q, scales, acc, w, block=block, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_scaled(
    x: jax.Array, scales: jax.Array, *, block: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """int8-encode a flat payload with shared blockwise scales."""
    return quantize_scaled_fwd(
        x.reshape(-1).astype(jnp.float32), scales, block=block,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def topk_sparsify(
    x: jax.Array, *, k: int, block: int = 1024, interpret: bool = False
):
    """Fused blockwise top-k select+scatter over a flat payload:
    ``(dense, vals (nb, k), idxs (nb, k))``."""
    return topk_sparsify_fwd(
        x.reshape(-1).astype(jnp.float32), k, block=block, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def scatter_accumulate(
    vals: jax.Array, idxs: jax.Array, acc: jax.Array, w: jax.Array, *,
    block: int = 1024, interpret: bool = False,
) -> jax.Array:
    """Fused ``acc + w * scatter(vals at block-local idxs)``."""
    return scatter_accumulate_fwd(
        vals, idxs, acc, w, block=block, interpret=interpret
    )
