"""Pallas TPU kernels: blockwise int8 quantization of TDM payloads, plus the
fused receive-side dequant + weighted-accumulate pass.

The ISL (ICI) link is the scarce resource in constellation-scale TDM
exchange (DESIGN.md §3); quantizing gossip payloads to int8 on-chip before
``ppermute`` cuts link bytes 4x. One fused pass per block: absmax reduce ->
scale -> round/clip -> int8 store, blocked to VMEM-sized tiles.

The receive side of the fused exchange engine (:mod:`repro.core.fused`)
accumulates Metropolis-weighted dequantized payloads, one matching at a
time: ``acc += w * (q * scale)``. Doing dequant and accumulate in one kernel
keeps the int8 payload from ever materializing as fp32 in HBM — a single
pass over the buffer per matching.

Grid (n/block,); tiles (block,) live fully in VMEM (block = 1024 fp32 =
4 KiB in, 1 KiB out). Scales are written per block (fp32). Arbitrary
lengths are handled by zero-padding up to the next block boundary (zeros
never raise a block's absmax, and padded lanes are sliced off on the way
out).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this class as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _pad_to_block(x: jax.Array, block: int) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    return x


def _quant_scaled_kernel(x_ref, s_ref, q_ref):
    x = x_ref[...].astype(jnp.float32)                    # (1, block)
    q_ref[...] = jnp.clip(
        jnp.round(x / s_ref[0, 0]), -127, 127
    ).astype(jnp.int8)


def _topk_kernel(k: int, x_ref, dense_ref, v_ref, i_ref):
    """Blockwise top-|x| selection: k rounds of masked argmax over the tile.

    Selection key is |x| with NaN ranked above +inf; ties break toward the
    lowest index — the exact order of the stable descending argsort in
    ``topk_sparsify_ref``, so vals/idxs match the oracle elementwise.
    """
    x = x_ref[...].astype(jnp.float32)                    # (1, block)
    block = x.shape[1]
    key = jnp.where(jnp.isnan(x), jnp.inf, jnp.abs(x))
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)  # (1, block)
    out_pos = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

    def body(t, carry):
        live, sel, vals, idxs = carry
        hit = live == jnp.max(live)
        # lowest index among the maxima (killed lanes hold key -1, below
        # every remaining |x| >= 0, so they can never be re-picked)
        idx_t = jnp.min(jnp.where(hit, col, block))
        chosen = col == idx_t
        v_t = jnp.sum(jnp.where(chosen, x, 0.0))
        at_t = out_pos == t
        return (
            jnp.where(chosen, -1.0, live),
            sel | chosen,
            jnp.where(at_t, v_t, vals),
            jnp.where(at_t, idx_t, idxs),
        )

    init = (
        key,
        jnp.zeros(x.shape, dtype=jnp.bool_),
        jnp.zeros((1, k), jnp.float32),
        jnp.zeros((1, k), jnp.int32),
    )
    _, sel, vals, idxs = jax.lax.fori_loop(0, k, body, init)
    dense_ref[...] = jnp.where(sel, x, 0.0)
    v_ref[...] = vals
    i_ref[...] = idxs


def _scatter_acc_kernel(v_ref, i_ref, acc_ref, w_ref, out_ref):
    vals = v_ref[...].astype(jnp.float32)                 # (1, k)
    idxs = i_ref[...]                                     # (1, k)
    col = jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 1)  # (1, block)
    hit = idxs[0, :, None] == col[0, None, :]             # (k, block)
    dense = jnp.sum(
        jnp.where(hit, vals[0, :, None], 0.0), axis=0, keepdims=True
    )
    out_ref[...] = acc_ref[...] + w_ref[0, 0] * dense


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (1, block)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    s_ref[0, 0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def _dequant_acc_kernel(q_ref, s_ref, acc_ref, w_ref, out_ref):
    out_ref[...] = acc_ref[...] + w_ref[0, 0] * (
        q_ref[...].astype(jnp.float32) * s_ref[0, 0]
    )


def quantize_fwd(x: jax.Array, *, block: int = 1024, interpret: bool = False):
    """x: flat (n,) any length -> (q int8 (n,), scales fp32 (ceil(n/block),)).

    Lengths that are not block multiples are zero-padded internally; the
    padded tail is sliced off ``q`` (the last scale still reflects only the
    real entries, since zero padding cannot raise the block absmax).
    """
    n = x.shape[0]
    x = _pad_to_block(x, block)
    nb = x.shape[0] // block
    x2 = x.reshape(nb, block)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(x2)
    return q.reshape(nb * block)[:n], s.reshape(nb)


def dequantize_fwd(q: jax.Array, scales: jax.Array, *, block: int = 1024,
                   interpret: bool = False):
    """Inverse of :func:`quantize_fwd`; returns fp32 of q's (unpadded) length."""
    n = q.shape[0]
    q = _pad_to_block(q, block)
    nb = q.shape[0] // block
    assert scales.shape[0] == nb, (scales.shape, nb, block)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(q.reshape(nb, block), scales.reshape(nb, 1))
    return x.reshape(nb * block)[:n]


def dequant_accumulate_fwd(
    q: jax.Array,
    scales: jax.Array,
    acc: jax.Array,
    w: jax.Array,
    *,
    block: int = 1024,
    interpret: bool = False,
):
    """Fused receive side: ``acc + w * dequant(q, scales)`` in one pass.

    q: integer (n,) — int8 gossip payloads, or the quantize-once relay's
    int16 partial sums; scales: fp32 (ceil(n/block),); acc: fp32 (n,);
    w: scalar (the per-node Metropolis weight of the matching this payload
    arrived on — a traced value inside shard_map). Returns fp32 (n,).
    """
    n = q.shape[0]
    q = _pad_to_block(q, block)
    acc = _pad_to_block(acc.astype(jnp.float32), block)
    nb = q.shape[0] // block
    assert scales.shape[0] == nb, (scales.shape, nb, block)
    w2 = jnp.asarray(w, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _dequant_acc_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(q.reshape(nb, block), scales.reshape(nb, 1), acc.reshape(nb, block), w2)
    return out.reshape(nb * block)[:n]


def quantize_scaled_fwd(
    x: jax.Array,
    scales: jax.Array,
    *,
    block: int = 1024,
    interpret: bool = False,
):
    """Quantize with caller-supplied blockwise scales (one kernel pass).

    The quantize-once relay contract: every node on a route encodes with
    the SAME shared scales (``pmax`` of the local blockwise scales), so a
    payload pays exactly one quantize/dequant pair end-to-end no matter how
    many hops it rides. x: flat (n,); scales: fp32 (ceil(n/block),),
    strictly positive. Returns q int8 (n,).
    """
    n = x.shape[0]
    x = _pad_to_block(x.astype(jnp.float32), block)
    nb = x.shape[0] // block
    assert scales.shape[0] == nb, (scales.shape, nb, block)
    q = pl.pallas_call(
        _quant_scaled_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.int8),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(x.reshape(nb, block), scales.reshape(nb, 1))
    return q.reshape(nb * block)[:n]


def topk_sparsify_fwd(
    x: jax.Array,
    k: int,
    *,
    block: int = 1024,
    interpret: bool = False,
):
    """Fused blockwise top-k select+scatter: one pass emits the sparsified
    dense buffer AND the wire payload, no host-side gather.

    x: flat (n,) -> ``(dense (n,) fp32, vals (nb, k) fp32, idxs (nb, k)
    int32 block-local)`` with ``nb = ceil(n/block)``; semantics (selection
    key, NaN/tie order) match :func:`..ref.topk_sparsify_ref` bit-for-bit.
    ``k`` is the static per-block budget, ``0 <= k <= block``.
    """
    if not 0 <= k <= block:
        raise ValueError(f"per-block k must be in [0, {block}], got {k}")
    n = x.shape[0]
    x = _pad_to_block(x.astype(jnp.float32), block)
    nb = x.shape[0] // block
    if k == 0:
        # zero-size VMEM tiles are not a thing; the empty payload is static
        return (
            jnp.zeros((n,), jnp.float32),
            jnp.zeros((nb, 0), jnp.float32),
            jnp.zeros((nb, 0), jnp.int32),
        )
    dense, vals, idxs = pl.pallas_call(
        functools.partial(_topk_kernel, k),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.float32),
            jax.ShapeDtypeStruct((nb, k), jnp.float32),
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(x.reshape(nb, block))
    return dense.reshape(nb * block)[:n], vals, idxs


def scatter_accumulate_fwd(
    vals: jax.Array,
    idxs: jax.Array,
    acc: jax.Array,
    w: jax.Array,
    *,
    block: int = 1024,
    interpret: bool = False,
):
    """Fused top-k receive side: ``acc + w * scatter(vals at idxs)`` in one
    pass over the buffer — the dense contribution never materializes in HBM.

    vals/idxs: (nb, k) as produced by :func:`topk_sparsify_fwd` (indices
    unique within each block row); acc: flat fp32 with
    ``nb = ceil(len(acc)/block)``; w: scalar. Returns fp32 (len(acc),).
    """
    n = acc.shape[0]
    acc = _pad_to_block(acc.astype(jnp.float32), block)
    nb = acc.shape[0] // block
    assert vals.shape == idxs.shape and vals.shape[0] == nb, (
        vals.shape, idxs.shape, nb,
    )
    k = vals.shape[1]
    if k == 0:
        return acc.reshape(nb * block)[:n]
    w2 = jnp.asarray(w, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _scatter_acc_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(vals, idxs, acc.reshape(nb, block), w2)
    return out.reshape(nb * block)[:n]
