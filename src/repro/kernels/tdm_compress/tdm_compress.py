"""Pallas TPU kernels: blockwise int8 quantization of TDM payloads, plus the
fused receive-side dequant + weighted-accumulate pass.

The ISL (ICI) link is the scarce resource in constellation-scale TDM
exchange (DESIGN.md §3); quantizing gossip payloads to int8 on-chip before
``ppermute`` cuts link bytes 4x. One fused pass per block: absmax reduce ->
scale -> round/clip -> int8 store, blocked to VMEM-sized tiles.

The receive side of the fused exchange engine (:mod:`repro.core.fused`)
accumulates Metropolis-weighted dequantized payloads, one matching at a
time: ``acc += w * (q * scale)``. Doing dequant and accumulate in one kernel
keeps the int8 payload from ever materializing as fp32 in HBM — a single
pass over the buffer per matching.

Grid (n/block,); tiles (block,) live fully in VMEM (block = 1024 fp32 =
4 KiB in, 1 KiB out). Scales are written per block (fp32). Arbitrary
lengths are handled by zero-padding up to the next block boundary (zeros
never raise a block's absmax, and padded lanes are sliced off on the way
out).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this class as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _pad_to_block(x: jax.Array, block: int) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    return x


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (1, block)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    s_ref[0, 0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def _dequant_acc_kernel(q_ref, s_ref, acc_ref, w_ref, out_ref):
    out_ref[...] = acc_ref[...] + w_ref[0, 0] * (
        q_ref[...].astype(jnp.float32) * s_ref[0, 0]
    )


def quantize_fwd(x: jax.Array, *, block: int = 1024, interpret: bool = False):
    """x: flat (n,) any length -> (q int8 (n,), scales fp32 (ceil(n/block),)).

    Lengths that are not block multiples are zero-padded internally; the
    padded tail is sliced off ``q`` (the last scale still reflects only the
    real entries, since zero padding cannot raise the block absmax).
    """
    n = x.shape[0]
    x = _pad_to_block(x, block)
    nb = x.shape[0] // block
    x2 = x.reshape(nb, block)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(x2)
    return q.reshape(nb * block)[:n], s.reshape(nb)


def dequantize_fwd(q: jax.Array, scales: jax.Array, *, block: int = 1024,
                   interpret: bool = False):
    """Inverse of :func:`quantize_fwd`; returns fp32 of q's (unpadded) length."""
    n = q.shape[0]
    q = _pad_to_block(q, block)
    nb = q.shape[0] // block
    assert scales.shape[0] == nb, (scales.shape, nb, block)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(q.reshape(nb, block), scales.reshape(nb, 1))
    return x.reshape(nb * block)[:n]


def dequant_accumulate_fwd(
    q: jax.Array,
    scales: jax.Array,
    acc: jax.Array,
    w: jax.Array,
    *,
    block: int = 1024,
    interpret: bool = False,
):
    """Fused receive side: ``acc + w * dequant(q, scales)`` in one pass.

    q: int8 (n,); scales: fp32 (ceil(n/block),); acc: fp32 (n,); w: scalar
    (the per-node Metropolis weight of the matching this payload arrived
    on — a traced value inside shard_map). Returns fp32 (n,).
    """
    n = q.shape[0]
    q = _pad_to_block(q, block)
    acc = _pad_to_block(acc.astype(jnp.float32), block)
    nb = q.shape[0] // block
    assert scales.shape[0] == nb, (scales.shape, nb, block)
    w2 = jnp.asarray(w, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _dequant_acc_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(q.reshape(nb, block), scales.reshape(nb, 1), acc.reshape(nb, block), w2)
    return out.reshape(nb * block)[:n]
