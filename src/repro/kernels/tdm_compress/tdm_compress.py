"""Pallas TPU kernel: blockwise int8 quantization of TDM payloads.

The ISL (ICI) link is the scarce resource in constellation-scale TDM
exchange (DESIGN.md §3); quantizing gossip payloads to int8 on-chip before
``ppermute`` cuts link bytes 4x. One fused pass per block: absmax reduce ->
scale -> round/clip -> int8 store, blocked to VMEM-sized tiles.

Grid (n/block,); tiles (block,) live fully in VMEM (block = 1024 fp32 =
4 KiB in, 1 KiB out). Scales are written per block (fp32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this class as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (1, block)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    s_ref[0, 0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def quantize_fwd(x: jax.Array, *, block: int = 1024, interpret: bool = False):
    """x: flat (n,) -> (q int8 (n,), scales fp32 (n/block,))."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    nb = n // block
    x2 = x.reshape(nb, block)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(x2)
    return q.reshape(n), s.reshape(nb)


def dequantize_fwd(q: jax.Array, scales: jax.Array, *, block: int = 1024,
                   interpret: bool = False):
    n = q.shape[0]
    nb = n // block
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(q.reshape(nb, block), scales.reshape(nb, 1))
    return x.reshape(n)
