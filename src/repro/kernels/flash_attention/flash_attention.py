"""Pallas TPU flash attention kernel (forward).

Grid (B*H, n_q_blocks, n_kv_blocks); the kv axis is the innermost
('arbitrary') dimension, so the online-softmax accumulators live in VMEM
scratch and persist across kv steps. GQA is done by the K/V BlockSpec
index maps (head h reads kv head h // G) — KV is never repeated in HBM.

VMEM tiling (per grid step):
    q block  (block_q, head_dim)    bf16/fp32
    k block  (block_k, head_dim)
    v block  (block_k, head_dim)
    acc      (block_q, head_dim)    fp32 scratch
    m, l     (block_q, 1)           fp32 scratch

MXU alignment: block_q/block_k multiples of 128, head_dim padded to 128 by
ops.py when needed. Causal/window blocks outside the q block's statically
reachable range are skipped with pl.when (no FLOPs on the skipped path).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this class as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int],
    softcap: Optional[float], block_q: int, block_k: int, nk: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # static-skip bounds are enforced by pl.when on positions:
    q_lo = qi * block_q
    k_lo = ki * block_k
    needed = True
    if causal:
        # any work iff k_lo <= q_hi
        needed = k_lo <= q_lo + block_q - 1
    if window is not None:
        needed = jnp.logical_and(needed, k_lo + block_k - 1 > q_lo - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                    # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                          # (bq, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                       # (bq, bk)
        l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,        # (BH, Sq, hd)
    k: jax.Array,        # (BKV, Skv, hd)
    v: jax.Array,
    *,
    group: int,          # H // KV (BlockSpec head folding)
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, hd = q.shape
    BKV, Skv, _ = k.shape
    assert BH == BKV * group, (BH, BKV, group)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    nq, nk = Sq // block_q, Skv // block_k
    scale = hd ** -0.5

    kernel = functools.partial(
        _fa_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, nk=nk,
    )
    grid = (BH, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b // group, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(q, k, v)
