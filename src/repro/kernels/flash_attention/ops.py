"""jit'd public wrapper for the flash attention kernel.

Handles layout: (B, S, H, hd) model layout -> (B*H, S, hd) kernel layout,
GQA head folding (no KV repeat — the kernel's BlockSpec maps head h to kv
head h // G), head_dim padding to the MXU lane width (128), and the
interpret switch for CPU validation.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_k", "interpret"
    ),
)
def flash_attention(
    q: jax.Array,        # (B, Sq, H, hd)
    k: jax.Array,        # (B, Skv, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV

    # MXU lane alignment: pad head_dim to 128 (zeros don't change qk^T or pv)
    hd_pad = max(128, -(-hd // 128) * 128)
    if hd_pad != hd:
        pad = ((0, 0), (0, 0), (0, 0), (0, hd_pad - hd))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        # qk^T over zero-padded lanes is exact; the scale must still use the
        # ORIGINAL head_dim — the kernel derives it from the padded shape, so
        # pre-scale q here to compensate.
        q = q * jnp.asarray((hd_pad / hd) ** 0.5, q.dtype)

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd_pad)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd_pad)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd_pad)

    out = flash_attention_fwd(
        qf, kf, vf,
        group=G, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    out = out.reshape(B, H, Sq, hd_pad).transpose(0, 2, 1, 3)
    return out[..., :hd]
