"""Pure-jnp oracle for the flash attention kernel.

Independent of the model-layer implementation on purpose: materialized
scores, explicit masks, fp32 softmax. Layout matches the kernel:
q (B, Sq, H, hd), k/v (B, Skv, KV, hd), GQA via head grouping.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    q5 = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q5, kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)
