"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid (BH, n_chunks); the chunk axis is 'arbitrary' (sequential), carrying
the (P, N) recurrent state in VMEM scratch. Each chunk step is three
MXU matmuls ((Q,N)x(N,Q), (Q,Q)x(Q,P), (P,Q)x(Q,N)) plus elementwise decay
math — exactly the structure of models/mamba2.ssd_chunked, one (batch·head)
per grid row.

VMEM tiling per step: x (Q,P), B/C (Q,N), dt rows (Q,1), state (P,N),
L-matrix (Q,Q). With Q=P=64..256 and N=128 everything is MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this class as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, state_scr,
    *, chunk: int, nc: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1)
    A = a_ref[0].astype(jnp.float32)          # (1,) per-head decay coeff
    B = b_ref[0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0].astype(jnp.float32)          # (Q, N)

    l = dt * A                                 # (Q,1) negative decays
    cum = jnp.cumsum(l, axis=0)                # (Q,1) inclusive
    cum_last = cum[-1:]                        # (1,1)

    # inter-chunk: y_t += exp(cum_t) * C_t . S_prev
    state = state_scr[...]                     # (P, N)
    y_inter = jnp.exp(cum) * jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # (Q, P)

    # intra-chunk: W[t,s] = (C_t.B_s) exp(cum_t - cum_s) dt_s ; s <= t
    CB = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (Q, Q)
    Ldec = jnp.exp(cum - cum.T)                # (Q, Q): exp(cum_t - cum_s)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    W = jnp.where(si <= ti, CB * Ldec, 0.0) * dt.T
    y_intra = jax.lax.dot_general(
        W, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (Q, P)

    y_ref[0] = (y_inter + y_intra).astype(y_ref.dtype)

    # state update: S = exp(cum_Q) S + sum_s exp(cum_Q - cum_s) dt_s x_s B_s^T
    decay_to_end = jnp.exp(cum_last - cum) * dt            # (Q,1)
    xw = x * decay_to_end                                   # (Q,P)
    new_state = jnp.exp(cum_last) * state + jax.lax.dot_general(
        xw, B, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (P, N)
    state_scr[...] = new_state

    @pl.when(ci == nc - 1)
    def _flush():
        state_out_ref[0] = new_state


def ssd_scan_fwd(
    x: jax.Array,      # (BH, S, P)
    dt: jax.Array,     # (BH, S, 1) fp32
    A: jax.Array,      # (BH, 1) fp32 negative
    B: jax.Array,      # (BH, S, N)
    C: jax.Array,      # (BH, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    BH, S, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nc=nc)
    y, final_state = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, 1), lambda b, ci: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, P, N), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(x, dt, A, B, C)
    return y, final_state
