"""Pure-jnp sequential-scan oracle for the SSD kernel.

The exact recurrence, one timestep at a time (O(S) sequential — slow but
unambiguous):

    S_t = exp(dt_t * A) * S_{t-1} + dt_t * (B_t ⊗ x_t)
    y_t = C_t · S_t

Layout matches the kernel: x (BH, S, P), dt (BH, S) [post-softplus],
A (BH,) negative, B/C (BH, S, N).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jax.Array,      # (BH, S, P)
    dt: jax.Array,     # (BH, S) fp32
    A: jax.Array,      # (BH,) fp32, negative
    B: jax.Array,      # (BH, S, N)
    C: jax.Array,      # (BH, S, N)
    init_state=None,   # (BH, P, N)
):
    BH, S, P = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def step(state, t):
        decay = jnp.exp(dt[:, t] * A)                      # (BH,)
        outer = jnp.einsum("bp,bn->bpn", xf[:, t], Bf[:, t])
        state = decay[:, None, None] * state + dt[:, t][:, None, None] * outer
        y_t = jnp.einsum("bn,bpn->bp", Cf[:, t], state)
        return state, y_t

    state0 = (
        jnp.zeros((BH, P, N), jnp.float32) if init_state is None
        else init_state.astype(jnp.float32)
    )
    state, ys = jax.lax.scan(step, state0, jnp.arange(S))
    y = ys.transpose(1, 0, 2).astype(x.dtype)              # (BH, S, P)
    return y, state
