"""jit'd wrapper for the SSD kernel: model layout (B,S,H,P) + per-head A
and group-shared B/C -> kernel layout (B*H, S, ...)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    xh: jax.Array,     # (B, S, H, P)
    dt: jax.Array,     # (B, S, H) fp32 post-softplus
    A: jax.Array,      # (H,) fp32 negative
    Bv: jax.Array,     # (B, S, G, N) group-shared
    Cv: jax.Array,     # (B, S, G, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    B_, S, H, P = xh.shape
    G, N = Bv.shape[2], Bv.shape[3]
    r = H // G
    xf = xh.transpose(0, 2, 1, 3).reshape(B_ * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B_ * H, S, 1).astype(jnp.float32)
    Af = jnp.broadcast_to(A[None, :], (B_, H)).reshape(B_ * H, 1).astype(jnp.float32)
    # expand groups -> heads (broadcast, then fold)
    Bh = jnp.broadcast_to(
        Bv[:, :, :, None, :], (B_, S, G, r, N)
    ).transpose(0, 2, 3, 1, 4).reshape(B_ * H, S, N)
    Ch = jnp.broadcast_to(
        Cv[:, :, :, None, :], (B_, S, G, r, N)
    ).transpose(0, 2, 3, 1, 4).reshape(B_ * H, S, N)

    y, state = ssd_scan_fwd(
        xf, dtf, Af, Bh, Ch, chunk=chunk, interpret=interpret
    )
    y = y.reshape(B_, H, S, P).transpose(0, 2, 1, 3)
    state = state.reshape(B_, H, P, N)
    return y, state
