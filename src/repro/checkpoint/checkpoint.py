"""Checkpointing: msgpack + zstd (stdlib zlib fallback when the optional
``zstandard`` package is absent), async save, content hashes, elastic
reshard-on-restore.

Layout per checkpoint directory (``<dir>/step_<N>/``):

    manifest.msgpack   {step, keys: {path: {shape, dtype, bytes, sha256}},
                        tree_hash, meta}
    data.msgpack.zst   {path: raw bytes}

Fault-tolerance contract:
- ``save`` writes to ``step_<N>.tmp`` then atomically renames — a crash
  mid-save never corrupts the latest checkpoint.
- every tensor carries a sha256; ``restore`` verifies before use.
- ``restore`` takes optional shardings: tensors are placed shard-by-shard
  via ``jax.make_array_from_callback`` for whatever mesh the NEW job has —
  elastic rescale = restore with different shardings, no resave needed.
- ``keep`` bounds disk usage; old checkpoints are pruned after a
  successful save (never before).
"""

from __future__ import annotations

import hashlib
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zlib

try:  # optional: better ratio/speed when available
    import zstandard
except ImportError:  # pragma: no cover - depends on environment
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"

_SAVE_LOCK = threading.Lock()
_PENDING: List[threading.Thread] = []


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, level=6)


def _decompress(raw: bytes) -> bytes:
    if raw[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ImportError(
                "checkpoint was written with zstd but the 'zstandard' "
                "package is not installed (pip install zstandard)"
            )
        return zstandard.ZstdDecompressor().decompress(raw)
    return zlib.decompress(raw)


def _tree_def_hash(keys: List[str]) -> str:
    h = hashlib.sha256()
    for k in keys:
        h.update(k.encode())
    return h.hexdigest()[:16]


def save(
    ckpt_dir: str | os.PathLike,
    step: int,
    tree: Any,
    meta: Optional[Dict] = None,
    keep: int = 3,
    async_save: bool = True,
) -> threading.Thread | None:
    """Serialize ``tree`` (pytree of arrays) for ``step``. Returns the
    writer thread when async (join it or call wait_all())."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    # snapshot to host memory synchronously (device buffers may mutate next step)
    flat = _flatten_with_paths(tree)
    host = [(k, np.asarray(jax.device_get(v))) for k, v in flat]

    def write():
        with _SAVE_LOCK:
            final = ckpt_dir / f"step_{step:010d}"
            tmp = ckpt_dir / f"step_{step:010d}.tmp"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "keys": {}, "meta": meta or {}}
            blobs = {}
            for k, arr in host:
                raw = arr.tobytes()
                manifest["keys"][k] = {
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "bytes": len(raw),
                    "sha256": hashlib.sha256(raw).hexdigest(),
                }
                blobs[k] = raw
            manifest["tree_hash"] = _tree_def_hash(sorted(blobs))
            with open(tmp / "data.msgpack.zst", "wb") as f:
                f.write(_compress(msgpack.packb(blobs, use_bin_type=True)))
            with open(tmp / "manifest.msgpack", "wb") as f:
                f.write(msgpack.packb(manifest, use_bin_type=True))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            _prune(ckpt_dir, keep)

    if async_save:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _PENDING.append(t)
        return t
    write()
    return None


def wait_all() -> None:
    for t in list(_PENDING):
        t.join()
        _PENDING.remove(t)


def _prune(ckpt_dir: pathlib.Path, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:010d}", ignore_errors=True)


def all_steps(ckpt_dir: str | os.PathLike) -> List[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    out = []
    if not ckpt_dir.exists():
        return out
    for p in ckpt_dir.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and p.is_dir():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str | os.PathLike) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str | os.PathLike,
    step: Optional[int] = None,
    target: Any = None,
    shardings: Any = None,
) -> Tuple[int, Any]:
    """Load a checkpoint. With ``target`` (a pytree of like-structured
    arrays/ShapeDtypeStructs) the tree structure is rebuilt; with
    ``shardings`` each tensor is placed for the CURRENT mesh (elastic
    reshard-on-restore). Returns (step, tree)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:010d}"
    with open(d / "manifest.msgpack", "rb") as f:
        manifest = msgpack.unpackb(f.read(), raw=False)
    with open(d / "data.msgpack.zst", "rb") as f:
        blobs = msgpack.unpackb(_decompress(f.read()), raw=False)

    arrays: Dict[str, np.ndarray] = {}
    for k, info in manifest["keys"].items():
        raw = blobs[k]
        if hashlib.sha256(raw).hexdigest() != info["sha256"]:
            raise IOError(f"checkpoint corruption: sha256 mismatch for {k}")
        arrays[k] = np.frombuffer(raw, dtype=np.dtype(info["dtype"])).reshape(
            info["shape"]
        )

    if target is None:
        return step, arrays

    flat = _flatten_with_paths(target)
    sh_flat = _flatten_with_paths(shardings) if shardings is not None else None
    leaves = []
    for i, (k, tgt) in enumerate(flat):
        if k not in arrays:
            raise KeyError(f"checkpoint missing tensor {k}")
        arr = arrays[k]
        want_dtype = np.dtype(
            tgt.dtype if hasattr(tgt, "dtype") else np.float32
        )
        if arr.dtype != want_dtype:
            arr = arr.astype(want_dtype)
        if sh_flat is not None:
            sh = sh_flat[i][1]
            leaves.append(
                jax.make_array_from_callback(arr.shape, sh, lambda idx, a=arr: a[idx])
            )
        else:
            leaves.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(target)
    return step, jax.tree_util.tree_unflatten(treedef, leaves)
