"""Deterministic synthetic LM data pipeline, host-sharded.

Every batch is a pure function of (seed, step, shard) — no state, no I/O —
so restarts/elastic rescale reproduce the exact token stream (checkpointed
``step`` is all you need). The token process is a noisy affine walk over the
vocab, giving a learnable structure (loss decreases under training) while
staying trivially cheap to generate.

For multi-host runs, :func:`global_batch` builds a
``jax.make_array_from_callback`` global array where each host materializes
only its addressable shard.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np

from repro.models.config import ModelConfig, ShapeConfig


def _rng_for(seed: int, step: int, row: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(step, row))
    )


def synth_tokens(vocab: int, seq: int, rng: np.random.Generator) -> np.ndarray:
    """Noisy affine token walk: x_{t+1} = (a x_t + b + eps) mod V."""
    a = int(rng.integers(3, 17)) | 1
    b = int(rng.integers(0, vocab))
    x = np.empty(seq + 1, dtype=np.int64)
    x[0] = rng.integers(0, vocab)
    noise = rng.integers(0, 3, size=seq)
    for t in range(seq):
        x[t + 1] = (a * x[t] + b + noise[t]) % vocab
    return x


def host_batch(
    cfg: ModelConfig,
    shape: ShapeConfig,
    step: int,
    seed: int = 0,
    rows: Optional[range] = None,
) -> Dict[str, np.ndarray]:
    """Materialize (a slice of) the global batch for one host."""
    B, S = shape.global_batch, shape.seq_len
    rows = rows if rows is not None else range(B)
    toks = np.empty((len(rows), S + 1), dtype=np.int32)
    for i, r in enumerate(rows):
        toks[i] = synth_tokens(cfg.vocab_size, S, _rng_for(seed, step, r))
    batch: Dict[str, np.ndarray] = {
        "tokens": toks[:, :S],
        "labels": toks[:, 1:],
    }
    if cfg.mrope_sections is not None:
        pos = np.broadcast_to(np.arange(S)[None, :, None], (len(rows), S, 3))
        batch["positions"] = np.ascontiguousarray(pos, dtype=np.int32)
    if cfg.enc_dec:
        rng = _rng_for(seed, step, 10_000_000)
        batch["enc_embeds"] = rng.standard_normal(
            (len(rows), cfg.enc_frames, cfg.d_model), dtype=np.float32
        )
    return batch


def global_batch(
    cfg: ModelConfig,
    shape: ShapeConfig,
    step: int,
    shardings: Dict[str, jax.sharding.NamedSharding],
    seed: int = 0,
) -> Dict[str, jax.Array]:
    """Build global device arrays; each host generates only its shard rows."""
    out = {}
    host = host_batch(cfg, shape, step, seed)

    for name, arr in host.items():
        sh = shardings[name]

        def cb(index, arr=arr):
            return arr[index]

        out[name] = jax.make_array_from_callback(arr.shape, sh, cb)
    return out


@dataclasses.dataclass
class SyntheticStream:
    """Stateless iterator facade used by launch/train.py."""

    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        return host_batch(self.cfg, self.shape, step, self.seed)
