"""The 10 assigned architectures as exact configs, plus reduced smoke
variants of each family.

Sources as assigned (``[source; tier]`` from the task sheet). Head dims use
the published values where the d_model/n_heads quotient differs from the
real model (gemma2-9b: 256, gemma2-27b: 128, qwen3-moe: 128 — q/o projections
are rectangular, exactly as in the HF checkpoints).

Per-arch distribution defaults (fsdp / opt_dtype / micro_steps) encode what
the roofline requires at 256–512 chips; they are hillclimb levers in §Perf.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.models.config import MambaConfig, ModelConfig, MoEConfig, ShapeConfig, SHAPES

ARCHS: Dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# --- mamba2-780m [ssm] 48L d=1536 attn-free vocab=50280 ssm_state=128 --------
# SSD (state-space duality) [arXiv:2405.21060]
MAMBA2_780M = _register(ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0, n_kv_heads=0, head_dim=0,   # attention-free
    d_ff=0,
    no_ffn=True,
    attn_free=True,
    vocab_size=50_280,
    mamba=MambaConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
    tie_embeddings=True,
    # §Perf: 780M params on 256 chips drown in TP all-reduces; pure ZeRO-3
    # (batch over the whole mesh) makes per-layer traffic = weight gathers
    parallel_mode="fsdp_pure",
))

# --- gemma2-9b [dense] 42L d=3584 16H (GQA kv=8) ff=14336 vocab=256000 -------
# local+global alternating, logit softcap [arXiv:2408.00118]
GEMMA2_9B = _register(ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14_336,
    vocab_size=256_000,
    local_global_alternate=True,
    sliding_window=4_096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
    # §Perf iteration 4: fsdp_pure lifted this cell 8.6% -> 27.3% MFU
    parallel_mode="fsdp_pure",
))

# --- gemma2-27b [dense] 46L d=4608 32H (GQA kv=16) ff=36864 vocab=256000 -----
GEMMA2_27B = _register(ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    local_global_alternate=True,
    sliding_window=4_096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
))

# --- granite-20b [dense] 52L d=6144 48H (GQA kv=1 = MQA) ff=24576 ------------
# llama-arch, code [arXiv:2405.04324]
GRANITE_20B = _register(ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    gated_mlp=False,       # GPT-BigCode lineage: 2-matrix MLP
    act="gelu",
    tie_embeddings=True,
))

# --- qwen2-72b [dense] 80L d=8192 64H (GQA kv=8) ff=29568 vocab=152064 -------
# GQA + QKV bias [arXiv:2407.10671]
QWEN2_72B = _register(ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29_568,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    fsdp=True,
    micro_steps=4,
))

# --- jamba-1.5-large-398b [hybrid] 72L d=8192 64H (GQA kv=8) ff=24576 --------
# Mamba+attn 1:7, MoE 16e top-2 every other layer [arXiv:2403.19887]
JAMBA_1_5_LARGE = _register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    attn_every=8,
    mamba=MambaConfig(d_state=128, head_dim=64, expand=2, n_groups=8),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=24_576, every=2),
    tie_embeddings=True,
    fsdp=True,
    micro_steps=4,
    # serving: 398B params exceed TP-16 HBM; stationary 2D expert shard
    serve_parallel_mode="tp2d",
))

# --- qwen3-moe-30b-a3b [moe] 48L d=2048 32H (GQA kv=4) ff=768 128e top-8 -----
# [hf:Qwen/Qwen3-30B-A3B]
QWEN3_MOE_30B = _register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=6144,                       # dense-equivalent (unused: all-MoE)
    vocab_size=151_936,
    rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff=768, every=1),
    tie_embeddings=True,
))

# --- kimi-k2-1t-a32b [moe] 61L d=7168 64H (GQA kv=8) ff=2048 384e top-8 ------
# trillion-param MoE [arXiv:2501.kimi2 paper-table]
KIMI_K2_1T = _register(ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=22_528,                     # dense-equivalent (unused: all-MoE)
    vocab_size=163_840,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, every=1),
    tie_embeddings=False,
    fsdp=True,
    param_dtype="bfloat16",
    opt_dtype="int8",
    micro_steps=8,
    # §Perf iteration 3: pipeline parallelism (PP16xTP16, 64 microbatches)
    # replaced FSDP gather-per-microbatch: collective 196s -> 63s/step.
    pp_stages=16,
    pp_micro=64,
    # §Perf iteration 5: serving keeps experts stationary (E x F 2D shard;
    # fits 9.2 GB/device) instead of FSDP gather-per-token
    serve_parallel_mode="tp2d",
))

# --- whisper-base [audio] 6L(+6 enc) d=512 8H ff=2048 vocab=51865 ------------
# enc-dec, conv frontend STUB [arXiv:2212.04356]
WHISPER_BASE = _register(ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
    enc_dec=True,
    n_enc_layers=6,
    enc_frames=1536,       # whisper's 1500, padded to the 512-block tiling
    act="gelu",
    tie_embeddings=True,
    attn_block_q=512,
    attn_block_k=512,
))

# --- qwen2-vl-72b [vlm] 80L d=8192 64H (GQA kv=8) ff=29568 -------------------
# M-RoPE, dynamic resolution; patch frontend STUB [arXiv:2409.12191]
QWEN2_VL_72B = _register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29_568,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),     # t/h/w frequency pairs (sum = hd/2)
    tie_embeddings=False,
    fsdp=True,
    micro_steps=4,
))


# ---------------------------------------------------------------------------
# per-(arch, shape) config adjustments + cell validity
# ---------------------------------------------------------------------------

def long_context_applicable(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic families (DESIGN.md §5)."""
    return cfg.family in ("ssm", "hybrid")


def decode_applicable(cfg: ModelConfig) -> bool:
    return True  # all assigned archs are decoders (whisper via its decoder)


def cfg_for_cell(cfg: ModelConfig, shape: ShapeConfig) -> Optional[ModelConfig]:
    """Shape-specialized config, or None if the cell is skipped."""
    if shape.name == "long_500k":
        if not long_context_applicable(cfg):
            return None
        if cfg.family == "hybrid":
            # Jamba long-context serving: windowed attention layers (the
            # arch's effective-context design), mamba layers carry state.
            cfg = cfg.replace(force_local=True, sliding_window=4_096)
    if shape.kind == "train":
        # microbatching only matters for training cells
        return cfg
    return cfg.replace(micro_steps=1)


def smoke_cfg(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: tiny widths, few layers/experts, small
    vocab — used by per-arch CPU smoke tests."""
    kw = dict(
        n_layers=len_scan_unit(cfg) * 2,
        d_model=64,
        vocab_size=128,
        norm_eps=1e-6,
        attn_block_q=8,
        attn_block_k=8,
        loss_chunk=16,
        micro_steps=1,
        enc_frames=12 if cfg.enc_dec else cfg.enc_frames,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)), head_dim=16)
        if cfg.mrope_sections is not None:
            half = 16 // 2  # smoke head_dim = 16
            t = half // 4
            h = (half - t) // 2
            kw.update(mrope_sections=(t, h, half - t - h))
    if cfg.d_ff:
        kw.update(d_ff=96)
    if cfg.moe is not None:
        kw.update(moe=MoEConfig(
            n_experts=4, top_k=2, d_ff=32, every=cfg.moe.every,
            capacity_factor=4.0,   # generous: smoke tests assume no drops
        ))
    if cfg.mamba is not None:
        kw.update(mamba=MambaConfig(
            d_state=16, head_dim=8, expand=2,
            n_groups=min(cfg.mamba.n_groups, 2), chunk=8,
        ))
    if cfg.sliding_window is not None:
        kw.update(sliding_window=16)
    return cfg.replace(**kw)


def len_scan_unit(cfg: ModelConfig) -> int:
    from repro.models.transformer import scan_unit

    return len(scan_unit(cfg))


def get(name: str) -> ModelConfig:
    return ARCHS[name]


def all_cells():
    """Yield every valid (arch cfg, shape) cell — 40 minus inapplicable."""
    for name, cfg in ARCHS.items():
        for shape in SHAPES.values():
            c = cfg_for_cell(cfg, shape)
            if c is not None:
                yield name, shape.name, c, shape
