"""AdamW in pure JAX: cosine/warmup schedule, global-norm clipping, and
quantized (int8) moment storage for HBM-critical models (kimi-k2).

Moment quantization is row-wise symmetric int8 (one fp32 scale per
trailing-axis row — the 8-bit-Adam recipe adapted to keep the tensor's
sharding: scales drop only the last axis, so the moment tensors shard
exactly like their parameters).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    end_lr_frac: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    dtype: str = "float32"       # float32 | bfloat16 | int8


def schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    warm = cfg.peak_lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.peak_lr * (
        cfg.end_lr_frac + (1 - cfg.end_lr_frac) * 0.5 * (1 + jnp.cos(math.pi * t))
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos).astype(jnp.float32)


# ---------------------------------------------------------------------------
# quantized moment storage
# ---------------------------------------------------------------------------

class QTensor(NamedTuple):
    q: jax.Array        # int8, same shape as the param
    scale: jax.Array    # fp32, param.shape[:-1] + (1,)


def _quantize(x: jax.Array) -> QTensor:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale.astype(jnp.float32))


def _dequantize(t: QTensor) -> jax.Array:
    return t.q.astype(jnp.float32) * t.scale


def _store(x: jax.Array, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    return x.astype(jnp.bfloat16 if dtype == "bfloat16" else jnp.float32)


def _load(x) -> jax.Array:
    if isinstance(x, QTensor):
        return _dequantize(x)
    return x.astype(jnp.float32)


# ---------------------------------------------------------------------------
# state / update
# ---------------------------------------------------------------------------

def init_opt_state(params: Any, cfg: OptConfig) -> Dict[str, Any]:
    def zeros_like_store(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _store(z, cfg.dtype)

    return {
        "mu": jax.tree.map(zeros_like_store, params),
        "nu": jax.tree.map(zeros_like_store, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree)
        )
    )


def apply_updates(
    params: Any,
    grads: Any,
    state: Dict[str, Any],
    cfg: OptConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    count = state["count"] + 1
    lr = schedule(state["count"], cfg)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    is_q = lambda x: isinstance(x, QTensor)

    def update_leaf(p, g, mu_s, nu_s):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * _load(mu_s) + (1 - cfg.b1) * g
        nu = cfg.b2 * _load(nu_s) + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** count)
        nu_hat = nu / (1 - cfg.b2 ** count)
        upd = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if jnp.issubdtype(p.dtype, jnp.floating):
            new_p = (
                p.astype(jnp.float32) - lr * (upd + cfg.weight_decay * p.astype(jnp.float32))
            ).astype(p.dtype)
        else:  # non-float leaves pass through
            new_p = p
        return new_p, _store(mu, cfg.dtype), _store(nu, cfg.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.flatten(state["mu"], is_leaf=is_q)[0]
    flat_nu = jax.tree.flatten(state["nu"], is_leaf=is_q)[0]
    out = [update_leaf(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, metrics
