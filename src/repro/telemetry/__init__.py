"""Flight-recorder telemetry: spans/counters/gauges/histograms/events,
Chrome-trace + Prometheus export, oracle reconciliation of compiled rounds
(ISSUE 6), and the mission-control layer (ISSUE 9): route-provenance
audits and self-describing run reports.

Quick use::

    from repro import telemetry

    with telemetry.record_scope(tracing=True) as rec:
        ... run FL rounds ...
        telemetry.write_trace("trace.json", rec)        # -> Perfetto
        print(telemetry.metrics_snapshot(rec)["counters"])
        telemetry.write_report("mission", rec)          # -> .md + .json

Counters, gauges, and histograms are default-on (host-side dict/bisect
work, zero device syncs); spans, events, and per-round
``block_until_ready`` wall-clock timing exist only under ``tracing=True``;
``reconcile=True`` verifies every newly compiled round/window against the
static collective oracles. :func:`audit_window_programs` replays a planned
window sequence hop by hop and returns a structured verdict.
"""

from repro.telemetry.audit import (
    AuditError,
    AuditReport,
    AuditViolation,
    PayloadTrail,
    audit_recorder,
    audit_window_programs,
    expected_sink_weights,
)
from repro.telemetry.export import (
    chrome_trace,
    metrics_snapshot,
    prometheus_text,
    trace_scope,
    write_metrics,
    write_prometheus,
    write_trace,
)
from repro.telemetry.metrics import (
    Histogram,
    get_gauge,
    get_histogram,
    histograms_summary,
    observe,
    ratio_gauge,
    set_gauge,
)
from repro.telemetry.report import (
    mission_report,
    render_markdown,
    write_report,
)
from repro.telemetry.reconcile import (
    ReconcileReport,
    ReconciliationError,
    check_compiled,
    compare,
    compile_and_check,
    compiled_collective_counts,
    expected_hierarchical_collectives,
    expected_tdm_collectives,
)
from repro.telemetry.recorder import (
    Event,
    Recorder,
    Span,
    counters_snapshot,
    get_recorder,
    record_scope,
    set_reconcile,
    set_tracing,
    tracing_enabled,
)

__all__ = [
    "AuditError",
    "AuditReport",
    "AuditViolation",
    "Event",
    "Histogram",
    "PayloadTrail",
    "Recorder",
    "ReconcileReport",
    "ReconciliationError",
    "Span",
    "audit_recorder",
    "audit_window_programs",
    "check_compiled",
    "chrome_trace",
    "compare",
    "compile_and_check",
    "compiled_collective_counts",
    "counters_snapshot",
    "expected_hierarchical_collectives",
    "expected_sink_weights",
    "expected_tdm_collectives",
    "get_gauge",
    "get_histogram",
    "get_recorder",
    "histograms_summary",
    "metrics_snapshot",
    "mission_report",
    "observe",
    "prometheus_text",
    "ratio_gauge",
    "record_scope",
    "render_markdown",
    "set_gauge",
    "set_reconcile",
    "set_tracing",
    "trace_scope",
    "tracing_enabled",
    "write_metrics",
    "write_prometheus",
    "write_report",
    "write_trace",
]
