"""Flight-recorder telemetry: spans/counters/events, Chrome-trace export,
and oracle reconciliation of compiled rounds (see ISSUE 6).

Quick use::

    from repro import telemetry

    with telemetry.record_scope(tracing=True) as rec:
        ... run FL rounds ...
        telemetry.write_trace("trace.json", rec)        # -> Perfetto
        print(telemetry.metrics_snapshot(rec)["counters"])

Counters are default-on (host-side dict bumps, zero device syncs); spans,
events, and per-round ``block_until_ready`` wall-clock timing exist only
under ``tracing=True``; ``reconcile=True`` verifies every newly compiled
round/window against the static collective oracles.
"""

from repro.telemetry.export import (
    chrome_trace,
    metrics_snapshot,
    trace_scope,
    write_metrics,
    write_trace,
)
from repro.telemetry.reconcile import (
    ReconcileReport,
    ReconciliationError,
    check_compiled,
    compare,
    compile_and_check,
    compiled_collective_counts,
    expected_hierarchical_collectives,
    expected_tdm_collectives,
)
from repro.telemetry.recorder import (
    Event,
    Recorder,
    Span,
    counters_snapshot,
    get_recorder,
    record_scope,
    set_reconcile,
    set_tracing,
    tracing_enabled,
)

__all__ = [
    "Event",
    "Recorder",
    "ReconcileReport",
    "ReconciliationError",
    "Span",
    "check_compiled",
    "chrome_trace",
    "compare",
    "compile_and_check",
    "compiled_collective_counts",
    "counters_snapshot",
    "expected_hierarchical_collectives",
    "expected_tdm_collectives",
    "get_recorder",
    "metrics_snapshot",
    "record_scope",
    "set_reconcile",
    "set_tracing",
    "trace_scope",
    "tracing_enabled",
    "write_metrics",
    "write_trace",
]
