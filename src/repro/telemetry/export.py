"""Trace + metrics export for the flight recorder.

Two artifact kinds:

- :func:`chrome_trace` / :func:`write_trace` — the Chrome Trace Event
  JSON object format (the ``{"traceEvents": [...]}`` shape), loadable in
  Perfetto (https://ui.perfetto.dev) and ``chrome://tracing``. Spans
  become ``"X"`` complete events, instant events become ``"i"``, and the
  final counter values ride in ``otherData`` plus one ``"C"`` counter
  sample per counter so they show up in the UI's counter track.
- :func:`metrics_snapshot` / :func:`write_metrics` — a flat JSON dict of
  counters, gauges, histogram percentile summaries, and per-span-name
  timing aggregates, the machine-readable summary the benchmark harness
  embeds in its ``BENCH_<name>.json`` files.
- :func:`prometheus_text` / :func:`write_prometheus` — the same metrics
  in Prometheus-style text exposition (counters/gauges as single samples,
  histograms as cumulative ``_bucket{le=...}`` series plus ``_sum`` /
  ``_count``), so a run snapshot can be pushed at a scrape endpoint or
  diffed with standard tooling.

The exported event list is sorted by timestamp; ``tests/test_telemetry.py``
checks the schema (valid JSON, required keys, monotonic non-negative
timestamps) so traces stay loadable as instrumentation grows.
"""

from __future__ import annotations

import contextlib
import json
import pathlib
from typing import Any, Dict, Iterator, List, Optional

from repro.telemetry.metrics import histograms_summary
from repro.telemetry.recorder import Recorder, get_recorder, record_scope

_PID = 0  # single-process flight recorder; lanes are encoded as tids


def chrome_trace(rec: Optional[Recorder] = None) -> Dict[str, Any]:
    """Render a recording as a Chrome Trace Event Format object."""
    rec = rec or get_recorder()
    events: List[Dict[str, Any]] = [
        {
            "ph": "M",
            "name": "process_name",
            "pid": _PID,
            "ts": 0.0,
            "args": {"name": "repro flight recorder"},
        }
    ]
    for s in rec.spans:
        events.append(
            {
                "ph": "X",
                "name": s.name,
                "cat": s.cat,
                "pid": _PID,
                "tid": s.tid,
                "ts": s.t_start_us,
                "dur": s.dur_us,
                "args": s.args,
            }
        )
    for e in rec.events:
        events.append(
            {
                "ph": "i",
                "s": "t",
                "name": e.name,
                "cat": e.cat,
                "pid": _PID,
                "tid": e.tid,
                "ts": e.t_us,
                "args": e.args,
            }
        )
    t_end = max((ev["ts"] + ev.get("dur", 0.0) for ev in events), default=0.0)
    for name, value in sorted(rec.counters.items()):
        events.append(
            {
                "ph": "C",
                "name": name,
                "pid": _PID,
                "ts": t_end,
                "args": {"value": value},
            }
        )
    events.sort(key=lambda ev: (ev["ts"], ev["ph"] != "M"))
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "counters": dict(sorted(rec.counters.items())),
            "gauges": dict(sorted(rec.gauges.items())),
            "meta": dict(rec.meta),
        },
    }


def write_trace(path, rec: Optional[Recorder] = None) -> pathlib.Path:
    """Write the Chrome trace JSON to ``path`` (parents created)."""
    out = pathlib.Path(path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(chrome_trace(rec)))
    return out


def metrics_snapshot(rec: Optional[Recorder] = None) -> Dict[str, Any]:
    """Counters, gauges, histogram percentile digests, and per-span timing
    aggregates as one flat JSON-able dict."""
    rec = rec or get_recorder()
    return {
        "counters": dict(sorted(rec.counters.items())),
        "gauges": dict(sorted(rec.gauges.items())),
        "histograms": histograms_summary(rec),
        "spans": rec.span_stats(),
        "n_spans": len(rec.spans),
        "n_events": len(rec.events),
        "meta": dict(rec.meta),
    }


def write_metrics(path, rec: Optional[Recorder] = None) -> pathlib.Path:
    out = pathlib.Path(path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(metrics_snapshot(rec), indent=1))
    return out


def _prom_name(name: str) -> str:
    """Dotted recorder names -> Prometheus metric names (``[a-zA-Z0-9_]``,
    non-digit first char — every recorder name already starts with a
    subsystem word, so prefixing is unnecessary)."""
    return "".join(c if c.isalnum() else "_" for c in name)


def _prom_value(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def prometheus_text(rec: Optional[Recorder] = None) -> str:
    """Render the recorder as Prometheus-style text exposition.

    Counters and gauges become single samples with ``# TYPE`` headers;
    histograms become the standard cumulative ``_bucket{le="..."}`` series
    (``+Inf`` bucket == ``_count``) plus ``_sum`` and ``_count`` samples.
    """
    rec = rec or get_recorder()
    lines: List[str] = []
    for name, value in sorted(rec.counters.items()):
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} counter")
        lines.append(f"{pn} {_prom_value(value)}")
    for name, value in sorted(rec.gauges.items()):
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} gauge")
        lines.append(f"{pn} {_prom_value(value)}")
    for name in sorted(rec.hists):
        h = rec.hists[name]
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} histogram")
        for bound, cum in zip(h.bounds, h.cumulative()):
            lines.append(f'{pn}_bucket{{le="{_prom_value(bound)}"}} {cum}')
        lines.append(f'{pn}_bucket{{le="+Inf"}} {h.count}')
        lines.append(f"{pn}_sum {_prom_value(h.total)}")
        lines.append(f"{pn}_count {h.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_prometheus(path, rec: Optional[Recorder] = None) -> pathlib.Path:
    out = pathlib.Path(path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(prometheus_text(rec))
    return out


@contextlib.contextmanager
def trace_scope(
    trace_path=None, *, reconcile: Optional[bool] = None
) -> Iterator[Recorder]:
    """:func:`record_scope` wired for CLI ``--trace out.json`` flags:
    tracing is on iff a path was given, and the Chrome trace is written
    there when the scope exits (even on error — a crashed run's trace is
    the one you want most)."""
    with record_scope(
        tracing=bool(trace_path) if trace_path else None,
        reconcile=reconcile,
    ) as rec:
        try:
            yield rec
        finally:
            if trace_path:
                out = write_trace(trace_path, rec)
                print(f"wrote trace to {out}", flush=True)
